"""L2 model tests: canonical parameters pinned to the Rust side, the
section-3.1 decomposition, and every Figure variant's shape/dtype
contract against the ref oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def test_canonical_values_pinned_to_rust():
    # Must match rust/src/figures.rs::canonical_values_stable exactly.
    w = model.canonical_weight(3, 3)
    np.testing.assert_array_equal(
        w.reshape(-1), [-11, -8, -5, -4, -1, 2, 3, 6, 9]
    )
    b = model.canonical_bias(3)
    np.testing.assert_array_equal(b, [-50, -37, -24])
    k = model.canonical_conv_kernel(1, 1, 2, 2)
    np.testing.assert_array_equal(k.reshape(-1), [-9, -8, -2, -1])
    x = model.canonical_input(1, 4, 42)
    np.testing.assert_array_equal(x.reshape(-1), [40, 71, 88, 9])


def test_decompose_paper_example():
    # Section 3.1: 1/3 -> integer scale ~11184811 at shift 25.
    qs, shift = model.decompose(1.0 / 3.0)
    assert shift == 25
    assert qs in (11184810, 11184811)
    # Every decomposition must be exactly representable in f32.
    for m in (0.25, 1 / 192, 1 / 48, 1 / 96, 1 / 24, 0.9, 3.7):
        qs, shift = model.decompose(m)
        assert qs <= 1 << 24
        assert float(np.float32(qs)) == qs


@pytest.mark.parametrize("name", sorted(model.VARIANTS))
@pytest.mark.parametrize("batch", [1, 8])
def test_variant_contract(name, batch):
    fn, input_builder = model.VARIANTS[name]
    x = input_builder(batch)
    out = np.asarray(fn(jnp.asarray(x)))
    assert out.shape[0] == batch
    if name == "fig3_conv":
        assert out.shape == (batch, 4, 8, 8)
    else:
        assert out.shape == (batch, model.FC_OUT)
    if name in ("fig2_fc_relu", "fig6_sigmoid_f16"):
        assert out.dtype == np.uint8
    else:
        assert out.dtype == np.int8


def test_fig1_matches_ref_oracle():
    x = jnp.asarray(model.canonical_input(4, model.FC_IN, 1))
    qs, shift = model.decompose(1.0 / 192.0)
    want = ref.fig_fc(
        x,
        jnp.asarray(model.canonical_weight(model.FC_IN, model.FC_OUT)),
        jnp.asarray(model.canonical_bias(model.FC_OUT)),
        float(qs),
        2.0 ** -shift,
    )
    got = model.fig1_fc(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fig3_matches_ref_oracle():
    x = jnp.asarray(model.canonical_input(2, 64, 3).reshape(2, 1, 8, 8))
    want = ref.fig_conv(
        x,
        jnp.asarray(model.canonical_conv_kernel(4, 1, 3, 3)),
        jnp.asarray(model.canonical_bias(4)),
        1.0 / 64.0,
    )
    got = model.fig3_conv(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fig4_vs_fig5_range_and_precision():
    # Same input, different codified ranges: fig4 maps the full +-4 tanh
    # range (coarser, saturates), fig5 evaluates in f16 on +-2 (finer).
    # Both stay in the int8 domain and visibly differ (precision choice
    # is observable in the output, which is the point of the two figures).
    x = jnp.asarray(model.canonical_input(8, model.FC_IN, 9))
    y4 = np.asarray(model.fig4_tanh_int8(x)).astype(np.int32)
    y5 = np.asarray(model.fig5_tanh_f16(x)).astype(np.int32)
    assert y4.min() >= -127 and y4.max() <= 127
    assert y5.min() >= -127 and y5.max() <= 127
    assert (y4 != y5).sum() > 0
