"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, dtypes and seeds; exact equality is required —
these are integer/quantized pipelines where "close" is not a thing.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline image: deterministic fallback sweep
    from _hyp_compat import given, settings, st

from compile.kernels import act as act_k
from compile.kernels import conv_int8 as conv_k
from compile.kernels import matmul_int8 as mm_k
from compile.kernels import ref


def rand_i8(rng, shape):
    return rng.integers(-128, 128, size=shape, dtype=np.int64).astype(np.int8)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 16),
    k=st.integers(1, 64),
    n=st.integers(1, 32),
    seed=st.integers(0, 2**32 - 1),
)
def test_matmul_int8_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand_i8(rng, (m, k))
    w = rand_i8(rng, (k, n))
    got = mm_k.matmul_int8(jnp.asarray(x), jnp.asarray(w), block_m=m, block_n=n)
    want = ref.matmul_integer(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_matmul_int8_tiled_grid():
    # Multi-tile grid must agree with the single-tile result.
    rng = np.random.default_rng(7)
    x = rand_i8(rng, (16, 64))
    w = rand_i8(rng, (64, 32))
    whole = mm_k.matmul_int8(jnp.asarray(x), jnp.asarray(w), block_m=16, block_n=32)
    tiled = mm_k.matmul_int8(jnp.asarray(x), jnp.asarray(w), block_m=4, block_n=8)
    np.testing.assert_array_equal(np.asarray(whole), np.asarray(tiled))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    relu=st.booleans(),
    unsigned=st.booleans(),
    qs=st.integers(1, 1 << 24),
    shift=st.integers(0, 31),
)
def test_fc_requant_matches_ref(seed, relu, unsigned, qs, shift):
    rng = np.random.default_rng(seed)
    m, k, n = 4, 16, 8
    x = rand_i8(rng, (m, k))
    w = rand_i8(rng, (k, n))
    b = rng.integers(-1000, 1000, size=n, dtype=np.int32)
    out_dtype = jnp.uint8 if unsigned else jnp.int8
    got = mm_k.fc_requant(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
        float(qs), 2.0 ** -shift, relu=relu, out_dtype=out_dtype,
        block_m=m, block_n=n,
    )
    want = ref.fig_fc(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
        float(qs), 2.0 ** -shift, relu_after=relu, out_dtype=out_dtype,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fc_requant_round_half_even():
    # acc = 3, multiplier 0.5 -> 1.5 -> rounds to 2? No: half-even -> 2.
    # acc = 5 -> 2.5 -> 2 (even), distinguishing from round-half-away.
    x = jnp.asarray(np.array([[1]], dtype=np.int8))
    w = jnp.asarray(np.array([[1]], dtype=np.int8))
    for acc, want in [(3, 2), (5, 2), (1, 0), (-3, -2), (-5, -2)]:
        b = jnp.asarray(np.array([acc - 1], dtype=np.int32))
        got = mm_k.fc_requant(x, w, b, 1.0, 0.5, block_m=1, block_n=1)
        assert int(np.asarray(got)[0, 0]) == want, (acc, want)


def test_fc_requant_saturates():
    x = jnp.asarray(np.full((1, 1), 127, dtype=np.int8))
    w = jnp.asarray(np.full((1, 1), 127, dtype=np.int8))
    b = jnp.asarray(np.zeros(1, dtype=np.int32))
    got = mm_k.fc_requant(x, w, b, 1.0, 1.0, block_m=1, block_n=1)
    assert int(np.asarray(got)[0, 0]) == 127
    got = mm_k.fc_requant(x, -w, b, 1.0, 1.0, block_m=1, block_n=1)
    assert int(np.asarray(got)[0, 0]) == -128


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    act=st.sampled_from(["tanh", "sigmoid"]),
    f16=st.booleans(),
)
def test_act_float_matches_ref(seed, act, f16):
    rng = np.random.default_rng(seed)
    q8 = rand_i8(rng, (32,))
    in_scale, out_scale = 4.0 / 127.0, 1.0 / 127.0
    out_dtype = jnp.uint8 if act == "sigmoid" else jnp.int8
    got = act_k.act_float(jnp.asarray(q8), act, f16, in_scale, out_scale,
                          out_dtype=out_dtype)
    x = ref.dequantize_linear(jnp.asarray(q8), in_scale)
    if act == "tanh":
        y = ref.tanh_f16(x) if f16 else jnp.tanh(x)
    else:
        y = ref.sigmoid_f16(x) if f16 else 1.0 / (1.0 + jnp.exp(-x))
    want = ref.quantize_linear(y, out_scale, out_dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    act=st.sampled_from(["tanh", "sigmoid"]),
    f16=st.booleans(),
)
def test_act_lut_matches_float_pipeline(seed, act, f16):
    # The hardware ROM and the literal float pipeline must agree exactly
    # at full 8-bit index width (same claim as rust hwsim::lut tests).
    rng = np.random.default_rng(seed)
    q8 = rand_i8(rng, (64,))
    in_scale, out_scale = 2.0 / 127.0, 1.0 / 127.0
    out_dtype = jnp.uint8 if act == "sigmoid" else jnp.int8
    via_lut = act_k.act_lut(jnp.asarray(q8), act, f16, in_scale, out_scale,
                            out_dtype=out_dtype)
    via_float = act_k.act_float(jnp.asarray(q8), act, f16, in_scale,
                                out_scale, out_dtype=out_dtype)
    np.testing.assert_array_equal(np.asarray(via_lut), np.asarray(via_float))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), batch=st.integers(1, 3))
def test_conv_int8_matches_ref(seed, batch):
    rng = np.random.default_rng(seed)
    x = rand_i8(rng, (batch, 1, 8, 8))
    w = rand_i8(rng, (4, 1, 3, 3))
    b = rng.integers(-500, 500, size=4, dtype=np.int32)
    got = conv_k.conv_int8_requant(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), 1.0 / 64.0
    )
    want = ref.fig_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), 1.0 / 64.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_linear_dtype_selection():
    x = jnp.asarray(np.array([-300.0, -0.5, 0.5, 300.0], dtype=np.float32))
    q_i8 = ref.quantize_linear(x, 1.0, jnp.int8)
    q_u8 = ref.quantize_linear(x, 1.0, jnp.uint8)
    assert q_i8.dtype == jnp.int8
    assert q_u8.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(q_i8), [-128, 0, 0, 127])
    np.testing.assert_array_equal(np.asarray(q_u8), [0, 0, 0, 255])
