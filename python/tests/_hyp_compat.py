"""Deterministic fallback for the `hypothesis` API used by test_kernel.py.

The offline image ships numpy/pytest/jax but not hypothesis. When the real
library is importable the tests use it unchanged (CI installs it); otherwise
this shim samples a fixed number of pseudo-random cases from the declared
strategies with a seeded generator, so the suite still sweeps shapes/dtypes
reproducibly instead of being skipped.
"""

import numpy as np

_SEED = 0xC0FFEE
_DEFAULT_EXAMPLES = 20


class _Strategy:
    def sample(self, rng):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def sample(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))


class _Booleans(_Strategy):
    def sample(self, rng):
        return bool(rng.integers(0, 2))


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def sample(self, rng):
        return self.options[int(rng.integers(0, len(self.options)))]


class st:
    """Mirror of the tiny slice of `hypothesis.strategies` the tests use."""

    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)


def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        def wrapper():
            rng = np.random.default_rng(_SEED)
            examples = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            for _ in range(examples):
                kwargs = {k: s.sample(rng) for k, s in strategies.items()}
                fn(**kwargs)

        # Deliberately NOT functools.wraps: pytest must see a zero-argument
        # signature, not the strategy parameters of the wrapped function.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
