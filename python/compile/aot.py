"""AOT pipeline: lower every Figure variant to HLO *text* + manifest.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the image's xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Python runs ONLY here, at build time. The Rust runtime
(``rust/src/runtime``) loads ``artifacts/<variant>_b<batch>.hlo.txt``
via the PJRT C API and never touches Python again.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

BATCHES = (1, 8)


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"variants": {}}
    for name, (fn, input_builder) in model.VARIANTS.items():
        entries = []
        for batch in BATCHES:
            example = input_builder(batch)
            spec = jax.ShapeDtypeStruct(example.shape, example.dtype)
            lowered = jax.jit(lambda x, f=fn: (f(x),)).lower(spec)
            text = to_hlo_text(lowered)
            fname = f"{name}_b{batch}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            # Golden output for the canonical input (lets the Rust side
            # verify the PJRT round trip without running Python).
            out = np.asarray(fn(example))
            entries.append(
                {
                    "batch": batch,
                    "file": fname,
                    "input_dtype": str(example.dtype),
                    "input_shape": list(example.shape),
                    "output_dtype": str(out.dtype),
                    "output_shape": list(out.shape),
                    "golden_input_seed": 42,
                    "golden_output": out.reshape(-1).astype(int).tolist(),
                }
            )
            print(f"wrote {fname} ({len(text)} chars)")
        manifest["variants"][name] = entries

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json with {len(manifest['variants'])} variants")


if __name__ == "__main__":
    main()
