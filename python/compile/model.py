"""L2: the canonical Figure 1-6 models in JAX, calling the L1 kernels.

Parameters are generated from the SAME integer formulas as
``rust/src/figures.rs`` (pinned by tests on both sides), so the AOT
artifacts produced from these functions describe byte-identical networks
to the ONNX models the Rust stack builds — no weight files cross the
language boundary.

Formulas (keep in sync with rust/src/figures.rs):
* weight   w[i, j] = ((i*7 + j*3) mod 23) - 11          (int8)
* bias     b[j]    = ((j*13) mod 101) - 50              (int32)
* conv     w[m, c, i, j] = ((m*5 + c*3 + i*7 + j) mod 19) - 9
* rescale  decompose(multiplier): frac in [0.5, 1), qs = round(frac*2^24)
"""

import math

import jax.numpy as jnp
import numpy as np

from .kernels import act as act_k
from .kernels import conv_int8 as conv_k
from .kernels import matmul_int8 as mm_k

FC_IN = 64
FC_OUT = 32


def canonical_weight(k, n):
    i = np.arange(k)[:, None]
    j = np.arange(n)[None, :]
    return ((i * 7 + j * 3) % 23 - 11).astype(np.int8)


def canonical_bias(n):
    j = np.arange(n)
    return ((j * 13) % 101 - 50).astype(np.int32)


def canonical_conv_kernel(m, c, kh, kw):
    out = np.zeros((m, c, kh, kw), dtype=np.int8)
    for mi in range(m):
        for ci in range(c):
            for i in range(kh):
                for j in range(kw):
                    out[mi, ci, i, j] = (mi * 5 + ci * 3 + i * 7 + j) % 19 - 9
    return out


def canonical_input(batch, dim, seed):
    """SplitMix64 stream, identical to rust figures::canonical_input."""
    mask = (1 << 64) - 1
    gamma = 0x9E3779B97F4A7C15
    s = (seed + gamma) & mask
    vals = np.zeros(batch * dim, dtype=np.int8)
    for idx in range(batch * dim):
        s = (s + gamma) & mask
        z = s
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & mask
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask
        z = z ^ (z >> 31)
        vals[idx] = np.uint8((z >> 56) & 0xFF).astype(np.int8)
    return vals.reshape(batch, dim)


def decompose(multiplier, max_shift=31):
    """Section 3.1 decomposition, mirroring rust quant::rescale::decompose."""
    assert multiplier > 0
    e = math.floor(math.log2(multiplier)) + 1
    shift = 24 - e
    if shift > max_shift:
        shift = max_shift
    qs = round(multiplier * 2.0 ** shift)
    while qs > (1 << 24):
        qs = (qs + 1) >> 1
        shift -= 1
    return qs, shift


# --- figure model functions (int8 in -> int8/uint8 out) --------------------


def fig1_fc(x_q):
    """Fig. 1: FC, 2-Mul rescale (1/192), int8 out — fused L1 kernel."""
    qs, shift = decompose(1.0 / 192.0)
    return mm_k.fc_requant(
        x_q,
        jnp.asarray(canonical_weight(FC_IN, FC_OUT)),
        jnp.asarray(canonical_bias(FC_OUT)),
        float(qs),
        2.0 ** -shift,
        relu=False,
        out_dtype=jnp.int8,
    )


def fig2_fc_relu(x_q):
    """Fig. 2: FC + ReLU, 1-Mul rescale, uint8 out."""
    return mm_k.fc_requant(
        x_q,
        jnp.asarray(canonical_weight(FC_IN, FC_OUT)),
        jnp.asarray(canonical_bias(FC_OUT)),
        1.0 / 192.0,
        1.0,
        relu=True,
        out_dtype=jnp.uint8,
    )


def fig3_conv(x_q):
    """Fig. 3: ConvInteger 1->4 ch, 3x3 pad 1, 1-Mul rescale (1/64)."""
    return conv_k.conv_int8_requant(
        x_q,
        jnp.asarray(canonical_conv_kernel(4, 1, 3, 3)),
        jnp.asarray(canonical_bias(4)),
        1.0 / 64.0,
        relu=False,
        out_dtype=jnp.int8,
    )


def _fc_to_int8(x_q, multiplier):
    qs, shift = decompose(multiplier)
    return mm_k.fc_requant(
        x_q,
        jnp.asarray(canonical_weight(FC_IN, FC_OUT)),
        jnp.asarray(canonical_bias(FC_OUT)),
        float(qs),
        2.0 ** -shift,
        relu=False,
        out_dtype=jnp.int8,
    )


def fig4_tanh_int8(x_q):
    """Fig. 4: FC + int8 tanh (full range +-4 mapped onto int8)."""
    q8 = _fc_to_int8(x_q, 127.0 / (48.0 * 127.0))
    return act_k.act_float(q8, "tanh", False, 4.0 / 127.0, 1.0 / 127.0,
                           out_dtype=jnp.int8)


def fig5_tanh_f16(x_q):
    """Fig. 5: FC + genuine-f16 tanh on a narrow (+-2) range."""
    q8 = _fc_to_int8(x_q, 127.0 / (96.0 * 127.0))
    return act_k.act_float(q8, "tanh", True, 2.0 / 127.0, 1.0 / 127.0,
                           out_dtype=jnp.int8)


def fig6_sigmoid_f16(x_q):
    """Fig. 6: FC + f16 sigmoid, uint8 out (sigmoid >= 0)."""
    qs, shift = decompose(127.0 / (24.0 * 127.0))
    del qs, shift  # fig6 uses the 1-Mul form
    q8 = mm_k.fc_requant(
        x_q,
        jnp.asarray(canonical_weight(FC_IN, FC_OUT)),
        jnp.asarray(canonical_bias(FC_OUT)),
        127.0 / (24.0 * 127.0),
        1.0,
        relu=False,
        out_dtype=jnp.int8,
    )
    return act_k.act_float(q8, "sigmoid", True, 8.0 / 127.0, 1.0 / 255.0,
                           out_dtype=jnp.uint8)


#: variant name -> (fn, input builder(batch) -> np array)
VARIANTS = {
    "fig1_fc": (fig1_fc, lambda b: canonical_input(b, FC_IN, 42)),
    "fig2_fc_relu": (fig2_fc_relu, lambda b: canonical_input(b, FC_IN, 42)),
    "fig3_conv": (
        fig3_conv,
        lambda b: canonical_input(b, 64, 42).reshape(b, 1, 8, 8),
    ),
    "fig4_tanh_int8": (fig4_tanh_int8, lambda b: canonical_input(b, FC_IN, 42)),
    "fig5_tanh_f16": (fig5_tanh_f16, lambda b: canonical_input(b, FC_IN, 42)),
    "fig6_sigmoid_f16": (fig6_sigmoid_f16, lambda b: canonical_input(b, FC_IN, 42)),
}
