"""L1 Pallas kernels for the quantized activation tails (Figures 4-6).

Two realizations of the same codified stage:

* ``act_lut`` — the int8->int8 stage as a 256-entry table lookup, i.e.
  exactly what the fixed-point accelerator does (mirrors
  ``rust/src/hwsim/lut.rs``). The ROM is baked at trace time from the
  model's codified scales.
* ``act_float`` — the literal ONNX pipeline (Dequantize -> [f16 cast] ->
  Tanh/Sigmoid -> Quantize) as a Pallas kernel, matching the standard
  tooling path bit-for-bit.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def build_lut(act, f16, in_scale, out_scale, out_dtype):
    """Bake the 256-entry ROM: index = (q8 as u8), value = requantized
    activation output.

    Built with the SAME jnp ops as the float pipeline (XLA's f16
    transcendentals differ from numpy's by 1 ULP, and the ROM must
    reproduce the standard-tool path bit-exactly)."""
    q = np.arange(-128, 128, dtype=np.int32)
    x = jnp.asarray(q, dtype=jnp.float32) * jnp.float32(in_scale)
    if f16:
        x = x.astype(jnp.float16)
    if act == "tanh":
        y = jnp.tanh(x)
    elif act == "sigmoid":
        y = 1.0 / (1.0 + jnp.exp(-x))
    else:
        raise ValueError(act)
    y = np.asarray(y.astype(jnp.float32))
    info = np.iinfo(out_dtype)
    # np.round is round-half-even, matching ONNX QuantizeLinear.
    vals = np.clip(np.round(y / np.float32(out_scale)), info.min, info.max)
    # Table indexed by u8 reinterpretation of the int8 input.
    table = np.zeros(256, dtype=np.int32)
    table[(q & 0xFF)] = vals.astype(np.int32)
    return jnp.asarray(table)


def _lut_kernel(x_ref, t_ref, o_ref, *, out_dtype):
    idx = x_ref[...].astype(jnp.int32) & 0xFF
    o_ref[...] = t_ref[...][idx].astype(out_dtype)


def act_lut(q8, act, f16, in_scale, out_scale, out_dtype=jnp.int8):
    """Apply the activation stage via ROM lookup (hardware realization)."""
    table = build_lut(act, f16, in_scale, out_scale, out_dtype)
    flat = q8.reshape(-1)
    kernel = functools.partial(_lut_kernel, out_dtype=out_dtype)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(flat.shape, out_dtype),
        interpret=True,
    )(flat, table)
    return out.reshape(q8.shape)


def _float_kernel(x_ref, o_ref, *, act, f16, in_scale, out_scale, out_dtype):
    x = x_ref[...].astype(jnp.float32) * jnp.float32(in_scale)
    if f16:
        x = x.astype(jnp.float16)
    if act == "tanh":
        y = jnp.tanh(x)
    else:
        one = x.dtype.type(1.0) if hasattr(x.dtype, "type") else 1.0
        y = 1.0 / (1.0 + jnp.exp(-x))
        del one
    y = y.astype(jnp.float32)
    info = jnp.iinfo(out_dtype)
    q = jnp.round(y / jnp.float32(out_scale))
    o_ref[...] = jnp.clip(q, info.min, info.max).astype(out_dtype)


def act_float(q8, act, f16, in_scale, out_scale, out_dtype=jnp.int8):
    """The literal ONNX activation tail as a Pallas kernel."""
    flat = q8.reshape(-1)
    kernel = functools.partial(
        _float_kernel,
        act=act,
        f16=f16,
        in_scale=float(in_scale),
        out_scale=float(out_scale),
        out_dtype=out_dtype,
    )
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(flat.shape, out_dtype),
        interpret=True,
    )(flat)
    return out.reshape(q8.shape)
