"""L1 Pallas kernel: int8 GEMM with i32 accumulation and a fused
rescale + requantize epilogue.

Hardware adaptation (GPU->TPU, see DESIGN.md section "Hardware
adaptation"): the paper's target is a fixed-point ASIC with int8 MACs and
i32 accumulators. On TPU the analogue is the MXU with
``preferred_element_type=int32`` accumulation; VMEM plays the role of the
accelerator's SRAM, so we tile M x N with BlockSpec (K resident) and fuse
the section-3.1 rescale + round + clip into the same kernel so the i32
accumulator tile never leaves VMEM — the structural equivalent of the
ASIC's rescale unit sitting behind the MAC array.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); correctness is asserted against ``ref.py`` in
pytest, and TPU-perf is *estimated* from the BlockSpec in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_int8_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile: int8 x int8 -> int32."""
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def matmul_int8(x_q, w_q, block_m=None, block_n=None):
    """MatMulInteger semantics: [m,k] int8 x [k,n] int8 -> [m,n] int32."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (k, k2)
    bm = block_m or min(m, 128)
    bn = block_n or min(n, 128)
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_int8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x_q, w_q)


def _fc_requant_kernel(x_ref, w_ref, b_ref, o_ref, *, quant_scale,
                       quant_shift, relu, out_dtype):
    """Fused FC tile: MatMulInteger + bias + rescale + round/clip.

    The epilogue reproduces the ONNX chain bit-for-bit: Cast to f32,
    Mul by the integer-valued Quant_scale FLOAT, Mul by Quant_shift,
    (Relu,) then QuantizeLinear's round-half-even + saturation.
    """
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    acc = acc + b_ref[...].astype(jnp.int32)[None, :]
    f = acc.astype(jnp.float32)
    f = f * jnp.float32(quant_scale) * jnp.float32(quant_shift)
    if relu:
        f = jnp.maximum(f, 0.0)
    info = jnp.iinfo(out_dtype)
    q = jnp.round(f)
    o_ref[...] = jnp.clip(q, info.min, info.max).astype(out_dtype)


def fc_requant(x_q, w_q, b_q, quant_scale, quant_shift, relu=False,
               out_dtype=jnp.int8, block_m=None, block_n=None):
    """Figures 1/2 as ONE fused Pallas kernel (the paper's FC hot-spot).

    The i32 accumulator tile lives in VMEM only; HBM sees int8 in,
    int8/uint8 out — the memory-traffic profile of the ASIC datapath.
    """
    m, k = x_q.shape
    _, n = w_q.shape
    bm = block_m or min(m, 128)
    bn = block_n or min(n, 128)
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    kernel = functools.partial(
        _fc_requant_kernel,
        quant_scale=float(quant_scale),
        quant_shift=float(quant_shift),
        relu=relu,
        out_dtype=out_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=True,
    )(x_q, w_q, b_q)


def rescale_requant(acc_i32, quant_scale, quant_shift, relu=False,
                    out_dtype=jnp.int8):
    """Standalone rescale+requantize Pallas kernel (vector epilogue as
    its own stage, used by the conv path where the GEMM runs separately).
    """
    def kernel(a_ref, o_ref):
        f = a_ref[...].astype(jnp.float32)
        f = f * jnp.float32(quant_scale) * jnp.float32(quant_shift)
        if relu:
            f = jnp.maximum(f, 0.0)
        info = jnp.iinfo(out_dtype)
        o_ref[...] = jnp.clip(jnp.round(f), info.min, info.max).astype(out_dtype)

    flat = acc_i32.reshape(-1)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(flat.shape, out_dtype),
        interpret=True,
    )(flat)
    return out.reshape(acc_i32.shape)
