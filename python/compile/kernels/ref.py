"""Pure-jnp oracle implementing the ONNX operator semantics.

This is the correctness ground truth for the Pallas kernels (pytest
compares kernel vs ref) and mirrors, operation for operation, the Rust
``ops/`` implementations — so L1 (Pallas), L2 (JAX) and L3 (Rust interp)
all agree on the same contract:

* ``MatMulInteger``: int8/uint8 x int8 -> int32 accumulation.
* rescale (paper section 3.1): f32 multiply by integer ``Quant_scale``
  (stored as FLOAT) then by ``Quant_shift`` = 2**-N.
* ``QuantizeLinear``: round half-to-even, saturate, dtype from the
  zero-point (int8 vs uint8).
* ``DequantizeLinear``, f32/f16 ``Tanh``/``Sigmoid``.
"""

import jax.numpy as jnp


def matmul_integer(x_q, w_q):
    """ONNX MatMulInteger with zero-point 0: int32 accumulation."""
    return jnp.matmul(
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def quantize_linear(x, scale, out_dtype):
    """ONNX QuantizeLinear (zero_point = 0): saturating round-half-even.

    jnp.round implements round-half-to-even, matching the ONNX spec and
    the Rust ``ops::qlinear::round_half_even``.
    """
    info = jnp.iinfo(out_dtype)
    q = jnp.round(x / jnp.float32(scale))
    return jnp.clip(q, info.min, info.max).astype(out_dtype)


def dequantize_linear(q, scale):
    """ONNX DequantizeLinear (zero_point = 0)."""
    return q.astype(jnp.float32) * jnp.float32(scale)


def rescale(acc_i32, quant_scale, quant_shift):
    """Paper section 3.1 rescale: Cast INT32->FLOAT then Mul, Mul.

    ``quant_scale`` is the integer-valued FLOAT; ``quant_shift`` is
    2**-N. Passing quant_shift=1.0 degenerates to the 1-Mul form.
    """
    f = acc_i32.astype(jnp.float32)
    return f * jnp.float32(quant_scale) * jnp.float32(quant_shift)


def relu(x):
    return jnp.maximum(x, 0)


def tanh_f16(x_f32):
    """Fig. 5: Cast FLOAT->FLOAT16, Tanh in f16, Cast back."""
    return jnp.tanh(x_f32.astype(jnp.float16)).astype(jnp.float32)


def sigmoid_f16(x_f32):
    """Fig. 6: sigmoid evaluated in f16."""
    h = x_f32.astype(jnp.float16)
    one = jnp.float16(1.0)
    return (one / (one + jnp.exp(-h))).astype(jnp.float32)


# --- full figure patterns (the oracles for model.py) -----------------------


def fig_fc(x_q, w_q, b_q, quant_scale, quant_shift, relu_after=False,
           out_dtype=jnp.int8):
    """Figures 1/2: MatMulInteger + Add + Cast + Mul(+Mul) [+Relu] +
    QuantizeLinear(scale=1)."""
    acc = matmul_integer(x_q, w_q) + b_q.astype(jnp.int32)
    f = rescale(acc, quant_scale, quant_shift)
    if relu_after:
        f = relu(f)
    return quantize_linear(f, 1.0, out_dtype)


def fig_act(x_q, w_q, b_q, quant_scale, quant_shift, act, f16, in_scale,
            out_scale, out_dtype):
    """Figures 4/5/6: fig_fc -> Dequantize -> [f16] act -> Quantize."""
    q8 = fig_fc(x_q, w_q, b_q, quant_scale, quant_shift, out_dtype=jnp.int8)
    x = dequantize_linear(q8, in_scale)
    if act == "tanh":
        y = tanh_f16(x) if f16 else jnp.tanh(x)
    elif act == "sigmoid":
        y = sigmoid_f16(x) if f16 else 1.0 / (1.0 + jnp.exp(-x))
    else:
        raise ValueError(act)
    return quantize_linear(y, out_scale, out_dtype)


def conv_integer_pad1(x_q, w_q):
    """ONNX ConvInteger, stride 1, pad 1, int32 accumulation (NCHW)."""
    n, c, h, w = x_q.shape
    m, _, kh, kw = w_q.shape
    xp = jnp.pad(x_q.astype(jnp.int32), ((0, 0), (0, 0), (1, 1), (1, 1)))
    patches = []
    for ci in range(c):
        for ky in range(kh):
            for kx in range(kw):
                patches.append(xp[:, ci, ky:ky + h, kx:kx + w].reshape(n, h * w))
    col = jnp.stack(patches, axis=1)  # [n, c*kh*kw, h*w]
    wm = w_q.astype(jnp.int32).reshape(m, c * kh * kw)
    return jnp.einsum("mk,nkp->nmp", wm, col).reshape(n, m, h, w)


def fig_conv(x_q, w_q, b_q, multiplier, out_dtype=jnp.int8):
    """Figure 3: ConvInteger(pad 1) + Add + Cast + Mul + QuantizeLinear."""
    m = w_q.shape[0]
    acc = conv_integer_pad1(x_q, w_q) + b_q.astype(jnp.int32).reshape(1, m, 1, 1)
    f = rescale(acc, multiplier, 1.0)
    return quantize_linear(f, 1.0, out_dtype)
