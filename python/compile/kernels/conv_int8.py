"""L1 ConvInteger path: im2col layout transform (L2, jnp) feeding the
Pallas int8 GEMM tile (L1), plus the fused rescale epilogue.

On the TPU mapping, im2col is the BlockSpec-expressible HBM->VMEM
gather; the MAC work itself goes through the same ``matmul_int8`` tile
as the fully-connected path — mirroring how the ASIC reuses one MAC
array for both layer types (and how the Rust interpreter and hwsim share
``gemm_i32``).
"""

import jax.numpy as jnp

from . import matmul_int8 as mm


def im2col_pad1(x_q, kh, kw):
    """int8 NCHW -> [n, c*kh*kw, h*w] patch matrix (stride 1, pad 1),
    row order (c, ky, kx) matching rust ops::conv::im2col."""
    n, c, h, w = x_q.shape
    xp = jnp.pad(x_q, ((0, 0), (0, 0), (1, 1), (1, 1)))
    patches = []
    for ci in range(c):
        for ky in range(kh):
            for kx in range(kw):
                patches.append(xp[:, ci, ky:ky + h, kx:kx + w].reshape(n, h * w))
    return jnp.stack(patches, axis=1)


def conv_int8_requant(x_q, w_q, b_q, multiplier, relu=False,
                      out_dtype=jnp.int8):
    """Figure 3 block: ConvInteger(pad1) + bias + 1-Mul rescale +
    QuantizeLinear, with the GEMM on the Pallas tile."""
    n, c, h, w = x_q.shape
    m, _, kh, kw = w_q.shape
    col = im2col_pad1(x_q, kh, kw)  # [n, k', hw] int8
    wm = w_q.reshape(m, c * kh * kw)  # [m, k'] int8
    outs = []
    for b in range(n):
        acc = mm.matmul_int8(wm, col[b], block_m=m, block_n=h * w)
        acc = acc + b_q.astype(jnp.int32)[:, None]
        outs.append(acc)
    acc = jnp.stack(outs, axis=0).reshape(n, m, h, w)
    return mm.rescale_requant(acc, multiplier, 1.0, relu=relu,
                              out_dtype=out_dtype)
