//! Integration: the coordinator serving all six figure models over
//! interpreter + hwsim backends simultaneously, plus the validation
//! service sweeping all of them (paper goal 3 at the system level).

use pqdl::coordinator::{
    validate, Backend, CoordinatorBuilder, HwSimBackend, InterpBackend, ServerConfig,
};
use pqdl::figures::Figure;
use pqdl::hwsim::HwConfig;
use pqdl::interp::Session;
use pqdl::tensor::Tensor;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn coordinator_serves_all_figures() {
    let mut builder = CoordinatorBuilder::new(ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        // Two replicas per lane: the integration test exercises the
        // shared-plan replica path across every figure model.
        replicas: 2,
        ..ServerConfig::default()
    });
    for fig in Figure::ALL {
        builder = builder.register(
            fig.name(),
            Arc::new(InterpBackend::new(fig.model()).unwrap()),
        );
    }
    let coord = builder.start();
    assert_eq!(coord.models().len(), 6);

    for fig in Figure::ALL {
        let sess = Session::new(fig.model()).unwrap();
        for seed in 0..4u64 {
            let x = fig.input(1, seed);
            let resp = coord.infer(fig.name(), x.clone()).unwrap();
            let got = resp.output.expect(fig.name());
            let want = &sess.run(&[("x", x)]).unwrap()[0];
            assert_eq!(&got, want, "{} seed {seed}", fig.name());
        }
    }
    let report = coord.metrics.report();
    assert!(report.contains("fig1_fc"));
    assert!(report.contains("fig6_sigmoid_f16"));
    coord.shutdown();
}

#[test]
fn validation_sweep_all_figures_interp_vs_hwsim() {
    // The GOAL3 experiment shape: every figure, interp as reference,
    // hwsim must agree within slope-dependent LSB margins.
    for fig in Figure::ALL {
        let model = fig.model();
        let backends: Vec<Arc<dyn Backend>> = vec![
            Arc::new(InterpBackend::new(model.clone()).unwrap()),
            Arc::new(HwSimBackend::new(&model, HwConfig::default()).unwrap()),
        ];
        let inputs: Vec<Tensor> = (0..20).map(|s| fig.input(4, s)).collect();
        let report = validate(fig.name(), &backends, &inputs).unwrap();
        let tol = fig.hw_tolerance();
        assert!(
            report.all_within(tol),
            "{} out of tolerance:\n{}",
            fig.name(),
            report.table()
        );
        // The overwhelming majority must be bit-exact.
        assert!(
            report.rows[0].report.exact_rate() > 0.95,
            "{}: exact rate {:.4}",
            fig.name(),
            report.rows[0].report.exact_rate()
        );
    }
}

#[test]
fn hwsim_cost_scales_with_batch() {
    let fig = Figure::Fig1FcTwoMul;
    let be = HwSimBackend::new(&fig.model(), HwConfig::default()).unwrap();
    be.run_batch(&fig.input(1, 1)).unwrap();
    let c1 = be.total_cost();
    be.run_batch(&fig.input(8, 1)).unwrap();
    let c9 = be.total_cost();
    assert_eq!(c9.macs - c1.macs, 8 * c1.macs);
}
