//! Differential tests for the compiled execution plan: across all six
//! figure models, the quantized float-I/O MLP, and the hardware
//! simulator, the planned executor (`Session::run` / `run_serial` /
//! `run_observed`) must produce BIT-IDENTICAL outputs — and for the
//! calibration hook, an identical observer stream — to the legacy
//! string-keyed interpreter (`Session::run_unplanned`), which is the
//! pre-plan implementation retained verbatim as the oracle.

use pqdl::figures::Figure;
use pqdl::hwsim::{HwConfig, HwModule, HW_PAR_MIN_BATCH};
use pqdl::interp::Session;
use pqdl::proptest_util::{run_prop, RangeUsize};
use pqdl::quant::CalibStrategy;
use pqdl::rewrite::{calibrate, quantize_model, QuantizeOptions};
use pqdl::tensor::{DType, Tensor};
use pqdl::train::{synthetic_digits, train_classifier, HiddenAct, Mlp};

#[test]
fn plan_matches_legacy_on_all_figures() {
    for fig in Figure::ALL {
        let sess = Session::new(fig.model()).unwrap();
        run_prop(
            &format!("plan_vs_legacy::{}", fig.name()),
            &RangeUsize { lo: 1, hi: 17 },
            0x9A7D ^ fig.name().len() as u64,
            8,
            |&batch| {
                let x = fig.input(batch, batch as u64 * 131 + 7);
                let legacy = sess
                    .run_unplanned(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let planned = sess
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                if legacy != planned {
                    return Err(format!(
                        "{}: planned serial != legacy at batch {batch}",
                        fig.name()
                    ));
                }
                // The auto (possibly batch-parallel) path must agree too.
                let auto = sess.run(&[("x", x)]).map_err(|e| e.to_string())?;
                if legacy != auto {
                    return Err(format!(
                        "{}: planned auto != legacy at batch {batch}",
                        fig.name()
                    ));
                }
                Ok(())
            },
        );
    }
}

/// The serving-shaped model the coordinator batches: float I/O, Gemm
/// chain, Softmax head, produced by the real quantization pipeline.
fn quantized_digits_mlp() -> (Session, Vec<Vec<f32>>) {
    let data = synthetic_digits(400, 91);
    let mut mlp = Mlp::new(&[64, 24, 10], HiddenAct::Relu, 92);
    train_classifier(&mut mlp, &data, 6, 32, 0.1, 0.9, 93);
    let model = mlp.to_model("digits_plan");
    let sess = Session::new(model.clone()).unwrap();
    let batches: Vec<_> = (0..32)
        .map(|i| {
            let (x, _) = data.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange).unwrap();
    let preq = quantize_model(&model, &cal, &QuantizeOptions::default()).unwrap();
    let rows: Vec<Vec<f32>> = (0..48).map(|i| data.sample(i).0.to_vec()).collect();
    (Session::new(preq).unwrap(), rows)
}

#[test]
fn plan_matches_legacy_on_quantized_float_io_mlp() {
    let (qsess, rows) = quantized_digits_mlp();
    for batch in [1usize, 3, 9] {
        let mut xs = Vec::with_capacity(batch * 64);
        for i in 0..batch {
            xs.extend_from_slice(&rows[(i * 5) % rows.len()]);
        }
        let x = Tensor::from_f32(&[batch, 64], xs).unwrap();
        let legacy = qsess.run_unplanned(&[("x", x.clone())]).unwrap();
        let planned = qsess.run_serial(&[("x", x.clone())]).unwrap();
        assert_eq!(legacy, planned, "batch {batch}");
        let auto = qsess.run(&[("x", x)]).unwrap();
        assert_eq!(legacy, auto, "batch {batch} (auto)");
    }
}

/// The calibration hook: the planned executor's observer stream (names
/// and tensors, in order) must be identical to the legacy interpreter's.
#[test]
fn observer_stream_identical_planned_vs_legacy() {
    for fig in Figure::ALL {
        let sess = Session::new(fig.model()).unwrap();
        let x = fig.input(3, 0xCA11B);
        let mut planned: Vec<(String, Tensor)> = Vec::new();
        sess.run_observed(&[("x", x.clone())], &mut |name, t| {
            planned.push((name.to_string(), t.clone()));
        })
        .unwrap();
        let mut legacy: Vec<(String, Tensor)> = Vec::new();
        sess.run_unplanned_observed(&[("x", x)], &mut |name, t| {
            legacy.push((name.to_string(), t.clone()));
        })
        .unwrap();
        assert_eq!(
            planned.len(),
            legacy.len(),
            "{}: observer event count",
            fig.name()
        );
        for (i, (p, l)) in planned.iter().zip(&legacy).enumerate() {
            assert_eq!(p.0, l.0, "{}: observer name at event {i}", fig.name());
            assert_eq!(p.1, l.1, "{}: observer tensor for '{}'", fig.name(), p.0);
        }
    }
}

/// End-to-end calibration (the run_observed consumer) over the planned
/// executor must reproduce the legacy thresholds exactly.
#[test]
fn calibration_thresholds_identical_planned_vs_legacy() {
    let data = synthetic_digits(200, 51);
    let mut mlp = Mlp::new(&[64, 16, 10], HiddenAct::Tanh, 52);
    train_classifier(&mut mlp, &data, 4, 32, 0.1, 0.9, 53);
    let model = mlp.to_model("digits_cal");
    let sess = Session::new(model).unwrap();
    let batches: Vec<_> = (0..16)
        .map(|i| {
            let (x, _) = data.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    // Planned path (what `calibrate` uses today).
    let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange).unwrap();
    // Legacy path: same strategy driven through run_unplanned_observed.
    let mut legacy_max: std::collections::HashMap<String, f32> =
        std::collections::HashMap::new();
    for feeds in &batches {
        let feeds_ref: Vec<(&str, Tensor)> = feeds
            .iter()
            .map(|(n, t)| (n.as_str(), t.clone()))
            .collect();
        sess.run_unplanned_observed(&feeds_ref, &mut |name, t| {
            if t.dtype() == DType::F32 {
                let m = legacy_max.entry(name.to_string()).or_insert(0.0);
                for &v in t.as_f32().unwrap() {
                    *m = m.max(v.abs());
                }
            }
        })
        .unwrap();
    }
    assert_eq!(cal.thresholds.len(), legacy_max.len());
    for (name, &want) in &legacy_max {
        assert_eq!(
            cal.threshold(name),
            Some(want),
            "threshold for '{name}' drifted between planned and legacy"
        );
    }
}

/// hwsim consumes the same plan-compiled stages; its batch-split schedule
/// must stay bit-identical to its serial path and in agreement with the
/// (planned) interpreter within the established per-figure margins.
#[test]
fn hwsim_agreement_unchanged_under_planned_interp() {
    for fig in Figure::ALL {
        let model = fig.model();
        let hw = HwModule::compile(&model, HwConfig::default()).unwrap();
        let sess = Session::new(model).unwrap();
        let batch = HW_PAR_MIN_BATCH + 2; // exercises the split schedule
        let x = fig.input(batch, 77);
        let (hw_out, cost) = hw.run(&x).unwrap();
        let (hw_serial, serial_cost) = hw.run_serial(&x).unwrap();
        assert_eq!(hw_out, hw_serial, "{}: hw split != serial", fig.name());
        assert_eq!(cost.macs, serial_cost.macs, "{}: MACs drifted", fig.name());
        let want = &sess.run(&[("x", x)]).unwrap()[0];
        let wv = want.as_quantized_i32().unwrap();
        let gv = hw_out.as_quantized_i32().unwrap();
        let tol = fig.hw_tolerance();
        let max_diff = wv.iter().zip(&gv).map(|(a, b)| (a - b).abs()).max().unwrap();
        assert!(
            max_diff <= tol,
            "{}: interp-vs-hw max diff {max_diff} > {tol}",
            fig.name()
        );
    }
}
