//! Differential tests for the compiled execution plan: across all six
//! figure models, the quantized float-I/O MLP and CNN, and the hardware
//! simulator, the planned executor (`Session::run` / `run_serial` /
//! `run_observed`) must produce BIT-IDENTICAL outputs — and for the
//! calibration hook, an identical observer stream — to the legacy
//! string-keyed interpreter (`Session::run_unplanned`), which is the
//! pre-plan implementation retained verbatim as the oracle.
//!
//! Since the plan-time graph optimizer (`pqdl::opt`), the contract is
//! three-way: FUSED plan == UNFUSED plan == legacy interpreter, plus
//! coverage pins (the six figures must fuse to their expected step
//! counts) and decline proofs (breaking a fusion precondition must leave
//! results bit-identical with no fused kernel in the plan).

use pqdl::figures::Figure;
use pqdl::hwsim::{HwConfig, HwModule, HW_PAR_MIN_BATCH};
use pqdl::interp::{PlanOptions, Session};
use pqdl::proptest_util::{run_prop, Pair, RangeUsize};
use pqdl::quant::CalibStrategy;
use pqdl::rewrite::{calibrate, quantize_model, QuantizeOptions};
use pqdl::tensor::{DType, Tensor};
use pqdl::train::{synthetic_digits, train_classifier, train_cnn, Cnn, HiddenAct, Mlp};

#[test]
fn plan_matches_legacy_on_all_figures() {
    for fig in Figure::ALL {
        let sess = Session::new(fig.model()).unwrap();
        run_prop(
            &format!("plan_vs_legacy::{}", fig.name()),
            &RangeUsize { lo: 1, hi: 17 },
            0x9A7D ^ fig.name().len() as u64,
            8,
            |&batch| {
                let x = fig.input(batch, batch as u64 * 131 + 7);
                let legacy = sess
                    .run_unplanned(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let planned = sess
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                if legacy != planned {
                    return Err(format!(
                        "{}: planned serial != legacy at batch {batch}",
                        fig.name()
                    ));
                }
                // The auto (possibly batch-parallel) path must agree too.
                let auto = sess.run(&[("x", x)]).map_err(|e| e.to_string())?;
                if legacy != auto {
                    return Err(format!(
                        "{}: planned auto != legacy at batch {batch}",
                        fig.name()
                    ));
                }
                Ok(())
            },
        );
    }
}

/// The serving-shaped model the coordinator batches: float I/O, Gemm
/// chain, Softmax head, produced by the real quantization pipeline.
fn quantized_digits_mlp() -> (Session, Vec<Vec<f32>>) {
    let data = synthetic_digits(400, 91);
    let mut mlp = Mlp::new(&[64, 24, 10], HiddenAct::Relu, 92);
    train_classifier(&mut mlp, &data, 6, 32, 0.1, 0.9, 93);
    let model = mlp.to_model("digits_plan");
    let sess = Session::new(model.clone()).unwrap();
    let batches: Vec<_> = (0..32)
        .map(|i| {
            let (x, _) = data.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange).unwrap();
    let preq = quantize_model(&model, &cal, &QuantizeOptions::default()).unwrap();
    let rows: Vec<Vec<f32>> = (0..48).map(|i| data.sample(i).0.to_vec()).collect();
    (Session::new(preq).unwrap(), rows)
}

#[test]
fn plan_matches_legacy_on_quantized_float_io_mlp() {
    let (qsess, rows) = quantized_digits_mlp();
    for batch in [1usize, 3, 9] {
        let mut xs = Vec::with_capacity(batch * 64);
        for i in 0..batch {
            xs.extend_from_slice(&rows[(i * 5) % rows.len()]);
        }
        let x = Tensor::from_f32(&[batch, 64], xs).unwrap();
        let legacy = qsess.run_unplanned(&[("x", x.clone())]).unwrap();
        let planned = qsess.run_serial(&[("x", x.clone())]).unwrap();
        assert_eq!(legacy, planned, "batch {batch}");
        let auto = qsess.run(&[("x", x)]).unwrap();
        assert_eq!(legacy, auto, "batch {batch} (auto)");
    }
}

/// The calibration hook: the planned executor's observer stream (names
/// and tensors, in order) must be identical to the legacy interpreter's.
///
/// Regression pin for the plan-time optimizer: these sessions run FUSED
/// plans (asserted below), whose steps never materialize mid-chain
/// values — `run_observed` must therefore force the unfused plan, or
/// every mid-chain observation (the bulk of the calibration signal)
/// would silently vanish from the stream.
#[test]
fn observer_stream_identical_planned_vs_legacy() {
    for fig in Figure::ALL {
        let sess = Session::new(fig.model()).unwrap();
        assert!(
            sess.plan_stats().steps < sess.plan_stats().nodes,
            "{}: session must be fused for this regression to bite",
            fig.name()
        );
        let x = fig.input(3, 0xCA11B);
        let mut planned: Vec<(String, Tensor)> = Vec::new();
        sess.run_observed(&[("x", x.clone())], &mut |name, t| {
            planned.push((name.to_string(), t.clone()));
        })
        .unwrap();
        let mut legacy: Vec<(String, Tensor)> = Vec::new();
        sess.run_unplanned_observed(&[("x", x)], &mut |name, t| {
            legacy.push((name.to_string(), t.clone()));
        })
        .unwrap();
        assert_eq!(
            planned.len(),
            legacy.len(),
            "{}: observer event count",
            fig.name()
        );
        for (i, (p, l)) in planned.iter().zip(&legacy).enumerate() {
            assert_eq!(p.0, l.0, "{}: observer name at event {i}", fig.name());
            assert_eq!(p.1, l.1, "{}: observer tensor for '{}'", fig.name(), p.0);
        }
    }
}

/// End-to-end calibration (the run_observed consumer) over the planned
/// executor must reproduce the legacy thresholds exactly.
#[test]
fn calibration_thresholds_identical_planned_vs_legacy() {
    let data = synthetic_digits(200, 51);
    let mut mlp = Mlp::new(&[64, 16, 10], HiddenAct::Tanh, 52);
    train_classifier(&mut mlp, &data, 4, 32, 0.1, 0.9, 53);
    let model = mlp.to_model("digits_cal");
    let sess = Session::new(model).unwrap();
    let batches: Vec<_> = (0..16)
        .map(|i| {
            let (x, _) = data.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    // Planned path (what `calibrate` uses today).
    let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange).unwrap();
    // Legacy path: same strategy driven through run_unplanned_observed.
    let mut legacy_max: std::collections::HashMap<String, f32> =
        std::collections::HashMap::new();
    for feeds in &batches {
        let feeds_ref: Vec<(&str, Tensor)> = feeds
            .iter()
            .map(|(n, t)| (n.as_str(), t.clone()))
            .collect();
        sess.run_unplanned_observed(&feeds_ref, &mut |name, t| {
            if t.dtype() == DType::F32 {
                let m = legacy_max.entry(name.to_string()).or_insert(0.0);
                for &v in t.as_f32().unwrap() {
                    *m = m.max(v.abs());
                }
            }
        })
        .unwrap();
    }
    assert_eq!(cal.thresholds.len(), legacy_max.len());
    for (name, &want) in &legacy_max {
        assert_eq!(
            cal.threshold(name),
            Some(want),
            "threshold for '{name}' drifted between planned and legacy"
        );
    }
}

/// The three-way fusion contract on every figure model: fused plan,
/// unfused plan, and the legacy interpreter agree bit for bit across
/// batch sizes (serial and auto/batch-parallel paths).
#[test]
fn fused_vs_unfused_vs_legacy_three_way_on_all_figures() {
    for fig in Figure::ALL {
        let fused = Session::new(fig.model()).unwrap();
        let unfused =
            Session::new_with_options(fig.model(), PlanOptions { fuse: false }).unwrap();
        let stats = fused.plan_stats();
        assert!(
            stats.steps < stats.nodes,
            "{}: fusion must shrink the plan ({stats})",
            fig.name()
        );
        assert_eq!(unfused.plan_stats().steps, unfused.plan_stats().nodes, "{}", fig.name());
        run_prop(
            &format!("fused_three_way::{}", fig.name()),
            &RangeUsize { lo: 1, hi: 17 },
            0xF05E ^ fig.name().len() as u64,
            8,
            |&batch| {
                let x = fig.input(batch, batch as u64 * 211 + 3);
                let legacy = fused
                    .run_unplanned(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let f = fused
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let u = unfused
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let f_auto = fused.run(&[("x", x)]).map_err(|e| e.to_string())?;
                if legacy != f || legacy != u || legacy != f_auto {
                    return Err(format!(
                        "{}: three-way divergence at batch {batch}",
                        fig.name()
                    ));
                }
                Ok(())
            },
        );
    }
}

/// Fusion coverage pins for the six canonical figures (the CI fusion
/// smoke): the whole accumulate chain collapses to ONE FusedQFc /
/// FusedQConv step, plus one FusedActLut where Figs. 4–6 fold their
/// activation tail.
#[test]
fn fusion_coverage_expected_step_counts() {
    // (figure, nodes, steps, fused_qfc, fused_qconv, fused_act_lut)
    let expect = [
        (Figure::Fig1FcTwoMul, 6, 1, 1, 0, 0),
        (Figure::Fig2FcReluOneMul, 6, 1, 1, 0, 0),
        (Figure::Fig3Conv, 5, 1, 0, 1, 0),
        (Figure::Fig4TanhInt8, 9, 2, 1, 0, 1),
        (Figure::Fig5TanhF16, 11, 2, 1, 0, 1),
        (Figure::Fig6SigmoidF16, 11, 2, 1, 0, 1),
    ];
    for (fig, nodes, steps, qfc, qconv, lut) in expect {
        let sess = Session::new(fig.model()).unwrap();
        let s = sess.plan_stats();
        assert_eq!(s.nodes, nodes, "{}: node count", fig.name());
        assert_eq!(s.steps, steps, "{}: fused step count", fig.name());
        assert_eq!(s.fused_qfc, qfc, "{}: FusedQFc count", fig.name());
        assert_eq!(s.fused_qconv, qconv, "{}: FusedQConv count", fig.name());
        assert_eq!(s.fused_act_lut, lut, "{}: FusedActLut count", fig.name());
        assert_eq!(s.eliminated, 0, "{}: nothing to eliminate", fig.name());
    }
}

/// The quantized float-I/O MLP and CNN (real calibration + rewrite
/// output) under the same three-way contract — and both must actually
/// fuse (the rewrite emits exactly the codified chains).
#[test]
fn fused_three_way_on_quantized_mlp_and_cnn() {
    // MLP (Gemm chain + Softmax head, quantized to Fig. 1/2 patterns).
    let (qsess, rows) = quantized_digits_mlp();
    let qmodel = qsess.model().clone();
    let unfused = Session::new_with_options(qmodel, PlanOptions { fuse: false }).unwrap();
    let stats = qsess.plan_stats();
    assert!(stats.fused_qfc >= 2, "quantized MLP must fuse its FC chains ({stats})");
    for batch in [1usize, 3, 9] {
        let mut xs = Vec::with_capacity(batch * 64);
        for i in 0..batch {
            xs.extend_from_slice(&rows[(i * 7) % rows.len()]);
        }
        let x = Tensor::from_f32(&[batch, 64], xs).unwrap();
        let legacy = qsess.run_unplanned(&[("x", x.clone())]).unwrap();
        let f = qsess.run_serial(&[("x", x.clone())]).unwrap();
        let u = unfused.run_serial(&[("x", x.clone())]).unwrap();
        let auto = qsess.run(&[("x", x)]).unwrap();
        assert_eq!(legacy, f, "mlp batch {batch} (fused)");
        assert_eq!(legacy, u, "mlp batch {batch} (unfused)");
        assert_eq!(legacy, auto, "mlp batch {batch} (auto)");
    }

    // CNN (ConvInteger chain + pool/flatten + FC head). Training quality
    // is irrelevant here — only the quantized structure matters.
    let data = synthetic_digits(300, 171);
    let mut cnn = Cnn::new(4, 10, 172);
    train_cnn(&mut cnn, &data, 2, 32, 0.08, 0.9, 173);
    let model = cnn.to_model("digits_cnn_fused");
    let sess = Session::new(model.clone()).unwrap();
    let batches: Vec<_> = (0..16)
        .map(|i| {
            let (x, _) = data.sample(i);
            vec![(
                "x".to_string(),
                Tensor::from_f32(&[1, 1, 8, 8], x.to_vec()).unwrap(),
            )]
        })
        .collect();
    let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange).unwrap();
    let q = quantize_model(&model, &cal, &QuantizeOptions::default()).unwrap();
    let qcnn = Session::new(q.clone()).unwrap();
    let ucnn = Session::new_with_options(q, PlanOptions { fuse: false }).unwrap();
    let stats = qcnn.plan_stats();
    assert!(stats.fused_qconv >= 1, "quantized CNN must fuse its conv chain ({stats})");
    for batch in [1usize, 4] {
        let mut xs = Vec::with_capacity(batch * 64);
        for i in 0..batch {
            xs.extend_from_slice(data.sample((i * 3) % data.len()).0);
        }
        let x = Tensor::from_f32(&[batch, 1, 8, 8], xs).unwrap();
        let legacy = qcnn.run_unplanned(&[("x", x.clone())]).unwrap();
        let f = qcnn.run_serial(&[("x", x.clone())]).unwrap();
        let u = ucnn.run_serial(&[("x", x)]).unwrap();
        assert_eq!(legacy, f, "cnn batch {batch} (fused)");
        assert_eq!(legacy, u, "cnn batch {batch} (unfused)");
    }
}

/// Breaking a fusion precondition must make the matcher DECLINE (no
/// fused kernel in the plan) while results stay bit-identical to the
/// legacy interpreter — fusion is an optimization, never a semantic
/// dependency.
#[test]
fn broken_fusion_preconditions_decline_and_stay_bit_identical() {
    use pqdl::onnx::ir::Attr;
    use pqdl::onnx::{batched, GraphBuilder};

    /// Fig. 1-like chain with one precondition broken per mutation:
    /// 1 = extra consumer on the accumulator (mid-chain value),
    /// 2 = extra consumer on the rescale Mul output,
    /// 3 = requantize scale is a runtime input, not an initializer,
    /// 4 = rescale multiplier is a non-scalar initializer,
    /// 5 = bias is a runtime input, not an initializer.
    fn model(mutation: usize) -> pqdl::onnx::Model {
        let mut b = GraphBuilder::new("break_fusion");
        b.input("x", DType::I8, &batched(&[4]));
        b.init("w", Tensor::from_i8(&[4, 2], vec![1, -3, 5, -7, 2, -4, 6, -8]).unwrap());
        if mutation == 5 {
            b.input("bias", DType::I32, &pqdl::onnx::fixed_dims(&[2]));
        } else {
            b.init("bias", Tensor::from_i32(&[2], vec![40, -60]).unwrap());
        }
        if mutation == 4 {
            b.init("scale1", Tensor::from_f32(&[2], vec![0.5, 0.25]).unwrap());
        } else {
            b.init("scale1", Tensor::scalar_f32(0.5));
        }
        if mutation == 3 {
            b.input("q_one", DType::F32, &pqdl::onnx::fixed_dims(&[]));
        } else {
            b.init("q_one", Tensor::scalar_f32(1.0));
        }
        b.init("q_zp", Tensor::scalar_i8(0));
        let acc = b.node("MatMulInteger", &["x", "w"], &[]);
        let accb = b.node("Add", &[&acc, "bias"], &[]);
        let f = b.node("Cast", &[&accb], &[("to", Attr::Str("FLOAT".into()))]);
        let m1 = b.node("Mul", &[&f, "scale1"], &[]);
        let y = b.node("QuantizeLinear", &[&m1, "q_one", "q_zp"], &[]);
        b.output(&y, DType::I8, &batched(&[2]));
        if mutation == 1 {
            let extra = b.node("Relu", &[&acc], &[]);
            b.output(&extra, DType::I32, &batched(&[2]));
        }
        if mutation == 2 {
            let extra = b.node("Relu", &[&m1], &[]);
            b.output(&extra, DType::F32, &batched(&[2]));
        }
        b.finish_model()
    }

    // Sanity: the unmutated chain DOES fuse (so the declines below mean
    // something).
    let base = Session::new(model(0)).unwrap();
    assert_eq!(base.plan_stats().fused_qfc, 1, "baseline must fuse");

    run_prop(
        "broken_preconditions_decline",
        &Pair(RangeUsize { lo: 1, hi: 5 }, RangeUsize { lo: 1, hi: 9 }),
        0xDEC1,
        24,
        |&(mutation, batch)| {
            let sess = Session::new(model(mutation)).map_err(|e| e.to_string())?;
            let stats = sess.plan_stats();
            if stats.fused_qfc != 0 {
                return Err(format!("mutation {mutation}: matcher must decline ({stats})"));
            }
            let data: Vec<i8> = (0..batch * 4)
                .map(|i| ((i * 89 + mutation * 41) % 251) as u8 as i8)
                .collect();
            let x = Tensor::from_i8(&[batch, 4], data).unwrap();
            let mut feeds: Vec<(&str, Tensor)> = vec![("x", x)];
            if mutation == 3 {
                feeds.push(("q_one", Tensor::scalar_f32(1.0)));
            }
            if mutation == 5 {
                feeds.push(("bias", Tensor::from_i32(&[2], vec![40, -60]).unwrap()));
            }
            let legacy = sess.run_unplanned(&feeds).map_err(|e| e.to_string())?;
            let planned = sess.run_serial(&feeds).map_err(|e| e.to_string())?;
            if legacy != planned {
                return Err(format!("mutation {mutation}: bit divergence at batch {batch}"));
            }
            Ok(())
        },
    );
}

/// hwsim consumes the same plan-compiled stages; its batch-split schedule
/// must stay bit-identical to its serial path and in agreement with the
/// (planned) interpreter within the established per-figure margins.
#[test]
fn hwsim_agreement_unchanged_under_planned_interp() {
    for fig in Figure::ALL {
        let model = fig.model();
        let hw = HwModule::compile(&model, HwConfig::default()).unwrap();
        let sess = Session::new(model).unwrap();
        let batch = HW_PAR_MIN_BATCH + 2; // exercises the split schedule
        let x = fig.input(batch, 77);
        let (hw_out, cost) = hw.run(&x).unwrap();
        let (hw_serial, serial_cost) = hw.run_serial(&x).unwrap();
        assert_eq!(hw_out, hw_serial, "{}: hw split != serial", fig.name());
        assert_eq!(cost.macs, serial_cost.macs, "{}: MACs drifted", fig.name());
        let want = &sess.run(&[("x", x)]).unwrap()[0];
        let wv = want.as_quantized_i32().unwrap();
        let gv = hw_out.as_quantized_i32().unwrap();
        let tol = fig.hw_tolerance();
        let max_diff = wv.iter().zip(&gv).map(|(a, b)| (a - b).abs()).max().unwrap();
        assert!(
            max_diff <= tol,
            "{}: interp-vs-hw max diff {max_diff} > {tol}",
            fig.name()
        );
    }
}
