//! Auto-tuning integration tests (PR 7 acceptance):
//!
//! * every `GemmConfig` candidate is bit-identical to the scalar
//!   differential oracle — tuning can move time, never bits (proptested
//!   over shapes spanning every tile boundary);
//! * the tuning cache round-trips through its disk mirror and
//!   invalidates per key component (digest / shapes / ISA / nthreads);
//! * `PQDL_TUNE=off` reproduces the historical hand-picked constants;
//! * a second compile for the same key is a cache hit — no re-measuring;
//! * the unfused twin plan is lazy: pure-serving fused sessions never
//!   pay its baked-weight memory, observer/profiling paths force it on
//!   first use, and unfused sessions share one plan for both roles;
//! * the serving-time controller stays within its bounds and settles
//!   under any observation sequence.

use pqdl::figures::Figure;
use pqdl::interp::{PlanOptions, Session};
use pqdl::ops::matmul::{
    gemm_i32, gemm_i8_i32, gemm_i8_packed_a_isa, gemm_i8_packed_isa, gemm_i8_packed_par_isa,
    PackedA, PackedB,
};
use pqdl::ops::Isa;
use pqdl::parallel::ThreadPool;
use pqdl::proptest_util::{run_prop, Pair, RangeUsize};
use pqdl::tune::tuner::tune_gemms_with;
use pqdl::tune::{
    cache, Controller, ControllerConfig, GemmConfig, GemmProblem, LaneObservation, ProblemKind,
    TuneCache, TuneMode, TuneOutcome, TuneSource,
};
use std::time::Duration;

/// Deterministic data fill (tests must reproduce from the printed seed
/// alone; the interesting coverage axis is the SHAPE, which the proptest
/// generators drive across every tile boundary).
fn det_i8(len: usize, seed: u64) -> Vec<i8> {
    let mut s = seed | 1;
    (0..len)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) & 0xff) as u8 as i8
        })
        .collect()
}

/// Widened weights in i8 range (packable) but stored as i32, as the
/// zero-point-folding bake produces them.
fn det_w(len: usize, seed: u64) -> Vec<i32> {
    det_i8(len, seed).into_iter().map(|v| v as i32).collect()
}

// ---------------------------------------------------------------- bits

/// Every candidate config, on the packed-B (FC) side, against the
/// unpacked reference — serial, parallel, scalar, and the active ISA.
/// Shapes range past 512 in k so every KC ∈ {128, 256, 512} hits both
/// full blocks and remainders, and past 16 in n for every NR.
#[test]
fn every_candidate_bit_exact_on_packed_b_gemm() {
    let shapes = Pair(
        RangeUsize { lo: 1, hi: 13 },
        Pair(RangeUsize { lo: 1, hi: 530 }, RangeUsize { lo: 1, hi: 37 }),
    );
    let pool = ThreadPool::global();
    run_prop("candidates_bit_exact_b", &shapes, 0xB17, 12, |&(m, (k, n))| {
        let a = det_i8(m * k, (m * 31 + k * 7 + n) as u64);
        let bw = det_w(k * n, (k * 13 + n) as u64);
        let mut want = vec![0i32; m * n];
        gemm_i8_i32(&a, &bw, m, k, n, &mut want);
        for cfg in GemmConfig::candidates() {
            let bp = PackedB::pack_with(&bw, k, n, cfg)
                .ok_or_else(|| format!("{cfg} refused packable weights"))?;
            for isa in [Isa::Scalar, Isa::active()] {
                let mut got = vec![0i32; m * n];
                gemm_i8_packed_isa(isa, &a, &bp, m, &mut got);
                if got != want {
                    return Err(format!("serial {cfg} on {isa} diverged at {m}x{k}x{n}"));
                }
                let mut got = vec![0i32; m * n];
                gemm_i8_packed_par_isa(pool, isa, &a, &bp, m, &mut got);
                if got != want {
                    return Err(format!("parallel {cfg} on {isa} diverged at {m}x{k}x{n}"));
                }
            }
        }
        Ok(())
    });
}

/// Same property on the packed-A (conv im2col) side.
#[test]
fn every_candidate_bit_exact_on_packed_a_gemm() {
    let shapes = Pair(
        RangeUsize { lo: 1, hi: 18 },
        Pair(RangeUsize { lo: 1, hi: 530 }, RangeUsize { lo: 1, hi: 21 }),
    );
    run_prop("candidates_bit_exact_a", &shapes, 0xA17, 12, |&(m, (k, n))| {
        let aw = det_w(m * k, (m * 17 + k) as u64);
        let b = det_i8(k * n, (k * 3 + n * 11) as u64);
        let b_wide: Vec<i32> = b.iter().map(|&v| v as i32).collect();
        let mut want = vec![0i32; m * n];
        gemm_i32(&aw, &b_wide, m, k, n, &mut want);
        for cfg in GemmConfig::candidates() {
            let ap = PackedA::pack_with(&aw, m, k, cfg)
                .ok_or_else(|| format!("{cfg} refused packable weights"))?;
            for isa in [Isa::Scalar, Isa::active()] {
                let mut got = vec![0i32; m * n];
                gemm_i8_packed_a_isa(isa, &ap, &b, n, &mut got);
                if got != want {
                    return Err(format!("{cfg} on {isa} diverged at {m}x{k}x{n}"));
                }
            }
        }
        Ok(())
    });
}

// --------------------------------------------------------------- cache

#[test]
fn cache_round_trips_through_disk_and_invalidates_per_key_component() {
    let path = std::env::temp_dir().join(format!("pqdl_tune_cache_{}.txt", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let key = cache::key_line(0xD1CE, &["b64x32".into(), "a27x8".into()], Isa::Scalar, 4);
    let cfg = GemmConfig {
        kc: 512,
        nr: 16,
        par_min_work: 16 * 1024,
        ..GemmConfig::DEFAULT
    };
    {
        let warm = TuneCache::new(Some(path.clone()));
        warm.store(&key, cfg);
        // Overwrite with a second store: later lines must win on reload.
        warm.store(&key, GemmConfig { kc: 128, ..cfg });
    }
    // A fresh cache over the same file sees the LAST stored winner…
    let cold = TuneCache::new(Some(path.clone()));
    assert_eq!(cold.lookup(&key), Some(GemmConfig { kc: 128, ..cfg }));
    assert_eq!(cold.len(), 1, "appends collapse to one key on reload");
    // …and every perturbed key component misses: invalidation is
    // structural, not TTL-based.
    for wrong in [
        cache::key_line(0xD1CF, &["b64x32".into(), "a27x8".into()], Isa::Scalar, 4),
        cache::key_line(0xD1CE, &["b64x33".into(), "a27x8".into()], Isa::Scalar, 4),
        cache::key_line(0xD1CE, &["b64x32".into()], Isa::Scalar, 4),
        cache::key_line(0xD1CE, &["b64x32".into(), "a27x8".into()], Isa::Avx2, 4),
        cache::key_line(0xD1CE, &["b64x32".into(), "a27x8".into()], Isa::Scalar, 8),
    ] {
        assert_ne!(wrong, key);
        assert_eq!(cold.lookup(&wrong), None, "key {wrong:?} must miss");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn second_tune_for_the_same_key_hits_without_re_measuring() {
    let fig = Figure::Fig1FcTwoMul;
    let model = fig.model();
    let digest = cache::model_digest(&model);
    let bw = det_w(12 * 10, 5);
    let problems = [GemmProblem {
        w: &bw,
        k: 12,
        out: 10,
        kind: ProblemKind::PackedBGemm,
        bits: 8,
    }];
    let own = TuneCache::new(None);
    let first = tune_gemms_with(&own, digest, &problems, Isa::Scalar, 1, TuneMode::Full);
    assert_eq!(first.source, TuneSource::Measured);
    assert_eq!(own.len(), 1);
    let second = tune_gemms_with(&own, digest, &problems, Isa::Scalar, 1, TuneMode::Full);
    assert_eq!(second.source, TuneSource::CacheHit, "second compile must not re-measure");
    assert_eq!(second.cfg, first.cfg);
    assert_eq!(own.len(), 1, "a hit stores nothing new");
}

#[test]
fn tune_off_reproduces_the_hand_picked_constants() {
    // The knob's `off` contract at the tuner API: exactly the DEFAULT
    // outcome, no cache traffic.
    let bw = det_w(8 * 8, 9);
    let p = GemmProblem {
        w: &bw,
        k: 8,
        out: 8,
        kind: ProblemKind::PackedBGemm,
        bits: 8,
    };
    let own = TuneCache::new(None);
    let out = tune_gemms_with(&own, 1, &[p], Isa::Scalar, 1, TuneMode::Off);
    assert_eq!(out, TuneOutcome::DEFAULT);
    assert!(own.is_empty());
    // And DEFAULT is literally the constants every release so far
    // shipped with — the pack() convenience constructor agrees.
    assert_eq!(GemmConfig::DEFAULT.kc, pqdl::ops::matmul::GEMM_KC);
    assert_eq!(GemmConfig::DEFAULT.nr, pqdl::ops::matmul::GEMM_NR);
    let bp = PackedB::pack(&bw, 8, 8).unwrap();
    assert_eq!(bp.cfg, GemmConfig::DEFAULT);
    let ap = PackedA::pack(&bw, 8, 8).unwrap();
    assert_eq!(ap.cfg, GemmConfig::DEFAULT);
}

// ------------------------------------------------------------- session

/// Whatever `PQDL_TUNE` this process runs under, a session's stamped
/// tile and its provenance must be mutually consistent, and two sessions
/// over the same model must agree (the cache makes tuning idempotent).
#[test]
fn session_tile_stamp_is_consistent_and_idempotent() {
    let fig = Figure::Fig1FcTwoMul;
    let s1 = Session::new(fig.model()).unwrap();
    let s2 = Session::new(fig.model()).unwrap();
    let (a, b) = (s1.plan_stats(), s2.plan_stats());
    assert_eq!(a.tile, b.tile, "same model + same key must stamp the same tile");
    match a.tuned {
        TuneSource::Default => assert!(a.tile.is_default()),
        TuneSource::CacheHit | TuneSource::Measured => {
            assert!(GemmConfig::candidates().contains(&a.tile));
        }
    }
    if matches!(TuneMode::active(), TuneMode::Full) {
        // Acceptance: the second `Session::new` for the same (digest,
        // shapes, ISA, nthreads) must come from the cache.
        assert_eq!(b.tuned, TuneSource::CacheHit);
    }
    // The tuned plan still answers bit-identically to the untuned
    // legacy interpreter path.
    let x = fig.input(3, 42);
    let planned = s1.run(&[("x", x.clone())]).unwrap();
    let unplanned = s1.run_unplanned(&[("x", x)]).unwrap();
    assert_eq!(planned, unplanned);
}

/// The CI `tuning` job's cache-hit smoke: runs this test ALONE with
/// `PQDL_TUNE=full PQDL_TUNE_SMOKE=1`, where the process-global
/// measurement counter must stay flat across the second compile. In a
/// full parallel suite run (no `PQDL_TUNE_SMOKE`) the counter assertions
/// are skipped — concurrent tests measure for other models — but the
/// cache-hit provenance still holds.
#[test]
fn cache_hit_smoke_second_compile_skips_measurement() {
    let fig = Figure::Fig1FcTwoMul;
    let s1 = Session::new(fig.model()).unwrap();
    let mid = cache::stats();
    let s2 = Session::new(fig.model()).unwrap();
    let after = cache::stats();
    assert_eq!(s2.plan_stats().tile, s1.plan_stats().tile);
    if matches!(TuneMode::active(), TuneMode::Full) {
        assert_eq!(
            s2.plan_stats().tuned,
            TuneSource::CacheHit,
            "second compile for the same key must be a cache hit"
        );
        assert!(after.hits > mid.hits);
    }
    if std::env::var("PQDL_TUNE_SMOKE").is_ok() {
        assert_eq!(
            after.measurements, mid.measurements,
            "second compile must not re-measure"
        );
    }
}

#[test]
fn fused_session_compiles_the_unfused_twin_lazily() {
    let fig = Figure::Fig1FcTwoMul;
    let sess = Session::new(fig.model()).unwrap();
    let stats = sess.plan_stats();
    assert!(
        stats.steps < stats.nodes,
        "precondition: fusion must change fig1's plan"
    );
    assert!(!stats.twin_compiled, "pure-serving session must not pay for the twin");
    assert!(sess.profile().is_empty());
    let lean = sess.baked_plan_bytes();
    assert!(lean > 0);
    // Serving runs never force the twin.
    let fused_out = sess.run(&[("x", fig.input(2, 3))]).unwrap();
    assert!(!sess.plan_stats().twin_compiled);
    assert_eq!(sess.baked_plan_bytes(), lean);
    // The first observed (calibration/oracle) run forces it…
    let mut events = 0usize;
    let observed = sess
        .run_observed(&[("x", fig.input(2, 3))], &mut |_, _| events += 1)
        .unwrap();
    assert!(events > 0);
    assert!(sess.plan_stats().twin_compiled);
    // …paying the double baked-weight memory serving now avoids…
    assert!(
        sess.baked_plan_bytes() > lean,
        "forcing the twin must grow baked plan bytes"
    );
    // …and both plans answer bit-identically.
    assert_eq!(observed, fused_out);
}

#[test]
fn unfused_session_shares_one_plan_for_both_roles() {
    let fig = Figure::Fig1FcTwoMul;
    let sess = Session::new_with_options(fig.model(), PlanOptions { fuse: false }).unwrap();
    let stats = sess.plan_stats();
    assert!(
        stats.twin_compiled,
        "an identical twin is shared eagerly at zero cost"
    );
    // Shared means shared: the observer path adds no baked bytes.
    let b0 = sess.baked_plan_bytes();
    sess.run_observed(&[("x", fig.input(1, 1))], &mut |_, _| {}).unwrap();
    assert_eq!(sess.baked_plan_bytes(), b0);
}

#[test]
fn profiling_forces_the_twin_and_reports_per_node_stats() {
    let fig = Figure::Fig1FcTwoMul;
    let sess = Session::new(fig.model()).unwrap().with_profiling();
    assert!(sess.profile().is_empty());
    sess.run(&[("x", fig.input(1, 7))]).unwrap();
    let stats = sess.plan_stats();
    assert!(stats.twin_compiled, "profiled runs execute the unfused twin");
    let prof = sess.profile();
    assert_eq!(prof.len(), stats.nodes, "every node ran exactly once");
    assert!(prof.iter().all(|n| n.calls == 1));
}

#[test]
fn replicas_share_the_lazy_twin() {
    let fig = Figure::Fig1FcTwoMul;
    let sess = Session::new(fig.model()).unwrap();
    let replica = sess.fork_replica();
    assert!(!replica.plan_stats().twin_compiled);
    // Forcing it on the replica makes it visible on the parent too —
    // one twin per session family, compiled once.
    replica
        .run_observed(&[("x", fig.input(1, 2))], &mut |_, _| {})
        .unwrap();
    assert!(sess.plan_stats().twin_compiled);
}

// ---------------------------------------------------------- controller

/// Under ANY observation sequence the controller's decisions stay inside
/// the configured bounds, and under a constant observation they settle:
/// after enough ticks the decision stops changing (hysteresis + bounds
/// make every constant input a fixed point, not an oscillation).
#[test]
fn controller_is_bounded_and_settles_under_any_trace() {
    let obs_gen = Pair(
        Pair(RangeUsize { lo: 0, hi: 300 }, RangeUsize { lo: 0, hi: 20 }),
        Pair(RangeUsize { lo: 0, hi: 20_000 }, RangeUsize { lo: 1, hi: 20_000 }),
    );
    let cfg = ControllerConfig {
        min_replicas: 1,
        max_replicas: 6,
        min_wait: Duration::from_micros(500),
        max_wait: Duration::from_millis(8),
        dwell_ticks: 2,
        ..ControllerConfig::default()
    };
    let to_obs = |(reqs, shed): (usize, usize), (q_us, e_us): (usize, usize)| LaneObservation {
        requests: reqs as u64,
        shed: shed as u64,
        queue_mean_us: q_us as f64,
        exec_mean_us: e_us as f64,
        mean_rows: 1.0 + (reqs % 8) as f64,
        max_batch: 8,
    };
    run_prop("controller_bounded_and_settling", &obs_gen, 0xC0, 120, |&(rs, qe)| {
        let obs = to_obs(rs, qe);
        // Bounded along a mixed 40-tick trace seeded from the case.
        let mut c = Controller::new(cfg, 3, Duration::from_millis(2));
        for tick in 0..40usize {
            let mixed = if tick % 3 == 0 {
                LaneObservation::default()
            } else {
                obs
            };
            let d = c.step(&mixed);
            if d.replicas < cfg.min_replicas || d.replicas > cfg.max_replicas {
                return Err(format!("replicas {} escaped bounds at tick {tick}", d.replicas));
            }
            if d.wait < cfg.min_wait || d.wait > cfg.max_wait {
                return Err(format!("wait {:?} escaped bounds at tick {tick}", d.wait));
            }
        }
        // Settling: a constant observation reaches a fixed point well
        // within bounds*dwell ticks and never moves again.
        let mut c = Controller::new(cfg, 3, Duration::from_millis(2));
        let mut last = c.current();
        let mut settled_at = None;
        for tick in 0..200usize {
            let d = c.step(&obs);
            if d != last {
                last = d;
                settled_at = Some(tick);
            }
        }
        if let Some(t) = settled_at {
            if t > 100 {
                return Err(format!("still moving at tick {t} under a constant load"));
            }
        }
        Ok(())
    });
}
