//! Property tests for the cache-blocked packed int8 GEMM: across random
//! shapes — explicitly including k % 4 != 0, n smaller than one panel
//! (GEMM_NR), m not a multiple of the register tile (GEMM_MR), and k
//! crossing the KC block boundary — the packed kernels must match a
//! naive triple loop bit for bit, serial and pool-dispatched alike.

use pqdl::ops::matmul::{
    gemm_i8_i32, gemm_i8_i32_par, gemm_i8_packed, gemm_i8_packed_a, gemm_i8_packed_par,
    PackedA, PackedB, GEMM_KC, GEMM_MR, GEMM_NR,
};
use pqdl::parallel::ThreadPool;
use pqdl::proptest_util::{run_prop, Pair, RangeUsize};
use pqdl::train::Rng;

/// The oracle: C[i,j] = sum_k A[i,k] * B[k,j], ascending k, plain i32.
fn naive(a: &[i8], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for kk in 0..k {
                acc += a[i * k + kk] as i32 * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

fn rand_i8(rng: &mut Rng, len: usize) -> Vec<i8> {
    (0..len).map(|_| rng.i8()).collect()
}

#[test]
fn packed_kernels_match_naive_triple_loop() {
    let shapes = Pair(
        Pair(RangeUsize { lo: 1, hi: 9 }, RangeUsize { lo: 1, hi: 70 }),
        RangeUsize { lo: 1, hi: 21 },
    );
    run_prop(
        "packed_gemm_vs_naive",
        &shapes,
        0x9ACC_ED,
        60,
        |&((m, k), n)| {
            let mut rng = Rng::new((m * 1_000_003 + k * 1009 + n) as u64);
            let a = rand_i8(&mut rng, m * k);
            let b8 = rand_i8(&mut rng, k * n);
            let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
            let want = naive(&a, &bw, m, k, n);

            let bp = PackedB::pack(&bw, k, n).ok_or("PackedB refused i8 data")?;
            let mut got = vec![0i32; m * n];
            gemm_i8_packed(&a, &bp, m, &mut got);
            if got != want {
                return Err(format!("packed B mismatch at ({m},{k},{n})"));
            }

            let aw: Vec<i32> = a.iter().map(|&x| x as i32).collect();
            let ap = PackedA::pack(&aw, m, k).ok_or("PackedA refused i8 data")?;
            let mut got_a = vec![0i32; m * n];
            gemm_i8_packed_a(&ap, &b8, n, &mut got_a);
            if got_a != want {
                return Err(format!("packed A mismatch at ({m},{k},{n})"));
            }

            // The pre-existing unpacked kernel stays the cross-check.
            let mut got_u = vec![0i32; m * n];
            gemm_i8_i32(&a, &bw, m, k, n, &mut got_u);
            if got_u != want {
                return Err(format!("unpacked kernel mismatch at ({m},{k},{n})"));
            }
            Ok(())
        },
    );
}

#[test]
fn packed_gemm_crosses_kc_block_boundary() {
    // k spanning one full KC block plus a remainder, n rag below/above a
    // panel, m ragged vs the register tile.
    for (m, k, n) in [
        (GEMM_MR + 1, GEMM_KC + 5, GEMM_NR - 1),
        (2 * GEMM_MR - 1, GEMM_KC, GEMM_NR + 3),
        (1, 2 * GEMM_KC + 1, 1),
    ] {
        let mut rng = Rng::new(k as u64 * 31 + n as u64);
        let a = rand_i8(&mut rng, m * k);
        let b8 = rand_i8(&mut rng, k * n);
        let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
        let want = naive(&a, &bw, m, k, n);
        let bp = PackedB::pack(&bw, k, n).unwrap();
        let mut got = vec![0i32; m * n];
        gemm_i8_packed(&a, &bp, m, &mut got);
        assert_eq!(want, got, "({m},{k},{n})");
    }
}

#[test]
fn packed_parallel_bit_exact_across_pool_sizes() {
    // Large enough to clear GEMM_PAR_MIN_WORK so dispatch engages.
    let (m, k, n) = (64usize, 48, 33);
    let mut rng = Rng::new(0xBADu64);
    let a = rand_i8(&mut rng, m * k);
    let b8 = rand_i8(&mut rng, k * n);
    let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
    let bp = PackedB::pack(&bw, k, n).unwrap();
    let mut serial = vec![0i32; m * n];
    gemm_i8_packed(&a, &bp, m, &mut serial);
    assert_eq!(serial, naive(&a, &bw, m, k, n));
    for threads in [1usize, 2, 3, 8] {
        let pool = ThreadPool::new(threads);
        let mut par = vec![0i32; m * n];
        gemm_i8_packed_par(&pool, &a, &bp, m, &mut par);
        assert_eq!(serial, par, "{threads} threads (packed)");
        let mut par_u = vec![0i32; m * n];
        gemm_i8_i32_par(&pool, &a, &bw, m, k, n, &mut par_u);
        assert_eq!(serial, par_u, "{threads} threads (unpacked)");
    }
}
