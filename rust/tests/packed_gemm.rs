//! Property tests for the cache-blocked packed int8 GEMM: across random
//! shapes — explicitly including k % 4 != 0, n smaller than one panel
//! (GEMM_NR), m not a multiple of the register tile (GEMM_MR), and k
//! crossing the KC block boundary — the packed kernels must match a
//! naive triple loop bit for bit, serial and pool-dispatched alike.

use pqdl::ops::bitpack::{
    gemm_i2_packed_a_isa, gemm_i2_packed_isa, gemm_i3_packed_a_isa, gemm_i3_packed_isa,
    gemm_i4_packed_a_isa, gemm_i4_packed_isa, gemm_i4a_bytes_isa, gemm_i4a_bytes_par_isa,
    gemm_xnor_a_isa, gemm_xnor_isa, pack_bits_cols, pack_bits_rows, pack_nibble_rows, BitPackedA,
    BitPackedB, PackedA2, PackedA3, PackedA4, PackedB2, PackedB3, PackedB4, PackedWeights,
};
use pqdl::ops::matmul::{
    gemm_i8_i32, gemm_i8_i32_par, gemm_i8_packed, gemm_i8_packed_a, gemm_i8_packed_a_isa,
    gemm_i8_packed_isa, gemm_i8_packed_par, gemm_i8_packed_par_isa, matmul_integer_packed_into,
    matmul_integer_prewidened, matmul_integer_prewidened_into, PackedA, PackedB, GEMM_KC,
    GEMM_MR, GEMM_NR,
};
use pqdl::ops::Isa;
use pqdl::parallel::ThreadPool;
use pqdl::proptest_util::{run_prop, Pair, RangeUsize};
use pqdl::tensor::Tensor;
use pqdl::train::Rng;

/// The oracle: C[i,j] = sum_k A[i,k] * B[k,j], ascending k, plain i32.
fn naive(a: &[i8], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for kk in 0..k {
                acc += a[i * k + kk] as i32 * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

fn rand_i8(rng: &mut Rng, len: usize) -> Vec<i8> {
    (0..len).map(|_| rng.i8()).collect()
}

#[test]
fn packed_kernels_match_naive_triple_loop() {
    let shapes = Pair(
        Pair(RangeUsize { lo: 1, hi: 9 }, RangeUsize { lo: 1, hi: 70 }),
        RangeUsize { lo: 1, hi: 21 },
    );
    run_prop(
        "packed_gemm_vs_naive",
        &shapes,
        0x9ACC_ED,
        60,
        |&((m, k), n)| {
            let mut rng = Rng::new((m * 1_000_003 + k * 1009 + n) as u64);
            let a = rand_i8(&mut rng, m * k);
            let b8 = rand_i8(&mut rng, k * n);
            let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
            let want = naive(&a, &bw, m, k, n);

            let bp = PackedB::pack(&bw, k, n).ok_or("PackedB refused i8 data")?;
            let mut got = vec![0i32; m * n];
            gemm_i8_packed(&a, &bp, m, &mut got);
            if got != want {
                return Err(format!("packed B mismatch at ({m},{k},{n})"));
            }

            let aw: Vec<i32> = a.iter().map(|&x| x as i32).collect();
            let ap = PackedA::pack(&aw, m, k).ok_or("PackedA refused i8 data")?;
            let mut got_a = vec![0i32; m * n];
            gemm_i8_packed_a(&ap, &b8, n, &mut got_a);
            if got_a != want {
                return Err(format!("packed A mismatch at ({m},{k},{n})"));
            }

            // The pre-existing unpacked kernel stays the cross-check.
            let mut got_u = vec![0i32; m * n];
            gemm_i8_i32(&a, &bw, m, k, n, &mut got_u);
            if got_u != want {
                return Err(format!("unpacked kernel mismatch at ({m},{k},{n})"));
            }
            Ok(())
        },
    );
}

#[test]
fn isa_variants_match_naive_triple_loop() {
    // The SIMD microkernels under the same differential contract: every
    // ISA this host supports (scalar always among them) must reproduce
    // the naive ascending-k i32 accumulation bit for bit — across odd
    // k/n, sub-panel n, and ragged m, same generator as the scalar
    // proptest above.
    let shapes = Pair(
        Pair(RangeUsize { lo: 1, hi: 9 }, RangeUsize { lo: 1, hi: 70 }),
        RangeUsize { lo: 1, hi: 21 },
    );
    run_prop(
        "isa_gemm_vs_naive",
        &shapes,
        0x51_3D_9ACC,
        60,
        |&((m, k), n)| {
            let mut rng = Rng::new((m * 1_000_003 + k * 1009 + n) as u64 ^ 0x151A);
            let a = rand_i8(&mut rng, m * k);
            let b8 = rand_i8(&mut rng, k * n);
            let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
            let want = naive(&a, &bw, m, k, n);
            let bp = PackedB::pack(&bw, k, n).ok_or("PackedB refused i8 data")?;
            let aw: Vec<i32> = a.iter().map(|&x| x as i32).collect();
            let ap = PackedA::pack(&aw, m, k).ok_or("PackedA refused i8 data")?;
            for isa in Isa::available() {
                let mut got = vec![0i32; m * n];
                gemm_i8_packed_isa(isa, &a, &bp, m, &mut got);
                if got != want {
                    return Err(format!("{isa} packed-B mismatch at ({m},{k},{n})"));
                }
                let mut got_a = vec![0i32; m * n];
                gemm_i8_packed_a_isa(isa, &ap, &b8, n, &mut got_a);
                if got_a != want {
                    return Err(format!("{isa} packed-A mismatch at ({m},{k},{n})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn isa_variants_at_saturation_extremes() {
    // Worst-case accumulator growth: every product at |a*b| = 16384
    // (i8::MIN * i8::MIN), k deep enough to cross the KC boundary. The
    // i32 accumulator holds (16384 * k << i32::MAX for any admitted k);
    // all ISAs must agree exactly, including on the mixed-sign block
    // where partial sums swing between large positive and negative.
    let (m, n) = (GEMM_MR + 2, GEMM_NR + 3);
    for k in [1usize, 7, GEMM_KC + 5] {
        for (av, bv) in [
            (i8::MIN, i8::MIN),
            (i8::MIN, i8::MAX),
            (i8::MAX, i8::MAX),
        ] {
            let a = vec![av; m * k];
            let b8 = vec![bv; k * n];
            let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
            let want = naive(&a, &bw, m, k, n);
            let bp = PackedB::pack(&bw, k, n).unwrap();
            let aw: Vec<i32> = a.iter().map(|&x| x as i32).collect();
            let ap = PackedA::pack(&aw, m, k).unwrap();
            for isa in Isa::available() {
                let mut got = vec![0i32; m * n];
                gemm_i8_packed_isa(isa, &a, &bp, m, &mut got);
                assert_eq!(want, got, "{isa} packed-B ({m},{k},{n}) a={av} b={bv}");
                let mut got_a = vec![0i32; m * n];
                gemm_i8_packed_a_isa(isa, &ap, &b8, n, &mut got_a);
                assert_eq!(want, got_a, "{isa} packed-A ({m},{k},{n}) a={av} b={bv}");
            }
        }
    }
    // Alternating-sign columns: partial sums cancel, exposing any lane
    // that reorders the ascending-k accumulation.
    let (m, k, n) = (3usize, GEMM_KC + 1, GEMM_NR * 2 + 1);
    let a: Vec<i8> = (0..m * k)
        .map(|i| if i % 2 == 0 { i8::MAX } else { i8::MIN })
        .collect();
    let b8: Vec<i8> = (0..k * n)
        .map(|i| if (i / n) % 2 == 0 { i8::MIN } else { i8::MAX })
        .collect();
    let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
    let want = naive(&a, &bw, m, k, n);
    let bp = PackedB::pack(&bw, k, n).unwrap();
    for isa in Isa::available() {
        let mut got = vec![0i32; m * n];
        gemm_i8_packed_isa(isa, &a, &bp, m, &mut got);
        assert_eq!(want, got, "{isa} alternating-sign");
    }
}

#[test]
fn isa_prewidened_matches_scalar_at_zp_edges() {
    // Zero-point edge cases through the tensor-level entry point: every
    // ISA (and, for nonzero a_zp, the bit-identical unpacked fallback it
    // routes to) must agree with the strictly scalar oracle wrapper.
    let (m, k, n) = (5usize, 19, GEMM_NR + 3);
    let mut rng = Rng::new(0x2ED6E5);
    let a = Tensor::from_i8(&[m, k], rand_i8(&mut rng, m * k)).unwrap();
    let b8 = rand_i8(&mut rng, k * n);
    let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
    let bp = PackedB::pack(&bw, k, n).unwrap();
    for a_zp in [-128i32, -1, 0, 1, 127] {
        let want = matmul_integer_prewidened(&a, &bw, k, n, a_zp).unwrap();
        for isa in Isa::available() {
            let got =
                matmul_integer_prewidened_into(&a, &bw, Some(&bp), k, n, a_zp, isa, None)
                    .unwrap();
            assert_eq!(want, got, "{isa} a_zp={a_zp}");
        }
    }
}

#[test]
fn isa_parallel_wrapper_bit_exact_across_pool_sizes() {
    // The pool-dispatched ISA wrapper splits rows exactly like the scalar
    // one; every (isa, threads) combination must agree with the serial
    // scalar kernel bit for bit.
    let (m, k, n) = (64usize, 48, 33);
    let mut rng = Rng::new(0x15A_BADu64);
    let a = rand_i8(&mut rng, m * k);
    let b8 = rand_i8(&mut rng, k * n);
    let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
    let bp = PackedB::pack(&bw, k, n).unwrap();
    let mut serial = vec![0i32; m * n];
    gemm_i8_packed(&a, &bp, m, &mut serial);
    assert_eq!(serial, naive(&a, &bw, m, k, n));
    for isa in Isa::available() {
        for threads in [1usize, 2, 3, 8] {
            let pool = ThreadPool::new(threads);
            let mut par = vec![0i32; m * n];
            gemm_i8_packed_par_isa(&pool, isa, &a, &bp, m, &mut par);
            assert_eq!(serial, par, "{isa}, {threads} threads");
        }
    }
}

#[test]
fn packed_gemm_crosses_kc_block_boundary() {
    // k spanning one full KC block plus a remainder, n rag below/above a
    // panel, m ragged vs the register tile.
    for (m, k, n) in [
        (GEMM_MR + 1, GEMM_KC + 5, GEMM_NR - 1),
        (2 * GEMM_MR - 1, GEMM_KC, GEMM_NR + 3),
        (1, 2 * GEMM_KC + 1, 1),
    ] {
        let mut rng = Rng::new(k as u64 * 31 + n as u64);
        let a = rand_i8(&mut rng, m * k);
        let b8 = rand_i8(&mut rng, k * n);
        let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
        let want = naive(&a, &bw, m, k, n);
        let bp = PackedB::pack(&bw, k, n).unwrap();
        let mut got = vec![0i32; m * n];
        gemm_i8_packed(&a, &bp, m, &mut got);
        assert_eq!(want, got, "({m},{k},{n})");
    }
}

#[test]
fn i4_packed_kernels_match_naive_ragged() {
    // The nibble-packed family under the same contract as the i8 panels:
    // random shapes with ragged m/k/n (odd n exercises the padded last
    // nibble; k past UNPACK_KC exercises block-partial-sum order), every
    // ISA, B-packed (FC) and A-packed (conv) orientations.
    let shapes = Pair(
        Pair(RangeUsize { lo: 1, hi: 9 }, RangeUsize { lo: 1, hi: 70 }),
        RangeUsize { lo: 1, hi: 21 },
    );
    run_prop("i4_gemm_vs_naive", &shapes, 0x14_9ACC, 60, |&((m, k), n)| {
        let mut rng = Rng::new((m * 1_000_003 + k * 1009 + n) as u64 ^ 0x1417);

        // FC orientation: full-range i8 activations x int4 weights
        // (drawn from the whole [-8, 7] range including both extremes).
        let a = rand_i8(&mut rng, m * k);
        let b4: Vec<i32> = (0..k * n).map(|_| (rng.below(16) as i32) - 8).collect();
        let want = naive(&a, &b4, m, k, n);
        let bp = PackedB4::pack(&b4, k, n).ok_or("PackedB4 refused int4 data")?;

        // Conv orientation: int4 weights x full-range i8 activations.
        let aw: Vec<i32> = (0..m * k).map(|_| (rng.below(16) as i32) - 8).collect();
        let aw8: Vec<i8> = aw.iter().map(|&v| v as i8).collect();
        let bact = rand_i8(&mut rng, k * n);
        let bact_w: Vec<i32> = bact.iter().map(|&v| v as i32).collect();
        let want_a = naive(&aw8, &bact_w, m, k, n);
        let ap = PackedA4::pack(&aw, m, k).ok_or("PackedA4 refused int4 data")?;

        for isa in Isa::available() {
            let mut got = vec![0i32; m * n];
            gemm_i4_packed_isa(isa, &a, &bp, m, &mut got);
            if got != want {
                return Err(format!("{isa} i4 packed-B mismatch at ({m},{k},{n})"));
            }
            let mut got_a = vec![0i32; m * n];
            gemm_i4_packed_a_isa(isa, &ap, &bact, n, &mut got_a);
            if got_a != want_a {
                return Err(format!("{isa} i4 packed-A mismatch at ({m},{k},{n})"));
            }
        }
        Ok(())
    });
}

#[test]
fn i2_i3_packed_kernels_match_naive_ragged() {
    // The crumb (int2) and tribble (int3) families under the identical
    // differential contract: ragged m/k/n (odd n exercises padded tail
    // fields; int3's 3-bit stream only byte-aligns every 8 columns, so
    // sub-panel n hits the straddling-field decode), every ISA, both
    // orientations.
    let shapes = Pair(
        Pair(RangeUsize { lo: 1, hi: 9 }, RangeUsize { lo: 1, hi: 70 }),
        RangeUsize { lo: 1, hi: 21 },
    );
    run_prop("i2_i3_gemm_vs_naive", &shapes, 0x23_9ACC, 60, |&((m, k), n)| {
        let mut rng = Rng::new((m * 1_000_003 + k * 1009 + n) as u64 ^ 0x2323);

        // FC orientation: full-range i8 activations x narrow weights
        // spanning each width's whole range including both extremes.
        let a = rand_i8(&mut rng, m * k);
        let b3: Vec<i32> = (0..k * n).map(|_| (rng.below(8) as i32) - 4).collect();
        let b2: Vec<i32> = (0..k * n).map(|_| (rng.below(4) as i32) - 2).collect();
        let want3 = naive(&a, &b3, m, k, n);
        let want2 = naive(&a, &b2, m, k, n);
        let bp3 = PackedB3::pack(&b3, k, n).ok_or("PackedB3 refused int3 data")?;
        let bp2 = PackedB2::pack(&b2, k, n).ok_or("PackedB2 refused int2 data")?;

        // Conv orientation: narrow weights x full-range i8 activations.
        let aw3: Vec<i32> = (0..m * k).map(|_| (rng.below(8) as i32) - 4).collect();
        let aw2: Vec<i32> = (0..m * k).map(|_| (rng.below(4) as i32) - 2).collect();
        let aw3_8: Vec<i8> = aw3.iter().map(|&v| v as i8).collect();
        let aw2_8: Vec<i8> = aw2.iter().map(|&v| v as i8).collect();
        let bact = rand_i8(&mut rng, k * n);
        let bact_w: Vec<i32> = bact.iter().map(|&v| v as i32).collect();
        let want3_a = naive(&aw3_8, &bact_w, m, k, n);
        let want2_a = naive(&aw2_8, &bact_w, m, k, n);
        let ap3 = PackedA3::pack(&aw3, m, k).ok_or("PackedA3 refused int3 data")?;
        let ap2 = PackedA2::pack(&aw2, m, k).ok_or("PackedA2 refused int2 data")?;

        for isa in Isa::available() {
            let mut got = vec![0i32; m * n];
            gemm_i3_packed_isa(isa, &a, &bp3, m, &mut got);
            if got != want3 {
                return Err(format!("{isa} i3 packed-B mismatch at ({m},{k},{n})"));
            }
            got.fill(0);
            gemm_i2_packed_isa(isa, &a, &bp2, m, &mut got);
            if got != want2 {
                return Err(format!("{isa} i2 packed-B mismatch at ({m},{k},{n})"));
            }
            got.fill(0);
            gemm_i3_packed_a_isa(isa, &ap3, &bact, n, &mut got);
            if got != want3_a {
                return Err(format!("{isa} i3 packed-A mismatch at ({m},{k},{n})"));
            }
            got.fill(0);
            gemm_i2_packed_a_isa(isa, &ap2, &bact, n, &mut got);
            if got != want2_a {
                return Err(format!("{isa} i2 packed-A mismatch at ({m},{k},{n})"));
            }
        }
        Ok(())
    });
}

#[test]
fn nibble_activation_kernel_matches_naive_ragged() {
    // The packed-activation int4 body (u8 nibble rows x widened i32
    // weights) that fused chains feed directly: odd k exercises the
    // padded last nibble per row, every ISA, and the row-parallel
    // wrapper across pool sizes must all equal the widened oracle.
    let shapes = Pair(
        Pair(RangeUsize { lo: 1, hi: 9 }, RangeUsize { lo: 1, hi: 70 }),
        RangeUsize { lo: 1, hi: 21 },
    );
    run_prop("i4a_gemm_vs_naive", &shapes, 0x4A_9ACC, 60, |&((m, k), n)| {
        let mut rng = Rng::new((m * 1_000_003 + k * 1009 + n) as u64 ^ 0x4A4A);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(16) as i8) - 8).collect();
        let bw: Vec<i32> = (0..k * n).map(|_| rng.i8() as i32).collect();
        let want = naive(&a, &bw, m, k, n);
        let mut a_bytes = Vec::new();
        pack_nibble_rows(&a, m, k, &mut a_bytes);
        for isa in Isa::available() {
            let mut got = vec![0i32; m * n];
            gemm_i4a_bytes_isa(isa, &a_bytes, m, k, &bw, n, &mut got);
            if got != want {
                return Err(format!("{isa} i4a mismatch at ({m},{k},{n})"));
            }
            for threads in [1usize, 3] {
                let pool = ThreadPool::new(threads);
                let mut par = vec![0i32; m * n];
                gemm_i4a_bytes_par_isa(&pool, isa, &a_bytes, m, k, &bw, n, &mut par);
                if par != want {
                    return Err(format!(
                        "{isa} i4a par mismatch at ({m},{k},{n}), {threads} threads"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn narrow_isa_bodies_at_saturation_extremes() {
    // Worst-case accumulator growth for the sub-8-bit SIMD bodies. The
    // int4 AVX2 path rides maddubs-style i16 lane math pre-widened to
    // i32 — the (-128 activation) x (-8 weight) corner at KC-crossing
    // depth is exactly where an unwidened lane would saturate; all ISAs
    // must reproduce the naive i32 accumulation bit for bit. Same drill
    // for int3/int2 extremes and the nibble-activation body.
    let (m, n) = (GEMM_MR + 2, GEMM_NR + 3);
    for k in [1usize, 7, GEMM_KC + 5] {
        for (av, wv4) in [(i8::MIN, -8i32), (i8::MIN, 7), (i8::MAX, -8), (i8::MAX, 7)] {
            let a = vec![av; m * k];
            let b4 = vec![wv4; k * n];
            let want = naive(&a, &b4, m, k, n);
            let bp4 = PackedB4::pack(&b4, k, n).unwrap();
            let b3 = vec![wv4.clamp(-4, 3); k * n];
            let want3 = naive(&a, &b3, m, k, n);
            let bp3 = PackedB3::pack(&b3, k, n).unwrap();
            let b2 = vec![wv4.clamp(-2, 1); k * n];
            let want2 = naive(&a, &b2, m, k, n);
            let bp2 = PackedB2::pack(&b2, k, n).unwrap();
            for isa in Isa::available() {
                let mut got = vec![0i32; m * n];
                gemm_i4_packed_isa(isa, &a, &bp4, m, &mut got);
                assert_eq!(want, got, "{isa} i4 ({m},{k},{n}) a={av} w={wv4}");
                got.fill(0);
                gemm_i3_packed_isa(isa, &a, &bp3, m, &mut got);
                assert_eq!(want3, got, "{isa} i3 ({m},{k},{n}) a={av}");
                got.fill(0);
                gemm_i2_packed_isa(isa, &a, &bp2, m, &mut got);
                assert_eq!(want2, got, "{isa} i2 ({m},{k},{n}) a={av}");
            }
        }
        // Nibble-activation body at its own extremes: ±8-range packed
        // activations against i8-extreme widened weights.
        for (av4, wv) in [(-8i8, i8::MIN as i32), (-8, i8::MAX as i32), (7, i8::MIN as i32)] {
            let a = vec![av4; m * k];
            let bw = vec![wv; k * n];
            let want = naive(&a, &bw, m, k, n);
            let mut a_bytes = Vec::new();
            pack_nibble_rows(&a, m, k, &mut a_bytes);
            for isa in Isa::available() {
                let mut got = vec![0i32; m * n];
                gemm_i4a_bytes_isa(isa, &a_bytes, m, k, &bw, n, &mut got);
                assert_eq!(want, got, "{isa} i4a ({m},{k},{n}) a={av4} w={wv}");
            }
        }
    }
    // Alternating-sign int4 weights: partial sums cancel, exposing any
    // SIMD lane that reorders the ascending-k accumulation.
    let (m, k, n) = (3usize, GEMM_KC + 1, GEMM_NR * 2 + 1);
    let a: Vec<i8> = (0..m * k)
        .map(|i| if i % 2 == 0 { i8::MAX } else { i8::MIN })
        .collect();
    let b4: Vec<i32> = (0..k * n).map(|i| if (i / n) % 2 == 0 { -8 } else { 7 }).collect();
    let want = naive(&a, &b4, m, k, n);
    let bp4 = PackedB4::pack(&b4, k, n).unwrap();
    for isa in Isa::available() {
        let mut got = vec![0i32; m * n];
        gemm_i4_packed_isa(isa, &a, &bp4, m, &mut got);
        assert_eq!(want, got, "{isa} i4 alternating-sign");
    }
}

#[test]
fn xnor_kernels_match_naive_across_word_boundaries() {
    // The bipolar family: shapes spanning the 64-bit word boundary (the
    // ragged-tail proof relies on zero tail bits XORing to zero), every
    // ISA, both orientations — FC (runtime-packed activation rows) and
    // conv (plan-packed weight rows against runtime-packed im2col cols).
    let shapes = Pair(
        Pair(RangeUsize { lo: 1, hi: 7 }, RangeUsize { lo: 1, hi: 140 }),
        RangeUsize { lo: 1, hi: 13 },
    );
    run_prop("xnor_gemm_vs_naive", &shapes, 0x1_9ACC, 60, |&((m, k), n)| {
        let mut rng = Rng::new((m * 1_000_003 + k * 1009 + n) as u64 ^ 0xB1);
        let a8: Vec<i8> = (0..m * k).map(|_| if rng.below(2) == 0 { -1 } else { 1 }).collect();
        let b1: Vec<i32> = (0..k * n).map(|_| if rng.below(2) == 0 { -1 } else { 1 }).collect();
        let want = naive(&a8, &b1, m, k, n);

        let bb = BitPackedB::pack(&b1, k, n).ok_or("BitPackedB refused ±1 data")?;
        let mut a_bits = Vec::new();
        if !pack_bits_rows(&a8, m, k, &mut a_bits) {
            return Err("pack_bits_rows refused ±1 data".into());
        }
        let aw: Vec<i32> = a8.iter().map(|&v| v as i32).collect();
        let ap = BitPackedA::pack(&aw, m, k).ok_or("BitPackedA refused ±1 data")?;
        let b8: Vec<i8> = b1.iter().map(|&v| v as i8).collect();
        let mut b_bits = Vec::new();
        if !pack_bits_cols(&b8, k, n, &mut b_bits) {
            return Err("pack_bits_cols refused ±1 data".into());
        }

        for isa in Isa::available() {
            let mut got = vec![0i32; m * n];
            gemm_xnor_isa(isa, &a_bits, &bb, m, &mut got);
            if got != want {
                return Err(format!("{isa} xnor mismatch at ({m},{k},{n})"));
            }
            let mut got_a = vec![0i32; m * n];
            gemm_xnor_a_isa(isa, &ap, &b_bits, n, &mut got_a);
            if got_a != want {
                return Err(format!("{isa} xnor-a mismatch at ({m},{k},{n})"));
            }
        }
        Ok(())
    });
}

#[test]
fn width_dispatched_entry_point_at_zp_edges() {
    // The tensor-level width dispatcher: for every baked-width variant
    // and every zero-point edge, the result must equal the strictly
    // scalar widen-to-i32 oracle. Nonzero a_zp (and non-±1 activations
    // under a bipolar baking) must route to the widened fallback — the
    // "narrow baking never changes results" contract.
    let (m, k, n) = (5usize, 67, GEMM_NR + 3);
    let mut rng = Rng::new(0x2ED_4B1);

    // int4-baked weights, full-range i8 activations.
    let a = Tensor::from_i8(&[m, k], rand_i8(&mut rng, m * k)).unwrap();
    let b4: Vec<i32> = (0..k * n).map(|_| (rng.below(16) as i32) - 8).collect();
    let w4 = PackedWeights::I4(PackedB4::pack(&b4, k, n).unwrap());

    // bipolar-baked weights; strictly ±1 activations qualify for XNOR,
    // the mixed tensor (one 0 inserted) must fall back.
    let b1: Vec<i32> = (0..k * n).map(|_| if rng.below(2) == 0 { -1 } else { 1 }).collect();
    let w1 = PackedWeights::Bipolar(BitPackedB::pack(&b1, k, n).unwrap());
    let mut pm1 = vec![0i8; m * k];
    for v in &mut pm1 {
        *v = if rng.below(2) == 0 { -1 } else { 1 };
    }
    let a_pm1 = Tensor::from_i8(&[m, k], pm1.clone()).unwrap();
    pm1[m * k / 2] = 0;
    let a_mixed = Tensor::from_i8(&[m, k], pm1).unwrap();

    for (label, act, bw, packed) in [
        ("int4", &a, &b4, &w4),
        ("bipolar/pm1", &a_pm1, &b1, &w1),
        ("bipolar/mixed", &a_mixed, &b1, &w1),
    ] {
        for a_zp in [-128i32, -1, 0, 1, 127] {
            let want = matmul_integer_prewidened(act, bw, k, n, a_zp).unwrap();
            for isa in Isa::available() {
                let mut bits_scratch = None;
                let got = matmul_integer_packed_into(
                    act,
                    bw,
                    Some(packed),
                    k,
                    n,
                    a_zp,
                    isa,
                    None,
                    &mut bits_scratch,
                )
                .unwrap();
                assert_eq!(want, got, "{label} {isa} a_zp={a_zp}");
            }
        }
    }
}

#[test]
fn packed_parallel_bit_exact_across_pool_sizes() {
    // Large enough to clear GEMM_PAR_MIN_WORK so dispatch engages.
    let (m, k, n) = (64usize, 48, 33);
    let mut rng = Rng::new(0xBADu64);
    let a = rand_i8(&mut rng, m * k);
    let b8 = rand_i8(&mut rng, k * n);
    let bw: Vec<i32> = b8.iter().map(|&x| x as i32).collect();
    let bp = PackedB::pack(&bw, k, n).unwrap();
    let mut serial = vec![0i32; m * n];
    gemm_i8_packed(&a, &bp, m, &mut serial);
    assert_eq!(serial, naive(&a, &bw, m, k, n));
    for threads in [1usize, 2, 3, 8] {
        let pool = ThreadPool::new(threads);
        let mut par = vec![0i32; m * n];
        gemm_i8_packed_par(&pool, &a, &bp, m, &mut par);
        assert_eq!(serial, par, "{threads} threads (packed)");
        let mut par_u = vec![0i32; m * n];
        gemm_i8_i32_par(&pool, &a, &bw, m, k, n, &mut par_u);
        assert_eq!(serial, par_u, "{threads} threads (unpacked)");
    }
}
