//! Integration: the full decoupled-quantization flow on really trained
//! models — train fp32 → calibrate → rewrite to the paper's patterns →
//! execute on interpreter AND hardware simulator → accuracy preserved.
//!
//! This is the paper's whole point operating end-to-end: the quantizer
//! never saw the hardware, the hardware compiler never saw the fp32
//! model, and the ONNX file in between carries everything.

use pqdl::hwsim::{HwConfig, HwModule};
use pqdl::interp::Session;
use pqdl::quant::CalibStrategy;
use pqdl::rewrite::{calibrate, quantize_model, ActPrecision, QuantizeOptions};
use pqdl::tensor::Tensor;
use pqdl::train::{
    accuracy, synthetic_digits, train_classifier, train_cnn, Cnn, HiddenAct, Mlp,
};

fn calib_batches(
    data: &pqdl::train::Dataset,
    n: usize,
    shape: &[usize],
) -> Vec<Vec<(String, Tensor)>> {
    (0..n.min(data.len()))
        .map(|i| {
            let (x, _) = data.sample(i);
            let mut dims = vec![1usize];
            dims.extend_from_slice(shape);
            vec![(
                "x".to_string(),
                Tensor::from_f32(&dims, x.to_vec()).unwrap(),
            )]
        })
        .collect()
}

/// Accuracy of a quantized model (float I/O, softmax output) via argmax.
fn quantized_accuracy(
    sess: &Session,
    data: &pqdl::train::Dataset,
    shape: &[usize],
) -> f32 {
    let mut correct = 0usize;
    for i in 0..data.len() {
        let (x, y) = data.sample(i);
        let mut dims = vec![1usize];
        dims.extend_from_slice(shape);
        let out = sess
            .run(&[("x", Tensor::from_f32(&dims, x.to_vec()).unwrap())])
            .unwrap();
        let probs = out[0].as_f32().unwrap();
        let pred = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == y {
            correct += 1;
        }
    }
    correct as f32 / data.len() as f32
}

fn hwsim_accuracy(hw: &HwModule, data: &pqdl::train::Dataset, shape: &[usize]) -> f32 {
    let mut correct = 0usize;
    for i in 0..data.len() {
        let (x, y) = data.sample(i);
        let mut dims = vec![1usize];
        dims.extend_from_slice(shape);
        let (out, _) = hw
            .run(&Tensor::from_f32(&dims, x.to_vec()).unwrap())
            .unwrap();
        let probs = out.as_f32().unwrap();
        let pred = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == y {
            correct += 1;
        }
    }
    correct as f32 / data.len() as f32
}

#[test]
fn mlp_relu_quantization_preserves_accuracy() {
    let data = synthetic_digits(1500, 100);
    let (train, test) = data.split(0.2, 101);
    let mut mlp = Mlp::new(&[64, 32, 10], HiddenAct::Relu, 102);
    train_classifier(&mut mlp, &train, 20, 32, 0.1, 0.9, 103);
    let fp32_acc = accuracy(&mlp, &test);
    assert!(fp32_acc > 0.9, "fp32 acc {fp32_acc}");

    let model = mlp.to_model("digits_mlp");
    let sess = Session::new(model.clone()).unwrap();
    let cal = calibrate(&sess, &calib_batches(&train, 64, &[64]), CalibStrategy::MaxRange)
        .unwrap();
    let q = quantize_model(&model, &cal, &QuantizeOptions::default()).unwrap();

    // Round-trip through serialization: the file IS the interchange.
    let text = pqdl::onnx::model_to_json(&q);
    let q = pqdl::onnx::model_from_json(&text).unwrap();

    let qsess = Session::new(q.clone()).unwrap();
    let q_acc = quantized_accuracy(&qsess, &test, &[64]);
    assert!(
        q_acc >= fp32_acc - 0.03,
        "int8 acc {q_acc} vs fp32 {fp32_acc}"
    );

    // Same file on the integer hardware.
    let hw = HwModule::compile(&q, HwConfig::default()).unwrap();
    let hw_acc = hwsim_accuracy(&hw, &test, &[64]);
    assert!(
        (hw_acc - q_acc).abs() <= 0.02,
        "hwsim acc {hw_acc} vs interp {q_acc}"
    );
}

#[test]
fn mlp_tanh_f16_pattern_end_to_end() {
    let data = synthetic_digits(1000, 110);
    let (train, test) = data.split(0.2, 111);
    let mut mlp = Mlp::new(&[64, 24, 10], HiddenAct::Tanh, 112);
    train_classifier(&mut mlp, &train, 20, 32, 0.1, 0.9, 113);
    let fp32_acc = accuracy(&mlp, &test);
    assert!(fp32_acc > 0.85, "fp32 acc {fp32_acc}");

    let model = mlp.to_model("digits_mlp_tanh");
    let sess = Session::new(model.clone()).unwrap();
    let cal = calibrate(&sess, &calib_batches(&train, 64, &[64]), CalibStrategy::MaxRange)
        .unwrap();
    for (precision, min_drop) in [(ActPrecision::F16, 0.04), (ActPrecision::Int8, 0.06)] {
        let opts = QuantizeOptions {
            act_precision: precision,
            ..Default::default()
        };
        let q = quantize_model(&model, &cal, &opts).unwrap();
        let qsess = Session::new(q.clone()).unwrap();
        let q_acc = quantized_accuracy(&qsess, &test, &[64]);
        assert!(
            q_acc >= fp32_acc - min_drop,
            "{precision:?}: int8 acc {q_acc} vs fp32 {fp32_acc}"
        );
        // Fig. 5 structure check for the f16 path: Cast->Tanh->Cast.
        if precision == ActPrecision::F16 {
            let has_f16_cast = q
                .graph
                .nodes
                .iter()
                .any(|n| n.op_type == "Cast" && n.attr_str("to") == Some("FLOAT16"));
            assert!(has_f16_cast, "f16 tanh lowering missing Cast to FLOAT16");
        }
        let hw = HwModule::compile(&q, HwConfig::default()).unwrap();
        let hw_acc = hwsim_accuracy(&hw, &test, &[64]);
        assert!((hw_acc - q_acc).abs() <= 0.03);
    }
}

#[test]
fn cnn_conv_pattern_end_to_end() {
    let data = synthetic_digits(1200, 120);
    let (train, test) = data.split(0.2, 121);
    let mut cnn = Cnn::new(6, 10, 122);
    train_cnn(&mut cnn, &train, 10, 32, 0.08, 0.9, 123);
    let fp32_acc = pqdl::train::cnn_accuracy(&cnn, &test);
    assert!(fp32_acc > 0.85, "fp32 acc {fp32_acc}");

    let model = cnn.to_model("digits_cnn");
    let sess = Session::new(model.clone()).unwrap();
    let cal = calibrate(
        &sess,
        &calib_batches(&train, 64, &[1, 8, 8]),
        CalibStrategy::MaxRange,
    )
    .unwrap();
    let q = quantize_model(&model, &cal, &QuantizeOptions::default()).unwrap();
    // Fig. 3 structure: ConvInteger present, no custom ops, checker green.
    assert!(q.graph.nodes.iter().any(|n| n.op_type == "ConvInteger"));
    pqdl::onnx::check_model(&q).unwrap();

    let qsess = Session::new(q.clone()).unwrap();
    let q_acc = quantized_accuracy(&qsess, &test, &[1, 8, 8]);
    assert!(
        q_acc >= fp32_acc - 0.05,
        "int8 acc {q_acc} vs fp32 {fp32_acc}"
    );
    let hw = HwModule::compile(&q, HwConfig::default()).unwrap();
    let hw_acc = hwsim_accuracy(&hw, &test, &[1, 8, 8]);
    assert!((hw_acc - q_acc).abs() <= 0.03);
}

#[test]
fn calibration_strategy_is_swappable_without_touching_execution() {
    // Claim D: the decoupled flow lets calibration change while the
    // model format and every executor stay identical.
    let data = synthetic_digits(800, 130);
    let (train, test) = data.split(0.25, 131);
    let mut mlp = Mlp::new(&[64, 32, 10], HiddenAct::Relu, 132);
    train_classifier(&mut mlp, &train, 15, 32, 0.1, 0.9, 133);
    let model = mlp.to_model("digits_mlp");
    let sess = Session::new(model.clone()).unwrap();
    let batches = calib_batches(&train, 64, &[64]);
    for strategy in [
        CalibStrategy::MaxRange,
        CalibStrategy::Percentile(0.999),
        CalibStrategy::Mse,
    ] {
        let cal = calibrate(&sess, &batches, strategy).unwrap();
        let q = quantize_model(&model, &cal, &QuantizeOptions::default()).unwrap();
        pqdl::onnx::check_model(&q).unwrap();
        let qsess = Session::new(q.clone()).unwrap();
        let acc = quantized_accuracy(&qsess, &test, &[64]);
        assert!(acc > 0.8, "{strategy:?}: acc {acc}");
        // And the hardware compiler accepts all of them unchanged.
        HwModule::compile(&q, HwConfig::default()).unwrap();
    }
}
