//! Property tests for the parallel executor: across random batch sizes,
//! thread counts and figure patterns, the batch-parallel `Session::run` /
//! `HwModule::run` paths must produce BIT-IDENTICAL outputs to the serial
//! path. This is the contract that lets the serving layer enable
//! parallelism unconditionally without touching the paper's narrow-margins
//! claims.

use pqdl::figures::Figure;
use pqdl::hwsim::{HwConfig, HwModule};
use pqdl::interp::Session;
use pqdl::parallel::ThreadPool;
use pqdl::proptest_util::{run_prop, Pair, RangeUsize};
use pqdl::tensor::Tensor;

/// Plan: (batch size, thread count) drawn from ranges that cover the
/// serial fallback (batch 1, 1 thread) through oversubscribed splits.
fn plan() -> Pair<RangeUsize, RangeUsize> {
    Pair(
        RangeUsize { lo: 1, hi: 33 },
        RangeUsize { lo: 1, hi: 8 },
    )
}

#[test]
fn session_parallel_matches_serial_across_batches_and_threads() {
    for fig in Figure::ALL {
        let sess = Session::new(fig.model()).unwrap();
        assert!(
            sess.batch_parallelizable(),
            "{} should be batch-splittable",
            fig.name()
        );
        run_prop(
            &format!("session_parallel::{}", fig.name()),
            &plan(),
            0xBA7C4 ^ fig.name().len() as u64,
            12,
            |&(batch, threads)| {
                let pool = ThreadPool::new(threads);
                let x = fig.input(batch, (batch * 31 + threads) as u64);
                let serial = sess
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let parallel = sess
                    .run_on(&[("x", x.clone())], &pool)
                    .map_err(|e| e.to_string())?;
                if serial != parallel {
                    return Err(format!(
                        "{}: serial != parallel at batch {batch}, {threads} threads",
                        fig.name()
                    ));
                }
                // The default auto path must agree too.
                let auto = sess.run(&[("x", x)]).map_err(|e| e.to_string())?;
                if serial != auto {
                    return Err(format!(
                        "{}: serial != auto at batch {batch}",
                        fig.name()
                    ));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn hwsim_parallel_matches_serial_across_batches_and_threads() {
    for fig in Figure::ALL {
        let model = fig.model();
        let hw = HwModule::compile(&model, HwConfig::default()).unwrap();
        assert!(
            hw.batch_parallelizable(),
            "{} should be batch-splittable on hwsim",
            fig.name()
        );
        run_prop(
            &format!("hwsim_parallel::{}", fig.name()),
            &plan(),
            0x4A5117 ^ fig.name().len() as u64,
            8,
            |&(batch, threads)| {
                let pool = ThreadPool::new(threads);
                let x = fig.input(batch, (batch * 17 + threads) as u64);
                let (serial, serial_cost) =
                    hw.run_serial(&x).map_err(|e| e.to_string())?;
                let (parallel, parallel_cost) =
                    hw.run_on(&x, &pool).map_err(|e| e.to_string())?;
                if serial != parallel {
                    return Err(format!(
                        "{}: hwsim serial != parallel at batch {batch}, {threads} threads",
                        fig.name()
                    ));
                }
                if serial_cost.macs != parallel_cost.macs {
                    return Err(format!(
                        "{}: MAC count drifted under splitting ({} vs {})",
                        fig.name(),
                        serial_cost.macs,
                        parallel_cost.macs
                    ));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn replica_serving_is_transparent_on_the_quantized_model() {
    // The serving path end to end on the serving-shaped model (float
    // I/O, Gemm chain, Softmax head): a replica pool fusing borrowed
    // request tensors must answer every request bit-identically to a
    // direct Session run — multi-row requests included — for any
    // interleaving the client threads produce.
    use pqdl::coordinator::{CoordinatorBuilder, InterpBackend, ServerConfig};
    use pqdl::quant::CalibStrategy;
    use pqdl::rewrite::{calibrate, quantize_model, QuantizeOptions};
    use pqdl::train::{synthetic_digits, train_classifier, HiddenAct, Mlp};
    use std::sync::Arc;
    use std::time::Duration;

    let data = synthetic_digits(300, 171);
    let mut mlp = Mlp::new(&[64, 16, 10], HiddenAct::Relu, 172);
    train_classifier(&mut mlp, &data, 4, 32, 0.1, 0.9, 173);
    let model = mlp.to_model("digits_serve");
    let sess = Session::new(model.clone()).unwrap();
    let batches: Vec<_> = (0..16)
        .map(|i| {
            let (x, _) = data.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange).unwrap();
    let preq = quantize_model(&model, &cal, &QuantizeOptions::default()).unwrap();
    let qsess = Session::new(preq.clone()).unwrap();

    let coord = Arc::new(
        CoordinatorBuilder::new(ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(300),
            replicas: 3,
            ..ServerConfig::default()
        })
        .register("digits", Arc::new(InterpBackend::new(preq).unwrap()))
        .start(),
    );
    let mut joins = Vec::new();
    for t in 0..4usize {
        let coord = coord.clone();
        let data = data.clone();
        joins.push(std::thread::spawn(move || {
            let mut results = Vec::new();
            for i in 0..10usize {
                // Rows 1..=3: multi-row requests ride along.
                let rows = 1 + (t + i) % 3;
                let mut xs = Vec::with_capacity(rows * 64);
                for r in 0..rows {
                    xs.extend_from_slice(data.sample((t * 40 + i + r) % data.len()).0);
                }
                let x = Tensor::from_f32(&[rows, 64], xs).unwrap();
                let resp = coord.infer("digits", x.clone()).unwrap();
                results.push((x, resp));
            }
            results
        }));
    }
    let mut total = 0;
    for j in joins {
        for (x, resp) in j.join().unwrap() {
            let want = &qsess.run(&[("x", x)]).unwrap()[0];
            let got = resp.output.expect("serving must not fail");
            assert_eq!(&got, want);
            assert!(resp.batch_rows >= resp.batch_requests);
            total += 1;
        }
    }
    assert_eq!(total, 40);
    let stats = coord.metrics.snapshot("digits").unwrap();
    assert_eq!(stats.requests, 40);
    assert_eq!(stats.shed_total(), 0);
    coord.shutdown();
}

#[test]
fn quantized_float_io_model_parallel_matches_serial() {
    // The serving-shaped model: float I/O, Gemm chain, Softmax head —
    // exactly what the coordinator batches. Serial and parallel must agree
    // bit-for-bit on the f32 outputs too.
    use pqdl::quant::CalibStrategy;
    use pqdl::rewrite::{calibrate, quantize_model, QuantizeOptions};
    use pqdl::train::{synthetic_digits, train_classifier, HiddenAct, Mlp};

    let data = synthetic_digits(400, 71);
    let mut mlp = Mlp::new(&[64, 24, 10], HiddenAct::Relu, 72);
    train_classifier(&mut mlp, &data, 6, 32, 0.1, 0.9, 73);
    let model = mlp.to_model("digits_par");
    let sess = Session::new(model.clone()).unwrap();
    let batches: Vec<_> = (0..32)
        .map(|i| {
            let (x, _) = data.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange).unwrap();
    let preq = quantize_model(&model, &cal, &QuantizeOptions::default()).unwrap();
    let qsess = Session::new(preq).unwrap();
    assert!(qsess.batch_parallelizable());

    run_prop(
        "quantized_float_io_parallel",
        &plan(),
        0xF10A7,
        10,
        |&(batch, threads)| {
            let pool = ThreadPool::new(threads);
            let mut xs = Vec::with_capacity(batch * 64);
            for i in 0..batch {
                xs.extend_from_slice(data.sample((i * 7) % data.len()).0);
            }
            let x = Tensor::from_f32(&[batch, 64], xs).unwrap();
            let serial = qsess
                .run_serial(&[("x", x.clone())])
                .map_err(|e| e.to_string())?;
            let parallel = qsess
                .run_on(&[("x", x)], &pool)
                .map_err(|e| e.to_string())?;
            if serial != parallel {
                return Err(format!(
                    "float-io serial != parallel at batch {batch}, {threads} threads"
                ));
            }
            Ok(())
        },
    );
}
