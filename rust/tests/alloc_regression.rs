//! Allocation-regression proof for the scratch planner: after the first
//! `Session::run_into` at a given batch size, subsequent runs at that
//! batch size perform **zero heap allocations** on the serial planned
//! path.
//!
//! Mechanism: a counting `#[global_allocator]` gated on a thread-local
//! flag, so only allocations made BY THE MEASURED CALL on the test
//! thread are counted (idle pool workers, the test harness, and TLS
//! teardown can't pollute the count). This file holds a single test for
//! exactly that reason — libtest running a second test concurrently
//! would be harmless for correctness but could confuse a debugging
//! session reading the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

use pqdl::interp::{PlanOptions, Session};
use pqdl::onnx::ir::Attr;
use pqdl::onnx::{batched, GraphBuilder};
use pqdl::tensor::{DType, Tensor};

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static COUNT_HERE: Cell<bool> = const { Cell::new(false) };
}

struct CountingAlloc;

impl CountingAlloc {
    #[inline]
    fn tick() {
        // try_with: never panic inside the allocator (TLS teardown).
        if COUNT_HERE.try_with(|c| c.get()).unwrap_or(false) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::tick();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::tick();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::tick();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // Frees are not counted: recycling parks buffers instead of
        // freeing them, but a steady-state drop would not be a leak bug.
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

/// Count allocations performed by `f` on this thread.
fn counted<R>(f: impl FnOnce() -> R) -> (usize, R) {
    ALLOCS.store(0, Ordering::SeqCst);
    COUNT_HERE.with(|c| c.set(true));
    let r = f();
    COUNT_HERE.with(|c| c.set(false));
    (ALLOCS.load(Ordering::SeqCst), r)
}

/// The paper's Figure-1 serving chain: MatMulInteger (prebound + packed)
/// -> Add bias -> Cast FLOAT -> Mul(Quant_scale) -> Mul(Quant_shift) ->
/// QuantizeLinear. Every kernel on it has a recycled fast path.
fn fig1_like() -> pqdl::onnx::ir::Model {
    let mut b = GraphBuilder::new("alloc_fig1");
    b.input("x", DType::I8, &batched(&[4]));
    b.init("w", Tensor::from_i8(&[4, 2], vec![1, 2, 3, 4, 5, 6, 7, 8]).unwrap());
    b.init("bias", Tensor::from_i32(&[2], vec![100, -100]).unwrap());
    b.init("quant_scale", Tensor::scalar_f32(3.0));
    b.init("quant_shift", Tensor::scalar_f32(1.0 / 8.0));
    b.init("q_one", Tensor::scalar_f32(1.0));
    b.init("q_zp", Tensor::scalar_i8(0));
    let acc = b.node("MatMulInteger", &["x", "w"], &[]);
    let accb = b.node("Add", &[&acc, "bias"], &[]);
    let f = b.node("Cast", &[&accb], &[("to", Attr::Str("FLOAT".into()))]);
    let m1 = b.node("Mul", &[&f, "quant_scale"], &[]);
    let m2 = b.node("Mul", &[&m1, "quant_shift"], &[]);
    let y = b.node("QuantizeLinear", &[&m2, "q_one", "q_zp"], &[]);
    b.output(&y, DType::I8, &batched(&[2]));
    b.finish_model()
}

fn batch_input(batch: usize, seed: u8) -> Tensor {
    let data: Vec<i8> = (0..batch * 4)
        .map(|i| ((i as u8).wrapping_mul(37).wrapping_add(seed)) as i8)
        .collect();
    Tensor::from_i8(&[batch, 4], data).unwrap()
}

/// Fig. 3-like conv chain (fuses to one FusedQConv step): ConvInteger ->
/// Add([1,M,1,1] bias) -> Cast -> Mul -> QuantizeLinear.
fn fig3_like() -> pqdl::onnx::ir::Model {
    let mut b = GraphBuilder::new("alloc_fig3");
    b.input("x", DType::I8, &batched(&[1, 4, 4]));
    b.init(
        "w",
        Tensor::from_i8(&[2, 1, 3, 3], (0..18).map(|i| (i as i8) - 9).collect()).unwrap(),
    );
    b.init("bias", Tensor::from_i32(&[1, 2, 1, 1], vec![50, -50]).unwrap());
    b.init("mult", Tensor::scalar_f32(1.0 / 16.0));
    b.init("q_one", Tensor::scalar_f32(1.0));
    b.init("q_zp", Tensor::scalar_i8(0));
    let acc = b.node(
        "ConvInteger",
        &["x", "w"],
        &[
            ("strides", Attr::Ints(vec![1, 1])),
            ("pads", Attr::Ints(vec![1, 1, 1, 1])),
        ],
    );
    let accb = b.node("Add", &[&acc, "bias"], &[]);
    let f = b.node("Cast", &[&accb], &[("to", Attr::Str("FLOAT".into()))]);
    let m1 = b.node("Mul", &[&f, "mult"], &[]);
    let y = b.node("QuantizeLinear", &[&m1, "q_one", "q_zp"], &[]);
    b.output(&y, DType::I8, &batched(&[2, 4, 4]));
    b.finish_model()
}

#[test]
fn second_run_at_fixed_batch_allocates_nothing() {
    // Sanity: the counter actually counts.
    let (n, _) = counted(|| {
        let v: Vec<u8> = Vec::with_capacity(128);
        std::hint::black_box(&v);
    });
    assert!(n >= 1, "counting allocator is not engaged");

    // Since the plan-time optimizer, the default session runs this chain
    // as ONE FusedQFc step — so everything below proves the FUSED path's
    // steady state (the kernel's accumulator parks in per-step scratch,
    // the output recycles through `run_into`).
    let sess = Session::new(fig1_like()).unwrap().with_parallelism(false);
    assert_eq!(sess.plan_stats().fused_qfc, 1, "fig1 chain must fuse");
    // The plan is stamped with the host's active ISA at compile time
    // (the `Isa::active()` OnceLock is warm from here on, so the
    // zero-allocation proof below covers the SIMD dispatch path wherever
    // the host — or a PQDL_FORCE_ISA override — selects one).
    assert_eq!(
        sess.plan_stats().isa,
        pqdl::ops::Isa::active(),
        "plan must carry the active kernel ISA"
    );
    assert!(
        sess.plan_stats().isa_steps >= 1,
        "the fused FC step must report ISA dispatch"
    );
    let x8 = batch_input(8, 3);
    let expected8 = sess.run_unplanned(&[("x", x8.clone())]).unwrap();

    // Run 1: warms the arena (allocates every buffer once) and fills
    // `outs` whose storage run 2 recycles.
    let mut outs = Vec::new();
    sess.run_into(&[("x", &x8)], &mut outs).unwrap();
    assert_eq!(outs, expected8, "run 1 output");

    // Run 2 at the same batch size: the acceptance criterion — ZERO
    // heap allocations on the hot path.
    let (allocs, result) = counted(|| sess.run_into(&[("x", &x8)], &mut outs));
    result.unwrap();
    assert_eq!(outs, expected8, "run 2 output");
    assert_eq!(
        allocs, 0,
        "second run at a fixed batch size must not allocate (steady-state arena)"
    );

    // And it stays at zero (run 3, different input values, same shape).
    let x8b = batch_input(8, 111);
    let expected8b = sess.run_unplanned(&[("x", x8b.clone())]).unwrap();
    let (allocs, result) = counted(|| sess.run_into(&[("x", &x8b)], &mut outs));
    result.unwrap();
    assert_eq!(outs, expected8b, "run 3 output");
    assert_eq!(allocs, 0, "third run must not allocate either");

    // A batch-size change may allocate once (buffers re-size)...
    let x3 = batch_input(3, 7);
    let expected3 = sess.run_unplanned(&[("x", x3.clone())]).unwrap();
    sess.run_into(&[("x", &x3)], &mut outs).unwrap();
    assert_eq!(outs, expected3, "post-resize output");
    // ...after which the new size is steady-state again. (Shrinking
    // reuses capacity, so this holds immediately.)
    let (allocs, result) = counted(|| sess.run_into(&[("x", &x3)], &mut outs));
    result.unwrap();
    assert_eq!(outs, expected3, "steady small-batch output");
    assert_eq!(allocs, 0, "steady state at the new batch size");

    // -- unfused plan keeps its zero-allocation steady state -------------
    // `PlanOptions { fuse: false }` is the differential baseline; its
    // node-per-step execution must not have regressed.
    let unfused = Session::new_with_options(fig1_like(), PlanOptions { fuse: false })
        .unwrap()
        .with_parallelism(false);
    assert_eq!(unfused.plan_stats().fused_qfc, 0);
    let mut uouts = Vec::new();
    unfused.run_into(&[("x", &x8)], &mut uouts).unwrap();
    assert_eq!(uouts, expected8, "unfused run 1 output");
    let (allocs, result) = counted(|| unfused.run_into(&[("x", &x8)], &mut uouts));
    result.unwrap();
    assert_eq!(uouts, expected8, "unfused run 2 output");
    assert_eq!(allocs, 0, "unfused plan steady state must stay allocation-free");

    // -- fused conv chain (FusedQConv: im2col scratch + accumulator
    //    scratch + recycled output) ---------------------------------------
    let conv = Session::new(fig3_like()).unwrap().with_parallelism(false);
    assert_eq!(conv.plan_stats().fused_qconv, 1, "fig3 chain must fuse");
    let cx = Tensor::from_i8(
        &[2, 1, 4, 4],
        (0..32).map(|i| ((i * 23 % 251) as u8) as i8).collect(),
    )
    .unwrap();
    let cexpected = conv.run_unplanned(&[("x", cx.clone())]).unwrap();
    let mut couts = Vec::new();
    conv.run_into(&[("x", &cx)], &mut couts).unwrap();
    assert_eq!(couts, cexpected, "fused conv run 1 output");
    let (allocs, result) = counted(|| conv.run_into(&[("x", &cx)], &mut couts));
    result.unwrap();
    assert_eq!(couts, cexpected, "fused conv run 2 output");
    assert_eq!(allocs, 0, "fused conv steady state must be allocation-free");

    // -- serving-path fusion discipline ---------------------------------
    // The batch worker fuses queued request tensors by REFERENCE
    // (`concat_batch(&[&Tensor])`): the fused buffer is the only
    // allocation, independent of how many requests are fused. The old
    // worker cloned every input first, adding one data allocation PER
    // REQUEST — the bound below (fused data + slack for the enum wrap)
    // would trip immediately if the clones came back.
    let requests: Vec<pqdl::tensor::Tensor> = (0..4).map(|i| batch_input(2, i)).collect();
    let refs: Vec<&pqdl::tensor::Tensor> = requests.iter().collect();
    let (allocs, fused) = counted(|| pqdl::coordinator::concat_batch(&refs));
    let fused = fused.unwrap();
    assert_eq!(fused.shape(), &[8, 4]);
    assert!(
        allocs <= 2,
        "fusing 4 borrowed requests must only allocate the fused buffer \
         (got {allocs} allocations; per-request input clones are back?)"
    );
}
