//! Integration: the python→HLO→PJRT round trip against the Rust stack.
//!
//! Requires `make artifacts` to have run (skips gracefully otherwise so
//! `cargo test` works on a fresh checkout).

use pqdl::figures::Figure;
use pqdl::hwsim::{HwConfig, HwModule};
use pqdl::interp::Session;
use pqdl::runtime::{ArtifactRegistry, PjrtEngine};

fn registry() -> Option<(PjrtEngine, ArtifactRegistry)> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    let engine = PjrtEngine::cpu().expect("PJRT CPU client");
    let reg = ArtifactRegistry::load(&engine, &dir).expect("loading artifacts");
    Some((engine, reg))
}

#[test]
fn artifacts_reproduce_python_golden_outputs() {
    let Some((_engine, reg)) = registry() else {
        return;
    };
    let rows = reg.verify_golden().expect("golden verification");
    assert_eq!(rows.len(), 12, "6 variants x 2 batches");
    for (variant, batch, diff) in rows {
        // PJRT re-executes the very HLO Python lowered: bit-exact.
        assert_eq!(diff, 0, "{variant}_b{batch} diverged from golden");
    }
}

#[test]
fn pjrt_agrees_with_interpreter_within_margins() {
    let Some((_engine, reg)) = registry() else {
        return;
    };
    for fig in Figure::ALL {
        let model = fig.model();
        let sess = Session::new(model).unwrap();
        for batch in reg.batches(fig.name()) {
            let entry = reg.get(fig.name(), batch).unwrap();
            let x = fig.input(batch, 42);
            let interp_out = &sess.run(&[("x", x.clone())]).unwrap()[0];
            let pjrt_out = entry.run(&x).unwrap();
            assert_eq!(interp_out.shape(), pjrt_out.shape());
            let a = interp_out.as_quantized_i32().unwrap();
            let b = pjrt_out.as_quantized_i32().unwrap();
            let max_diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).max().unwrap();
            // Same float contract on both sides; XLA may fold the two
            // rescale Muls into one product, worth at most 1 LSB before
            // an activation and its slope-amplified equivalent after.
            let tol = match fig {
                Figure::Fig4TanhInt8 => 4,
                Figure::Fig5TanhF16 => 2,
                Figure::Fig6SigmoidF16 => 5,
                _ => 1,
            };
            assert!(
                max_diff <= tol,
                "{}_b{batch}: interp vs PJRT max LSB diff {max_diff} > {tol}",
                fig.name()
            );
        }
    }
}

#[test]
fn pjrt_agrees_with_hwsim_within_margins() {
    let Some((_engine, reg)) = registry() else {
        return;
    };
    for fig in Figure::ALL {
        let model = fig.model();
        let hw = HwModule::compile(&model, HwConfig::default()).unwrap();
        for batch in reg.batches(fig.name()) {
            let entry = reg.get(fig.name(), batch).unwrap();
            let x = fig.input(batch, 42);
            let (hw_out, cost) = hw.run(&x).unwrap();
            let pjrt_out = entry.run(&x).unwrap();
            assert!(cost.macs > 0);
            let a = hw_out.as_quantized_i32().unwrap();
            let b = pjrt_out.as_quantized_i32().unwrap();
            let max_diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).max().unwrap();
            let tol = match fig {
                Figure::Fig4TanhInt8 => 5,
                Figure::Fig5TanhF16 => 3,
                Figure::Fig6SigmoidF16 => 6,
                _ => 1,
            };
            assert!(
                max_diff <= tol,
                "{}_b{batch}: hwsim vs PJRT max LSB diff {max_diff} > {tol}",
                fig.name()
            );
        }
    }
}

#[test]
fn pjrt_backend_pads_and_chunks_odd_batches() {
    // Artifacts exist only for batches {1, 8}; the backend must pad
    // batch 3 up to 8 and chunk batch 20 through 8+8+4(padded), with
    // outputs identical to the interpreter per-row.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    use pqdl::coordinator::{Backend, PjrtBackend};
    use pqdl::runtime::PjrtService;
    let svc = PjrtService::spawn(dir).unwrap();
    let fig = Figure::Fig1FcTwoMul;
    let be = PjrtBackend::new(svc.clone(), fig.name()).unwrap();
    let sess = Session::new(fig.model()).unwrap();
    for batch in [1usize, 3, 8, 9, 20, 64] {
        let x = fig.input(batch, batch as u64);
        let got = be.run_batch(&x).unwrap();
        let want = &sess.run(&[("x", x)]).unwrap()[0];
        assert_eq!(got.shape(), want.shape(), "batch {batch}");
        let a = got.as_quantized_i32().unwrap();
        let b = want.as_quantized_i32().unwrap();
        let max_diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).max().unwrap();
        assert!(max_diff <= 1, "batch {batch}: max diff {max_diff}");
    }
    svc.shutdown();
}
