//! Sub-8-bit end-to-end contract on the narrow figure-class models
//! (int4 MLP, bipolar CNN) plus emitted int3/int2 FC chains:
//!
//! 1. Models validate (including their advisory `pqdl.width.*`
//!    metadata) and their plans bake the expected narrow kernel
//!    families (`fused_int4` / `fused_int3` / `fused_int2` /
//!    `fused_bipolar` in [`PlanStats`]) — including the nibble-packed
//!    activation edge between paired fused FCs (`packed_act_nibble`).
//! 2. The three-way differential oracle holds bit for bit: fused plan ==
//!    unfused plan == legacy interpreter, across batch sizes, on both
//!    the serial and auto executor paths. Narrow baking (and packed
//!    activation hand-off) is an optimization, never a semantic change.
//! 3. Forced `PQDL_PACK_WIDTH` values are honored exactly: a model whose
//!    widened weights fit the forced range bakes that family on every
//!    fused chain; one that does not is rejected at plan time with
//!    [`SessionError::Pack`] naming the knob. The CI width matrix
//!    re-runs this suite across auto/int8/int4/bipolar/int2.
//! 4. The hardware lift derives the minimal logical weight width from
//!    the weight values alone (no metadata required — paper goal 1),
//!    pinning the widths the cost model's traffic scaling uses.

use pqdl::hwsim::{HwConfig, HwModule};
use pqdl::interp::{PlanOptions, Session, SessionError};
use pqdl::onnx::{batched, GraphBuilder, Model};
use pqdl::opt::PackWidth;
use pqdl::proptest_util::{run_prop, RangeUsize};
use pqdl::quant::QType;
use pqdl::rewrite::patterns::{emit_fc, ActKind, FcParams, RescaleOp};
use pqdl::tensor::{DType, Tensor};
use pqdl::train::NarrowModel;

/// Does the forced width admit weight values spanning `[lo, hi]`?
/// (Bipolar is stricter than its range: it has no code point for 0.)
fn width_admits(w: PackWidth, lo: i32, hi: i32) -> bool {
    match w {
        PackWidth::Auto | PackWidth::Int8 => true,
        PackWidth::Int4 => lo >= -8 && hi <= 7,
        PackWidth::Int3 => lo >= -4 && hi <= 3,
        PackWidth::Int2 => lo >= -2 && hi <= 1,
        PackWidth::Bipolar => lo == -1 && hi == 1,
    }
}

/// Weight-value span of each narrow figure model (int4 quantization pins
/// an extremal ±7 weight; binarization emits strictly ±1).
fn model_span(m: NarrowModel) -> (i32, i32) {
    match m {
        NarrowModel::Mlp4 => (-7, 7),
        NarrowModel::BipolarCnn => (-1, 1),
    }
}

/// Assert that `model` is rejected at plan time with a [`SessionError::Pack`]
/// whose message names the knob and the offending width.
fn assert_pack_rejection(model: Model, name: &str) {
    let err = Session::new(model).expect_err(name);
    assert!(
        matches!(err, SessionError::Pack(_)),
        "{name}: expected Pack rejection, got {err}"
    );
    let msg = err.to_string();
    assert!(
        msg.contains("PQDL_PACK_WIDTH") && msg.contains(PackWidth::active().name()),
        "{name}: rejection must name the knob and width: {msg}"
    );
}

#[test]
fn narrow_models_validate_and_bake_narrow_kernels() {
    // The CI width matrix re-runs this suite with forced PQDL_PACK_WIDTH
    // values; the expectations below branch on the active policy. Under
    // the default Auto policy the minimal-width counts are pinned
    // exactly; under a forced width every fused chain either bakes that
    // family or the whole session is rejected at plan time.
    let width = PackWidth::active();
    for m in NarrowModel::ALL {
        let model = m.model();
        pqdl::onnx::check_model(&model).unwrap();
        let (lo, hi) = model_span(m);
        if !width_admits(width, lo, hi) {
            assert_pack_rejection(model, m.name());
            continue;
        }
        let sess = Session::new(model).unwrap();
        let stats = sess.plan_stats();
        assert!(
            stats.steps < stats.nodes,
            "{}: fusion must shrink the plan ({stats})",
            m.name()
        );
        let chains = match m {
            NarrowModel::Mlp4 => {
                assert_eq!(stats.fused_qfc, 2, "{}: FC chains ({stats})", m.name());
                2
            }
            NarrowModel::BipolarCnn => {
                assert_eq!(stats.fused_qconv, 1, "{}: conv chain ({stats})", m.name());
                assert_eq!(stats.fused_qfc, 1, "{}: FC head ({stats})", m.name());
                2
            }
        };
        let (want4, want3, want2, want1) = match (width, m) {
            // Auto picks the minimal width per chain.
            (PackWidth::Auto, NarrowModel::Mlp4) => (chains, 0, 0, 0),
            (PackWidth::Auto, NarrowModel::BipolarCnn) => (0, 0, 0, chains),
            // Forced int8 bakes zero narrow kernels.
            (PackWidth::Int8, _) => (0, 0, 0, 0),
            // Forced narrow widths pin EVERY fused chain to that family
            // (±1 weights fit any narrower container).
            (PackWidth::Int4, _) => (chains, 0, 0, 0),
            (PackWidth::Int3, _) => (0, chains, 0, 0),
            (PackWidth::Int2, _) => (0, 0, chains, 0),
            (PackWidth::Bipolar, _) => (0, 0, 0, chains),
        };
        assert_eq!(stats.fused_int4, want4, "{} {width:?}: ({stats})", m.name());
        assert_eq!(stats.fused_int3, want3, "{} {width:?}: ({stats})", m.name());
        assert_eq!(stats.fused_int2, want2, "{} {width:?}: ({stats})", m.name());
        assert_eq!(
            stats.fused_bipolar, want1,
            "{} {width:?}: ({stats})",
            m.name()
        );
        // Packed-activation pairing: Mlp4's hidden edge is int4-typed and
        // chains FC→FC, so any non-int8 policy hands the second FC the
        // nibble-packed edge; the bipolar CNN has no FC→FC edge.
        let want_nibble = match m {
            NarrowModel::Mlp4 if width != PackWidth::Int8 => 1,
            _ => 0,
        };
        assert_eq!(
            stats.packed_act_nibble, want_nibble,
            "{} {width:?}: packed-activation edges ({stats})",
            m.name()
        );
        assert_eq!(
            stats.packed_act_bitplane, 0,
            "{} {width:?}: ({stats})",
            m.name()
        );
    }
}

/// The three-way oracle extended to the sub-8-bit models. This is the
/// strongest statement the PR makes: nibble-packed int4 GEMM, the
/// XNOR-popcount conv, the packed-activation fused hand-off, the
/// Clip-absorbing matcher, and the narrow saturation epilogues all agree
/// BIT FOR BIT with the node-by-node legacy interpreter executing the
/// raw standard-ONNX graph. (Under the default Auto policy the Mlp4 leg
/// exercises the nibble-packed activation edge for real — the plan
/// stamps it, per the stats pin above.)
#[test]
fn narrow_three_way_bit_identical() {
    let width = PackWidth::active();
    for m in NarrowModel::ALL {
        let (lo, hi) = model_span(m);
        if !width_admits(width, lo, hi) {
            continue; // rejection contract covered above
        }
        let fused = Session::new(m.model()).unwrap();
        let unfused = Session::new_with_options(m.model(), PlanOptions { fuse: false }).unwrap();
        assert_eq!(
            unfused.plan_stats().steps,
            unfused.plan_stats().nodes,
            "{}: unfused twin must not fuse",
            m.name()
        );
        run_prop(
            &format!("narrow_three_way::{}", m.name()),
            &RangeUsize { lo: 1, hi: 17 },
            0x5B17 ^ m.name().len() as u64,
            8,
            |&batch| {
                let x = m.input(batch, batch as u64 * 173 + 11);
                let legacy = fused
                    .run_unplanned(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let f = fused
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let u = unfused
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let auto = fused.run(&[("x", x)]).map_err(|e| e.to_string())?;
                if legacy != f || legacy != u || legacy != auto {
                    return Err(format!(
                        "{}: three-way divergence at batch {batch}",
                        m.name()
                    ));
                }
                Ok(())
            },
        );
    }
}

const TINY_K: usize = 12;
const TINY_H: usize = 10;
const TINY_N: usize = 4;

/// A two-layer FC chain whose weights deterministically sweep the whole
/// `[lo, hi]` alphabet (both extremes present, so `QType::minimal_for`
/// recovers exactly the intended width). The hidden edge is int4-typed,
/// making the pair nibble-eligible — the packed-activation hand-off runs
/// over int3/int2-baked consumer weights.
fn tiny_fc_chain(name: &str, lo: i32, hi: i32) -> Model {
    let span = hi - lo + 1;
    let w0: Vec<i8> = (0..TINY_K * TINY_H)
        .map(|i| (lo + (i as i32 % span)) as i8)
        .collect();
    let w1: Vec<i8> = (0..TINY_H * TINY_N)
        .map(|i| (lo + ((i as i32 + 1) % span)) as i8)
        .collect();
    let mut b = GraphBuilder::new(name);
    b.input("x", DType::I8, &batched(&[TINY_K]));
    let h = emit_fc(
        &mut b,
        "x",
        &FcParams {
            weight_q: Tensor::from_i8(&[TINY_K, TINY_H], w0).unwrap(),
            bias_q: None,
            rescale: RescaleOp::OneMul(0.25),
            activation: ActKind::Relu,
            out_qtype: QType::Int(4),
        },
        "l0",
    );
    let y = emit_fc(
        &mut b,
        &h,
        &FcParams {
            weight_q: Tensor::from_i8(&[TINY_H, TINY_N], w1).unwrap(),
            bias_q: None,
            rescale: RescaleOp::OneMul(0.5),
            activation: ActKind::None,
            out_qtype: QType::I8,
        },
        "l1",
    );
    b.output(&y, DType::I8, &batched(&[TINY_N]));
    b.finish_model()
}

/// int3/int2 end-to-end round-trips: the Auto ladder bakes the minimal
/// family, forced widths pin or reject, the nibble-packed edge pairs
/// over the narrow consumer weights, and the three-way oracle holds.
#[test]
fn int2_int3_chains_bake_and_stay_bit_identical() {
    let width = PackWidth::active();
    for (label, lo, hi) in [("int3", -4i32, 3i32), ("int2", -2, 1)] {
        let model = tiny_fc_chain(label, lo, hi);
        pqdl::onnx::check_model(&model).unwrap();
        if !width_admits(width, lo, hi) {
            assert_pack_rejection(model, label);
            continue;
        }
        let fused = Session::new(model.clone()).unwrap();
        let stats = fused.plan_stats();
        assert_eq!(stats.fused_qfc, 2, "{label}: FC chains ({stats})");
        let (want4, want3, want2) = match (width, label) {
            (PackWidth::Auto, "int3") => (0, 2, 0),
            (PackWidth::Auto, "int2") => (0, 0, 2),
            (PackWidth::Int8, _) => (0, 0, 0),
            (PackWidth::Int4, _) => (2, 0, 0),
            (PackWidth::Int3, _) => (0, 2, 0),
            (PackWidth::Int2, _) => (0, 0, 2), // int3 weights were rejected
            (w, l) => unreachable!("unadmitted combination {w:?}/{l}"),
        };
        assert_eq!(stats.fused_int4, want4, "{label} {width:?}: ({stats})");
        assert_eq!(stats.fused_int3, want3, "{label} {width:?}: ({stats})");
        assert_eq!(stats.fused_int2, want2, "{label} {width:?}: ({stats})");
        let want_nibble = if width == PackWidth::Int8 { 0 } else { 1 };
        assert_eq!(
            stats.packed_act_nibble, want_nibble,
            "{label} {width:?}: packed-activation edge ({stats})"
        );

        let unfused = Session::new_with_options(model, PlanOptions { fuse: false }).unwrap();
        run_prop(
            &format!("tiny_three_way::{label}"),
            &RangeUsize { lo: 1, hi: 13 },
            0x2331 ^ lo as u64,
            8,
            |&batch| {
                let x = pqdl::figures::canonical_input(batch, TINY_K, batch as u64 * 31 + 7);
                let legacy = fused
                    .run_unplanned(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let f = fused
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let u = unfused
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let auto = fused.run(&[("x", x)]).map_err(|e| e.to_string())?;
                if legacy != f || legacy != u || legacy != auto {
                    return Err(format!("{label}: three-way divergence at batch {batch}"));
                }
                Ok(())
            },
        );
    }
}

/// The hardware lift re-derives each stage's logical weight width from
/// the weight VALUES (int4 quantization pins an extremal ±7 weight;
/// binarization emits strictly ±1), with no reliance on the advisory
/// metadata — and independently of the interpreter's PQDL_PACK_WIDTH
/// policy, which never reaches the lift.
#[test]
fn hw_lift_derives_minimal_weight_widths() {
    let mlp4 = HwModule::compile(&NarrowModel::Mlp4.model(), HwConfig::default()).unwrap();
    assert_eq!(mlp4.weight_widths(), vec![4, 4]);

    let bcnn = HwModule::compile(&NarrowModel::BipolarCnn.model(), HwConfig::default()).unwrap();
    assert_eq!(bcnn.weight_widths(), vec![1, 1]);

    // The narrow widths must shrink the modeled weight traffic relative
    // to the same graph costed at full width: DRAM bytes are dominated
    // by weight loads in these models.
    let b = 4usize;
    let (_, cost4) = mlp4.run_serial(&NarrowModel::Mlp4.input(b, 5)).unwrap();
    let (_, cost1) = bcnn.run_serial(&NarrowModel::BipolarCnn.input(b, 5)).unwrap();
    // mlp4 weights: 8*16 + 16*3 = 176 logical int4 values -> 88 bytes.
    assert_eq!(cost4.dram_bytes, 88);
    // bipolar cnn: conv 4*9 = 36 bits -> 5 bytes (per im2col'd GEMM),
    // fc 36*10 = 360 bits -> 45 bytes.
    assert_eq!(cost1.dram_bytes, 5 + 45);
}
