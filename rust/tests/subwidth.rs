//! Sub-8-bit end-to-end contract on the narrow figure-class models
//! (int4 MLP, bipolar CNN):
//!
//! 1. Both models validate (including their advisory `pqdl.width.*`
//!    metadata) and their plans bake the expected narrow kernel
//!    families (`fused_int4` / `fused_bipolar` in [`PlanStats`]).
//! 2. The three-way differential oracle holds bit for bit: fused plan ==
//!    unfused plan == legacy interpreter, across batch sizes, on both
//!    the serial and auto executor paths. Narrow baking is an
//!    optimization, never a semantic change.
//! 3. The hardware lift derives the minimal logical weight width from
//!    the weight values alone (no metadata required — paper goal 1),
//!    pinning the widths the cost model's traffic scaling uses.

use pqdl::hwsim::{HwConfig, HwModule};
use pqdl::interp::{PlanOptions, Session};
use pqdl::opt::PackWidth;
use pqdl::proptest_util::{run_prop, RangeUsize};
use pqdl::train::NarrowModel;

#[test]
fn narrow_models_validate_and_bake_narrow_kernels() {
    // The CI width matrix re-runs this suite with PQDL_PACK_WIDTH=int8;
    // under forced-int8 the plans must bake ZERO narrow kernels (and the
    // three-way oracle below still holds — the knob moves memory, never
    // bits). Under the default Auto policy the counts are pinned exactly.
    let auto = PackWidth::active() == PackWidth::Auto;
    for m in NarrowModel::ALL {
        let model = m.model();
        pqdl::onnx::check_model(&model).unwrap();
        let sess = Session::new(model).unwrap();
        let stats = sess.plan_stats();
        assert!(
            stats.steps < stats.nodes,
            "{}: fusion must shrink the plan ({stats})",
            m.name()
        );
        if !auto {
            assert_eq!(stats.fused_int4, 0, "{}: forced int8 ({stats})", m.name());
            assert_eq!(stats.fused_bipolar, 0, "{}: forced int8 ({stats})", m.name());
        }
        match m {
            NarrowModel::Mlp4 => {
                assert_eq!(stats.fused_qfc, 2, "{}: FC chains ({stats})", m.name());
                if auto {
                    assert_eq!(
                        stats.fused_int4, 2,
                        "{}: both FC layers must bake int4 ({stats})",
                        m.name()
                    );
                    assert_eq!(stats.fused_bipolar, 0, "{}: ({stats})", m.name());
                }
            }
            NarrowModel::BipolarCnn => {
                assert_eq!(stats.fused_qconv, 1, "{}: conv chain ({stats})", m.name());
                assert_eq!(stats.fused_qfc, 1, "{}: FC head ({stats})", m.name());
                if auto {
                    assert_eq!(
                        stats.fused_bipolar, 2,
                        "{}: conv + head must bake bipolar ({stats})",
                        m.name()
                    );
                    assert_eq!(stats.fused_int4, 0, "{}: ({stats})", m.name());
                }
            }
        }
    }
}

/// The three-way oracle extended to the sub-8-bit models. This is the
/// strongest statement the PR makes: nibble-packed int4 GEMM, the
/// XNOR-popcount conv, the Clip-absorbing matcher, and the narrow
/// saturation epilogues all agree BIT FOR BIT with the node-by-node
/// legacy interpreter executing the raw standard-ONNX graph.
#[test]
fn narrow_three_way_bit_identical() {
    for m in NarrowModel::ALL {
        let fused = Session::new(m.model()).unwrap();
        let unfused = Session::new_with_options(m.model(), PlanOptions { fuse: false }).unwrap();
        assert_eq!(
            unfused.plan_stats().steps,
            unfused.plan_stats().nodes,
            "{}: unfused twin must not fuse",
            m.name()
        );
        run_prop(
            &format!("narrow_three_way::{}", m.name()),
            &RangeUsize { lo: 1, hi: 17 },
            0x5B17 ^ m.name().len() as u64,
            8,
            |&batch| {
                let x = m.input(batch, batch as u64 * 173 + 11);
                let legacy = fused
                    .run_unplanned(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let f = fused
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let u = unfused
                    .run_serial(&[("x", x.clone())])
                    .map_err(|e| e.to_string())?;
                let auto = fused.run(&[("x", x)]).map_err(|e| e.to_string())?;
                if legacy != f || legacy != u || legacy != auto {
                    return Err(format!(
                        "{}: three-way divergence at batch {batch}",
                        m.name()
                    ));
                }
                Ok(())
            },
        );
    }
}

/// The hardware lift re-derives each stage's logical weight width from
/// the weight VALUES (int4 quantization pins an extremal ±7 weight;
/// binarization emits strictly ±1), with no reliance on the advisory
/// metadata.
#[test]
fn hw_lift_derives_minimal_weight_widths() {
    let mlp4 = HwModule::compile(&NarrowModel::Mlp4.model(), HwConfig::default()).unwrap();
    assert_eq!(mlp4.weight_widths(), vec![4, 4]);

    let bcnn = HwModule::compile(&NarrowModel::BipolarCnn.model(), HwConfig::default()).unwrap();
    assert_eq!(bcnn.weight_widths(), vec![1, 1]);

    // The narrow widths must shrink the modeled weight traffic relative
    // to the same graph costed at full width: DRAM bytes are dominated
    // by weight loads in these models.
    let b = 4usize;
    let (_, cost4) = mlp4.run_serial(&NarrowModel::Mlp4.input(b, 5)).unwrap();
    let (_, cost1) = bcnn.run_serial(&NarrowModel::BipolarCnn.input(b, 5)).unwrap();
    // mlp4 weights: 8*16 + 16*3 = 176 logical int4 values -> 88 bytes.
    assert_eq!(cost4.dram_bytes, 88);
    // bipolar cnn: conv 4*9 = 36 bits -> 5 bytes (per im2col'd GEMM),
    // fc 36*10 = 360 bits -> 45 bytes.
    assert_eq!(cost1.dram_bytes, 5 + 45);
}
