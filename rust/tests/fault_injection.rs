//! Chaos suite: the serving coordinator under a deterministically
//! hostile backend.
//!
//! `FaultInjectingBackend` schedules errors, panics, delays, and replica
//! aborts by call index from a seed — no wall-clock randomness — and
//! these tests assert the fault-tolerance contract end to end: every
//! accepted request gets exactly one typed response, `shutdown()` still
//! drains, the circuit breaker cycles closed → open → half-open →
//! closed against a real outage, the supervisor respawns aborted
//! replicas and abandons slots whose restart budget is spent, and the
//! metrics account for every fate.
//!
//! Knobs (the CI `fault-injection` job arms the heavy profile):
//! * `PQDL_CHAOS=full` — more replicas, more requests, higher fault
//!   rates, more seeds;
//! * `PQDL_CHAOS_SEED=<u64>` — base seed override, for replaying a
//!   reported failure exactly.

use pqdl::coordinator::{
    BreakerConfig, CoordinatorBuilder, FaultInjectingBackend, FaultKind, FaultPlan, InterpBackend,
    RejectReason, ServeError, ServerConfig, SupervisorConfig,
};
use pqdl::figures::Figure;
use pqdl::interp::Session;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn chaos_full() -> bool {
    std::env::var("PQDL_CHAOS").map(|v| v == "full").unwrap_or(false)
}

fn chaos_seed() -> u64 {
    std::env::var("PQDL_CHAOS_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC4A0_5EED)
}

fn base_config(replicas: usize) -> ServerConfig {
    ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        replicas,
        queue_depth: 4096,
        deadline: None,
        controller: None,
        breaker: None,
        supervisor: None,
    }
}

/// An aggressive supervisor for tests: fast scans, fast respawns.
fn fast_supervisor(max_restarts: u32) -> SupervisorConfig {
    SupervisorConfig {
        heartbeat_timeout: Duration::from_secs(5),
        max_restarts,
        backoff_base: Duration::from_millis(2),
        backoff_cap: Duration::from_millis(50),
        tick: Duration::from_millis(5),
    }
}

/// The headline chaos property. For every replica count and seed in the
/// profile: submit a mixed stream of well-formed and malformed requests
/// against a backend injecting errors, panics, delays, AND replica
/// aborts (with the supervisor respawning the dead) — then require:
///
/// 1. exactly one response per submission, each a typed fate;
/// 2. well-formed outputs that survive are bit-identical to a direct
///    `Session` run;
/// 3. malformed submissions are always `InvalidInput`, faults or not;
/// 4. the metrics account for every fate: executed requests equal
///    Ok+Exec+Panic responses, `errors`/`panics` match the per-response
///    counts, `shed_invalid` matches the malformed count;
/// 5. `shutdown()` still returns (clean drain) afterwards.
#[test]
fn chaos_exactly_one_response_clean_drain_full_accounting() {
    let full = chaos_full();
    let replica_counts: &[usize] = if full { &[1, 2, 4] } else { &[1, 3] };
    let seeds: u64 = if full { 6 } else { 2 };
    let requests: usize = if full { 160 } else { 48 };
    let rate_per_mille: u64 = if full { 300 } else { 150 };

    let fig = Figure::Fig1FcTwoMul;
    let sess = Session::new(fig.model()).unwrap();
    for &replicas in replica_counts {
        for round in 0..seeds {
            let seed = chaos_seed() ^ (round.wrapping_mul(0x9E37) + replicas as u64);
            let plan = FaultPlan::seeded(
                seed,
                rate_per_mille,
                &[
                    FaultKind::Error,
                    FaultKind::Panic,
                    FaultKind::Delay,
                    FaultKind::Abort,
                ],
            )
            .with_delay(Duration::from_millis(2));
            let inner = Arc::new(InterpBackend::new(fig.model()).unwrap());
            let injector = FaultInjectingBackend::new(inner, plan);
            let counters = injector.counters();
            let mut cfg = base_config(replicas);
            // Aborts kill worker threads; the supervisor must keep the
            // lane alive for the whole stream. Budget far above anything
            // this stream can spend.
            cfg.supervisor = Some(fast_supervisor(10_000));
            let coord = CoordinatorBuilder::new(cfg)
                .register("fig1_fc", Arc::new(injector))
                .start();

            // A deterministic request mix: every 5th submission is
            // malformed (wrong feature dim).
            let mut rxs = Vec::new();
            let mut malformed = 0u64;
            for i in 0..requests {
                let x = if i % 5 == 4 {
                    malformed += 1;
                    pqdl::tensor::Tensor::from_i8(&[1, 63], vec![0; 63]).unwrap()
                } else {
                    fig.input(1 + i % 3, seed ^ i as u64)
                };
                rxs.push((i, coord.submit("fig1_fc", x).unwrap()));
            }

            let (mut ok, mut exec, mut panicked, mut invalid, mut lost) =
                (0u64, 0u64, 0u64, 0u64, 0u64);
            for (i, rx) in rxs {
                let resp = rx
                    .recv_timeout(Duration::from_secs(30))
                    .unwrap_or_else(|e| {
                        panic!("req {i} (replicas {replicas}, seed {seed:#x}): no response ({e})")
                    });
                if i % 5 == 4 {
                    match resp.reject_reason() {
                        Some(RejectReason::InvalidInput(_)) => invalid += 1,
                        other => panic!("req {i}: malformed classified {other:?}"),
                    }
                } else {
                    match resp.output {
                        Ok(got) => {
                            let rows = 1 + i % 3;
                            let want = &sess
                                .run(&[("x", fig.input(rows, seed ^ i as u64))])
                                .unwrap()[0];
                            assert_eq!(&got, want, "req {i}: surviving output must be exact");
                            ok += 1;
                        }
                        Err(ServeError::Exec(ref m)) => {
                            assert!(m.contains("injected"), "req {i}: unexpected exec: {m}");
                            exec += 1;
                        }
                        Err(ServeError::BackendPanic(_)) => panicked += 1,
                        Err(ServeError::WorkerLost) => lost += 1,
                        Err(ref e) => panic!("req {i}: unexpected fate {e}"),
                    }
                }
                assert!(rx.try_recv().is_err(), "req {i}: more than one response");
            }
            assert_eq!(invalid, malformed);
            assert_eq!(
                ok + exec + panicked + lost,
                (requests as u64) - malformed,
                "every well-formed request has exactly one typed fate"
            );
            assert_eq!(lost, 0, "supervised lane must not lose requests pre-shutdown");

            // The metrics agree with the observed fates.
            let stats = coord.metrics.snapshot("fig1_fc").unwrap();
            assert_eq!(stats.requests, ok + exec + panicked, "executed requests");
            assert_eq!(stats.errors, exec);
            assert_eq!(stats.panics, panicked);
            assert_eq!(stats.shed_invalid, malformed);
            // Abort panics both answer a batch AND kill the worker; any
            // injected abort shows up as panic responses.
            let injected = counters.total_injected();
            if exec + panicked > 0 {
                assert!(injected > 0);
            }

            // Clean drain even after aborts/restarts.
            let t0 = Instant::now();
            coord.shutdown();
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "drain wedged after chaos"
            );
        }
    }
}

/// Breaker integration against a real (scheduled) outage: two failed
/// batches trip it open, the open window sheds `CircuitOpen`, the
/// cooldown admits a half-open probe, and the healthy probe closes it.
#[test]
fn circuit_breaker_cycles_through_a_real_outage() {
    let fig = Figure::Fig1FcTwoMul;
    let sess = Session::new(fig.model()).unwrap();
    let inner = Arc::new(InterpBackend::new(fig.model()).unwrap());
    // Calls 0 and 1 fail; everything afterwards is healthy.
    let plan = FaultPlan::none()
        .at(0, FaultKind::Error)
        .at(1, FaultKind::Panic);
    let mut cfg = base_config(1);
    cfg.breaker = Some(BreakerConfig {
        failures_to_open: 2,
        cooldown: Duration::from_millis(150),
        half_open_probes: 1,
    });
    let coord = CoordinatorBuilder::new(cfg)
        .register("fig1_fc", Arc::new(FaultInjectingBackend::new(inner, plan)))
        .start();

    // Closed: the two scheduled failures execute (and trip the breaker).
    let r0 = coord.infer("fig1_fc", fig.input(1, 0)).unwrap();
    assert!(matches!(r0.output, Err(ServeError::Exec(_))));
    let r1 = coord.infer("fig1_fc", fig.input(1, 1)).unwrap();
    assert!(matches!(r1.output, Err(ServeError::BackendPanic(_))));

    // Open: immediate shed, no execution.
    let shed = coord.infer("fig1_fc", fig.input(1, 2)).unwrap();
    assert!(matches!(
        shed.reject_reason(),
        Some(RejectReason::CircuitOpen)
    ));

    // Half-open after the cooldown: the probe executes (call 2 — clean)
    // and closes the breaker.
    std::thread::sleep(Duration::from_millis(200));
    let x = fig.input(1, 3);
    let probe = coord.infer("fig1_fc", x.clone()).unwrap();
    let want = &sess.run(&[("x", x)]).unwrap()[0];
    assert_eq!(&probe.output.unwrap(), want, "probe batch must serve");

    // Closed again: full traffic, no sheds.
    for i in 10..16u64 {
        let x = fig.input(1, i);
        let resp = coord.infer("fig1_fc", x.clone()).unwrap();
        let want = &sess.run(&[("x", x)]).unwrap()[0];
        assert_eq!(&resp.output.unwrap(), want);
    }
    let stats = coord.metrics.snapshot("fig1_fc").unwrap();
    assert_eq!(stats.breaker_opens, 1);
    assert!(stats.shed_circuit >= 1);
    coord.shutdown();
}

/// Supervision: an injected `ReplicaAbort` kills the lane's only worker
/// after answering its batch; the supervisor respawns the slot (fresh
/// fork from the root backend) and the next request serves normally.
#[test]
fn supervisor_respawns_an_aborted_replica() {
    let fig = Figure::Fig1FcTwoMul;
    let sess = Session::new(fig.model()).unwrap();
    let inner = Arc::new(InterpBackend::new(fig.model()).unwrap());
    let plan = FaultPlan::none().at(0, FaultKind::Abort);
    let mut cfg = base_config(1);
    cfg.supervisor = Some(fast_supervisor(5));
    let coord = CoordinatorBuilder::new(cfg)
        .register("fig1_fc", Arc::new(FaultInjectingBackend::new(inner, plan)))
        .start();

    // Call 0 aborts: the request is still answered (typed panic), then
    // the worker thread exits.
    let r0 = coord.infer("fig1_fc", fig.input(1, 0)).unwrap();
    assert!(matches!(r0.output, Err(ServeError::BackendPanic(_))));

    // The lane has zero live workers until the supervisor respawns one;
    // this infer blocks on exactly that happening.
    let x = fig.input(1, 1);
    let r1 = coord.infer("fig1_fc", x.clone()).unwrap();
    let want = &sess.run(&[("x", x)]).unwrap()[0];
    assert_eq!(&r1.output.unwrap(), want, "respawned replica must serve");

    let stats = coord.metrics.snapshot("fig1_fc").unwrap();
    assert!(stats.restarts >= 1, "restart must be counted");
    assert_eq!(stats.panics, 1);
    coord.shutdown();
}

/// Restart-budget exhaustion: a backend that aborts EVERY call burns
/// through `max_restarts`, the slot is abandoned (counted once), and a
/// request queued into the dead lane is answered `WorkerLost` by the
/// graceful shutdown's leftover sweep — never silently dropped.
#[test]
fn supervisor_restart_budget_exhaustion_is_counted_and_drains_typed() {
    let fig = Figure::Fig1FcTwoMul;
    let inner = Arc::new(InterpBackend::new(fig.model()).unwrap());
    let plan = FaultPlan::seeded(0, 1000, &[FaultKind::Abort]); // every call aborts
    let mut cfg = base_config(1);
    cfg.supervisor = Some(fast_supervisor(2)); // 2 respawns, then abandoned
    let coord = CoordinatorBuilder::new(cfg)
        .register("fig1_fc", Arc::new(FaultInjectingBackend::new(inner, plan)))
        .start();

    // Three served batches: the original worker plus its two respawns,
    // each answering one batch (typed panic) before dying.
    for i in 0..3u64 {
        let resp = coord.infer("fig1_fc", fig.input(1, i)).unwrap();
        assert!(
            matches!(resp.output, Err(ServeError::BackendPanic(_))),
            "batch {i} must still be answered"
        );
    }

    // The third death exhausts the budget; wait for the ticker to count
    // it (bounded poll, no sleep-and-hope).
    let t0 = Instant::now();
    loop {
        let stats = coord.metrics.snapshot("fig1_fc").unwrap();
        if stats.restart_budget_exhausted >= 1 {
            assert_eq!(stats.restarts, 2, "exactly the budgeted respawns happened");
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "budget exhaustion never recorded"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    // A request into the dead (but open) lane is accepted, never served
    // — graceful shutdown must still answer it, typed.
    let rx = coord.submit("fig1_fc", fig.input(1, 99)).unwrap();
    coord.shutdown();
    let resp = rx.try_recv().expect("leftover request must be answered");
    assert_eq!(resp.output, Err(ServeError::WorkerLost));
}
