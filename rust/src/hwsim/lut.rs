//! Activation lookup tables.
//!
//! On fixed-point hardware any pure elementwise int8→int8 function is a
//! 256-entry ROM. The table is built by composing exactly the float
//! pipeline the ONNX model codifies (Dequantize → [f16 cast] → Tanh /
//! Sigmoid → Quantize), so an 8-bit LUT reproduces the standard-tool
//! output *bit-exactly*; narrower indices (`lut_bits < 8`) quantize the
//! index and expose the accuracy/area trade-off in the co-design sweep.

use crate::ops::qlinear::round_half_even;
use crate::quant::QType;
use crate::tensor::f16::F16;

/// Which activation function the stage computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActFn {
    Tanh,
    Sigmoid,
}

/// Precision the (simulated) hardware evaluates the function in when
/// building the ROM — mirrors the model's Fig. 4 (f32) vs Fig. 5/6 (f16)
/// variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActEval {
    F32,
    F16,
}

/// A ROM mapping the int8 stage input to the quantized activation output.
#[derive(Clone, Debug)]
pub struct ActLut {
    /// 256 entries indexed by (q8 as u8); values are the output integer
    /// (i8 or u8 domain per `out_qtype`), stored widened.
    table: Vec<i16>,
    pub out_qtype: QType,
    pub index_bits: u32,
}

impl ActLut {
    /// Build the ROM from the codified parameters.
    pub fn build(
        f: ActFn,
        eval: ActEval,
        in_scale: f32,
        out_scale: f32,
        out_qtype: QType,
        index_bits: u32,
    ) -> ActLut {
        let (lo, hi) = out_qtype.range();
        let mut table = vec![0i16; 256];
        let index_mask: i32 = !0i32 << (8 - index_bits.min(8)); // top index_bits kept
        for raw in -128..=127i32 {
            // Narrow index: truncate low bits (hardware drops them).
            let idx = raw & index_mask;
            let x = idx as f32 * in_scale;
            let y = match (f, eval) {
                (ActFn::Tanh, ActEval::F32) => x.tanh(),
                (ActFn::Sigmoid, ActEval::F32) => 1.0 / (1.0 + (-x).exp()),
                (ActFn::Tanh, ActEval::F16) => F16::from_f32(x).tanh().to_f32(),
                (ActFn::Sigmoid, ActEval::F16) => F16::from_f32(x).sigmoid().to_f32(),
            };
            let q = round_half_even(y / out_scale).clamp(lo as f32, hi as f32) as i16;
            table[(raw as u8) as usize] = q;
        }
        ActLut {
            table,
            out_qtype,
            index_bits,
        }
    }

    /// Look up one int8 input.
    #[inline]
    pub fn get(&self, q: i8) -> i16 {
        self.table[(q as u8) as usize]
    }

    /// Apply to a widened-i32 slice in place (values must be in i8 range;
    /// the preceding requantize stage guarantees it).
    pub fn apply(&self, xs: &mut [i32]) {
        for v in xs {
            *v = self.get(*v as i8) as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_width_lut_matches_float_pipeline() {
        let in_scale = 4.0 / 127.0;
        let out_scale = 1.0 / 127.0;
        let lut = ActLut::build(ActFn::Tanh, ActEval::F32, in_scale, out_scale, QType::I8, 8);
        for q in -128..=127i32 {
            let x = q as f32 * in_scale;
            let want = round_half_even(x.tanh() / out_scale).clamp(-128.0, 127.0) as i16;
            assert_eq!(lut.get(q as i8), want, "q={q}");
        }
    }

    #[test]
    fn sigmoid_lut_is_uint8_monotone() {
        let lut = ActLut::build(
            ActFn::Sigmoid,
            ActEval::F16,
            8.0 / 127.0,
            1.0 / 255.0,
            QType::U8,
            8,
        );
        let mut prev = -1i16;
        for q in -128..=127i32 {
            let v = lut.get(q as i8);
            assert!((0..=255).contains(&v));
            assert!(v >= prev, "monotonicity broken at {q}");
            prev = v;
        }
        assert_eq!(lut.get(-128), 0);
        assert_eq!(lut.get(127), 255);
    }

    #[test]
    fn narrow_index_coarsens() {
        let fine = ActLut::build(ActFn::Tanh, ActEval::F32, 0.03, 1.0 / 127.0, QType::I8, 8);
        let coarse = ActLut::build(ActFn::Tanh, ActEval::F32, 0.03, 1.0 / 127.0, QType::I8, 5);
        // Coarse LUT is piecewise constant over 2^3-wide input bins.
        assert_eq!(coarse.get(8), coarse.get(9));
        assert_eq!(coarse.get(8), coarse.get(15));
        // And differs from the fine LUT somewhere.
        let diffs = (-128..=127)
            .filter(|&q| fine.get(q as i8) != coarse.get(q as i8))
            .count();
        assert!(diffs > 0);
    }
}
