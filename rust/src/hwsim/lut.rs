//! Activation ROM — re-export shim.
//!
//! The LUT builder moved to [`crate::quant::lut`] so the interpreter's
//! plan-time graph optimizer (`crate::opt`, LUT-folding pass) and the
//! hardware simulator share one implementation: the simulator keeps using
//! [`ActLut::build`] (hardware ROM semantics, narrowable index), the
//! optimizer uses [`ActLut::build_exact`] (bit-identical to the
//! interpreter's node chain). Existing `hwsim::lut` paths keep working
//! through this shim.

pub use crate::quant::lut::{ActEval, ActFn, ActLut};
