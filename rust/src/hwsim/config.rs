//! Hardware configuration of the simulated fixed-point accelerator.
//!
//! The knobs here are the co-design surface: the model producer never
//! sees them, and the model file never changes when they change — that
//! independence is the paper's central claim. Defaults are sized like a
//! small edge-inference NPU (8×8 MAC array class).

/// Rounding the rescale unit applies to the shifted-out bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// Round half away from zero (add ±half before shifting) — the
    /// cheapest fixed-point rounding, common in NPU rescale units.
    HalfAwayFromZero,
    /// Round half to even — matches ONNX QuantizeLinear exactly, costs
    /// one extra comparator.
    HalfEven,
    /// Truncate (floor toward zero) — the degenerate no-rounding unit;
    /// included to let the co-design sweep show why rounding hardware is
    /// worth its gates.
    Truncate,
}

/// Accelerator configuration.
#[derive(Clone, Debug)]
pub struct HwConfig {
    /// Systolic MAC array rows (output-stationary mapping: rows ↔ M).
    pub mac_rows: usize,
    /// MAC array columns (↔ N).
    pub mac_cols: usize,
    /// Activation LUT index width in bits (8 = exact int8 lookup; fewer
    /// bits truncate the index and interpolate nothing — the co-design
    /// sweep measures the accuracy cost).
    pub lut_bits: u32,
    /// Rescale-unit rounding mode.
    pub rounding: Rounding,
    /// Maximum right-shift the rescale unit supports.
    pub max_shift: u32,
    /// Whether an fp16 activation FPU exists (Figs. 5/6). Without it,
    /// fp16 activation stages fall back to the LUT path.
    pub has_f16_unit: bool,
    /// Clock, for latency estimates.
    pub freq_mhz: f64,
    /// Energy per int8 MAC (pJ).
    pub pj_per_mac: f64,
    /// Energy per byte moved SRAM<->array (pJ).
    pub pj_per_sram_byte: f64,
    /// Energy per byte moved DRAM<->SRAM (pJ).
    pub pj_per_dram_byte: f64,
}

impl Default for HwConfig {
    fn default() -> HwConfig {
        HwConfig {
            mac_rows: 8,
            mac_cols: 8,
            lut_bits: 8,
            rounding: Rounding::HalfEven,
            max_shift: 31,
            has_f16_unit: true,
            freq_mhz: 800.0,
            // Representative 7nm-class numbers (order-of-magnitude).
            pj_per_mac: 0.05,
            pj_per_sram_byte: 0.2,
            pj_per_dram_byte: 20.0,
        }
    }
}

impl HwConfig {
    /// Convenience: a named sweep point for the co-design bench.
    pub fn with_array(mut self, rows: usize, cols: usize) -> HwConfig {
        self.mac_rows = rows;
        self.mac_cols = cols;
        self
    }

    pub fn with_lut_bits(mut self, bits: u32) -> HwConfig {
        self.lut_bits = bits;
        self
    }

    pub fn with_rounding(mut self, r: Rounding) -> HwConfig {
        self.rounding = r;
        self
    }
}
