//! The "hardware tool chain" + integer-only executor.
//!
//! [`HwModule::compile`] plays the role of the accelerator vendor's
//! compiler: it consumes the *same standard ONNX file* every other
//! backend runs, recognizes the paper's codified patterns, and lifts them
//! into fixed-point pipeline stages:
//!
//! * `MatMulInteger/ConvInteger + Add + Cast + Mul(+Mul) [+Relu] +
//!   QuantizeLinear` → int8 GEMM/conv with an integer-multiplier +
//!   right-shift rescale unit (§3.1). With the 2-Mul codification the
//!   integer constants are read directly from the model; with 1-Mul the
//!   tool chain derives them (the paper's "responsibility of the
//!   hardware-specific tool chain").
//! * `DequantizeLinear [+Cast f16] + Tanh/Sigmoid [+Cast f32] +
//!   QuantizeLinear` → a 256-entry activation ROM ([`super::lut`]).
//! * Edge `QuantizeLinear`/`DequantizeLinear`/`Softmax` → host stages.
//!
//! Execution is pure integer arithmetic end-to-end on the accelerator
//! stages — there is no f32 rescale path to fall back on, so agreement
//! with the interpreter *demonstrates* the paper's expressiveness claim.

use super::config::{HwConfig, Rounding};
use super::cost::{gemm_cost_wa, host_cost, vector_cost, CostReport};
use super::lut::{ActEval, ActLut};
use crate::onnx::ir::{Graph, Model, Node};
use crate::onnx::shape::ConvAttrs;
use crate::ops::matmul::{gemm_i32, gemm_i32_par};
use crate::opt::matcher::{
    act_chain_follows, match_act_chain, match_q_chain, ConsumerIndex, InitPolicy, MatchFail,
    QChain,
};
use crate::parallel::{self, ThreadPool};
use crate::quant::QType;
use crate::tensor::{DType, Tensor};
use thiserror::Error;

/// Smallest batch [`HwModule::run`] will split: one full
/// [`HW_SPLIT_ROWS`]-row sub-batch plus at least one extra row, so the
/// schedule always has >= 2 pieces (a single piece would be the serial
/// path with extra bookkeeping).
pub const HW_PAR_MIN_BATCH: usize = HW_SPLIT_ROWS + 1;

/// Fixed sub-batch height [`HwModule::run`] schedules batched inference
/// in. This is a CONSTANT of the simulated schedule — deliberately NOT the
/// host's core count (and deliberately NOT auto-tuned) — so the cost
/// report (cycles, traffic, energy) for a given model + input is identical
/// on every machine and thread-pool size; only wall-clock time varies with
/// available workers. Defined through [`crate::tune::Thresholds`] so every
/// split threshold has one home.
pub const HW_SPLIT_ROWS: usize = crate::tune::Thresholds::DEFAULT.hw_split_rows;

#[derive(Error, Debug)]
pub enum HwError {
    #[error("unsupported model for hw compilation: {0}")]
    Unsupported(String),
    #[error("pattern mismatch at node '{node}': {msg}")]
    Pattern { node: String, msg: String },
    #[error("tensor: {0}")]
    Tensor(#[from] crate::tensor::TensorError),
    #[error("quant: {0}")]
    Quant(#[from] crate::quant::QuantError),
    #[error("execution: {0}")]
    Exec(String),
}

fn perr(node: &Node, msg: impl Into<String>) -> HwError {
    HwError::Pattern {
        node: node.name.clone(),
        msg: msg.into(),
    }
}

/// Map a shared-matcher failure into this compiler's error vocabulary.
/// The emitted pre-quantized graphs are linear chains; a value with
/// multiple consumers is outside the pattern language and reported as
/// `Unsupported`, any structural deviation as a `Pattern` error.
fn match_err(e: MatchFail) -> HwError {
    match e {
        MatchFail::MultiConsumer { value } => HwError::Unsupported(format!(
            "value '{value}' has multiple consumers; hw compiler handles chains"
        )),
        MatchFail::Mismatch { node, msg } => HwError::Pattern { node, msg },
    }
}

/// Integer rescale constants lifted from the model.
#[derive(Clone, Copy, Debug)]
pub struct HwRescale {
    pub quant_scale: u32,
    pub shift: u32,
    /// True when read verbatim from a 2-Mul codification (exact); false
    /// when derived from a 1-Mul float multiplier.
    pub exact_from_model: bool,
}

/// One pipeline stage.
pub enum Stage {
    /// Host-side input quantization (float-I/O models only).
    QuantizeInput { scale: f32, qtype: QType },
    /// Fully-connected integer block.
    Fc {
        /// Widened weights, row-major [K, N].
        w: Vec<i32>,
        k: usize,
        n: usize,
        bias: Option<Vec<i32>>,
        rescale: HwRescale,
        relu: bool,
        out_qtype: QType,
        /// Minimal logical weight width (bits), derived from the weight
        /// VALUES at lift time; drives the width-scaled traffic terms of
        /// the cost model ([`gemm_cost_wa`]).
        weight_bits: u8,
    },
    /// Convolution integer block (NCHW).
    Conv {
        w: Vec<i32>,
        m: usize,
        c: usize,
        kh: usize,
        kw: usize,
        attrs: ConvAttrs,
        bias: Option<Vec<i32>>, // length m
        rescale: HwRescale,
        relu: bool,
        out_qtype: QType,
        /// Minimal logical weight width (bits), as in [`Stage::Fc`].
        weight_bits: u8,
    },
    /// Activation ROM stage.
    Act { lut: ActLut, f16_evaluated: bool },
    /// Integer max-pool.
    MaxPool {
        kernel: [usize; 2],
        attrs: ConvAttrs,
    },
    /// Pure shape change.
    Flatten { axis: usize },
    Reshape { spec: Vec<i64> },
    /// Host-side output dequantization.
    DequantizeOutput { scale: f32 },
    /// Host-side softmax (classifier tail).
    SoftmaxHost { axis: i64 },
}

/// A compiled, executable hardware program.
pub struct HwModule {
    pub cfg: HwConfig,
    stages: Vec<Stage>,
    input_dtype: DType,
    /// True when every stage is row-independent along axis 0, enabling the
    /// batch-parallel [`HwModule::run`] path.
    batch_splittable: bool,
}

/// Whether the compiled pipeline treats axis 0 purely as a batch axis:
/// every stage except an axis-0 `Flatten`, a batch-fixing `Reshape`, or a
/// `Softmax` normalizing over axis 0 processes rows independently.
fn stages_batch_splittable(stages: &[Stage], model: &Model) -> bool {
    for stage in stages {
        match stage {
            Stage::Flatten { axis } => {
                if *axis == 0 {
                    return false;
                }
            }
            Stage::Reshape { spec } => {
                // Only an explicit leading 0 (copy the batch dim) provably
                // keeps rows independent. A leading -1 can FOLD rows (e.g.
                // spec [-1, 2*row_elems] merges row pairs), which would make
                // the split path silently diverge from the serial one.
                if spec.first() != Some(&0) {
                    return false;
                }
            }
            _ => {}
        }
    }
    // Softmax axis-0 guard, shared with the interpreter (the stage itself
    // does not carry shapes, so resolve against the source graph).
    if model.graph.nodes.iter().any(|n| n.op_type == "Softmax") {
        let Ok(types) = crate::onnx::shape::infer_graph(&model.graph) else {
            return false;
        };
        if crate::onnx::shape::couples_rows_on_axis0(&model.graph, &types) {
            return false;
        }
    }
    true
}

/// Runtime tensor inside the accelerator: integers widened to i32, plus
/// the quantized type they logically carry.
struct HwInt {
    data: Vec<i32>,
    shape: Vec<usize>,
    qtype: QType,
}

enum HwValue {
    Int(HwInt),
    Float(Vec<f32>, Vec<usize>),
}

fn scalar_f32(g: &Graph, name: &str, node: &Node) -> Result<f32, HwError> {
    let t = g
        .initializer(name)
        .ok_or_else(|| perr(node, format!("'{name}' must be an initializer")))?;
    if t.numel() != 1 {
        return Err(perr(node, format!("'{name}' must be scalar")));
    }
    Ok(t.as_f32()?[0])
}

fn zp_qtype(g: &Graph, name: &str, node: &Node) -> Result<QType, HwError> {
    let t = g
        .initializer(name)
        .ok_or_else(|| perr(node, "zero point must be an initializer"))?;
    match t.dtype() {
        DType::I8 => Ok(QType::I8),
        DType::U8 => Ok(QType::U8),
        d => Err(perr(node, format!("unsupported zero-point dtype {d}"))),
    }
}

/// Derive the integer rescale from the Mul scalar(s) (§3.1 both forms).
fn lift_rescale(muls: &[f32], max_shift: u32) -> Result<HwRescale, HwError> {
    if muls.len() == 2 {
        let (s1, s2) = (muls[0] as f64, muls[1] as f64);
        // 2-Mul form: integer Quant_scale then Quant_shift = 2^-N.
        let integral = s1.fract() == 0.0 && s1 >= 1.0 && s1 <= (1u64 << 24) as f64;
        let n = -s2.log2();
        let pow2 = n.fract() == 0.0 && n >= 0.0 && n <= 63.0;
        if integral && pow2 {
            return Ok(HwRescale {
                quant_scale: s1 as u32,
                shift: n as u32,
                exact_from_model: true,
            });
        }
    }
    // 1-Mul form (or unrecognized constants): the hardware tool chain
    // derives integer scale + shift itself.
    let m: f64 = muls.iter().map(|&x| x as f64).product();
    let d = crate::quant::decompose(m as f32, max_shift)?;
    Ok(HwRescale {
        quant_scale: d.quant_scale,
        shift: d.shift,
        exact_from_model: false,
    })
}

/// Integer rescale + round + saturate — the hardware rescale unit.
#[inline]
fn rescale_sat(acc: i32, r: &HwRescale, rounding: Rounding, lo: i32, hi: i32) -> i32 {
    let prod = acc as i64 * r.quant_scale as i64;
    let q = if r.shift == 0 {
        prod
    } else {
        match rounding {
            Rounding::HalfAwayFromZero => {
                let half = 1i64 << (r.shift - 1);
                if prod >= 0 {
                    (prod + half) >> r.shift
                } else {
                    -((-prod + half) >> r.shift)
                }
            }
            Rounding::HalfEven => {
                let floor = prod >> r.shift; // arithmetic = floor
                let rem = prod - (floor << r.shift);
                let half = 1i64 << (r.shift - 1);
                if rem > half || (rem == half && (floor & 1) == 1) {
                    floor + 1
                } else {
                    floor
                }
            }
            Rounding::Truncate => prod >> r.shift,
        }
    };
    q.clamp(lo as i64, hi as i64) as i32
}

impl HwModule {
    /// Compile a pre-quantized standard-ONNX model for this hardware.
    ///
    /// Pattern recognition runs on the SHARED matcher
    /// ([`crate::opt::matcher`]) — the same chain queries the
    /// interpreter's plan-time fusion passes use, so the recognition
    /// logic exists exactly once; this compiler only adds its
    /// hardware-specific lifting (integer rescale derivation, ROM
    /// construction, the `scale == 1.0` requantize contract). Chain
    /// walking is O(1) per edge over the one-pass [`ConsumerIndex`] with
    /// borrowed value names.
    pub fn compile(model: &Model, cfg: HwConfig) -> Result<HwModule, HwError> {
        let g = &model.graph;
        let inputs = g.runtime_inputs();
        if inputs.len() != 1 || g.outputs.len() != 1 {
            return Err(HwError::Unsupported(
                "hw compiler expects single-input single-output models".into(),
            ));
        }
        let input_dtype = inputs[0].dtype;
        let output_name = g.outputs[0].name.as_str();
        let idx = ConsumerIndex::build(g);

        let mut stages = Vec::new();
        let mut cur: &str = inputs[0].name.as_str();

        loop {
            if cur == output_name {
                break;
            }
            let (node_idx, node) = match idx.sole_consumer(g, cur).map_err(match_err)? {
                Some(n) => n,
                None => break,
            };
            match node.op_type.as_str() {
                "QuantizeLinear" => {
                    // Edge input quantization (f32 host input).
                    let scale = scalar_f32(g, &node.inputs[1], node)?;
                    let qtype = zp_qtype(g, &node.inputs[2], node)?;
                    stages.push(Stage::QuantizeInput { scale, qtype });
                    cur = node.outputs[0].as_str();
                }
                "MatMulInteger" => {
                    let chain = match_q_chain(g, &idx, node_idx, InitPolicy::AnyInitializer)
                        .map_err(match_err)?;
                    stages.push(Self::lift_fc(g, &chain, &cfg)?);
                    cur = chain.output;
                }
                "ConvInteger" => {
                    let chain = match_q_chain(g, &idx, node_idx, InitPolicy::AnyInitializer)
                        .map_err(match_err)?;
                    stages.push(Self::lift_conv(g, &chain, &cfg)?);
                    cur = chain.output;
                }
                "DequantizeLinear" => {
                    // Look ahead: activation tail or output edge?
                    if act_chain_follows(g, &idx, node).map_err(match_err)? {
                        let chain = match_act_chain(g, &idx, node_idx, InitPolicy::AnyInitializer)
                            .map_err(match_err)?;
                        let eval = if chain.f16 { ActEval::F16 } else { ActEval::F32 };
                        let lut = ActLut::build(
                            chain.act,
                            eval,
                            chain.in_scale,
                            chain.out_scale,
                            chain.out_qtype,
                            cfg.lut_bits,
                        );
                        stages.push(Stage::Act {
                            lut,
                            f16_evaluated: chain.f16,
                        });
                        cur = chain.output;
                    } else {
                        let in_scale = scalar_f32(g, &node.inputs[1], node)?;
                        stages.push(Stage::DequantizeOutput { scale: in_scale });
                        cur = node.outputs[0].as_str();
                    }
                }
                "MaxPool" => {
                    let kernel = node
                        .attr_ints("kernel_shape")
                        .ok_or_else(|| perr(node, "missing kernel_shape"))?;
                    stages.push(Stage::MaxPool {
                        kernel: [kernel[0] as usize, kernel[1] as usize],
                        attrs: ConvAttrs::from_node(node),
                    });
                    cur = node.outputs[0].as_str();
                }
                "Flatten" => {
                    stages.push(Stage::Flatten {
                        axis: node.attr_int("axis").unwrap_or(1) as usize,
                    });
                    cur = node.outputs[0].as_str();
                }
                "Reshape" => {
                    let spec = g
                        .initializer(&node.inputs[1])
                        .ok_or_else(|| perr(node, "reshape spec must be initializer"))?
                        .as_i64()?
                        .to_vec();
                    stages.push(Stage::Reshape { spec });
                    cur = node.outputs[0].as_str();
                }
                "Softmax" => {
                    stages.push(Stage::SoftmaxHost {
                        axis: node.attr_int("axis").unwrap_or(-1),
                    });
                    cur = node.outputs[0].as_str();
                }
                "Identity" => {
                    cur = node.outputs[0].as_str();
                }
                op => {
                    return Err(perr(node, format!("unsupported op '{op}' in hw chain")))
                }
            }
        }

        let batch_splittable = stages_batch_splittable(&stages, model);
        Ok(HwModule {
            cfg,
            stages,
            input_dtype,
            batch_splittable,
        })
    }

    /// True when this program qualifies for batch-parallel execution.
    pub fn batch_parallelizable(&self) -> bool {
        self.batch_splittable
    }

    /// Lift a matched `MatMulInteger + Add + Cast + Mul(s) [+Relu] +
    /// QuantizeLinear` chain into the FC integer block.
    fn lift_fc(g: &Graph, chain: &QChain<'_>, cfg: &HwConfig) -> Result<Stage, HwError> {
        let w_t = chain.weight; // rank-2, enforced by the matcher
        let (k, n) = (w_t.shape()[0], w_t.shape()[1]);
        let w = w_t.as_quantized_i32()?;
        let bias = match chain.bias {
            Some(b) => Some(b.as_i32()?.to_vec()),
            None => None,
        };
        let rescale = lift_rescale(&chain.muls, cfg.max_shift)?;
        Self::check_unit_requantize(g, chain)?;
        let weight_bits = QType::minimal_for(&w).map_or(8, |q| q.bits());
        Ok(Stage::Fc {
            w,
            k,
            n,
            bias,
            rescale,
            relu: chain.relu,
            out_qtype: chain.out_qtype,
            weight_bits,
        })
    }

    /// Lift the same chain over `ConvInteger` into the conv integer
    /// block.
    fn lift_conv(g: &Graph, chain: &QChain<'_>, cfg: &HwConfig) -> Result<Stage, HwError> {
        let w_t = chain.weight; // rank-4, enforced by the matcher
        let s = w_t.shape();
        let (m, c, kh, kw) = (s[0], s[1], s[2], s[3]);
        let w = w_t.as_quantized_i32()?;
        let attrs = ConvAttrs::from_node(&g.nodes[chain.anchor]);
        let bias = match chain.bias {
            Some(b) => {
                if b.numel() != m {
                    let add = &g.nodes[chain.bias_node.unwrap_or(chain.anchor)];
                    return Err(perr(add, "conv bias must have M elements"));
                }
                Some(b.as_i32()?.to_vec())
            }
            None => None,
        };
        let rescale = lift_rescale(&chain.muls, cfg.max_shift)?;
        Self::check_unit_requantize(g, chain)?;
        let weight_bits = QType::minimal_for(&w).map_or(8, |q| q.bits());
        Ok(Stage::Conv {
            w,
            m,
            c,
            kh,
            kw,
            attrs,
            bias,
            rescale,
            relu: chain.relu,
            out_qtype: chain.out_qtype,
            weight_bits,
        })
    }

    /// The hardware rescale unit has no second multiplier: the final
    /// `QuantizeLinear` must be the pure round+clip stage (`scale == 1`).
    fn check_unit_requantize(g: &Graph, chain: &QChain<'_>) -> Result<(), HwError> {
        if chain.q_scale != 1.0 {
            let qnode = &g.nodes[*chain.nodes.last().unwrap()];
            return Err(perr(
                qnode,
                format!("requantize scale must be 1.0, got {}", chain.q_scale),
            ));
        }
        Ok(())
    }

    /// Execute one inference. Returns the output tensor and the cost
    /// report for this run.
    ///
    /// Batches of at least [`HW_PAR_MIN_BATCH`] rows on splittable
    /// pipelines are scheduled as fixed [`HW_SPLIT_ROWS`]-row sub-batches
    /// (executed across the global pool, or inline when nested/single
    /// threaded). Outputs are bit-identical to [`HwModule::run_serial`]
    /// (integer arithmetic on independent rows, reassembled in chunk
    /// order). The cost report is the in-order sum of the sub-batch
    /// reports; because the sub-batch height is a constant of the
    /// simulated schedule, the report is machine- and thread-count-
    /// independent (it differs from the whole-batch serial schedule only
    /// in per-sub-batch tile fill and weight reload, by design).
    pub fn run(&self, input: &Tensor) -> Result<(Tensor, CostReport), HwError> {
        let batch = input.shape().first().copied().unwrap_or(0);
        if self.batch_splittable && batch >= HW_PAR_MIN_BATCH {
            let pieces = batch.div_ceil(HW_SPLIT_ROWS);
            if pieces >= 2 {
                return self.run_split(input, ThreadPool::global(), pieces);
            }
        }
        self.run_serial(input)
    }

    /// Execute with the batch split across `pool` whenever the pipeline and
    /// batch allow it at all (no minimum-batch heuristic — used by the
    /// serial-vs-parallel property tests), falling back to serial.
    pub fn run_on(
        &self,
        input: &Tensor,
        pool: &ThreadPool,
    ) -> Result<(Tensor, CostReport), HwError> {
        let batch = input.shape().first().copied().unwrap_or(0);
        if self.batch_splittable && batch >= 2 && parallel::allow_pool_dispatch() {
            let pieces = parallel::chunk_count(batch, pool.threads().max(2), 1);
            if pieces >= 2 {
                return self.run_split(input, pool, pieces);
            }
        }
        self.run_serial(input)
    }

    /// Scatter the fixed sub-batch schedule over the pool and gather the
    /// chunk outputs + cost reports in order, via the shared
    /// [`parallel::scatter_gather`] (which also keeps the chunk SCHEDULE
    /// under `serial_scope` — the cost report is a constant of it — while
    /// running the chunks inline there).
    fn run_split(
        &self,
        input: &Tensor,
        pool: &ThreadPool,
        pieces: usize,
    ) -> Result<(Tensor, CostReport), HwError> {
        let batch = input.shape()[0];
        let chunks = parallel::ranges(batch, pieces);
        let results = parallel::scatter_gather(pool, &chunks, |range| {
            let part = input.slice_rows(range.start, range.len())?;
            self.run_serial(&part)
        })?;
        let mut outputs = Vec::with_capacity(results.len());
        let mut cost = CostReport::default();
        for (out, c) in results {
            cost.add(&c);
            outputs.push(out);
        }
        Ok((Tensor::concat_rows(&outputs)?, cost))
    }

    /// Execute strictly on the calling thread (the reference path the
    /// parallel executor is tested against).
    pub fn run_serial(&self, input: &Tensor) -> Result<(Tensor, CostReport), HwError> {
        if input.dtype() != self.input_dtype {
            return Err(HwError::Exec(format!(
                "input dtype {} != model input {}",
                input.dtype(),
                self.input_dtype
            )));
        }
        let mut cost = CostReport::default();
        let mut val = match input.dtype() {
            DType::F32 => HwValue::Float(input.as_f32()?.to_vec(), input.shape().to_vec()),
            DType::I8 => HwValue::Int(HwInt {
                data: input.as_quantized_i32()?,
                shape: input.shape().to_vec(),
                qtype: QType::I8,
            }),
            DType::U8 => HwValue::Int(HwInt {
                data: input.as_quantized_i32()?,
                shape: input.shape().to_vec(),
                qtype: QType::U8,
            }),
            d => return Err(HwError::Exec(format!("unsupported input dtype {d}"))),
        };

        for stage in &self.stages {
            val = self.run_stage(stage, val, &mut cost)?;
        }

        let out = match val {
            HwValue::Float(data, shape) => Tensor::from_f32(&shape, data)?,
            // Narrow logical widths still live in their standard 8-bit
            // container at the edge, so only the container dtype matters.
            HwValue::Int(t) => match t.qtype.dtype() {
                DType::U8 => {
                    Tensor::from_u8(&t.shape, t.data.iter().map(|&v| v as u8).collect())?
                }
                _ => Tensor::from_i8(&t.shape, t.data.iter().map(|&v| v as i8).collect())?,
            },
        };
        Ok((out, cost))
    }

    fn run_stage(
        &self,
        stage: &Stage,
        val: HwValue,
        cost: &mut CostReport,
    ) -> Result<HwValue, HwError> {
        match stage {
            Stage::QuantizeInput { scale, qtype } => {
                let (data, shape) = match val {
                    HwValue::Float(d, s) => (d, s),
                    _ => return Err(HwError::Exec("QuantizeInput expects float".into())),
                };
                let (lo, hi) = qtype.range();
                let inv = 1.0 / scale;
                let q: Vec<i32> = data
                    .iter()
                    .map(|&x| {
                        crate::ops::qlinear::round_half_even(x * inv)
                            .clamp(lo as f32, hi as f32) as i32
                    })
                    .collect();
                cost.add(&host_cost(q.len(), 2));
                Ok(HwValue::Int(HwInt {
                    data: q,
                    shape,
                    qtype: *qtype,
                }))
            }
            Stage::Fc {
                w,
                k,
                n,
                bias,
                rescale,
                relu,
                out_qtype,
                weight_bits,
            } => {
                let t = match val {
                    HwValue::Int(t) => t,
                    _ => return Err(HwError::Exec("Fc expects int".into())),
                };
                let m: usize = t.shape[..t.shape.len() - 1].iter().product();
                let kk = *t.shape.last().ok_or_else(|| HwError::Exec("rank-0 fc".into()))?;
                if kk != *k {
                    return Err(HwError::Exec(format!("fc K mismatch {kk} vs {k}")));
                }
                let mut acc = vec![0i32; m * n];
                // Pool-dispatched for large single batches; bit-exact and
                // cost-model-neutral (MACs are counted analytically below,
                // and nested calls inside the run_split schedule fall back
                // to the serial kernel on pool workers).
                gemm_i32_par(ThreadPool::global(), &t.data, w, m, *k, *n, &mut acc);
                if let Some(b) = bias {
                    for row in acc.chunks_mut(*n) {
                        for (v, bv) in row.iter_mut().zip(b) {
                            *v = v.wrapping_add(*bv);
                        }
                    }
                }
                let (lo, hi) = out_qtype.range();
                for v in &mut acc {
                    let mut q = rescale_sat(*v, rescale, self.cfg.rounding, lo, hi);
                    if *relu && q < 0 {
                        q = 0;
                    }
                    *v = q;
                }
                // Activation stream width follows the producing stage's
                // qtype: a bipolar or int4 edge arrives bit-packed.
                cost.add(&gemm_cost_wa(&self.cfg, m, *k, *n, *weight_bits, t.qtype.bits()));
                cost.add(&vector_cost(&self.cfg, m * n, 2));
                let mut shape = t.shape[..t.shape.len() - 1].to_vec();
                shape.push(*n);
                Ok(HwValue::Int(HwInt {
                    data: acc,
                    shape,
                    qtype: *out_qtype,
                }))
            }
            Stage::Conv {
                w,
                m,
                c,
                kh,
                kw,
                attrs,
                bias,
                rescale,
                relu,
                out_qtype,
                weight_bits,
            } => {
                let t = match val {
                    HwValue::Int(t) => t,
                    _ => return Err(HwError::Exec("Conv expects int".into())),
                };
                if t.shape.len() != 4 || t.shape[1] != *c {
                    return Err(HwError::Exec(format!("conv input shape {:?}", t.shape)));
                }
                let (nb, h, wd) = (t.shape[0], t.shape[2], t.shape[3]);
                let out_dim = |i: usize, kk: usize, pb: usize, pe: usize, st: usize, dl: usize| {
                    (i + pb + pe - (dl * (kk - 1) + 1)) / st + 1
                };
                let oh = out_dim(h, *kh, attrs.pads[0], attrs.pads[2], attrs.strides[0], attrs.dilations[0]);
                let ow = out_dim(wd, *kw, attrs.pads[1], attrs.pads[3], attrs.strides[1], attrs.dilations[1]);
                let patch_rows = c * kh * kw;
                let patch = oh * ow;
                let mut col = vec![0i32; patch_rows * patch];
                let mut out = vec![0i32; nb * m * patch];
                for b in 0..nb {
                    let src = &t.data[b * c * h * wd..(b + 1) * c * h * wd];
                    im2col_i32(src, *c, h, wd, *kh, *kw, attrs, oh, ow, &mut col);
                    let dst = &mut out[b * m * patch..(b + 1) * m * patch];
                    gemm_i32(w, &col, *m, patch_rows, patch, dst);
                }
                let (lo, hi) = out_qtype.range();
                for b in 0..nb {
                    for mi in 0..*m {
                        let base = (b * m + mi) * patch;
                        let bv = bias.as_ref().map(|bb| bb[mi]).unwrap_or(0);
                        for v in &mut out[base..base + patch] {
                            let mut q = rescale_sat(
                                v.wrapping_add(bv),
                                rescale,
                                self.cfg.rounding,
                                lo,
                                hi,
                            );
                            if *relu && q < 0 {
                                q = 0;
                            }
                            *v = q;
                        }
                    }
                }
                // Output-stationary mapping with the kernel in the
                // DRAM-resident B position: A = im2col patches
                // [nb·patch, patch_rows] streamed from SRAM, B = kernel
                // [patch_rows, m] loaded once and width-packed — so the
                // width scaling lands on the true weight operand.
                // im2col replicates input values, so the patch matrix
                // streams at the input edge's logical width.
                cost.add(&gemm_cost_wa(
                    &self.cfg,
                    nb * patch,
                    patch_rows,
                    *m,
                    *weight_bits,
                    t.qtype.bits(),
                ));
                cost.add(&vector_cost(&self.cfg, nb * m * patch, 2));
                Ok(HwValue::Int(HwInt {
                    data: out,
                    shape: vec![nb, *m, oh, ow],
                    qtype: *out_qtype,
                }))
            }
            Stage::Act { lut, .. } => {
                let mut t = match val {
                    HwValue::Int(t) => t,
                    _ => return Err(HwError::Exec("Act expects int".into())),
                };
                lut.apply(&mut t.data);
                cost.add(&vector_cost(&self.cfg, t.data.len(), 1));
                t.qtype = lut.out_qtype;
                Ok(HwValue::Int(t))
            }
            Stage::MaxPool { kernel, attrs } => {
                let t = match val {
                    HwValue::Int(t) => t,
                    _ => return Err(HwError::Exec("MaxPool expects int".into())),
                };
                let (nb, c, h, w) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
                let oh = (h + attrs.pads[0] + attrs.pads[2] - kernel[0]) / attrs.strides[0] + 1;
                let ow = (w + attrs.pads[1] + attrs.pads[3] - kernel[1]) / attrs.strides[1] + 1;
                let mut out = Vec::with_capacity(nb * c * oh * ow);
                for b in 0..nb {
                    for ci in 0..c {
                        let plane = &t.data[(b * c + ci) * h * w..(b * c + ci + 1) * h * w];
                        for oy in 0..oh {
                            for ox in 0..ow {
                                let mut best = i32::MIN;
                                for ky in 0..kernel[0] {
                                    let iy = (oy * attrs.strides[0] + ky) as isize
                                        - attrs.pads[0] as isize;
                                    if iy < 0 || iy as usize >= h {
                                        continue;
                                    }
                                    for kx in 0..kernel[1] {
                                        let ix = (ox * attrs.strides[1] + kx) as isize
                                            - attrs.pads[1] as isize;
                                        if ix < 0 || ix as usize >= w {
                                            continue;
                                        }
                                        best = best.max(plane[iy as usize * w + ix as usize]);
                                    }
                                }
                                out.push(best);
                            }
                        }
                    }
                }
                cost.add(&vector_cost(
                    &self.cfg,
                    out.len(),
                    (kernel[0] * kernel[1]) as u64,
                ));
                Ok(HwValue::Int(HwInt {
                    data: out,
                    shape: vec![nb, c, oh, ow],
                    qtype: t.qtype,
                }))
            }
            Stage::Flatten { axis } => match val {
                HwValue::Int(mut t) => {
                    let d0: usize = t.shape[..*axis].iter().product();
                    let d1: usize = t.shape[*axis..].iter().product();
                    t.shape = vec![d0, d1];
                    Ok(HwValue::Int(t))
                }
                HwValue::Float(d, s) => {
                    let d0: usize = s[..*axis].iter().product();
                    let d1: usize = s[*axis..].iter().product();
                    Ok(HwValue::Float(d, vec![d0, d1]))
                }
            },
            Stage::Reshape { spec } => {
                let (numel, old_shape) = match &val {
                    HwValue::Int(t) => (t.data.len(), t.shape.clone()),
                    HwValue::Float(d, s) => (d.len(), s.clone()),
                };
                let mut dims = Vec::with_capacity(spec.len());
                let mut infer = None;
                for (i, &s) in spec.iter().enumerate() {
                    match s {
                        0 => dims.push(old_shape[i]),
                        -1 => {
                            infer = Some(i);
                            dims.push(1);
                        }
                        s => dims.push(s as usize),
                    }
                }
                if let Some(at) = infer {
                    let rest: usize =
                        dims.iter().enumerate().filter(|(i, _)| *i != at).map(|(_, &d)| d).product();
                    dims[at] = numel / rest;
                }
                Ok(match val {
                    HwValue::Int(mut t) => {
                        t.shape = dims;
                        HwValue::Int(t)
                    }
                    HwValue::Float(d, _) => HwValue::Float(d, dims),
                })
            }
            Stage::DequantizeOutput { scale } => {
                let t = match val {
                    HwValue::Int(t) => t,
                    _ => return Err(HwError::Exec("DequantizeOutput expects int".into())),
                };
                let f: Vec<f32> = t.data.iter().map(|&q| q as f32 * scale).collect();
                cost.add(&host_cost(f.len(), 1));
                Ok(HwValue::Float(f, t.shape))
            }
            Stage::SoftmaxHost { axis } => {
                let (data, shape) = match val {
                    HwValue::Float(d, s) => (d, s),
                    _ => return Err(HwError::Exec("Softmax expects float".into())),
                };
                let t = Tensor::from_f32(&shape, data)?;
                let y = crate::ops::shape_ops::softmax(&t, *axis)
                    .map_err(|e| HwError::Exec(e.to_string()))?;
                cost.add(&host_cost(y.numel(), 4));
                Ok(HwValue::Float(y.as_f32()?.to_vec(), shape))
            }
        }
    }

    /// Number of compiled pipeline stages.
    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    /// True if every rescale stage read its integer constants verbatim
    /// from the model (2-Mul codification).
    pub fn all_rescales_exact(&self) -> bool {
        self.stages.iter().all(|s| match s {
            Stage::Fc { rescale, .. } | Stage::Conv { rescale, .. } => rescale.exact_from_model,
            _ => true,
        })
    }

    /// Minimal logical weight width of each FC/conv stage in pipeline
    /// order (8, 4, ..., 1 for bipolar) — the widths the cost model's
    /// width-scaled traffic terms use.
    pub fn weight_widths(&self) -> Vec<u8> {
        self.stages
            .iter()
            .filter_map(|s| match s {
                Stage::Fc { weight_bits, .. } | Stage::Conv { weight_bits, .. } => {
                    Some(*weight_bits)
                }
                _ => None,
            })
            .collect()
    }
}

/// i32 im2col (same layout as ops::conv, widened domain).
#[allow(clippy::too_many_arguments)]
fn im2col_i32(
    src: &[i32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    attrs: &ConvAttrs,
    oh: usize,
    ow: usize,
    dst: &mut [i32],
) {
    let [stride_h, stride_w] = attrs.strides;
    let [pad_t, pad_l, _, _] = attrs.pads;
    let [dil_h, dil_w] = attrs.dilations;
    let patch = oh * ow;
    for ci in 0..c {
        for ki in 0..kh {
            for kj in 0..kw {
                let row = (ci * kh * kw + ki * kw + kj) * patch;
                for oy in 0..oh {
                    let iy = (oy * stride_h + ki * dil_h) as isize - pad_t as isize;
                    let base = row + oy * ow;
                    if iy < 0 || iy as usize >= h {
                        dst[base..base + ow].fill(0);
                        continue;
                    }
                    let src_row = (ci * h + iy as usize) * w;
                    for ox in 0..ow {
                        let ix = (ox * stride_w + kj * dil_w) as isize - pad_l as isize;
                        dst[base + ox] = if ix < 0 || ix as usize >= w {
                            0
                        } else {
                            src[src_row + ix as usize]
                        };
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::Session;
    use crate::onnx::{batched, GraphBuilder};
    use crate::quant::decompose;
    use crate::rewrite::patterns::{emit_fc, ActKind, FcParams, RescaleOp};

    fn fig1_model(rescale: RescaleOp, act: ActKind, out_qtype: QType) -> Model {
        let mut b = GraphBuilder::new("hw_fc");
        b.input("x", DType::I8, &batched(&[8]));
        let params = FcParams {
            weight_q: Tensor::from_i8(
                &[8, 4],
                (0..32).map(|i| ((i * 7 % 23) as i8) - 11).collect(),
            )
            .unwrap(),
            bias_q: Some(Tensor::from_i32(&[4], vec![50, -75, 0, 125]).unwrap()),
            rescale,
            activation: act,
            out_qtype,
        };
        let y = emit_fc(&mut b, "x", &params, "l0");
        let dt = match act {
            ActKind::SigmoidF16 { .. } => DType::U8,
            _ => out_qtype.dtype(),
        };
        b.output(&y, dt, &batched(&[4]));
        b.finish_model()
    }

    fn random_i8(n: usize, seed: u64) -> Vec<i8> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 33) as u8) as i8
            })
            .collect()
    }

    fn agree(model: Model, batch: usize, tol: i32) {
        let sess = Session::new(model.clone()).unwrap();
        let hw = HwModule::compile(&model, HwConfig::default()).unwrap();
        let k = 8;
        for seed in 1..=5u64 {
            let x = Tensor::from_i8(&[batch, k], random_i8(batch * k, seed)).unwrap();
            let want = &sess.run(&[("x", x.clone())]).unwrap()[0];
            let (got, cost) = hw.run(&x).unwrap();
            assert_eq!(want.shape(), got.shape());
            assert!(cost.macs > 0);
            let wv = want.as_quantized_i32().unwrap();
            let gv = got.as_quantized_i32().unwrap();
            for (i, (a, b)) in wv.iter().zip(&gv).enumerate() {
                assert!(
                    (a - b).abs() <= tol,
                    "seed {seed} elem {i}: interp {a} vs hw {b}"
                );
            }
        }
    }

    #[test]
    fn fc_two_mul_agrees_bit_exact_mostly() {
        let d = decompose(1.0 / 3.0, 31).unwrap();
        // 2-Mul: hw reads the exact integer constants from the model; the
        // only possible divergence is f32 product rounding in the interp,
        // bounded to 1 LSB.
        agree(
            fig1_model(RescaleOp::TwoMul(d), ActKind::None, QType::I8),
            4,
            1,
        );
    }

    #[test]
    fn fc_one_mul_agrees_within_lsb() {
        agree(
            fig1_model(RescaleOp::OneMul(0.0123), ActKind::Relu, QType::U8),
            4,
            1,
        );
    }

    #[test]
    fn act_lut_stage_bit_exact() {
        let d = decompose(127.0 / 2560.0, 31).unwrap();
        // Activation ROM is built from the same float composition the
        // interpreter executes, so the Act stage itself is bit-exact; the
        // preceding rescale may differ by 1 LSB which the tanh LUT maps
        // to at most a small output delta.
        agree(
            fig1_model(
                RescaleOp::TwoMul(d),
                ActKind::TanhF16 {
                    in_scale: 2.0 / 127.0,
                    out_scale: 1.0 / 127.0,
                },
                QType::I8,
            ),
            2,
            2,
        );
    }

    #[test]
    fn sigmoid_uint8_path() {
        let d = decompose(127.0 / 2560.0, 31).unwrap();
        agree(
            fig1_model(
                RescaleOp::TwoMul(d),
                ActKind::SigmoidF16 {
                    in_scale: 8.0 / 127.0,
                    out_scale: 1.0 / 255.0,
                },
                QType::U8,
            ),
            2,
            2,
        );
    }

    #[test]
    fn hw_parallel_run_bit_exact_vs_serial() {
        let d = decompose(1.0 / 3.0, 31).unwrap();
        let m = fig1_model(RescaleOp::TwoMul(d), ActKind::None, QType::I8);
        let hw = HwModule::compile(&m, HwConfig::default()).unwrap();
        assert!(hw.batch_parallelizable());
        let pool = crate::parallel::ThreadPool::new(3);
        for batch in [1usize, 2, 5, 9] {
            let x =
                Tensor::from_i8(&[batch, 8], random_i8(batch * 8, batch as u64 + 1)).unwrap();
            let (serial, sc) = hw.run_serial(&x).unwrap();
            let (par, pc) = hw.run_on(&x, &pool).unwrap();
            assert_eq!(serial, par, "batch {batch}");
            // MAC counts are exact under splitting; cycle estimates may
            // differ by per-chunk tile fill, macs must not.
            assert_eq!(sc.macs, pc.macs, "batch {batch}");
            let (auto, _) = hw.run(&x).unwrap();
            assert_eq!(serial, auto, "batch {batch} (auto)");
        }
    }

    #[test]
    fn exactness_flag_reflects_codification() {
        let d = decompose(0.25, 31).unwrap();
        let m2 = fig1_model(RescaleOp::TwoMul(d), ActKind::None, QType::I8);
        assert!(HwModule::compile(&m2, HwConfig::default())
            .unwrap()
            .all_rescales_exact());
        let m1 = fig1_model(RescaleOp::OneMul(0.25), ActKind::None, QType::I8);
        assert!(!HwModule::compile(&m1, HwConfig::default())
            .unwrap()
            .all_rescales_exact());
    }

    #[test]
    fn rejects_unsupported_graph() {
        let mut b = GraphBuilder::new("bad");
        b.input("x", DType::F32, &batched(&[2]));
        let y = b.node("Tanh", &["x"], &[]);
        b.output(&y, DType::F32, &batched(&[2]));
        let m = b.finish_model();
        assert!(HwModule::compile(&m, HwConfig::default()).is_err());
    }

    #[test]
    fn truncate_rounding_biases_down() {
        let d = decompose(0.5, 31).unwrap();
        let r = HwRescale {
            quant_scale: d.quant_scale,
            shift: d.shift,
            exact_from_model: true,
        };
        // 3 * 0.5 = 1.5: HalfEven -> 2, HalfAway -> 2, Truncate -> 1.
        assert_eq!(rescale_sat(3, &r, Rounding::HalfEven, -128, 127), 2);
        assert_eq!(rescale_sat(3, &r, Rounding::HalfAwayFromZero, -128, 127), 2);
        assert_eq!(rescale_sat(3, &r, Rounding::Truncate, -128, 127), 1);
        // 5 * 0.5 = 2.5: HalfEven -> 2, HalfAway -> 3.
        assert_eq!(rescale_sat(5, &r, Rounding::HalfEven, -128, 127), 2);
        assert_eq!(rescale_sat(5, &r, Rounding::HalfAwayFromZero, -128, 127), 3);
    }
}
