//! Integer-only fixed-point accelerator simulator — the "hardware" side
//! of the co-design claim.
//!
//! [`exec::HwModule::compile`] consumes the *same* pre-quantized standard
//! ONNX model every software backend runs and lifts the codified patterns
//! into fixed-point pipeline stages; execution is integer arithmetic only
//! (int8 MACs, i32 accumulators, integer-multiplier + right-shift rescale
//! per §3.1, activation ROMs). [`cost`] attaches a cycle/energy model so
//! hardware configurations can be swept against model accuracy
//! (`bench_codesign_sweep`).

pub mod config;
pub mod cost;
pub mod exec;
pub mod lut;

pub use config::{HwConfig, Rounding};
pub use cost::CostReport;
pub use exec::{HwModule, HwError, Stage, HW_PAR_MIN_BATCH, HW_SPLIT_ROWS};
pub use lut::{ActEval, ActFn, ActLut};
