//! Cycle / energy / traffic cost model of the simulated accelerator.
//!
//! Deliberately simple and auditable: an output-stationary systolic MAC
//! array (`rows × cols`), single-ported SRAM, DRAM for initial weight
//! load. Good enough to rank co-design points (array size vs utilization,
//! LUT width vs accuracy), which is all the paper's claim needs.

use super::config::HwConfig;

/// Accumulated execution cost of one inference.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostReport {
    /// Total int8 MAC operations.
    pub macs: u64,
    /// Accelerator cycles (MAC array + vector unit + LUT).
    pub cycles: u64,
    /// Bytes moved between SRAM and the compute units.
    pub sram_bytes: u64,
    /// Bytes loaded from DRAM (weights, once per inference in this model).
    pub dram_bytes: u64,
    /// Elementwise vector-unit operations (rescale mul+shift, relu, pool
    /// compares, LUT lookups).
    pub vector_ops: u64,
    /// Work executed on the host CPU (edge quantize/dequantize, softmax),
    /// in float ops.
    pub host_flops: u64,
}

impl CostReport {
    pub fn add(&mut self, other: &CostReport) {
        self.macs += other.macs;
        self.cycles += other.cycles;
        self.sram_bytes += other.sram_bytes;
        self.dram_bytes += other.dram_bytes;
        self.vector_ops += other.vector_ops;
        self.host_flops += other.host_flops;
    }

    /// Latency at the configured clock (accelerator cycles only).
    pub fn latency_us(&self, cfg: &HwConfig) -> f64 {
        self.cycles as f64 / cfg.freq_mhz
    }

    /// Energy estimate in nanojoules.
    pub fn energy_nj(&self, cfg: &HwConfig) -> f64 {
        (self.macs as f64 * cfg.pj_per_mac
            + self.sram_bytes as f64 * cfg.pj_per_sram_byte
            + self.dram_bytes as f64 * cfg.pj_per_dram_byte
            // Vector/LUT ops cost roughly one MAC each.
            + self.vector_ops as f64 * cfg.pj_per_mac)
            / 1000.0
    }

    /// MAC-array utilization: ideal cycles / modeled cycles.
    pub fn utilization(&self, cfg: &HwConfig) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        let ideal = self.macs as f64 / (cfg.mac_rows * cfg.mac_cols) as f64;
        (ideal / self.cycles as f64).min(1.0)
    }
}

/// Cost of an M×K×N integer GEMM on the systolic array with 8-bit
/// weights. See [`gemm_cost_w`] for sub-8-bit weight widths.
pub fn gemm_cost(cfg: &HwConfig, m: usize, k: usize, n: usize) -> CostReport {
    gemm_cost_w(cfg, m, k, n, 8)
}

/// Cost of an M×K×N integer GEMM on the systolic array: the array
/// computes a `rows × cols` output tile per K cycles (output-stationary),
/// plus a pipeline-fill overhead per tile.
///
/// `weight_bits` is the *logical* weight width (8, 4, ..., 1 for
/// bipolar). Weights travel bit-packed, so the weight terms of DRAM and
/// SRAM traffic scale with the width; compute cycles and MAC count do
/// not (the array still performs one MAC per weight, whatever its
/// width — narrow widths buy bandwidth and energy, not cycles, which is
/// exactly the co-design trade-off the report exists to expose).
///
/// Activations are assumed full 8-bit; see [`gemm_cost_wa`] when the
/// incoming edge is itself packed (nibble / bit-plane fused chains).
pub fn gemm_cost_w(cfg: &HwConfig, m: usize, k: usize, n: usize, weight_bits: u8) -> CostReport {
    gemm_cost_wa(cfg, m, k, n, weight_bits, 8)
}

/// [`gemm_cost_w`] with a packed *activation* width as well. When a fused
/// chain hands its successor a nibble- or bit-plane-packed edge, the
/// activation stream that re-plays per output tile column shrinks by the
/// same bit-packing factor as the weights — `act_bits` scales that term.
/// Output traffic stays i32 (accumulators are width-independent), and
/// compute is untouched for the same reason as in [`gemm_cost_w`].
pub fn gemm_cost_wa(
    cfg: &HwConfig,
    m: usize,
    k: usize,
    n: usize,
    weight_bits: u8,
    act_bits: u8,
) -> CostReport {
    let tiles_m = m.div_ceil(cfg.mac_rows) as u64;
    let tiles_n = n.div_ceil(cfg.mac_cols) as u64;
    let fill = (cfg.mac_rows + cfg.mac_cols) as u64; // systolic skew
    let cycles = tiles_m * tiles_n * (k as u64 + fill);
    let weight_bytes = (k * n * weight_bits.clamp(1, 8) as usize).div_ceil(8) as u64;
    let act_bytes = (m * k * act_bits.clamp(1, 8) as usize).div_ceil(8) as u64;
    CostReport {
        macs: (m * k * n) as u64,
        cycles,
        // Activations stream in per tile-row; weights per tile.
        sram_bytes: act_bytes * tiles_n + weight_bytes * tiles_m + (m * n) as u64 * 4,
        dram_bytes: weight_bytes, // weight load
        vector_ops: 0,
        host_flops: 0,
    }
}

/// Plan-time tuner seed ([`crate::tune::tuner`]): estimated relative cost
/// of running an `m x k x n` GEMM with an `mr x nr` register tile and
/// `kc` k-blocking on the HOST CPU. Reuses [`gemm_cost`] with the MAC
/// array sized to the register tile (an `mr x nr` tile of independent
/// accumulators is the CPU analogue of an output-stationary array), plus
/// a panel-traffic term the systolic model has no reason to charge for:
/// every MR-row tile re-streams each B panel block, and a `kc x nr`
/// panel that outgrows a 32 KiB L1 tile budget pays a spill penalty.
/// Units are arbitrary "cycles" — only the RANKING matters; the top few
/// candidates get real wall-clock measurement.
pub fn gemm_tile_estimate(mr: usize, nr: usize, kc: usize, m: usize, k: usize, n: usize) -> u64 {
    let cfg = HwConfig::default().with_array(mr.max(1), nr.max(1));
    let compute = gemm_cost(&cfg, m, k, n).cycles;
    let tiles_m = m.div_ceil(mr.max(1)) as u64;
    let blocks_k = k.div_ceil(kc.max(1)) as u64;
    let panels_n = n.div_ceil(nr.max(1)) as u64;
    let panel_bytes = (kc.min(k) * nr) as u64;
    let mut traffic = tiles_m * blocks_k * panels_n * panel_bytes;
    if kc * nr > 32 * 1024 {
        traffic *= 4; // panel no longer L1-resident
    }
    // ~8 bytes/cycle effective load bandwidth for the i8 panels.
    compute + traffic / 8
}

/// Cost of an elementwise vector stage over `n` elements (`lanes` wide,
/// one op per element).
pub fn vector_cost(cfg: &HwConfig, n: usize, ops_per_elem: u64) -> CostReport {
    let lanes = cfg.mac_cols as u64; // vector unit shares the column width
    CostReport {
        cycles: (n as u64 * ops_per_elem).div_ceil(lanes),
        sram_bytes: (n * 2) as u64, // read + write, 1B each
        vector_ops: n as u64 * ops_per_elem,
        ..Default::default()
    }
}

/// Host-side float work (edge conversion, softmax).
pub fn host_cost(n: usize, flops_per_elem: u64) -> CostReport {
    CostReport {
        host_flops: n as u64 * flops_per_elem,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_cost_scales_with_mnk() {
        let cfg = HwConfig::default();
        let a = gemm_cost(&cfg, 8, 64, 8);
        let b = gemm_cost(&cfg, 8, 128, 8);
        assert_eq!(b.macs, 2 * a.macs);
        assert!(b.cycles > a.cycles);
    }

    #[test]
    fn narrow_weights_cut_traffic_not_compute() {
        let cfg = HwConfig::default();
        let w8 = gemm_cost_w(&cfg, 8, 64, 16, 8);
        let w4 = gemm_cost_w(&cfg, 8, 64, 16, 4);
        let w1 = gemm_cost_w(&cfg, 8, 64, 16, 1);
        assert_eq!(w8, gemm_cost(&cfg, 8, 64, 16));
        assert_eq!(w4.dram_bytes, w8.dram_bytes / 2);
        assert_eq!(w1.dram_bytes, w8.dram_bytes / 8);
        assert!(w4.sram_bytes < w8.sram_bytes);
        // Same array, same schedule: compute is width-independent.
        assert_eq!(w4.macs, w8.macs);
        assert_eq!(w4.cycles, w8.cycles);
        // Ragged packing rounds up, never to zero.
        assert_eq!(gemm_cost_w(&cfg, 1, 3, 3, 1).dram_bytes, 2);
    }

    #[test]
    fn packed_activations_cut_sram_traffic_only() {
        let cfg = HwConfig::default();
        let a8 = gemm_cost_wa(&cfg, 8, 64, 16, 4, 8);
        let a4 = gemm_cost_wa(&cfg, 8, 64, 16, 4, 4);
        let a1 = gemm_cost_wa(&cfg, 8, 64, 16, 4, 1);
        assert_eq!(a8, gemm_cost_w(&cfg, 8, 64, 16, 4));
        // Weight DRAM traffic is activation-width-independent.
        assert_eq!(a4.dram_bytes, a8.dram_bytes);
        // Activation streaming shrinks; i32 output term is untouched,
        // so strict inequality is the exact claim.
        assert!(a4.sram_bytes < a8.sram_bytes);
        assert!(a1.sram_bytes < a4.sram_bytes);
        assert_eq!(a4.macs, a8.macs);
        assert_eq!(a4.cycles, a8.cycles);
        // Ragged rows round up per the whole streamed block, never to 0.
        assert!(gemm_cost_wa(&cfg, 1, 3, 3, 8, 1).sram_bytes > 0);
    }

    #[test]
    fn bigger_array_fewer_cycles_lower_utilization_on_small_work() {
        let small = HwConfig::default().with_array(8, 8);
        let big = HwConfig::default().with_array(64, 64);
        let cs = gemm_cost(&small, 32, 256, 32);
        let cb = gemm_cost(&big, 32, 256, 32);
        assert!(cb.cycles < cs.cycles);
        assert!(cb.utilization(&big) < cs.utilization(&small));
    }

    #[test]
    fn tile_estimate_ranks_sanely() {
        // More work costs more, for any tile.
        let small = gemm_tile_estimate(4, 8, 256, 64, 64, 64);
        let big = gemm_tile_estimate(4, 8, 256, 64, 256, 64);
        assert!(big > small);
        // A panel far past the L1 budget is penalized vs one inside it.
        let fits = gemm_tile_estimate(4, 8, 256, 64, 100_000, 8);
        let spills = gemm_tile_estimate(4, 8, 100_000, 64, 100_000, 8);
        assert!(spills > fits);
        // Degenerate inputs don't panic or divide by zero.
        assert!(gemm_tile_estimate(4, 8, 256, 0, 0, 0) < u64::MAX);
    }

    #[test]
    fn energy_accumulates() {
        let cfg = HwConfig::default();
        let mut total = CostReport::default();
        total.add(&gemm_cost(&cfg, 4, 4, 4));
        total.add(&vector_cost(&cfg, 16, 2));
        assert!(total.energy_nj(&cfg) > 0.0);
        assert!(total.latency_us(&cfg) > 0.0);
    }
}
