//! Compile-once execution plans.
//!
//! [`CompiledPlan::compile`] lowers a validated, scheduled model into the
//! form the hot loop actually wants:
//!
//! * every value name interned to a dense slot index (`u32`) — the run
//!   loop indexes a `Vec` instead of hashing strings,
//! * every node input resolved once to a [`Src`]: a store slot, an
//!   initializer index into the model's initializer table, or `None` for
//!   omitted optional inputs,
//! * every node lowered to a pre-bound [`Kernel`] (attributes parsed,
//!   initializer-derived parameters baked) with a plan-time error for
//!   unsupported operators,
//! * per-step `frees` as slot indices (the last-use analysis over the
//!   schedule, so peak memory stays at the live-set size — and, through
//!   [`ScratchArena`], so every dying buffer is parked for the next run
//!   instead of freed: the steady-state serving path allocates nothing).
//!
//! The plan holds no tensors of its own except what kernels baked
//! (pre-widened + panel-packed integer weights, pre-transposed Gemm
//! weights); initializers stay owned by the
//! [`Model`](crate::onnx::ir::Model) and are referenced by index.

use super::SessionError;
use crate::onnx::ir::Model;
use crate::onnx::shape::ValueType;
use crate::ops::{Isa, Kernel};
use crate::opt::{self, OptStats, PlanItem, PlanOptions};
use crate::tensor::Tensor;
use crate::tune::{GemmConfig, TuneSource};
use std::collections::HashMap;

/// Where a node input (or graph output) comes from, resolved at plan
/// time. `SlotOrInit` covers the degenerate ONNX case of an initializer
/// shadowed by a node output; the `Feed*` variants mark graph-input
/// slots, which resolve store-first (a later node may overwrite the
/// value) and then against the run's borrowed feeds by name — exactly
/// the visibility the string-keyed interpreter's
/// `values.get(..).or(initializer)` gave, with feeds placed in `values`
/// up front. Keeping feeds OUT of the slot store lets the store hold
/// plain owned tensors, which is what makes the store itself recyclable
/// across runs (see [`ScratchArena`]).
#[derive(Clone, Copy, Debug)]
pub(crate) enum Src {
    None,
    Slot(u32),
    Init(u32),
    SlotOrInit { slot: u32, init: u32 },
    /// Graph-input slot (slot index doubles as the name-interner index).
    Feed { slot: u32 },
    /// Graph input shadowing an initializer: feed overrides initializer.
    FeedOrInit { slot: u32, init: u32 },
}

/// One scheduled step: pre-bound kernel, resolved inputs, output slot,
/// and the slots whose last use this step is. A step is usually one
/// graph node; after the plan-time optimizer (`crate::opt`) it may cover
/// a whole fused chain, recorded in `span`.
pub(crate) struct Step {
    /// Anchor graph-node index (error reporting, profiling labels).
    pub node_idx: usize,
    /// All graph-node indices this step covers, in chain order — empty
    /// for ordinary 1:1 steps (the anchor alone).
    pub span: Box<[u32]>,
    pub kernel: Kernel,
    pub inputs: Box<[Src]>,
    /// Slot of `outputs[0]` when it is named (the admitted operator set
    /// is single-output; extra declared outputs are never produced, as
    /// in the string-keyed interpreter).
    pub output: Option<u32>,
    pub frees: Box<[u32]>,
}

/// A model lowered for execution: see the module docs.
///
/// Immutable after [`CompiledPlan::compile`] — every field (including the
/// kernels' baked packed weights) is read-only during execution, which is
/// what lets [`Session`](super::Session) hold it behind an `Arc` and
/// [`fork_replica`](super::Session::fork_replica) share ONE plan across
/// every serving replica: all mutable per-run state lives in the
/// [`ScratchArena`] a run checks out, never here.
pub(crate) struct CompiledPlan {
    pub steps: Vec<Step>,
    pub n_slots: usize,
    /// Slot index -> value name (the interner, read by the observer path
    /// so calibration still sees string names without any per-call
    /// allocation, and by [`resolve_src`] to match `Feed` slots against
    /// the run's borrowed feeds).
    pub names: Vec<String>,
    /// Graph outputs in declaration order.
    pub outputs: Vec<Src>,
    /// What the plan-time optimizer did (zeroed for unfused plans).
    pub stats: OptStats,
    /// Kernel ISA the lowering stamped into the plan's dispatched steps
    /// ([`Isa::active`] at compile time — recorded here so `plan_stats()`
    /// and serving reports can name the variant actually running).
    pub isa: Isa,
    /// Packed-GEMM tile config (kc / nr / parallel thresholds) the plan's
    /// quantized kernels run with. `compile` stamps the default; the
    /// session's plan-time micro-tuner ([`crate::tune::tuner`]) may repack
    /// the baked panels and overwrite this — always BEFORE the plan is
    /// frozen behind its `Arc`, extending the ISA stamp above with the
    /// second half of the dispatch decision.
    pub tile: GemmConfig,
    /// Where `tile` came from: untouched default, tuning-cache hit, or a
    /// fresh on-machine measurement.
    pub tuned: TuneSource,
}

/// Per-session recycled execution state: the steady-state zero-allocation
/// guarantee lives here. One arena serves one run at a time (the session
/// keeps a pool of them, so concurrent batch-parallel chunks each check
/// one out); between runs it holds every buffer the next run will write
/// into:
///
/// * `store` — the slot-indexed value store. All `None` between runs
///   (its `Vec` stays allocated).
/// * `recycle` — per-slot retired output tensors: when a slot's value
///   dies (its `frees` step, or the end-of-run sweep) the tensor moves
///   here instead of being dropped, and the next run's kernel for that
///   slot writes into its storage.
/// * `scratch` — three per-step kernel-internal buffers (conv im2col
///   columns, pre-bias conv results, the fused FC's packed-activation
///   staging container), owned by schedule position.
///
/// Memory stays bounded by the live-set of the largest batch seen: a
/// shape change just re-fills the affected buffers once.
pub(crate) struct ScratchArena {
    pub store: Vec<Option<Tensor>>,
    pub recycle: Vec<Option<Tensor>>,
    pub scratch: Vec<[Option<Tensor>; 3]>,
}

impl ScratchArena {
    pub fn new(n_slots: usize, n_steps: usize) -> ScratchArena {
        let mut store = Vec::with_capacity(n_slots);
        store.resize_with(n_slots, || None);
        let mut recycle = Vec::with_capacity(n_slots);
        recycle.resize_with(n_slots, || None);
        let mut scratch = Vec::with_capacity(n_steps);
        scratch.resize_with(n_steps, || [None, None, None]);
        ScratchArena {
            store,
            recycle,
            scratch,
        }
    }

    /// Move every still-live store entry into the recycle table — run
    /// teardown (covers values the schedule never freed, e.g. dead
    /// outputs, and error exits mid-run).
    pub fn sweep(&mut self) {
        for i in 0..self.store.len() {
            if let Some(t) = self.store[i].take() {
                self.recycle[i] = Some(t);
            }
        }
    }
}

/// Find a feed by name (feeds are few — one for every serving model — so
/// a linear scan beats any map and allocates nothing). Shared by input
/// resolution here and the executor's output-collection path.
#[inline]
pub(crate) fn feed_by_name<'v>(feeds: &[(&str, &'v Tensor)], name: &str) -> Option<&'v Tensor> {
    feeds.iter().find(|(n, _)| *n == name).map(|(_, t)| *t)
}

/// Resolve a [`Src`] against the run's slot store, its borrowed feeds,
/// and the model's initializer table.
#[inline]
pub(crate) fn resolve_src<'v>(
    src: &Src,
    store: &'v [Option<Tensor>],
    feeds: &[(&str, &'v Tensor)],
    names: &[String],
    inits: &'v [(String, Tensor)],
) -> Option<&'v Tensor> {
    match *src {
        Src::None => None,
        Src::Slot(s) => store[s as usize].as_ref(),
        Src::Init(i) => Some(&inits[i as usize].1),
        Src::SlotOrInit { slot, init } => store[slot as usize]
            .as_ref()
            .or(Some(&inits[init as usize].1)),
        Src::Feed { slot } => store[slot as usize]
            .as_ref()
            .or_else(|| feed_by_name(feeds, &names[slot as usize])),
        Src::FeedOrInit { slot, init } => store[slot as usize]
            .as_ref()
            .or_else(|| feed_by_name(feeds, &names[slot as usize]))
            .or(Some(&inits[init as usize].1)),
    }
}

impl CompiledPlan {
    /// Lower `model` (already checked) along the given schedule, running
    /// the plan-time optimizer first when `opts.fuse` is set. `types` is
    /// the checker's value-type map (consumed by the optimizer's LUT
    /// pass). With `fuse: false` the lowering is the 1:1 node-per-step
    /// form the differential oracle and observer path rely on.
    pub fn compile(
        model: &Model,
        order: &[usize],
        types: &HashMap<String, ValueType>,
        opts: &PlanOptions,
    ) -> Result<CompiledPlan, SessionError> {
        let g = &model.graph;
        let opt::Optimized {
            items,
            aliases,
            stats,
        } = opt::optimize(model, order, types, opts).map_err(SessionError::Pack)?;
        let init_pos: HashMap<&str, u32> = g
            .initializers
            .iter()
            .enumerate()
            .map(|(i, (n, _))| (n.as_str(), i as u32))
            .collect();
        // Eliminated no-op steps leave their output name as an alias of
        // their input; every name resolution canonicalizes through this
        // map first (empty for unfused plans).
        let canon = |name: &str| -> &str {
            aliases.get(name).map(String::as_str).unwrap_or(name)
        };

        // Intern: slots for every graph input (feeds, including shadowed
        // initializers) and every value a surviving step produces
        // (mid-chain values of fused spans are never materialized and get
        // no slot).
        fn intern(name: &str, slot_of: &mut HashMap<String, u32>, names: &mut Vec<String>) -> u32 {
            if let Some(&s) = slot_of.get(name) {
                return s;
            }
            let s = names.len() as u32;
            names.push(name.to_string());
            slot_of.insert(name.to_string(), s);
            s
        }
        let mut slot_of: HashMap<String, u32> = HashMap::new();
        let mut names: Vec<String> = Vec::new();
        for vi in &g.inputs {
            intern(&vi.name, &mut slot_of, &mut names);
        }
        for item in &items {
            match item {
                PlanItem::Node(idx) => {
                    for out in &g.nodes[*idx].outputs {
                        if !out.is_empty() {
                            intern(out, &mut slot_of, &mut names);
                        }
                    }
                }
                PlanItem::Fused { output, .. } => {
                    intern(output, &mut slot_of, &mut names);
                }
            }
        }

        let resolve = |name: &str| -> Src {
            if name.is_empty() {
                return Src::None;
            }
            let name = canon(name);
            // Graph-input slots resolve through the run's feeds (the
            // store holds only node-produced values — see [`Src`]).
            let is_feed = g.input(name).is_some();
            match (slot_of.get(name), init_pos.get(name), is_feed) {
                (Some(&slot), Some(&init), false) => Src::SlotOrInit { slot, init },
                (Some(&s), None, false) => Src::Slot(s),
                (Some(&slot), Some(&init), true) => Src::FeedOrInit { slot, init },
                (Some(&slot), None, true) => Src::Feed { slot },
                (None, Some(&i), _) => Src::Init(i),
                // Never defined anywhere: resolves to a missing input at
                // run time, as in the string-keyed interpreter (the
                // checker rejects such graphs up front anyway).
                (None, None, _) => Src::None,
            }
        };

        // Lower each surviving item.
        let mut steps = Vec::with_capacity(items.len());
        for item in items {
            match item {
                PlanItem::Node(idx) => {
                    let node = &g.nodes[idx];
                    let kernel =
                        Kernel::bind_in_graph(node, g).map_err(|source| SessionError::Op {
                            node: node.name.clone(),
                            source,
                        })?;
                    let inputs: Box<[Src]> = node.inputs.iter().map(|n| resolve(n)).collect();
                    let output = node
                        .outputs
                        .first()
                        .filter(|n| !n.is_empty())
                        .map(|n| slot_of[canon(n)]);
                    steps.push(Step {
                        node_idx: idx,
                        span: Box::default(),
                        kernel,
                        inputs,
                        output,
                        frees: Box::default(),
                    });
                }
                PlanItem::Fused {
                    nodes,
                    kernel,
                    input,
                    output,
                } => {
                    let inputs: Box<[Src]> = [resolve(&input)].into();
                    let out_slot = slot_of[output.as_str()];
                    steps.push(Step {
                        node_idx: nodes[0],
                        span: nodes.iter().map(|&n| n as u32).collect(),
                        kernel,
                        inputs,
                        output: Some(out_slot),
                        frees: Box::default(),
                    });
                }
            }
        }

        let outputs: Vec<Src> = g.outputs.iter().map(|vi| resolve(&vi.name)).collect();

        // Last-use analysis over the schedule, on slots. Only pure-slot
        // values are freed: initializer-backed inputs are owned by the
        // model, and any slot a graph output resolves to (directly or
        // through an alias) lives to the end of the run.
        let mut last_use: HashMap<u32, usize> = HashMap::new();
        for (pos, step) in steps.iter().enumerate() {
            for src in step.inputs.iter() {
                if let Src::Slot(s) = src {
                    last_use.insert(*s, pos);
                }
            }
        }
        for src in &outputs {
            match *src {
                Src::Slot(s)
                | Src::SlotOrInit { slot: s, .. }
                | Src::Feed { slot: s }
                | Src::FeedOrInit { slot: s, .. } => {
                    last_use.remove(&s);
                }
                Src::Init(_) | Src::None => {}
            }
        }
        let mut frees: Vec<Vec<u32>> = vec![Vec::new(); steps.len()];
        for (slot, pos) in last_use {
            frees[pos].push(slot);
        }
        for (step, f) in steps.iter_mut().zip(frees) {
            step.frees = f.into_boxed_slice();
        }

        // The stamped ISA is uniform across a plan (every prebind calls
        // `Isa::active()` under one compile), so the first dispatched
        // step names it; plans with no dispatched step report the
        // selection that WOULD apply.
        let isa = steps
            .iter()
            .find_map(|s| s.kernel.isa())
            .unwrap_or_else(Isa::active);

        Ok(CompiledPlan {
            steps,
            n_slots: names.len(),
            names,
            outputs,
            stats,
            isa,
            tile: GemmConfig::DEFAULT,
            tuned: TuneSource::Default,
        })
    }
}
