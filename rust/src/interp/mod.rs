//! Generic graph executor — the "standard ONNX tool" of the reproduction.
//!
//! [`Session::new`] validates a model once (structure, standard-ops-only,
//! shape/dtype inference), plans an execution order and value lifetimes,
//! then **lowers the graph into a [`CompiledPlan`]**: value names interned
//! to dense slots, initializers resolved to indices, attributes parsed
//! into pre-bound [`crate::ops::Kernel`]s, per-step frees as slot lists.
//! Executing a feed set is then a tight loop over `Vec`-indexed slots —
//! no string hashing, no per-node attribute parsing, no feed cloning —
//! and, since the scratch planner (EXPERIMENTS.md §Perf), **no
//! steady-state heap allocation**: every intermediate buffer recycles
//! through a per-run [`plan::ScratchArena`] checked out of a session
//! pool, kernels write through the `run_with` out-param API, and
//! [`Session::run_into`] recycles even the output tensors a caller
//! hands back. `tests/alloc_regression.rs` holds the counting-allocator
//! proof.
//!
//! A pre-quantized model runs here *because* it is expressed in standard
//! operators (paper goal 2) — the session treats `Quant_scale` exactly
//! like any other initializer. The pre-plan string-keyed interpreter is
//! retained as [`Session::run_unplanned`], serving as the differential-
//! test oracle (`tests/executor_plan.rs`) and the legacy baseline in
//! `bench_serving`.

mod plan;

use crate::onnx::check::{check_model, CheckError};
use crate::onnx::ir::{Dim, Model, ValueInfo};
use crate::onnx::shape::ValueType;
use crate::onnx::topo::topo_order;
use crate::ops::{execute_node, Isa, OpError};
use crate::parallel::{self, ThreadPool};
use crate::tensor::{DType, Tensor};
use crate::tune::{model_digest, tune_gemms, GemmConfig, TuneMode, TuneOutcome, TuneSource};
use plan::{resolve_src, CompiledPlan, ScratchArena, Src};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use thiserror::Error;

pub use crate::opt::PlanOptions;

/// Smallest batch the auto-parallel path will split: below this the pool
/// dispatch overhead dominates the per-row graph execution. Alias of the
/// unified [`crate::tune::Thresholds`] policy.
pub const PAR_MIN_BATCH: usize = crate::tune::Thresholds::DEFAULT.batch_par_min;

/// Node inputs at or below this arity resolve into a stack array in the
/// hot loop (every admitted operator has <= 4 inputs; the heap fallback
/// only exists for malformed hand-built nodes).
const STACK_INPUTS: usize = 8;

/// Upper bound on retained [`ScratchArena`]s per session. Arenas above
/// the cap (created only while MORE than this many runs execute the same
/// session concurrently) are dropped on check-in instead of pooled, so a
/// burst of concurrency cannot pin an unbounded number of max-batch
/// live-sets for the session's lifetime.
const MAX_POOLED_ARENAS: usize = 32;

#[derive(Error, Debug)]
pub enum SessionError {
    #[error("model check failed: {0}")]
    Check(#[from] CheckError),
    #[error("feed '{0}' is not a graph input")]
    UnknownFeed(String),
    #[error("missing feed for graph input '{0}'")]
    MissingFeed(String),
    #[error("feed '{name}': expected dtype {expected}, got {got}")]
    FeedDType {
        name: String,
        expected: DType,
        got: DType,
    },
    #[error("feed '{name}': shape {got:?} incompatible with declared {declared:?}")]
    FeedShape {
        name: String,
        declared: Vec<Dim>,
        got: Vec<usize>,
    },
    #[error("symbolic dim '{sym}' bound inconsistently: {a} vs {b}")]
    SymbolClash { sym: String, a: usize, b: usize },
    #[error("op failed at node '{node}': {source}")]
    Op { node: String, source: OpError },
    #[error("internal: value '{0}' missing during execution")]
    ValueMissing(String),
    #[error("batch split/concat failed: {0}")]
    Batch(#[from] crate::tensor::TensorError),
    /// A forced `PQDL_PACK_WIDTH` the model's fused weights cannot admit
    /// — rejected at plan time instead of silently falling back (the
    /// forcing values exist precisely to pin a kernel family).
    #[error(transparent)]
    Pack(#[from] crate::opt::PackError),
}

/// Per-node execution statistics (filled when profiling is enabled).
#[derive(Clone, Debug, Default)]
pub struct NodeStats {
    pub name: String,
    pub op_type: String,
    pub nanos: u128,
    pub calls: u64,
}

/// What plan compilation did to this session's model: step counts before
/// and after the plan-time graph optimizer, and the fused-kernel counts
/// by kind — fusion coverage observable without a debugger (printed by
/// `examples/serve_demo.rs`, asserted by the CI fusion smoke).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanStats {
    /// Scheduled graph nodes (= steps of the unfused plan).
    pub nodes: usize,
    /// Steps of the execution plan after fusion/elimination.
    pub steps: usize,
    /// Graph nodes absorbed into fused steps (sum of the fused spans).
    pub fused_nodes: usize,
    pub fused_qfc: usize,
    pub fused_qconv: usize,
    pub fused_act_lut: usize,
    /// Fused FC/conv steps whose weights baked to the int4 nibble-packed
    /// kernel family (subset of `fused_qfc + fused_qconv`).
    pub fused_int4: usize,
    /// Fused FC/conv steps whose weights baked to int3 tribble panels.
    pub fused_int3: usize,
    /// Fused FC/conv steps whose weights baked to int2 crumb panels.
    pub fused_int2: usize,
    /// Fused FC/conv steps whose weights baked to the bipolar
    /// XNOR-popcount kernel family (subset of `fused_qfc + fused_qconv`).
    pub fused_bipolar: usize,
    /// Fused FC→FC edges carrying nibble-packed activation rows (the
    /// producer never materializes the i8 container for the edge).
    pub packed_act_nibble: usize,
    /// Fused FC→FC edges attempting bitplane (±1) activation packing
    /// (runtime-gated; a batch containing 0 falls back to the container).
    pub packed_act_bitplane: usize,
    pub eliminated: usize,
    /// Kernel instruction set the plan's quantized microkernels were
    /// stamped with at compile time (see [`crate::ops::Isa::active`]).
    pub isa: Isa,
    /// Steps dispatching through that ISA (pre-bound + fused int8
    /// GEMM/conv kernels) — the plan's ISA coverage.
    pub isa_steps: usize,
    /// Packed-GEMM tile config the plan's quantized kernels were stamped
    /// with (kc / nr / parallel split thresholds) — the plan-time
    /// micro-tuner's pick, or [`GemmConfig::DEFAULT`] when tuning is off,
    /// found nothing better, or the model has no packed GEMM.
    pub tile: GemmConfig,
    /// Where `tile` came from (default / tuning-cache hit / measured).
    pub tuned: TuneSource,
    /// Whether the 1:1 unfused twin plan exists right now. Lazily
    /// compiled (first observer / oracle / profiling use), so a
    /// pure-serving fused session reports `false` and pays no double
    /// baked-weight memory; sessions where fusion changed nothing share
    /// ONE plan for both roles and report `true` at no extra cost.
    pub twin_compiled: bool,
}

impl std::fmt::Display for PlanStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} nodes -> {} steps ({} fused-fc, {} fused-conv, {} act-lut over {} nodes, {} int4 / {} int3 / {} int2 / {} bipolar baked, {} nibble-act / {} bitplane-act edges, {} eliminated; isa {} on {} steps; tile {} [{}]; twin {})",
            self.nodes,
            self.steps,
            self.fused_qfc,
            self.fused_qconv,
            self.fused_act_lut,
            self.fused_nodes,
            self.fused_int4,
            self.fused_int3,
            self.fused_int2,
            self.fused_bipolar,
            self.packed_act_nibble,
            self.packed_act_bitplane,
            self.eliminated,
            self.isa,
            self.isa_steps,
            self.tile,
            self.tuned.name(),
            if self.twin_compiled { "compiled" } else { "lazy" }
        )
    }
}

/// Per-plan-step accumulator behind the profiler: stats are keyed by
/// schedule position, so a profiled run takes ONE lock at the end instead
/// of a `HashMap` entry lock per node.
#[derive(Clone, Default)]
struct StepProfile {
    nanos: u128,
    calls: u64,
}

/// The 1:1 node-per-step twin plan plus the legacy string-keyed free
/// lists derived from it — everything the observer, oracle, and profiling
/// paths need that the fused execution plan cannot provide.
struct TwinPlan {
    unfused: Arc<CompiledPlan>,
    /// Frees as value names, for [`Session::run_unplanned`] only (kept so
    /// the legacy path reproduces the pre-plan interpreter faithfully,
    /// including its memory behavior).
    unplanned_frees: Vec<Vec<String>>,
}

impl TwinPlan {
    fn new(unfused: Arc<CompiledPlan>) -> TwinPlan {
        let unplanned_frees = unfused
            .steps
            .iter()
            .map(|s| {
                s.frees
                    .iter()
                    .map(|&f| unfused.names[f as usize].clone())
                    .collect()
            })
            .collect();
        TwinPlan {
            unfused,
            unplanned_frees,
        }
    }
}

/// Lazily compiled unfused twin. Sessions where fusion fired used to
/// compile BOTH plans eagerly, so every pure-serving process paid double
/// baked-weight memory for observer/oracle/profiling plans it never ran.
/// The twin now compiles on first use — the retained schedule and type
/// map make that possible long after [`Session::new`] returned — and is
/// shared across [`Session::fork_replica`] clones, so one compile serves
/// a whole replica pool. When fusion changed nothing (or was disabled)
/// the slot is seeded eagerly with the execution plan itself: same
/// `Arc`, zero extra memory.
struct LazyTwin {
    /// Topological schedule the session compiled with.
    order: Vec<usize>,
    /// The checker's value-type map (the optimizer's LUT pass input).
    types: HashMap<String, ValueType>,
    /// Scheduled node count (= the unfused plan's step count), stored
    /// eagerly so [`Session::plan_stats`] never forces the compile.
    nodes: usize,
    slot: Mutex<Option<Arc<TwinPlan>>>,
}

/// A validated, planned, executable model.
///
/// The compiled state (model, plan, legacy free lists) is immutable after
/// [`Session::new`] and held behind `Arc`s, so [`Session::fork_replica`]
/// can hand out additional sessions over the SAME plan at the cost of a
/// few reference counts — each replica owns only its own arena pool and
/// profiler. This is what makes a serving replica
/// (`coordinator::server`) nearly free: N replicas share one set of
/// pre-bound kernels and packed weights, and never contend on each
/// other's arena locks.
pub struct Session {
    model: Arc<Model>,
    /// The execution plan: fused by the plan-time optimizer
    /// (`crate::opt`) unless compiled with `PlanOptions { fuse: false }`
    /// or no pass changed anything (then it IS `unfused`, shared).
    plan: Arc<CompiledPlan>,
    /// The 1:1 node-per-step plan (plus legacy free lists), compiled on
    /// first use by [`Session::run_observed`], the `run_unplanned`
    /// oracle, or a profiling run — see [`LazyTwin`].
    twin: Arc<LazyTwin>,
    /// `Some(symbol)` when the graph is provably row-independent along a
    /// leading symbolic batch axis (see [`detect_batch_symbol`]) — the
    /// precondition for the batch-parallel execution path.
    batch_symbol: Option<String>,
    /// Auto-parallel batched `run` calls (on by default; disable with
    /// [`Session::with_parallelism`] to force the serial path).
    parallel: bool,
    /// Pool of recycled execution arenas: one is checked out per run (so
    /// concurrent batch-parallel chunks never contend on buffers) and
    /// returned with its store swept into the recycle table. After the
    /// first run at a given batch size, the checked-out arena already
    /// holds every intermediate buffer the run needs — the steady-state
    /// zero-allocation guarantee (see `tests/alloc_regression.rs`).
    arenas: Mutex<Vec<ScratchArena>>,
    profile: Mutex<Vec<StepProfile>>,
    profiling: bool,
}

/// Decide whether the model can be executed per-row along a leading
/// symbolic batch axis. True when:
///
/// * every runtime input and every declared output has the SAME symbolic
///   dim in position 0 and nowhere else (so splitting rows touches nothing
///   but the batch), and no output is served from an initializer,
/// * no `Softmax` normalizes over axis 0 (the only admitted operator that
///   could couple rows; every other standard op in
///   [`crate::onnx::check::STANDARD_OPS`] is row-independent along a
///   leading batch axis, which shape inference enforces).
fn detect_batch_symbol(model: &Model, types: &HashMap<String, ValueType>) -> Option<String> {
    let g = &model.graph;
    let inputs = g.runtime_inputs();
    let first = inputs.first()?;
    let sym = match first.shape.first()? {
        Dim::Symbolic(s) => s.clone(),
        Dim::Fixed(_) => return None,
    };
    let leading_only = |vi: &ValueInfo| -> bool {
        matches!(vi.shape.first(), Some(Dim::Symbolic(s)) if *s == sym)
            && !vi.shape[1..]
                .iter()
                .any(|d| matches!(d, Dim::Symbolic(s) if *s == sym))
    };
    if !inputs.iter().all(|vi| leading_only(vi)) {
        return None;
    }
    if g.outputs.is_empty() || !g.outputs.iter().all(|vi| leading_only(vi)) {
        return None;
    }
    if g.outputs.iter().any(|vi| g.initializer(&vi.name).is_some()) {
        return None;
    }
    if crate::onnx::shape::couples_rows_on_axis0(g, types) {
        return None;
    }
    Some(sym)
}

impl Session {
    /// Validate + plan + lower (with the plan-time graph optimizer on —
    /// the default). Fails on any malformed or non-standard model —
    /// including operators the executor cannot run, which error here
    /// (plan time) instead of at the first `run`.
    pub fn new(model: Model) -> Result<Session, SessionError> {
        Session::new_with_options(model, PlanOptions::default())
    }

    /// [`Session::new`] with explicit [`PlanOptions`]. `fuse: false`
    /// compiles only the 1:1 node-per-step plan (useful as the
    /// fused-vs-unfused baseline in benches and differential tests).
    /// When fusion fires, the 1:1 twin the observer / oracle / profiling
    /// paths need is compiled lazily on first use — see [`LazyTwin`] —
    /// so a serving session holds exactly one set of baked weights.
    pub fn new_with_options(model: Model, opts: PlanOptions) -> Result<Session, SessionError> {
        let types = check_model(&model)?;
        let batch_symbol = detect_batch_symbol(&model, &types);
        let order = topo_order(&model.graph)
            .map_err(|e| SessionError::Check(crate::onnx::shape::ShapeError::from(e).into()))?;
        // Compile the execution plan (optimizer on when requested).
        let mut first = CompiledPlan::compile(&model, &order, &types, &opts)?;

        // Plan-time micro-tuner (`crate::tune`): pick a packed-GEMM tile
        // config for this (model, shapes, ISA, nthreads) point — cache
        // hit, or measured on the real machine with the actual baked
        // weight panels under `PQDL_TUNE=full`. Runs BEFORE the plan is
        // frozen behind its `Arc`, while the kernels are still mutable:
        // a non-default winner repacks every baked panel via
        // `Kernel::retune`. Every candidate computes bit-identically to
        // the default (`tests/tuner.rs`), so this is a pure perf choice.
        let outcome = {
            let problems: Vec<_> = first
                .steps
                .iter()
                .filter_map(|s| s.kernel.tune_problem())
                .collect();
            let mode = TuneMode::active();
            if matches!(mode, TuneMode::Off) || problems.is_empty() {
                TuneOutcome::DEFAULT
            } else {
                tune_gemms(
                    model_digest(&model),
                    &problems,
                    first.isa,
                    ThreadPool::global().threads(),
                    mode,
                )
            }
        };
        if !outcome.cfg.is_default() {
            for step in &mut first.steps {
                step.kernel.retune(outcome.cfg);
            }
        }
        first.tile = outcome.cfg;
        first.tuned = outcome.source;
        let plan = Arc::new(first);

        // The 1:1 twin plan is LAZY: if no optimizer pass changed
        // anything, the execution plan IS the 1:1 lowering and serves
        // both roles (seeded below — same `Arc`, zero extra memory);
        // otherwise the twin compiles on its first observer / oracle /
        // profiling use, so pure-serving sessions never pay the second
        // set of baked weights.
        let nodes = order.len();
        let twin = LazyTwin {
            order,
            types,
            nodes,
            slot: Mutex::new(None),
        };
        if !(opts.fuse && plan.stats.changed()) {
            *twin.slot.lock().unwrap() = Some(Arc::new(TwinPlan::new(plan.clone())));
        }
        let profile = Mutex::new(vec![StepProfile::default(); nodes]);

        Ok(Session {
            model: Arc::new(model),
            plan,
            twin: Arc::new(twin),
            batch_symbol,
            parallel: true,
            arenas: Mutex::new(Vec::new()),
            profile,
            profiling: false,
        })
    }

    /// The unfused twin (compiling it now if this is the first use).
    fn twin_plan(&self) -> Result<Arc<TwinPlan>, SessionError> {
        let mut slot = self.twin.slot.lock().unwrap();
        if let Some(t) = slot.as_ref() {
            return Ok(t.clone());
        }
        let unfused = Arc::new(CompiledPlan::compile(
            &self.model,
            &self.twin.order,
            &self.twin.types,
            &PlanOptions { fuse: false },
        )?);
        let t = Arc::new(TwinPlan::new(unfused));
        *slot = Some(t.clone());
        Ok(t)
    }

    /// Bytes of baked kernel weights (widened int32 copies, packed
    /// panels, bias vectors) held by this session's compiled plans: the
    /// execution plan, plus the unfused twin only once it actually
    /// exists. The lazy-twin plan-memory claim is observable here —
    /// `bench_serving` and `tests/tuner.rs` read it before and after
    /// forcing the twin.
    pub fn baked_plan_bytes(&self) -> usize {
        let mut bytes: usize = self
            .plan
            .steps
            .iter()
            .map(|s| s.kernel.baked_bytes())
            .sum();
        if let Some(t) = self.twin.slot.lock().unwrap().as_ref() {
            if !Arc::ptr_eq(&t.unfused, &self.plan) {
                bytes += t
                    .unfused
                    .steps
                    .iter()
                    .map(|s| s.kernel.baked_bytes())
                    .sum::<usize>();
            }
        }
        bytes
    }

    /// A new session over the SAME compiled plan, model, and baked
    /// kernels (shared by `Arc`, not recompiled), with its own arena pool
    /// and profiler. Replicas therefore cost a few pointers plus whatever
    /// scratch they warm up, and concurrent replicas never touch each
    /// other's `arenas` mutex — the serving layer's per-replica checkout.
    /// Results are bit-identical to the parent by construction (same plan,
    /// same kernels).
    pub fn fork_replica(&self) -> Session {
        Session {
            model: self.model.clone(),
            plan: self.plan.clone(),
            twin: self.twin.clone(),
            batch_symbol: self.batch_symbol.clone(),
            parallel: self.parallel,
            arenas: Mutex::new(Vec::new()),
            profile: Mutex::new(vec![StepProfile::default(); self.twin.nodes]),
            profiling: self.profiling,
        }
    }

    /// Enable per-node wall-clock accounting (used by the §Perf pass).
    /// Profiling sessions always execute serially — and on the UNFUSED
    /// plan — so per-node timings stay attributable to single operators.
    pub fn with_profiling(mut self) -> Session {
        self.profiling = true;
        // Pooled arenas are sized for the execution plan, which just
        // changed to the unfused one — drop any warmed-up arenas.
        self.arenas = Mutex::new(Vec::new());
        self
    }

    /// The plan `run`/`run_into`/`run_serial` execute: the fused plan,
    /// except for profiling sessions (per-node attribution), whose first
    /// run forces the lazy twin compile.
    fn exec_plan(&self) -> Result<Arc<CompiledPlan>, SessionError> {
        if self.profiling {
            Ok(self.twin_plan()?.unfused.clone())
        } else {
            Ok(self.plan.clone())
        }
    }

    /// Enable/disable the batch-parallel `run` path (default: enabled).
    pub fn with_parallelism(mut self, enabled: bool) -> Session {
        self.parallel = enabled;
        self
    }

    /// True when this model qualifies for batch-parallel execution.
    pub fn batch_parallelizable(&self) -> bool {
        self.batch_symbol.is_some()
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    /// Fusion coverage of this session's execution plan — see
    /// [`PlanStats`].
    pub fn plan_stats(&self) -> PlanStats {
        let s = self.plan.stats;
        PlanStats {
            nodes: self.twin.nodes,
            steps: self.plan.steps.len(),
            fused_nodes: self.plan.steps.iter().map(|st| st.span.len()).sum(),
            fused_qfc: s.fused_qfc,
            fused_qconv: s.fused_qconv,
            fused_act_lut: s.fused_act_lut,
            fused_int4: s.fused_int4,
            fused_int3: s.fused_int3,
            fused_int2: s.fused_int2,
            fused_bipolar: s.fused_bipolar,
            packed_act_nibble: s.packed_act_nibble,
            packed_act_bitplane: s.packed_act_bitplane,
            eliminated: s.eliminated,
            isa: self.plan.isa,
            isa_steps: self
                .plan
                .steps
                .iter()
                .filter(|st| st.kernel.isa().is_some())
                .count(),
            tile: self.plan.tile,
            tuned: self.plan.tuned,
            twin_compiled: self.twin.slot.lock().unwrap().is_some(),
        }
    }

    /// Execute the graph. `feeds` must cover every runtime input; outputs
    /// are returned in graph-output declaration order.
    ///
    /// Batches of at least [`PAR_MIN_BATCH`] rows on batch-splittable
    /// models are split across the global thread pool; results are
    /// bit-identical to [`Session::run_serial`] (rows are independent and
    /// reassembled in order — see `tests/parallel_exec.rs`).
    pub fn run(&self, feeds: &[(&str, Tensor)]) -> Result<Vec<Tensor>, SessionError> {
        let refs: Vec<(&str, &Tensor)> = feeds.iter().map(|(n, t)| (*n, t)).collect();
        self.run_refs(&refs)
    }

    /// [`Session::run`] over borrowed feeds — the serving layer's entry
    /// point, avoiding a tensor clone per request.
    pub fn run_refs(&self, feeds: &[(&str, &Tensor)]) -> Result<Vec<Tensor>, SessionError> {
        let mut outs = Vec::new();
        self.run_into(feeds, &mut outs)?;
        Ok(outs)
    }

    /// [`Session::run_refs`] with output-buffer recycling: pass the
    /// `outs` of the previous call back in and their storage is recycled
    /// into the plan's output slots, closing the last per-run allocation
    /// — at a steady batch size the whole call performs **zero heap
    /// allocations** on the serial planned path (intermediates recycle
    /// through the session's [`ScratchArena`] pool regardless of which
    /// entry point is used; `tests/alloc_regression.rs` enforces this).
    ///
    /// On the batch-*parallel* path (splittable model, batch >=
    /// [`PAR_MIN_BATCH`], default parallelism) outputs are assembled by
    /// slicing + concatenation, so the handed-back buffers are replaced
    /// rather than reused there — only the per-chunk intermediates
    /// recycle (each chunk's `run_serial` checks out its own arena).
    /// Disable parallelism (or stay under the split threshold) to get
    /// the full zero-allocation contract.
    ///
    /// Degenerate passthrough outputs (a graph output aliasing a graph
    /// input or initializer with no producing node) are cloned from
    /// their source on every call, exactly as the pre-arena executor
    /// did — there is no buffer to recycle into for a value no kernel
    /// writes.
    pub fn run_into(
        &self,
        feeds: &[(&str, &Tensor)],
        outs: &mut Vec<Tensor>,
    ) -> Result<(), SessionError> {
        if self.parallel && !self.profiling {
            let pool = ThreadPool::global();
            // A 1-thread pool would execute the chunks sequentially anyway,
            // so splitting there is pure slice/concat overhead (run_on keeps
            // chunking on tiny pools deliberately, for the property tests).
            if pool.threads() > 1 {
                if let Some(chunks) = self.batch_chunks(feeds, pool, PAR_MIN_BATCH) {
                    let res = self.run_parallel(feeds, &chunks, pool)?;
                    outs.clear();
                    outs.extend(res);
                    return Ok(());
                }
            }
            // Not batch-split (small batch or non-splittable model): run on
            // this thread, leaving the op-level GEMM/conv parallelism free
            // to engage for large single calls.
            return self.execute_core(feeds, outs);
        }
        parallel::serial_scope(|| self.execute_core(feeds, outs))
    }

    /// Execute strictly on the calling thread — [`parallel::serial_scope`]
    /// also forces the op-level GEMM/conv parallelism to its serial path,
    /// so this is a true single-thread reference.
    pub fn run_serial(&self, feeds: &[(&str, Tensor)]) -> Result<Vec<Tensor>, SessionError> {
        let refs: Vec<(&str, &Tensor)> = feeds.iter().map(|(n, t)| (*n, t)).collect();
        let mut outs = Vec::new();
        parallel::serial_scope(|| self.execute_core(&refs, &mut outs))?;
        Ok(outs)
    }

    /// Execute with the batch axis split across `pool` whenever the model
    /// and batch allow it at all (no minimum-batch heuristic — used by the
    /// serial-vs-parallel property tests), falling back to serial
    /// otherwise.
    pub fn run_on(
        &self,
        feeds: &[(&str, Tensor)],
        pool: &ThreadPool,
    ) -> Result<Vec<Tensor>, SessionError> {
        let refs: Vec<(&str, &Tensor)> = feeds.iter().map(|(n, t)| (*n, t)).collect();
        if let Some(chunks) = self.batch_chunks(&refs, pool, 2) {
            return self.run_parallel(&refs, &chunks, pool);
        }
        self.run_serial(feeds)
    }

    /// Plan the row ranges for a parallel run, or `None` when the serial
    /// path should handle the call (not splittable, too small, nested in a
    /// pool worker, or feeds that serial validation should reject).
    fn batch_chunks(
        &self,
        feeds: &[(&str, &Tensor)],
        pool: &ThreadPool,
        min_batch: usize,
    ) -> Option<Vec<std::ops::Range<usize>>> {
        self.batch_symbol.as_ref()?;
        if !parallel::allow_pool_dispatch() {
            return None;
        }
        let batch = feeds.first()?.1.shape().first().copied()?;
        if feeds.iter().any(|(_, t)| t.shape().first() != Some(&batch)) {
            return None;
        }
        if batch < min_batch.max(2) {
            return None;
        }
        let pieces = parallel::chunk_count(batch, pool.threads().max(2), 1);
        if pieces < 2 {
            return None;
        }
        Some(parallel::ranges(batch, pieces))
    }

    /// Run each row-chunk through the serial executor and stitch the
    /// outputs back together in chunk order (the shared
    /// [`parallel::scatter_gather`] does the dispatch + ordered gather).
    fn run_parallel(
        &self,
        feeds: &[(&str, &Tensor)],
        chunks: &[std::ops::Range<usize>],
        pool: &ThreadPool,
    ) -> Result<Vec<Tensor>, SessionError> {
        let mut per_chunk: Vec<Vec<Tensor>> =
            parallel::scatter_gather(pool, chunks, |range| {
                let mut chunk_feeds: Vec<(&str, Tensor)> = Vec::with_capacity(feeds.len());
                for (name, t) in feeds {
                    chunk_feeds.push((*name, t.slice_rows(range.start, range.len())?));
                }
                self.run_serial(&chunk_feeds)
            })?;
        let n_outputs = self.model.graph.outputs.len();
        let mut outputs = Vec::with_capacity(n_outputs);
        for _ in 0..n_outputs {
            let parts: Vec<Tensor> = per_chunk.iter_mut().map(|c| c.remove(0)).collect();
            outputs.push(Tensor::concat_rows(&parts)?);
        }
        Ok(outputs)
    }

    /// Execute while reporting every produced value (name, tensor) to
    /// `observer` — the hook the calibration pass uses to profile
    /// intermediate activations without declaring them as outputs. Names
    /// come from the plan's interner (slot -> name), so observation adds
    /// no per-call allocation.
    ///
    /// Always runs the UNFUSED plan: a fused span materializes none of
    /// its mid-chain values, so observing it would silently drop events.
    /// On the unfused plan the observer stream is bit-identical to the
    /// legacy interpreter's (regression-pinned in
    /// `tests/executor_plan.rs`). Uses a fresh arena (the session pool's
    /// arenas are sized for the execution plan) — this is the calibration
    /// path, not a serving hot path.
    pub fn run_observed(
        &self,
        feeds: &[(&str, Tensor)],
        observer: &mut dyn FnMut(&str, &Tensor),
    ) -> Result<Vec<Tensor>, SessionError> {
        let refs: Vec<(&str, &Tensor)> = feeds.iter().map(|(n, t)| (*n, t)).collect();
        self.validate_feeds(&refs)?;
        let twin = self.twin_plan()?;
        let unfused = &twin.unfused;
        let mut outs = Vec::new();
        let mut arena = ScratchArena::new(unfused.n_slots, unfused.steps.len());
        self.execute_steps(unfused, &mut arena, &refs, observer, &mut outs, false)?;
        Ok(outs)
    }

    /// Validate feeds against the declared graph inputs, binding symbolic
    /// dims consistently across feeds.
    ///
    /// Allocation-free on success: symbol bindings live in a small stack
    /// array (models bind a handful of symbols — usually one, the batch
    /// axis), spilling to a heap vector only past its capacity; the
    /// required-feed check scans the graph inputs in place instead of
    /// materializing `runtime_inputs()`.
    fn validate_feeds(&self, feeds: &[(&str, &Tensor)]) -> Result<(), SessionError> {
        const INLINE_SYMS: usize = 8;
        let g = &self.model.graph;
        let mut inline: [Option<(&str, usize)>; INLINE_SYMS] = [None; INLINE_SYMS];
        let mut n_inline = 0usize;
        let mut spill: Vec<(&str, usize)> = Vec::new();
        for (name, t) in feeds {
            let vi = g
                .input(name)
                .ok_or_else(|| SessionError::UnknownFeed(name.to_string()))?;
            if vi.dtype != t.dtype() {
                return Err(SessionError::FeedDType {
                    name: name.to_string(),
                    expected: vi.dtype,
                    got: t.dtype(),
                });
            }
            if vi.shape.len() != t.shape().len() {
                return Err(SessionError::FeedShape {
                    name: name.to_string(),
                    declared: vi.shape.clone(),
                    got: t.shape().to_vec(),
                });
            }
            for (d, &got) in vi.shape.iter().zip(t.shape()) {
                match d {
                    Dim::Fixed(n) => {
                        if *n != got {
                            return Err(SessionError::FeedShape {
                                name: name.to_string(),
                                declared: vi.shape.clone(),
                                got: t.shape().to_vec(),
                            });
                        }
                    }
                    Dim::Symbolic(s) => {
                        let prev = inline[..n_inline]
                            .iter()
                            .flatten()
                            .chain(spill.iter())
                            .find(|(sym, _)| *sym == s.as_str())
                            .map(|&(_, v)| v);
                        match prev {
                            Some(prev) => {
                                if prev != got {
                                    return Err(SessionError::SymbolClash {
                                        sym: s.clone(),
                                        a: prev,
                                        b: got,
                                    });
                                }
                            }
                            None => {
                                if n_inline < INLINE_SYMS {
                                    inline[n_inline] = Some((s.as_str(), got));
                                    n_inline += 1;
                                } else {
                                    spill.push((s.as_str(), got));
                                }
                            }
                        }
                    }
                }
            }
        }
        for vi in &g.inputs {
            if g.initializer(&vi.name).is_some() {
                continue; // initializer-backed input: feed optional
            }
            if !feeds.iter().any(|(n, _)| *n == vi.name) {
                return Err(SessionError::MissingFeed(vi.name.clone()));
            }
        }
        Ok(())
    }

    /// The planned hot loop: slot-indexed value store, pre-bound kernels,
    /// recycled buffers. Checks an arena out of the session pool, seeds
    /// its output slots with the storage of the tensors the caller hands
    /// back in `outs`, executes, and refills `outs` in graph-output
    /// declaration order. After the first run at a batch size, the whole
    /// call allocates nothing on the serial path.
    fn execute_core(
        &self,
        feeds: &[(&str, &Tensor)],
        outs: &mut Vec<Tensor>,
    ) -> Result<(), SessionError> {
        self.validate_feeds(feeds)?;
        let plan = self.exec_plan()?;
        let mut arena = {
            let mut pool = self.arenas.lock().unwrap();
            pool.pop()
        }
        .unwrap_or_else(|| ScratchArena::new(plan.n_slots, plan.steps.len()));

        // Recycle the caller's previous outputs into their slots.
        for (t, src) in outs.drain(..).zip(plan.outputs.iter()) {
            match *src {
                Src::Slot(s)
                | Src::SlotOrInit { slot: s, .. }
                | Src::Feed { slot: s }
                | Src::FeedOrInit { slot: s, .. } => arena.recycle[s as usize] = Some(t),
                Src::Init(_) | Src::None => {}
            }
        }

        let mut noop = |_: &str, _: &Tensor| {};
        let result =
            self.execute_steps(&plan, &mut arena, feeds, &mut noop, outs, self.profiling);
        // Teardown: park every remaining live value for the next run and
        // return the arena — also on the error path. Beyond the cap the
        // arena is dropped: memory stays bounded by MAX_POOLED_ARENAS
        // live-sets even after a burst of concurrent runs.
        arena.sweep();
        {
            let mut pool = self.arenas.lock().unwrap();
            if pool.len() < MAX_POOLED_ARENAS {
                pool.push(arena);
            }
        }
        result
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_steps(
        &self,
        plan: &CompiledPlan,
        arena: &mut ScratchArena,
        feeds: &[(&str, &Tensor)],
        observer: &mut dyn FnMut(&str, &Tensor),
        outs: &mut Vec<Tensor>,
        profile: bool,
    ) -> Result<(), SessionError> {
        let g = &self.model.graph;
        let inits = &g.initializers;
        let names = &plan.names;
        for &(name, t) in feeds {
            observer(name, t);
        }

        let mut timings: Vec<u128> = if profile {
            vec![0; plan.steps.len()]
        } else {
            Vec::new()
        };
        for (pos, step) in plan.steps.iter().enumerate() {
            // Resolve inputs on the stack — no per-node heap allocation.
            let n_in = step.inputs.len();
            let mut stack: [Option<&Tensor>; STACK_INPUTS] = [None; STACK_INPUTS];
            let heap: Vec<Option<&Tensor>>;
            let input_refs: &[Option<&Tensor>] = if n_in <= STACK_INPUTS {
                for (dst, src) in stack.iter_mut().zip(step.inputs.iter()) {
                    *dst = resolve_src(src, &arena.store, feeds, names, inits);
                }
                &stack[..n_in]
            } else {
                heap = step
                    .inputs
                    .iter()
                    .map(|src| resolve_src(src, &arena.store, feeds, names, inits))
                    .collect();
                &heap
            };
            // The step's retired output buffer from a previous run, if
            // any, plus its two kernel-internal scratch slots.
            let recycled = match step.output {
                Some(slot) => arena.recycle[slot as usize].take(),
                None => None,
            };
            let t0 = profile.then(std::time::Instant::now);
            let out = step
                .kernel
                .run_with(input_refs, recycled, &mut arena.scratch[pos])
                .map_err(|source| {
                    let node = &g.nodes[step.node_idx];
                    SessionError::Op {
                        node: node.name.clone(),
                        source: source.with_node(&node.name),
                    }
                })?;
            if let Some(t0) = t0 {
                timings[pos] = t0.elapsed().as_nanos();
            }
            if let Some(slot) = step.output {
                observer(&names[slot as usize], &out);
                arena.store[slot as usize] = Some(out);
            }
            // Last uses: park the dead value's storage for the next run
            // instead of dropping it.
            for &dead in step.frees.iter() {
                if let Some(t) = arena.store[dead as usize].take() {
                    arena.recycle[dead as usize] = Some(t);
                }
            }
        }

        if profile {
            // One lock per run: merge the local step timings.
            let mut prof = self.profile.lock().unwrap();
            for (p, &nanos) in prof.iter_mut().zip(&timings) {
                p.nanos += nanos;
                p.calls += 1;
            }
        }

        outs.reserve(plan.outputs.len());
        for (src, vi) in plan.outputs.iter().zip(&g.outputs) {
            let t = match *src {
                Src::Slot(s) => arena.store[s as usize].take(),
                Src::SlotOrInit { slot, init } => arena.store[slot as usize]
                    .take()
                    .or_else(|| Some(inits[init as usize].1.clone())),
                Src::Feed { slot } => arena.store[slot as usize]
                    .take()
                    .or_else(|| plan::feed_by_name(feeds, &names[slot as usize]).cloned()),
                Src::FeedOrInit { slot, init } => arena.store[slot as usize]
                    .take()
                    .or_else(|| plan::feed_by_name(feeds, &names[slot as usize]).cloned())
                    .or_else(|| Some(inits[init as usize].1.clone())),
                Src::Init(i) => Some(inits[i as usize].1.clone()),
                Src::None => None,
            };
            outs.push(t.ok_or_else(|| SessionError::ValueMissing(vi.name.clone()))?);
        }
        Ok(())
    }

    /// The pre-plan string-keyed interpreter: `HashMap<String, Tensor>`
    /// value store, per-node attribute re-parsing via
    /// [`crate::ops::execute_node`], per-feed clones. Retained as the
    /// differential-test oracle for the compiled plan and the legacy
    /// baseline in `bench_serving`; always strictly serial. Unlike the
    /// old interpreter it does NOT feed the profiler — profiling is a
    /// planned-path (step-indexed) feature.
    pub fn run_unplanned(&self, feeds: &[(&str, Tensor)]) -> Result<Vec<Tensor>, SessionError> {
        let mut noop = |_: &str, _: &Tensor| {};
        parallel::serial_scope(|| self.run_unplanned_observed(feeds, &mut noop))
    }

    /// Observer form of [`Session::run_unplanned`] (used to check the
    /// calibration observer stream against the planned executor).
    pub fn run_unplanned_observed(
        &self,
        feeds: &[(&str, Tensor)],
        observer: &mut dyn FnMut(&str, &Tensor),
    ) -> Result<Vec<Tensor>, SessionError> {
        let g = &self.model.graph;
        let refs: Vec<(&str, &Tensor)> = feeds.iter().map(|(n, t)| (*n, t)).collect();
        self.validate_feeds(&refs)?;
        let twin = self.twin_plan()?;

        let mut values: HashMap<String, Tensor> = HashMap::with_capacity(feeds.len() + 16);
        for (name, t) in feeds {
            observer(name, t);
            values.insert(name.to_string(), t.clone());
        }

        for (pos, step) in twin.unfused.steps.iter().enumerate() {
            let node = &g.nodes[step.node_idx];
            let inputs: Vec<Option<&Tensor>> = node
                .inputs
                .iter()
                .map(|n| {
                    if n.is_empty() {
                        None
                    } else {
                        values.get(n.as_str()).or_else(|| g.initializer(n))
                    }
                })
                .collect();
            let outs = execute_node(node, &inputs).map_err(|source| SessionError::Op {
                node: node.name.clone(),
                source,
            })?;
            for (name, t) in node.outputs.iter().zip(outs) {
                if !name.is_empty() {
                    observer(name, &t);
                    values.insert(name.clone(), t);
                }
            }
            for dead in &twin.unplanned_frees[pos] {
                values.remove(dead);
            }
        }

        g.outputs
            .iter()
            .map(|vi| {
                values
                    .remove(&vi.name)
                    .or_else(|| g.initializer(&vi.name).cloned())
                    .ok_or_else(|| SessionError::ValueMissing(vi.name.clone()))
            })
            .collect()
    }

    /// Convenience: single-input single-output execution.
    pub fn run1(&self, input: Tensor) -> Result<Tensor, SessionError> {
        let inputs = self.model.graph.runtime_inputs();
        let name = inputs
            .first()
            .map(|vi| vi.name.clone())
            .ok_or_else(|| SessionError::MissingFeed("<none declared>".into()))?;
        let mut out = self.run(&[(&name, input)])?;
        Ok(out.remove(0))
    }

    /// Snapshot of per-node timings (profiling sessions only), sorted by
    /// total time descending. Stats are kept per plan step; the node name
    /// and op type are resolved here for the report.
    pub fn profile(&self) -> Vec<NodeStats> {
        // No twin means no profiled run ever executed (profiling runs
        // force it) — nothing to report, and nothing worth compiling.
        let twin = match self.twin.slot.lock().unwrap().as_ref() {
            Some(t) => t.clone(),
            None => return Vec::new(),
        };
        let prof = self.profile.lock().unwrap();
        let mut v: Vec<NodeStats> = twin
            .unfused
            .steps
            .iter()
            .zip(prof.iter())
            .filter(|(_, p)| p.calls > 0)
            .map(|(step, p)| {
                let node = &self.model.graph.nodes[step.node_idx];
                NodeStats {
                    name: node.name.clone(),
                    op_type: node.op_type.clone(),
                    nanos: p.nanos,
                    calls: p.calls,
                }
            })
            .collect();
        v.sort_by_key(|s| std::cmp::Reverse(s.nanos));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::ir::Attr;
    use crate::onnx::{batched, GraphBuilder};
    use crate::tensor::DType;

    /// The paper's Figure 1 pattern, hand-built: MatMulInteger -> Add ->
    /// Cast -> Mul(Quant_scale) -> Mul(Quant_shift) -> QuantizeLinear.
    fn fig1_model() -> Model {
        let mut b = GraphBuilder::new("fig1");
        b.input("x", DType::I8, &batched(&[4]));
        b.init("w", Tensor::from_i8(&[4, 2], vec![1, 2, 3, 4, 5, 6, 7, 8]).unwrap());
        b.init("bias", Tensor::from_i32(&[2], vec![100, -100]).unwrap());
        b.init("quant_scale", Tensor::scalar_f32(1.0));
        b.init("quant_shift", Tensor::scalar_f32(1.0 / 4.0)); // >>2
        b.init("q_one", Tensor::scalar_f32(1.0));
        b.init("q_zp", Tensor::scalar_i8(0));
        let acc = b.node("MatMulInteger", &["x", "w"], &[]);
        let accb = b.node("Add", &[&acc, "bias"], &[]);
        let f = b.node("Cast", &[&accb], &[("to", Attr::Str("FLOAT".into()))]);
        let m1 = b.node("Mul", &[&f, "quant_scale"], &[]);
        let m2 = b.node("Mul", &[&m1, "quant_shift"], &[]);
        let y = b.node("QuantizeLinear", &[&m2, "q_one", "q_zp"], &[]);
        b.output(&y, DType::I8, &batched(&[2]));
        b.finish_model()
    }

    #[test]
    fn fig1_end_to_end() {
        let sess = Session::new(fig1_model()).unwrap();
        let x = Tensor::from_i8(&[1, 4], vec![1, 1, 1, 1]).unwrap();
        let y = sess.run(&[("x", x)]).unwrap();
        // acc = [1+3+5+7, 2+4+6+8] = [16, 20]; +bias = [116, -80];
        // * 1.0 * 0.25 = [29, -20]; quantize(scale 1) = [29, -20].
        assert_eq!(y[0].as_i8().unwrap(), &[29, -20]);
    }

    #[test]
    fn batch_via_symbolic_dim() {
        let sess = Session::new(fig1_model()).unwrap();
        let x = Tensor::from_i8(&[3, 4], vec![1; 12]).unwrap();
        let y = sess.run(&[("x", x)]).unwrap();
        assert_eq!(y[0].shape(), &[3, 2]);
        assert_eq!(y[0].as_i8().unwrap(), &[29, -20, 29, -20, 29, -20]);
    }

    #[test]
    fn feed_validation() {
        let sess = Session::new(fig1_model()).unwrap();
        // wrong dtype
        let bad = Tensor::from_f32(&[1, 4], vec![0.0; 4]).unwrap();
        assert!(matches!(
            sess.run(&[("x", bad)]),
            Err(SessionError::FeedDType { .. })
        ));
        // wrong fixed dim
        let bad = Tensor::from_i8(&[1, 5], vec![0; 5]).unwrap();
        assert!(matches!(
            sess.run(&[("x", bad)]),
            Err(SessionError::FeedShape { .. })
        ));
        // missing feed
        assert!(matches!(
            sess.run(&[]),
            Err(SessionError::MissingFeed(_))
        ));
        // unknown feed
        let x = Tensor::from_i8(&[1, 4], vec![0; 4]).unwrap();
        assert!(matches!(
            sess.run(&[("nope", x)]),
            Err(SessionError::UnknownFeed(_))
        ));
    }

    #[test]
    fn parallel_run_bit_exact_vs_serial() {
        let sess = Session::new(fig1_model()).unwrap();
        assert!(sess.batch_parallelizable());
        let pool = crate::parallel::ThreadPool::new(3);
        for batch in [1usize, 2, 5, 8, 17] {
            let data: Vec<i8> = (0..batch * 4).map(|i| (i * 37 % 251) as u8 as i8).collect();
            let x = Tensor::from_i8(&[batch, 4], data).unwrap();
            let serial = sess.run_serial(&[("x", x.clone())]).unwrap();
            let par = sess.run_on(&[("x", x.clone())], &pool).unwrap();
            assert_eq!(serial, par, "batch {batch}");
            let auto = sess.run(&[("x", x)]).unwrap();
            assert_eq!(serial, auto, "batch {batch} (auto)");
        }
    }

    #[test]
    fn fixed_batch_model_not_parallelizable() {
        use crate::onnx::fixed_dims;
        let mut b = GraphBuilder::new("fixed");
        b.input("x", DType::I8, &fixed_dims(&[2, 4]));
        b.init("w", Tensor::from_i8(&[4, 2], vec![1; 8]).unwrap());
        let y = b.node("MatMulInteger", &["x", "w"], &[]);
        b.output(&y, DType::I32, &fixed_dims(&[2, 2]));
        let sess = Session::new(b.finish_model()).unwrap();
        assert!(!sess.batch_parallelizable());
        // Still runs fine through the (serial) path.
        let x = Tensor::from_i8(&[2, 4], vec![1; 8]).unwrap();
        sess.run(&[("x", x)]).unwrap();
    }

    #[test]
    fn profiling_collects() {
        let sess = Session::new(fig1_model()).unwrap().with_profiling();
        let x = Tensor::from_i8(&[1, 4], vec![1; 4]).unwrap();
        sess.run(&[("x", x.clone())]).unwrap();
        sess.run(&[("x", x)]).unwrap();
        let prof = sess.profile();
        assert!(!prof.is_empty());
        assert!(prof.iter().any(|s| s.op_type == "MatMulInteger"));
        // Step-indexed stats: every executed step counted both runs.
        assert!(prof.iter().all(|s| s.calls == 2));
    }

    #[test]
    fn planned_matches_unplanned() {
        let sess = Session::new(fig1_model()).unwrap();
        for batch in [1usize, 2, 7] {
            let data: Vec<i8> = (0..batch * 4).map(|i| (i * 91 % 253) as u8 as i8).collect();
            let x = Tensor::from_i8(&[batch, 4], data).unwrap();
            let legacy = sess.run_unplanned(&[("x", x.clone())]).unwrap();
            let planned = sess.run_serial(&[("x", x)]).unwrap();
            assert_eq!(legacy, planned, "batch {batch}");
        }
    }

    #[test]
    fn run_refs_avoids_feed_clone_and_matches() {
        let sess = Session::new(fig1_model()).unwrap();
        let x = Tensor::from_i8(&[2, 4], vec![3; 8]).unwrap();
        let owned = sess.run(&[("x", x.clone())]).unwrap();
        let by_ref = sess.run_refs(&[("x", &x)]).unwrap();
        assert_eq!(owned, by_ref);
    }

    #[test]
    fn run_into_recycles_outputs_and_stays_bit_identical() {
        let sess = Session::new(fig1_model()).unwrap().with_parallelism(false);
        let mut outs = Vec::new();
        for round in 0..4u8 {
            let data: Vec<i8> = (0..3 * 4).map(|i| (i as i8) - 6 + round as i8).collect();
            let x = Tensor::from_i8(&[3, 4], data.clone()).unwrap();
            // Recycled-path run (outs from the previous round feed the
            // arena) vs a fresh legacy run: identical bits every round.
            sess.run_into(&[("x", &x)], &mut outs).unwrap();
            let legacy = sess.run_unplanned(&[("x", x)]).unwrap();
            assert_eq!(outs, legacy, "round {round}");
        }
        // Changing the batch size mid-stream re-sizes buffers correctly.
        let x = Tensor::from_i8(&[7, 4], vec![2; 28]).unwrap();
        sess.run_into(&[("x", &x)], &mut outs).unwrap();
        let legacy = sess.run_unplanned(&[("x", x)]).unwrap();
        assert_eq!(outs, legacy, "after batch change");
    }

    #[test]
    fn fork_replica_shares_plan_and_matches_bit_for_bit() {
        let sess = Session::new(fig1_model()).unwrap();
        let replica = sess.fork_replica();
        // The plan and model are shared, not recompiled.
        assert!(Arc::ptr_eq(&sess.plan, &replica.plan));
        assert!(Arc::ptr_eq(&sess.model, &replica.model));
        for batch in [1usize, 3, 8] {
            let data: Vec<i8> = (0..batch * 4).map(|i| (i * 53 % 251) as u8 as i8).collect();
            let x = Tensor::from_i8(&[batch, 4], data).unwrap();
            let a = sess.run(&[("x", x.clone())]).unwrap();
            let b = replica.run(&[("x", x)]).unwrap();
            assert_eq!(a, b, "batch {batch}");
        }
        // Replicas of replicas still share the original plan.
        let grand = replica.fork_replica();
        assert!(Arc::ptr_eq(&sess.plan, &grand.plan));
        // Concurrent replicas hammer their own arena pools.
        let parent = Arc::new(sess);
        let mut joins = Vec::new();
        for t in 0..3u8 {
            let rep = parent.fork_replica();
            let parent = parent.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..15u8 {
                    let v = (t.wrapping_mul(17).wrapping_add(i)) as i8;
                    let x = Tensor::from_i8(&[2, 4], vec![v; 8]).unwrap();
                    let got = rep.run(&[("x", x.clone())]).unwrap();
                    let want = parent.run_unplanned(&[("x", x)]).unwrap();
                    assert_eq!(got, want);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn concurrent_runs_use_independent_arenas() {
        // Two threads hammer the same session; arenas are checked out per
        // run so results must stay independent and correct.
        let sess = std::sync::Arc::new(Session::new(fig1_model()).unwrap());
        let mut joins = Vec::new();
        for t in 0..4u8 {
            let sess = sess.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..20u8 {
                    let v = (t.wrapping_mul(31).wrapping_add(i)) as i8;
                    let x = Tensor::from_i8(&[2, 4], vec![v; 8]).unwrap();
                    let got = sess.run(&[("x", x.clone())]).unwrap();
                    let want = sess.run_unplanned(&[("x", x)]).unwrap();
                    assert_eq!(got, want);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }
}
