//! Dtyped dense tensors — the numeric substrate for the whole stack.
//!
//! Tensors are row-major contiguous. The dtype set is exactly what the
//! paper's patterns require: `f32` (rescale path), `f16` (Fig. 5/6
//! activation path), `i8`/`u8` (quantized tensors), `i32` (accumulators
//! and biases), plus `i64`/`bool` for shape-carrying ONNX operators.

pub mod f16;

pub use f16::F16;

use thiserror::Error;

/// Element type of a [`Tensor`]. Mirrors the ONNX `TensorProto.DataType`
/// subset the paper's patterns use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    I8,
    U8,
    I32,
    I64,
    Bool,
}

impl DType {
    /// Size of one element in bytes (used by the hwsim memory-traffic
    /// model and the PJRT literal conversion).
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 => 2,
            DType::I8 | DType::U8 | DType::Bool => 1,
            DType::I64 => 8,
        }
    }

    /// ONNX textual name, used in the model serialization.
    pub fn onnx_name(self) -> &'static str {
        match self {
            DType::F32 => "FLOAT",
            DType::F16 => "FLOAT16",
            DType::I8 => "INT8",
            DType::U8 => "UINT8",
            DType::I32 => "INT32",
            DType::I64 => "INT64",
            DType::Bool => "BOOL",
        }
    }

    /// Parse the ONNX textual name.
    pub fn from_onnx_name(s: &str) -> Option<DType> {
        Some(match s {
            "FLOAT" => DType::F32,
            "FLOAT16" => DType::F16,
            "INT8" => DType::I8,
            "UINT8" => DType::U8,
            "INT32" => DType::I32,
            "INT64" => DType::I64,
            "BOOL" => DType::Bool,
            _ => return None,
        })
    }

    pub fn is_quantized_int(self) -> bool {
        matches!(self, DType::I8 | DType::U8)
    }

    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F16)
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.onnx_name())
    }
}

/// Ranks up to this many dims are stored inline in [`Shape`] — every
/// shape the paper's patterns produce (NCHW is rank 4; Reshape specs in
/// the admitted models never exceed this). Higher ranks fall back to a
/// heap vector, trading the zero-allocation guarantee for generality.
pub const SHAPE_INLINE: usize = 6;

/// A tensor shape with inline storage for small ranks, so constructing,
/// cloning, and extending shapes on the execution hot path allocates
/// nothing (see EXPERIMENTS.md §Perf — shape `Vec`s were one of the
/// per-node steady-state allocations the scratch planner eliminates).
///
/// Dereferences to `&[usize]`, so all slice-based call sites keep
/// working unchanged.
#[derive(Clone, Debug)]
pub enum Shape {
    Inline { len: u8, dims: [usize; SHAPE_INLINE] },
    Heap(Vec<usize>),
}

impl Shape {
    /// Rank-0 shape (scalars).
    pub fn empty() -> Shape {
        Shape::Inline {
            len: 0,
            dims: [0; SHAPE_INLINE],
        }
    }

    /// Copy a dim slice (inline when rank permits — no allocation).
    pub fn from_slice(s: &[usize]) -> Shape {
        if s.len() <= SHAPE_INLINE {
            let mut dims = [0usize; SHAPE_INLINE];
            dims[..s.len()].copy_from_slice(s);
            Shape::Inline {
                len: s.len() as u8,
                dims,
            }
        } else {
            Shape::Heap(s.to_vec())
        }
    }

    /// Append a trailing dim (promotes to heap storage past
    /// [`SHAPE_INLINE`]).
    pub fn push(&mut self, d: usize) {
        match self {
            Shape::Inline { len, dims } => {
                if (*len as usize) < SHAPE_INLINE {
                    dims[*len as usize] = d;
                    *len += 1;
                } else {
                    let mut v = dims.to_vec();
                    v.push(d);
                    *self = Shape::Heap(v);
                }
            }
            Shape::Heap(v) => v.push(d),
        }
    }

    pub fn as_slice(&self) -> &[usize] {
        match self {
            Shape::Inline { len, dims } => &dims[..*len as usize],
            Shape::Heap(v) => v,
        }
    }

    pub fn as_mut_slice(&mut self) -> &mut [usize] {
        match self {
            Shape::Inline { len, dims } => &mut dims[..*len as usize],
            Shape::Heap(v) => v,
        }
    }

    /// Total element count implied by the shape.
    pub fn numel(&self) -> usize {
        self.as_slice().iter().product()
    }
}

impl std::ops::Deref for Shape {
    type Target = [usize];
    fn deref(&self) -> &[usize] {
        self.as_slice()
    }
}

impl PartialEq for Shape {
    fn eq(&self, other: &Shape) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl From<&[usize]> for Shape {
    fn from(s: &[usize]) -> Shape {
        Shape::from_slice(s)
    }
}

impl From<Vec<usize>> for Shape {
    fn from(v: Vec<usize>) -> Shape {
        if v.len() <= SHAPE_INLINE {
            Shape::from_slice(&v)
        } else {
            Shape::Heap(v)
        }
    }
}

impl<const N: usize> From<[usize; N]> for Shape {
    fn from(s: [usize; N]) -> Shape {
        Shape::from_slice(&s)
    }
}

/// Typed storage behind a [`Tensor`].
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    F16(Vec<F16>),
    I8(Vec<i8>),
    U8(Vec<u8>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    Bool(Vec<bool>),
}

impl TensorData {
    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::F16(_) => DType::F16,
            TensorData::I8(_) => DType::I8,
            TensorData::U8(_) => DType::U8,
            TensorData::I32(_) => DType::I32,
            TensorData::I64(_) => DType::I64,
            TensorData::Bool(_) => DType::Bool,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::F16(v) => v.len(),
            TensorData::I8(v) => v.len(),
            TensorData::U8(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::I64(v) => v.len(),
            TensorData::Bool(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Errors raised by tensor construction and access.
#[derive(Error, Debug)]
pub enum TensorError {
    #[error("shape {shape:?} implies {expected} elements but data has {got}")]
    ShapeMismatch {
        shape: Vec<usize>,
        expected: usize,
        got: usize,
    },
    #[error("dtype mismatch: expected {expected}, got {got}")]
    DTypeMismatch { expected: DType, got: DType },
    #[error("cannot reshape {numel} elements to shape {shape:?}")]
    BadReshape { numel: usize, shape: Vec<usize> },
    #[error("incompatible shapes for broadcast: {a:?} vs {b:?}")]
    BroadcastMismatch { a: Vec<usize>, b: Vec<usize> },
    #[error("cannot concatenate along axis 0: {a:?}/{a_dtype} vs {b:?}/{b_dtype}")]
    ConcatMismatch {
        a: Vec<usize>,
        a_dtype: DType,
        b: Vec<usize>,
        b_dtype: DType,
    },
    #[error("row slice [{off}, {off}+{len}) out of batch {batch}")]
    RowSliceOutOfRange { off: usize, len: usize, batch: usize },
}

/// A dense row-major tensor: shape + typed storage.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Shape,
    data: TensorData,
}

impl Tensor {
    /// Construct from shape + typed data, validating element count.
    pub fn new(shape: impl Into<Shape>, data: TensorData) -> Result<Tensor, TensorError> {
        let shape = shape.into();
        let expected = shape.numel();
        if expected != data.len() {
            return Err(TensorError::ShapeMismatch {
                shape: shape.to_vec(),
                expected,
                got: data.len(),
            });
        }
        Ok(Tensor { shape, data })
    }

    pub fn from_f32(shape: &[usize], v: Vec<f32>) -> Result<Tensor, TensorError> {
        Tensor::new(Shape::from_slice(shape), TensorData::F32(v))
    }
    pub fn from_f16(shape: &[usize], v: Vec<F16>) -> Result<Tensor, TensorError> {
        Tensor::new(Shape::from_slice(shape), TensorData::F16(v))
    }
    pub fn from_i8(shape: &[usize], v: Vec<i8>) -> Result<Tensor, TensorError> {
        Tensor::new(Shape::from_slice(shape), TensorData::I8(v))
    }
    pub fn from_u8(shape: &[usize], v: Vec<u8>) -> Result<Tensor, TensorError> {
        Tensor::new(Shape::from_slice(shape), TensorData::U8(v))
    }
    pub fn from_i32(shape: &[usize], v: Vec<i32>) -> Result<Tensor, TensorError> {
        Tensor::new(Shape::from_slice(shape), TensorData::I32(v))
    }
    pub fn from_i64(shape: &[usize], v: Vec<i64>) -> Result<Tensor, TensorError> {
        Tensor::new(Shape::from_slice(shape), TensorData::I64(v))
    }

    /// Rank-0 f32 scalar (ONNX scalar initializers such as `Quant_scale`).
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor {
            shape: Shape::empty(),
            data: TensorData::F32(vec![v]),
        }
    }
    /// Rank-0 i8 scalar (e.g. QuantizeLinear `zero_point`).
    pub fn scalar_i8(v: i8) -> Tensor {
        Tensor {
            shape: Shape::empty(),
            data: TensorData::I8(vec![v]),
        }
    }
    /// Rank-0 u8 scalar.
    pub fn scalar_u8(v: u8) -> Tensor {
        Tensor {
            shape: Shape::empty(),
            data: TensorData::U8(vec![v]),
        }
    }

    /// All-zeros tensor of the given dtype/shape.
    pub fn zeros(dtype: DType, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        let data = match dtype {
            DType::F32 => TensorData::F32(vec![0.0; n]),
            DType::F16 => TensorData::F16(vec![F16::ZERO; n]),
            DType::I8 => TensorData::I8(vec![0; n]),
            DType::U8 => TensorData::U8(vec![0; n]),
            DType::I32 => TensorData::I32(vec![0; n]),
            DType::I64 => TensorData::I64(vec![0; n]),
            DType::Bool => TensorData::Bool(vec![false; n]),
        };
        Tensor {
            shape: Shape::from_slice(shape),
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        self.shape.as_slice()
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn data(&self) -> &TensorData {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut TensorData {
        &mut self.data
    }

    /// Consume the tensor, yielding its typed storage (the entry point of
    /// the buffer-recycling helpers below).
    pub fn into_data(self) -> TensorData {
        self.data
    }

    /// Bytes of payload (hwsim memory-traffic model).
    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype().size_bytes()
    }

    /// Reshape in place to a compatible shape.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor, TensorError> {
        let n: usize = shape.iter().product();
        if n != self.numel() {
            return Err(TensorError::BadReshape {
                numel: self.numel(),
                shape: shape.to_vec(),
            });
        }
        self.shape = Shape::from_slice(shape);
        Ok(self)
    }

    // --- typed slice accessors -------------------------------------------

    pub fn as_f32(&self) -> Result<&[f32], TensorError> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::F32,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_f16(&self) -> Result<&[F16], TensorError> {
        match &self.data {
            TensorData::F16(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::F16,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_i8(&self) -> Result<&[i8], TensorError> {
        match &self.data {
            TensorData::I8(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::I8,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_u8(&self) -> Result<&[u8], TensorError> {
        match &self.data {
            TensorData::U8(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::U8,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32], TensorError> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::I32,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_i64(&self) -> Result<&[i64], TensorError> {
        match &self.data {
            TensorData::I64(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::I64,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_bool(&self) -> Result<&[bool], TensorError> {
        match &self.data {
            TensorData::Bool(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::Bool,
                got: d.dtype(),
            }),
        }
    }

    /// Read the quantized integer values widened to i32, regardless of
    /// whether storage is i8 or u8 (the paper's patterns allow either for
    /// layer inputs).
    pub fn as_quantized_i32(&self) -> Result<Vec<i32>, TensorError> {
        match &self.data {
            TensorData::I8(v) => Ok(v.iter().map(|&x| x as i32).collect()),
            TensorData::U8(v) => Ok(v.iter().map(|&x| x as i32).collect()),
            TensorData::I32(v) => Ok(v.clone()),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::I8,
                got: d.dtype(),
            }),
        }
    }

    /// First element of an i8/u8/i32 tensor widened to i32, without the
    /// intermediate `Vec` of [`Tensor::as_quantized_i32`] — the zero-point
    /// read on the QuantizeLinear/DequantizeLinear hot path.
    pub fn quantized_scalar_i32(&self) -> Result<i32, TensorError> {
        match &self.data {
            TensorData::I8(v) => Ok(v[0] as i32),
            TensorData::U8(v) => Ok(v[0] as i32),
            TensorData::I32(v) => Ok(v[0]),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::I8,
                got: d.dtype(),
            }),
        }
    }

    /// Convert every element to f32 (lossless for all our dtypes).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match &self.data {
            TensorData::F32(v) => v.clone(),
            TensorData::F16(v) => v.iter().map(|x| x.to_f32()).collect(),
            TensorData::I8(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::U8(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::I32(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::I64(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::Bool(v) => v.iter().map(|&x| x as u8 as f32).collect(),
        }
    }

    /// Elements per row when axis 0 is treated as the batch axis (1 for
    /// rank-0 tensors).
    pub fn row_elems(&self) -> usize {
        self.shape.get(1..).map_or(1, |s| s.iter().product())
    }

    /// Rows `[off, off + len)` along axis 0 as a new contiguous tensor.
    /// The batch-parallel executors use this to split work; slicing then
    /// [`Tensor::concat_rows`] is the identity.
    pub fn slice_rows(&self, off: usize, len: usize) -> Result<Tensor, TensorError> {
        let Some(&batch) = self.shape.first() else {
            return Err(TensorError::RowSliceOutOfRange { off, len, batch: 0 });
        };
        if off + len > batch {
            return Err(TensorError::RowSliceOutOfRange { off, len, batch });
        }
        let re = self.row_elems();
        let (a, b) = (off * re, (off + len) * re);
        let data = match &self.data {
            TensorData::F32(v) => TensorData::F32(v[a..b].to_vec()),
            TensorData::F16(v) => TensorData::F16(v[a..b].to_vec()),
            TensorData::I8(v) => TensorData::I8(v[a..b].to_vec()),
            TensorData::U8(v) => TensorData::U8(v[a..b].to_vec()),
            TensorData::I32(v) => TensorData::I32(v[a..b].to_vec()),
            TensorData::I64(v) => TensorData::I64(v[a..b].to_vec()),
            TensorData::Bool(v) => TensorData::Bool(v[a..b].to_vec()),
        };
        let mut shape = self.shape.clone();
        shape.as_mut_slice()[0] = len;
        Ok(Tensor { shape, data })
    }

    /// Concatenate tensors along axis 0. Every part must be rank >= 1 and
    /// share dtype and row shape.
    pub fn concat_rows(parts: &[Tensor]) -> Result<Tensor, TensorError> {
        let refs: Vec<&Tensor> = parts.iter().collect();
        Tensor::concat_rows_refs(&refs)
    }

    /// [`Tensor::concat_rows`] over borrowed parts — concatenation only
    /// reads, so callers that merely hold references (the serving layer
    /// fusing queued request tensors) need not clone a single input. The
    /// only allocation is the fused output buffer.
    pub fn concat_rows_refs(parts: &[&Tensor]) -> Result<Tensor, TensorError> {
        let first = *parts.first().ok_or(TensorError::RowSliceOutOfRange {
            off: 0,
            len: 0,
            batch: 0,
        })?;
        if first.shape().is_empty() {
            return Err(TensorError::ConcatMismatch {
                a: Vec::new(),
                a_dtype: first.dtype(),
                b: Vec::new(),
                b_dtype: first.dtype(),
            });
        }
        let row_shape = &first.shape()[1..];
        let dtype = first.dtype();
        let mut total = 0usize;
        for t in parts {
            if t.shape().get(1..) != Some(row_shape) || t.dtype() != dtype {
                return Err(TensorError::ConcatMismatch {
                    a: first.shape().to_vec(),
                    a_dtype: dtype,
                    b: t.shape().to_vec(),
                    b_dtype: t.dtype(),
                });
            }
            total += t.shape()[0];
        }
        let mut shape = Shape::empty();
        shape.push(total);
        for &d in row_shape {
            shape.push(d);
        }

        macro_rules! concat_as {
            ($variant:ident, $ty:ty) => {{
                let mut out: Vec<$ty> =
                    Vec::with_capacity(total * row_shape.iter().product::<usize>());
                for t in parts {
                    match t.data() {
                        TensorData::$variant(v) => out.extend_from_slice(v),
                        _ => unreachable!("dtype checked above"),
                    }
                }
                TensorData::$variant(out)
            }};
        }
        let data = match dtype {
            DType::F32 => concat_as!(F32, f32),
            DType::F16 => concat_as!(F16, F16),
            DType::I8 => concat_as!(I8, i8),
            DType::U8 => concat_as!(U8, u8),
            DType::I32 => concat_as!(I32, i32),
            DType::I64 => concat_as!(I64, i64),
            DType::Bool => concat_as!(Bool, bool),
        };
        Tensor::new(shape, data)
    }

    /// ONNX `Cast` semantics: float->int truncates toward zero, float->f16
    /// rounds to nearest-even, int widenings are exact. Saturation is NOT
    /// applied (ONNX Cast wraps/UBs on overflow; the paper's patterns only
    /// cast i32->f32 and f32<->f16 where this cannot occur).
    pub fn cast(&self, to: DType) -> Tensor {
        self.cast_recycled(to, None)
    }

    /// [`Tensor::cast`] writing into recycled storage: identical values
    /// element for element, the output buffer just comes from `recycled`
    /// when its dtype matches and its capacity suffices (the scratch
    /// planner's steady state). Also replaces the `to_f32_vec`
    /// intermediate of the old cast with direct per-source loops, so the
    /// hot i32->f32 cast after every integer accumulate allocates nothing.
    pub fn cast_recycled(&self, to: DType, recycled: Option<Tensor>) -> Tensor {
        if to == self.dtype() {
            return self.clone_recycled(recycled);
        }
        let n = self.numel();
        let data = match to {
            DType::F32 => {
                let mut o = recycled_f32(recycled, n);
                map_to_f32(&self.data, &mut o, |x| x);
                TensorData::F32(o)
            }
            DType::F16 => {
                let mut o = recycled_f16(recycled, n);
                map_to_f32(&self.data, &mut o, F16::from_f32);
                TensorData::F16(o)
            }
            DType::I8 => {
                let mut o = recycled_i8(recycled, n);
                match &self.data {
                    TensorData::U8(v) => o.extend(v.iter().map(|&x| x as i8)),
                    TensorData::I32(v) => o.extend(v.iter().map(|&x| x as i8)),
                    TensorData::I64(v) => o.extend(v.iter().map(|&x| x as i8)),
                    d => map_to_f32(d, &mut o, |x| x as i8),
                }
                TensorData::I8(o)
            }
            DType::U8 => {
                let mut o = recycled_u8(recycled, n);
                match &self.data {
                    TensorData::I8(v) => o.extend(v.iter().map(|&x| x as u8)),
                    TensorData::I32(v) => o.extend(v.iter().map(|&x| x as u8)),
                    TensorData::I64(v) => o.extend(v.iter().map(|&x| x as u8)),
                    d => map_to_f32(d, &mut o, |x| x as u8),
                }
                TensorData::U8(o)
            }
            DType::I32 => {
                let mut o = recycled_i32(recycled, n);
                match &self.data {
                    TensorData::I8(v) => o.extend(v.iter().map(|&x| x as i32)),
                    TensorData::U8(v) => o.extend(v.iter().map(|&x| x as i32)),
                    TensorData::I64(v) => o.extend(v.iter().map(|&x| x as i32)),
                    d => map_to_f32(d, &mut o, |x| x as i32),
                }
                TensorData::I32(o)
            }
            DType::I64 => {
                let mut o = recycled_i64(recycled, n);
                match &self.data {
                    TensorData::I8(v) => o.extend(v.iter().map(|&x| x as i64)),
                    TensorData::U8(v) => o.extend(v.iter().map(|&x| x as i64)),
                    TensorData::I32(v) => o.extend(v.iter().map(|&x| x as i64)),
                    d => map_to_f32(d, &mut o, |x| x as i64),
                }
                TensorData::I64(o)
            }
            DType::Bool => {
                let mut o = recycled_bool(recycled, n);
                map_to_f32(&self.data, &mut o, |x| x != 0.0);
                TensorData::Bool(o)
            }
        };
        debug_assert_eq!(data.len(), n);
        Tensor {
            shape: self.shape.clone(),
            data,
        }
    }

    /// Bitwise copy of this tensor into recycled storage (the Identity /
    /// Reshape / Flatten path of the scratch planner): same values and
    /// shape as `self.clone()`, zero allocations once `recycled` carries a
    /// matching-dtype buffer of sufficient capacity.
    pub fn clone_recycled(&self, recycled: Option<Tensor>) -> Tensor {
        let n = self.numel();
        macro_rules! copy_into {
            ($variant:ident, $recycle:ident, $v:expr) => {{
                let mut o = $recycle(recycled, n);
                o.extend_from_slice($v);
                TensorData::$variant(o)
            }};
        }
        let data = match &self.data {
            TensorData::F32(v) => copy_into!(F32, recycled_f32, v),
            TensorData::F16(v) => copy_into!(F16, recycled_f16, v),
            TensorData::I8(v) => copy_into!(I8, recycled_i8, v),
            TensorData::U8(v) => copy_into!(U8, recycled_u8, v),
            TensorData::I32(v) => copy_into!(I32, recycled_i32, v),
            TensorData::I64(v) => copy_into!(I64, recycled_i64, v),
            TensorData::Bool(v) => copy_into!(Bool, recycled_bool, v),
        };
        Tensor {
            shape: self.shape.clone(),
            data,
        }
    }
}

/// Map every element of `src` to f32 and feed it through `f` into `out`
/// — the per-source conversions are exactly [`Tensor::to_f32_vec`]'s,
/// minus its intermediate allocation.
fn map_to_f32<T>(src: &TensorData, out: &mut Vec<T>, f: impl Fn(f32) -> T) {
    match src {
        TensorData::F32(v) => out.extend(v.iter().map(|&x| f(x))),
        TensorData::F16(v) => out.extend(v.iter().map(|x| f(x.to_f32()))),
        TensorData::I8(v) => out.extend(v.iter().map(|&x| f(x as f32))),
        TensorData::U8(v) => out.extend(v.iter().map(|&x| f(x as f32))),
        TensorData::I32(v) => out.extend(v.iter().map(|&x| f(x as f32))),
        TensorData::I64(v) => out.extend(v.iter().map(|&x| f(x as f32))),
        TensorData::Bool(v) => out.extend(v.iter().map(|&x| f(x as u8 as f32))),
    }
}

// --- recycled-storage helpers ---------------------------------------------
//
// Each takes the storage of a retired tensor (from the execution plan's
// ScratchArena or a caller handing back last run's outputs) and returns an
// EMPTY Vec of the requested element type with that buffer's capacity when
// the dtype matches — so `extend`/`resize` up to the previous length
// performs no heap allocation. On a dtype mismatch (or no recycled tensor)
// a fresh Vec with `cap` reserved is returned; that happens once per
// (slot, shape) and is the "first request warms the arena" cost.

macro_rules! recycled_fn {
    ($name:ident, $variant:ident, $ty:ty) => {
        /// See the module note on recycled-storage helpers.
        pub fn $name(src: Option<Tensor>, cap: usize) -> Vec<$ty> {
            match src.map(Tensor::into_data) {
                Some(TensorData::$variant(mut v)) => {
                    v.clear();
                    v.reserve(cap);
                    v
                }
                _ => Vec::with_capacity(cap),
            }
        }
    };
}

recycled_fn!(recycled_f32, F32, f32);
recycled_fn!(recycled_f16, F16, F16);
recycled_fn!(recycled_i8, I8, i8);
recycled_fn!(recycled_u8, U8, u8);
recycled_fn!(recycled_i32, I32, i32);
recycled_fn!(recycled_i64, I64, i64);
recycled_fn!(recycled_bool, Bool, bool);

/// [`recycled_i32`] pre-sized to `n` zeros — the GEMM output form (the
/// kernels overwrite every element, the zeroing just keeps the buffer
/// initialized for the remainder paths).
pub fn recycled_i32_zeroed(src: Option<Tensor>, n: usize) -> Vec<i32> {
    let mut v = recycled_i32(src, n);
    v.resize(n, 0);
    v
}

/// [`recycled_f32`] pre-sized to `n` zeros.
pub fn recycled_f32_zeroed(src: Option<Tensor>, n: usize) -> Vec<f32> {
    let mut v = recycled_f32(src, n);
    v.resize(n, 0.0);
    v
}

/// [`recycled_i8`] pre-sized to `n` zeros (the i8 im2col scratch form).
pub fn recycled_i8_zeroed(src: Option<Tensor>, n: usize) -> Vec<i8> {
    let mut v = recycled_i8(src, n);
    v.resize(n, 0);
    v
}

/// Compute the broadcast result shape per ONNX/NumPy multidirectional
/// broadcasting rules, as an (inline, allocation-free for rank <=
/// [`SHAPE_INLINE`]) [`Shape`] — the form the elementwise hot path uses.
pub fn broadcast_dims(a: &[usize], b: &[usize]) -> Result<Shape, TensorError> {
    let rank = a.len().max(b.len());
    let mut out = Shape::empty();
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        let d = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            return Err(TensorError::BroadcastMismatch {
                a: a.to_vec(),
                b: b.to_vec(),
            });
        };
        out.push(d);
    }
    Ok(out)
}

/// [`broadcast_dims`] as a `Vec` (compatibility form).
pub fn broadcast_shape(a: &[usize], b: &[usize]) -> Result<Vec<usize>, TensorError> {
    Ok(broadcast_dims(a, b)?.to_vec())
}

/// Row-major strides of a shape (in elements).
pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

/// Iterator mapping a flat output index to the flat index into a
/// broadcast operand. Precomputes per-axis strides once; used by the
/// elementwise kernels so broadcasting has no per-element allocation.
pub struct BroadcastIndexer {
    out_strides: Vec<usize>,
    op_strides: Vec<usize>, // 0 on broadcast axes
}

impl BroadcastIndexer {
    pub fn new(out_shape: &[usize], op_shape: &[usize]) -> BroadcastIndexer {
        let rank = out_shape.len();
        let out_strides = strides_of(out_shape);
        let op_full: Vec<usize> = std::iter::repeat(1)
            .take(rank - op_shape.len())
            .chain(op_shape.iter().copied())
            .collect();
        let op_nat = strides_of(&op_full);
        let op_strides = (0..rank)
            .map(|i| if op_full[i] == 1 { 0 } else { op_nat[i] })
            .collect();
        BroadcastIndexer {
            out_strides,
            op_strides,
        }
    }

    /// Flat index into the operand for flat output index `idx`.
    #[inline]
    pub fn map(&self, mut idx: usize) -> usize {
        let mut off = 0usize;
        for (os, ps) in self.out_strides.iter().zip(&self.op_strides) {
            let coord = idx / os;
            idx %= os;
            off += coord * ps;
        }
        off
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(t.as_i8().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::from_f32(&[2, 2], vec![1., 2., 3.]).is_err());
    }

    #[test]
    fn reshape_checks_numel() {
        let t = Tensor::from_i32(&[4], vec![1, 2, 3, 4]).unwrap();
        let t = t.reshape(&[2, 2]).unwrap();
        assert_eq!(t.shape(), &[2, 2]);
        assert!(t.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn cast_i32_to_f32_exact() {
        let t = Tensor::from_i32(&[3], vec![-128, 0, 16777216]).unwrap();
        let f = t.cast(DType::F32);
        assert_eq!(f.as_f32().unwrap(), &[-128.0, 0.0, 16777216.0]);
    }

    #[test]
    fn cast_f32_to_f16_rounds() {
        let t = Tensor::from_f32(&[2], vec![1.0, 65504.0]).unwrap();
        let h = t.cast(DType::F16);
        assert_eq!(h.as_f16().unwrap()[0].0, 0x3C00);
        assert_eq!(h.as_f16().unwrap()[1].0, 0x7BFF);
    }

    #[test]
    fn broadcast_shapes() {
        assert_eq!(broadcast_shape(&[2, 3], &[3]).unwrap(), vec![2, 3]);
        assert_eq!(broadcast_shape(&[2, 1], &[1, 4]).unwrap(), vec![2, 4]);
        assert_eq!(broadcast_shape(&[], &[5]).unwrap(), vec![5]);
        assert!(broadcast_shape(&[2, 3], &[4]).is_err());
    }

    #[test]
    fn broadcast_indexer_bias_row() {
        // out [2,3], operand [3] (bias broadcast over rows).
        let ix = BroadcastIndexer::new(&[2, 3], &[3]);
        let got: Vec<usize> = (0..6).map(|i| ix.map(i)).collect();
        assert_eq!(got, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn broadcast_indexer_scalar() {
        let ix = BroadcastIndexer::new(&[2, 2], &[]);
        assert!((0..4).all(|i| ix.map(i) == 0));
    }

    #[test]
    fn slice_concat_rows_round_trip() {
        let t = Tensor::from_i8(&[4, 3], (0..12).collect()).unwrap();
        let a = t.slice_rows(0, 1).unwrap();
        let b = t.slice_rows(1, 3).unwrap();
        assert_eq!(a.shape(), &[1, 3]);
        assert_eq!(b.as_i8().unwrap(), &[3, 4, 5, 6, 7, 8, 9, 10, 11]);
        let back = Tensor::concat_rows(&[a, b]).unwrap();
        assert_eq!(back, t);
        assert!(t.slice_rows(3, 2).is_err());
        assert!(Tensor::scalar_f32(1.0).slice_rows(0, 1).is_err());
    }

    #[test]
    fn concat_rows_rejects_mismatch() {
        let a = Tensor::from_i8(&[1, 3], vec![1, 2, 3]).unwrap();
        let b = Tensor::from_i8(&[1, 2], vec![1, 2]).unwrap();
        assert!(Tensor::concat_rows(&[a.clone(), b]).is_err());
        let c = Tensor::from_u8(&[1, 3], vec![1, 2, 3]).unwrap();
        assert!(Tensor::concat_rows(&[a, c]).is_err());
        assert!(Tensor::concat_rows(&[]).is_err());
        // Rank-0 parts are rejected, not a panic.
        assert!(Tensor::concat_rows(&[Tensor::scalar_f32(1.0)]).is_err());
        assert_eq!(Tensor::scalar_f32(1.0).row_elems(), 1);
    }

    #[test]
    fn shape_inline_and_heap_agree() {
        let s = Shape::from_slice(&[2, 3, 4]);
        assert_eq!(s.as_slice(), &[2, 3, 4]);
        assert_eq!(s.numel(), 24);
        let mut s2 = Shape::empty();
        for d in [2usize, 3, 4] {
            s2.push(d);
        }
        assert_eq!(s, s2);
        // Past SHAPE_INLINE dims the shape promotes to heap storage and
        // still compares equal by dims.
        let long: Vec<usize> = (1..=SHAPE_INLINE + 2).collect();
        let heap = Shape::from_slice(&long);
        let mut pushed = Shape::empty();
        for &d in &long {
            pushed.push(d);
        }
        assert_eq!(heap, pushed);
        assert_eq!(heap.as_slice(), &long[..]);
    }

    #[test]
    fn recycled_buffers_reuse_matching_dtype() {
        let t = Tensor::from_i32(&[4], vec![1, 2, 3, 4]).unwrap();
        let v = recycled_i32(Some(t), 4);
        assert!(v.is_empty());
        assert!(v.capacity() >= 4);
        // Mismatched dtype falls back to a fresh buffer.
        let t = Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap();
        let v = recycled_i32(Some(t), 8);
        assert!(v.is_empty());
        assert!(v.capacity() >= 8);
        let z = recycled_i32_zeroed(None, 3);
        assert_eq!(z, vec![0, 0, 0]);
    }

    #[test]
    fn cast_recycled_matches_cast() {
        let t = Tensor::from_i32(&[3], vec![-7, 0, 42]).unwrap();
        for to in [DType::F32, DType::F16, DType::I8, DType::U8, DType::I64, DType::Bool] {
            let plain = t.cast(to);
            let spare = Tensor::from_f32(&[5], vec![9.0; 5]).unwrap();
            let rec = t.cast_recycled(to, Some(spare));
            assert_eq!(plain, rec, "cast to {to}");
        }
        let f = Tensor::from_f32(&[2], vec![1.5, -2.5]).unwrap();
        for to in [DType::I8, DType::U8, DType::I32, DType::I64] {
            assert_eq!(f.cast(to), f.cast_recycled(to, None), "f32 cast to {to}");
        }
    }

    #[test]
    fn clone_recycled_matches_clone() {
        let t = Tensor::from_i8(&[2, 2], vec![1, -2, 3, -4]).unwrap();
        let spare = Tensor::from_i8(&[9], vec![0; 9]).unwrap();
        assert_eq!(t.clone(), t.clone_recycled(Some(spare)));
        assert_eq!(t.clone(), t.clone_recycled(None));
    }

    #[test]
    fn quantized_scalar_reads_without_alloc_path() {
        assert_eq!(Tensor::scalar_i8(-3).quantized_scalar_i32().unwrap(), -3);
        assert_eq!(Tensor::scalar_u8(200).quantized_scalar_i32().unwrap(), 200);
        assert!(Tensor::scalar_f32(1.0).quantized_scalar_i32().is_err());
    }

    #[test]
    fn quantized_widen() {
        let t = Tensor::from_u8(&[3], vec![0, 128, 255]).unwrap();
        assert_eq!(t.as_quantized_i32().unwrap(), vec![0, 128, 255]);
        let t = Tensor::from_i8(&[2], vec![-128, 127]).unwrap();
        assert_eq!(t.as_quantized_i32().unwrap(), vec![-128, 127]);
    }
}
