//! Dtyped dense tensors — the numeric substrate for the whole stack.
//!
//! Tensors are row-major contiguous. The dtype set is exactly what the
//! paper's patterns require: `f32` (rescale path), `f16` (Fig. 5/6
//! activation path), `i8`/`u8` (quantized tensors), `i32` (accumulators
//! and biases), plus `i64`/`bool` for shape-carrying ONNX operators.

pub mod f16;

pub use f16::F16;

use thiserror::Error;

/// Element type of a [`Tensor`]. Mirrors the ONNX `TensorProto.DataType`
/// subset the paper's patterns use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    I8,
    U8,
    I32,
    I64,
    Bool,
}

impl DType {
    /// Size of one element in bytes (used by the hwsim memory-traffic
    /// model and the PJRT literal conversion).
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 => 2,
            DType::I8 | DType::U8 | DType::Bool => 1,
            DType::I64 => 8,
        }
    }

    /// ONNX textual name, used in the model serialization.
    pub fn onnx_name(self) -> &'static str {
        match self {
            DType::F32 => "FLOAT",
            DType::F16 => "FLOAT16",
            DType::I8 => "INT8",
            DType::U8 => "UINT8",
            DType::I32 => "INT32",
            DType::I64 => "INT64",
            DType::Bool => "BOOL",
        }
    }

    /// Parse the ONNX textual name.
    pub fn from_onnx_name(s: &str) -> Option<DType> {
        Some(match s {
            "FLOAT" => DType::F32,
            "FLOAT16" => DType::F16,
            "INT8" => DType::I8,
            "UINT8" => DType::U8,
            "INT32" => DType::I32,
            "INT64" => DType::I64,
            "BOOL" => DType::Bool,
            _ => return None,
        })
    }

    pub fn is_quantized_int(self) -> bool {
        matches!(self, DType::I8 | DType::U8)
    }

    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F16)
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.onnx_name())
    }
}

/// Typed storage behind a [`Tensor`].
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    F16(Vec<F16>),
    I8(Vec<i8>),
    U8(Vec<u8>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    Bool(Vec<bool>),
}

impl TensorData {
    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::F16(_) => DType::F16,
            TensorData::I8(_) => DType::I8,
            TensorData::U8(_) => DType::U8,
            TensorData::I32(_) => DType::I32,
            TensorData::I64(_) => DType::I64,
            TensorData::Bool(_) => DType::Bool,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::F16(v) => v.len(),
            TensorData::I8(v) => v.len(),
            TensorData::U8(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::I64(v) => v.len(),
            TensorData::Bool(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Errors raised by tensor construction and access.
#[derive(Error, Debug)]
pub enum TensorError {
    #[error("shape {shape:?} implies {expected} elements but data has {got}")]
    ShapeMismatch {
        shape: Vec<usize>,
        expected: usize,
        got: usize,
    },
    #[error("dtype mismatch: expected {expected}, got {got}")]
    DTypeMismatch { expected: DType, got: DType },
    #[error("cannot reshape {numel} elements to shape {shape:?}")]
    BadReshape { numel: usize, shape: Vec<usize> },
    #[error("incompatible shapes for broadcast: {a:?} vs {b:?}")]
    BroadcastMismatch { a: Vec<usize>, b: Vec<usize> },
    #[error("cannot concatenate along axis 0: {a:?}/{a_dtype} vs {b:?}/{b_dtype}")]
    ConcatMismatch {
        a: Vec<usize>,
        a_dtype: DType,
        b: Vec<usize>,
        b_dtype: DType,
    },
    #[error("row slice [{off}, {off}+{len}) out of batch {batch}")]
    RowSliceOutOfRange { off: usize, len: usize, batch: usize },
}

/// A dense row-major tensor: shape + typed storage.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: TensorData,
}

impl Tensor {
    /// Construct from shape + typed data, validating element count.
    pub fn new(shape: Vec<usize>, data: TensorData) -> Result<Tensor, TensorError> {
        let expected: usize = shape.iter().product();
        if expected != data.len() {
            return Err(TensorError::ShapeMismatch {
                shape,
                expected,
                got: data.len(),
            });
        }
        Ok(Tensor { shape, data })
    }

    pub fn from_f32(shape: &[usize], v: Vec<f32>) -> Result<Tensor, TensorError> {
        Tensor::new(shape.to_vec(), TensorData::F32(v))
    }
    pub fn from_f16(shape: &[usize], v: Vec<F16>) -> Result<Tensor, TensorError> {
        Tensor::new(shape.to_vec(), TensorData::F16(v))
    }
    pub fn from_i8(shape: &[usize], v: Vec<i8>) -> Result<Tensor, TensorError> {
        Tensor::new(shape.to_vec(), TensorData::I8(v))
    }
    pub fn from_u8(shape: &[usize], v: Vec<u8>) -> Result<Tensor, TensorError> {
        Tensor::new(shape.to_vec(), TensorData::U8(v))
    }
    pub fn from_i32(shape: &[usize], v: Vec<i32>) -> Result<Tensor, TensorError> {
        Tensor::new(shape.to_vec(), TensorData::I32(v))
    }
    pub fn from_i64(shape: &[usize], v: Vec<i64>) -> Result<Tensor, TensorError> {
        Tensor::new(shape.to_vec(), TensorData::I64(v))
    }

    /// Rank-0 f32 scalar (ONNX scalar initializers such as `Quant_scale`).
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: TensorData::F32(vec![v]),
        }
    }
    /// Rank-0 i8 scalar (e.g. QuantizeLinear `zero_point`).
    pub fn scalar_i8(v: i8) -> Tensor {
        Tensor {
            shape: vec![],
            data: TensorData::I8(vec![v]),
        }
    }
    /// Rank-0 u8 scalar.
    pub fn scalar_u8(v: u8) -> Tensor {
        Tensor {
            shape: vec![],
            data: TensorData::U8(vec![v]),
        }
    }

    /// All-zeros tensor of the given dtype/shape.
    pub fn zeros(dtype: DType, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        let data = match dtype {
            DType::F32 => TensorData::F32(vec![0.0; n]),
            DType::F16 => TensorData::F16(vec![F16::ZERO; n]),
            DType::I8 => TensorData::I8(vec![0; n]),
            DType::U8 => TensorData::U8(vec![0; n]),
            DType::I32 => TensorData::I32(vec![0; n]),
            DType::I64 => TensorData::I64(vec![0; n]),
            DType::Bool => TensorData::Bool(vec![false; n]),
        };
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn data(&self) -> &TensorData {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut TensorData {
        &mut self.data
    }

    /// Bytes of payload (hwsim memory-traffic model).
    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype().size_bytes()
    }

    /// Reshape in place to a compatible shape.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor, TensorError> {
        let n: usize = shape.iter().product();
        if n != self.numel() {
            return Err(TensorError::BadReshape {
                numel: self.numel(),
                shape: shape.to_vec(),
            });
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    // --- typed slice accessors -------------------------------------------

    pub fn as_f32(&self) -> Result<&[f32], TensorError> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::F32,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_f16(&self) -> Result<&[F16], TensorError> {
        match &self.data {
            TensorData::F16(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::F16,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_i8(&self) -> Result<&[i8], TensorError> {
        match &self.data {
            TensorData::I8(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::I8,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_u8(&self) -> Result<&[u8], TensorError> {
        match &self.data {
            TensorData::U8(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::U8,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32], TensorError> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::I32,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_i64(&self) -> Result<&[i64], TensorError> {
        match &self.data {
            TensorData::I64(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::I64,
                got: d.dtype(),
            }),
        }
    }
    pub fn as_bool(&self) -> Result<&[bool], TensorError> {
        match &self.data {
            TensorData::Bool(v) => Ok(v),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::Bool,
                got: d.dtype(),
            }),
        }
    }

    /// Read the quantized integer values widened to i32, regardless of
    /// whether storage is i8 or u8 (the paper's patterns allow either for
    /// layer inputs).
    pub fn as_quantized_i32(&self) -> Result<Vec<i32>, TensorError> {
        match &self.data {
            TensorData::I8(v) => Ok(v.iter().map(|&x| x as i32).collect()),
            TensorData::U8(v) => Ok(v.iter().map(|&x| x as i32).collect()),
            TensorData::I32(v) => Ok(v.clone()),
            d => Err(TensorError::DTypeMismatch {
                expected: DType::I8,
                got: d.dtype(),
            }),
        }
    }

    /// Convert every element to f32 (lossless for all our dtypes).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match &self.data {
            TensorData::F32(v) => v.clone(),
            TensorData::F16(v) => v.iter().map(|x| x.to_f32()).collect(),
            TensorData::I8(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::U8(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::I32(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::I64(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::Bool(v) => v.iter().map(|&x| x as u8 as f32).collect(),
        }
    }

    /// Elements per row when axis 0 is treated as the batch axis (1 for
    /// rank-0 tensors).
    pub fn row_elems(&self) -> usize {
        self.shape.get(1..).map_or(1, |s| s.iter().product())
    }

    /// Rows `[off, off + len)` along axis 0 as a new contiguous tensor.
    /// The batch-parallel executors use this to split work; slicing then
    /// [`Tensor::concat_rows`] is the identity.
    pub fn slice_rows(&self, off: usize, len: usize) -> Result<Tensor, TensorError> {
        let Some(&batch) = self.shape.first() else {
            return Err(TensorError::RowSliceOutOfRange { off, len, batch: 0 });
        };
        if off + len > batch {
            return Err(TensorError::RowSliceOutOfRange { off, len, batch });
        }
        let re = self.row_elems();
        let (a, b) = (off * re, (off + len) * re);
        let data = match &self.data {
            TensorData::F32(v) => TensorData::F32(v[a..b].to_vec()),
            TensorData::F16(v) => TensorData::F16(v[a..b].to_vec()),
            TensorData::I8(v) => TensorData::I8(v[a..b].to_vec()),
            TensorData::U8(v) => TensorData::U8(v[a..b].to_vec()),
            TensorData::I32(v) => TensorData::I32(v[a..b].to_vec()),
            TensorData::I64(v) => TensorData::I64(v[a..b].to_vec()),
            TensorData::Bool(v) => TensorData::Bool(v[a..b].to_vec()),
        };
        let mut shape = self.shape.clone();
        shape[0] = len;
        Ok(Tensor { shape, data })
    }

    /// Concatenate tensors along axis 0. Every part must be rank >= 1 and
    /// share dtype and row shape.
    pub fn concat_rows(parts: &[Tensor]) -> Result<Tensor, TensorError> {
        let first = parts.first().ok_or(TensorError::RowSliceOutOfRange {
            off: 0,
            len: 0,
            batch: 0,
        })?;
        if first.shape().is_empty() {
            return Err(TensorError::ConcatMismatch {
                a: Vec::new(),
                a_dtype: first.dtype(),
                b: Vec::new(),
                b_dtype: first.dtype(),
            });
        }
        let row_shape = &first.shape()[1..];
        let dtype = first.dtype();
        let mut total = 0usize;
        for t in parts {
            if t.shape().get(1..) != Some(row_shape) || t.dtype() != dtype {
                return Err(TensorError::ConcatMismatch {
                    a: first.shape().to_vec(),
                    a_dtype: dtype,
                    b: t.shape().to_vec(),
                    b_dtype: t.dtype(),
                });
            }
            total += t.shape()[0];
        }
        let mut shape = vec![total];
        shape.extend_from_slice(row_shape);

        macro_rules! concat_as {
            ($variant:ident, $ty:ty) => {{
                let mut out: Vec<$ty> =
                    Vec::with_capacity(total * row_shape.iter().product::<usize>());
                for t in parts {
                    match t.data() {
                        TensorData::$variant(v) => out.extend_from_slice(v),
                        _ => unreachable!("dtype checked above"),
                    }
                }
                TensorData::$variant(out)
            }};
        }
        let data = match dtype {
            DType::F32 => concat_as!(F32, f32),
            DType::F16 => concat_as!(F16, F16),
            DType::I8 => concat_as!(I8, i8),
            DType::U8 => concat_as!(U8, u8),
            DType::I32 => concat_as!(I32, i32),
            DType::I64 => concat_as!(I64, i64),
            DType::Bool => concat_as!(Bool, bool),
        };
        Tensor::new(shape, data)
    }

    /// ONNX `Cast` semantics: float->int truncates toward zero, float->f16
    /// rounds to nearest-even, int widenings are exact. Saturation is NOT
    /// applied (ONNX Cast wraps/UBs on overflow; the paper's patterns only
    /// cast i32->f32 and f32<->f16 where this cannot occur).
    pub fn cast(&self, to: DType) -> Tensor {
        if to == self.dtype() {
            return self.clone();
        }
        let n = self.numel();
        let data = match to {
            DType::F32 => TensorData::F32(self.to_f32_vec()),
            DType::F16 => {
                TensorData::F16(self.to_f32_vec().iter().map(|&x| F16::from_f32(x)).collect())
            }
            DType::I8 => TensorData::I8(match &self.data {
                TensorData::U8(v) => v.iter().map(|&x| x as i8).collect(),
                TensorData::I32(v) => v.iter().map(|&x| x as i8).collect(),
                TensorData::I64(v) => v.iter().map(|&x| x as i8).collect(),
                _ => self.to_f32_vec().iter().map(|&x| x as i8).collect(),
            }),
            DType::U8 => TensorData::U8(match &self.data {
                TensorData::I8(v) => v.iter().map(|&x| x as u8).collect(),
                TensorData::I32(v) => v.iter().map(|&x| x as u8).collect(),
                TensorData::I64(v) => v.iter().map(|&x| x as u8).collect(),
                _ => self.to_f32_vec().iter().map(|&x| x as u8).collect(),
            }),
            DType::I32 => TensorData::I32(match &self.data {
                TensorData::I8(v) => v.iter().map(|&x| x as i32).collect(),
                TensorData::U8(v) => v.iter().map(|&x| x as i32).collect(),
                TensorData::I64(v) => v.iter().map(|&x| x as i32).collect(),
                _ => self.to_f32_vec().iter().map(|&x| x as i32).collect(),
            }),
            DType::I64 => TensorData::I64(match &self.data {
                TensorData::I8(v) => v.iter().map(|&x| x as i64).collect(),
                TensorData::U8(v) => v.iter().map(|&x| x as i64).collect(),
                TensorData::I32(v) => v.iter().map(|&x| x as i64).collect(),
                _ => self.to_f32_vec().iter().map(|&x| x as i64).collect(),
            }),
            DType::Bool => {
                TensorData::Bool(self.to_f32_vec().iter().map(|&x| x != 0.0).collect())
            }
        };
        debug_assert_eq!(data.len(), n);
        Tensor {
            shape: self.shape.clone(),
            data,
        }
    }
}

/// Compute the broadcast result shape per ONNX/NumPy multidirectional
/// broadcasting rules.
pub fn broadcast_shape(a: &[usize], b: &[usize]) -> Result<Vec<usize>, TensorError> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            return Err(TensorError::BroadcastMismatch {
                a: a.to_vec(),
                b: b.to_vec(),
            });
        };
    }
    Ok(out)
}

/// Row-major strides of a shape (in elements).
pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

/// Iterator mapping a flat output index to the flat index into a
/// broadcast operand. Precomputes per-axis strides once; used by the
/// elementwise kernels so broadcasting has no per-element allocation.
pub struct BroadcastIndexer {
    out_strides: Vec<usize>,
    op_strides: Vec<usize>, // 0 on broadcast axes
}

impl BroadcastIndexer {
    pub fn new(out_shape: &[usize], op_shape: &[usize]) -> BroadcastIndexer {
        let rank = out_shape.len();
        let out_strides = strides_of(out_shape);
        let op_full: Vec<usize> = std::iter::repeat(1)
            .take(rank - op_shape.len())
            .chain(op_shape.iter().copied())
            .collect();
        let op_nat = strides_of(&op_full);
        let op_strides = (0..rank)
            .map(|i| if op_full[i] == 1 { 0 } else { op_nat[i] })
            .collect();
        BroadcastIndexer {
            out_strides,
            op_strides,
        }
    }

    /// Flat index into the operand for flat output index `idx`.
    #[inline]
    pub fn map(&self, mut idx: usize) -> usize {
        let mut off = 0usize;
        for (os, ps) in self.out_strides.iter().zip(&self.op_strides) {
            let coord = idx / os;
            idx %= os;
            off += coord * ps;
        }
        off
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(t.as_i8().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::from_f32(&[2, 2], vec![1., 2., 3.]).is_err());
    }

    #[test]
    fn reshape_checks_numel() {
        let t = Tensor::from_i32(&[4], vec![1, 2, 3, 4]).unwrap();
        let t = t.reshape(&[2, 2]).unwrap();
        assert_eq!(t.shape(), &[2, 2]);
        assert!(t.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn cast_i32_to_f32_exact() {
        let t = Tensor::from_i32(&[3], vec![-128, 0, 16777216]).unwrap();
        let f = t.cast(DType::F32);
        assert_eq!(f.as_f32().unwrap(), &[-128.0, 0.0, 16777216.0]);
    }

    #[test]
    fn cast_f32_to_f16_rounds() {
        let t = Tensor::from_f32(&[2], vec![1.0, 65504.0]).unwrap();
        let h = t.cast(DType::F16);
        assert_eq!(h.as_f16().unwrap()[0].0, 0x3C00);
        assert_eq!(h.as_f16().unwrap()[1].0, 0x7BFF);
    }

    #[test]
    fn broadcast_shapes() {
        assert_eq!(broadcast_shape(&[2, 3], &[3]).unwrap(), vec![2, 3]);
        assert_eq!(broadcast_shape(&[2, 1], &[1, 4]).unwrap(), vec![2, 4]);
        assert_eq!(broadcast_shape(&[], &[5]).unwrap(), vec![5]);
        assert!(broadcast_shape(&[2, 3], &[4]).is_err());
    }

    #[test]
    fn broadcast_indexer_bias_row() {
        // out [2,3], operand [3] (bias broadcast over rows).
        let ix = BroadcastIndexer::new(&[2, 3], &[3]);
        let got: Vec<usize> = (0..6).map(|i| ix.map(i)).collect();
        assert_eq!(got, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn broadcast_indexer_scalar() {
        let ix = BroadcastIndexer::new(&[2, 2], &[]);
        assert!((0..4).all(|i| ix.map(i) == 0));
    }

    #[test]
    fn slice_concat_rows_round_trip() {
        let t = Tensor::from_i8(&[4, 3], (0..12).collect()).unwrap();
        let a = t.slice_rows(0, 1).unwrap();
        let b = t.slice_rows(1, 3).unwrap();
        assert_eq!(a.shape(), &[1, 3]);
        assert_eq!(b.as_i8().unwrap(), &[3, 4, 5, 6, 7, 8, 9, 10, 11]);
        let back = Tensor::concat_rows(&[a, b]).unwrap();
        assert_eq!(back, t);
        assert!(t.slice_rows(3, 2).is_err());
        assert!(Tensor::scalar_f32(1.0).slice_rows(0, 1).is_err());
    }

    #[test]
    fn concat_rows_rejects_mismatch() {
        let a = Tensor::from_i8(&[1, 3], vec![1, 2, 3]).unwrap();
        let b = Tensor::from_i8(&[1, 2], vec![1, 2]).unwrap();
        assert!(Tensor::concat_rows(&[a.clone(), b]).is_err());
        let c = Tensor::from_u8(&[1, 3], vec![1, 2, 3]).unwrap();
        assert!(Tensor::concat_rows(&[a, c]).is_err());
        assert!(Tensor::concat_rows(&[]).is_err());
        // Rank-0 parts are rejected, not a panic.
        assert!(Tensor::concat_rows(&[Tensor::scalar_f32(1.0)]).is_err());
        assert_eq!(Tensor::scalar_f32(1.0).row_elems(), 1);
    }

    #[test]
    fn quantized_widen() {
        let t = Tensor::from_u8(&[3], vec![0, 128, 255]).unwrap();
        assert_eq!(t.as_quantized_i32().unwrap(), vec![0, 128, 255]);
        let t = Tensor::from_i8(&[2], vec![-128, 127]).unwrap();
        assert_eq!(t.as_quantized_i32().unwrap(), vec![-128, 127]);
    }
}
