//! Bit-exact IEEE 754 binary16 ("half") implemented in software.
//!
//! The `half` crate is not available in this offline environment, and the
//! paper's Figure 5/6 patterns require genuine fp16 activation arithmetic
//! (`Cast FLOAT -> FLOAT16`, `Tanh FLOAT16 -> FLOAT16`, ...). This module
//! implements conversions that are bit-exact with hardware f16 (round to
//! nearest, ties to even; subnormals; inf/nan preserved) so the Rust
//! interpreter, the hardware simulator and the XLA/PJRT artifact all see
//! the same numbers.

/// IEEE 754 binary16 value stored as its raw bit pattern.
///
/// Arithmetic is performed by converting to f32, operating, and rounding
/// back — which is exactly what commodity fp16 hardware units (and XLA's
/// CPU backend) do for the transcendental ops used in the paper.
#[derive(Clone, Copy, PartialEq, Eq, Default)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3C00);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    pub const NAN: F16 = F16(0x7E00);
    /// Largest finite f16 = 65504.
    pub const MAX: F16 = F16(0x7BFF);

    /// Convert from f32 with round-to-nearest-even (the IEEE default mode,
    /// matching x86 `vcvtps2ph` and XLA's `convert` lowering).
    #[inline]
    pub fn from_f32(x: f32) -> F16 {
        F16(f32_to_f16_bits(x))
    }

    /// Exact widening conversion to f32 (every f16 is representable).
    #[inline]
    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x03FF) != 0
    }

    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }

    /// tanh evaluated in f32 then rounded to f16 — correctly rounded for
    /// all f16 inputs (f32 has more than twice the precision of f16, so
    /// double rounding cannot change the result here).
    #[inline]
    pub fn tanh(self) -> F16 {
        F16::from_f32(self.to_f32().tanh())
    }

    /// Logistic sigmoid evaluated in f32 then rounded to f16.
    #[inline]
    pub fn sigmoid(self) -> F16 {
        let x = self.to_f32();
        F16::from_f32(1.0 / (1.0 + (-x).exp()))
    }
}

impl std::fmt::Debug for F16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "F16({} = {:#06x})", self.to_f32(), self.0)
    }
}

impl std::fmt::Display for F16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_f32())
    }
}

/// f32 -> f16 bit conversion, round-to-nearest-even.
///
/// Handles normals, subnormals, overflow to infinity, and NaN payload
/// preservation (quietened, top payload bits kept) identically to the
/// x86/ARM hardware converters.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf or NaN.
        return if mant == 0 {
            sign | 0x7C00
        } else {
            // Quiet NaN, keep top 9 payload bits, ensure non-zero mantissa.
            let payload = (mant >> 13) as u16;
            sign | 0x7C00 | 0x0200 | payload
        };
    }

    // Unbiased exponent; f16 bias is 15, f32 bias is 127.
    let unbiased = exp - 127;
    if unbiased > 15 {
        // Overflows f16 range -> infinity.
        return sign | 0x7C00;
    }
    if unbiased >= -14 {
        // Normal f16. 23-bit mantissa -> 10 bits: shift out 13 bits with
        // round-to-nearest-even on the removed bits.
        let half_exp = ((unbiased + 15) as u16) << 10;
        let mant10 = (mant >> 13) as u16;
        let rem = mant & 0x1FFF; // 13 discarded bits
        let mut out = sign | half_exp | mant10;
        if rem > 0x1000 || (rem == 0x1000 && (mant10 & 1) == 1) {
            out = out.wrapping_add(1); // carries into exponent correctly
        }
        return out;
    }
    if unbiased >= -25 {
        // Subnormal f16: implicit leading 1 becomes explicit, shifted right.
        let full = mant | 0x0080_0000; // 24-bit significand
        let shift = (-14 - unbiased) + 13; // total right shift, 14..=24
        let mant_sub = (full >> shift) as u16;
        let rem_mask = (1u32 << shift) - 1;
        let rem = full & rem_mask;
        let half_point = 1u32 << (shift - 1);
        let mut out = sign | mant_sub;
        if rem > half_point || (rem == half_point && (mant_sub & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    // Underflows to (signed) zero.
    sign
}

/// f16 -> f32 bit conversion (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;

    let bits = if exp == 0x1F {
        // Inf / NaN.
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign // +-0
        } else {
            // Subnormal: normalize.
            let lz = mant.leading_zeros() - 22; // zeros above bit 9
            let mant_norm = (mant << (lz + 1)) & 0x03FF;
            let exp_f32 = 127 - 15 - lz;
            sign | (exp_f32 << 23) | (mant_norm << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_exact_values() {
        // All f16 bit patterns must survive f16 -> f32 -> f16 unchanged
        // (modulo NaN payload equivalence).
        for bits in 0u16..=0xFFFF {
            let h = F16(bits);
            let rt = F16::from_f32(h.to_f32());
            if h.is_nan() {
                assert!(rt.is_nan(), "NaN lost at {bits:#06x}");
            } else {
                assert_eq!(h.0, rt.0, "round-trip failed at {bits:#06x}");
            }
        }
    }

    #[test]
    fn known_constants() {
        assert_eq!(F16::from_f32(0.0).0, 0x0000);
        assert_eq!(F16::from_f32(-0.0).0, 0x8000);
        assert_eq!(F16::from_f32(1.0).0, 0x3C00);
        assert_eq!(F16::from_f32(-2.0).0, 0xC000);
        assert_eq!(F16::from_f32(65504.0).0, 0x7BFF);
        assert_eq!(F16::from_f32(65536.0).0, 0x7C00); // overflow -> inf
        assert_eq!(F16::from_f32(f32::INFINITY).0, 0x7C00);
        assert_eq!(F16::from_f32(f32::NEG_INFINITY).0, 0xFC00);
        assert!(F16::from_f32(f32::NAN).is_nan());
        // Smallest positive subnormal 2^-24.
        assert_eq!(F16::from_f32(5.960_464_5e-8).0, 0x0001);
        // Below half the smallest subnormal -> 0.
        assert_eq!(F16::from_f32(2.0e-8).0, 0x0000);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16
        // (1 + 2^-10); ties-to-even keeps 1.0.
        let halfway = 1.0 + 2.0_f32.powi(-11);
        assert_eq!(F16::from_f32(halfway).0, 0x3C00);
        // Slightly above rounds up.
        let above = 1.0 + 2.0_f32.powi(-11) + 2.0_f32.powi(-20);
        assert_eq!(F16::from_f32(above).0, 0x3C01);
        // 1 + 3*2^-11 is halfway between 0x3C01 and 0x3C02 -> even 0x3C02.
        let halfway2 = 1.0 + 3.0 * 2.0_f32.powi(-11);
        assert_eq!(F16::from_f32(halfway2).0, 0x3C02);
    }

    #[test]
    fn subnormal_conversion() {
        // 2^-15 is subnormal in f16: 0x0200.
        assert_eq!(F16::from_f32(2.0_f32.powi(-15)).0, 0x0200);
        assert_eq!(F16(0x0200).to_f32(), 2.0_f32.powi(-15));
        // 2^-24 round trips.
        assert_eq!(F16(0x0001).to_f32(), 2.0_f32.powi(-24));
    }

    #[test]
    fn tanh_sigmoid_sane() {
        assert_eq!(F16::from_f32(0.0).tanh().0, 0);
        let t = F16::from_f32(1.0).tanh().to_f32();
        assert!((t - 0.7615942).abs() < 1e-3, "tanh(1)={t}");
        let s = F16::from_f32(0.0).sigmoid().to_f32();
        assert!((s - 0.5).abs() < 1e-3);
        // Saturation: tanh of large input is exactly 1.0 in f16.
        assert_eq!(F16::from_f32(20.0).tanh().0, 0x3C00);
    }
}
