//! The shared DAG pattern matcher: single-consumer chain walking over a
//! plan-time [`ConsumerIndex`], with initializer-aware operand
//! predicates.
//!
//! This is the ONE copy of the recognition logic for the paper's codified
//! patterns (Figures 1–6). Two very different consumers drive it:
//!
//! * the interpreter's plan-time fusion passes ([`super`]) — a failed
//!   match means "decline fusion, keep executing node by node", so every
//!   structural requirement here is conservative: a mid-chain value with
//!   a second consumer, a rescale multiplier that is not a scalar
//!   initializer, a chain value that doubles as a graph output — all
//!   return [`MatchFail`] and leave execution bit-identical to the
//!   unfused plan;
//! * the hardware-simulator compiler ([`crate::hwsim::exec`]) — a failed
//!   match is a hard compile error (the accelerator has no node-by-node
//!   fallback), so [`MatchFail`] carries the offending node and message
//!   for the error report.
//!
//! The matcher validates *structure* (operator sequence, scalar
//! initializers, sole consumers). Backend-specific value constraints —
//! hwsim's `requantize scale == 1.0`, the interpreter's bias-layout and
//! packed-weight preconditions — stay with the backend that imposes them.

use crate::onnx::ir::{Graph, Node};
use crate::quant::lut::ActFn;
use crate::quant::QType;
use crate::tensor::{DType, Tensor};
use std::collections::HashMap;

/// Why a pattern match gave up.
#[derive(Debug)]
pub enum MatchFail {
    /// A chain value has more than one consumer: outside the pattern
    /// language (the emitted pre-quantized graphs are linear chains).
    MultiConsumer { value: String },
    /// The chain deviates structurally at `node`.
    Mismatch { node: String, msg: String },
}

fn mismatch(node: &Node, msg: impl Into<String>) -> MatchFail {
    MatchFail::Mismatch {
        node: node.name.clone(),
        msg: msg.into(),
    }
}

/// Plan-time value -> consumer index, built in ONE pass over the graph so
/// chain walking is O(1) per edge instead of an O(nodes) scan per lookup.
enum ConsumerEntry {
    One(usize),
    Multiple,
}

pub struct ConsumerIndex<'g> {
    map: HashMap<&'g str, ConsumerEntry>,
}

impl<'g> ConsumerIndex<'g> {
    pub fn build(g: &'g Graph) -> ConsumerIndex<'g> {
        let mut map = HashMap::new();
        for (idx, n) in g.nodes.iter().enumerate() {
            for input in &n.inputs {
                if input.is_empty() {
                    continue;
                }
                // A node listing the value twice (e.g. Mul(x, x)) is one
                // consumer.
                let entry = map.entry(input.as_str()).or_insert(ConsumerEntry::One(idx));
                if let ConsumerEntry::One(prev) = entry {
                    if *prev != idx {
                        *entry = ConsumerEntry::Multiple;
                    }
                }
            }
        }
        ConsumerIndex { map }
    }

    /// The sole consumer of a value (index + node), `None` at the end of
    /// the chain, or [`MatchFail::MultiConsumer`].
    pub fn sole_consumer(
        &self,
        g: &'g Graph,
        value: &str,
    ) -> Result<Option<(usize, &'g Node)>, MatchFail> {
        match self.map.get(value) {
            None => Ok(None),
            Some(ConsumerEntry::One(idx)) => Ok(Some((*idx, &g.nodes[*idx]))),
            Some(ConsumerEntry::Multiple) => Err(MatchFail::MultiConsumer {
                value: value.to_string(),
            }),
        }
    }
}

/// How initializer-stored pattern operands are admitted. The recognition
/// logic is shared; what a backend may soundly READ from the model is
/// not:
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitPolicy {
    /// Plan-time baking (the interpreter's fusion passes): the
    /// initializer must not be shadowed by a graph input (a feed could
    /// override the value at run time) and "scalars" must be rank <= 2
    /// (a rank-3+ scalar would rank-EXTEND the chain value under ONNX
    /// broadcasting, changing the unfused output shape the fused kernel
    /// must reproduce bit for bit). Violations decline the fusion.
    Bakeable,
    /// Pattern lifting for a backend with its own execution contract
    /// (the hw compiler): any initializer, shadowed or not — `HwModule`'s
    /// run API never accepts feeds for those inputs, so reading the
    /// stored value is sound, and stage shapes are the backend's own.
    /// This preserves the acceptance of the pre-matcher bespoke walk
    /// (e.g. models exported with `keep_initializers_as_inputs`).
    AnyInitializer,
}

/// An initializer usable as a pattern operand under `policy`.
pub fn pattern_init<'g>(g: &'g Graph, name: &str, policy: InitPolicy) -> Option<&'g Tensor> {
    if policy == InitPolicy::Bakeable && g.input(name).is_some() {
        return None;
    }
    g.initializer(name)
}

/// Scalar f32 pattern initializer, by value (see [`InitPolicy`] for the
/// rank cap applied under `Bakeable`).
pub fn scalar_f32_init(g: &Graph, name: &str, policy: InitPolicy) -> Option<f32> {
    let t = pattern_init(g, name, policy)?;
    if t.numel() != 1 {
        return None;
    }
    if policy == InitPolicy::Bakeable && t.rank() > 2 {
        return None;
    }
    t.as_f32().ok().map(|v| v[0])
}

/// i8/u8 zero-point pattern initializer, with the quantized type its
/// dtype selects (§3.1: "an uint8 zero_point argument results in uint8
/// output"). `Bakeable` requires a scalar (the value gets baked);
/// `AnyInitializer` reads only the dtype, like the old hw walk.
fn scalar_zp_init<'g>(
    g: &'g Graph,
    name: &str,
    policy: InitPolicy,
) -> Option<(&'g Tensor, QType)> {
    let t = pattern_init(g, name, policy)?;
    if policy == InitPolicy::Bakeable && t.numel() != 1 {
        return None;
    }
    match t.dtype() {
        DType::I8 => Some((t, QType::I8)),
        DType::U8 => Some((t, QType::U8)),
        _ => None,
    }
}

/// True when `name` can be absorbed into a fused chain (or aliased away
/// by the elimination passes): produced and consumed strictly inside the
/// graph's dataflow — not a declared output, and not shadowing a graph
/// input or initializer.
pub(crate) fn chain_internal(g: &Graph, name: &str) -> bool {
    g.output(name).is_none() && g.input(name).is_none() && g.initializer(name).is_none()
}

/// Chain-walk cursor: `cur` is the value the next node must solely
/// consume; `nodes` accumulates matched node indices in chain order.
struct Walk<'g, 'i> {
    g: &'g Graph,
    idx: &'i ConsumerIndex<'g>,
    cur: &'g str,
    nodes: Vec<usize>,
}

impl<'g, 'i> Walk<'g, 'i> {
    fn start(g: &'g Graph, idx: &'i ConsumerIndex<'g>, anchor_idx: usize) -> Result<Walk<'g, 'i>, MatchFail> {
        let anchor = &g.nodes[anchor_idx];
        let out = anchor
            .outputs
            .first()
            .filter(|n| !n.is_empty())
            .ok_or_else(|| mismatch(anchor, "anchor has no output"))?;
        Ok(Walk {
            g,
            idx,
            cur: out.as_str(),
            nodes: vec![anchor_idx],
        })
    }

    /// Advance to the sole consumer of `cur`, requiring `cur` to be
    /// chain-internal (fusing would otherwise lose an externally visible
    /// value).
    fn step(&mut self, from: &Node) -> Result<(usize, &'g Node), MatchFail> {
        if !chain_internal(self.g, self.cur) {
            return Err(mismatch(
                from,
                format!("value '{}' is externally visible; chain must be internal", self.cur),
            ));
        }
        match self.idx.sole_consumer(self.g, self.cur)? {
            Some((i, n)) => Ok((i, n)),
            None => Err(mismatch(from, "dangling chain")),
        }
    }

    /// Record `node` as matched and move the cursor past its output.
    fn consume(&mut self, idx: usize, node: &'g Node) -> Result<(), MatchFail> {
        let out = node
            .outputs
            .first()
            .filter(|n| !n.is_empty())
            .ok_or_else(|| mismatch(node, "chain node has no output"))?;
        self.nodes.push(idx);
        self.cur = out.as_str();
        Ok(())
    }
}

/// The matched quantized-FC/conv epilogue chain (Figures 1–3 and the
/// accumulate half of 4–6): `MatMulInteger|ConvInteger [+ Add(bias)] +
/// Cast(FLOAT) + Mul[+Mul] [+ Relu] [+ Clip] + QuantizeLinear`, where
/// the optional Clip declares a sub-8-bit logical output range (absorbed
/// into `out_qtype`; see the lemma at the match site).
pub struct QChain<'g> {
    /// Anchor node index (the MatMulInteger / ConvInteger).
    pub anchor: usize,
    /// The anchor's weight initializer (rank-2 for FC, rank-4 for conv).
    pub weight: &'g Tensor,
    /// Bias initializer + the Add node's index, when the chain has one.
    pub bias: Option<&'g Tensor>,
    pub bias_node: Option<usize>,
    /// The 1–2 scalar rescale multipliers, in application order (§3.1).
    pub muls: Vec<f32>,
    pub relu: bool,
    /// Final `QuantizeLinear` scale (scalar initializer, by value).
    pub q_scale: f32,
    /// Final `QuantizeLinear` zero-point initializer (scalar i8/u8).
    pub q_zp: &'g Tensor,
    /// Output integer type, selected by the zero point's dtype.
    pub out_qtype: QType,
    /// Every matched node index, in chain order (anchor first).
    pub nodes: Vec<usize>,
    /// The chain's final value name (the QuantizeLinear output).
    pub output: &'g str,
}

/// Match the quantized epilogue chain hanging off `anchor_idx` (which
/// must be a `MatMulInteger` or `ConvInteger` with an initializer
/// weight). See the module docs for the decline-vs-error contract.
pub fn match_q_chain<'g>(
    g: &'g Graph,
    idx: &ConsumerIndex<'g>,
    anchor_idx: usize,
    policy: InitPolicy,
) -> Result<QChain<'g>, MatchFail> {
    let anchor = &g.nodes[anchor_idx];
    let want_rank = match anchor.op_type.as_str() {
        "MatMulInteger" => 2,
        "ConvInteger" => 4,
        op => {
            return Err(mismatch(
                anchor,
                format!("'{op}' is not a quantized-chain anchor"),
            ))
        }
    };
    let w_name = anchor
        .inputs
        .get(1)
        .filter(|n| !n.is_empty())
        .ok_or_else(|| mismatch(anchor, "missing weight input"))?;
    let weight = pattern_init(g, w_name, policy)
        .ok_or_else(|| mismatch(anchor, "weight must be an initializer"))?;
    if weight.rank() != want_rank {
        return Err(mismatch(anchor, format!("weight must be rank-{want_rank}")));
    }

    let mut walk = Walk::start(g, idx, anchor_idx)?;
    let (mut node_idx, mut node) = walk.step(anchor)?;

    // Optional bias Add (the initializer may sit on either operand).
    let mut bias = None;
    let mut bias_node = None;
    if node.op_type == "Add" {
        let bias_name = if node.inputs.first().map(String::as_str) == Some(walk.cur) {
            node.inputs.get(1)
        } else {
            node.inputs.first()
        }
        .filter(|n| !n.is_empty())
        .ok_or_else(|| mismatch(node, "malformed bias Add"))?;
        bias = Some(
            pattern_init(g, bias_name, policy)
                .ok_or_else(|| mismatch(node, "bias must be an initializer"))?,
        );
        bias_node = Some(node_idx);
        walk.consume(node_idx, node)?;
        (node_idx, node) = walk.step(node)?;
    }

    // Cast INT32 -> FLOAT before the Mul-codified rescale.
    if node.op_type != "Cast" || node.attr_str("to") != Some("FLOAT") {
        return Err(mismatch(node, "expected Cast to FLOAT after accumulate"));
    }
    walk.consume(node_idx, node)?;
    (node_idx, node) = walk.step(node)?;

    // One or two scalar rescale Muls (§3.1: 1-Mul or 2-Mul codification).
    let mut muls = Vec::new();
    while node.op_type == "Mul" && muls.len() < 2 {
        let s_name = if node.inputs.first().map(String::as_str) == Some(walk.cur) {
            node.inputs.get(1)
        } else {
            node.inputs.first()
        }
        .filter(|n| !n.is_empty())
        .ok_or_else(|| mismatch(node, "malformed rescale Mul"))?;
        muls.push(
            scalar_f32_init(g, s_name, policy)
                .ok_or_else(|| mismatch(node, "rescale multiplier must be a scalar initializer"))?,
        );
        walk.consume(node_idx, node)?;
        (node_idx, node) = walk.step(node)?;
    }
    if muls.is_empty() {
        return Err(mismatch(node, "expected rescale Mul after Cast"));
    }

    // Optional ReLU on the rescaled f32 value (Fig. 2).
    let mut relu = false;
    if node.op_type == "Relu" {
        relu = true;
        walk.consume(node_idx, node)?;
        (node_idx, node) = walk.step(node)?;
    }

    // Optional Clip declaring a narrow logical output range (the
    // sub-8-bit codification): scalar f32 bounds that are exactly the
    // integer range of a sub-8-bit [`QType`]. The Clip is absorbed by
    // narrowing the chain's `out_qtype` — sound because the following
    // QuantizeLinear must then be the identity requantize (scale == 1,
    // zero point == 0, verified below), and for integer bounds
    // `round_half_even(clip(v, lo, hi)) == clamp(round_half_even(v), lo,
    // hi)` for every finite v (round is monotone and fixes the integer
    // endpoints), while NaN propagates through both paths to the same
    // saturating cast and ±inf pin to the same bound. Anything that
    // doesn't fit this shape declines, leaving the Clip to execute as
    // its own (bit-defined) node.
    let mut clip_qtype: Option<QType> = None;
    if node.op_type == "Clip" {
        if node.inputs.first().map(String::as_str) != Some(walk.cur) {
            return Err(mismatch(node, "chain value must be Clip's data input"));
        }
        let bound = |i: usize| -> Option<f32> {
            node.inputs
                .get(i)
                .filter(|n| !n.is_empty())
                .and_then(|n| scalar_f32_init(g, n, policy))
        };
        let (Some(lo), Some(hi)) = (bound(1), bound(2)) else {
            return Err(mismatch(node, "Clip bounds must be scalar initializers"));
        };
        if !(lo.is_finite() && hi.is_finite() && lo.fract() == 0.0 && hi.fract() == 0.0) {
            return Err(mismatch(node, "Clip bounds must be finite integers"));
        }
        let range = (lo as i32, hi as i32);
        let qt = (2..=8u8)
            .flat_map(|b| [QType::Int(b), QType::UInt(b)])
            .find(|qt| qt.range() == range)
            .ok_or_else(|| {
                mismatch(node, format!("Clip range [{lo}, {hi}] is not a width's range"))
            })?;
        clip_qtype = Some(qt);
        walk.consume(node_idx, node)?;
        (node_idx, node) = walk.step(node)?;
    }

    // Rounding + clipping stage.
    if node.op_type != "QuantizeLinear" {
        return Err(mismatch(node, "expected QuantizeLinear (round+clip)"));
    }
    let s_name = node
        .inputs
        .get(1)
        .filter(|n| !n.is_empty())
        .ok_or_else(|| mismatch(node, "QuantizeLinear missing scale"))?;
    let q_scale = scalar_f32_init(g, s_name, policy)
        .ok_or_else(|| mismatch(node, "requantize scale must be a scalar initializer"))?;
    let zp_name = node
        .inputs
        .get(2)
        .filter(|n| !n.is_empty())
        .ok_or_else(|| mismatch(node, "QuantizeLinear missing zero point"))?;
    let (q_zp, mut out_qtype) = scalar_zp_init(g, zp_name, policy)
        .ok_or_else(|| mismatch(node, "zero point must be a scalar i8/u8 initializer"))?;
    if let Some(narrow) = clip_qtype {
        // The absorption lemma above needs the identity requantize and a
        // container whose dtype matches the narrow type's signedness.
        if q_scale != 1.0 {
            return Err(mismatch(node, "Clip absorption requires requantize scale 1"));
        }
        if q_zp.numel() != 1
            || q_zp.as_quantized_i32().ok().and_then(|v| v.first().copied()) != Some(0)
        {
            return Err(mismatch(node, "Clip absorption requires zero point 0"));
        }
        if narrow.dtype() != out_qtype.dtype() {
            return Err(mismatch(
                node,
                "Clip range signedness does not match the container dtype",
            ));
        }
        out_qtype = narrow;
    }
    walk.consume(node_idx, node)?;

    Ok(QChain {
        anchor: anchor_idx,
        weight,
        bias,
        bias_node,
        muls,
        relu,
        q_scale,
        q_zp,
        out_qtype,
        nodes: walk.nodes,
        output: walk.cur,
    })
}

/// The matched activation chain (Figures 4–6): `DequantizeLinear
/// [+ Cast f16] + Tanh|Sigmoid [+ Cast f32] + QuantizeLinear`.
pub struct ActChain<'g> {
    /// The DequantizeLinear node index.
    pub deq: usize,
    /// True for the f16-evaluated variants (Figs. 5/6).
    pub f16: bool,
    pub act: ActFn,
    /// Dequantize scale (scalar initializer, by value) and zero point
    /// (scalar i8/u8 initializer when present; the paper's patterns emit
    /// 0).
    pub in_scale: f32,
    pub in_zp: Option<&'g Tensor>,
    /// Requantize scale + zero point of the final QuantizeLinear.
    pub out_scale: f32,
    pub out_zp: &'g Tensor,
    pub out_qtype: QType,
    pub nodes: Vec<usize>,
    pub output: &'g str,
}

/// Look ahead from a `DequantizeLinear`: does an activation chain follow
/// (vs an output-edge dequantization)? Errors only on a multi-consumer
/// dequantize output.
pub fn act_chain_follows(
    g: &Graph,
    idx: &ConsumerIndex<'_>,
    deq: &Node,
) -> Result<bool, MatchFail> {
    let Some(out) = deq.outputs.first().filter(|n| !n.is_empty()) else {
        return Ok(false);
    };
    Ok(matches!(
        idx.sole_consumer(g, out)?.map(|(_, n)| n.op_type.as_str()),
        Some("Cast") | Some("Tanh") | Some("Sigmoid")
    ))
}

/// Match the activation chain anchored at the `DequantizeLinear` node
/// `deq_idx`.
pub fn match_act_chain<'g>(
    g: &'g Graph,
    idx: &ConsumerIndex<'g>,
    deq_idx: usize,
    policy: InitPolicy,
) -> Result<ActChain<'g>, MatchFail> {
    let deq = &g.nodes[deq_idx];
    if deq.op_type != "DequantizeLinear" {
        return Err(mismatch(deq, "activation chain must start at DequantizeLinear"));
    }
    let s_name = deq
        .inputs
        .get(1)
        .filter(|n| !n.is_empty())
        .ok_or_else(|| mismatch(deq, "DequantizeLinear missing scale"))?;
    let in_scale = scalar_f32_init(g, s_name, policy)
        .ok_or_else(|| mismatch(deq, "dequantize scale must be a scalar initializer"))?;
    let in_zp = match deq.inputs.get(2).map(String::as_str) {
        None | Some("") => None,
        Some(name) => Some(
            scalar_zp_init(g, name, policy)
                .ok_or_else(|| mismatch(deq, "dequantize zero point must be a scalar i8/u8 initializer"))?
                .0,
        ),
    };

    let mut walk = Walk::start(g, idx, deq_idx)?;
    let (mut node_idx, mut node) = walk.step(deq)?;

    // Optional Cast FLOAT -> FLOAT16 (Figs. 5/6).
    let mut f16 = false;
    if node.op_type == "Cast" {
        if node.attr_str("to") != Some("FLOAT16") {
            return Err(mismatch(node, "expected Cast to FLOAT16 in act block"));
        }
        f16 = true;
        walk.consume(node_idx, node)?;
        (node_idx, node) = walk.step(node)?;
    }

    let act = match node.op_type.as_str() {
        "Tanh" => ActFn::Tanh,
        "Sigmoid" => ActFn::Sigmoid,
        op => return Err(mismatch(node, format!("expected Tanh/Sigmoid, got {op}"))),
    };
    walk.consume(node_idx, node)?;
    (node_idx, node) = walk.step(node)?;

    if f16 {
        if node.op_type != "Cast" || node.attr_str("to") != Some("FLOAT") {
            return Err(mismatch(node, "expected Cast back to FLOAT"));
        }
        walk.consume(node_idx, node)?;
        (node_idx, node) = walk.step(node)?;
    }

    if node.op_type != "QuantizeLinear" {
        return Err(mismatch(node, "expected final QuantizeLinear in act block"));
    }
    let s_name = node
        .inputs
        .get(1)
        .filter(|n| !n.is_empty())
        .ok_or_else(|| mismatch(node, "QuantizeLinear missing scale"))?;
    let out_scale = scalar_f32_init(g, s_name, policy)
        .ok_or_else(|| mismatch(node, "requantize scale must be a scalar initializer"))?;
    let zp_name = node
        .inputs
        .get(2)
        .filter(|n| !n.is_empty())
        .ok_or_else(|| mismatch(node, "QuantizeLinear missing zero point"))?;
    let (out_zp, out_qtype) = scalar_zp_init(g, zp_name, policy)
        .ok_or_else(|| mismatch(node, "zero point must be a scalar i8/u8 initializer"))?;
    walk.consume(node_idx, node)?;

    Ok(ActChain {
        deq: deq_idx,
        f16,
        act,
        in_scale,
        in_zp,
        out_scale,
        out_zp,
        out_qtype,
        nodes: walk.nodes,
        output: walk.cur,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::Figure;

    fn names(g: &Graph, nodes: &[usize]) -> Vec<String> {
        nodes.iter().map(|&i| g.nodes[i].op_type.clone()).collect()
    }

    #[test]
    fn matches_all_fc_and_conv_figures() {
        for fig in Figure::ALL {
            let m = fig.model();
            let g = &m.graph;
            let idx = ConsumerIndex::build(g);
            let anchor = g
                .nodes
                .iter()
                .position(|n| n.op_type == "MatMulInteger" || n.op_type == "ConvInteger")
                .unwrap();
            let chain = match_q_chain(g, &idx, anchor, InitPolicy::Bakeable)
                .unwrap_or_else(|_| panic!("{}: q-chain must match", fig.name()));
            assert!(chain.bias.is_some(), "{}", fig.name());
            assert!(!chain.muls.is_empty() && chain.muls.len() <= 2, "{}", fig.name());
            assert_eq!(chain.q_scale, 1.0, "{}", fig.name());
            // The chain covers the anchor through the first QuantizeLinear.
            assert_eq!(names(g, &chain.nodes).last().unwrap(), "QuantizeLinear");
        }
    }

    #[test]
    fn matches_act_chains_on_figures_4_to_6() {
        for (fig, f16, len) in [
            (Figure::Fig4TanhInt8, false, 3),
            (Figure::Fig5TanhF16, true, 5),
            (Figure::Fig6SigmoidF16, true, 5),
        ] {
            let m = fig.model();
            let g = &m.graph;
            let idx = ConsumerIndex::build(g);
            let deq = g
                .nodes
                .iter()
                .position(|n| n.op_type == "DequantizeLinear")
                .unwrap();
            let chain = match_act_chain(g, &idx, deq, InitPolicy::Bakeable)
                .unwrap_or_else(|_| panic!("{}: act chain must match", fig.name()));
            assert_eq!(chain.f16, f16, "{}", fig.name());
            assert_eq!(chain.nodes.len(), len, "{}", fig.name());
            assert_eq!(chain.output, m.graph.outputs[0].name, "{}", fig.name());
        }
    }

    #[test]
    fn multi_consumer_mid_chain_fails_with_multiconsumer() {
        use crate::onnx::ir::Attr;
        use crate::onnx::{batched, GraphBuilder};
        use crate::tensor::{DType, Tensor};
        let mut b = GraphBuilder::new("mc");
        b.input("x", DType::I8, &batched(&[4]));
        b.init("w", Tensor::from_i8(&[4, 2], vec![1; 8]).unwrap());
        b.init("s", Tensor::scalar_f32(0.5));
        b.init("one", Tensor::scalar_f32(1.0));
        b.init("zp", Tensor::scalar_i8(0));
        let acc = b.node("MatMulInteger", &["x", "w"], &[]);
        let f = b.node("Cast", &[&acc], &[("to", Attr::Str("FLOAT".into()))]);
        let m1 = b.node("Mul", &[&f, "s"], &[]);
        let y = b.node("QuantizeLinear", &[&m1, "one", "zp"], &[]);
        // Second consumer of the Cast output.
        let extra = b.node("Relu", &[&f], &[]);
        b.output(&y, DType::I8, &batched(&[2]));
        b.output(&extra, DType::F32, &batched(&[2]));
        let m = b.finish_model();
        let idx = ConsumerIndex::build(&m.graph);
        assert!(matches!(
            match_q_chain(&m.graph, &idx, 0, InitPolicy::Bakeable),
            Err(MatchFail::MultiConsumer { .. })
        ));
    }
}
