//! Plan-time graph optimizer: the pass pipeline between model checking
//! and [`CompiledPlan::compile`](crate::interp) lowering.
//!
//! The paper's whole point is that the pre-quantized patterns (Figures
//! 1–6) are *recognizable* from the standard ONNX stream, so a backend
//! can lift them into fused fixed-point stages. `hwsim::compile` has done
//! exactly that from the start — this module makes the recognition a
//! shared layer (the [`matcher`]) and gives the production interpreter
//! the same lift: instead of executing a `MatMulInteger + Add + Cast +
//! Mul(+Mul) [+Relu] + QuantizeLinear` chain as 6–7 steps with 6–7
//! intermediate tensors and as many full passes over the activation, the
//! compiled plan runs ONE fused kernel (packed int8 GEMM + a single
//! integer-rescale/saturate epilogue pass — [`crate::ops::fused`]).
//!
//! Passes, in order:
//! 1. **Quantized-FC fusion** — the FC chain above → [`Kernel::FusedQFc`].
//! 2. **Quantized-conv fusion** — the same chain over `ConvInteger` →
//!    [`Kernel::FusedQConv`].
//! 3. **LUT folding** — `DequantizeLinear [+Cast f16] + Tanh/Sigmoid
//!    [+Cast f32] + QuantizeLinear` → a 256-entry table
//!    ([`Kernel::FusedActLut`], sharing `quant::lut::ActLut` with hwsim).
//! 4. **Identity / no-op-reshape elimination** — `Identity` nodes and
//!    `Reshape/Flatten/Identity` feeding a 0-free-spec `Reshape` become
//!    value aliases instead of copy steps.
//! 5. **Dead-node elimination** — steps whose outputs reach no graph
//!    output are dropped (reverse liveness sweep).
//!
//! Every fused kernel is **bit-identical** to its node chain: the same
//! scalar arithmetic in the same order, just without materializing the
//! intermediates (the LUT precomputes the chain per 8-bit input; see
//! `quant::lut::ActLut::build_exact`). Any precondition failure — an
//! extra consumer on a mid-chain value, a non-initializer scale, a bias
//! layout the epilogue can't bake — declines the fusion and leaves those
//! nodes executing one by one, so correctness never depends on a pattern
//! firing (`tests/executor_plan.rs` proves both directions).

pub mod matcher;

use crate::onnx::ir::{Graph, Model};
use crate::onnx::shape::ValueType;
use crate::ops::bitpack::{self, PackedConvWeights, PackedWeights};
use crate::ops::fused::{ActPack, FusedActLut, FusedQConv, FusedQFc, QEpilogue};
use crate::ops::kernel::{prebind_conv_integer, prebind_matmul_integer};
use crate::ops::{matmul, Kernel};
use crate::quant::lut::{ActEval, ActLut};
use crate::quant::QType;
use crate::tensor::DType;
use matcher::{match_act_chain, match_q_chain, ConsumerIndex, InitPolicy, QChain};
use std::collections::{HashMap, HashSet};
use std::sync::OnceLock;

/// The `PQDL_PACK_WIDTH` knob: which weight widths plan-time baking may
/// select for the fused kernels.
///
/// `Auto` and `Int8` are policies (never fail); the narrow values are
/// *forcing* — they pin every fused chain to one storage width so CI and
/// benches exercise a specific kernel family deliberately, and they
/// reject the plan with a clear [`PackError`] when a chain's weights do
/// not admit the width (a silent fallback would defeat the pinning).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackWidth {
    /// Narrowest storage the widened weights admit: bipolar bit columns
    /// when every value is ±1, then crumb (int2) / tribble (int3) /
    /// nibble (int4) panels by range, else the i8 panels. The default.
    Auto,
    /// i8 panels only — pre-PR-9 behavior, and the CI width-matrix
    /// baseline (narrow baking can never change results, so this knob
    /// only moves memory, never bits).
    Int8,
    /// Force int4 nibble panels; plan-time error if any fused chain's
    /// weights leave `[-8, 7]`.
    Int4,
    /// Force int3 tribble panels; plan-time error outside `[-4, 3]`.
    Int3,
    /// Force int2 crumb panels; plan-time error outside `[-2, 1]`.
    Int2,
    /// Force bipolar bit columns; plan-time error unless strictly ±1.
    Bipolar,
}

impl PackWidth {
    pub fn name(&self) -> &'static str {
        match self {
            PackWidth::Auto => "auto",
            PackWidth::Int8 => "int8",
            PackWidth::Int4 => "int4",
            PackWidth::Int3 => "int3",
            PackWidth::Int2 => "int2",
            PackWidth::Bipolar => "bipolar",
        }
    }

    /// Parse a knob value; unknown strings are `None` (callers fall back
    /// to the default — same contract as `PQDL_TUNE`).
    pub fn from_name(s: &str) -> Option<PackWidth> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(PackWidth::Auto),
            "int8" => Some(PackWidth::Int8),
            "int4" => Some(PackWidth::Int4),
            "int3" => Some(PackWidth::Int3),
            "int2" => Some(PackWidth::Int2),
            "bipolar" => Some(PackWidth::Bipolar),
            _ => None,
        }
    }

    /// Process-wide mode, decided once (`OnceLock`) like `TuneMode` and
    /// `Isa::active`.
    pub fn active() -> PackWidth {
        static ACTIVE: OnceLock<PackWidth> = OnceLock::new();
        *ACTIVE.get_or_init(|| {
            std::env::var("PQDL_PACK_WIDTH")
                .ok()
                .and_then(|v| PackWidth::from_name(&v))
                .unwrap_or(PackWidth::Auto)
        })
    }
}

/// Plan-time rejection of a forced `PQDL_PACK_WIDTH`: a fused chain's
/// weights do not admit the requested storage width. Raised instead of
/// silently keeping wider panels — the forcing values exist to pin a
/// kernel family (CI dispatch matrix, benches), and a fallback would
/// defeat that pin. Surfaced through `SessionError::Pack`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackError {
    /// Anchor node of the fused chain whose weights failed to pack.
    pub node: String,
    /// The forced width's knob name (`"int4"`, `"bipolar"`, ...).
    pub width: &'static str,
    /// What the weights actually look like.
    pub reason: String,
}

impl std::fmt::Display for PackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PQDL_PACK_WIDTH={} rejected at plan time: node '{}': {}",
            self.width, self.node, self.reason
        )
    }
}

impl std::error::Error for PackError {}

/// Plan-compilation options. `fuse` (default: on) runs the pass pipeline;
/// sessions compile an unfused plan alongside regardless, for the
/// observer/calibration path and the `run_unplanned` oracle.
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    pub fuse: bool,
}

impl Default for PlanOptions {
    fn default() -> PlanOptions {
        PlanOptions { fuse: true }
    }
}

/// What the pass pipeline did to a plan (per-kind fused-kernel counts +
/// eliminated steps). Surfaced through `Session::plan_stats`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OptStats {
    pub fused_qfc: usize,
    pub fused_qconv: usize,
    pub fused_act_lut: usize,
    pub eliminated: usize,
    /// Fused kernels whose weights baked as int4 nibble panels.
    pub fused_int4: usize,
    /// Fused kernels whose weights baked as int3 tribble panels.
    pub fused_int3: usize,
    /// Fused kernels whose weights baked as int2 crumb panels.
    pub fused_int2: usize,
    /// Fused kernels whose weights baked as bipolar bit rows/columns.
    pub fused_bipolar: usize,
    /// Fused FC→FC edges where the producer emits its activation rows
    /// nibble-packed (two values per byte) for the consumer's int4
    /// activation GEMM.
    pub packed_act_nibble: usize,
    /// Fused FC→FC edges where the producer tries bitplane (±1) packing
    /// for the consumer's XNOR GEMM (runtime-gated: any 0 in the
    /// activation falls back to the i8 container for that batch).
    pub packed_act_bitplane: usize,
}

impl OptStats {
    pub fn fused_total(&self) -> usize {
        self.fused_qfc + self.fused_qconv + self.fused_act_lut
    }

    /// True when the optimized plan differs from the 1:1 lowering at all
    /// (used to share one plan allocation when it doesn't).
    pub fn changed(&self) -> bool {
        self.fused_total() + self.eliminated > 0
    }
}

/// One schedulable unit after optimization: a single graph node, or a
/// fused span executing as one kernel.
pub(crate) enum PlanItem {
    Node(usize),
    Fused {
        /// Covered graph-node indices, in chain order (anchor first).
        nodes: Vec<usize>,
        kernel: Kernel,
        /// The chain's single external data input (value name).
        input: String,
        /// The chain's output value name.
        output: String,
    },
}

/// The optimizer's output: the item schedule, value aliases from
/// eliminated no-op nodes (resolved transitively), and the stats.
pub(crate) struct Optimized {
    pub items: Vec<PlanItem>,
    pub aliases: HashMap<String, String>,
    pub stats: OptStats,
}

/// Run the pass pipeline over a checked model's schedule. `types` is the
/// checker's value-type map (used to pin the LUT input domain). The only
/// error is a forced `PQDL_PACK_WIDTH` the model's fused weights cannot
/// admit ([`PackError`]) — every other precondition failure declines its
/// pass and leaves the nodes unfused.
pub(crate) fn optimize(
    model: &Model,
    order: &[usize],
    types: &HashMap<String, ValueType>,
    opts: &PlanOptions,
) -> Result<Optimized, PackError> {
    let g = &model.graph;
    if !opts.fuse {
        return Ok(Optimized {
            items: order.iter().map(|&i| PlanItem::Node(i)).collect(),
            aliases: HashMap::new(),
            stats: OptStats::default(),
        });
    }

    let idx = ConsumerIndex::build(g);
    let mut stats = OptStats::default();

    // --- fusion passes (chain matching over the consumer index) ---------
    let mut claimed = vec![false; g.nodes.len()];
    let mut items: Vec<PlanItem> = Vec::with_capacity(order.len());
    for &i in order {
        if claimed[i] {
            continue; // absorbed into an earlier fused span
        }
        let fused = match g.nodes[i].op_type.as_str() {
            "MatMulInteger" => try_fuse_qfc(g, &idx, i)?,
            "ConvInteger" => try_fuse_qconv(g, &idx, i)?,
            "DequantizeLinear" => try_fuse_act_lut(g, &idx, i, types),
            _ => None,
        };
        match fused {
            Some(PlanItem::Fused { nodes, kernel, input, output })
                // Guard: a member already absorbed elsewhere (cannot
                // happen for the disjoint chain anchors, but cheap).
                if !nodes.iter().any(|&n| claimed[n]) =>
            {
                for &n in &nodes {
                    claimed[n] = true;
                }
                match &kernel {
                    Kernel::FusedQFc(f) => {
                        stats.fused_qfc += 1;
                        match f.bp.as_ref().map(|p| p.bits()) {
                            Some(4) => stats.fused_int4 += 1,
                            Some(3) => stats.fused_int3 += 1,
                            Some(2) => stats.fused_int2 += 1,
                            Some(1) => stats.fused_bipolar += 1,
                            _ => {}
                        }
                    }
                    Kernel::FusedQConv(f) => {
                        stats.fused_qconv += 1;
                        match f.wp.as_ref().map(|p| p.bits()) {
                            Some(4) => stats.fused_int4 += 1,
                            Some(3) => stats.fused_int3 += 1,
                            Some(2) => stats.fused_int2 += 1,
                            Some(1) => stats.fused_bipolar += 1,
                            _ => {}
                        }
                    }
                    Kernel::FusedActLut(_) => stats.fused_act_lut += 1,
                    _ => {}
                }
                items.push(PlanItem::Fused { nodes, kernel, input, output });
            }
            _ => items.push(PlanItem::Node(i)),
        }
    }

    // --- packed-activation pairing (fused FC -> fused FC edges) ---------
    // With packing enabled at all, a fused FC whose output feeds exactly
    // one other fused FC can hand the activation over in packed form —
    // the plan stamps the decision on both kernels (`emit` / `a_pack`).
    if PackWidth::active() != PackWidth::Int8 {
        pair_packed_activations(g, &idx, &mut items, &mut stats);
    }

    // --- identity / no-op-reshape elimination (value aliasing) ----------
    let mut removed = vec![false; items.len()];
    let mut aliases: HashMap<String, String> = HashMap::new();
    // An output name can alias away only if nothing outside the graph's
    // dataflow can see it — the same visibility rule the chain matcher
    // applies to fused mid-chain values.
    let eliminable = matcher::chain_internal;
    let canon = |aliases: &HashMap<String, String>, name: &str| -> String {
        aliases.get(name).cloned().unwrap_or_else(|| name.to_string())
    };
    for (pos, item) in items.iter().enumerate() {
        let PlanItem::Node(i) = item else { continue };
        let node = &g.nodes[*i];
        if node.op_type != "Identity" {
            continue;
        }
        let (Some(inp), Some(out)) = (node.inputs.first(), node.outputs.first()) else {
            continue;
        };
        if inp.is_empty() || out.is_empty() || !eliminable(g, out) {
            continue;
        }
        // Aliases stay transitively resolved because items are visited in
        // schedule order (the input's own alias, if any, already exists).
        let target = canon(&aliases, inp);
        aliases.insert(out.clone(), target);
        removed[pos] = true;
        stats.eliminated += 1;
    }

    // No-op reshape chains: `Reshape/Flatten/Identity -> Reshape(spec)`
    // collapses to the outer Reshape alone when the outer spec has no 0
    // entries (its result then depends only on element count, which the
    // inner shape-op preserves) and the inner value is chain-internal.
    let producer: HashMap<&str, usize> = items
        .iter()
        .enumerate()
        .filter_map(|(pos, item)| match item {
            PlanItem::Node(i) => g.nodes[*i]
                .outputs
                .first()
                .filter(|n| !n.is_empty())
                .map(|n| (n.as_str(), pos)),
            PlanItem::Fused { .. } => None,
        })
        .collect();
    let mut changed = true;
    while changed {
        changed = false;
        for pos in 0..items.len() {
            if removed[pos] {
                continue;
            }
            let PlanItem::Node(i) = &items[pos] else { continue };
            let node = &g.nodes[*i];
            if node.op_type != "Reshape" {
                continue;
            }
            let Some(spec_name) = node.inputs.get(1).filter(|n| !n.is_empty()) else {
                continue;
            };
            let spec_ok = matcher::pattern_init(g, spec_name, InitPolicy::Bakeable)
                .and_then(|t| t.as_i64().ok())
                .is_some_and(|s| !s.is_empty() && s.iter().all(|&d| d != 0));
            if !spec_ok {
                continue;
            }
            let Some(data) = node.inputs.first().filter(|n| !n.is_empty()) else {
                continue;
            };
            let Some(&inner_pos) = producer.get(data.as_str()) else {
                continue;
            };
            if removed[inner_pos] || inner_pos == pos {
                continue;
            }
            let PlanItem::Node(inner_i) = &items[inner_pos] else {
                continue;
            };
            let inner = &g.nodes[*inner_i];
            if !matches!(inner.op_type.as_str(), "Reshape" | "Flatten" | "Identity") {
                continue;
            }
            // The inner value must feed ONLY this Reshape and be invisible
            // outside the chain.
            let sole = matches!(
                idx.sole_consumer(g, data),
                Ok(Some((consumer, _))) if consumer == *i
            );
            if !sole || !eliminable(g, data) {
                continue;
            }
            let Some(inner_in) = inner.inputs.first().filter(|n| !n.is_empty()) else {
                continue;
            };
            let target = canon(&aliases, inner_in);
            aliases.insert(data.clone(), target);
            removed[inner_pos] = true;
            stats.eliminated += 1;
            changed = true;
        }
    }

    // --- dead-node elimination (reverse liveness over the schedule) ------
    let mut live: HashSet<String> = g
        .outputs
        .iter()
        .map(|vi| canon(&aliases, &vi.name))
        .collect();
    for pos in (0..items.len()).rev() {
        if removed[pos] {
            continue;
        }
        let (outputs, inputs): (Vec<&str>, Vec<&str>) = match &items[pos] {
            PlanItem::Node(i) => {
                let n = &g.nodes[*i];
                (
                    n.outputs.iter().filter(|o| !o.is_empty()).map(String::as_str).collect(),
                    n.inputs.iter().filter(|o| !o.is_empty()).map(String::as_str).collect(),
                )
            }
            PlanItem::Fused { input, output, .. } => {
                (vec![output.as_str()], vec![input.as_str()])
            }
        };
        if outputs.iter().any(|o| live.contains(&canon(&aliases, o))) {
            for inp in inputs {
                live.insert(canon(&aliases, inp));
            }
        } else {
            removed[pos] = true;
            stats.eliminated += 1;
        }
    }

    let items = items
        .into_iter()
        .zip(removed)
        .filter_map(|(item, dead)| (!dead).then_some(item))
        .collect();
    Ok(Optimized {
        items,
        aliases,
        stats,
    })
}

/// The packed-activation pairing pass (tentpole part b): for every fused
/// FC whose output value is chain-internal, consumed SOLELY by the anchor
/// of another fused FC with `a_zp == 0`, stamp a packed edge form on both
/// kernels. Nibble when the producer's epilogue saturates into `[-8, 7]`
/// (i8 container — packing is then infallible), bitplane when the
/// producer emits bipolar AND the consumer holds bit-packed weights
/// (runtime-gated: the epilogue can emit 0, which a bit plane cannot
/// carry, so those batches travel as the container and the consumer's
/// dtype dispatch falls back — no coordination needed). Bit-exactness:
/// the packed forms re-encode exactly the saturated values the container
/// would hold, and the consuming kernels accumulate them in the same
/// order ([`bitpack::gemm_i4a_bytes`], `gemm_xnor`).
fn pair_packed_activations(
    g: &Graph,
    idx: &ConsumerIndex<'_>,
    items: &mut [PlanItem],
    stats: &mut OptStats,
) {
    // Producer map: fused-FC output value -> item position.
    let mut producers: HashMap<&str, usize> = HashMap::new();
    for (pos, item) in items.iter().enumerate() {
        if let PlanItem::Fused {
            kernel: Kernel::FusedQFc(_),
            output,
            ..
        } = item
        {
            producers.insert(output.as_str(), pos);
        }
    }
    let mut pairs: Vec<(usize, usize, ActPack)> = Vec::new();
    for (pos, item) in items.iter().enumerate() {
        let PlanItem::Fused {
            kernel: Kernel::FusedQFc(cons),
            input,
            nodes,
            ..
        } = item
        else {
            continue;
        };
        let Some(&ppos) = producers.get(input.as_str()) else {
            continue;
        };
        if ppos == pos {
            continue;
        }
        let PlanItem::Fused {
            kernel: Kernel::FusedQFc(prod),
            ..
        } = &items[ppos]
        else {
            continue;
        };
        // The edge value must be invisible outside the pair and feed ONLY
        // the consumer chain's anchor — otherwise some other reader would
        // see a packed tensor where the graph promises an i8 container.
        if !matcher::chain_internal(g, input) {
            continue;
        }
        let sole = matches!(
            idx.sole_consumer(g, input),
            Ok(Some((consumer, _))) if consumer == nodes[0]
        );
        // Nibble/bitplane GEMMs carry no zero-point; the pairing demands
        // the symmetric case (a_zp == 0), the overwhelmingly common one
        // for i8 hidden activations.
        if !sole || cons.a_zp != 0 || prod.n != cons.k {
            continue;
        }
        if let Some(form) = packed_act_form(prod, cons) {
            pairs.push((ppos, pos, form));
        }
    }
    for (ppos, cpos, form) in pairs {
        if let PlanItem::Fused {
            kernel: Kernel::FusedQFc(f),
            ..
        } = &mut items[ppos]
        {
            f.emit = form;
        }
        if let PlanItem::Fused {
            kernel: Kernel::FusedQFc(f),
            ..
        } = &mut items[cpos]
        {
            f.a_pack = form;
        }
        match form {
            ActPack::Nibble => stats.packed_act_nibble += 1,
            ActPack::Bitplane => stats.packed_act_bitplane += 1,
            ActPack::Container => {}
        }
    }
}

/// Which packed form (if any) a fused FC -> fused FC edge admits.
fn packed_act_form(prod: &FusedQFc, cons: &FusedQFc) -> Option<ActPack> {
    let q = prod.epi.out_qtype;
    if q == QType::Bipolar {
        // XNOR consumption needs bit-packed weights on the other side.
        if matches!(cons.bp, Some(PackedWeights::Bipolar(_))) {
            return Some(ActPack::Bitplane);
        }
        return None;
    }
    if q.dtype() == DType::I8 {
        let (lo, hi) = q.range();
        if lo >= -8 && hi <= 7 {
            return Some(ActPack::Nibble);
        }
    }
    None
}

/// Backend-side preconditions shared by both fused epilogue builders:
/// the requantize scale must be one the unfused `QuantizeLinear` would
/// accept (a fused kernel must never turn a runtime error into silence).
fn build_epilogue(chain: &QChain<'_>) -> Option<QEpilogue> {
    if chain.q_scale <= 0.0 || !chain.q_scale.is_finite() {
        return None;
    }
    let zp = chain.q_zp.quantized_scalar_i32().ok()?;
    Some(QEpilogue {
        s1: chain.muls[0],
        s2: chain.muls.get(1).copied(),
        relu: chain.relu,
        inv_scale: 1.0 / chain.q_scale,
        zp,
        out_qtype: chain.out_qtype,
    })
}

/// Describe why a forced width can't hold these weights (the value range
/// the packers would refuse), for [`PackError::reason`].
fn width_refusal(w: &[i32], width: PackWidth) -> String {
    let lo = w.iter().copied().min().unwrap_or(0);
    let hi = w.iter().copied().max().unwrap_or(0);
    let admit = match width {
        PackWidth::Bipolar => "strictly ±1".to_string(),
        PackWidth::Int2 => "[-2, 1]".to_string(),
        PackWidth::Int3 => "[-4, 3]".to_string(),
        PackWidth::Int4 => "[-8, 7]".to_string(),
        _ => "<any>".to_string(),
    };
    format!(
        "widened weight values span [{lo}, {hi}], outside the {} range {admit} \
         (use PQDL_PACK_WIDTH=auto or int8 for this model)",
        width.name()
    )
}

/// Select the weight storage for a fused FC's widened weights (tentpole
/// of the sub-8-bit refactor). `Auto` walks the minimal-width ladder —
/// bipolar bit columns when strictly ±1, else crumb / tribble / nibble
/// panels by range — before keeping the i8 panels the prebinder already
/// built; `Int8` always keeps them; the forced narrow values pack that
/// width or fail with the refusal reason (the caller attaches the node
/// name). The choice can never change results: the fused kernels gate
/// the narrow paths on the activations at run time and fall back to the
/// widened-i32 loop over `bw` otherwise, and every narrow kernel is
/// bit-identical to that loop when it does engage (see `ops::bitpack`).
fn select_packed_fc(
    bw: &[i32],
    bp: Option<matmul::PackedB>,
    k: usize,
    n: usize,
) -> Result<Option<PackedWeights>, String> {
    let width = PackWidth::active();
    match width {
        PackWidth::Auto => {
            if bw.iter().all(|&v| v == 1 || v == -1) {
                if let Some(p) = bitpack::BitPackedB::pack(bw, k, n) {
                    return Ok(Some(PackedWeights::Bipolar(p)));
                }
            } else if bw.iter().all(|&v| (-2..=1).contains(&v)) {
                if let Some(p) = bitpack::PackedB2::pack(bw, k, n) {
                    return Ok(Some(PackedWeights::I2(p)));
                }
            } else if bw.iter().all(|&v| (-4..=3).contains(&v)) {
                if let Some(p) = bitpack::PackedB3::pack(bw, k, n) {
                    return Ok(Some(PackedWeights::I3(p)));
                }
            } else if bw.iter().all(|&v| (-8..=7).contains(&v)) {
                if let Some(p) = bitpack::PackedB4::pack(bw, k, n) {
                    return Ok(Some(PackedWeights::I4(p)));
                }
            }
            Ok(bp.map(PackedWeights::I8))
        }
        PackWidth::Int8 => Ok(bp.map(PackedWeights::I8)),
        PackWidth::Int4 => bitpack::PackedB4::pack(bw, k, n)
            .map(|p| Some(PackedWeights::I4(p)))
            .ok_or_else(|| width_refusal(bw, width)),
        PackWidth::Int3 => bitpack::PackedB3::pack(bw, k, n)
            .map(|p| Some(PackedWeights::I3(p)))
            .ok_or_else(|| width_refusal(bw, width)),
        PackWidth::Int2 => bitpack::PackedB2::pack(bw, k, n)
            .map(|p| Some(PackedWeights::I2(p)))
            .ok_or_else(|| width_refusal(bw, width)),
        PackWidth::Bipolar => bitpack::BitPackedB::pack(bw, k, n)
            .map(|p| Some(PackedWeights::Bipolar(p)))
            .ok_or_else(|| width_refusal(bw, width)),
    }
}

/// Conv twin of [`select_packed_fc`]: `wv` is the `[m, c*kh*kw]` weight
/// matrix the im2col GEMM streams against.
fn select_packed_conv(
    wv: &[i32],
    wp: Option<matmul::PackedA>,
    m: usize,
    k: usize,
) -> Result<Option<PackedConvWeights>, String> {
    let width = PackWidth::active();
    match width {
        PackWidth::Auto => {
            if wv.iter().all(|&v| v == 1 || v == -1) {
                if let Some(p) = bitpack::BitPackedA::pack(wv, m, k) {
                    return Ok(Some(PackedConvWeights::Bipolar(p)));
                }
            } else if wv.iter().all(|&v| (-2..=1).contains(&v)) {
                if let Some(p) = bitpack::PackedA2::pack(wv, m, k) {
                    return Ok(Some(PackedConvWeights::I2(p)));
                }
            } else if wv.iter().all(|&v| (-4..=3).contains(&v)) {
                if let Some(p) = bitpack::PackedA3::pack(wv, m, k) {
                    return Ok(Some(PackedConvWeights::I3(p)));
                }
            } else if wv.iter().all(|&v| (-8..=7).contains(&v)) {
                if let Some(p) = bitpack::PackedA4::pack(wv, m, k) {
                    return Ok(Some(PackedConvWeights::I4(p)));
                }
            }
            Ok(wp.map(PackedConvWeights::I8))
        }
        PackWidth::Int8 => Ok(wp.map(PackedConvWeights::I8)),
        PackWidth::Int4 => bitpack::PackedA4::pack(wv, m, k)
            .map(|p| Some(PackedConvWeights::I4(p)))
            .ok_or_else(|| width_refusal(wv, width)),
        PackWidth::Int3 => bitpack::PackedA3::pack(wv, m, k)
            .map(|p| Some(PackedConvWeights::I3(p)))
            .ok_or_else(|| width_refusal(wv, width)),
        PackWidth::Int2 => bitpack::PackedA2::pack(wv, m, k)
            .map(|p| Some(PackedConvWeights::I2(p)))
            .ok_or_else(|| width_refusal(wv, width)),
        PackWidth::Bipolar => bitpack::BitPackedA::pack(wv, m, k)
            .map(|p| Some(PackedConvWeights::Bipolar(p)))
            .ok_or_else(|| width_refusal(wv, width)),
    }
}

fn fused_item(nodes: Vec<usize>, kernel: Kernel, g: &Graph) -> PlanItem {
    let anchor = &g.nodes[nodes[0]];
    PlanItem::Fused {
        input: anchor.inputs[0].clone(),
        output: g.nodes[*nodes.last().unwrap()].outputs[0].clone(),
        nodes,
        kernel,
    }
}

/// Quantized-FC fusion: requires the matcher's chain plus the packed /
/// pre-widened weight baking (`prebind_matmul_integer`) and a bias the
/// row-broadcast epilogue reproduces exactly (`[N]` or `[1, N]` i32).
/// `Ok(None)` declines the fusion; `Err` propagates a forced-width
/// packing rejection (only possible once the chain WOULD fuse — unfused
/// chains make no packing decision).
fn try_fuse_qfc(
    g: &Graph,
    idx: &ConsumerIndex<'_>,
    anchor: usize,
) -> Result<Option<PlanItem>, PackError> {
    if g.nodes[anchor]
        .inputs
        .first()
        .filter(|n| !n.is_empty())
        .is_none()
    {
        return Ok(None);
    }
    let Ok(chain) = match_q_chain(g, idx, anchor, InitPolicy::Bakeable) else {
        return Ok(None);
    };
    let Some(Kernel::MatMulIntegerPrebound {
        bw,
        bp,
        k,
        n,
        a_zp,
        isa,
    }) = prebind_matmul_integer(&g.nodes[anchor], g)
    else {
        return Ok(None);
    };
    let bias = match chain.bias {
        None => None,
        Some(b) => {
            // `[N]` or `[1, N]` only: exactly the layouts whose broadcast
            // preserves the accumulator's shape (a rank-3+ bias would
            // rank-extend the unfused output; the anchor output is always
            // rank >= 2, so rank <= 2 suffices).
            if b.numel() != n || b.shape().last() != Some(&n) || b.rank() > 2 {
                return Ok(None); // layout the per-column epilogue can't bake
            }
            match b.as_i32() {
                Ok(v) => Some(v.to_vec()),
                Err(_) => return Ok(None),
            }
        }
    };
    let Some(epi) = build_epilogue(&chain) else {
        return Ok(None);
    };
    let bp = select_packed_fc(&bw, bp, k, n).map_err(|reason| PackError {
        node: g.nodes[anchor].name.clone(),
        width: PackWidth::active().name(),
        reason,
    })?;
    let kernel = Kernel::FusedQFc(FusedQFc {
        bw,
        bp,
        k,
        n,
        a_zp,
        bias,
        isa,
        epi,
        emit: ActPack::Container,
        a_pack: ActPack::Container,
    });
    Ok(Some(fused_item(chain.nodes, kernel, g)))
}

/// Quantized-conv fusion: the conv chain with a `[1, M, 1, 1]` i32 bias
/// (exactly the layout the emitted Fig. 3 pattern broadcasts). Error
/// semantics as in [`try_fuse_qfc`].
fn try_fuse_qconv(
    g: &Graph,
    idx: &ConsumerIndex<'_>,
    anchor: usize,
) -> Result<Option<PlanItem>, PackError> {
    if g.nodes[anchor]
        .inputs
        .first()
        .filter(|n| !n.is_empty())
        .is_none()
    {
        return Ok(None);
    }
    let Ok(chain) = match_q_chain(g, idx, anchor, InitPolicy::Bakeable) else {
        return Ok(None);
    };
    let Some(Kernel::ConvIntegerPrebound {
        wv,
        wp,
        m,
        c,
        kh,
        kw,
        x_zp,
        attrs,
        isa,
    }) = prebind_conv_integer(
        &g.nodes[anchor],
        g,
        &crate::onnx::shape::ConvAttrs::from_node(&g.nodes[anchor]),
    )
    else {
        return Ok(None);
    };
    let bias = match chain.bias {
        None => None,
        Some(b) => {
            if b.shape() != [1, m, 1, 1] {
                return Ok(None);
            }
            match b.as_i32() {
                Ok(v) => Some(v.to_vec()),
                Err(_) => return Ok(None),
            }
        }
    };
    let Some(epi) = build_epilogue(&chain) else {
        return Ok(None);
    };
    let wp = select_packed_conv(&wv, wp, m, c * kh * kw).map_err(|reason| PackError {
        node: g.nodes[anchor].name.clone(),
        width: PackWidth::active().name(),
        reason,
    })?;
    let kernel = Kernel::FusedQConv(FusedQConv {
        wv,
        wp,
        m,
        c,
        kh,
        kw,
        x_zp,
        attrs,
        bias,
        isa,
        epi,
    });
    Ok(Some(fused_item(chain.nodes, kernel, g)))
}

/// LUT folding: the activation chain becomes a 256-entry table built by
/// composing the interpreter's exact per-element arithmetic
/// ([`ActLut::build_exact`]). The input domain (i8 vs u8) comes from the
/// checker's type of the dequantize input; anything else declines.
fn try_fuse_act_lut(
    g: &Graph,
    idx: &ConsumerIndex<'_>,
    anchor: usize,
    types: &HashMap<String, ValueType>,
) -> Option<PlanItem> {
    let chain = match_act_chain(g, idx, anchor, InitPolicy::Bakeable).ok()?;
    let deq = &g.nodes[anchor];
    let in_name = deq.inputs.first().filter(|n| !n.is_empty())?;
    let in_qtype = match types.get(in_name.as_str()).map(|t| t.dtype) {
        Some(DType::I8) => QType::I8,
        Some(DType::U8) => QType::U8,
        _ => return None,
    };
    let in_zp = match chain.in_zp {
        None => 0,
        Some(t) => t.quantized_scalar_i32().ok()?,
    };
    if chain.out_scale <= 0.0 || !chain.out_scale.is_finite() {
        return None; // the unfused QuantizeLinear would error at run time
    }
    let out_zp = chain.out_zp.quantized_scalar_i32().ok()?;
    let eval = if chain.f16 { ActEval::F16 } else { ActEval::F32 };
    let lut = ActLut::build_exact(
        chain.act,
        eval,
        chain.in_scale,
        in_zp,
        in_qtype,
        chain.out_scale,
        out_zp,
        chain.out_qtype,
    );
    let kernel = Kernel::FusedActLut(FusedActLut { lut, in_qtype });
    Some(fused_item(chain.nodes, kernel, g))
}
