//! Calibration strategies — the part of the flow the paper argues should
//! be *decoupled* from the hardware compiler (§1, §3: "There are multiple
//! ways to determine the scale ... Precisely, this is one of the
//! motivations for this paper").
//!
//! Three strategies are provided, all producing a saturation threshold
//! `max_abs` that [`super::scheme::SymmetricScale`] maps to the integer
//! range:
//!
//! * [`MaxRange`] — profile the true |max| (the paper's first example).
//! * [`Percentile`] — histogram profile, saturate at a percentile (the
//!   paper's "profile histograms and saturating the numerical range").
//! * [`MseOptimal`] — choose the threshold minimizing expected squared
//!   quantization error over the histogram.

use super::scheme::{QType, QuantError, SymmetricScale};

/// A streaming observer of fp32 tensor values that yields a saturation
/// threshold.
pub trait Calibrator: Send {
    /// Account a batch of observed values.
    fn observe(&mut self, data: &[f32]);
    /// Saturation threshold (absolute value) after observation.
    fn threshold(&self) -> f32;
    /// Human-readable strategy name (reports/benches).
    fn name(&self) -> &'static str;

    /// Finish calibration into a scale for the given target type.
    fn scale(&self, qtype: QType) -> Result<SymmetricScale, QuantError> {
        SymmetricScale::from_max_abs(self.threshold(), qtype)
    }
}

/// Full-range calibration: threshold = max |x| observed.
#[derive(Default, Debug, Clone)]
pub struct MaxRange {
    max_abs: f32,
}

impl MaxRange {
    pub fn new() -> MaxRange {
        MaxRange::default()
    }
}

impl Calibrator for MaxRange {
    fn observe(&mut self, data: &[f32]) {
        for &x in data {
            let a = x.abs();
            if a.is_finite() && a > self.max_abs {
                self.max_abs = a;
            }
        }
    }

    fn threshold(&self) -> f32 {
        self.max_abs
    }

    fn name(&self) -> &'static str {
        "max_range"
    }
}

/// Fixed-capacity dynamic-range histogram of |x|. When a new maximum
/// exceeds the current range the bin width doubles (existing counts are
/// folded pairwise), so observation is single-pass and bounded-memory.
#[derive(Debug, Clone)]
pub struct AbsHistogram {
    counts: Vec<u64>,
    /// Upper edge of the histogram (bin width = range / counts.len()).
    range: f32,
    total: u64,
}

impl AbsHistogram {
    pub fn new(bins: usize) -> AbsHistogram {
        AbsHistogram {
            counts: vec![0; bins.max(16)],
            range: 0.0,
            total: 0,
        }
    }

    pub fn observe(&mut self, data: &[f32]) {
        for &x in data {
            let a = x.abs();
            if !a.is_finite() {
                continue;
            }
            if a > self.range {
                self.grow_to(a);
            }
            let n = self.counts.len();
            let idx = if self.range == 0.0 {
                0
            } else {
                (((a / self.range) * n as f32) as usize).min(n - 1)
            };
            self.counts[idx] += 1;
            self.total += 1;
        }
    }

    fn grow_to(&mut self, new_max: f32) {
        if self.range == 0.0 {
            self.range = new_max;
            return;
        }
        while self.range < new_max {
            // Double the range: fold bins pairwise into the lower half.
            let n = self.counts.len();
            for i in 0..n / 2 {
                self.counts[i] = self.counts[2 * i] + self.counts[2 * i + 1];
            }
            for c in &mut self.counts[n / 2..] {
                *c = 0;
            }
            self.range *= 2.0;
        }
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Threshold below which `pct` (0..=1) of observations fall.
    pub fn percentile(&self, pct: f32) -> f32 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (pct as f64 * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        let n = self.counts.len();
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.range * (i + 1) as f32 / n as f32;
            }
        }
        self.range
    }

    /// Expected squared quantization error if saturating at `threshold`
    /// with `levels` positive quantization levels. Clipped mass
    /// contributes its (bin-center - threshold)^2; in-range mass
    /// contributes the uniform-quantization step variance step^2/12.
    pub fn quant_mse(&self, threshold: f32, levels: f32) -> f64 {
        if self.total == 0 || threshold <= 0.0 {
            return 0.0;
        }
        let n = self.counts.len();
        let bin_w = self.range / n as f32;
        let step = threshold / levels;
        let in_range_var = (step as f64).powi(2) / 12.0;
        let mut err = 0.0f64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let center = (i as f32 + 0.5) * bin_w;
            if center <= threshold {
                err += c as f64 * in_range_var;
            } else {
                let clip = (center - threshold) as f64;
                err += c as f64 * clip * clip;
            }
        }
        err / self.total as f64
    }
}

/// Percentile calibration (e.g. 99.9%): ignores extreme outliers, the
/// "saturating the numerical range prior to mapping" strategy.
#[derive(Debug, Clone)]
pub struct Percentile {
    hist: AbsHistogram,
    pct: f32,
}

impl Percentile {
    pub fn new(pct: f32) -> Percentile {
        Percentile {
            hist: AbsHistogram::new(2048),
            pct,
        }
    }
}

impl Calibrator for Percentile {
    fn observe(&mut self, data: &[f32]) {
        self.hist.observe(data);
    }

    fn threshold(&self) -> f32 {
        self.hist.percentile(self.pct)
    }

    fn name(&self) -> &'static str {
        "percentile"
    }
}

/// MSE-optimal calibration: grid-searches the saturation threshold that
/// minimizes expected squared error under the observed distribution
/// (histogram variant of the minimize-overall-quantization-error
/// strategy the paper mentions).
#[derive(Debug, Clone)]
pub struct MseOptimal {
    hist: AbsHistogram,
    levels: f32,
}

impl MseOptimal {
    pub fn new(qtype: QType) -> MseOptimal {
        MseOptimal {
            hist: AbsHistogram::new(2048),
            levels: qtype.positive_levels(),
        }
    }
}

impl Calibrator for MseOptimal {
    fn observe(&mut self, data: &[f32]) {
        self.hist.observe(data);
    }

    fn threshold(&self) -> f32 {
        let hi = self.hist.percentile(1.0);
        if hi == 0.0 {
            return 0.0;
        }
        // Search thresholds from 30% to 100% of the observed max.
        let mut best = hi;
        let mut best_err = f64::INFINITY;
        for i in 30..=100 {
            let t = hi * i as f32 / 100.0;
            let e = self.hist.quant_mse(t, self.levels);
            if e < best_err {
                best_err = e;
                best = t;
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "mse"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_range_tracks_extremes() {
        let mut c = MaxRange::new();
        c.observe(&[0.5, -3.0, 2.0]);
        c.observe(&[1.0]);
        assert_eq!(c.threshold(), 3.0);
    }

    #[test]
    fn max_range_ignores_nan_inf() {
        let mut c = MaxRange::new();
        c.observe(&[1.0, f32::NAN, f32::INFINITY]);
        assert_eq!(c.threshold(), 1.0);
    }

    #[test]
    fn histogram_grows_and_counts() {
        let mut h = AbsHistogram::new(64);
        h.observe(&[0.1; 100]);
        h.observe(&[10.0]); // forces range growth
        assert_eq!(h.total(), 101);
        assert!(h.percentile(1.0) >= 10.0 * 63.0 / 64.0);
    }

    #[test]
    fn percentile_clips_outliers() {
        let mut c = Percentile::new(0.95);
        // 990 values at ~1.0, 10 outliers (1%) at 100: the 95th
        // percentile lies firmly inside the bulk.
        let mut data = vec![1.0f32; 990];
        data.extend(vec![100.0f32; 10]);
        c.observe(&data);
        let t = c.threshold();
        assert!(t < 10.0, "threshold {t} should ignore the 1% outliers");
        assert!(t >= 0.9);
    }

    #[test]
    fn mse_saturates_heavy_tail() {
        let mut c = MseOptimal::new(QType::I8);
        // Bulk in [-1,1] plus a *population* of moderate outliers (not a
        // single point — a lone extreme value genuinely dominates MSE and
        // must be kept; a thin tail should be clipped).
        let mut data: Vec<f32> =
            (0..100_000).map(|i| ((i % 200) as f32 - 100.0) / 100.0).collect();
        data.extend((0..20).map(|i| 10.0 + i as f32));
        c.observe(&data);
        let t = c.threshold();
        let max_t = {
            let mut m = MaxRange::new();
            m.observe(&data);
            m.threshold()
        };
        assert_eq!(max_t, 29.0);
        // The chosen threshold must never be worse than full-range, and
        // here the tail is thin enough that clipping wins.
        assert!(
            c.hist.quant_mse(t, 127.0) <= c.hist.quant_mse(max_t, 127.0) + 1e-12,
            "mse({t}) > mse({max_t})"
        );
        assert!(t < max_t, "threshold {t} should clip the thin tail");
    }

    #[test]
    fn calibrators_produce_valid_scales() {
        for c in [&mut MaxRange::new() as &mut dyn Calibrator] {
            c.observe(&[0.3, -0.7]);
            let s = c.scale(QType::I8).unwrap();
            assert!(s.scale > 0.0);
        }
        let mut p = Percentile::new(0.999);
        p.observe(&[0.3, -0.7]);
        assert!(p.scale(QType::I8).unwrap().scale > 0.0);
        let mut m = MseOptimal::new(QType::I8);
        m.observe(&[0.3, -0.7]);
        assert!(m.scale(QType::I8).unwrap().scale > 0.0);
    }
}
