//! Symmetric quantization scheme (paper §3, Eq. 1): `X = scale_X * X_q`
//! with zero offset. int8 for signed tensors, uint8 for provably
//! non-negative ones (post-ReLU / post-Sigmoid, Figure 6).

use crate::ops::qlinear::round_half_even;
use crate::tensor::{DType, Tensor, TensorData};
use thiserror::Error;

#[derive(Error, Debug)]
pub enum QuantError {
    #[error("invalid scale {0} (must be finite and > 0)")]
    BadScale(f32),
    #[error("multiplier {0} out of decomposable range")]
    BadMultiplier(f32),
    #[error("tensor: {0}")]
    Tensor(#[from] crate::tensor::TensorError),
    #[error("{0}")]
    Other(String),
}

/// Quantized integer target type.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QType {
    I8,
    U8,
}

impl QType {
    pub fn dtype(self) -> DType {
        match self {
            QType::I8 => DType::I8,
            QType::U8 => DType::U8,
        }
    }

    /// Integer range the quantized values live in.
    pub fn range(self) -> (i32, i32) {
        match self {
            QType::I8 => (-128, 127),
            QType::U8 => (0, 255),
        }
    }

    /// The positive magnitude the scale maps onto (127 for symmetric
    /// int8 — the paper's scheme keeps ±ranges symmetric so -128 is
    /// never produced by quantization, only by saturating arithmetic —
    /// and 255 for uint8 one-sided data).
    pub fn positive_levels(self) -> f32 {
        match self {
            QType::I8 => 127.0,
            QType::U8 => 255.0,
        }
    }
}

/// A per-tensor symmetric scale: `x ≈ scale * q`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SymmetricScale {
    pub scale: f32,
    pub qtype: QType,
}

impl SymmetricScale {
    /// Scale from an observed absolute maximum (the "map max range to the
    /// full int8 range" strategy of §3; other calibrators feed a
    /// saturated max_abs here instead).
    pub fn from_max_abs(max_abs: f32, qtype: QType) -> Result<SymmetricScale, QuantError> {
        if !max_abs.is_finite() || max_abs < 0.0 {
            return Err(QuantError::BadScale(max_abs));
        }
        // Degenerate all-zero tensor: scale 1 encodes zeros exactly.
        let max_abs = if max_abs == 0.0 { 1.0 } else { max_abs };
        let scale = max_abs / qtype.positive_levels();
        if !scale.is_finite() || scale <= 0.0 {
            return Err(QuantError::BadScale(scale));
        }
        Ok(SymmetricScale { scale, qtype })
    }

    /// Quantize an fp32 tensor: `q = clip(round(x / scale))` with
    /// round-half-to-even, matching ONNX QuantizeLinear.
    pub fn quantize(&self, x: &Tensor) -> Result<Tensor, QuantError> {
        let xv = x.as_f32()?;
        let inv = 1.0 / self.scale;
        let (lo, hi) = self.qtype.range();
        let data = match self.qtype {
            QType::I8 => TensorData::I8(
                xv.iter()
                    .map(|&v| round_half_even(v * inv).clamp(lo as f32, hi as f32) as i8)
                    .collect(),
            ),
            QType::U8 => TensorData::U8(
                xv.iter()
                    .map(|&v| round_half_even(v * inv).clamp(lo as f32, hi as f32) as u8)
                    .collect(),
            ),
        };
        Ok(Tensor::new(x.shape().to_vec(), data)?)
    }

    /// Dequantize back to fp32 (Eq. 1).
    pub fn dequantize(&self, q: &Tensor) -> Result<Tensor, QuantError> {
        let v: Vec<f32> = q
            .as_quantized_i32()?
            .iter()
            .map(|&x| x as f32 * self.scale)
            .collect();
        Ok(Tensor::from_f32(q.shape(), v)?)
    }

    /// Worst-case absolute reconstruction error for in-range inputs:
    /// half a quantization step.
    pub fn max_error(&self) -> f32 {
        self.scale * 0.5
    }
}

/// Quantize a bias vector to i32 at the accumulator scale (Eq. 6):
/// `B_q = round(B / (scale_W * scale_X))`.
pub fn quantize_bias(bias: &Tensor, scale_w: f32, scale_x: f32) -> Result<Tensor, QuantError> {
    let s = scale_w * scale_x;
    if !s.is_finite() || s <= 0.0 {
        return Err(QuantError::BadScale(s));
    }
    let v: Vec<i32> = bias
        .as_f32()?
        .iter()
        .map(|&b| {
            round_half_even((b as f64 / s as f64) as f32)
                .clamp(i32::MIN as f32, i32::MAX as f32) as i32
        })
        .collect();
    Ok(Tensor::from_i32(bias.shape(), v)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_maps_max_to_full_range() {
        let s = SymmetricScale::from_max_abs(12.7, QType::I8).unwrap();
        assert!((s.scale - 0.1).abs() < 1e-6);
        let x = Tensor::from_f32(&[3], vec![12.7, -12.7, 0.0]).unwrap();
        let q = s.quantize(&x).unwrap();
        assert_eq!(q.as_i8().unwrap(), &[127, -127, 0]);
    }

    #[test]
    fn uint8_one_sided() {
        let s = SymmetricScale::from_max_abs(25.5, QType::U8).unwrap();
        let x = Tensor::from_f32(&[3], vec![25.5, 12.75, -3.0]).unwrap();
        let q = s.quantize(&x).unwrap();
        // Negative values clamp to 0 in the one-sided uint8 scheme.
        assert_eq!(q.as_u8().unwrap(), &[255, 128, 0]);
    }

    #[test]
    fn round_trip_error_bounded() {
        let s = SymmetricScale::from_max_abs(1.0, QType::I8).unwrap();
        let xs: Vec<f32> = (0..201).map(|i| -1.0 + i as f32 * 0.01).collect();
        let x = Tensor::from_f32(&[xs.len()], xs.clone()).unwrap();
        let rt = s.dequantize(&s.quantize(&x).unwrap()).unwrap();
        for (a, b) in xs.iter().zip(rt.as_f32().unwrap()) {
            assert!((a - b).abs() <= s.max_error() + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_tensor_ok() {
        let s = SymmetricScale::from_max_abs(0.0, QType::I8).unwrap();
        assert_eq!(s.scale, 1.0 / 127.0);
    }

    #[test]
    fn bias_quantization_eq6() {
        // B_q = B / (scale_W * scale_X)
        let b = Tensor::from_f32(&[2], vec![1.0, -0.5]).unwrap();
        let q = quantize_bias(&b, 0.1, 0.05).unwrap();
        assert_eq!(q.as_i32().unwrap(), &[200, -100]);
    }

    #[test]
    fn bias_large_values_saturate_i32() {
        let b = Tensor::from_f32(&[1], vec![1e30]).unwrap();
        let q = quantize_bias(&b, 1e-6, 1e-6).unwrap();
        assert_eq!(q.as_i32().unwrap()[0], i32::MAX);
    }

    #[test]
    fn rejects_bad_scales() {
        assert!(SymmetricScale::from_max_abs(f32::NAN, QType::I8).is_err());
        assert!(SymmetricScale::from_max_abs(-1.0, QType::I8).is_err());
        let b = Tensor::from_f32(&[1], vec![0.0]).unwrap();
        assert!(quantize_bias(&b, 0.0, 1.0).is_err());
    }
}
