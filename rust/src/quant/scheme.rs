//! Symmetric quantization scheme (paper §3, Eq. 1): `X = scale_X * X_q`
//! with zero offset — generalized, QONNX-style, to arbitrary logical
//! widths. The paper's instantiation (int8 for signed tensors, uint8 for
//! provably non-negative ones, Figure 6) is the `QType::I8` / `QType::U8`
//! pair; narrower widths (int{2..8}, uint{2..8}, bipolar {-1,+1}) carry
//! their values in the same i8/u8 **container** with a declared narrow
//! **logical** range, so every existing kernel runs them unchanged and
//! bit-packed kernels can opt in where the payoff exists.

use crate::ops::qlinear::round_half_even;
use crate::tensor::{DType, Tensor, TensorData};
use thiserror::Error;

#[derive(Error, Debug)]
pub enum QuantError {
    #[error("invalid scale {0} (must be finite and > 0)")]
    BadScale(f32),
    #[error("multiplier {0} out of decomposable range")]
    BadMultiplier(f32),
    #[error("tensor: {0}")]
    Tensor(#[from] crate::tensor::TensorError),
    #[error("{0}")]
    Other(String),
}

/// Quantized integer target type: a logical width plus signedness.
///
/// `Int(b)` / `UInt(b)` are signed/unsigned integers of `b ∈ 2..=8`
/// logical bits; `Bipolar` is the two-level {-1,+1} scheme of binarized
/// networks (no zero — packs one bit per weight in the XNOR kernels).
/// The **container** dtype every variant is stored and computed in stays
/// i8/u8 (`dtype()`), mirroring QONNX's container-vs-logical-width split:
/// a narrow tensor is an i8 tensor whose values provably fit `range()`.
///
/// Range, packing density, and rescale magnitudes are *derived* from the
/// width here — never matched per-variant at a use site — so adding a
/// width cannot drift the clamp bounds of any downstream epilogue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QType {
    /// Signed int of the given logical bit width (2..=8), i8 container.
    Int(u8),
    /// Unsigned int of the given logical bit width (2..=8), u8 container.
    UInt(u8),
    /// Two-level {-1, +1}, i8 container, one logical bit per value.
    Bipolar,
}

impl QType {
    /// The paper's signed instantiation. An associated const, not an enum
    /// variant — construction sites read the same, but range math is now
    /// derived from the width.
    pub const I8: QType = QType::Int(8);
    /// The paper's unsigned instantiation (post-ReLU / post-Sigmoid).
    pub const U8: QType = QType::UInt(8);
    /// 4-bit signed: two values per container byte once packed.
    pub const I4: QType = QType::Int(4);

    /// Logical bits carried per value (Bipolar is one bit: sign).
    pub fn bits(self) -> u8 {
        match self {
            QType::Int(b) | QType::UInt(b) => b,
            QType::Bipolar => 1,
        }
    }

    pub fn signed(self) -> bool {
        !matches!(self, QType::UInt(_))
    }

    /// Container dtype the values are stored and computed in.
    pub fn dtype(self) -> DType {
        if self.signed() {
            DType::I8
        } else {
            DType::U8
        }
    }

    /// Logical integer range, derived from width. This is the single
    /// source the checker, hwsim saturation, and the fused epilogues all
    /// clamp with.
    pub fn range(self) -> (i32, i32) {
        match self {
            QType::Int(b) => (-(1i32 << (b - 1)), (1i32 << (b - 1)) - 1),
            QType::UInt(b) => (0, (1i32 << b) - 1),
            QType::Bipolar => (-1, 1),
        }
    }

    /// The positive magnitude the scale maps onto (127 for symmetric
    /// int8 — the paper's scheme keeps ±ranges symmetric so -2^(b-1) is
    /// never produced by quantization, only by saturating arithmetic —
    /// and 2^b - 1 for one-sided unsigned data; 1 for bipolar).
    pub fn positive_levels(self) -> f32 {
        self.range().1 as f32
    }

    /// Values per container byte once bit-packed (8 for bipolar, 2 for
    /// int4, 1 for int8 — intermediate widths round down to their packed
    /// density even though only 4/1-bit kernels exist today).
    pub fn packed_per_byte(self) -> usize {
        8 / self.bits() as usize
    }

    /// True when a dedicated bit-packed kernel family exists for this
    /// width (int4 nibble GEMM, bipolar XNOR-popcount GEMM, int2 crumb
    /// and int3 tribble GEMMs).
    pub fn has_packed_kernel(self) -> bool {
        matches!(
            self,
            QType::Bipolar | QType::Int(4) | QType::Int(3) | QType::Int(2)
        )
    }

    /// Canonical lowercase name ("int8", "uint4", "bipolar", …).
    pub fn name(self) -> String {
        match self {
            QType::Int(b) => format!("int{b}"),
            QType::UInt(b) => format!("uint{b}"),
            QType::Bipolar => "bipolar".to_string(),
        }
    }

    /// Parse a canonical name back into a `QType`.
    pub fn parse(s: &str) -> Option<QType> {
        if s == "bipolar" {
            return Some(QType::Bipolar);
        }
        let (signed, rest) = if let Some(r) = s.strip_prefix("uint") {
            (false, r)
        } else if let Some(r) = s.strip_prefix("int") {
            (true, r)
        } else {
            return None;
        };
        let b: u8 = rest.parse().ok()?;
        if !(2..=8).contains(&b) {
            return None;
        }
        Some(if signed { QType::Int(b) } else { QType::UInt(b) })
    }

    /// Narrowest `QType` whose logical range covers every value, matching
    /// the observed signedness. `{-1,+1}`-only data (no zero) infers
    /// `Bipolar`; all-zero data degenerates to the widest type of its
    /// signedness so a zero tensor never claims a 1-bit kernel.
    pub fn minimal_for(values: &[i32]) -> Option<QType> {
        let (mut lo, mut hi) = (i32::MAX, i32::MIN);
        for &v in values {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if values.is_empty() || lo > hi {
            return None;
        }
        if lo >= -1 && hi <= 1 && values.iter().all(|&v| v != 0) {
            return Some(QType::Bipolar);
        }
        if lo >= 0 {
            for b in 2..=8u8 {
                if hi <= (1i32 << b) - 1 {
                    return Some(QType::UInt(b));
                }
            }
        } else {
            for b in 2..=8u8 {
                if lo >= -(1i32 << (b - 1)) && hi <= (1i32 << (b - 1)) - 1 {
                    return Some(QType::Int(b));
                }
            }
        }
        None
    }

    /// True when every value fits this type's logical range (and, for
    /// bipolar, is exactly ±1).
    pub fn admits(self, values: &[i32]) -> bool {
        let (lo, hi) = self.range();
        match self {
            QType::Bipolar => values.iter().all(|&v| v == -1 || v == 1),
            _ => values.iter().all(|&v| v >= lo && v <= hi),
        }
    }
}

/// A per-tensor symmetric scale: `x ≈ scale * q`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SymmetricScale {
    pub scale: f32,
    pub qtype: QType,
}

impl SymmetricScale {
    /// Scale from an observed absolute maximum (the "map max range to the
    /// full int8 range" strategy of §3; other calibrators feed a
    /// saturated max_abs here instead).
    pub fn from_max_abs(max_abs: f32, qtype: QType) -> Result<SymmetricScale, QuantError> {
        if !max_abs.is_finite() || max_abs < 0.0 {
            return Err(QuantError::BadScale(max_abs));
        }
        // Degenerate all-zero tensor: scale 1 encodes zeros exactly.
        let max_abs = if max_abs == 0.0 { 1.0 } else { max_abs };
        let scale = max_abs / qtype.positive_levels();
        if !scale.is_finite() || scale <= 0.0 {
            return Err(QuantError::BadScale(scale));
        }
        Ok(SymmetricScale { scale, qtype })
    }

    /// Quantize an fp32 tensor: `q = clip(round(x / scale))` with
    /// round-half-to-even, matching ONNX QuantizeLinear. The clamp bounds
    /// come from the qtype's derived logical range, so sub-8-bit types
    /// produce values that provably fit their declared width while living
    /// in the same i8/u8 container. `Bipolar` is the exception: it has no
    /// zero level, so it binarizes by sign (`x >= 0 → +1`), the standard
    /// BNN deterministic binarization.
    pub fn quantize(&self, x: &Tensor) -> Result<Tensor, QuantError> {
        let xv = x.as_f32()?;
        if self.qtype == QType::Bipolar {
            let data = TensorData::I8(xv.iter().map(|&v| if v >= 0.0 { 1 } else { -1 }).collect());
            return Ok(Tensor::new(x.shape().to_vec(), data)?);
        }
        let inv = 1.0 / self.scale;
        let (lo, hi) = self.qtype.range();
        let quant = |v: f32| round_half_even(v * inv).clamp(lo as f32, hi as f32);
        let data = match self.qtype.dtype() {
            DType::I8 => TensorData::I8(xv.iter().map(|&v| quant(v) as i8).collect()),
            _ => TensorData::U8(xv.iter().map(|&v| quant(v) as u8).collect()),
        };
        Ok(Tensor::new(x.shape().to_vec(), data)?)
    }

    /// Dequantize back to fp32 (Eq. 1).
    pub fn dequantize(&self, q: &Tensor) -> Result<Tensor, QuantError> {
        let v: Vec<f32> = q
            .as_quantized_i32()?
            .iter()
            .map(|&x| x as f32 * self.scale)
            .collect();
        Ok(Tensor::from_f32(q.shape(), v)?)
    }

    /// Worst-case absolute reconstruction error for in-range inputs:
    /// half a quantization step.
    pub fn max_error(&self) -> f32 {
        self.scale * 0.5
    }
}

/// Quantize a bias vector to i32 at the accumulator scale (Eq. 6):
/// `B_q = round(B / (scale_W * scale_X))`.
pub fn quantize_bias(bias: &Tensor, scale_w: f32, scale_x: f32) -> Result<Tensor, QuantError> {
    let s = scale_w * scale_x;
    if !s.is_finite() || s <= 0.0 {
        return Err(QuantError::BadScale(s));
    }
    let v: Vec<i32> = bias
        .as_f32()?
        .iter()
        .map(|&b| {
            round_half_even((b as f64 / s as f64) as f32)
                .clamp(i32::MIN as f32, i32::MAX as f32) as i32
        })
        .collect();
    Ok(Tensor::from_i32(bias.shape(), v)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_maps_max_to_full_range() {
        let s = SymmetricScale::from_max_abs(12.7, QType::I8).unwrap();
        assert!((s.scale - 0.1).abs() < 1e-6);
        let x = Tensor::from_f32(&[3], vec![12.7, -12.7, 0.0]).unwrap();
        let q = s.quantize(&x).unwrap();
        assert_eq!(q.as_i8().unwrap(), &[127, -127, 0]);
    }

    #[test]
    fn uint8_one_sided() {
        let s = SymmetricScale::from_max_abs(25.5, QType::U8).unwrap();
        let x = Tensor::from_f32(&[3], vec![25.5, 12.75, -3.0]).unwrap();
        let q = s.quantize(&x).unwrap();
        // Negative values clamp to 0 in the one-sided uint8 scheme.
        assert_eq!(q.as_u8().unwrap(), &[255, 128, 0]);
    }

    #[test]
    fn round_trip_error_bounded() {
        let s = SymmetricScale::from_max_abs(1.0, QType::I8).unwrap();
        let xs: Vec<f32> = (0..201).map(|i| -1.0 + i as f32 * 0.01).collect();
        let x = Tensor::from_f32(&[xs.len()], xs.clone()).unwrap();
        let rt = s.dequantize(&s.quantize(&x).unwrap()).unwrap();
        for (a, b) in xs.iter().zip(rt.as_f32().unwrap()) {
            assert!((a - b).abs() <= s.max_error() + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_tensor_ok() {
        let s = SymmetricScale::from_max_abs(0.0, QType::I8).unwrap();
        assert_eq!(s.scale, 1.0 / 127.0);
    }

    #[test]
    fn bias_quantization_eq6() {
        // B_q = B / (scale_W * scale_X)
        let b = Tensor::from_f32(&[2], vec![1.0, -0.5]).unwrap();
        let q = quantize_bias(&b, 0.1, 0.05).unwrap();
        assert_eq!(q.as_i32().unwrap(), &[200, -100]);
    }

    #[test]
    fn bias_large_values_saturate_i32() {
        let b = Tensor::from_f32(&[1], vec![1e30]).unwrap();
        let q = quantize_bias(&b, 1e-6, 1e-6).unwrap();
        assert_eq!(q.as_i32().unwrap()[0], i32::MAX);
    }

    #[test]
    fn rejects_bad_scales() {
        assert!(SymmetricScale::from_max_abs(f32::NAN, QType::I8).is_err());
        assert!(SymmetricScale::from_max_abs(-1.0, QType::I8).is_err());
        let b = Tensor::from_f32(&[1], vec![0.0]).unwrap();
        assert!(quantize_bias(&b, 0.0, 1.0).is_err());
    }

    #[test]
    fn ranges_derived_from_width() {
        assert_eq!(QType::I8.range(), (-128, 127));
        assert_eq!(QType::U8.range(), (0, 255));
        assert_eq!(QType::Int(4).range(), (-8, 7));
        assert_eq!(QType::UInt(4).range(), (0, 15));
        assert_eq!(QType::Int(2).range(), (-2, 1));
        assert_eq!(QType::Bipolar.range(), (-1, 1));
        assert_eq!(QType::I8.positive_levels(), 127.0);
        assert_eq!(QType::U8.positive_levels(), 255.0);
        assert_eq!(QType::Int(4).positive_levels(), 7.0);
    }

    #[test]
    fn container_and_density_derived() {
        assert_eq!(QType::Int(4).dtype(), DType::I8);
        assert_eq!(QType::UInt(4).dtype(), DType::U8);
        assert_eq!(QType::Bipolar.dtype(), DType::I8);
        assert_eq!(QType::I8.packed_per_byte(), 1);
        assert_eq!(QType::Int(4).packed_per_byte(), 2);
        assert_eq!(QType::Bipolar.packed_per_byte(), 8);
        assert!(QType::Int(4).has_packed_kernel());
        assert!(QType::Bipolar.has_packed_kernel());
        assert!(QType::Int(3).has_packed_kernel());
        assert!(QType::Int(2).has_packed_kernel());
        assert!(!QType::I8.has_packed_kernel());
        assert!(!QType::Int(5).has_packed_kernel());
        assert!(!QType::UInt(4).has_packed_kernel());
    }

    #[test]
    fn name_parse_round_trip() {
        for q in [
            QType::I8,
            QType::U8,
            QType::Int(4),
            QType::UInt(3),
            QType::Int(2),
            QType::Bipolar,
        ] {
            assert_eq!(QType::parse(&q.name()), Some(q), "{}", q.name());
        }
        assert_eq!(QType::parse("int8"), Some(QType::I8));
        assert!(QType::parse("int1").is_none());
        assert!(QType::parse("int9").is_none());
        assert!(QType::parse("float32").is_none());
    }

    #[test]
    fn minimal_for_infers_width_and_bipolarity() {
        assert_eq!(QType::minimal_for(&[-1, 1, 1]), Some(QType::Bipolar));
        // A zero forbids bipolar (no zero level).
        assert_eq!(QType::minimal_for(&[-1, 0, 1]), Some(QType::Int(2)));
        assert_eq!(QType::minimal_for(&[-8, 7]), Some(QType::Int(4)));
        assert_eq!(QType::minimal_for(&[-9, 7]), Some(QType::Int(5)));
        assert_eq!(QType::minimal_for(&[0, 15]), Some(QType::UInt(4)));
        assert_eq!(QType::minimal_for(&[-128, 127]), Some(QType::I8));
        assert_eq!(QType::minimal_for(&[300]), None);
        assert_eq!(QType::minimal_for(&[]), None);
    }

    #[test]
    fn admits_checks_logical_range() {
        assert!(QType::Int(4).admits(&[-8, 0, 7]));
        assert!(!QType::Int(4).admits(&[8]));
        assert!(QType::Bipolar.admits(&[-1, 1]));
        assert!(!QType::Bipolar.admits(&[0]));
    }

    #[test]
    fn narrow_quantize_clamps_to_logical_range() {
        let s = SymmetricScale {
            scale: 1.0,
            qtype: QType::Int(4),
        };
        let x = Tensor::from_f32(&[4], vec![-100.0, -8.0, 7.0, 100.0]).unwrap();
        let q = s.quantize(&x).unwrap();
        // i8 container, int4 logical range.
        assert_eq!(q.as_i8().unwrap(), &[-8, -8, 7, 7]);
    }

    #[test]
    fn bipolar_quantize_is_sign() {
        let s = SymmetricScale {
            scale: 1.0,
            qtype: QType::Bipolar,
        };
        let x = Tensor::from_f32(&[4], vec![-0.3, 0.0, 0.2, -5.0]).unwrap();
        let q = s.quantize(&x).unwrap();
        assert_eq!(q.as_i8().unwrap(), &[-1, 1, 1, -1]);
    }
}
