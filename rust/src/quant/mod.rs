//! The decoupled quantization toolchain (paper §3).
//!
//! Everything needed to turn a trained fp32 model into a *pre-quantized*
//! one lives here, independent of any execution backend: calibration
//! ([`calib`]), the symmetric scale scheme ([`scheme`]), and the
//! integer-multiplier + right-shift rescale decomposition ([`rescale`])
//! that makes the model expressive enough for fixed-point hardware
//! (goal 4). The [`crate::rewrite`] module consumes these to emit the
//! Figure 1–6 operator patterns.

pub mod calib;
pub mod lut;
pub mod rescale;
pub mod scheme;

pub use calib::{AbsHistogram, Calibrator, MaxRange, MseOptimal, Percentile};
pub use lut::{ActEval, ActFn, ActLut};
pub use rescale::{apply_integer, decompose, RescaleDecomposition, MAX_EXACT_F32_INT};
pub use scheme::{quantize_bias, QType, QuantError, SymmetricScale};

/// Which calibration strategy to use, as a config-friendly enum.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CalibStrategy {
    MaxRange,
    Percentile(f32),
    Mse,
}

impl CalibStrategy {
    pub fn build(self, qtype: QType) -> Box<dyn Calibrator> {
        match self {
            CalibStrategy::MaxRange => Box::new(MaxRange::new()),
            CalibStrategy::Percentile(p) => Box::new(Percentile::new(p)),
            CalibStrategy::Mse => Box::new(MseOptimal::new(qtype)),
        }
    }

    pub fn parse(s: &str) -> Option<CalibStrategy> {
        Some(match s {
            "max" | "max_range" => CalibStrategy::MaxRange,
            "mse" => CalibStrategy::Mse,
            s if s.starts_with("p") => {
                CalibStrategy::Percentile(s[1..].parse::<f32>().ok()? / 100.0)
            }
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse() {
        assert_eq!(CalibStrategy::parse("max"), Some(CalibStrategy::MaxRange));
        assert_eq!(CalibStrategy::parse("mse"), Some(CalibStrategy::Mse));
        assert_eq!(
            CalibStrategy::parse("p99.9"),
            Some(CalibStrategy::Percentile(0.999))
        );
        assert_eq!(CalibStrategy::parse("bogus"), None);
    }
}
