//! Activation lookup tables — shared by the hardware simulator's ROM
//! stages and the interpreter's plan-time LUT-folding pass.
//!
//! On fixed-point hardware any pure elementwise int8→int8 function is a
//! 256-entry ROM. [`ActLut::build`] composes the float pipeline the ONNX
//! model codifies (Dequantize → [f16 cast] → Tanh / Sigmoid → Quantize)
//! the way the simulated hardware evaluates it; narrower indices
//! (`lut_bits < 8`) quantize the index and expose the accuracy/area
//! trade-off in the co-design sweep. [`ActLut::build_exact`] composes the
//! *interpreter's* per-element operator implementations instead — zero
//! points included, quantization as multiply-by-reciprocal — so a fused
//! interpreter step that replaces the node chain with a table lookup is
//! bit-identical to executing the chain node by node (the `opt` module's
//! LUT-folding pass; differential proof in `tests/executor_plan.rs`).
//!
//! This module lived in `hwsim::lut` until the plan-time graph optimizer
//! needed it too; `hwsim::lut` remains as a re-export shim.

use crate::ops::qlinear::round_half_even;
use crate::quant::QType;
use crate::tensor::f16::F16;

/// Which activation function the stage computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActFn {
    Tanh,
    Sigmoid,
}

/// Precision the function is evaluated in when building the table —
/// mirrors the model's Fig. 4 (f32) vs Fig. 5/6 (f16) variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActEval {
    F32,
    F16,
}

/// A ROM mapping an 8-bit stage input to the quantized activation output.
#[derive(Clone, Debug)]
pub struct ActLut {
    /// 256 entries indexed by the raw byte pattern of the input (for an
    /// i8 domain, `(q as u8) as usize`); values are the output integer
    /// (i8 or u8 domain per `out_qtype`), stored widened.
    table: Vec<i16>,
    pub out_qtype: QType,
    pub index_bits: u32,
}

impl ActLut {
    /// Build the ROM from the codified parameters, hardware-style: the
    /// index is the i8 input (optionally truncated to `index_bits`), the
    /// zero points are assumed symmetric (0), and requantization divides
    /// by the output scale — [`super::QType::range`]-saturated.
    pub fn build(
        f: ActFn,
        eval: ActEval,
        in_scale: f32,
        out_scale: f32,
        out_qtype: QType,
        index_bits: u32,
    ) -> ActLut {
        let (lo, hi) = out_qtype.range();
        let mut table = vec![0i16; 256];
        let index_mask: i32 = !0i32 << (8 - index_bits.min(8)); // top index_bits kept
        for raw in -128..=127i32 {
            // Narrow index: truncate low bits (hardware drops them).
            let idx = raw & index_mask;
            let x = idx as f32 * in_scale;
            let y = eval_act(f, eval, x);
            let q = round_half_even(y / out_scale).clamp(lo as f32, hi as f32) as i16;
            table[(raw as u8) as usize] = q;
        }
        ActLut {
            table,
            out_qtype,
            index_bits,
        }
    }

    /// Build the ROM by composing EXACTLY the interpreter's per-element
    /// operator arithmetic for `DequantizeLinear → [Cast f16] → act →
    /// [Cast f32] → QuantizeLinear`:
    ///
    /// * dequantize: `(q - in_zp) as f32 * in_scale`
    ///   (`ops::qlinear::dequantize_linear_into`),
    /// * the activation exactly as `ops::elementwise` evaluates it (f32,
    ///   or round-tripped through the software f16),
    /// * quantize: `round_half_even(y * (1.0 / out_scale)) + out_zp`,
    ///   then saturate (`ops::qlinear::quantize_linear_into` — note the
    ///   multiply-by-reciprocal, which can differ from `build`'s division
    ///   in the last ULP).
    ///
    /// The index domain is the full 8 bits of `in_qtype` (i8 or u8, by
    /// raw byte pattern — see [`ActLut::get_raw`]). Because the chain is
    /// a pure function of the 8-bit input, a table built this way makes
    /// the fused step bit-identical to running the nodes one by one.
    #[allow(clippy::too_many_arguments)]
    pub fn build_exact(
        f: ActFn,
        eval: ActEval,
        in_scale: f32,
        in_zp: i32,
        in_qtype: QType,
        out_scale: f32,
        out_zp: i32,
        out_qtype: QType,
    ) -> ActLut {
        let (lo, hi) = out_qtype.range();
        let inv = 1.0 / out_scale;
        let mut table = vec![0i16; 256];
        for b in 0..=255u16 {
            let b = b as u8;
            // The index domain is the full 8-bit *container*; narrow
            // logical widths reuse their container's interpretation.
            let q = match in_qtype.dtype() {
                crate::tensor::DType::I8 => (b as i8) as i32,
                _ => b as i32,
            };
            let x = (q - in_zp) as f32 * in_scale;
            let y = eval_act(f, eval, x);
            let r = round_half_even(y * inv) + out_zp as f32;
            table[b as usize] = r.clamp(lo as f32, hi as f32) as i16;
        }
        ActLut {
            table,
            out_qtype,
            index_bits: 8,
        }
    }

    /// Look up one int8 input.
    #[inline]
    pub fn get(&self, q: i8) -> i16 {
        self.table[(q as u8) as usize]
    }

    /// Look up by raw byte pattern (the u8-domain form of [`ActLut::get`]).
    #[inline]
    pub fn get_raw(&self, b: u8) -> i16 {
        self.table[b as usize]
    }

    /// Apply to a widened-i32 slice in place (values must be in i8 range;
    /// the preceding requantize stage guarantees it).
    pub fn apply(&self, xs: &mut [i32]) {
        for v in xs {
            *v = self.get(*v as i8) as i32;
        }
    }
}

/// One activation evaluation, in the requested precision. The f16 path is
/// bit-identical to the interpreter's `Cast f16 → act → Cast f32` node
/// sequence: `F16::from_f32` is the Cast, `F16::{tanh, sigmoid}` evaluate
/// in f32 and round the result to f16 (exactly `ops::elementwise`'s f16
/// arms), and `to_f32` is the exact widening Cast back.
#[inline]
fn eval_act(f: ActFn, eval: ActEval, x: f32) -> f32 {
    match (f, eval) {
        (ActFn::Tanh, ActEval::F32) => x.tanh(),
        (ActFn::Sigmoid, ActEval::F32) => 1.0 / (1.0 + (-x).exp()),
        (ActFn::Tanh, ActEval::F16) => F16::from_f32(x).tanh().to_f32(),
        (ActFn::Sigmoid, ActEval::F16) => F16::from_f32(x).sigmoid().to_f32(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_width_lut_matches_float_pipeline() {
        let in_scale = 4.0 / 127.0;
        let out_scale = 1.0 / 127.0;
        let lut = ActLut::build(ActFn::Tanh, ActEval::F32, in_scale, out_scale, QType::I8, 8);
        for q in -128..=127i32 {
            let x = q as f32 * in_scale;
            let want = round_half_even(x.tanh() / out_scale).clamp(-128.0, 127.0) as i16;
            assert_eq!(lut.get(q as i8), want, "q={q}");
        }
    }

    #[test]
    fn sigmoid_lut_is_uint8_monotone() {
        let lut = ActLut::build(
            ActFn::Sigmoid,
            ActEval::F16,
            8.0 / 127.0,
            1.0 / 255.0,
            QType::U8,
            8,
        );
        let mut prev = -1i16;
        for q in -128..=127i32 {
            let v = lut.get(q as i8);
            assert!((0..=255).contains(&v));
            assert!(v >= prev, "monotonicity broken at {q}");
            prev = v;
        }
        assert_eq!(lut.get(-128), 0);
        assert_eq!(lut.get(127), 255);
    }

    #[test]
    fn narrow_index_coarsens() {
        let fine = ActLut::build(ActFn::Tanh, ActEval::F32, 0.03, 1.0 / 127.0, QType::I8, 8);
        let coarse = ActLut::build(ActFn::Tanh, ActEval::F32, 0.03, 1.0 / 127.0, QType::I8, 5);
        // Coarse LUT is piecewise constant over 2^3-wide input bins.
        assert_eq!(coarse.get(8), coarse.get(9));
        assert_eq!(coarse.get(8), coarse.get(15));
        // And differs from the fine LUT somewhere.
        let diffs = (-128..=127)
            .filter(|&q| fine.get(q as i8) != coarse.get(q as i8))
            .count();
        assert!(diffs > 0);
    }

    #[test]
    fn exact_lut_replicates_interpreter_ops_per_element() {
        use crate::ops::{elementwise, qlinear};
        use crate::tensor::{DType, Tensor};
        // Every 8-bit input, both domains, f32 and f16 evaluation: the
        // table entry must equal running the actual operator kernels.
        let (in_scale, out_scale) = (2.0 / 127.0, 1.0 / 127.0);
        for (eval, f16) in [(ActEval::F32, false), (ActEval::F16, true)] {
            let lut = ActLut::build_exact(
                ActFn::Tanh,
                eval,
                in_scale,
                0,
                QType::I8,
                out_scale,
                0,
                QType::I8,
            );
            let q: Vec<i8> = (-128..=127).map(|v| v as i8).collect();
            let x = Tensor::from_i8(&[256], q.clone()).unwrap();
            let deq = qlinear::dequantize_linear(
                &x,
                &Tensor::scalar_f32(in_scale),
                Some(&Tensor::scalar_i8(0)),
            )
            .unwrap();
            let act_in = if f16 { deq.cast(DType::F16) } else { deq };
            let act = elementwise::tanh(&act_in).unwrap();
            let act_f32 = if f16 { act.cast(DType::F32) } else { act };
            let want = qlinear::quantize_linear(
                &act_f32,
                &Tensor::scalar_f32(out_scale),
                Some(&Tensor::scalar_i8(0)),
            )
            .unwrap();
            for (qi, &w) in q.iter().zip(want.as_i8().unwrap()) {
                assert_eq!(lut.get(*qi) as i8, w, "eval {eval:?} q={qi}");
            }
        }
    }

    #[test]
    fn exact_lut_u8_domain_and_zero_points() {
        use crate::ops::qlinear;
        use crate::tensor::Tensor;
        // Nonzero zero points on BOTH edges (the asymmetric-u8 case the
        // paper's §3.1 dtype-selection rule exists for).
        let lut = ActLut::build_exact(
            ActFn::Sigmoid,
            ActEval::F32,
            0.05,
            128,
            QType::U8,
            1.0 / 255.0,
            10,
            QType::U8,
        );
        let q: Vec<u8> = (0..=255).map(|v| v as u8).collect();
        let x = Tensor::from_u8(&[256], q.clone()).unwrap();
        let deq = qlinear::dequantize_linear(
            &x,
            &Tensor::scalar_f32(0.05),
            Some(&Tensor::scalar_u8(128)),
        )
        .unwrap();
        let s = deq.as_f32().unwrap();
        let act: Vec<f32> = s.iter().map(|&v| 1.0 / (1.0 + (-v).exp())).collect();
        let act = Tensor::from_f32(&[256], act).unwrap();
        let want = qlinear::quantize_linear(
            &act,
            &Tensor::scalar_f32(1.0 / 255.0),
            Some(&Tensor::scalar_u8(10)),
        )
        .unwrap();
        for (b, &w) in q.iter().zip(want.as_u8().unwrap()) {
            assert_eq!(lut.get_raw(*b) as u8, w, "b={b}");
        }
    }
}
