//! §3.1 rescale decomposition: replace the floating-point rescale
//! multiplier with an **integer multiply + arithmetic right shift** —
//! the operation fixed-point accelerator hardware actually performs —
//! and codify both constants in the ONNX model as FLOAT initializers.
//!
//! `Quant_multiplier ≈ Quant_scale * 2^-N` where `Quant_scale` is an
//! integer stored as FLOAT. The paper notes the largest exactly-
//! representable integer in f32 is 2^24 = 16,777,216, which bounds the
//! precision; its worked example is 1/3 ≈ 11,184,810 * 2^-25.

use super::scheme::QuantError;

/// An integer-multiplier / right-shift pair representing a positive
/// rescale multiplier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RescaleDecomposition {
    /// Integer multiplier, guaranteed <= 2^24 so its FLOAT encoding in
    /// the ONNX file is exact.
    pub quant_scale: u32,
    /// Right-shift bit count N (`Quant_shift = 2^-N`).
    pub shift: u32,
}

/// Largest integer exactly representable as f32 (paper §3.1).
pub const MAX_EXACT_F32_INT: u32 = 1 << 24;

impl RescaleDecomposition {
    /// The multiplier this decomposition encodes, in f64 (exact:
    /// both factors are powers-of-two-scaled small integers).
    pub fn multiplier(&self) -> f64 {
        self.quant_scale as f64 * (self.shift as f64).exp2().recip()
    }

    /// `Quant_scale` as the FLOAT the ONNX initializer stores — exact by
    /// construction (<= 2^24).
    pub fn quant_scale_f32(&self) -> f32 {
        self.quant_scale as f32
    }

    /// `Quant_shift` = 2^-N as FLOAT — exact for all N < 127.
    pub fn quant_shift_f32(&self) -> f32 {
        (-(self.shift as i32) as f32).exp2()
    }

    /// Relative error vs a target multiplier.
    pub fn relative_error(&self, target: f64) -> f64 {
        if target == 0.0 {
            return 0.0;
        }
        ((self.multiplier() - target) / target).abs()
    }
}

/// Decompose a positive multiplier into (integer scale <= 2^24, right
/// shift <= `max_shift`), minimizing representation error.
///
/// Strategy: normalize `m = frac * 2^e` with `frac` in [0.5, 1), then
/// `quant_scale = round(frac * 2^24)` and `shift = 24 - e`. This uses the
/// full 24-bit mantissa budget, giving relative error <= 2^-24 whenever
/// the shift fits; when `shift` would exceed `max_shift` the multiplier
/// is tiny and precision degrades gracefully (error reported by
/// [`RescaleDecomposition::relative_error`]).
pub fn decompose(multiplier: f32, max_shift: u32) -> Result<RescaleDecomposition, QuantError> {
    if !multiplier.is_finite() || multiplier <= 0.0 {
        return Err(QuantError::BadMultiplier(multiplier));
    }
    let m = multiplier as f64;
    // e such that m = frac * 2^e, frac in [0.5, 1).
    let e = m.log2().floor() as i32 + 1;
    let mut shift = 24 - e;
    let mut qs: u64;
    if shift > max_shift as i32 {
        // Multiplier too small for full precision at this shift budget.
        shift = max_shift as i32;
        qs = (m * (shift as f64).exp2()).round() as u64;
        if qs == 0 {
            return Err(QuantError::BadMultiplier(multiplier));
        }
    } else if shift < 0 {
        // Multiplier >= 2^24: not representable with a right shift.
        return Err(QuantError::BadMultiplier(multiplier));
    } else {
        qs = (m * (shift as f64).exp2()).round() as u64;
        if qs == MAX_EXACT_F32_INT as u64 * 2 {
            // frac rounded up to exactly 1.0 (cannot happen with
            // round-to-nearest from [0.5,1) * 2^24, but guard anyway).
            qs = MAX_EXACT_F32_INT as u64;
            shift -= 1;
        }
        while qs > MAX_EXACT_F32_INT as u64 {
            qs = (qs + 1) >> 1;
            shift -= 1;
            if shift < 0 {
                return Err(QuantError::BadMultiplier(multiplier));
            }
        }
    }
    Ok(RescaleDecomposition {
        quant_scale: qs as u32,
        shift: shift as u32,
    })
}

/// Apply the decomposition in pure integer arithmetic, exactly as the
/// hardware rescale unit does: `(acc * quant_scale) >> shift` in i64 with
/// round-to-nearest (add half before shifting), then saturate to the
/// output integer range. This is the function `hwsim` uses.
#[inline]
pub fn apply_integer(acc: i32, d: &RescaleDecomposition, lo: i32, hi: i32) -> i32 {
    let prod = acc as i64 * d.quant_scale as i64;
    let rounded = if d.shift == 0 {
        prod
    } else {
        // Round half away from zero on the shifted-out bits; the +-half
        // offset is the standard fixed-point rounding the paper's target
        // hardware class performs.
        let half = 1i64 << (d.shift - 1);
        if prod >= 0 {
            (prod + half) >> d.shift
        } else {
            -((-prod + half) >> d.shift)
        }
    };
    rounded.clamp(lo as i64, hi as i64) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_quarter() {
        // Quant_multiplier 0.25 = 1 * 2^-2 family; our normalizer uses
        // the full mantissa: 2^23 * 2^-25 == 0.25 exactly.
        let d = decompose(0.25, 31).unwrap();
        assert_eq!(d.multiplier(), 0.25);
    }

    #[test]
    fn paper_example_one_third() {
        // §3.1: 1/3 ~ Quant_scale 11184810 (truncated) or 11184811
        // (nearest), shift 25. Round-to-nearest picks 11184811.
        let d = decompose(1.0 / 3.0, 31).unwrap();
        assert_eq!(d.shift, 25);
        assert!(
            d.quant_scale == 11184811 || d.quant_scale == 11184810,
            "got {}",
            d.quant_scale
        );
        assert!(d.relative_error(1.0 / 3.0) < 1e-7);
    }

    #[test]
    fn quant_scale_always_exact_in_f32() {
        for &m in &[0.1f32, 0.9, 1.7, 100.3, 1e-3, 1e-6, 0.5, 2.0_f32.powi(-20)] {
            let d = decompose(m, 31).unwrap();
            assert!(d.quant_scale <= MAX_EXACT_F32_INT);
            // f32 round trip of the integer is exact.
            assert_eq!(d.quant_scale_f32() as u32, d.quant_scale);
        }
    }

    #[test]
    fn precision_within_2_pow_24() {
        for i in 1..=1000 {
            let m = i as f32 * 7.3e-4;
            let d = decompose(m, 40).unwrap();
            assert!(
                d.relative_error(m as f64) <= 2.0_f64.powi(-24),
                "m={m} err={}",
                d.relative_error(m as f64)
            );
        }
    }

    #[test]
    fn shift_budget_degrades_gracefully() {
        // Small multiplier with a capped shift budget: representable but
        // with fewer effective mantissa bits.
        let m = 2.0_f32.powi(-10);
        let d = decompose(m, 15).unwrap();
        assert_eq!(d.shift, 15);
        assert_eq!(d.quant_scale, 32); // 2^-10 * 2^15
        assert_eq!(d.multiplier(), m as f64);
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(decompose(0.0, 31).is_err());
        assert!(decompose(-1.0, 31).is_err());
        assert!(decompose(f32::INFINITY, 31).is_err());
        assert!(decompose(17_000_000.0, 31).is_err()); // >= 2^24
    }

    #[test]
    fn apply_integer_matches_float() {
        let d = decompose(1.0 / 3.0, 31).unwrap();
        for &acc in &[0i32, 1, 2, 3, 300, -300, 1000, -1000, 38100, -38100] {
            let hw = apply_integer(acc, &d, -128, 127);
            let float = (acc as f64 / 3.0).round().clamp(-128.0, 127.0) as i32;
            assert!(
                (hw - float).abs() <= 1,
                "acc={acc}: hw={hw} float={float}"
            );
        }
    }

    #[test]
    fn apply_integer_rounds() {
        // multiplier exactly 0.5: acc=3 -> 1.5 -> rounds away from zero to 2.
        let d = decompose(0.5, 31).unwrap();
        assert_eq!(apply_integer(3, &d, -128, 127), 2);
        assert_eq!(apply_integer(-3, &d, -128, 127), -2);
        assert_eq!(apply_integer(300, &d, -128, 127), 127); // saturates
    }

    #[test]
    fn tiny_multiplier_underflow_is_error() {
        assert!(decompose(2.0_f32.powi(-20), 10).is_err());
    }
}
