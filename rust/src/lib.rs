//! # pqdl — Pre-Quantized Deep Learning models codified in ONNX
//!
//! Reproduction of *"Pre-Quantized Deep Learning Models Codified in ONNX to
//! Enable Hardware/Software Co-Design"* (Hanebutte et al., 2021).
//!
//! The crate implements the paper's full stack from scratch:
//!
//! * [`tensor`] — dtyped strided tensors (f32/f16/i8/u8/i32/i64/bool) with a
//!   bit-exact software f16.
//! * [`onnx`] — an ONNX-compatible IR (model / graph / node / attribute /
//!   initializer), its own JSON text serialization, shape & dtype inference
//!   and a graph checker.
//! * [`ops`] — implementations of the standard ONNX operators the paper's
//!   patterns use (MatMulInteger, ConvInteger, QuantizeLinear, ...).
//! * [`interp`] — a generic graph executor ("ONNXruntime" stand-in): it has
//!   no quantization-specific logic, it simply runs standard operators.
//! * [`opt`] — the plan-time graph optimizer: a shared DAG pattern matcher
//!   over the codified chains plus fusion / LUT-folding / elimination
//!   passes, feeding both the interpreter's compiled plans and (through
//!   the matcher) the hwsim pattern compiler.
//! * [`quant`] — the decoupled quantization toolchain: calibration,
//!   symmetric scales, and the §3.1 integer-multiplier + right-shift
//!   rescale decomposition.
//! * [`rewrite`] — the fp32 → pre-quantized graph compiler emitting exactly
//!   the paper's Figure 1–6 operator patterns.
//! * [`hwsim`] — an integer-only fixed-point accelerator simulator with a
//!   cycle/energy cost model; it consumes the same ONNX file and must agree
//!   with [`interp`] bit-exactly (the paper's co-design claim).
//! * [`train`] — a small fp32 training substrate (MLP/CNN + SGD) so the
//!   end-to-end example quantizes a really-trained model.
//! * [`parallel`] — dependency-free thread pool powering the batch-parallel
//!   interpreter/simulator paths and the blocked GEMM/conv kernels.
//! * [`runtime`] — PJRT bridge executing the JAX/Pallas AOT artifacts.
//! * [`coordinator`] — serving layer: router, dynamic batcher, worker pool,
//!   cross-backend validation, metrics.
//!
//! See `DESIGN.md` for the module inventory and experiment index.

pub mod bench_util;
pub mod compare;
pub mod figures;
pub mod coordinator;
pub mod hwsim;
pub mod interp;
pub mod onnx;
pub mod ops;
pub mod opt;
pub mod parallel;
pub mod proptest_util;
pub mod quant;
pub mod rewrite;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod tune;

pub use tensor::{DType, Tensor};
