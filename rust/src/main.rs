//! `pqdl` command-line interface.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!
//! * `train`    — train an fp32 MLP/CNN on the synthetic-digits corpus
//!   and save the ONNX-form model.
//! * `quantize` — calibrate + rewrite an fp32 model file into the
//!   paper's pre-quantized patterns.
//! * `run`      — execute a model file on a chosen backend.
//! * `validate` — cross-backend narrow-margins table for a model file.
//! * `figures`  — emit the six canonical Figure models as files.
//! * `verify-artifacts` — check the PJRT artifacts against the Python
//!   golden outputs.
//! * `serve`    — start the coordinator on the canonical figures and
//!   run a synthetic load (demo).

use anyhow::{anyhow, bail, Context, Result};
use pqdl::coordinator::{validate as xvalidate, Backend, HwSimBackend, InterpBackend};
use pqdl::figures::Figure;
use pqdl::hwsim::{HwConfig, HwModule};
use pqdl::interp::Session;
use pqdl::onnx::{load_model, save_model};
use pqdl::quant::CalibStrategy;
use pqdl::rewrite::{calibrate, quantize_model, ActPrecision, QuantizeOptions};
use pqdl::tensor::Tensor;
use pqdl::train::{
    accuracy, cnn_accuracy, synthetic_digits, train_classifier, train_cnn, Cnn, HiddenAct, Mlp,
};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal `--key value` / `--flag` argument map.
struct Args {
    values: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut values = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    values.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else {
                flags.push(a.clone());
                i += 1;
            }
        }
        Args { values, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    fn req(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing --{key}"))
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

const USAGE: &str = "pqdl — pre-quantized ONNX models for HW/SW co-design

USAGE:
  pqdl train    --arch mlp|cnn --out MODEL.json [--epochs N] [--act relu|tanh|sigmoid]
  pqdl quantize --model FP32.json --out PREQ.json [--calib max|p99.9|mse]
                [--one-mul] [--act-precision int8|f16] [--int8-io]
  pqdl run      --model MODEL.json [--backend interp|hwsim] [--batch N]
  pqdl validate --model PREQ.json [--inputs N]
  pqdl figures  [--out-dir DIR]
  pqdl verify-artifacts [--dir artifacts]
  pqdl serve    [--requests N]
  pqdl profile  [--fig NAME] [--batch N] [--iters N]
";

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "quantize" => cmd_quantize(&args),
        "run" => cmd_run(&args),
        "validate" => cmd_validate(&args),
        "figures" => cmd_figures(&args),
        "verify-artifacts" => cmd_verify_artifacts(&args),
        "serve" => cmd_serve(&args),
        "profile" => cmd_profile(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.req("out")?);
    let arch = args.get("arch").unwrap_or("mlp");
    let epochs: usize = args.get("epochs").unwrap_or("20").parse()?;
    let data = synthetic_digits(3000, 42);
    let (train, test) = data.split(0.2, 43);
    let model = match arch {
        "mlp" => {
            let act = match args.get("act").unwrap_or("relu") {
                "relu" => HiddenAct::Relu,
                "tanh" => HiddenAct::Tanh,
                "sigmoid" => HiddenAct::Sigmoid,
                other => bail!("unknown activation '{other}'"),
            };
            let mut mlp = Mlp::new(&[64, 64, 10], act, 44);
            train_classifier(&mut mlp, &train, epochs, 32, 0.1, 0.9, 45);
            println!("fp32 test accuracy: {:.2}%", 100.0 * accuracy(&mlp, &test));
            mlp.to_model("digits_mlp")
        }
        "cnn" => {
            let mut cnn = Cnn::new(8, 10, 46);
            train_cnn(&mut cnn, &train, epochs, 32, 0.08, 0.9, 47);
            println!(
                "fp32 test accuracy: {:.2}%",
                100.0 * cnn_accuracy(&cnn, &test)
            );
            cnn.to_model("digits_cnn")
        }
        other => bail!("unknown arch '{other}'"),
    };
    save_model(&model, &out)?;
    println!("wrote {}", out.display());
    Ok(())
}

fn digits_calib_batches(model: &pqdl::onnx::Model) -> Vec<Vec<(String, Tensor)>> {
    let data = synthetic_digits(128, 48);
    let image = model.graph.runtime_inputs()[0].shape.len() == 4;
    (0..data.len())
        .map(|i| {
            let (x, _) = data.sample(i);
            let shape: Vec<usize> = if image { vec![1, 1, 8, 8] } else { vec![1, 64] };
            vec![(
                "x".to_string(),
                Tensor::from_f32(&shape, x.to_vec()).unwrap(),
            )]
        })
        .collect()
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let model = load_model(Path::new(args.req("model")?))?;
    let out = PathBuf::from(args.req("out")?);
    let strategy = CalibStrategy::parse(args.get("calib").unwrap_or("max"))
        .ok_or_else(|| anyhow!("bad --calib"))?;
    let opts = QuantizeOptions {
        two_mul: !args.flag("one-mul"),
        act_precision: match args.get("act-precision").unwrap_or("f16") {
            "int8" => ActPrecision::Int8,
            _ => ActPrecision::F16,
        },
        strategy,
        float_io: !args.flag("int8-io"),
        ..Default::default()
    };
    let sess = Session::new(model.clone()).map_err(|e| anyhow!("{e}"))?;
    let cal = calibrate(&sess, &digits_calib_batches(&model), strategy)
        .map_err(|e| anyhow!("{e}"))?;
    let preq = quantize_model(&model, &cal, &opts)?;
    save_model(&preq, &out)?;
    println!(
        "wrote {} ({} nodes, strategy {}, {})",
        out.display(),
        preq.graph.nodes.len(),
        cal.strategy_name,
        if opts.two_mul { "2-Mul" } else { "1-Mul" }
    );
    Ok(())
}

fn random_input(model: &pqdl::onnx::Model, batch: usize) -> Result<Tensor> {
    let vi = model.graph.runtime_inputs()[0].clone();
    let mut dims = vec![batch];
    for d in &vi.shape[1..] {
        dims.push(d.fixed().ok_or_else(|| anyhow!("non-batch symbolic dim"))?);
    }
    let n: usize = dims.iter().product();
    let mut rng = pqdl::train::Rng::new(7);
    Ok(match vi.dtype {
        pqdl::tensor::DType::F32 => {
            Tensor::from_f32(&dims, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())?
        }
        pqdl::tensor::DType::I8 => {
            Tensor::from_i8(&dims, (0..n).map(|_| rng.i8()).collect())?
        }
        d => bail!("unsupported input dtype {d}"),
    })
}

fn cmd_run(args: &Args) -> Result<()> {
    let model = load_model(Path::new(args.req("model")?))?;
    let batch: usize = args.get("batch").unwrap_or("1").parse()?;
    let input = random_input(&model, batch)?;
    match args.get("backend").unwrap_or("interp") {
        "interp" => {
            let sess = Session::new(model).map_err(|e| anyhow!("{e}"))?;
            let name = sess.model().graph.runtime_inputs()[0].name.clone();
            let out = sess.run(&[(&name, input)]).map_err(|e| anyhow!("{e}"))?;
            println!("output[0] ({} x {:?}):", out[0].dtype(), out[0].shape());
            println!("{:?}", &out[0].to_f32_vec()[..out[0].numel().min(16)]);
        }
        "hwsim" => {
            let cfg = HwConfig::default();
            let hw = HwModule::compile(&model, cfg.clone())?;
            let (out, cost) = hw.run(&input)?;
            println!("output ({} x {:?}):", out.dtype(), out.shape());
            println!("{:?}", &out.to_f32_vec()[..out.numel().min(16)]);
            println!(
                "cost: {} MACs, {} cycles ({:.2} us @ {:.0} MHz), {:.3} uJ, util {:.1}%",
                cost.macs,
                cost.cycles,
                cost.latency_us(&cfg),
                cfg.freq_mhz,
                cost.energy_nj(&cfg) / 1000.0,
                100.0 * cost.utilization(&cfg)
            );
        }
        other => bail!("unknown backend '{other}'"),
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let model = load_model(Path::new(args.req("model")?))?;
    let n_inputs: usize = args.get("inputs").unwrap_or("50").parse()?;
    let backends: Vec<Arc<dyn Backend>> = vec![
        Arc::new(InterpBackend::new(model.clone()).map_err(|e| anyhow!("{e}"))?),
        Arc::new(HwSimBackend::new(&model, HwConfig::default())?),
    ];
    let inputs: Vec<Tensor> = (0..n_inputs)
        .map(|_| random_input(&model, 4))
        .collect::<Result<_>>()?;
    let report = xvalidate("model", &backends, &inputs)?;
    print!("{}", report.table());
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("out-dir").unwrap_or("figures_out"));
    std::fs::create_dir_all(&dir)?;
    for fig in Figure::ALL {
        let m = fig.model();
        let path = dir.join(format!("{}.json", fig.name()));
        save_model(&m, &path)?;
        let ops: Vec<&str> = m.graph.nodes.iter().map(|n| n.op_type.as_str()).collect();
        println!("{:<18} {} -> {:?}", fig.name(), path.display(), ops);
    }
    Ok(())
}

fn cmd_verify_artifacts(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("dir").unwrap_or("artifacts"));
    let svc = pqdl::runtime::PjrtService::spawn(dir).context("starting PJRT")?;
    let rows = svc.verify_golden()?;
    println!("variant              | batch | max LSB diff vs python golden");
    for (v, b, d) in &rows {
        println!("{v:<20} | {b:>5} | {d}");
    }
    let worst = rows.iter().map(|r| r.2).max().unwrap_or(0);
    svc.shutdown();
    if worst == 0 {
        println!("all {} artifacts bit-exact.", rows.len());
        Ok(())
    } else {
        bail!("max divergence {worst} LSB");
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    use pqdl::coordinator::{CoordinatorBuilder, ServerConfig};
    let requests: usize = args.get("requests").unwrap_or("500").parse()?;
    let mut builder = CoordinatorBuilder::new(ServerConfig::default());
    for fig in Figure::ALL {
        builder = builder.register(
            fig.name(),
            Arc::new(InterpBackend::new(fig.model()).map_err(|e| anyhow!("{e}"))?),
        );
    }
    let coord = Arc::new(builder.start());
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..4u64 {
        let coord = coord.clone();
        let per = requests / 4;
        joins.push(std::thread::spawn(move || {
            let mut rng = pqdl::train::Rng::new(c);
            for i in 0..per {
                let fig = Figure::ALL[rng.below(6)];
                let x = fig.input(1, c * 100_000 + i as u64);
                coord.infer(fig.name(), x).unwrap().output.unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    println!(
        "{requests} requests in {:.2?}\n\n{}",
        t0.elapsed(),
        coord.metrics.report()
    );
    coord.shutdown();
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let fig_name = args.get("fig").unwrap_or("fig1_fc");
    let batch: usize = args.get("batch").unwrap_or("64").parse()?;
    let iters: usize = args.get("iters").unwrap_or("2000").parse()?;
    let fig = Figure::ALL
        .into_iter()
        .find(|f| f.name() == fig_name)
        .ok_or_else(|| anyhow!("unknown figure '{fig_name}'"))?;
    let sess = Session::new(fig.model())
        .map_err(|e| anyhow!("{e}"))?
        .with_profiling();
    let x = fig.input(batch, 42);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        sess.run(&[("x", x.clone())]).map_err(|e| anyhow!("{e}"))?;
    }
    let total = t0.elapsed();
    println!(
        "{fig_name} b{batch}: {iters} iters in {total:.2?} ({:.2} us/iter)\n",
        total.as_secs_f64() * 1e6 / iters as f64
    );
    println!("{:<28} | {:>10} | {:>8} | share", "node", "total ms", "us/call");
    let prof = sess.profile();
    let sum: u128 = prof.iter().map(|s| s.nanos).sum();
    for s in &prof {
        println!(
            "{:<28} | {:>10.2} | {:>8.2} | {:>5.1}%",
            format!("{} ({})", s.name, s.op_type),
            s.nanos as f64 / 1e6,
            s.nanos as f64 / 1e3 / s.calls as f64,
            100.0 * s.nanos as f64 / sum as f64
        );
    }
    Ok(())
}
