//! "Narrow margins" measurement (paper goal 3).
//!
//! Quantifies agreement between two executions of the same pre-quantized
//! model on different backends: exact-match rate, LSB-difference
//! histogram, max absolute difference — the numbers EXPERIMENTS.md
//! reports for every figure.

use crate::tensor::{DType, Tensor};

/// Comparison summary between two integer tensors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MatchReport {
    pub elements: usize,
    pub exact: usize,
    /// Histogram of |a-b|: index 0 = exact, 1 = 1 LSB, ...; last bucket
    /// accumulates everything >= its index.
    pub lsb_hist: Vec<usize>,
    pub max_abs_diff: i32,
    pub mean_abs_diff: f64,
}

impl MatchReport {
    pub fn exact_rate(&self) -> f64 {
        if self.elements == 0 {
            return 1.0;
        }
        self.exact as f64 / self.elements as f64
    }

    /// Fraction of elements within `lsb` LSBs.
    pub fn within(&self, lsb: usize) -> f64 {
        if self.elements == 0 {
            return 1.0;
        }
        let ok: usize = self.lsb_hist.iter().take(lsb + 1).sum();
        ok as f64 / self.elements as f64
    }

    /// Merge another report into this one (accumulating over inputs).
    pub fn merge(&mut self, other: &MatchReport) {
        let prev = self.elements;
        self.elements += other.elements;
        self.exact += other.exact;
        if self.lsb_hist.len() < other.lsb_hist.len() {
            self.lsb_hist.resize(other.lsb_hist.len(), 0);
        }
        for (i, &c) in other.lsb_hist.iter().enumerate() {
            self.lsb_hist[i] += c;
        }
        self.max_abs_diff = self.max_abs_diff.max(other.max_abs_diff);
        if self.elements > 0 {
            self.mean_abs_diff = (self.mean_abs_diff * prev as f64
                + other.mean_abs_diff * other.elements as f64)
                / self.elements as f64;
        }
    }
}

/// Compare two quantized tensors element-wise (widened to i32).
pub fn compare_quantized(a: &Tensor, b: &Tensor, hist_buckets: usize) -> MatchReport {
    let av = a.as_quantized_i32().unwrap_or_default();
    let bv = b.as_quantized_i32().unwrap_or_default();
    let n = av.len().min(bv.len());
    let mut hist = vec![0usize; hist_buckets.max(2)];
    let mut exact = 0usize;
    let mut max_d = 0i32;
    let mut sum_d = 0f64;
    for i in 0..n {
        let d = (av[i] - bv[i]).abs();
        if d == 0 {
            exact += 1;
        }
        let bucket = (d as usize).min(hist.len() - 1);
        hist[bucket] += 1;
        max_d = max_d.max(d);
        sum_d += d as f64;
    }
    MatchReport {
        elements: n,
        exact,
        lsb_hist: hist,
        max_abs_diff: max_d,
        mean_abs_diff: if n > 0 { sum_d / n as f64 } else { 0.0 },
    }
}

/// Max |a-b| between two f32 tensors (fp32 reference comparisons).
pub fn max_abs_diff_f32(a: &Tensor, b: &Tensor) -> f32 {
    debug_assert_eq!(a.dtype(), DType::F32);
    let av = a.as_f32().unwrap_or_default();
    let bv = b.as_f32().unwrap_or_default();
    av.iter()
        .zip(bv)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match() {
        let a = Tensor::from_i8(&[4], vec![1, -2, 3, -4]).unwrap();
        let r = compare_quantized(&a, &a, 4);
        assert_eq!(r.exact_rate(), 1.0);
        assert_eq!(r.max_abs_diff, 0);
        assert_eq!(r.within(0), 1.0);
    }

    #[test]
    fn lsb_histogram() {
        let a = Tensor::from_i8(&[4], vec![0, 0, 0, 0]).unwrap();
        let b = Tensor::from_i8(&[4], vec![0, 1, -1, 5]).unwrap();
        let r = compare_quantized(&a, &b, 4);
        assert_eq!(r.exact, 1);
        assert_eq!(r.lsb_hist[0], 1);
        assert_eq!(r.lsb_hist[1], 2);
        assert_eq!(r.lsb_hist[3], 1); // 5 clamps into last bucket
        assert_eq!(r.max_abs_diff, 5);
        assert_eq!(r.within(1), 0.75);
    }

    #[test]
    fn merge_accumulates() {
        let a = Tensor::from_i8(&[2], vec![0, 0]).unwrap();
        let b = Tensor::from_i8(&[2], vec![0, 1]).unwrap();
        let mut total = MatchReport::default();
        total.merge(&compare_quantized(&a, &b, 3));
        total.merge(&compare_quantized(&a, &a, 3));
        assert_eq!(total.elements, 4);
        assert_eq!(total.exact, 3);
        assert_eq!(total.max_abs_diff, 1);
        assert!((total.mean_abs_diff - 0.25).abs() < 1e-9);
    }

    #[test]
    fn f32_diff() {
        let a = Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_f32(&[2], vec![1.5, 2.0]).unwrap();
        assert_eq!(max_abs_diff_f32(&a, &b), 0.5);
    }
}
