//! fp32 → pre-quantized graph compiler.
//!
//! [`patterns`] emits the paper's Figure 1–6 operator sequences;
//! [`calibrate`] profiles activations on a calibration set; [`pass`]
//! drives the whole-model rewrite. The output model embeds every
//! quantization parameter as a standard initializer and runs unmodified
//! on the interpreter, the hardware simulator, and XLA/PJRT.

pub mod calibrate;
pub mod pass;
pub mod patterns;

pub use calibrate::{calibrate, Calibration};
pub use pass::{quantize_model, ActPrecision, QuantizeOptions, RewriteError};
pub use patterns::{emit_conv, emit_fc, ActKind, ConvParams, FcParams, RescaleOp};
