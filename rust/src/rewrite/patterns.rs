//! Emitters for the paper's Figure 1–6 operator patterns.
//!
//! Each function appends one pre-quantized layer to a [`GraphBuilder`]
//! using **only standard ONNX operators**, embedding every quantization
//! parameter as an initializer (paper goals 1 & 3):
//!
//! * Fig. 1 — FC, rescale as 2 Mul (`Quant_scale` int-as-FLOAT, `Quant_shift` 2^-N)
//! * Fig. 2 — FC + ReLU, rescale as 1 Mul
//! * Fig. 3 — Conv, rescale as 1 Mul
//! * Fig. 4 — FC + int8 Tanh (Dequantize → Tanh f32 → Quantize)
//! * Fig. 5 — FC + fp16 Tanh (… → Cast f16 → Tanh → Cast f32 → …)
//! * Fig. 6 — FC + fp16 Sigmoid, uint8 output

use crate::onnx::ir::Attr;
use crate::onnx::GraphBuilder;
use crate::quant::{QType, RescaleDecomposition};
use crate::tensor::Tensor;

/// How the rescale multiplier is codified (§3.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RescaleOp {
    /// One `Mul` by the fp32 multiplier; the integer/shift split is left
    /// to the hardware tool chain.
    OneMul(f32),
    /// Two `Mul`s: integer `Quant_scale` (stored as FLOAT) then
    /// `Quant_shift` = 2^-N — the fully hardware-explicit form.
    TwoMul(RescaleDecomposition),
}

impl RescaleOp {
    /// The effective multiplier this op applies.
    pub fn multiplier(&self) -> f64 {
        match self {
            RescaleOp::OneMul(m) => *m as f64,
            RescaleOp::TwoMul(d) => d.multiplier(),
        }
    }
}

/// Activation wired into the pattern.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ActKind {
    /// Fig. 1 / Fig. 3: no activation.
    None,
    /// Fig. 2: ReLU on the rescaled f32 value before requantization.
    Relu,
    /// Fig. 4: int8 tanh approximation — requantize to int8 mapping the
    /// full tanh input range, Dequantize, Tanh in f32, Quantize with
    /// `out_scale` mapping [-1,1] onto int8.
    TanhInt8 { in_scale: f32, out_scale: f32 },
    /// Fig. 5: tanh evaluated in genuine fp16 on a narrow input range.
    TanhF16 { in_scale: f32, out_scale: f32 },
    /// Fig. 6: sigmoid in fp16; output is uint8 (sigmoid >= 0).
    SigmoidF16 { in_scale: f32, out_scale: f32 },
}

/// Parameters of one pre-quantized fully-connected layer.
#[derive(Clone, Debug)]
pub struct FcParams {
    /// Quantized weights, i8 `[K, N]`.
    pub weight_q: Tensor,
    /// Quantized bias, i32 `[N]` at scale `scale_W * scale_X` (Eq. 6).
    pub bias_q: Option<Tensor>,
    pub rescale: RescaleOp,
    pub activation: ActKind,
    /// Output integer type of the requantization stage.
    pub out_qtype: QType,
}

/// Parameters of one pre-quantized convolution layer (Fig. 3).
#[derive(Clone, Debug)]
pub struct ConvParams {
    /// Quantized kernel, i8 `[M, C, kH, kW]`.
    pub weight_q: Tensor,
    /// Quantized bias, i32 `[M]`.
    pub bias_q: Option<Tensor>,
    pub rescale: RescaleOp,
    /// ReLU folded after rescale (a Fig. 2-style variant of Fig. 3).
    pub relu: bool,
    pub out_qtype: QType,
    pub strides: [usize; 2],
    pub pads: [usize; 4],
}

fn zp_init(b: &mut GraphBuilder, prefix: &str, qtype: QType) -> String {
    // The zero point's dtype selects the *container* (i8 vs u8); any
    // narrower logical width lives inside that container.
    let t = match qtype.dtype() {
        crate::tensor::DType::U8 => Tensor::scalar_u8(0),
        _ => Tensor::scalar_i8(0),
    };
    b.init_fresh(&format!("{prefix}_zero_point"), t)
}

/// Emit the rescale Mul(s) (§3.1) on a f32 value; returns the rescaled
/// f32 value name.
fn emit_rescale(b: &mut GraphBuilder, x: &str, rescale: &RescaleOp, prefix: &str) -> String {
    match rescale {
        RescaleOp::OneMul(m) => {
            let s = b.init_fresh(&format!("{prefix}_quant_multiplier"), Tensor::scalar_f32(*m));
            b.node("Mul", &[x, &s], &[])
        }
        RescaleOp::TwoMul(d) => {
            let qs = b.init_fresh(
                &format!("{prefix}_quant_scale"),
                Tensor::scalar_f32(d.quant_scale_f32()),
            );
            let qh = b.init_fresh(
                &format!("{prefix}_quant_shift"),
                Tensor::scalar_f32(d.quant_shift_f32()),
            );
            let m1 = b.node("Mul", &[x, &qs], &[]);
            b.node("Mul", &[&m1, &qh], &[])
        }
    }
}

/// Rounding + clipping stage: `QuantizeLinear(scale=1, zero_point=0)`;
/// the zero-point dtype selects int8 vs uint8 (§3.1). Sub-8-bit logical
/// outputs additionally get an explicit `Clip` to the narrow range
/// *before* the quantizer — the standard-ops codification of "this i8
/// container only ever holds int4 values", which the optimizer's matcher
/// absorbs back into the fused kernel's saturation bounds. Bipolar is
/// excluded: `round(clip(x, -1, 1))` collapses (-0.5, 0.5) to 0, so a
/// {-1, +1} activation alphabet is not expressible with this stage.
fn emit_round_clip(b: &mut GraphBuilder, x: &str, qtype: QType, prefix: &str) -> String {
    let pre_q = if qtype.bits() < 8 && qtype != QType::Bipolar {
        let (lo, hi) = qtype.range();
        let lo = b.init_fresh(&format!("{prefix}_clip_min"), Tensor::scalar_f32(lo as f32));
        let hi = b.init_fresh(&format!("{prefix}_clip_max"), Tensor::scalar_f32(hi as f32));
        b.node("Clip", &[x, &lo, &hi], &[])
    } else {
        x.to_string()
    };
    let one = b.init_fresh(&format!("{prefix}_unit_scale"), Tensor::scalar_f32(1.0));
    let zp = zp_init(b, prefix, qtype);
    b.node("QuantizeLinear", &[&pre_q, &one, &zp], &[])
}

/// Emit the activation tail shared by Figs. 4–6: Dequantize -> (optional
/// f16 casts) -> Tanh/Sigmoid -> Quantize(out_scale).
fn emit_float_activation(
    b: &mut GraphBuilder,
    q8: &str,
    op: &str,
    f16: bool,
    in_scale: f32,
    out_scale: f32,
    out_qtype: QType,
    prefix: &str,
) -> String {
    let xs = b.init_fresh(&format!("{prefix}_x_scale"), Tensor::scalar_f32(in_scale));
    let xzp = zp_init(b, &format!("{prefix}_x"), QType::I8);
    let deq = b.node("DequantizeLinear", &[q8, &xs, &xzp], &[]);
    let act_in = if f16 {
        b.node("Cast", &[&deq], &[("to", Attr::Str("FLOAT16".into()))])
    } else {
        deq
    };
    let act = b.node(op, &[&act_in], &[]);
    let act_f32 = if f16 {
        b.node("Cast", &[&act], &[("to", Attr::Str("FLOAT".into()))])
    } else {
        act
    };
    let ys = b.init_fresh(&format!("{prefix}_y_scale"), Tensor::scalar_f32(out_scale));
    let yzp = zp_init(b, &format!("{prefix}_y"), out_qtype);
    b.node("QuantizeLinear", &[&act_f32, &ys, &yzp], &[])
}

/// Append one pre-quantized fully-connected layer (Figs. 1/2/4/5/6
/// depending on `params`); returns the quantized output value name.
pub fn emit_fc(b: &mut GraphBuilder, x: &str, params: &FcParams, prefix: &str) -> String {
    let w = b.init_fresh(&format!("{prefix}_weight_q"), params.weight_q.clone());
    // Eq. 5: Y_intermediate = W_q · X_q + B_q, all integer.
    let mut acc = b.node("MatMulInteger", &[x, &w], &[]);
    if let Some(bias) = &params.bias_q {
        let bias_name = b.init_fresh(&format!("{prefix}_bias_q"), bias.clone());
        acc = b.node("Add", &[&acc, &bias_name], &[]);
    }
    // Cast INT32 -> FLOAT for the Mul-codified rescale.
    let f = b.node("Cast", &[&acc], &[("to", Attr::Str("FLOAT".into()))]);
    let rescaled = emit_rescale(b, &f, &params.rescale, prefix);

    match params.activation {
        ActKind::None => emit_round_clip(b, &rescaled, params.out_qtype, prefix),
        ActKind::Relu => {
            // Fig. 2: ReLU on the rescaled f32 value, then round+clip.
            // (Symmetric scheme: ReLU commutes with the zero-point-free
            // quantizer, so this is equivalent to int-domain ReLU.)
            let r = b.node("Relu", &[&rescaled], &[]);
            emit_round_clip(b, &r, params.out_qtype, prefix)
        }
        ActKind::TanhInt8 {
            in_scale,
            out_scale,
        } => {
            let q8 = emit_round_clip(b, &rescaled, QType::I8, prefix);
            emit_float_activation(
                b, &q8, "Tanh", false, in_scale, out_scale, params.out_qtype, prefix,
            )
        }
        ActKind::TanhF16 {
            in_scale,
            out_scale,
        } => {
            let q8 = emit_round_clip(b, &rescaled, QType::I8, prefix);
            emit_float_activation(
                b, &q8, "Tanh", true, in_scale, out_scale, params.out_qtype, prefix,
            )
        }
        ActKind::SigmoidF16 {
            in_scale,
            out_scale,
        } => {
            let q8 = emit_round_clip(b, &rescaled, QType::I8, prefix);
            // Fig. 6: sigmoid output is always positive -> uint8.
            emit_float_activation(
                b, &q8, "Sigmoid", true, in_scale, out_scale, QType::U8, prefix,
            )
        }
    }
}

/// Append one pre-quantized convolution layer (Fig. 3); returns the
/// quantized output value name.
pub fn emit_conv(b: &mut GraphBuilder, x: &str, params: &ConvParams, prefix: &str) -> String {
    let w = b.init_fresh(&format!("{prefix}_kernel_q"), params.weight_q.clone());
    let m = params.weight_q.shape()[0];
    let mut acc = b.node(
        "ConvInteger",
        &[x, &w],
        &[
            (
                "strides",
                Attr::Ints(params.strides.iter().map(|&s| s as i64).collect()),
            ),
            (
                "pads",
                Attr::Ints(params.pads.iter().map(|&p| p as i64).collect()),
            ),
        ],
    );
    if let Some(bias) = &params.bias_q {
        // Bias [M] broadcast over NCHW needs shape [1, M, 1, 1].
        let b4 = bias.clone().reshape(&[1, m, 1, 1]).expect("bias reshape");
        let bias_name = b.init_fresh(&format!("{prefix}_bias_q"), b4);
        acc = b.node("Add", &[&acc, &bias_name], &[]);
    }
    let f = b.node("Cast", &[&acc], &[("to", Attr::Str("FLOAT".into()))]);
    let rescaled = emit_rescale(b, &f, &params.rescale, prefix);
    let pre_q = if params.relu {
        b.node("Relu", &[&rescaled], &[])
    } else {
        rescaled
    };
    emit_round_clip(b, &pre_q, params.out_qtype, prefix)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::Session;
    use crate::onnx::{batched, check_model, fixed_dims};
    use crate::quant::decompose;
    use crate::tensor::DType;

    fn fc_params(rescale: RescaleOp, act: ActKind, out_qtype: QType) -> FcParams {
        FcParams {
            weight_q: Tensor::from_i8(&[4, 2], vec![1, -1, 2, -2, 3, -3, 4, -4]).unwrap(),
            bias_q: Some(Tensor::from_i32(&[2], vec![10, -10]).unwrap()),
            rescale,
            activation: act,
            out_qtype,
        }
    }

    fn build_fc_model(params: &FcParams, out_dtype: DType) -> crate::onnx::Model {
        let mut b = GraphBuilder::new("fc_pattern");
        b.input("x", DType::I8, &batched(&[4]));
        let y = emit_fc(&mut b, "x", params, "l0");
        b.output(&y, out_dtype, &batched(&[2]));
        b.finish_model()
    }

    #[test]
    fn fig1_two_mul_structure_and_numerics() {
        let d = decompose(0.25, 31).unwrap();
        let params = fc_params(RescaleOp::TwoMul(d), ActKind::None, QType::I8);
        let m = build_fc_model(&params, DType::I8);
        check_model(&m).unwrap();
        // Structure: MatMulInteger, Add, Cast, Mul, Mul, QuantizeLinear.
        let ops: Vec<&str> = m.graph.nodes.iter().map(|n| n.op_type.as_str()).collect();
        assert_eq!(
            ops,
            vec!["MatMulInteger", "Add", "Cast", "Mul", "Mul", "QuantizeLinear"]
        );
        let sess = Session::new(m).unwrap();
        let x = Tensor::from_i8(&[1, 4], vec![10, 10, 10, 10]).unwrap();
        let y = sess.run(&[("x", x)]).unwrap();
        // acc = [100, -100] + bias = [110, -110]; * 0.25 = [27.5, -27.5]
        // round-half-even -> [28, -28].
        assert_eq!(y[0].as_i8().unwrap(), &[28, -28]);
    }

    #[test]
    fn fig2_relu_one_mul() {
        let params = fc_params(RescaleOp::OneMul(0.25), ActKind::Relu, QType::U8);
        let m = build_fc_model(&params, DType::U8);
        check_model(&m).unwrap();
        let ops: Vec<&str> = m.graph.nodes.iter().map(|n| n.op_type.as_str()).collect();
        assert_eq!(
            ops,
            vec!["MatMulInteger", "Add", "Cast", "Mul", "Relu", "QuantizeLinear"]
        );
        let sess = Session::new(m).unwrap();
        let x = Tensor::from_i8(&[1, 4], vec![10, 10, 10, 10]).unwrap();
        let y = sess.run(&[("x", x)]).unwrap();
        // [110, -110] * 0.25 = [27.5, -27.5]; ReLU -> [27.5, 0]; u8 -> [28, 0].
        assert_eq!(y[0].as_u8().unwrap(), &[28, 0]);
    }

    #[test]
    fn sub8_fc_emits_clip_and_saturates_narrow() {
        let params = fc_params(RescaleOp::OneMul(0.25), ActKind::None, QType::Int(4));
        let m = build_fc_model(&params, DType::I8);
        check_model(&m).unwrap();
        let ops: Vec<&str> = m.graph.nodes.iter().map(|n| n.op_type.as_str()).collect();
        assert_eq!(
            ops,
            vec!["MatMulInteger", "Add", "Cast", "Mul", "Clip", "QuantizeLinear"]
        );
        let sess = Session::new(m).unwrap();
        let x = Tensor::from_i8(&[1, 4], vec![10, 10, 10, 10]).unwrap();
        let y = sess.run(&[("x", x)]).unwrap();
        // [110, -110] * 0.25 = [27.5, -27.5]; int4 clip -> [7, -8].
        assert_eq!(y[0].as_i8().unwrap(), &[7, -8]);
    }

    #[test]
    fn fig3_conv_pattern() {
        let params = ConvParams {
            weight_q: Tensor::from_i8(&[1, 1, 2, 2], vec![1, 1, 1, 1]).unwrap(),
            bias_q: Some(Tensor::from_i32(&[1], vec![4]).unwrap()),
            rescale: RescaleOp::OneMul(0.5),
            relu: false,
            out_qtype: QType::I8,
            strides: [1, 1],
            pads: [0, 0, 0, 0],
        };
        let mut b = GraphBuilder::new("fig3");
        b.input("x", DType::I8, &batched(&[1, 3, 3]));
        let y = emit_conv(&mut b, "x", &params, "c0");
        b.output(&y, DType::I8, &batched(&[1, 2, 2]));
        let m = b.finish_model();
        check_model(&m).unwrap();
        let ops: Vec<&str> = m.graph.nodes.iter().map(|n| n.op_type.as_str()).collect();
        assert_eq!(
            ops,
            vec!["ConvInteger", "Add", "Cast", "Mul", "QuantizeLinear"]
        );
        let sess = Session::new(m).unwrap();
        let x = Tensor::from_i8(&[1, 1, 3, 3], vec![1, 2, 3, 4, 5, 6, 7, 8, 9]).unwrap();
        let y = sess.run(&[("x", x)]).unwrap();
        // window sums [12,16,24,28] + 4 = [16,20,28,32]; * 0.5 = [8,10,14,16].
        assert_eq!(y[0].as_i8().unwrap(), &[8, 10, 14, 16]);
    }

    #[test]
    fn fig4_tanh_int8_structure() {
        let d = decompose(4.0 / 127.0 / 1.0, 31).unwrap(); // maps acc 1:1 onto tanh range
        let params = fc_params(
            RescaleOp::TwoMul(d),
            ActKind::TanhInt8 {
                in_scale: 4.0 / 127.0,
                out_scale: 1.0 / 127.0,
            },
            QType::I8,
        );
        let m = build_fc_model(&params, DType::I8);
        check_model(&m).unwrap();
        let ops: Vec<&str> = m.graph.nodes.iter().map(|n| n.op_type.as_str()).collect();
        assert_eq!(
            ops,
            vec![
                "MatMulInteger",
                "Add",
                "Cast",
                "Mul",
                "Mul",
                "QuantizeLinear",
                "DequantizeLinear",
                "Tanh",
                "QuantizeLinear"
            ]
        );
    }

    #[test]
    fn fig5_tanh_f16_structure_and_range() {
        let d = decompose(2.0 / 127.0, 31).unwrap();
        let params = fc_params(
            RescaleOp::TwoMul(d),
            ActKind::TanhF16 {
                in_scale: 2.0 / 127.0,
                out_scale: 1.0 / 127.0,
            },
            QType::I8,
        );
        let m = build_fc_model(&params, DType::I8);
        check_model(&m).unwrap();
        let ops: Vec<&str> = m.graph.nodes.iter().map(|n| n.op_type.as_str()).collect();
        assert_eq!(
            ops,
            vec![
                "MatMulInteger",
                "Add",
                "Cast",
                "Mul",
                "Mul",
                "QuantizeLinear",
                "DequantizeLinear",
                "Cast",
                "Tanh",
                "Cast",
                "QuantizeLinear"
            ]
        );
        // The two casts around Tanh are f32->f16 and f16->f32.
        let casts: Vec<&str> = m
            .graph
            .nodes
            .iter()
            .filter(|n| n.op_type == "Cast")
            .filter_map(|n| n.attr_str("to"))
            .collect();
        assert_eq!(casts, vec!["FLOAT", "FLOAT16", "FLOAT"]);
    }

    #[test]
    fn fig5_tanh_f16_numerics() {
        // Multiplier sized to map the saturated accumulator (|acc| <=
        // 127*10 + 10 = 1280) onto the int8 range: m = 127/1280; tanh is
        // then evaluated at q*2/127, i.e. +-2.0 at saturation.
        let d = decompose(127.0 / 1280.0, 31).unwrap();
        let params = fc_params(
            RescaleOp::TwoMul(d),
            ActKind::TanhF16 {
                in_scale: 2.0 / 127.0,
                out_scale: 1.0 / 127.0,
            },
            QType::I8,
        );
        let m = build_fc_model(&params, DType::I8);
        let sess = Session::new(m).unwrap();
        let x = Tensor::from_i8(&[1, 4], vec![127, 127, 127, 127]).unwrap();
        let y = sess.run(&[("x", x)]).unwrap();
        // acc = [1280, -1280] -> q8 [127, -127] -> tanh(+-2.0) = +-0.96403
        // (in f16) -> round(0.964*127) = +-122.
        assert_eq!(y[0].as_i8().unwrap(), &[122, -122]);
    }

    #[test]
    fn fig6_sigmoid_f16_uint8_output() {
        let params = fc_params(
            RescaleOp::OneMul(8.0 / 127.0),
            ActKind::SigmoidF16 {
                in_scale: 8.0 / 127.0,
                out_scale: 1.0 / 255.0,
            },
            QType::U8, // requested, and enforced regardless
        );
        let m = build_fc_model(&params, DType::U8);
        check_model(&m).unwrap();
        let sess = Session::new(m).unwrap();
        // Zero input -> acc = bias [10, -10] -> small positive/negative
        // -> sigmoid around 0.5.
        let x = Tensor::from_i8(&[1, 4], vec![0, 0, 0, 0]).unwrap();
        let y = sess.run(&[("x", x)]).unwrap();
        let out = y[0].as_u8().unwrap();
        assert!(out[0] > 127 && out[0] < 160, "sigmoid(+small)={}", out[0]);
        assert!(out[1] < 128 && out[1] > 95, "sigmoid(-small)={}", out[1]);
        // Saturated positive: acc 1290 * 8/127 clamps to 127 -> sigmoid
        // input 8.0 -> 0.99966 -> ~255; negative column symmetric -> ~0.
        let x = Tensor::from_i8(&[1, 4], vec![127, 127, 127, 127]).unwrap();
        let y = sess.run(&[("x", x)]).unwrap();
        assert!(y[0].as_u8().unwrap()[0] >= 250, "{}", y[0].as_u8().unwrap()[0]);
        assert!(y[0].as_u8().unwrap()[1] <= 5, "{}", y[0].as_u8().unwrap()[1]);
    }

    #[test]
    fn patterns_serialize_round_trip() {
        let d = decompose(1.0 / 3.0, 31).unwrap();
        let params = fc_params(RescaleOp::TwoMul(d), ActKind::None, QType::I8);
        let m = build_fc_model(&params, DType::I8);
        let text = crate::onnx::model_to_json(&m);
        let back = crate::onnx::model_from_json(&text).unwrap();
        assert_eq!(m, back);
        // And the deserialized model still validates + runs.
        let sess = Session::new(back).unwrap();
        let x = Tensor::from_i8(&[1, 4], vec![3, 3, 3, 3]).unwrap();
        sess.run(&[("x", x)]).unwrap();
    }

    #[test]
    fn fc_no_bias() {
        let params = FcParams {
            weight_q: Tensor::from_i8(&[2, 2], vec![1, 0, 0, 1]).unwrap(),
            bias_q: None,
            rescale: RescaleOp::OneMul(1.0),
            activation: ActKind::None,
            out_qtype: QType::I8,
        };
        let mut b = GraphBuilder::new("nobias");
        b.input("x", DType::I8, &fixed_dims(&[1, 2]));
        let y = emit_fc(&mut b, "x", &params, "l0");
        b.output(&y, DType::I8, &fixed_dims(&[1, 2]));
        let sess = Session::new(b.finish_model()).unwrap();
        let x = Tensor::from_i8(&[1, 2], vec![5, -7]).unwrap();
        let y = sess.run(&[("x", x)]).unwrap();
        assert_eq!(y[0].as_i8().unwrap(), &[5, -7]);
    }
}
