//! The fp32 → pre-quantized model compiler.
//!
//! Walks a trained fp32 graph (Gemm / Conv / Relu / Tanh / Sigmoid /
//! MaxPool / Flatten / Reshape / Softmax) and re-emits it as the paper's
//! pre-quantized patterns (Figures 1–6), embedding all quantization
//! parameters as initializers. The result is a *standalone standard ONNX
//! model*: this crate's interpreter, the hwsim "hardware", and the
//! XLA/PJRT artifact all execute it without any out-of-band metadata
//! (paper goals 1–4).

use super::calibrate::Calibration;
use super::patterns::{emit_conv, emit_fc, ActKind, ConvParams, FcParams, RescaleOp};
use crate::onnx::ir::{Attr, Dim, Model, Node};
use crate::onnx::GraphBuilder;
use crate::quant::{
    decompose, quantize_bias, CalibStrategy, MaxRange, QType, SymmetricScale,
};
use crate::quant::calib::Calibrator;
use crate::tensor::{DType, Tensor};
use std::collections::{HashMap, HashSet};
use thiserror::Error;

#[derive(Error, Debug)]
pub enum RewriteError {
    #[error("missing calibration threshold for value '{0}'")]
    MissingCalibration(String),
    #[error("unsupported fp32 operator '{op}' at node '{node}'")]
    Unsupported { op: String, node: String },
    #[error("node '{0}': weight must be an fp32 initializer")]
    WeightNotInitializer(String),
    #[error("quant: {0}")]
    Quant(#[from] crate::quant::QuantError),
    #[error("tensor: {0}")]
    Tensor(#[from] crate::tensor::TensorError),
    #[error("graph: {0}")]
    Graph(String),
}

/// How float activations (Tanh/Sigmoid) are lowered.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ActPrecision {
    /// Fig. 4: int8 approximation via full-range mapping.
    Int8,
    /// Figs. 5/6: genuine fp16 evaluation on a narrow range.
    F16,
}

/// Options controlling the emitted patterns.
#[derive(Clone, Debug)]
pub struct QuantizeOptions {
    /// 2-Mul (hardware-explicit) or 1-Mul rescale codification (§3.1).
    pub two_mul: bool,
    /// Tanh/Sigmoid lowering precision.
    pub act_precision: ActPrecision,
    /// Calibration strategy used (recorded in model metadata only).
    pub strategy: CalibStrategy,
    /// Max right-shift the target hardware supports.
    pub max_shift: u32,
    /// Use uint8 after ReLU (doubles resolution of one-sided data).
    pub relu_uint8: bool,
    /// Tanh "full input range" for the Fig. 4 int8 approximation.
    pub tanh_full_range: f32,
    /// Narrow-range clamp for fp16 tanh/sigmoid inputs (Figs. 5/6).
    pub f16_act_range: f32,
    /// Keep f32 graph inputs/outputs by emitting QuantizeLinear /
    /// DequantizeLinear at the edges (self-contained model). When false
    /// the model has raw int8 I/O exactly like the paper's figures.
    pub float_io: bool,
}

impl Default for QuantizeOptions {
    fn default() -> Self {
        QuantizeOptions {
            two_mul: true,
            act_precision: ActPrecision::F16,
            strategy: CalibStrategy::MaxRange,
            max_shift: 31,
            relu_uint8: true,
            tanh_full_range: 4.0,
            f16_act_range: 8.0,
            float_io: true,
        }
    }
}

/// A value in the quantized graph: its name, scale and integer type.
#[derive(Clone, Debug)]
struct QValue {
    name: String,
    scale: f32,
    qtype: QType,
}

fn rescale_op(mult: f32, opts: &QuantizeOptions) -> Result<RescaleOp, RewriteError> {
    Ok(if opts.two_mul {
        RescaleOp::TwoMul(decompose(mult, opts.max_shift)?)
    } else {
        RescaleOp::OneMul(mult)
    })
}

/// Quantize a trained fp32 model into the paper's pre-quantized form.
///
/// `calibration` must cover the graph input and every pre/post-activation
/// f32 value (produced by [`super::calibrate::calibrate`] on the same
/// model).
pub fn quantize_model(
    model: &Model,
    calibration: &Calibration,
    opts: &QuantizeOptions,
) -> Result<Model, RewriteError> {
    let g = &model.graph;
    let order = crate::onnx::topo_order(g).map_err(|e| RewriteError::Graph(e.to_string()))?;
    let mut b = GraphBuilder::new(&format!("{}_preq", g.name));

    // Values already merged into an emitted pattern (activations fused
    // into the preceding FC/Conv).
    let mut consumed: HashSet<usize> = HashSet::new();
    // fp32 value name -> quantized counterpart.
    let mut qvals: HashMap<String, QValue> = HashMap::new();
    // fp32 value name -> f32 value name in the new graph (Softmax tail).
    let mut fvals: HashMap<String, String> = HashMap::new();

    let threshold = |name: &str| -> Result<f32, RewriteError> {
        calibration
            .threshold(name)
            .filter(|t| *t > 0.0)
            .ok_or_else(|| RewriteError::MissingCalibration(name.to_string()))
    };

    // Graph inputs: declare as i8 (paper figures) or f32 + QuantizeLinear.
    for vi in g.runtime_inputs() {
        let t_in = threshold(&vi.name)?;
        let s_x = SymmetricScale::from_max_abs(t_in, QType::I8)?;
        if opts.float_io {
            b.input(&vi.name, DType::F32, &vi.shape);
            let scale_name =
                b.init_fresh(&format!("{}_x_scale", vi.name), Tensor::scalar_f32(s_x.scale));
            let zp = b.init_fresh(&format!("{}_x_zp", vi.name), Tensor::scalar_i8(0));
            let q = b.node("QuantizeLinear", &[&vi.name, &scale_name, &zp], &[]);
            qvals.insert(
                vi.name.clone(),
                QValue {
                    name: q,
                    scale: s_x.scale,
                    qtype: QType::I8,
                },
            );
        } else {
            b.input(&vi.name, DType::I8, &vi.shape);
            qvals.insert(
                vi.name.clone(),
                QValue {
                    name: vi.name.clone(),
                    scale: s_x.scale,
                    qtype: QType::I8,
                },
            );
        }
    }

    // Consumer lookup for activation fusion.
    let consumers = |value: &str| -> Vec<usize> {
        g.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.inputs.iter().any(|i| i == value))
            .map(|(i, _)| i)
            .collect()
    };

    for &idx in &order {
        if consumed.contains(&idx) {
            continue;
        }
        let node = &g.nodes[idx];
        match node.op_type.as_str() {
            "Gemm" | "MatMul" => {
                let x = qvals
                    .get(&node.inputs[0])
                    .ok_or_else(|| RewriteError::Graph(format!(
                        "FC input '{}' not quantized (unsupported producer?)",
                        node.inputs[0]
                    )))?
                    .clone();
                // Weight: fp32 initializer [K,N] (transB=1 -> [N,K]).
                let w_name = &node.inputs[1];
                let mut w = g
                    .initializer(w_name)
                    .ok_or_else(|| RewriteError::WeightNotInitializer(node.name.clone()))?
                    .clone();
                if node.op_type == "Gemm" && node.attr_int("transB").unwrap_or(0) != 0 {
                    w = transpose2_f32(&w)?;
                }
                let bias = if node.op_type == "Gemm" {
                    node.inputs.get(2).and_then(|n| g.initializer(n)).cloned()
                } else {
                    None
                };

                // Weight scale from its own max (weights are fully known).
                let mut wc = MaxRange::new();
                wc.observe(w.as_f32()?);
                let s_w = SymmetricScale::from_max_abs(wc.threshold(), QType::I8)?;
                let w_q = s_w.quantize(&w)?;
                let bias_q = match &bias {
                    Some(bt) => Some(quantize_bias(bt, s_w.scale, x.scale)?),
                    None => None,
                };
                let acc_scale = s_w.scale * x.scale;
                let out_name = &node.outputs[0];

                // Activation fusion: single consumer that is an activation?
                let cons = consumers(out_name);
                let act_node: Option<&Node> = if cons.len() == 1 {
                    let n = &g.nodes[cons[0]];
                    matches!(n.op_type.as_str(), "Relu" | "Tanh" | "Sigmoid").then_some(n)
                } else {
                    None
                };

                let (params, result_scale, result_qtype, fused_value) = match act_node
                    .map(|n| n.op_type.as_str())
                {
                    Some("Relu") => {
                        let act_out = &act_node.unwrap().outputs[0];
                        let qtype = if opts.relu_uint8 { QType::U8 } else { QType::I8 };
                        let s_y =
                            SymmetricScale::from_max_abs(threshold(act_out)?, qtype)?;
                        (
                            FcParams {
                                weight_q: w_q,
                                bias_q,
                                rescale: rescale_op(acc_scale / s_y.scale, opts)?,
                                activation: ActKind::Relu,
                                out_qtype: qtype,
                            },
                            s_y.scale,
                            qtype,
                            Some(act_out.clone()),
                        )
                    }
                    Some("Tanh") => {
                        let act_out = &act_node.unwrap().outputs[0];
                        let (in_range, act) = match opts.act_precision {
                            ActPrecision::Int8 => {
                                let r = opts.tanh_full_range;
                                (
                                    r,
                                    ActKind::TanhInt8 {
                                        in_scale: r / 127.0,
                                        out_scale: 1.0 / 127.0,
                                    },
                                )
                            }
                            ActPrecision::F16 => {
                                let r = threshold(out_name)
                                    .unwrap_or(opts.f16_act_range)
                                    .min(opts.f16_act_range);
                                (
                                    r,
                                    ActKind::TanhF16 {
                                        in_scale: r / 127.0,
                                        out_scale: 1.0 / 127.0,
                                    },
                                )
                            }
                        };
                        (
                            FcParams {
                                weight_q: w_q,
                                bias_q,
                                rescale: rescale_op(acc_scale / (in_range / 127.0), opts)?,
                                activation: act,
                                out_qtype: QType::I8,
                            },
                            1.0 / 127.0,
                            QType::I8,
                            Some(act_out.clone()),
                        )
                    }
                    Some("Sigmoid") => {
                        let act_out = &act_node.unwrap().outputs[0];
                        let r = threshold(out_name)
                            .unwrap_or(opts.f16_act_range)
                            .min(opts.f16_act_range);
                        (
                            FcParams {
                                weight_q: w_q,
                                bias_q,
                                rescale: rescale_op(acc_scale / (r / 127.0), opts)?,
                                activation: ActKind::SigmoidF16 {
                                    in_scale: r / 127.0,
                                    out_scale: 1.0 / 255.0,
                                },
                                out_qtype: QType::U8,
                            },
                            1.0 / 255.0,
                            QType::U8,
                            Some(act_out.clone()),
                        )
                    }
                    _ => {
                        let s_y =
                            SymmetricScale::from_max_abs(threshold(out_name)?, QType::I8)?;
                        (
                            FcParams {
                                weight_q: w_q,
                                bias_q,
                                rescale: rescale_op(acc_scale / s_y.scale, opts)?,
                                activation: ActKind::None,
                                out_qtype: QType::I8,
                            },
                            s_y.scale,
                            QType::I8,
                            None,
                        )
                    }
                };

                let q_out = emit_fc(&mut b, &x.name, &params, &node.name);
                let key = fused_value.clone().unwrap_or_else(|| out_name.clone());
                if let Some(c) = fused_value.and(cons.first().copied()) {
                    consumed.insert(c);
                }
                qvals.insert(
                    key,
                    QValue {
                        name: q_out,
                        scale: result_scale,
                        qtype: result_qtype,
                    },
                );
            }
            "Conv" => {
                let x = qvals
                    .get(&node.inputs[0])
                    .ok_or_else(|| {
                        RewriteError::Graph(format!("Conv input '{}' not quantized", node.inputs[0]))
                    })?
                    .clone();
                let w = g
                    .initializer(&node.inputs[1])
                    .ok_or_else(|| RewriteError::WeightNotInitializer(node.name.clone()))?;
                let bias = node.inputs.get(2).and_then(|n| g.initializer(n)).cloned();
                let mut wc = MaxRange::new();
                wc.observe(w.as_f32()?);
                let s_w = SymmetricScale::from_max_abs(wc.threshold(), QType::I8)?;
                let w_q = s_w.quantize(w)?;
                let bias_q = match &bias {
                    Some(bt) => Some(quantize_bias(bt, s_w.scale, x.scale)?),
                    None => None,
                };
                let acc_scale = s_w.scale * x.scale;
                let out_name = &node.outputs[0];

                let cons = consumers(out_name);
                let relu_node = if cons.len() == 1 && g.nodes[cons[0]].op_type == "Relu" {
                    Some(cons[0])
                } else {
                    None
                };
                let (value_key, qtype) = match relu_node {
                    Some(c) => (
                        g.nodes[c].outputs[0].clone(),
                        if opts.relu_uint8 { QType::U8 } else { QType::I8 },
                    ),
                    None => (out_name.clone(), QType::I8),
                };
                let s_y = SymmetricScale::from_max_abs(threshold(&value_key)?, qtype)?;
                let attrs = crate::onnx::shape::ConvAttrs::from_node(node);
                let params = ConvParams {
                    weight_q: w_q,
                    bias_q,
                    rescale: rescale_op(acc_scale / s_y.scale, opts)?,
                    relu: relu_node.is_some(),
                    out_qtype: qtype,
                    strides: attrs.strides,
                    pads: attrs.pads,
                };
                let q_out = emit_conv(&mut b, &x.name, &params, &node.name);
                if let Some(c) = relu_node {
                    consumed.insert(c);
                }
                qvals.insert(
                    value_key,
                    QValue {
                        name: q_out,
                        scale: s_y.scale,
                        qtype,
                    },
                );
            }
            "MaxPool" => {
                let x = qvals
                    .get(&node.inputs[0])
                    .ok_or_else(|| {
                        RewriteError::Graph(format!(
                            "MaxPool input '{}' not quantized",
                            node.inputs[0]
                        ))
                    })?
                    .clone();
                // Max is order-preserving: runs directly on the quantized
                // tensor, same scale out.
                let attrs: Vec<(&str, Attr)> = node
                    .attributes
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect();
                let y = b.node("MaxPool", &[&x.name], &attrs);
                qvals.insert(
                    node.outputs[0].clone(),
                    QValue {
                        name: y,
                        scale: x.scale,
                        qtype: x.qtype,
                    },
                );
            }
            "Flatten" | "Reshape" => {
                let x = qvals
                    .get(&node.inputs[0])
                    .ok_or_else(|| {
                        RewriteError::Graph(format!(
                            "{} input '{}' not quantized",
                            node.op_type, node.inputs[0]
                        ))
                    })?
                    .clone();
                let y = if node.op_type == "Flatten" {
                    let axis = node.attr_int("axis").unwrap_or(1);
                    b.node("Flatten", &[&x.name], &[("axis", Attr::Int(axis))])
                } else {
                    let spec = g
                        .initializer(&node.inputs[1])
                        .ok_or_else(|| {
                            RewriteError::Graph("Reshape spec must be initializer".into())
                        })?
                        .clone();
                    let spec_name = b.init_fresh(&format!("{}_shape", node.name), spec);
                    b.node("Reshape", &[&x.name, &spec_name], &[])
                };
                qvals.insert(
                    node.outputs[0].clone(),
                    QValue {
                        name: y,
                        scale: x.scale,
                        qtype: x.qtype,
                    },
                );
            }
            "Softmax" => {
                // Classifier tail: dequantize, softmax in f32.
                let x = qvals
                    .get(&node.inputs[0])
                    .ok_or_else(|| {
                        RewriteError::Graph(format!(
                            "Softmax input '{}' not quantized",
                            node.inputs[0]
                        ))
                    })?
                    .clone();
                let s = b.init_fresh(
                    &format!("{}_deq_scale", node.name),
                    Tensor::scalar_f32(x.scale),
                );
                let zp = b.init_fresh(
                    &format!("{}_deq_zp", node.name),
                    match x.qtype.dtype() {
                        DType::U8 => Tensor::scalar_u8(0),
                        _ => Tensor::scalar_i8(0),
                    },
                );
                let f = b.node("DequantizeLinear", &[&x.name, &s, &zp], &[]);
                let axis = node.attr_int("axis").unwrap_or(-1);
                let y = b.node("Softmax", &[&f], &[("axis", Attr::Int(axis))]);
                fvals.insert(node.outputs[0].clone(), y);
            }
            "Identity" => {
                if let Some(x) = qvals.get(&node.inputs[0]).cloned() {
                    qvals.insert(node.outputs[0].clone(), x);
                } else if let Some(f) = fvals.get(&node.inputs[0]).cloned() {
                    fvals.insert(node.outputs[0].clone(), f);
                }
            }
            op => {
                return Err(RewriteError::Unsupported {
                    op: op.to_string(),
                    node: node.name.clone(),
                })
            }
        }
    }

    // Wire graph outputs.
    for out in &g.outputs {
        if let Some(f) = fvals.get(&out.name) {
            // Already f32 (softmax tail).
            rename_output(&mut b, f, &out.name, DType::F32, &out.shape);
        } else if let Some(q) = qvals.get(&out.name).cloned() {
            if opts.float_io {
                let s = b.init_fresh(
                    &format!("{}_out_scale", out.name),
                    Tensor::scalar_f32(q.scale),
                );
                let zp = b.init_fresh(
                    &format!("{}_out_zp", out.name),
                    match q.qtype.dtype() {
                        DType::U8 => Tensor::scalar_u8(0),
                        _ => Tensor::scalar_i8(0),
                    },
                );
                let f = b.node("DequantizeLinear", &[&q.name, &s, &zp], &[]);
                rename_output(&mut b, &f, &out.name, DType::F32, &out.shape);
            } else {
                rename_output(&mut b, &q.name, &out.name, q.qtype.dtype(), &out.shape);
            }
        } else {
            return Err(RewriteError::Graph(format!(
                "graph output '{}' was not produced by the quantized graph",
                out.name
            )));
        }
    }

    let mut m = b.finish_model();
    m.doc = format!(
        "pre-quantized from '{}' (strategy={}, {})",
        g.name,
        calibration.strategy_name,
        if opts.two_mul { "2-Mul rescale" } else { "1-Mul rescale" },
    );
    m.metadata
        .push(("quantizer".into(), "pqdl-rewrite".into()));
    Ok(m)
}

/// Give the final value the declared output name via Identity (keeps
/// external naming identical to the fp32 model).
fn rename_output(
    b: &mut GraphBuilder,
    value: &str,
    out_name: &str,
    dtype: DType,
    shape: &[Dim],
) {
    b.node_named("Identity", &[value], &[out_name], &[]);
    b.output(out_name, dtype, shape);
}

fn transpose2_f32(t: &Tensor) -> Result<Tensor, RewriteError> {
    let (r, c) = (t.shape()[0], t.shape()[1]);
    let src = t.as_f32()?;
    let mut dst = vec![0f32; r * c];
    for i in 0..r {
        for j in 0..c {
            dst[j * r + i] = src[i * c + j];
        }
    }
    Ok(Tensor::from_f32(&[c, r], dst)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::Session;
    use crate::onnx::{batched, check_model, GraphBuilder};
    use crate::rewrite::calibrate::calibrate;

    /// Small fp32 MLP: Gemm -> Relu -> Gemm -> Softmax.
    fn fp32_mlp() -> Model {
        let mut b = GraphBuilder::new("mlp");
        b.input("x", DType::F32, &batched(&[4]));
        b.init(
            "w0",
            Tensor::from_f32(&[4, 3], (0..12).map(|i| (i as f32 - 6.0) / 6.0).collect()).unwrap(),
        );
        b.init("b0", Tensor::from_f32(&[3], vec![0.1, -0.2, 0.3]).unwrap());
        let h = b.node("Gemm", &["x", "w0", "b0"], &[]);
        let r = b.node("Relu", &[&h], &[]);
        b.init(
            "w1",
            Tensor::from_f32(&[3, 2], vec![0.5, -0.5, 0.25, 0.25, -0.125, 0.75]).unwrap(),
        );
        b.init("b1", Tensor::from_f32(&[2], vec![0.05, -0.05]).unwrap());
        let o = b.node("Gemm", &[&r, "w1", "b1"], &[]);
        let sm = b.node("Softmax", &[&o], &[("axis", Attr::Int(-1))]);
        b.output(&sm, DType::F32, &batched(&[2]));
        b.finish_model()
    }

    fn calib_batches() -> Vec<Vec<(String, Tensor)>> {
        (0..8)
            .map(|i| {
                let v: Vec<f32> = (0..4).map(|j| ((i * 4 + j) as f32 / 16.0) - 1.0).collect();
                vec![("x".to_string(), Tensor::from_f32(&[1, 4], v).unwrap())]
            })
            .collect()
    }

    #[test]
    fn quantized_mlp_validates_and_tracks_fp32() {
        let fp32 = fp32_mlp();
        let sess = Session::new(fp32.clone()).unwrap();
        let cal = calibrate(&sess, &calib_batches(), CalibStrategy::MaxRange).unwrap();
        let q = quantize_model(&fp32, &cal, &QuantizeOptions::default()).unwrap();
        check_model(&q).unwrap();
        // All weights must now be int8/int32 initializers; no fp32 weight
        // tensors larger than scalars remain.
        for (name, t) in &q.graph.initializers {
            if t.dtype() == DType::F32 {
                assert!(t.numel() == 1, "fp32 initializer '{name}' is not a scalar");
            }
        }
        let qsess = Session::new(q).unwrap();
        let x = Tensor::from_f32(&[1, 4], vec![0.5, -0.5, 0.25, -1.0]).unwrap();
        let yf = sess.run(&[("x", x.clone())]).unwrap();
        let yq = qsess.run(&[("x", x)]).unwrap();
        let f = yf[0].as_f32().unwrap();
        let qv = yq[0].as_f32().unwrap();
        for (a, b) in f.iter().zip(qv) {
            assert!((a - b).abs() < 0.1, "fp32 {a} vs int8 {b}");
        }
        // Probabilities still sum to 1.
        assert!((qv.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn one_mul_mode() {
        let fp32 = fp32_mlp();
        let sess = Session::new(fp32.clone()).unwrap();
        let cal = calibrate(&sess, &calib_batches(), CalibStrategy::MaxRange).unwrap();
        let opts = QuantizeOptions {
            two_mul: false,
            ..Default::default()
        };
        let q = quantize_model(&fp32, &cal, &opts).unwrap();
        check_model(&q).unwrap();
        // 1-Mul rescale: exactly one Mul per FC layer.
        let muls = q.graph.nodes.iter().filter(|n| n.op_type == "Mul").count();
        assert_eq!(muls, 2);
    }

    #[test]
    fn two_mul_mode_has_two_muls_per_layer() {
        let fp32 = fp32_mlp();
        let sess = Session::new(fp32.clone()).unwrap();
        let cal = calibrate(&sess, &calib_batches(), CalibStrategy::MaxRange).unwrap();
        let q = quantize_model(&fp32, &cal, &QuantizeOptions::default()).unwrap();
        let muls = q.graph.nodes.iter().filter(|n| n.op_type == "Mul").count();
        assert_eq!(muls, 4);
    }

    #[test]
    fn int8_io_mode_matches_figures() {
        let fp32 = fp32_mlp();
        let sess = Session::new(fp32.clone()).unwrap();
        let cal = calibrate(&sess, &calib_batches(), CalibStrategy::MaxRange).unwrap();
        let opts = QuantizeOptions {
            float_io: false,
            ..Default::default()
        };
        // Softmax tail forces an f32 output; strip it for raw-int8 mode.
        let mut fp32_logits = fp32.clone();
        let softmax_idx = fp32_logits
            .graph
            .nodes
            .iter()
            .position(|n| n.op_type == "Softmax")
            .unwrap();
        let logits_name = fp32_logits.graph.nodes[softmax_idx].inputs[0].clone();
        fp32_logits.graph.nodes.remove(softmax_idx);
        fp32_logits.graph.outputs[0].name = logits_name;
        let q = quantize_model(&fp32_logits, &cal, &opts).unwrap();
        check_model(&q).unwrap();
        assert_eq!(q.graph.runtime_inputs()[0].dtype, DType::I8);
        assert_eq!(q.graph.outputs[0].dtype, DType::I8);
    }

    #[test]
    fn missing_calibration_is_error() {
        let fp32 = fp32_mlp();
        let cal = Calibration::default();
        assert!(matches!(
            quantize_model(&fp32, &cal, &QuantizeOptions::default()),
            Err(RewriteError::MissingCalibration(_))
        ));
    }
}
