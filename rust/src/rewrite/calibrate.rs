//! Activation profiling: run the fp32 model over a calibration set while
//! observing every f32 intermediate, producing per-value saturation
//! thresholds with a pluggable strategy (paper §3: "One approach might be
//! to profile the fp32 tensor ... another might be to ... create profile
//! histograms and saturate").

use crate::interp::{Session, SessionError};
use crate::quant::{CalibStrategy, Calibrator, QType};
use crate::tensor::{DType, Tensor};
use std::collections::HashMap;

/// Per-value calibration thresholds (absolute saturation values).
#[derive(Debug, Clone, Default)]
pub struct Calibration {
    pub thresholds: HashMap<String, f32>,
    pub strategy_name: &'static str,
}

impl Calibration {
    pub fn threshold(&self, value: &str) -> Option<f32> {
        self.thresholds.get(value).copied()
    }
}

/// Run `session` over `batches` (each a full feed set) and calibrate
/// every f32 value in the graph.
pub fn calibrate(
    session: &Session,
    batches: &[Vec<(String, Tensor)>],
    strategy: CalibStrategy,
) -> Result<Calibration, SessionError> {
    let mut calibs: HashMap<String, Box<dyn Calibrator>> = HashMap::new();
    for feeds in batches {
        let feeds_ref: Vec<(&str, Tensor)> = feeds
            .iter()
            .map(|(n, t)| (n.as_str(), t.clone()))
            .collect();
        session.run_observed(&feeds_ref, &mut |name, t| {
            if t.dtype() == DType::F32 {
                let c = calibs
                    .entry(name.to_string())
                    .or_insert_with(|| strategy.build(QType::I8));
                if let Ok(v) = t.as_f32() {
                    c.observe(v);
                }
            }
        })?;
    }
    let mut thresholds = HashMap::new();
    let mut strategy_name = "max_range";
    for (name, c) in calibs {
        strategy_name = c.name();
        thresholds.insert(name, c.threshold());
    }
    Ok(Calibration {
        thresholds,
        strategy_name,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::{batched, GraphBuilder};

    #[test]
    fn calibrates_inputs_and_intermediates() {
        let mut b = GraphBuilder::new("g");
        b.input("x", DType::F32, &batched(&[2]));
        let y = b.node("Relu", &["x"], &[]);
        b.output(&y, DType::F32, &batched(&[2]));
        let sess = Session::new(b.finish_model()).unwrap();

        let batches = vec![
            vec![(
                "x".to_string(),
                Tensor::from_f32(&[1, 2], vec![-3.0, 1.0]).unwrap(),
            )],
            vec![(
                "x".to_string(),
                Tensor::from_f32(&[1, 2], vec![0.5, 2.0]).unwrap(),
            )],
        ];
        let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange).unwrap();
        assert_eq!(cal.threshold("x"), Some(3.0));
        // Post-ReLU max is 2.0.
        let relu_out = cal
            .thresholds
            .iter()
            .find(|(k, _)| k.as_str() != "x")
            .unwrap();
        assert_eq!(*relu_out.1, 2.0);
    }
}
