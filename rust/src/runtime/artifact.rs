//! Manifest-driven artifact registry.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) maps
//! each Figure variant to per-batch HLO files plus a golden output for
//! the canonical input — letting the Rust side verify the whole
//! python→HLO→PJRT round trip without invoking Python at runtime.

use super::pjrt::{CompiledHlo, PjrtEngine};
use crate::onnx::json::Json;
use crate::tensor::{DType, Tensor};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One compiled (variant, batch) executable with its manifest metadata.
pub struct ArtifactEntry {
    pub variant: String,
    pub batch: usize,
    pub input_dtype: DType,
    pub input_shape: Vec<usize>,
    pub output_dtype: DType,
    pub output_shape: Vec<usize>,
    /// Expected output for the canonical seed-42 input (from Python).
    pub golden_output: Vec<i32>,
    pub compiled: CompiledHlo,
}

impl ArtifactEntry {
    /// Execute on an input tensor (shape must match the artifact batch).
    pub fn run(&self, input: &Tensor) -> Result<Tensor> {
        if input.shape() != self.input_shape.as_slice() {
            bail!(
                "artifact {}_b{} expects shape {:?}, got {:?}",
                self.variant,
                self.batch,
                self.input_shape,
                input.shape()
            );
        }
        self.compiled.run1(input, self.output_dtype)
    }
}

/// All artifacts for all variants, keyed by (variant, batch).
pub struct ArtifactRegistry {
    entries: HashMap<(String, usize), ArtifactEntry>,
    dir: PathBuf,
}

fn parse_np_dtype(s: &str) -> Result<DType> {
    Ok(match s {
        "int8" => DType::I8,
        "uint8" => DType::U8,
        "int32" => DType::I32,
        "float32" => DType::F32,
        other => bail!("unknown numpy dtype '{other}' in manifest"),
    })
}

impl ArtifactRegistry {
    /// Load + compile every artifact listed in `dir/manifest.json`.
    pub fn load(engine: &PjrtEngine, dir: &Path) -> Result<ArtifactRegistry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let variants = j
            .get("variants")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'variants'"))?;

        let mut entries = HashMap::new();
        for (variant, batches) in variants {
            for e in batches.as_arr().unwrap_or(&[]) {
                let get_usize = |k: &str| {
                    e.get(k)
                        .and_then(Json::to_usize)
                        .ok_or_else(|| anyhow!("manifest: missing {k}"))
                };
                let get_str = |k: &str| {
                    e.get(k)
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("manifest: missing {k}"))
                };
                let shape_of = |k: &str| -> Result<Vec<usize>> {
                    e.get(k)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("manifest: missing {k}"))?
                        .iter()
                        .map(|d| d.to_usize().ok_or_else(|| anyhow!("bad dim")))
                        .collect()
                };
                let batch = get_usize("batch")?;
                let file = get_str("file")?;
                let golden_output: Vec<i32> = e
                    .get("golden_output")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("manifest: missing golden_output"))?
                    .iter()
                    .map(|v| {
                        v.to_i64()
                            .and_then(|x| i32::try_from(x).ok())
                            .ok_or_else(|| anyhow!("bad golden value"))
                    })
                    .collect::<Result<_>>()?;
                let compiled = engine.compile_hlo_text(&dir.join(file))?;
                entries.insert(
                    (variant.clone(), batch),
                    ArtifactEntry {
                        variant: variant.clone(),
                        batch,
                        input_dtype: parse_np_dtype(get_str("input_dtype")?)?,
                        input_shape: shape_of("input_shape")?,
                        output_dtype: parse_np_dtype(get_str("output_dtype")?)?,
                        output_shape: shape_of("output_shape")?,
                        golden_output,
                        compiled,
                    },
                );
            }
        }
        Ok(ArtifactRegistry {
            entries,
            dir: dir.to_path_buf(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn get(&self, variant: &str, batch: usize) -> Option<&ArtifactEntry> {
        self.entries.get(&(variant.to_string(), batch))
    }

    pub fn variants(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .entries
            .keys()
            .map(|(name, _)| name.as_str())
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Batch sizes available for a variant, ascending.
    pub fn batches(&self, variant: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .keys()
            .filter(|(name, _)| name == variant)
            .map(|(_, b)| *b)
            .collect();
        v.sort_unstable();
        v
    }

    /// Verify every artifact reproduces its Python golden output on the
    /// canonical input. Returns (variant, batch, max_lsb_diff) rows.
    pub fn verify_golden(&self) -> Result<Vec<(String, usize, i32)>> {
        let mut rows = Vec::new();
        for ((variant, batch), entry) in &self.entries {
            let fig = crate::figures::Figure::ALL
                .iter()
                .find(|f| f.name() == variant)
                .ok_or_else(|| anyhow!("unknown variant {variant}"))?;
            let x = fig.input(*batch, 42);
            let y = entry.run(&x)?;
            let got = y.as_quantized_i32()?;
            if got.len() != entry.golden_output.len() {
                bail!("{variant}_b{batch}: output len mismatch");
            }
            let max_diff = got
                .iter()
                .zip(&entry.golden_output)
                .map(|(a, b)| (a - b).abs())
                .max()
                .unwrap_or(0);
            rows.push((variant.clone(), *batch, max_diff));
        }
        rows.sort();
        Ok(rows)
    }
}
