//! PJRT execution engine: loads the HLO-text artifacts the Python AOT
//! pipeline produced and runs them through the XLA CPU client — the
//! third "inference environment" of the paper's goal 3 (after the
//! interpreter and the hardware simulator).
//!
//! The interchange format is HLO **text**: jax >= 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use crate::tensor::{DType, Tensor, TensorData};
use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// Wrapper around one compiled HLO module.
pub struct CompiledHlo {
    exe: PjRtLoadedExecutable,
}

/// The PJRT engine: a CPU client plus compile/execute plumbing.
pub struct PjrtEngine {
    client: PjRtClient,
}

impl PjrtEngine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<PjrtEngine> {
        Ok(PjrtEngine {
            client: PjRtClient::cpu().context("creating PJRT CPU client")?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text file and compile it for this client.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<CompiledHlo> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(CompiledHlo { exe })
    }
}

impl CompiledHlo {
    /// Execute with a single input tensor; the artifact returns a
    /// 1-tuple (aot.py lowers with `return_tuple=True`).
    pub fn run1(&self, input: &Tensor, out_dtype: DType) -> Result<Tensor> {
        let lit = tensor_to_literal(input)?;
        let result = self.exe.execute::<Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        literal_to_tensor(&out, out_dtype)
    }
}

/// Convert one of our tensors to an XLA literal (exact byte copy).
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let (ty, bytes): (ElementType, Vec<u8>) = match t.data() {
        TensorData::I8(v) => (
            ElementType::S8,
            v.iter().map(|&x| x as u8).collect(),
        ),
        TensorData::U8(v) => (ElementType::U8, v.clone()),
        TensorData::I32(v) => (
            ElementType::S32,
            v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        TensorData::I64(v) => (
            ElementType::S64,
            v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        TensorData::F32(v) => (
            ElementType::F32,
            v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        TensorData::F16(v) => (
            ElementType::F16,
            v.iter().flat_map(|x| x.0.to_le_bytes()).collect(),
        ),
        TensorData::Bool(_) => bail!("bool tensors not supported by the PJRT bridge"),
    };
    Literal::create_from_shape_and_untyped_data(ty, t.shape(), &bytes)
        .map_err(|e| anyhow!("creating literal: {e}"))
}

/// Convert an XLA literal back to one of our tensors.
pub fn literal_to_tensor(lit: &Literal, dtype: DType) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = match dtype {
        DType::I8 => TensorData::I8(lit.to_vec::<i8>()?),
        DType::U8 => TensorData::U8(lit.to_vec::<u8>()?),
        DType::I32 => TensorData::I32(lit.to_vec::<i32>()?),
        DType::I64 => TensorData::I64(lit.to_vec::<i64>()?),
        DType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
        d => bail!("unsupported output dtype {d}"),
    };
    Ok(Tensor::new(dims, data)?)
}
