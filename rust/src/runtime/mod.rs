//! Runtime bridge to the AOT-compiled JAX/Pallas artifacts via PJRT.
//!
//! Python never runs here: [`pjrt::PjrtEngine`] loads HLO text files
//! produced at build time by `python/compile/aot.py`, compiles them on
//! the XLA CPU client and executes them from the Rust hot path.
//! [`artifact::ArtifactRegistry`] resolves (variant, batch) pairs from
//! the build manifest and carries golden outputs for round-trip
//! verification.

pub mod artifact;
#[cfg(feature = "xla")]
pub mod pjrt;
/// Stub PJRT bridge used when the `xla` feature (and its vendored crate) is
/// absent: same API surface, every entry point reports that the bridge is
/// unavailable. Keeps the coordinator/bench/example code building offline.
#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
pub mod service;

pub use artifact::{ArtifactEntry, ArtifactRegistry};
pub use pjrt::{literal_to_tensor, tensor_to_literal, CompiledHlo, PjrtEngine};
pub use service::PjrtService;
