//! Stub PJRT bridge (compiled when the `xla` cargo feature is disabled).
//!
//! The real bridge ([`pjrt.rs`](super::pjrt)) needs the `xla` crate, which is
//! not available in the default offline build. This module mirrors its public
//! API exactly — [`PjrtEngine`], [`CompiledHlo`], [`tensor_to_literal`],
//! [`literal_to_tensor`] — so the artifact registry, the thread-confined
//! service, the coordinator backend and the benches all compile unchanged;
//! every entry point fails with a clear "built without the xla feature"
//! error instead of executing.

use crate::tensor::{DType, Tensor};
use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "pqdl was built without the `xla` feature: the PJRT bridge is unavailable \
     (vendor the xla crate and rebuild with --features xla)";

/// Placeholder for `xla::Literal` so the conversion helpers keep their
/// signatures. Cannot be constructed.
pub struct Literal {
    _priv: (),
}

/// Stub of the compiled-HLO handle. Cannot be constructed.
pub struct CompiledHlo {
    _priv: (),
}

/// Stub of the PJRT engine. [`PjrtEngine::cpu`] always fails, so the other
/// methods are unreachable in practice but still type-check for callers.
pub struct PjrtEngine {
    _priv: (),
}

impl PjrtEngine {
    /// Always fails in the stub build.
    pub fn cpu() -> Result<PjrtEngine> {
        bail!(UNAVAILABLE)
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile_hlo_text(&self, _path: &std::path::Path) -> Result<CompiledHlo> {
        bail!(UNAVAILABLE)
    }
}

impl CompiledHlo {
    pub fn run1(&self, _input: &Tensor, _out_dtype: DType) -> Result<Tensor> {
        bail!(UNAVAILABLE)
    }
}

/// Always fails in the stub build.
pub fn tensor_to_literal(_t: &Tensor) -> Result<Literal> {
    bail!(UNAVAILABLE)
}

/// Always fails in the stub build.
pub fn literal_to_tensor(_lit: &Literal, _dtype: DType) -> Result<Tensor> {
    bail!(UNAVAILABLE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjrtEngine::cpu().unwrap_err().to_string();
        assert!(err.contains("xla"), "{err}");
    }
}
