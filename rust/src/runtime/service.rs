//! Thread-confined PJRT service.
//!
//! The `xla` crate's client/executable handles are not `Send`/`Sync`
//! (Rc-based internals over the PJRT C API), so the whole PJRT stack is
//! confined to one service thread; the rest of the system talks to it
//! over channels. This also matches how a real deployment pins an
//! accelerator context to a device thread.

use super::artifact::ArtifactRegistry;
use super::pjrt::PjrtEngine;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};

enum Job {
    Run {
        variant: String,
        batch: usize,
        input: Tensor,
        reply: mpsc::Sender<Result<Tensor, String>>,
    },
    VerifyGolden {
        reply: mpsc::Sender<Result<Vec<(String, usize, i32)>, String>>,
    },
    Shutdown,
}

/// Handle to the PJRT service thread. Clone-cheap and `Send + Sync`.
#[derive(Clone)]
pub struct PjrtService {
    tx: Arc<Mutex<mpsc::Sender<Job>>>,
    /// variant -> available artifact batch sizes (ascending).
    batches: Arc<HashMap<String, Vec<usize>>>,
}

impl PjrtService {
    /// Spawn the service thread; loads + compiles all artifacts in `dir`
    /// before returning (fails fast on a broken artifact set).
    pub fn spawn(dir: PathBuf) -> Result<PjrtService> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (init_tx, init_rx) = mpsc::channel::<Result<HashMap<String, Vec<usize>>, String>>();
        std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let setup = (|| -> Result<(PjrtEngine, ArtifactRegistry)> {
                    let engine = PjrtEngine::cpu()?;
                    let reg = ArtifactRegistry::load(&engine, &dir)?;
                    Ok((engine, reg))
                })();
                let (engine, reg) = match setup {
                    Ok(pair) => {
                        let mut batches = HashMap::new();
                        for v in pair.1.variants() {
                            batches.insert(v.to_string(), pair.1.batches(v));
                        }
                        let _ = init_tx.send(Ok(batches));
                        pair
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                let _engine = engine; // keep the client alive
                for job in rx {
                    match job {
                        Job::Run {
                            variant,
                            batch,
                            input,
                            reply,
                        } => {
                            let result = reg
                                .get(&variant, batch)
                                .ok_or_else(|| format!("no artifact {variant}_b{batch}"))
                                .and_then(|e| e.run(&input).map_err(|e| e.to_string()));
                            let _ = reply.send(result);
                        }
                        Job::VerifyGolden { reply } => {
                            let _ = reply.send(reg.verify_golden().map_err(|e| e.to_string()));
                        }
                        Job::Shutdown => return,
                    }
                }
            })
            .expect("spawning pjrt service");

        let batches = init_rx
            .recv()
            .map_err(|_| anyhow!("pjrt service died during init"))?
            .map_err(|e| anyhow!("pjrt init: {e}"))?;
        Ok(PjrtService {
            tx: Arc::new(Mutex::new(tx)),
            batches: Arc::new(batches),
        })
    }

    /// Variants available in the artifact set.
    pub fn variants(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.batches.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// Artifact batch sizes for a variant (ascending).
    pub fn batches(&self, variant: &str) -> Option<&[usize]> {
        self.batches.get(variant).map(Vec::as_slice)
    }

    /// Execute an exact-batch artifact.
    pub fn run_exact(&self, variant: &str, batch: usize, input: Tensor) -> Result<Tensor> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Run {
                variant: variant.to_string(),
                batch,
                input,
                reply,
            })
            .map_err(|_| anyhow!("pjrt service is down"))?;
        rx.recv()
            .map_err(|_| anyhow!("pjrt service dropped the request"))?
            .map_err(|e| anyhow!("{e}"))
    }

    /// Golden verification across all artifacts.
    pub fn verify_golden(&self) -> Result<Vec<(String, usize, i32)>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::VerifyGolden { reply })
            .map_err(|_| anyhow!("pjrt service is down"))?;
        rx.recv()
            .map_err(|_| anyhow!("pjrt service dropped the request"))?
            .map_err(|e| anyhow!("{e}"))
    }

    /// Stop the service thread.
    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Job::Shutdown);
    }
}
