//! MaxPool / AveragePool (NCHW, 2-D).
//!
//! MaxPool operates directly on quantized i8/u8 tensors (order-preserving,
//! so it commutes with symmetric quantization — which is why quantized
//! CNNs keep pooling in the integer domain), as well as f32.

use super::OpError;
use crate::onnx::shape::ConvAttrs;
use crate::tensor::{
    recycled_f32, recycled_i8, recycled_u8, Shape, Tensor, TensorData,
};

struct PoolGeom {
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    oh: usize,
    ow: usize,
    attrs: ConvAttrs,
}

fn geometry(x: &Tensor, kernel: &[i64], attrs: ConvAttrs) -> Result<PoolGeom, OpError> {
    let s = x.shape();
    if s.len() != 4 {
        return Err(OpError::Semantics(format!("pool expects NCHW, got {s:?}")));
    }
    let (kh, kw) = (kernel[0] as usize, kernel[1] as usize);
    let eff = |i: usize, k: usize, pb: usize, pe: usize, st: usize| (i + pb + pe - k) / st + 1;
    let oh = eff(s[2], kh, attrs.pads[0], attrs.pads[2], attrs.strides[0]);
    let ow = eff(s[3], kw, attrs.pads[1], attrs.pads[3], attrs.strides[1]);
    Ok(PoolGeom {
        n: s[0],
        c: s[1],
        h: s[2],
        w: s[3],
        kh,
        kw,
        oh,
        ow,
        attrs,
    })
}

/// Sweep every pooling window in output order, folding the in-window
/// values (in the same row-major in-window order the old `Vec`-collecting
/// sweep pushed them, so non-associative f32 reductions are bit-identical)
/// into `out`. No per-window buffer: the window state lives in `state`
/// seeded by `init` and finished by `fin(state, count)`.
fn pool_fold<T: Copy, S: Copy, FA: FnMut(S, T) -> S, FF: FnMut(S, usize) -> T>(
    src: &[T],
    g: &PoolGeom,
    out: &mut Vec<T>,
    init: S,
    mut acc: FA,
    mut fin: FF,
) {
    for b in 0..g.n {
        for ci in 0..g.c {
            let plane = &src[(b * g.c + ci) * g.h * g.w..(b * g.c + ci + 1) * g.h * g.w];
            for oy in 0..g.oh {
                for ox in 0..g.ow {
                    let mut state = init;
                    let mut count = 0usize;
                    for ky in 0..g.kh {
                        let iy = (oy * g.attrs.strides[0] + ky) as isize - g.attrs.pads[0] as isize;
                        if iy < 0 || iy as usize >= g.h {
                            continue;
                        }
                        for kx in 0..g.kw {
                            let ix =
                                (ox * g.attrs.strides[1] + kx) as isize - g.attrs.pads[1] as isize;
                            if ix < 0 || ix as usize >= g.w {
                                continue;
                            }
                            state = acc(state, plane[iy as usize * g.w + ix as usize]);
                            count += 1;
                        }
                    }
                    out.push(fin(state, count));
                }
            }
        }
    }
}

/// ONNX `MaxPool` over f32 / i8 / u8.
pub fn max_pool(x: &Tensor, kernel: &[i64], attrs: ConvAttrs) -> Result<Tensor, OpError> {
    max_pool_into(x, kernel, attrs, None)
}

/// [`max_pool`] into recycled storage (identical values).
pub fn max_pool_into(
    x: &Tensor,
    kernel: &[i64],
    attrs: ConvAttrs,
    recycled: Option<Tensor>,
) -> Result<Tensor, OpError> {
    let g = geometry(x, kernel, attrs)?;
    let n_out = g.n * g.c * g.oh * g.ow;
    let shape = Shape::from_slice(&[g.n, g.c, g.oh, g.ow]);
    let data = match x.data() {
        TensorData::F32(v) => {
            let mut out = recycled_f32(recycled, n_out);
            pool_fold(v, &g, &mut out, f32::NEG_INFINITY, f32::max, |s, _| s);
            TensorData::F32(out)
        }
        TensorData::I8(v) => {
            let mut out = recycled_i8(recycled, n_out);
            pool_fold(v, &g, &mut out, i8::MIN, i8::max, |s, _| s);
            TensorData::I8(out)
        }
        TensorData::U8(v) => {
            let mut out = recycled_u8(recycled, n_out);
            pool_fold(v, &g, &mut out, u8::MIN, u8::max, |s, _| s);
            TensorData::U8(out)
        }
        d => {
            return Err(OpError::Semantics(format!(
                "MaxPool: unsupported dtype {}",
                d.dtype()
            )))
        }
    };
    Ok(Tensor::new(shape, data)?)
}

/// ONNX `AveragePool` (f32, count_include_pad=0).
pub fn average_pool(x: &Tensor, kernel: &[i64], attrs: ConvAttrs) -> Result<Tensor, OpError> {
    average_pool_into(x, kernel, attrs, None)
}

/// [`average_pool`] into recycled storage (identical values: same
/// in-window summation order as the old collecting sweep).
pub fn average_pool_into(
    x: &Tensor,
    kernel: &[i64],
    attrs: ConvAttrs,
    recycled: Option<Tensor>,
) -> Result<Tensor, OpError> {
    let g = geometry(x, kernel, attrs)?;
    let n_out = g.n * g.c * g.oh * g.ow;
    let shape = Shape::from_slice(&[g.n, g.c, g.oh, g.ow]);
    match x.data() {
        TensorData::F32(v) => {
            let mut out = recycled_f32(recycled, n_out);
            pool_fold(
                v,
                &g,
                &mut out,
                0.0f32,
                |s, x| s + x,
                |s, count| if count == 0 { 0.0 } else { s / count as f32 },
            );
            Ok(Tensor::new(shape, TensorData::F32(out))?)
        }
        d => Err(OpError::Semantics(format!(
            "AveragePool: unsupported dtype {}",
            d.dtype()
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn attrs(strides: [usize; 2], pads: [usize; 4]) -> ConvAttrs {
        ConvAttrs {
            strides,
            pads,
            dilations: [1, 1],
            group: 1,
        }
    }

    #[test]
    fn max_pool_2x2() {
        let x = Tensor::from_f32(
            &[1, 1, 4, 4],
            (0..16).map(|i| i as f32).collect(),
        )
        .unwrap();
        let y = max_pool(&x, &[2, 2], attrs([2, 2], [0; 4])).unwrap();
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.as_f32().unwrap(), &[5., 7., 13., 15.]);
    }

    #[test]
    fn max_pool_i8_quantized_domain() {
        let x = Tensor::from_i8(&[1, 1, 2, 2], vec![-5, 3, -1, -8]).unwrap();
        let y = max_pool(&x, &[2, 2], attrs([1, 1], [0; 4])).unwrap();
        assert_eq!(y.as_i8().unwrap(), &[3]);
    }

    #[test]
    fn avg_pool_excludes_pad() {
        let x = Tensor::from_f32(&[1, 1, 2, 2], vec![2., 2., 2., 2.]).unwrap();
        // 2x2 kernel, pad 1 all around: corner windows see one real value.
        let y = average_pool(&x, &[2, 2], attrs([1, 1], [1, 1, 1, 1])).unwrap();
        assert_eq!(y.shape(), &[1, 1, 3, 3]);
        assert_eq!(y.as_f32().unwrap()[0], 2.0); // not diluted by pad
    }
}
