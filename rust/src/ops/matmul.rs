//! MatMulInteger (ONNX opset 10+), float MatMul, and Gemm.
//!
//! `MatMulInteger` is the heart of every pattern in the paper (Eq. 5:
//! `Y_intermediate = W_q · X_q + B_q`): int8/uint8 operands, i32
//! accumulation, optional zero points (the paper uses symmetric
//! quantization, i.e. zero points of 0, but the operator contract is
//! implemented in full).

use super::bitpack;
use super::isa::Isa;
use super::OpError;
use crate::parallel::{self, ThreadPool};
use crate::tensor::{DType, Shape, Tensor};
use crate::tune::{GemmConfig, Thresholds};

/// Below this many multiply-accumulates a GEMM is not worth dispatching to
/// the pool (dispatch + wake-up costs a few microseconds). Alias of the
/// unified [`Thresholds`] policy; the packed kernels read the (possibly
/// tuned) copy in their operand's [`GemmConfig`] instead.
pub const GEMM_PAR_MIN_WORK: usize = Thresholds::DEFAULT.gemm_par_min_work;
/// Minimum output rows per parallel chunk (alias of [`Thresholds`]).
pub const GEMM_PAR_MIN_ROWS: usize = Thresholds::DEFAULT.gemm_par_min_rows;

/// True when an `m x k x n` GEMM is worth running on the pool, under
/// explicit thresholds (the packed kernels pass their operand's tuned
/// config; everything else uses [`worth_parallel`]).
fn worth_parallel_cfg(
    pool: &ThreadPool,
    m: usize,
    k: usize,
    n: usize,
    min_rows: usize,
    min_work: usize,
) -> bool {
    pool.threads() > 1
        && parallel::allow_pool_dispatch()
        && m >= 2 * min_rows
        && m.saturating_mul(k).saturating_mul(n) >= min_work
}

/// [`worth_parallel_cfg`] at the default thresholds.
fn worth_parallel(pool: &ThreadPool, m: usize, k: usize, n: usize) -> bool {
    worth_parallel_cfg(pool, m, k, n, GEMM_PAR_MIN_ROWS, GEMM_PAR_MIN_WORK)
}

/// Widen an i8/u8 tensor to i32 applying an optional zero point. Also
/// used by the plan compiler to pre-widen initializer weights once.
pub(crate) fn widen_with_zp(t: &Tensor, zp: Option<&Tensor>) -> Result<Vec<i32>, OpError> {
    let zero = match zp {
        None => 0i32,
        Some(z) => {
            if z.numel() != 1 {
                return Err(OpError::Semantics(
                    "per-row/col zero points not supported (paper uses per-tensor)".into(),
                ));
            }
            z.as_quantized_i32()?[0]
        }
    };
    let mut v = t.as_quantized_i32()?;
    if zero != 0 {
        for x in &mut v {
            *x -= zero;
        }
    }
    Ok(v)
}

/// Blocked i32 GEMM kernel over pre-widened operands.
///
/// C[m,n] = sum_k A[m,k] * B[k,n], row-major. The k-inner/j-unrolled loop
/// ordering keeps B accesses sequential so the auto-vectorizer can work
/// with them; this is the interpreter's hot path (see EXPERIMENTS.md
/// §Perf).
pub fn gemm_i32(a: &[i32], b: &[i32], m: usize, k: usize, n: usize, c: &mut [i32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (kk, &a_ik) in a_row.iter().enumerate() {
            if a_ik == 0 {
                continue;
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for (c_v, &b_v) in c_row.iter_mut().zip(b_row) {
                *c_v += a_ik * b_v;
            }
        }
    }
}

/// f32 GEMM with the same loop structure.
pub fn gemm_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    c.fill(0.0);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (kk, &a_ik) in a_row.iter().enumerate() {
            let b_row = &b[kk * n..(kk + 1) * n];
            for (c_v, &b_v) in c_row.iter_mut().zip(b_row) {
                *c_v += a_ik * b_v;
            }
        }
    }
}

/// Flatten leading dims of A's shape into a single M (B is rank-2; shape
/// inference has already validated this form).
fn flat_mk(shape: &[usize]) -> (usize, usize) {
    let k = *shape.last().unwrap();
    let m = shape[..shape.len() - 1].iter().product();
    (m, k)
}

/// i8-activation GEMM with a pre-widened weight matrix: avoids
/// materializing the (batch-sized) widened activation buffer on every
/// call — the interpreter's hottest loop (§Perf).
pub fn gemm_i8_i32(a: &[i8], b_w: &[i32], m: usize, k: usize, n: usize, c: &mut [i32]) {
    c.fill(0);
    let k4 = k & !3;
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        // 4-wide k-unroll: one pass over c_row amortizes four b-rows
        // (4x the arithmetic intensity per store; see §Perf log).
        let mut kk = 0;
        while kk < k4 {
            let a0 = a_row[kk] as i32;
            let a1 = a_row[kk + 1] as i32;
            let a2 = a_row[kk + 2] as i32;
            let a3 = a_row[kk + 3] as i32;
            let b0 = &b_w[kk * n..(kk + 1) * n];
            let b1 = &b_w[(kk + 1) * n..(kk + 2) * n];
            let b2 = &b_w[(kk + 2) * n..(kk + 3) * n];
            let b3 = &b_w[(kk + 3) * n..(kk + 4) * n];
            for j in 0..n {
                c_row[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            kk += 4;
        }
        for kk in k4..k {
            let a_ik = a_row[kk] as i32;
            if a_ik == 0 {
                continue;
            }
            let b_row = &b_w[kk * n..(kk + 1) * n];
            for (c_v, &b_v) in c_row.iter_mut().zip(b_row) {
                *c_v += a_ik * b_v;
            }
        }
    }
}

/// Row-parallel wrapper over [`gemm_i8_i32`]: splits the output rows over
/// the pool. Integer accumulation per output element is identical to the
/// serial kernel, so the result is bit-exact regardless of the split.
pub fn gemm_i8_i32_par(
    pool: &ThreadPool,
    a: &[i8],
    b_w: &[i32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [i32],
) {
    if !worth_parallel(pool, m, k, n) {
        gemm_i8_i32(a, b_w, m, k, n, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, GEMM_PAR_MIN_ROWS, |row0, block| {
        let rows = block.len() / n;
        gemm_i8_i32(&a[row0 * k..(row0 + rows) * k], b_w, rows, k, n, block);
    });
}

// --- cache-blocked packed i8 GEMM -----------------------------------------
//
// The plan-time packed layout + register-tiled microkernels behind the
// compiled plans (EXPERIMENTS.md §Perf). Weights are stored as i8 (4x less
// memory traffic than the widened-i32 layout they replace) in L1-sized
// panels; accumulation is i32, and because integer addition is associative
// and commutative — and every kernel below visits k in ascending order per
// output element anyway — results are bit-identical to the naive triple
// loop under ANY blocking. `tests/packed_gemm.rs` proves it by property
// test, `tests/executor_plan.rs` end to end.
//
// Since the auto-tuner landed, the panel width NR and k-block KC are not
// constants but per-operand [`GemmConfig`] fields chosen at pack time
// (GEMM_NR/GEMM_KC below are the untuned defaults). The tile choice is a
// pure performance knob: NR changes the packed LAYOUT and register-tile
// shape, KC only the k-loop blocking — neither touches the ascending-k
// per-element accumulation order, so every candidate stays bit-identical
// to the scalar oracle (`tests/tuner.rs` proptests the whole space).

/// Default microkernel register-tile width (output columns per B panel).
pub const GEMM_NR: usize = 8;
/// Largest panel width any [`GemmConfig`] candidate may use (fallback
/// kernels size their stack accumulators with it).
pub const GEMM_NR_MAX: usize = 16;
/// Microkernel register-tile height (output rows per A panel). Not
/// tunable: the SIMD twins and the PackedA layout bake it in.
pub const GEMM_MR: usize = 4;
/// Default k-block size: one `[GEMM_KC x GEMM_NR]` i8 B-panel block is
/// 2 KiB, comfortably L1-resident with the A rows streaming against it.
pub const GEMM_KC: usize = 256;

/// A `[k, n]` B operand packed at plan time for [`gemm_i8_packed`]:
/// `ceil(n/nr)` column panels, each `[k x nr]` row-major i8 with the
/// ragged last panel zero-padded (`nr` from the pack-time [`GemmConfig`]).
/// Values are the zero-point-folded weights; packing refuses (returns
/// `None`) when any folded value leaves the i8 range (u8 weights, large
/// zero points), in which case callers keep the widened-i32 kernel —
/// identical results either way.
pub struct PackedB {
    data: Vec<i8>,
    pub k: usize,
    pub n: usize,
    /// Tile config this operand was packed with: `nr` fixes the panel
    /// LAYOUT, `kc` and the parallel thresholds steer the kernels at run
    /// time.
    pub cfg: GemmConfig,
}

impl PackedB {
    /// Pack widened (zero-point-folded) weights with the default tile
    /// config, or `None` if they don't fit i8 (symmetric quantization —
    /// every pattern in the paper — fits).
    pub fn pack(bw: &[i32], k: usize, n: usize) -> Option<PackedB> {
        PackedB::pack_with(bw, k, n, GemmConfig::DEFAULT)
    }

    /// Pack with an explicit (tuned) tile config.
    pub fn pack_with(bw: &[i32], k: usize, n: usize, cfg: GemmConfig) -> Option<PackedB> {
        debug_assert_eq!(bw.len(), k * n);
        assert!(cfg.nr > 0 && cfg.nr <= GEMM_NR_MAX, "bad panel width {}", cfg.nr);
        if bw.iter().any(|&v| v < i8::MIN as i32 || v > i8::MAX as i32) {
            return None;
        }
        let nr = cfg.nr;
        let np = n.div_ceil(nr);
        let mut data = vec![0i8; np * k * nr];
        for jp in 0..np {
            let j0 = jp * nr;
            let jw = nr.min(n - j0);
            let panel = &mut data[jp * k * nr..(jp + 1) * k * nr];
            for kk in 0..k {
                for jj in 0..jw {
                    panel[kk * nr + jj] = bw[kk * n + j0 + jj] as i8;
                }
            }
        }
        Some(PackedB { data, k, n, cfg })
    }

    /// Bytes held by the packed panels (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// i8 GEMM against a pre-packed B: C[m,n] = A[m,k] x B[k,n], i32
/// accumulation. Loop order: B column panel (L1-resident) -> MR-row
/// register tile -> KC-blocked k sweep. Every output element accumulates
/// its products in ascending-k order, so the result is bit-identical to
/// the naive triple loop and to [`gemm_i8_i32`] over widened weights.
/// Dispatches on the pack-time panel width so the common widths keep
/// compile-time-bounded (fully unrolled) accumulator loops.
pub fn gemm_i8_packed(a: &[i8], bp: &PackedB, m: usize, c: &mut [i32]) {
    match bp.cfg.nr {
        4 => gemm_i8_packed_tile::<4>(a, bp, m, c, 4),
        8 => gemm_i8_packed_tile::<8>(a, bp, m, c, 8),
        16 => gemm_i8_packed_tile::<16>(a, bp, m, c, 16),
        nr => gemm_i8_packed_tile::<GEMM_NR_MAX>(a, bp, m, c, nr),
    }
}

/// The [`gemm_i8_packed`] body, generic over the stack-accumulator
/// CAPACITY. `nr` is the runtime panel width (== `NR_CAP` for the
/// specialized widths; `<=` for the catch-all), and the `nr == NR_CAP`
/// branch around the k sweep lets the compiler unroll the fast path while
/// the same source handles any width — both sides accumulate in identical
/// ascending-k order.
fn gemm_i8_packed_tile<const NR_CAP: usize>(
    a: &[i8],
    bp: &PackedB,
    m: usize,
    c: &mut [i32],
    nr: usize,
) {
    let (k, n) = (bp.k, bp.n);
    debug_assert_eq!(nr, bp.cfg.nr);
    debug_assert!(nr > 0 && nr <= NR_CAP);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    let kc_blk = bp.cfg.kc.max(1);
    let np = n.div_ceil(nr);
    for jp in 0..np {
        let j0 = jp * nr;
        let jw = nr.min(n - j0);
        let panel = &bp.data[jp * k * nr..(jp + 1) * k * nr];
        let mut i0 = 0;
        while i0 < m {
            let iw = GEMM_MR.min(m - i0);
            let mut acc = [[0i32; NR_CAP]; GEMM_MR];
            let mut kb = 0;
            while kb < k {
                let kc = kc_blk.min(k - kb);
                if nr == NR_CAP {
                    for kk in kb..kb + kc {
                        let brow = &panel[kk * NR_CAP..(kk + 1) * NR_CAP];
                        for r in 0..iw {
                            let av = a[(i0 + r) * k + kk] as i32;
                            for jj in 0..NR_CAP {
                                acc[r][jj] += av * brow[jj] as i32;
                            }
                        }
                    }
                } else {
                    for kk in kb..kb + kc {
                        let brow = &panel[kk * nr..(kk + 1) * nr];
                        for r in 0..iw {
                            let av = a[(i0 + r) * k + kk] as i32;
                            for (jj, &bv) in brow.iter().enumerate() {
                                acc[r][jj] += av * bv as i32;
                            }
                        }
                    }
                }
                kb += kc;
            }
            for r in 0..iw {
                let base = (i0 + r) * n + j0;
                c[base..base + jw].copy_from_slice(&acc[r][..jw]);
            }
            i0 += GEMM_MR;
        }
    }
}

/// Row-parallel wrapper over [`gemm_i8_packed`] (bit-exact: disjoint row
/// blocks, identical per-element accumulation order). The dispatch
/// thresholds come from the operand's (possibly tuned) config.
pub fn gemm_i8_packed_par(pool: &ThreadPool, a: &[i8], bp: &PackedB, m: usize, c: &mut [i32]) {
    let (k, n) = (bp.k, bp.n);
    let min_rows = bp.cfg.par_min_rows.max(1);
    if !worth_parallel_cfg(pool, m, k, n, min_rows, bp.cfg.par_min_work) {
        gemm_i8_packed(a, bp, m, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, min_rows, |row0, block| {
        let rows = block.len() / n;
        gemm_i8_packed(&a[row0 * k..(row0 + rows) * k], bp, rows, block);
    });
}

/// An `[m, k]` A operand (the conv weight matrix) packed at plan time for
/// [`gemm_i8_packed_a`]: `ceil(m/MR)` row panels, each `[k x MR]` with the
/// MR row-values for one k interleaved (so the microkernel loads them as
/// one contiguous word per k step); ragged last panel zero-padded.
pub struct PackedA {
    data: Vec<i8>,
    pub m: usize,
    pub k: usize,
    /// Tile config this operand was packed with (see [`PackedA::pack_with`]
    /// for which fields matter on the packed-A path).
    pub cfg: GemmConfig,
}

impl PackedA {
    /// Pack widened (zero-point-folded) weights with the default tile
    /// config, or `None` if out of i8 range — see [`PackedB::pack`].
    pub fn pack(aw: &[i32], m: usize, k: usize) -> Option<PackedA> {
        PackedA::pack_with(aw, m, k, GemmConfig::DEFAULT)
    }

    /// Pack with an explicit (tuned) tile config. The PANEL layout only
    /// depends on the fixed `GEMM_MR` — `cfg.nr` steers the runtime
    /// column-block width of [`gemm_i8_packed_a`] (and `cfg.kc` is
    /// unused: that kernel streams B rows once, nothing to k-block).
    pub fn pack_with(aw: &[i32], m: usize, k: usize, cfg: GemmConfig) -> Option<PackedA> {
        debug_assert_eq!(aw.len(), m * k);
        assert!(cfg.nr > 0 && cfg.nr <= GEMM_NR_MAX, "bad panel width {}", cfg.nr);
        if aw.iter().any(|&v| v < i8::MIN as i32 || v > i8::MAX as i32) {
            return None;
        }
        let mp = m.div_ceil(GEMM_MR);
        let mut data = vec![0i8; mp * k * GEMM_MR];
        for ip in 0..mp {
            let i0 = ip * GEMM_MR;
            let iw = GEMM_MR.min(m - i0);
            let panel = &mut data[ip * k * GEMM_MR..(ip + 1) * k * GEMM_MR];
            for kk in 0..k {
                for r in 0..iw {
                    panel[kk * GEMM_MR + r] = aw[(i0 + r) * k + kk] as i8;
                }
            }
        }
        Some(PackedA { data, m, k, cfg })
    }

    /// Bytes held by the packed panels (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// i8 GEMM against a pre-packed A and a runtime row-major i8 B (the conv
/// im2col columns): C[m,n] = A[m,k] x B[k,n], i32 accumulation, ascending
/// k per element — bit-identical to the naive loop (see module note).
/// Dispatches on the config's column-block width like [`gemm_i8_packed`].
pub fn gemm_i8_packed_a(ap: &PackedA, b: &[i8], n: usize, c: &mut [i32]) {
    match ap.cfg.nr {
        4 => gemm_i8_packed_a_tile::<4>(ap, b, n, c, 4),
        8 => gemm_i8_packed_a_tile::<8>(ap, b, n, c, 8),
        16 => gemm_i8_packed_a_tile::<16>(ap, b, n, c, 16),
        nr => gemm_i8_packed_a_tile::<GEMM_NR_MAX>(ap, b, n, c, nr),
    }
}

/// The [`gemm_i8_packed_a`] body; capacity/width split as in
/// [`gemm_i8_packed_tile`]. `jw == NR_CAP` implies `nr == NR_CAP` (jw
/// never exceeds nr), so the fast branch is compile-time bounded.
fn gemm_i8_packed_a_tile<const NR_CAP: usize>(
    ap: &PackedA,
    b: &[i8],
    n: usize,
    c: &mut [i32],
    nr: usize,
) {
    let (m, k) = (ap.m, ap.k);
    debug_assert_eq!(nr, ap.cfg.nr);
    debug_assert!(nr > 0 && nr <= NR_CAP);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let mp = m.div_ceil(GEMM_MR);
    for ip in 0..mp {
        let i0 = ip * GEMM_MR;
        let iw = GEMM_MR.min(m - i0);
        let panel = &ap.data[ip * k * GEMM_MR..(ip + 1) * k * GEMM_MR];
        let mut j0 = 0;
        while j0 < n {
            let jw = nr.min(n - j0);
            let mut acc = [[0i32; NR_CAP]; GEMM_MR];
            if jw == NR_CAP {
                for kk in 0..k {
                    let arow = &panel[kk * GEMM_MR..(kk + 1) * GEMM_MR];
                    let brow = &b[kk * n + j0..kk * n + j0 + NR_CAP];
                    for r in 0..GEMM_MR {
                        let av = arow[r] as i32;
                        for jj in 0..NR_CAP {
                            acc[r][jj] += av * brow[jj] as i32;
                        }
                    }
                }
            } else {
                for kk in 0..k {
                    let arow = &panel[kk * GEMM_MR..(kk + 1) * GEMM_MR];
                    let brow = &b[kk * n + j0..kk * n + j0 + jw];
                    for r in 0..GEMM_MR {
                        let av = arow[r] as i32;
                        for (jj, &bv) in brow.iter().enumerate() {
                            acc[r][jj] += av * bv as i32;
                        }
                    }
                }
            }
            for r in 0..iw {
                let base = (i0 + r) * n + j0;
                c[base..base + jw].copy_from_slice(&acc[r][..jw]);
            }
            j0 += nr;
        }
    }
}

// --- plan-time ISA dispatch over the packed kernels -------------------------
//
// Each SIMD variant below is a lane-for-lane transcription of its scalar
// twin: the GEMM_NR-wide `jj` loop becomes one widening i8->i32 load plus
// a 32-bit-lane multiply-accumulate, still visiting k in ascending order
// per output element. i32 lane arithmetic is exact (i8 x i8 products fit
// i32 for any realistic k) and the accumulation ORDER is unchanged, so the
// results are bit-identical to the scalar kernels — which stay compiled on
// every target as the always-available differential oracle
// (`tests/packed_gemm.rs` proves the equivalence per available ISA).
//
// All `unsafe` is confined to `#[target_feature]` functions that are only
// reachable through `Isa::normalized()`, so a forced/unsupported ISA value
// degrades to scalar instead of executing illegal instructions. The
// in-bounds argument for every raw 8-byte load is given at each function.

/// [`gemm_i8_packed`] through a plan-selected ISA. Values the host does
/// not support degrade to the scalar kernel — identical bits either way.
/// The SIMD twins are written for the default 8-lane panel width, so any
/// other tuned width runs the (bit-identical) scalar kernels; the tuner
/// measures each candidate through this exact gate, so a non-8 width only
/// ever wins if its scalar path is genuinely faster on this machine.
pub fn gemm_i8_packed_isa(isa: Isa, a: &[i8], bp: &PackedB, m: usize, c: &mut [i32]) {
    if bp.cfg.nr != GEMM_NR {
        return gemm_i8_packed(a, bp, m, c);
    }
    match isa.normalized() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: normalized() verified the feature bit on this host.
        Isa::Avx2 => unsafe { x86::gemm_i8_packed_avx2(a, bp, m, c) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Isa::Sse41 => unsafe { x86::gemm_i8_packed_sse41(a, bp, m, c) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: normalized() admits Neon only on aarch64 hosts.
        Isa::Neon => unsafe { arm::gemm_i8_packed_neon(a, bp, m, c) },
        _ => gemm_i8_packed(a, bp, m, c),
    }
}

/// [`gemm_i8_packed_a`] through a plan-selected ISA (same contract as
/// [`gemm_i8_packed_isa`]).
pub fn gemm_i8_packed_a_isa(isa: Isa, ap: &PackedA, b: &[i8], n: usize, c: &mut [i32]) {
    if ap.cfg.nr != GEMM_NR {
        return gemm_i8_packed_a(ap, b, n, c);
    }
    match isa.normalized() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: normalized() verified the feature bit on this host.
        Isa::Avx2 => unsafe { x86::gemm_i8_packed_a_avx2(ap, b, n, c) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Isa::Sse41 => unsafe { x86::gemm_i8_packed_a_sse41(ap, b, n, c) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: normalized() admits Neon only on aarch64 hosts.
        Isa::Neon => unsafe { arm::gemm_i8_packed_a_neon(ap, b, n, c) },
        _ => gemm_i8_packed_a(ap, b, n, c),
    }
}

/// [`gemm_i8_packed_par`] through a plan-selected ISA: the pool split is
/// unchanged (disjoint row blocks), each block runs the ISA-dispatched
/// serial kernel — still bit-exact across thread counts for the same
/// reason the scalar parallel wrapper is.
pub fn gemm_i8_packed_par_isa(
    pool: &ThreadPool,
    isa: Isa,
    a: &[i8],
    bp: &PackedB,
    m: usize,
    c: &mut [i32],
) {
    let (k, n) = (bp.k, bp.n);
    let min_rows = bp.cfg.par_min_rows.max(1);
    if !worth_parallel_cfg(pool, m, k, n, min_rows, bp.cfg.par_min_work) {
        gemm_i8_packed_isa(isa, a, bp, m, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, min_rows, |row0, block| {
        let rows = block.len() / n;
        gemm_i8_packed_isa(isa, &a[row0 * k..(row0 + rows) * k], bp, rows, block);
    });
}

/// Scalar ragged right edge (jw < GEMM_NR) of [`gemm_i8_packed_a`], shared
/// by the SIMD variants. Byte-for-byte the scalar kernel's ragged branch:
/// same ascending-k accumulation, so splitting the column blocks between
/// vector body and scalar tail cannot change any output bit.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn packed_a_ragged_tail(
    panel: &[i8],
    b: &[i8],
    n: usize,
    c: &mut [i32],
    i0: usize,
    iw: usize,
    j0: usize,
    k: usize,
) {
    let jw = n - j0;
    let mut acc = [[0i32; GEMM_NR]; GEMM_MR];
    for kk in 0..k {
        let arow = &panel[kk * GEMM_MR..(kk + 1) * GEMM_MR];
        let brow = &b[kk * n + j0..kk * n + j0 + jw];
        for r in 0..GEMM_MR {
            let av = arow[r] as i32;
            for (jj, &bv) in brow.iter().enumerate() {
                acc[r][jj] += av * bv as i32;
            }
        }
    }
    for r in 0..iw {
        let base = (i0 + r) * n + j0;
        c[base..base + jw].copy_from_slice(&acc[r][..jw]);
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{PackedA, PackedB, GEMM_MR, GEMM_NR};
    #[allow(clippy::wildcard_imports)]
    use std::arch::x86_64::*;

    /// AVX2 twin of [`super::gemm_i8_packed`]: identical loop structure,
    /// with the GEMM_NR-wide `jj` loop as one 8-lane i32 vector (widening
    /// B load `vpmovsxbd`, then `vpmulld`+`vpaddd` accumulate).
    ///
    /// Safety: caller must have verified AVX2 (`Isa::normalized`). Every
    /// raw 8-byte B load reads `panel[kk*NR .. kk*NR+8]` with `kk < k`
    /// and `panel.len() == k*NR`, `NR == 8` (the ISA dispatchers route
    /// every other tuned width to the scalar kernels) — always in bounds.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_i8_packed_avx2(a: &[i8], bp: &PackedB, m: usize, c: &mut [i32]) {
        let (k, n) = (bp.k, bp.n);
        debug_assert_eq!(bp.cfg.nr, GEMM_NR);
        let kc_blk = bp.cfg.kc.max(1);
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(c.len(), m * n);
        let np = n.div_ceil(GEMM_NR);
        for jp in 0..np {
            let j0 = jp * GEMM_NR;
            let jw = GEMM_NR.min(n - j0);
            let panel = &bp.data[jp * k * GEMM_NR..(jp + 1) * k * GEMM_NR];
            let mut i0 = 0;
            while i0 < m {
                let iw = GEMM_MR.min(m - i0);
                let mut acc = [_mm256_setzero_si256(); GEMM_MR];
                let mut kb = 0;
                while kb < k {
                    let kc = kc_blk.min(k - kb);
                    for kk in kb..kb + kc {
                        let bv = _mm256_cvtepi8_epi32(_mm_loadl_epi64(
                            panel.as_ptr().add(kk * GEMM_NR) as *const __m128i,
                        ));
                        for r in 0..iw {
                            let av = _mm256_set1_epi32(a[(i0 + r) * k + kk] as i32);
                            acc[r] = _mm256_add_epi32(acc[r], _mm256_mullo_epi32(av, bv));
                        }
                    }
                    kb += kc;
                }
                let mut tmp = [0i32; GEMM_NR];
                for r in 0..iw {
                    _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, acc[r]);
                    let base = (i0 + r) * n + j0;
                    c[base..base + jw].copy_from_slice(&tmp[..jw]);
                }
                i0 += GEMM_MR;
            }
        }
    }

    /// SSE4.1 twin of [`super::gemm_i8_packed`]: the 8-wide panel row as
    /// two 4-lane halves (`pmovsxbd` + `pmulld`/`paddd`).
    ///
    /// Safety: caller verified SSE4.1; load bounds as in the AVX2 twin.
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn gemm_i8_packed_sse41(a: &[i8], bp: &PackedB, m: usize, c: &mut [i32]) {
        let (k, n) = (bp.k, bp.n);
        debug_assert_eq!(bp.cfg.nr, GEMM_NR);
        let kc_blk = bp.cfg.kc.max(1);
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(c.len(), m * n);
        let np = n.div_ceil(GEMM_NR);
        for jp in 0..np {
            let j0 = jp * GEMM_NR;
            let jw = GEMM_NR.min(n - j0);
            let panel = &bp.data[jp * k * GEMM_NR..(jp + 1) * k * GEMM_NR];
            let mut i0 = 0;
            while i0 < m {
                let iw = GEMM_MR.min(m - i0);
                let mut lo = [_mm_setzero_si128(); GEMM_MR];
                let mut hi = [_mm_setzero_si128(); GEMM_MR];
                let mut kb = 0;
                while kb < k {
                    let kc = kc_blk.min(k - kb);
                    for kk in kb..kb + kc {
                        let b8 = _mm_loadl_epi64(
                            panel.as_ptr().add(kk * GEMM_NR) as *const __m128i
                        );
                        let blo = _mm_cvtepi8_epi32(b8);
                        let bhi = _mm_cvtepi8_epi32(_mm_srli_si128::<4>(b8));
                        for r in 0..iw {
                            let av = _mm_set1_epi32(a[(i0 + r) * k + kk] as i32);
                            lo[r] = _mm_add_epi32(lo[r], _mm_mullo_epi32(av, blo));
                            hi[r] = _mm_add_epi32(hi[r], _mm_mullo_epi32(av, bhi));
                        }
                    }
                    kb += kc;
                }
                let mut tmp = [0i32; GEMM_NR];
                for r in 0..iw {
                    _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, lo[r]);
                    _mm_storeu_si128(tmp.as_mut_ptr().add(4) as *mut __m128i, hi[r]);
                    let base = (i0 + r) * n + j0;
                    c[base..base + jw].copy_from_slice(&tmp[..jw]);
                }
                i0 += GEMM_MR;
            }
        }
    }

    /// AVX2 twin of [`super::gemm_i8_packed_a`] for full GEMM_NR column
    /// blocks; the ragged right edge runs the shared scalar tail.
    ///
    /// Safety: caller verified AVX2. The raw 8-byte B loads read
    /// `b[kk*n + j0 ..][..8]` under `j0 + GEMM_NR <= n` and `kk < k`, so
    /// they end at `kk*n + j0 + 8 <= (kk+1)*n <= b.len()`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_i8_packed_a_avx2(ap: &PackedA, b: &[i8], n: usize, c: &mut [i32]) {
        let (m, k) = (ap.m, ap.k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let mp = m.div_ceil(GEMM_MR);
        for ip in 0..mp {
            let i0 = ip * GEMM_MR;
            let iw = GEMM_MR.min(m - i0);
            let panel = &ap.data[ip * k * GEMM_MR..(ip + 1) * k * GEMM_MR];
            let mut j0 = 0;
            while j0 + GEMM_NR <= n {
                let mut acc = [_mm256_setzero_si256(); GEMM_MR];
                for kk in 0..k {
                    let bv = _mm256_cvtepi8_epi32(_mm_loadl_epi64(
                        b.as_ptr().add(kk * n + j0) as *const __m128i,
                    ));
                    let arow = &panel[kk * GEMM_MR..(kk + 1) * GEMM_MR];
                    for r in 0..GEMM_MR {
                        let av = _mm256_set1_epi32(arow[r] as i32);
                        acc[r] = _mm256_add_epi32(acc[r], _mm256_mullo_epi32(av, bv));
                    }
                }
                let mut tmp = [0i32; GEMM_NR];
                for r in 0..iw {
                    _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, acc[r]);
                    let base = (i0 + r) * n + j0;
                    c[base..base + GEMM_NR].copy_from_slice(&tmp);
                }
                j0 += GEMM_NR;
            }
            if j0 < n {
                super::packed_a_ragged_tail(panel, b, n, c, i0, iw, j0, k);
            }
        }
    }

    /// SSE4.1 twin of [`super::gemm_i8_packed_a`] (two 4-lane halves);
    /// ragged right edge via the shared scalar tail.
    ///
    /// Safety: caller verified SSE4.1; load bounds as in the AVX2 twin.
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn gemm_i8_packed_a_sse41(ap: &PackedA, b: &[i8], n: usize, c: &mut [i32]) {
        let (m, k) = (ap.m, ap.k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let mp = m.div_ceil(GEMM_MR);
        for ip in 0..mp {
            let i0 = ip * GEMM_MR;
            let iw = GEMM_MR.min(m - i0);
            let panel = &ap.data[ip * k * GEMM_MR..(ip + 1) * k * GEMM_MR];
            let mut j0 = 0;
            while j0 + GEMM_NR <= n {
                let mut lo = [_mm_setzero_si128(); GEMM_MR];
                let mut hi = [_mm_setzero_si128(); GEMM_MR];
                for kk in 0..k {
                    let b8 = _mm_loadl_epi64(b.as_ptr().add(kk * n + j0) as *const __m128i);
                    let blo = _mm_cvtepi8_epi32(b8);
                    let bhi = _mm_cvtepi8_epi32(_mm_srli_si128::<4>(b8));
                    let arow = &panel[kk * GEMM_MR..(kk + 1) * GEMM_MR];
                    for r in 0..GEMM_MR {
                        let av = _mm_set1_epi32(arow[r] as i32);
                        lo[r] = _mm_add_epi32(lo[r], _mm_mullo_epi32(av, blo));
                        hi[r] = _mm_add_epi32(hi[r], _mm_mullo_epi32(av, bhi));
                    }
                }
                let mut tmp = [0i32; GEMM_NR];
                for r in 0..iw {
                    _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, lo[r]);
                    _mm_storeu_si128(tmp.as_mut_ptr().add(4) as *mut __m128i, hi[r]);
                    let base = (i0 + r) * n + j0;
                    c[base..base + GEMM_NR].copy_from_slice(&tmp);
                }
                j0 += GEMM_NR;
            }
            if j0 < n {
                super::packed_a_ragged_tail(panel, b, n, c, i0, iw, j0, k);
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{PackedA, PackedB, GEMM_MR, GEMM_NR};
    #[allow(clippy::wildcard_imports)]
    use std::arch::aarch64::*;

    /// NEON twin of [`super::gemm_i8_packed`]: the 8-wide panel row as
    /// two 4-lane i32 halves (`sshll` widening, `mla` accumulate).
    ///
    /// Safety: NEON is baseline on aarch64 (guarded by `Isa::normalized`
    /// anyway). Load bounds as in the x86 twins: 8 bytes at
    /// `panel[kk*NR..]` with `kk < k`, `panel.len() == k*NR`, `NR == 8`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm_i8_packed_neon(a: &[i8], bp: &PackedB, m: usize, c: &mut [i32]) {
        let (k, n) = (bp.k, bp.n);
        debug_assert_eq!(bp.cfg.nr, GEMM_NR);
        let kc_blk = bp.cfg.kc.max(1);
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(c.len(), m * n);
        let np = n.div_ceil(GEMM_NR);
        for jp in 0..np {
            let j0 = jp * GEMM_NR;
            let jw = GEMM_NR.min(n - j0);
            let panel = &bp.data[jp * k * GEMM_NR..(jp + 1) * k * GEMM_NR];
            let mut i0 = 0;
            while i0 < m {
                let iw = GEMM_MR.min(m - i0);
                let mut lo = [vdupq_n_s32(0); GEMM_MR];
                let mut hi = [vdupq_n_s32(0); GEMM_MR];
                let mut kb = 0;
                while kb < k {
                    let kc = kc_blk.min(k - kb);
                    for kk in kb..kb + kc {
                        let b16 = vmovl_s8(vld1_s8(panel.as_ptr().add(kk * GEMM_NR)));
                        let blo = vmovl_s16(vget_low_s16(b16));
                        let bhi = vmovl_s16(vget_high_s16(b16));
                        for r in 0..iw {
                            let av = vdupq_n_s32(a[(i0 + r) * k + kk] as i32);
                            lo[r] = vmlaq_s32(lo[r], av, blo);
                            hi[r] = vmlaq_s32(hi[r], av, bhi);
                        }
                    }
                    kb += kc;
                }
                let mut tmp = [0i32; GEMM_NR];
                for r in 0..iw {
                    vst1q_s32(tmp.as_mut_ptr(), lo[r]);
                    vst1q_s32(tmp.as_mut_ptr().add(4), hi[r]);
                    let base = (i0 + r) * n + j0;
                    c[base..base + jw].copy_from_slice(&tmp[..jw]);
                }
                i0 += GEMM_MR;
            }
        }
    }

    /// NEON twin of [`super::gemm_i8_packed_a`]; ragged right edge via
    /// the shared scalar tail.
    ///
    /// Safety: NEON baseline; B load bounds as in the x86 packed-A twins
    /// (`j0 + GEMM_NR <= n` keeps every 8-byte load inside row `kk`).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm_i8_packed_a_neon(ap: &PackedA, b: &[i8], n: usize, c: &mut [i32]) {
        let (m, k) = (ap.m, ap.k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let mp = m.div_ceil(GEMM_MR);
        for ip in 0..mp {
            let i0 = ip * GEMM_MR;
            let iw = GEMM_MR.min(m - i0);
            let panel = &ap.data[ip * k * GEMM_MR..(ip + 1) * k * GEMM_MR];
            let mut j0 = 0;
            while j0 + GEMM_NR <= n {
                let mut lo = [vdupq_n_s32(0); GEMM_MR];
                let mut hi = [vdupq_n_s32(0); GEMM_MR];
                for kk in 0..k {
                    let b16 = vmovl_s8(vld1_s8(b.as_ptr().add(kk * n + j0)));
                    let blo = vmovl_s16(vget_low_s16(b16));
                    let bhi = vmovl_s16(vget_high_s16(b16));
                    let arow = &panel[kk * GEMM_MR..(kk + 1) * GEMM_MR];
                    for r in 0..GEMM_MR {
                        let av = vdupq_n_s32(arow[r] as i32);
                        lo[r] = vmlaq_s32(lo[r], av, blo);
                        hi[r] = vmlaq_s32(hi[r], av, bhi);
                    }
                }
                let mut tmp = [0i32; GEMM_NR];
                for r in 0..iw {
                    vst1q_s32(tmp.as_mut_ptr(), lo[r]);
                    vst1q_s32(tmp.as_mut_ptr().add(4), hi[r]);
                    let base = (i0 + r) * n + j0;
                    c[base..base + GEMM_NR].copy_from_slice(&tmp);
                }
                j0 += GEMM_NR;
            }
            if j0 < n {
                super::packed_a_ragged_tail(panel, b, n, c, i0, iw, j0, k);
            }
        }
    }
}

/// Row-parallel wrapper over [`gemm_i32`] (bit-exact, see
/// [`gemm_i8_i32_par`]).
pub fn gemm_i32_par(
    pool: &ThreadPool,
    a: &[i32],
    b: &[i32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [i32],
) {
    if !worth_parallel(pool, m, k, n) {
        gemm_i32(a, b, m, k, n, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, GEMM_PAR_MIN_ROWS, |row0, block| {
        let rows = block.len() / n;
        gemm_i32(&a[row0 * k..(row0 + rows) * k], b, rows, k, n, block);
    });
}

/// ONNX `MatMulInteger`: quantized A (i8/u8), quantized B (i8/u8),
/// optional a_zero_point / b_zero_point, i32 output. Widens the weight
/// and resolves the activation zero point, then delegates to
/// [`matmul_integer_prewidened`] — the single copy of the GEMM dispatch
/// the compiled plans also execute.
pub fn matmul_integer(
    a: &Tensor,
    b: &Tensor,
    a_zp: Option<&Tensor>,
    b_zp: Option<&Tensor>,
) -> Result<Tensor, OpError> {
    let (_, k) = flat_mk(a.shape());
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    if k != kb {
        return Err(OpError::Semantics(format!("K mismatch {k} vs {kb}")));
    }
    let bw = widen_with_zp(b, b_zp)?;
    let az = match a_zp {
        None => 0,
        Some(z) => {
            if z.numel() != 1 {
                return Err(OpError::Semantics(
                    "per-row/col zero points not supported (paper uses per-tensor)".into(),
                ));
            }
            z.as_quantized_i32()?[0]
        }
    };
    matmul_integer_prewidened(a, &bw, k, n, az)
}

/// `MatMulInteger` against a `[k, n]` weight matrix that was widened to
/// i32 (zero point already subtracted) once at plan time, with the baked
/// activation zero point `a_zp`. Bit-identical to [`matmul_integer`]:
/// the same widened values reach the same GEMM kernels, the widening is
/// just hoisted out of the per-call path.
pub fn matmul_integer_prewidened(
    a: &Tensor,
    bw: &[i32],
    k: usize,
    n: usize,
    a_zp: i32,
) -> Result<Tensor, OpError> {
    // The unplanned path stays strictly scalar: it is the differential
    // oracle the planned (possibly SIMD) path is tested against.
    matmul_integer_prewidened_into(a, bw, None, k, n, a_zp, Isa::Scalar, None)
}

/// The compiled-plan form of [`matmul_integer_prewidened`]: optionally a
/// plan-time [`PackedB`] (preferred when the activations are i8 with a
/// zero a-zero-point — symmetric quantization, every pattern in the
/// paper), the plan-selected `isa` for the packed kernel, and recycled
/// output storage from the scratch planner. All kernels below produce
/// identical bits for the same operands, whichever ISA is stamped.
#[allow(clippy::too_many_arguments)]
pub fn matmul_integer_prewidened_into(
    a: &Tensor,
    bw: &[i32],
    bp: Option<&PackedB>,
    k: usize,
    n: usize,
    a_zp: i32,
    isa: Isa,
    recycled: Option<Tensor>,
) -> Result<Tensor, OpError> {
    let (m, ka) = flat_mk(a.shape());
    if ka != k {
        return Err(OpError::Semantics(format!("K mismatch {ka} vs {k}")));
    }
    let pool = ThreadPool::global();
    let mut c = crate::tensor::recycled_i32_zeroed(recycled, m * n);
    match (a.data(), a_zp == 0, bp) {
        // Hot path: i8 activations, zero zero-point, packed panels,
        // ISA-dispatched microkernel.
        (crate::tensor::TensorData::I8(av), true, Some(bp)) => {
            gemm_i8_packed_par_isa(pool, isa, av, bp, m, &mut c);
        }
        (crate::tensor::TensorData::I8(av), true, None) => {
            gemm_i8_i32_par(pool, av, bw, m, k, n, &mut c);
        }
        _ => {
            let mut aw = a.as_quantized_i32()?;
            if a_zp != 0 {
                for x in &mut aw {
                    *x -= a_zp;
                }
            }
            gemm_i32_par(pool, &aw, bw, m, k, n, &mut c);
        }
    }
    let mut out_shape = Shape::from_slice(&a.shape()[..a.shape().len() - 1]);
    out_shape.push(n);
    Ok(Tensor::new(out_shape, crate::tensor::TensorData::I32(c))?)
}

/// Width-dispatched form of [`matmul_integer_prewidened_into`]: the baked
/// weights may be i8 panels, int4 nibble panels, or bipolar bit columns
/// (see [`bitpack::PackedWeights`]). The narrow paths engage only when
/// the activations qualify (i8, zero zero-point; exactly ±1 for XNOR) —
/// otherwise the call degrades to the widened-i32 kernel over `bw`, so a
/// narrow baking can never change results, only memory traffic.
/// `bits_scratch` parks the XNOR activation bit-pack buffer between runs
/// (an i64 tensor from the scratch planner).
#[allow(clippy::too_many_arguments)]
pub fn matmul_integer_packed_into(
    a: &Tensor,
    bw: &[i32],
    bp: Option<&bitpack::PackedWeights>,
    k: usize,
    n: usize,
    a_zp: i32,
    isa: Isa,
    recycled: Option<Tensor>,
    bits_scratch: &mut Option<Tensor>,
) -> Result<Tensor, OpError> {
    use crate::tensor::TensorData;
    let narrow = match bp {
        Some(bitpack::PackedWeights::I4(_))
        | Some(bitpack::PackedWeights::I3(_))
        | Some(bitpack::PackedWeights::I2(_))
        | Some(bitpack::PackedWeights::Bipolar(_)) => bp,
        _ => None,
    };
    let (m, ka) = flat_mk(a.shape());
    if let (Some(narrow), TensorData::I8(av), true) = (narrow, a.data(), a_zp == 0) {
        if ka != k {
            return Err(OpError::Semantics(format!("K mismatch {ka} vs {k}")));
        }
        let pool = ThreadPool::global();
        match narrow {
            bitpack::PackedWeights::I4(bp4) => {
                let mut c = crate::tensor::recycled_i32_zeroed(recycled, m * n);
                bitpack::gemm_i4_packed_par_isa(pool, isa, av, bp4, m, &mut c);
                let mut out_shape = Shape::from_slice(&a.shape()[..a.shape().len() - 1]);
                out_shape.push(n);
                return Ok(Tensor::new(out_shape, TensorData::I32(c))?);
            }
            bitpack::PackedWeights::I3(bp3) => {
                let mut c = crate::tensor::recycled_i32_zeroed(recycled, m * n);
                bitpack::gemm_i3_packed_par_isa(pool, isa, av, bp3, m, &mut c);
                let mut out_shape = Shape::from_slice(&a.shape()[..a.shape().len() - 1]);
                out_shape.push(n);
                return Ok(Tensor::new(out_shape, TensorData::I32(c))?);
            }
            bitpack::PackedWeights::I2(bp2) => {
                let mut c = crate::tensor::recycled_i32_zeroed(recycled, m * n);
                bitpack::gemm_i2_packed_par_isa(pool, isa, av, bp2, m, &mut c);
                let mut out_shape = Shape::from_slice(&a.shape()[..a.shape().len() - 1]);
                out_shape.push(n);
                return Ok(Tensor::new(out_shape, TensorData::I32(c))?);
            }
            bitpack::PackedWeights::Bipolar(bb) => {
                // Runtime ±1 gate: pack the activations; on any non-±1
                // value fall through to the widened path below.
                let mut bits =
                    crate::tensor::recycled_i64(bits_scratch.take(), m * bitpack::bit_words(k));
                if bitpack::pack_bits_rows(av, m, k, &mut bits) {
                    let mut c = crate::tensor::recycled_i32_zeroed(recycled, m * n);
                    bitpack::gemm_xnor_par_isa(pool, isa, &bits, bb, m, &mut c);
                    *bits_scratch =
                        Some(Tensor::new(vec![bits.len()], TensorData::I64(bits))?);
                    let mut out_shape = Shape::from_slice(&a.shape()[..a.shape().len() - 1]);
                    out_shape.push(n);
                    return Ok(Tensor::new(out_shape, TensorData::I32(c))?);
                }
                bits.clear();
                *bits_scratch = Some(Tensor::new(vec![0], TensorData::I64(bits))?);
                return matmul_integer_prewidened_into(a, bw, None, k, n, a_zp, isa, recycled);
            }
            bitpack::PackedWeights::I8(_) => unreachable!(),
        }
    }
    let bp8 = match bp {
        Some(bitpack::PackedWeights::I8(p)) => Some(p),
        _ => None,
    };
    matmul_integer_prewidened_into(a, bw, bp8, k, n, a_zp, isa, recycled)
}

/// Row-parallel wrapper over [`gemm_f32`]. Bit-exact with the serial
/// kernel: the row split only changes WHICH thread computes an output
/// row; every element still accumulates its k-products in the identical
/// sequential order, so f32 non-associativity never enters.
pub fn gemm_f32_par(
    pool: &ThreadPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
) {
    if !worth_parallel(pool, m, k, n) {
        gemm_f32(a, b, m, k, n, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, GEMM_PAR_MIN_ROWS, |row0, block| {
        let rows = block.len() / n;
        gemm_f32(&a[row0 * k..(row0 + rows) * k], b, rows, k, n, block);
    });
}

/// ONNX float `MatMul` (A rank>=2, B rank-2).
pub fn matmul_f32(a: &Tensor, b: &Tensor) -> Result<Tensor, OpError> {
    matmul_f32_into(a, b, None)
}

/// [`matmul_f32`] with recycled output storage and pool dispatch for
/// large calls (bit-exact — see [`gemm_f32_par`]).
pub fn matmul_f32_into(a: &Tensor, b: &Tensor, recycled: Option<Tensor>) -> Result<Tensor, OpError> {
    let (m, k) = flat_mk(a.shape());
    let n = b.shape()[1];
    let mut c = crate::tensor::recycled_f32_zeroed(recycled, m * n);
    gemm_f32_par(ThreadPool::global(), a.as_f32()?, b.as_f32()?, m, k, n, &mut c);
    let mut out_shape = Shape::from_slice(&a.shape()[..a.shape().len() - 1]);
    out_shape.push(n);
    Ok(Tensor::new(out_shape, crate::tensor::TensorData::F32(c))?)
}

/// ONNX `Gemm`: alpha * op(A) * op(B) + beta * C (C broadcast).
pub fn gemm(
    a: &Tensor,
    b: &Tensor,
    c: Option<&Tensor>,
    alpha: f32,
    beta: f32,
    trans_a: bool,
    trans_b: bool,
) -> Result<Tensor, OpError> {
    let bt;
    let b_op = if trans_b {
        bt = transpose2(b)?;
        &bt
    } else {
        b
    };
    gemm_opb(a, b_op, c, alpha, beta, trans_a, None)
}

/// [`gemm`] against an already-resolved op(B) — the form the compiled
/// plans call with the `transB` transpose baked at plan time (the
/// per-run `transpose2` allocation + O(mn) shuffle this replaces ran on
/// every request). Identical arithmetic: the same op(B) values flow
/// through the same kernel.
pub fn gemm_opb(
    a: &Tensor,
    b_op: &Tensor,
    c: Option<&Tensor>,
    alpha: f32,
    beta: f32,
    trans_a: bool,
    recycled: Option<Tensor>,
) -> Result<Tensor, OpError> {
    let at;
    let a = if trans_a {
        at = transpose2(a)?;
        &at
    } else {
        a
    };
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b_op.shape()[0], b_op.shape()[1]);
    if k != kb {
        return Err(OpError::Semantics(format!("Gemm K mismatch {k} vs {kb}")));
    }
    let mut out = crate::tensor::recycled_f32_zeroed(recycled, m * n);
    gemm_f32_par(ThreadPool::global(), a.as_f32()?, b_op.as_f32()?, m, k, n, &mut out);
    if alpha != 1.0 {
        for v in &mut out {
            *v *= alpha;
        }
    }
    if let Some(c) = c {
        // Fast bias forms (no indexer construction): full-width row bias
        // `[n]` / `[1, n]`, else the generic broadcast indexer.
        let cv = c.as_f32()?;
        if (cv.len() == n && c.shape().last().copied() == Some(n)) || (n == 1 && cv.len() == 1) {
            for row in out.chunks_mut(n) {
                for (v, &bv) in row.iter_mut().zip(cv) {
                    *v += beta * bv;
                }
            }
        } else {
            let ix = crate::tensor::BroadcastIndexer::new(&[m, n], c.shape());
            for (i, v) in out.iter_mut().enumerate() {
                *v += beta * cv[ix.map(i)];
            }
        }
    }
    Ok(Tensor::from_f32(&[m, n], out)?)
}

pub(crate) fn transpose2(t: &Tensor) -> Result<Tensor, OpError> {
    if t.rank() != 2 {
        return Err(OpError::Semantics("transpose expects rank-2".into()));
    }
    let (r, c) = (t.shape()[0], t.shape()[1]);
    match t.dtype() {
        DType::F32 => {
            let src = t.as_f32()?;
            let mut dst = vec![0f32; r * c];
            for i in 0..r {
                for j in 0..c {
                    dst[j * r + i] = src[i * c + j];
                }
            }
            Ok(Tensor::from_f32(&[c, r], dst)?)
        }
        d => Err(OpError::Semantics(format!("transpose: unsupported {d}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_integer_basic() {
        // [[1,2],[3,4]] i8 x [[1,0],[0,1]] i8 = identity.
        let a = Tensor::from_i8(&[2, 2], vec![1, 2, 3, 4]).unwrap();
        let b = Tensor::from_i8(&[2, 2], vec![1, 0, 0, 1]).unwrap();
        let c = matmul_integer(&a, &b, None, None).unwrap();
        assert_eq!(c.as_i32().unwrap(), &[1, 2, 3, 4]);
    }

    #[test]
    fn matmul_integer_saturating_range() {
        // Worst-case int8 accumulation must not overflow i32:
        // 128 * 127 * 127 fits easily; check extreme values.
        let a = Tensor::from_i8(&[1, 4], vec![-128, -128, 127, 127]).unwrap();
        let b = Tensor::from_i8(&[4, 1], vec![127, 127, -128, -128]).unwrap();
        let c = matmul_integer(&a, &b, None, None).unwrap();
        assert_eq!(c.as_i32().unwrap(), &[2 * (-128 * 127) + 2 * (127 * -128)]);
    }

    #[test]
    fn matmul_integer_uint8_with_zero_point() {
        // uint8 activations with zp=128 behave like shifted int8.
        let a = Tensor::from_u8(&[1, 2], vec![130, 126]).unwrap(); // -> +2, -2
        let b = Tensor::from_i8(&[2, 1], vec![3, 1]).unwrap();
        let zp = Tensor::scalar_u8(128);
        let c = matmul_integer(&a, &b, Some(&zp), None).unwrap();
        assert_eq!(c.as_i32().unwrap(), &[2 * 3 + (-2) * 1]);
    }

    #[test]
    fn matmul_integer_batched() {
        let a = Tensor::from_i8(&[2, 1, 2], vec![1, 2, 3, 4]).unwrap();
        let b = Tensor::from_i8(&[2, 1], vec![1, 1]).unwrap();
        let c = matmul_integer(&a, &b, None, None).unwrap();
        assert_eq!(c.shape(), &[2, 1, 1]);
        assert_eq!(c.as_i32().unwrap(), &[3, 7]);
    }

    #[test]
    fn prewidened_matches_matmul_integer() {
        let a8 = Tensor::from_i8(&[3, 4], (0..12).map(|i| (i * 5 - 30) as i8).collect()).unwrap();
        let b = Tensor::from_i8(&[4, 2], vec![1, -2, 3, -4, 5, -6, 7, -8]).unwrap();
        let bw = widen_with_zp(&b, None).unwrap();
        let want = matmul_integer(&a8, &b, None, None).unwrap();
        let got = matmul_integer_prewidened(&a8, &bw, 4, 2, 0).unwrap();
        assert_eq!(want, got);
        // u8 activations with a nonzero zero point take the widened path.
        let au = Tensor::from_u8(&[2, 4], vec![130, 126, 128, 131, 0, 255, 128, 127]).unwrap();
        let zp = Tensor::scalar_u8(128);
        let want = matmul_integer(&au, &b, Some(&zp), None).unwrap();
        let got = matmul_integer_prewidened(&au, &bw, 4, 2, 128).unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn gemm_with_bias_and_transpose() {
        let a = Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_f32(&[2, 2], vec![1., 0., 0., 1.]).unwrap();
        let c = Tensor::from_f32(&[2], vec![10., 20.]).unwrap();
        let y = gemm(&a, &b, Some(&c), 1.0, 1.0, false, false).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[11., 22., 13., 24.]);
        // transB with identity is unchanged
        let y2 = gemm(&a, &b, None, 2.0, 0.0, false, true).unwrap();
        assert_eq!(y2.as_f32().unwrap(), &[2., 4., 6., 8.]);
    }

    #[test]
    fn parallel_gemm_bit_exact_vs_serial() {
        // Big enough to clear GEMM_PAR_MIN_WORK so the pool path engages.
        let (m, k, n) = (64, 32, 32);
        let mut state = 0xDEADBEEFu64;
        let mut rnd8 = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 56) as u8 as i8
        };
        let a: Vec<i8> = (0..m * k).map(|_| rnd8()).collect();
        let bw: Vec<i32> = (0..k * n).map(|_| rnd8() as i32).collect();
        let mut serial = vec![0i32; m * n];
        gemm_i8_i32(&a, &bw, m, k, n, &mut serial);
        for threads in [1usize, 2, 3, 8] {
            let pool = crate::parallel::ThreadPool::new(threads);
            let mut par = vec![0i32; m * n];
            gemm_i8_i32_par(&pool, &a, &bw, m, k, n, &mut par);
            assert_eq!(par, serial, "{threads} threads");
            let aw: Vec<i32> = a.iter().map(|&x| x as i32).collect();
            let mut par32 = vec![0i32; m * n];
            gemm_i32_par(&pool, &aw, &bw, m, k, n, &mut par32);
            assert_eq!(par32, serial, "{threads} threads (i32 kernel)");
        }
    }

    #[test]
    fn packed_b_gemm_matches_widened_kernel() {
        let mut state = 0xBADC0FFEu64;
        let mut rnd8 = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 56) as u8 as i8
        };
        // Shapes crossing every remainder path: m % MR != 0, n < NR,
        // n % NR != 0, k % KC != 0 and k % 4 != 0.
        for (m, k, n) in [(1, 3, 1), (5, 7, 3), (4, 13, 8), (9, 300, 11), (2, 4, 20)] {
            let a: Vec<i8> = (0..m * k).map(|_| rnd8()).collect();
            let bw: Vec<i32> = (0..k * n).map(|_| rnd8() as i32).collect();
            let bp = PackedB::pack(&bw, k, n).expect("i8 range");
            let mut want = vec![0i32; m * n];
            gemm_i8_i32(&a, &bw, m, k, n, &mut want);
            let mut got = vec![0i32; m * n];
            gemm_i8_packed(&a, &bp, m, &mut got);
            assert_eq!(want, got, "packed B ({m},{k},{n})");
            // Packed-A kernel on the transposed role: C = A x B with A
            // packed; use the same operands with A as the packed side.
            let aw: Vec<i32> = a.iter().map(|&x| x as i32).collect();
            let ap = PackedA::pack(&aw, m, k).expect("i8 range");
            let b8: Vec<i8> = bw.iter().map(|&x| x as i8).collect();
            let mut got_a = vec![0i32; m * n];
            gemm_i8_packed_a(&ap, &b8, n, &mut got_a);
            assert_eq!(want, got_a, "packed A ({m},{k},{n})");
        }
    }

    #[test]
    fn pack_refuses_out_of_range_values() {
        // A folded value of -200 (u8 weight minus large zero point) must
        // refuse to pack so the widened kernel keeps serving it.
        assert!(PackedB::pack(&[1, -200], 2, 1).is_none());
        assert!(PackedA::pack(&[300, 0], 1, 2).is_none());
    }

    #[test]
    fn prewidened_into_packed_matches_unpacked() {
        let a8 = Tensor::from_i8(&[5, 6], (0..30).map(|i| (i * 11 % 251) as u8 as i8).collect())
            .unwrap();
        let bw: Vec<i32> = (0..6 * 3).map(|i| ((i * 7 % 31) as i32) - 15).collect();
        let bp = PackedB::pack(&bw, 6, 3).unwrap();
        let plain = matmul_integer_prewidened(&a8, &bw, 6, 3, 0).unwrap();
        let packed =
            matmul_integer_prewidened_into(&a8, &bw, Some(&bp), 6, 3, 0, Isa::Scalar, None)
                .unwrap();
        assert_eq!(plain, packed);
        // Recycled storage changes nothing but the buffer's origin.
        let spare = Tensor::from_i32(&[100], vec![7; 100]).unwrap();
        let recycled = matmul_integer_prewidened_into(
            &a8,
            &bw,
            Some(&bp),
            6,
            3,
            0,
            Isa::Scalar,
            Some(spare),
        )
        .unwrap();
        assert_eq!(plain, recycled);
        // Every ISA this host supports lands on the same bits.
        for isa in Isa::available() {
            let got =
                matmul_integer_prewidened_into(&a8, &bw, Some(&bp), 6, 3, 0, isa, None).unwrap();
            assert_eq!(plain, got, "{isa}");
        }
    }

    #[test]
    fn isa_dispatch_matches_scalar_kernels() {
        // Direct differential check of the dispatchers on shapes hitting
        // the ragged edges (m % MR, n % NR, odd k); tests/packed_gemm.rs
        // extends this with proptests and saturation extremes.
        let mut state = 0x15A_D15Fu64;
        let mut rnd8 = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 56) as u8 as i8
        };
        for (m, k, n) in [(1, 1, 1), (5, 7, 3), (4, 13, 8), (9, 33, 11), (6, 300, 20)] {
            let a: Vec<i8> = (0..m * k).map(|_| rnd8()).collect();
            let bw: Vec<i32> = (0..k * n).map(|_| rnd8() as i32).collect();
            let bp = PackedB::pack(&bw, k, n).expect("i8 range");
            let mut want = vec![0i32; m * n];
            gemm_i8_packed(&a, &bp, m, &mut want);
            let aw: Vec<i32> = a.iter().map(|&x| x as i32).collect();
            let ap = PackedA::pack(&aw, m, k).expect("i8 range");
            let b8: Vec<i8> = bw.iter().map(|&x| x as i8).collect();
            let mut want_a = vec![0i32; m * n];
            gemm_i8_packed_a(&ap, &b8, n, &mut want_a);
            assert_eq!(want, want_a, "scalar twins disagree ({m},{k},{n})");
            for isa in Isa::available() {
                let mut got = vec![0i32; m * n];
                gemm_i8_packed_isa(isa, &a, &bp, m, &mut got);
                assert_eq!(want, got, "{isa} packed B ({m},{k},{n})");
                let mut got_a = vec![0i32; m * n];
                gemm_i8_packed_a_isa(isa, &ap, &b8, n, &mut got_a);
                assert_eq!(want, got_a, "{isa} packed A ({m},{k},{n})");
            }
            // An ISA the host may NOT support must degrade to scalar,
            // not fault — this is the CI matrix's graceful-skip contract.
            for isa in Isa::ALL {
                let mut got = vec![0i32; m * n];
                gemm_i8_packed_isa(isa, &a, &bp, m, &mut got);
                assert_eq!(want, got, "{isa} (normalized) packed B ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn gemm_f32_parallel_bit_exact_vs_serial() {
        let (m, k, n) = (64usize, 32, 32);
        let mut state = 0xF00Du64;
        let mut rnd = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 40) as i32 % 1000) as f32 / 99.0
        };
        let a: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let mut serial = vec![0f32; m * n];
        gemm_f32(&a, &b, m, k, n, &mut serial);
        for threads in [1usize, 2, 3, 8] {
            let pool = crate::parallel::ThreadPool::new(threads);
            let mut par = vec![0f32; m * n];
            gemm_f32_par(&pool, &a, &b, m, k, n, &mut par);
            // Bit-exact: compare raw bits, not approximate equality.
            let sb: Vec<u32> = serial.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = par.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sb, pb, "{threads} threads");
        }
    }

    #[test]
    fn gemm_opb_matches_gemm_with_transb() {
        let a = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Tensor::from_f32(&[4, 3], (0..12).map(|i| i as f32 * 0.5 - 2.0).collect())
            .unwrap();
        let c = Tensor::from_f32(&[4], vec![1., -1., 2., -2.]).unwrap();
        let want = gemm(&a, &b, Some(&c), 1.5, 0.5, false, true).unwrap();
        let bt = transpose2(&b).unwrap();
        let got = gemm_opb(&a, &bt, Some(&c), 1.5, 0.5, false, None).unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn gemm_i32_matches_naive_random() {
        // Cross-check the blocked kernel against a naive triple loop.
        let mut state = 0x12345678u64;
        let mut rnd = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as i32 % 256 - 128) as i32
        };
        let (m, k, n) = (5, 7, 3);
        let a: Vec<i32> = (0..m * k).map(|_| rnd()).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rnd()).collect();
        let mut c = vec![0i32; m * n];
        gemm_i32(&a, &b, m, k, n, &mut c);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
                assert_eq!(c[i * n + j], want);
            }
        }
    }
}
