//! MatMulInteger (ONNX opset 10+), float MatMul, and Gemm.
//!
//! `MatMulInteger` is the heart of every pattern in the paper (Eq. 5:
//! `Y_intermediate = W_q · X_q + B_q`): int8/uint8 operands, i32
//! accumulation, optional zero points (the paper uses symmetric
//! quantization, i.e. zero points of 0, but the operator contract is
//! implemented in full).

use super::OpError;
use crate::parallel::{self, ThreadPool};
use crate::tensor::{DType, Tensor};

/// Below this many multiply-accumulates a GEMM is not worth dispatching to
/// the pool (dispatch + wake-up costs a few microseconds).
pub const GEMM_PAR_MIN_WORK: usize = 32 * 1024;
/// Minimum output rows per parallel chunk.
pub const GEMM_PAR_MIN_ROWS: usize = 2;

/// True when an `m x k x n` GEMM is worth running on the pool.
fn worth_parallel(pool: &ThreadPool, m: usize, k: usize, n: usize) -> bool {
    pool.threads() > 1
        && parallel::allow_pool_dispatch()
        && m >= 2 * GEMM_PAR_MIN_ROWS
        && m.saturating_mul(k).saturating_mul(n) >= GEMM_PAR_MIN_WORK
}

/// Widen an i8/u8 tensor to i32 applying an optional zero point. Also
/// used by the plan compiler to pre-widen initializer weights once.
pub(crate) fn widen_with_zp(t: &Tensor, zp: Option<&Tensor>) -> Result<Vec<i32>, OpError> {
    let zero = match zp {
        None => 0i32,
        Some(z) => {
            if z.numel() != 1 {
                return Err(OpError::Semantics(
                    "per-row/col zero points not supported (paper uses per-tensor)".into(),
                ));
            }
            z.as_quantized_i32()?[0]
        }
    };
    let mut v = t.as_quantized_i32()?;
    if zero != 0 {
        for x in &mut v {
            *x -= zero;
        }
    }
    Ok(v)
}

/// Blocked i32 GEMM kernel over pre-widened operands.
///
/// C[m,n] = sum_k A[m,k] * B[k,n], row-major. The k-inner/j-unrolled loop
/// ordering keeps B accesses sequential so the auto-vectorizer can work
/// with them; this is the interpreter's hot path (see EXPERIMENTS.md
/// §Perf).
pub fn gemm_i32(a: &[i32], b: &[i32], m: usize, k: usize, n: usize, c: &mut [i32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (kk, &a_ik) in a_row.iter().enumerate() {
            if a_ik == 0 {
                continue;
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for (c_v, &b_v) in c_row.iter_mut().zip(b_row) {
                *c_v += a_ik * b_v;
            }
        }
    }
}

/// f32 GEMM with the same loop structure.
pub fn gemm_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    c.fill(0.0);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (kk, &a_ik) in a_row.iter().enumerate() {
            let b_row = &b[kk * n..(kk + 1) * n];
            for (c_v, &b_v) in c_row.iter_mut().zip(b_row) {
                *c_v += a_ik * b_v;
            }
        }
    }
}

/// Flatten leading dims of A's shape into a single M (B is rank-2; shape
/// inference has already validated this form).
fn flat_mk(shape: &[usize]) -> (usize, usize) {
    let k = *shape.last().unwrap();
    let m = shape[..shape.len() - 1].iter().product();
    (m, k)
}

/// i8-activation GEMM with a pre-widened weight matrix: avoids
/// materializing the (batch-sized) widened activation buffer on every
/// call — the interpreter's hottest loop (§Perf).
pub fn gemm_i8_i32(a: &[i8], b_w: &[i32], m: usize, k: usize, n: usize, c: &mut [i32]) {
    c.fill(0);
    let k4 = k & !3;
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        // 4-wide k-unroll: one pass over c_row amortizes four b-rows
        // (4x the arithmetic intensity per store; see §Perf log).
        let mut kk = 0;
        while kk < k4 {
            let a0 = a_row[kk] as i32;
            let a1 = a_row[kk + 1] as i32;
            let a2 = a_row[kk + 2] as i32;
            let a3 = a_row[kk + 3] as i32;
            let b0 = &b_w[kk * n..(kk + 1) * n];
            let b1 = &b_w[(kk + 1) * n..(kk + 2) * n];
            let b2 = &b_w[(kk + 2) * n..(kk + 3) * n];
            let b3 = &b_w[(kk + 3) * n..(kk + 4) * n];
            for j in 0..n {
                c_row[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            kk += 4;
        }
        for kk in k4..k {
            let a_ik = a_row[kk] as i32;
            if a_ik == 0 {
                continue;
            }
            let b_row = &b_w[kk * n..(kk + 1) * n];
            for (c_v, &b_v) in c_row.iter_mut().zip(b_row) {
                *c_v += a_ik * b_v;
            }
        }
    }
}

/// Row-parallel wrapper over [`gemm_i8_i32`]: splits the output rows over
/// the pool. Integer accumulation per output element is identical to the
/// serial kernel, so the result is bit-exact regardless of the split.
pub fn gemm_i8_i32_par(
    pool: &ThreadPool,
    a: &[i8],
    b_w: &[i32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [i32],
) {
    if !worth_parallel(pool, m, k, n) {
        gemm_i8_i32(a, b_w, m, k, n, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, GEMM_PAR_MIN_ROWS, |row0, block| {
        let rows = block.len() / n;
        gemm_i8_i32(&a[row0 * k..(row0 + rows) * k], b_w, rows, k, n, block);
    });
}

/// Row-parallel wrapper over [`gemm_i32`] (bit-exact, see
/// [`gemm_i8_i32_par`]).
pub fn gemm_i32_par(
    pool: &ThreadPool,
    a: &[i32],
    b: &[i32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [i32],
) {
    if !worth_parallel(pool, m, k, n) {
        gemm_i32(a, b, m, k, n, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, GEMM_PAR_MIN_ROWS, |row0, block| {
        let rows = block.len() / n;
        gemm_i32(&a[row0 * k..(row0 + rows) * k], b, rows, k, n, block);
    });
}

/// ONNX `MatMulInteger`: quantized A (i8/u8), quantized B (i8/u8),
/// optional a_zero_point / b_zero_point, i32 output. Widens the weight
/// and resolves the activation zero point, then delegates to
/// [`matmul_integer_prewidened`] — the single copy of the GEMM dispatch
/// the compiled plans also execute.
pub fn matmul_integer(
    a: &Tensor,
    b: &Tensor,
    a_zp: Option<&Tensor>,
    b_zp: Option<&Tensor>,
) -> Result<Tensor, OpError> {
    let (_, k) = flat_mk(a.shape());
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    if k != kb {
        return Err(OpError::Semantics(format!("K mismatch {k} vs {kb}")));
    }
    let bw = widen_with_zp(b, b_zp)?;
    let az = match a_zp {
        None => 0,
        Some(z) => {
            if z.numel() != 1 {
                return Err(OpError::Semantics(
                    "per-row/col zero points not supported (paper uses per-tensor)".into(),
                ));
            }
            z.as_quantized_i32()?[0]
        }
    };
    matmul_integer_prewidened(a, &bw, k, n, az)
}

/// `MatMulInteger` against a `[k, n]` weight matrix that was widened to
/// i32 (zero point already subtracted) once at plan time, with the baked
/// activation zero point `a_zp`. Bit-identical to [`matmul_integer`]:
/// the same widened values reach the same GEMM kernels, the widening is
/// just hoisted out of the per-call path.
pub fn matmul_integer_prewidened(
    a: &Tensor,
    bw: &[i32],
    k: usize,
    n: usize,
    a_zp: i32,
) -> Result<Tensor, OpError> {
    let (m, ka) = flat_mk(a.shape());
    if ka != k {
        return Err(OpError::Semantics(format!("K mismatch {ka} vs {k}")));
    }
    let pool = ThreadPool::global();
    let mut c = vec![0i32; m * n];
    match (a.data(), a_zp == 0) {
        // Hot path: i8 activations, zero a-zero-point (symmetric
        // quantization — every pattern in the paper).
        (crate::tensor::TensorData::I8(av), true) => {
            gemm_i8_i32_par(pool, av, bw, m, k, n, &mut c);
        }
        _ => {
            let mut aw = a.as_quantized_i32()?;
            if a_zp != 0 {
                for x in &mut aw {
                    *x -= a_zp;
                }
            }
            gemm_i32_par(pool, &aw, bw, m, k, n, &mut c);
        }
    }
    let mut out_shape = a.shape()[..a.shape().len() - 1].to_vec();
    out_shape.push(n);
    Ok(Tensor::from_i32(&out_shape, c)?)
}

/// ONNX float `MatMul` (A rank>=2, B rank-2).
pub fn matmul_f32(a: &Tensor, b: &Tensor) -> Result<Tensor, OpError> {
    let (m, k) = flat_mk(a.shape());
    let n = b.shape()[1];
    let mut c = vec![0f32; m * n];
    gemm_f32(a.as_f32()?, b.as_f32()?, m, k, n, &mut c);
    let mut out_shape = a.shape()[..a.shape().len() - 1].to_vec();
    out_shape.push(n);
    Ok(Tensor::from_f32(&out_shape, c)?)
}

/// ONNX `Gemm`: alpha * op(A) * op(B) + beta * C (C broadcast).
pub fn gemm(
    a: &Tensor,
    b: &Tensor,
    c: Option<&Tensor>,
    alpha: f32,
    beta: f32,
    trans_a: bool,
    trans_b: bool,
) -> Result<Tensor, OpError> {
    let at;
    let a = if trans_a {
        at = transpose2(a)?;
        &at
    } else {
        a
    };
    let bt;
    let b = if trans_b {
        bt = transpose2(b)?;
        &bt
    } else {
        b
    };
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    if k != kb {
        return Err(OpError::Semantics(format!("Gemm K mismatch {k} vs {kb}")));
    }
    let mut out = vec![0f32; m * n];
    gemm_f32(a.as_f32()?, b.as_f32()?, m, k, n, &mut out);
    if alpha != 1.0 {
        for v in &mut out {
            *v *= alpha;
        }
    }
    if let Some(c) = c {
        let ix = crate::tensor::BroadcastIndexer::new(&[m, n], c.shape());
        let cv = c.as_f32()?;
        for (i, v) in out.iter_mut().enumerate() {
            *v += beta * cv[ix.map(i)];
        }
    }
    Ok(Tensor::from_f32(&[m, n], out)?)
}

fn transpose2(t: &Tensor) -> Result<Tensor, OpError> {
    if t.rank() != 2 {
        return Err(OpError::Semantics("transpose expects rank-2".into()));
    }
    let (r, c) = (t.shape()[0], t.shape()[1]);
    match t.dtype() {
        DType::F32 => {
            let src = t.as_f32()?;
            let mut dst = vec![0f32; r * c];
            for i in 0..r {
                for j in 0..c {
                    dst[j * r + i] = src[i * c + j];
                }
            }
            Ok(Tensor::from_f32(&[c, r], dst)?)
        }
        d => Err(OpError::Semantics(format!("transpose: unsupported {d}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_integer_basic() {
        // [[1,2],[3,4]] i8 x [[1,0],[0,1]] i8 = identity.
        let a = Tensor::from_i8(&[2, 2], vec![1, 2, 3, 4]).unwrap();
        let b = Tensor::from_i8(&[2, 2], vec![1, 0, 0, 1]).unwrap();
        let c = matmul_integer(&a, &b, None, None).unwrap();
        assert_eq!(c.as_i32().unwrap(), &[1, 2, 3, 4]);
    }

    #[test]
    fn matmul_integer_saturating_range() {
        // Worst-case int8 accumulation must not overflow i32:
        // 128 * 127 * 127 fits easily; check extreme values.
        let a = Tensor::from_i8(&[1, 4], vec![-128, -128, 127, 127]).unwrap();
        let b = Tensor::from_i8(&[4, 1], vec![127, 127, -128, -128]).unwrap();
        let c = matmul_integer(&a, &b, None, None).unwrap();
        assert_eq!(c.as_i32().unwrap(), &[2 * (-128 * 127) + 2 * (127 * -128)]);
    }

    #[test]
    fn matmul_integer_uint8_with_zero_point() {
        // uint8 activations with zp=128 behave like shifted int8.
        let a = Tensor::from_u8(&[1, 2], vec![130, 126]).unwrap(); // -> +2, -2
        let b = Tensor::from_i8(&[2, 1], vec![3, 1]).unwrap();
        let zp = Tensor::scalar_u8(128);
        let c = matmul_integer(&a, &b, Some(&zp), None).unwrap();
        assert_eq!(c.as_i32().unwrap(), &[2 * 3 + (-2) * 1]);
    }

    #[test]
    fn matmul_integer_batched() {
        let a = Tensor::from_i8(&[2, 1, 2], vec![1, 2, 3, 4]).unwrap();
        let b = Tensor::from_i8(&[2, 1], vec![1, 1]).unwrap();
        let c = matmul_integer(&a, &b, None, None).unwrap();
        assert_eq!(c.shape(), &[2, 1, 1]);
        assert_eq!(c.as_i32().unwrap(), &[3, 7]);
    }

    #[test]
    fn prewidened_matches_matmul_integer() {
        let a8 = Tensor::from_i8(&[3, 4], (0..12).map(|i| (i * 5 - 30) as i8).collect()).unwrap();
        let b = Tensor::from_i8(&[4, 2], vec![1, -2, 3, -4, 5, -6, 7, -8]).unwrap();
        let bw = widen_with_zp(&b, None).unwrap();
        let want = matmul_integer(&a8, &b, None, None).unwrap();
        let got = matmul_integer_prewidened(&a8, &bw, 4, 2, 0).unwrap();
        assert_eq!(want, got);
        // u8 activations with a nonzero zero point take the widened path.
        let au = Tensor::from_u8(&[2, 4], vec![130, 126, 128, 131, 0, 255, 128, 127]).unwrap();
        let zp = Tensor::scalar_u8(128);
        let want = matmul_integer(&au, &b, Some(&zp), None).unwrap();
        let got = matmul_integer_prewidened(&au, &bw, 4, 2, 128).unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn gemm_with_bias_and_transpose() {
        let a = Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_f32(&[2, 2], vec![1., 0., 0., 1.]).unwrap();
        let c = Tensor::from_f32(&[2], vec![10., 20.]).unwrap();
        let y = gemm(&a, &b, Some(&c), 1.0, 1.0, false, false).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[11., 22., 13., 24.]);
        // transB with identity is unchanged
        let y2 = gemm(&a, &b, None, 2.0, 0.0, false, true).unwrap();
        assert_eq!(y2.as_f32().unwrap(), &[2., 4., 6., 8.]);
    }

    #[test]
    fn parallel_gemm_bit_exact_vs_serial() {
        // Big enough to clear GEMM_PAR_MIN_WORK so the pool path engages.
        let (m, k, n) = (64, 32, 32);
        let mut state = 0xDEADBEEFu64;
        let mut rnd8 = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 56) as u8 as i8
        };
        let a: Vec<i8> = (0..m * k).map(|_| rnd8()).collect();
        let bw: Vec<i32> = (0..k * n).map(|_| rnd8() as i32).collect();
        let mut serial = vec![0i32; m * n];
        gemm_i8_i32(&a, &bw, m, k, n, &mut serial);
        for threads in [1usize, 2, 3, 8] {
            let pool = crate::parallel::ThreadPool::new(threads);
            let mut par = vec![0i32; m * n];
            gemm_i8_i32_par(&pool, &a, &bw, m, k, n, &mut par);
            assert_eq!(par, serial, "{threads} threads");
            let aw: Vec<i32> = a.iter().map(|&x| x as i32).collect();
            let mut par32 = vec![0i32; m * n];
            gemm_i32_par(&pool, &aw, &bw, m, k, n, &mut par32);
            assert_eq!(par32, serial, "{threads} threads (i32 kernel)");
        }
    }

    #[test]
    fn gemm_i32_matches_naive_random() {
        // Cross-check the blocked kernel against a naive triple loop.
        let mut state = 0x12345678u64;
        let mut rnd = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as i32 % 256 - 128) as i32
        };
        let (m, k, n) = (5, 7, 3);
        let a: Vec<i32> = (0..m * k).map(|_| rnd()).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rnd()).collect();
        let mut c = vec![0i32; m * n];
        gemm_i32(&a, &b, m, k, n, &mut c);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
                assert_eq!(c[i * n + j], want);
            }
        }
    }
}
