//! Pre-bound operator kernels: the plan-time / run-time split.
//!
//! [`Kernel::bind`] does everything that depends only on the *node* —
//! attribute parsing (Cast's `to`, Gemm's alpha/beta/trans flags, conv
//! and pool geometry), operator lookup, and the unsupported-op error —
//! once, at plan time. [`Kernel::bind_in_graph`] additionally bakes
//! parameters that live in *initializers* (a Reshape's spec tensor, a
//! float Conv's bias pre-reshaped to `[1, M, 1, 1]`, MatMulInteger /
//! ConvInteger weights pre-widened to i32 with their zero points folded
//! in), so [`Kernel::run`] touches nothing but the input tensors.
//!
//! Every baked specialization is bit-identical to the generic path: the
//! same values flow through the same arithmetic, just hoisted out of the
//! per-call loop. When a prebinding precondition fails (weight produced
//! at runtime, non-scalar zero point, dtype mismatch, shadowed
//! initializer) the kernel falls back to the generic form so error
//! behavior is unchanged.

use super::OpError;
use super::{conv, elementwise, matmul, pool, qlinear, shape_ops};
use crate::onnx::ir::{Graph, Node};
use crate::onnx::shape::ConvAttrs;
use crate::tensor::{DType, Tensor};

/// One operator, lowered: attributes parsed and static parameters baked.
pub enum Kernel {
    MatMulInteger,
    /// MatMulInteger whose weight (and zero points) were initializers:
    /// `bw` is the weight widened to i32 with its zero point subtracted,
    /// `a_zp` the baked activation zero point.
    MatMulIntegerPrebound {
        bw: Vec<i32>,
        k: usize,
        n: usize,
        a_zp: i32,
    },
    MatMul,
    Gemm {
        alpha: f32,
        beta: f32,
        trans_a: bool,
        trans_b: bool,
    },
    ConvInteger {
        attrs: ConvAttrs,
    },
    /// ConvInteger with an initializer kernel, pre-widened like
    /// [`Kernel::MatMulIntegerPrebound`].
    ConvIntegerPrebound {
        wv: Vec<i32>,
        m: usize,
        c: usize,
        kh: usize,
        kw: usize,
        x_zp: i32,
        attrs: ConvAttrs,
    },
    /// Float Conv; `bias4` is the optional fp32 bias initializer already
    /// reshaped to `[1, M, 1, 1]` at plan time.
    Conv {
        attrs: ConvAttrs,
        bias4: Option<Tensor>,
    },
    Binary {
        op: elementwise::BinOp,
    },
    Cast {
        to: DType,
    },
    QuantizeLinear,
    DequantizeLinear,
    Relu,
    Tanh,
    Sigmoid,
    Softmax {
        axis: i64,
    },
    MaxPool {
        kernel: Vec<i64>,
        attrs: ConvAttrs,
    },
    AveragePool {
        kernel: Vec<i64>,
        attrs: ConvAttrs,
    },
    /// Reshape; `spec` is baked when the shape tensor is an initializer.
    Reshape {
        spec: Option<Vec<i64>>,
    },
    Flatten {
        axis: usize,
    },
    Identity,
}

/// An initializer eligible for plan-time baking: present, and not
/// shadowed by a graph input (a shadowed initializer can be overridden
/// by a feed at run time, so it must stay a dynamic input).
fn bakeable<'g>(g: &'g Graph, name: &str) -> Option<&'g Tensor> {
    if g.input(name).is_some() {
        return None;
    }
    g.initializer(name)
}

/// Baked value of an optional scalar zero-point input: `Some(0)` when the
/// input is omitted, `Some(zp)` when it is a bakeable scalar initializer,
/// `None` (don't prebind) otherwise.
fn baked_zero_point(g: &Graph, node: &Node, index: usize) -> Option<i32> {
    match node.inputs.get(index).map(String::as_str) {
        None | Some("") => Some(0),
        Some(name) => {
            let z = bakeable(g, name)?;
            if z.numel() != 1 {
                return None;
            }
            z.as_quantized_i32().ok().map(|v| v[0])
        }
    }
}

fn prebind_matmul_integer(node: &Node, g: &Graph) -> Option<Kernel> {
    let b = bakeable(g, node.inputs.get(1)?)?;
    if b.rank() != 2 {
        return None;
    }
    let b_zp = match node.inputs.get(3).map(String::as_str) {
        None | Some("") => None,
        Some(name) => Some(bakeable(g, name)?),
    };
    let a_zp = baked_zero_point(g, node, 2)?;
    let bw = matmul::widen_with_zp(b, b_zp).ok()?;
    Some(Kernel::MatMulIntegerPrebound {
        bw,
        k: b.shape()[0],
        n: b.shape()[1],
        a_zp,
    })
}

fn prebind_conv_integer(node: &Node, g: &Graph, attrs: &ConvAttrs) -> Option<Kernel> {
    if attrs.group != 1 {
        return None;
    }
    let w = bakeable(g, node.inputs.get(1)?)?;
    if w.rank() != 4 {
        return None;
    }
    let w_zp = baked_zero_point(g, node, 3)?;
    let x_zp = baked_zero_point(g, node, 2)?;
    let mut wv = w.as_quantized_i32().ok()?;
    if w_zp != 0 {
        for v in &mut wv {
            *v -= w_zp;
        }
    }
    let s = w.shape();
    Some(Kernel::ConvIntegerPrebound {
        wv,
        m: s[0],
        c: s[1],
        kh: s[2],
        kw: s[3],
        x_zp,
        attrs: *attrs,
    })
}

/// Pre-reshape a float Conv's initializer bias to `[1, M, 1, 1]` (M read
/// from the initializer weight) when both are statically known.
fn prebind_conv_bias(node: &Node, g: &Graph) -> Option<Tensor> {
    let name = node.inputs.get(2).map(String::as_str)?;
    if name.is_empty() {
        return None;
    }
    let b = bakeable(g, name)?;
    let w = bakeable(g, node.inputs.get(1)?)?;
    if w.rank() != 4 || b.numel() != w.shape()[0] {
        return None;
    }
    b.clone().reshape(&[1, w.shape()[0], 1, 1]).ok()
}

fn prebind_reshape_spec(node: &Node, g: &Graph) -> Option<Vec<i64>> {
    let spec = bakeable(g, node.inputs.get(1)?)?;
    spec.as_i64().ok().map(|v| v.to_vec())
}

impl Kernel {
    /// Lower a node from its attributes alone (no initializer access) —
    /// the compat path [`super::execute_node`] uses. Fails at *bind* time
    /// on unsupported operators and malformed attributes.
    pub fn bind(node: &Node) -> Result<Kernel, OpError> {
        Kernel::bind_inner(node, None)
    }

    /// Lower a node with plan-time access to the graph's initializers,
    /// additionally baking weight/bias/spec tensors into the kernel.
    pub fn bind_in_graph(node: &Node, g: &Graph) -> Result<Kernel, OpError> {
        Kernel::bind_inner(node, Some(g))
    }

    fn bind_inner(node: &Node, g: Option<&Graph>) -> Result<Kernel, OpError> {
        let kernel = match node.op_type.as_str() {
            "MatMulInteger" => g
                .and_then(|g| prebind_matmul_integer(node, g))
                .unwrap_or(Kernel::MatMulInteger),
            "MatMul" => Kernel::MatMul,
            "Gemm" => Kernel::Gemm {
                alpha: node.attr_float("alpha").unwrap_or(1.0),
                beta: node.attr_float("beta").unwrap_or(1.0),
                trans_a: node.attr_int("transA").unwrap_or(0) != 0,
                trans_b: node.attr_int("transB").unwrap_or(0) != 0,
            },
            "ConvInteger" => {
                let attrs = ConvAttrs::from_node(node);
                g.and_then(|g| prebind_conv_integer(node, g, &attrs))
                    .unwrap_or(Kernel::ConvInteger { attrs })
            }
            "Conv" => Kernel::Conv {
                attrs: ConvAttrs::from_node(node),
                bias4: g.and_then(|g| prebind_conv_bias(node, g)),
            },
            "Add" | "Mul" | "Sub" | "Div" => Kernel::Binary {
                op: elementwise::BinOp::from_op_type(&node.op_type).unwrap(),
            },
            "Cast" => Kernel::Cast {
                to: node
                    .attr_str("to")
                    .and_then(DType::from_onnx_name)
                    .ok_or_else(|| OpError::Semantics("Cast: missing/unknown 'to'".into()))?,
            },
            "QuantizeLinear" => Kernel::QuantizeLinear,
            "DequantizeLinear" => Kernel::DequantizeLinear,
            "Relu" => Kernel::Relu,
            "Tanh" => Kernel::Tanh,
            "Sigmoid" => Kernel::Sigmoid,
            "Softmax" => Kernel::Softmax {
                axis: node.attr_int("axis").unwrap_or(-1),
            },
            "MaxPool" => Kernel::MaxPool {
                kernel: node
                    .attr_ints("kernel_shape")
                    .ok_or_else(|| OpError::Semantics("MaxPool: missing kernel_shape".into()))?
                    .to_vec(),
                attrs: ConvAttrs::from_node(node),
            },
            "AveragePool" => Kernel::AveragePool {
                kernel: node
                    .attr_ints("kernel_shape")
                    .ok_or_else(|| {
                        OpError::Semantics("AveragePool: missing kernel_shape".into())
                    })?
                    .to_vec(),
                attrs: ConvAttrs::from_node(node),
            },
            "Reshape" => Kernel::Reshape {
                spec: g.and_then(|g| prebind_reshape_spec(node, g)),
            },
            "Flatten" => Kernel::Flatten {
                axis: node.attr_int("axis").unwrap_or(1) as usize,
            },
            "Identity" => Kernel::Identity,
            other => return Err(OpError::Unsupported(other.to_string())),
        };
        Ok(kernel)
    }

    /// Operator name reported in errors (the generic op, not the
    /// prebound specialization).
    pub fn op_name(&self) -> &'static str {
        match self {
            Kernel::MatMulInteger | Kernel::MatMulIntegerPrebound { .. } => "MatMulInteger",
            Kernel::MatMul => "MatMul",
            Kernel::Gemm { .. } => "Gemm",
            Kernel::ConvInteger { .. } | Kernel::ConvIntegerPrebound { .. } => "ConvInteger",
            Kernel::Conv { .. } => "Conv",
            Kernel::Binary { op } => match op {
                elementwise::BinOp::Add => "Add",
                elementwise::BinOp::Mul => "Mul",
                elementwise::BinOp::Sub => "Sub",
                elementwise::BinOp::Div => "Div",
            },
            Kernel::Cast { .. } => "Cast",
            Kernel::QuantizeLinear => "QuantizeLinear",
            Kernel::DequantizeLinear => "DequantizeLinear",
            Kernel::Relu => "Relu",
            Kernel::Tanh => "Tanh",
            Kernel::Sigmoid => "Sigmoid",
            Kernel::Softmax { .. } => "Softmax",
            Kernel::MaxPool { .. } => "MaxPool",
            Kernel::AveragePool { .. } => "AveragePool",
            Kernel::Reshape { .. } => "Reshape",
            Kernel::Flatten { .. } => "Flatten",
            Kernel::Identity => "Identity",
        }
    }

    /// Execute the pre-bound kernel on resolved inputs (`None` = omitted
    /// optional input). All admitted operators are single-output.
    /// `MissingInput` errors are minted without a node name; callers that
    /// know it patch it in via [`OpError::with_node`].
    pub fn run(&self, inputs: &[Option<&Tensor>]) -> Result<Tensor, OpError> {
        let req = |i: usize| -> Result<&Tensor, OpError> {
            inputs
                .get(i)
                .copied()
                .flatten()
                .ok_or_else(|| OpError::MissingInput {
                    node: String::new(),
                    op: self.op_name().to_string(),
                    index: i,
                })
        };
        let opt = |i: usize| -> Option<&Tensor> { inputs.get(i).copied().flatten() };

        let out = match self {
            Kernel::MatMulInteger => {
                matmul::matmul_integer(req(0)?, req(1)?, opt(2), opt(3))?
            }
            Kernel::MatMulIntegerPrebound { bw, k, n, a_zp } => {
                matmul::matmul_integer_prewidened(req(0)?, bw, *k, *n, *a_zp)?
            }
            Kernel::MatMul => matmul::matmul_f32(req(0)?, req(1)?)?,
            Kernel::Gemm {
                alpha,
                beta,
                trans_a,
                trans_b,
            } => matmul::gemm(req(0)?, req(1)?, opt(2), *alpha, *beta, *trans_a, *trans_b)?,
            Kernel::ConvInteger { attrs } => {
                conv::conv_integer(req(0)?, req(1)?, opt(2), opt(3), attrs)?
            }
            Kernel::ConvIntegerPrebound {
                wv,
                m,
                c,
                kh,
                kw,
                x_zp,
                attrs,
            } => conv::conv_integer_prewidened(req(0)?, wv, *m, *c, *kh, *kw, *x_zp, attrs)?,
            Kernel::Conv { attrs, bias4 } => {
                let y = conv::conv_f32(req(0)?, req(1)?, attrs)?;
                match (opt(2), bias4) {
                    (None, _) => y,
                    (Some(_), Some(b4)) => {
                        elementwise::binary(elementwise::BinOp::Add, &y, b4)?
                    }
                    (Some(b), None) => {
                        let m = y.shape()[1];
                        let b4 = b.clone().reshape(&[1, m, 1, 1])?;
                        elementwise::binary(elementwise::BinOp::Add, &y, &b4)?
                    }
                }
            }
            Kernel::Binary { op } => elementwise::binary(*op, req(0)?, req(1)?)?,
            Kernel::Cast { to } => req(0)?.cast(*to),
            Kernel::QuantizeLinear => qlinear::quantize_linear(req(0)?, req(1)?, opt(2))?,
            Kernel::DequantizeLinear => qlinear::dequantize_linear(req(0)?, req(1)?, opt(2))?,
            Kernel::Relu => elementwise::relu(req(0)?)?,
            Kernel::Tanh => elementwise::tanh(req(0)?)?,
            Kernel::Sigmoid => elementwise::sigmoid(req(0)?)?,
            Kernel::Softmax { axis } => shape_ops::softmax(req(0)?, *axis)?,
            Kernel::MaxPool { kernel, attrs } => pool::max_pool(req(0)?, kernel, *attrs)?,
            Kernel::AveragePool { kernel, attrs } => {
                pool::average_pool(req(0)?, kernel, *attrs)?
            }
            Kernel::Reshape { spec } => match spec {
                Some(s) => shape_ops::reshape(req(0)?, s)?,
                None => {
                    let s = req(1)?.as_i64()?.to_vec();
                    shape_ops::reshape(req(0)?, &s)?
                }
            },
            Kernel::Flatten { axis } => shape_ops::flatten(req(0)?, *axis)?,
            Kernel::Identity => req(0)?.clone(),
        };
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::ir::Attr;
    use crate::onnx::{batched, GraphBuilder};

    #[test]
    fn bind_parses_attributes_once() {
        let node = Node::new("g", "Gemm", &["a", "b"], &["y"])
            .with_attr("alpha", Attr::Float(2.0))
            .with_attr("transB", Attr::Int(1));
        match Kernel::bind(&node).unwrap() {
            Kernel::Gemm {
                alpha,
                beta,
                trans_a,
                trans_b,
            } => {
                assert_eq!(alpha, 2.0);
                assert_eq!(beta, 1.0);
                assert!(!trans_a);
                assert!(trans_b);
            }
            _ => panic!("wrong kernel"),
        }
    }

    #[test]
    fn bind_rejects_unsupported_at_plan_time() {
        let node = Node::new("n", "LSTM", &["x"], &["y"]);
        assert!(matches!(Kernel::bind(&node), Err(OpError::Unsupported(_))));
    }

    #[test]
    fn bind_rejects_bad_cast_at_plan_time() {
        let node = Node::new("c", "Cast", &["x"], &["y"]);
        assert!(matches!(Kernel::bind(&node), Err(OpError::Semantics(_))));
    }

    #[test]
    fn prebound_matmul_matches_generic() {
        let mut b = GraphBuilder::new("g");
        b.input("x", DType::I8, &batched(&[4]));
        b.init("w", Tensor::from_i8(&[4, 2], vec![1, 2, 3, 4, 5, 6, 7, 8]).unwrap());
        let y = b.node("MatMulInteger", &["x", "w"], &[]);
        b.output(&y, DType::I32, &batched(&[2]));
        let model = b.finish_model();
        let node = &model.graph.nodes[0];
        let kernel = Kernel::bind_in_graph(node, &model.graph).unwrap();
        assert!(matches!(kernel, Kernel::MatMulIntegerPrebound { .. }));
        let x = Tensor::from_i8(&[3, 4], (0..12).map(|i| i as i8 - 6).collect()).unwrap();
        let w = model.graph.initializer("w").unwrap();
        let generic = Kernel::MatMulInteger
            .run(&[Some(&x), Some(w)])
            .unwrap();
        let prebound = kernel.run(&[Some(&x), Some(w)]).unwrap();
        assert_eq!(generic, prebound);
    }

    #[test]
    fn runtime_weight_falls_back_to_generic() {
        // Weight produced by another node: nothing to bake.
        let node = Node::new("mm", "MatMulInteger", &["x", "w_dyn"], &["y"]);
        let g = Graph {
            name: "g".into(),
            ..Default::default()
        };
        let kernel = Kernel::bind_in_graph(&node, &g).unwrap();
        assert!(matches!(kernel, Kernel::MatMulInteger));
    }
}
