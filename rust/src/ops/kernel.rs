//! Pre-bound operator kernels: the plan-time / run-time split.
//!
//! [`Kernel::bind`] does everything that depends only on the *node* —
//! attribute parsing (Cast's `to`, Gemm's alpha/beta/trans flags, conv
//! and pool geometry), operator lookup, and the unsupported-op error —
//! once, at plan time. [`Kernel::bind_in_graph`] additionally bakes
//! parameters that live in *initializers* (a Reshape's spec tensor, a
//! float Conv's bias pre-reshaped to `[1, M, 1, 1]`, MatMulInteger /
//! ConvInteger weights pre-widened to i32 with their zero points folded
//! in), so [`Kernel::run`] touches nothing but the input tensors.
//!
//! Every baked specialization is bit-identical to the generic path: the
//! same values flow through the same arithmetic, just hoisted out of the
//! per-call loop. When a prebinding precondition fails (weight produced
//! at runtime, non-scalar zero point, dtype mismatch, shadowed
//! initializer) the kernel falls back to the generic form so error
//! behavior is unchanged.

use super::isa::Isa;
use super::OpError;
use super::{conv, elementwise, fused, matmul, pool, qlinear, shape_ops};
use crate::onnx::ir::{Graph, Node};
use crate::onnx::shape::ConvAttrs;
use crate::tensor::{DType, Tensor};

/// One operator, lowered: attributes parsed and static parameters baked.
pub enum Kernel {
    MatMulInteger,
    /// MatMulInteger whose weight (and zero points) were initializers:
    /// `bw` is the weight widened to i32 with its zero point subtracted,
    /// `bp` the same values packed into the cache-blocked i8 panel layout
    /// (when they fit i8 — symmetric quantization always does; `bw` stays
    /// as the bit-identical fallback for u8 activations / nonzero
    /// activation zero points), `a_zp` the baked activation zero point,
    /// `isa` the plan-time kernel instruction set (see [`Isa::active`]).
    MatMulIntegerPrebound {
        bw: Vec<i32>,
        bp: Option<matmul::PackedB>,
        k: usize,
        n: usize,
        a_zp: i32,
        isa: Isa,
    },
    MatMul,
    /// Gemm; `bt` is op(B) — the transB transpose already applied — baked
    /// at plan time when B is an initializer, so no per-run `transpose2`.
    Gemm {
        alpha: f32,
        beta: f32,
        trans_a: bool,
        trans_b: bool,
        bt: Option<Tensor>,
    },
    ConvInteger {
        attrs: ConvAttrs,
    },
    /// ConvInteger with an initializer kernel, pre-widened like
    /// [`Kernel::MatMulIntegerPrebound`]; `wp` is the plan-time packed
    /// `[m, c*kh*kw]` row-panel layout feeding the i8 im2col fast path.
    ConvIntegerPrebound {
        wv: Vec<i32>,
        wp: Option<matmul::PackedA>,
        m: usize,
        c: usize,
        kh: usize,
        kw: usize,
        x_zp: i32,
        attrs: ConvAttrs,
        isa: Isa,
    },
    /// Float Conv; `bias4` is the optional fp32 bias initializer already
    /// reshaped to `[1, M, 1, 1]` at plan time.
    Conv {
        attrs: ConvAttrs,
        bias4: Option<Tensor>,
    },
    Binary {
        op: elementwise::BinOp,
    },
    Cast {
        to: DType,
    },
    QuantizeLinear,
    DequantizeLinear,
    Relu,
    /// Clip; the optional scalar min/max arrive as inputs at run time
    /// (opset 13 form). The sub-8-bit codification emits integer-valued
    /// f32 bounds here to declare a narrow logical range — see
    /// `quant::scheme` and the matcher's Clip absorption.
    Clip,
    Tanh,
    Sigmoid,
    Softmax {
        axis: i64,
    },
    MaxPool {
        kernel: Vec<i64>,
        attrs: ConvAttrs,
    },
    AveragePool {
        kernel: Vec<i64>,
        attrs: ConvAttrs,
    },
    /// Reshape; `spec` is baked when the shape tensor is an initializer.
    Reshape {
        spec: Option<Vec<i64>>,
    },
    Flatten {
        axis: usize,
    },
    Identity,
    /// Fused quantized-FC chain (plan-time optimizer only — never
    /// produced by [`Kernel::bind`]; see [`crate::opt`]).
    FusedQFc(fused::FusedQFc),
    /// Fused quantized-conv chain (plan-time optimizer only).
    FusedQConv(fused::FusedQConv),
    /// Folded Dequantize→activation→Quantize chain (plan-time optimizer
    /// only).
    FusedActLut(fused::FusedActLut),
}

/// An initializer eligible for plan-time baking: present, and not
/// shadowed by a graph input (a shadowed initializer can be overridden
/// by a feed at run time, so it must stay a dynamic input).
fn bakeable<'g>(g: &'g Graph, name: &str) -> Option<&'g Tensor> {
    if g.input(name).is_some() {
        return None;
    }
    g.initializer(name)
}

/// Baked value of an optional scalar zero-point input: `Some(0)` when the
/// input is omitted, `Some(zp)` when it is a bakeable scalar initializer,
/// `None` (don't prebind) otherwise.
fn baked_zero_point(g: &Graph, node: &Node, index: usize) -> Option<i32> {
    match node.inputs.get(index).map(String::as_str) {
        None | Some("") => Some(0),
        Some(name) => {
            let z = bakeable(g, name)?;
            if z.numel() != 1 {
                return None;
            }
            z.as_quantized_i32().ok().map(|v| v[0])
        }
    }
}

pub(crate) fn prebind_matmul_integer(node: &Node, g: &Graph) -> Option<Kernel> {
    let b = bakeable(g, node.inputs.get(1)?)?;
    if b.rank() != 2 {
        return None;
    }
    let b_zp = match node.inputs.get(3).map(String::as_str) {
        None | Some("") => None,
        Some(name) => Some(bakeable(g, name)?),
    };
    let a_zp = baked_zero_point(g, node, 2)?;
    let bw = matmul::widen_with_zp(b, b_zp).ok()?;
    let (k, n) = (b.shape()[0], b.shape()[1]);
    let bp = matmul::PackedB::pack(&bw, k, n);
    Some(Kernel::MatMulIntegerPrebound {
        bw,
        bp,
        k,
        n,
        a_zp,
        isa: Isa::active(),
    })
}

pub(crate) fn prebind_conv_integer(node: &Node, g: &Graph, attrs: &ConvAttrs) -> Option<Kernel> {
    if attrs.group != 1 {
        return None;
    }
    let w = bakeable(g, node.inputs.get(1)?)?;
    if w.rank() != 4 {
        return None;
    }
    let w_zp = baked_zero_point(g, node, 3)?;
    let x_zp = baked_zero_point(g, node, 2)?;
    let mut wv = w.as_quantized_i32().ok()?;
    if w_zp != 0 {
        for v in &mut wv {
            *v -= w_zp;
        }
    }
    let s = w.shape();
    let wp = matmul::PackedA::pack(&wv, s[0], s[1] * s[2] * s[3]);
    Some(Kernel::ConvIntegerPrebound {
        wv,
        wp,
        m: s[0],
        c: s[1],
        kh: s[2],
        kw: s[3],
        x_zp,
        attrs: *attrs,
        isa: Isa::active(),
    })
}

/// Pre-transpose a `transB` Gemm's initializer weight at plan time, so
/// [`Kernel::run`] skips the per-call `transpose2` allocation + O(mn)
/// shuffle. Only baked for f32 rank-2 initializers (anything else keeps
/// the generic path and its error behavior).
fn prebind_gemm_bt(node: &Node, g: &Graph, trans_b: bool) -> Option<Tensor> {
    if !trans_b {
        return None;
    }
    let b = bakeable(g, node.inputs.get(1)?)?;
    if b.rank() != 2 || b.dtype() != DType::F32 {
        return None;
    }
    matmul::transpose2(b).ok()
}

/// Pre-reshape a float Conv's initializer bias to `[1, M, 1, 1]` (M read
/// from the initializer weight) when both are statically known.
fn prebind_conv_bias(node: &Node, g: &Graph) -> Option<Tensor> {
    let name = node.inputs.get(2).map(String::as_str)?;
    if name.is_empty() {
        return None;
    }
    let b = bakeable(g, name)?;
    let w = bakeable(g, node.inputs.get(1)?)?;
    if w.rank() != 4 || b.numel() != w.shape()[0] {
        return None;
    }
    b.clone().reshape(&[1, w.shape()[0], 1, 1]).ok()
}

fn prebind_reshape_spec(node: &Node, g: &Graph) -> Option<Vec<i64>> {
    let spec = bakeable(g, node.inputs.get(1)?)?;
    spec.as_i64().ok().map(|v| v.to_vec())
}

impl Kernel {
    /// Lower a node from its attributes alone (no initializer access) —
    /// the compat path [`super::execute_node`] uses. Fails at *bind* time
    /// on unsupported operators and malformed attributes.
    pub fn bind(node: &Node) -> Result<Kernel, OpError> {
        Kernel::bind_inner(node, None)
    }

    /// Lower a node with plan-time access to the graph's initializers,
    /// additionally baking weight/bias/spec tensors into the kernel.
    pub fn bind_in_graph(node: &Node, g: &Graph) -> Result<Kernel, OpError> {
        Kernel::bind_inner(node, Some(g))
    }

    fn bind_inner(node: &Node, g: Option<&Graph>) -> Result<Kernel, OpError> {
        let kernel = match node.op_type.as_str() {
            "MatMulInteger" => g
                .and_then(|g| prebind_matmul_integer(node, g))
                .unwrap_or(Kernel::MatMulInteger),
            "MatMul" => Kernel::MatMul,
            "Gemm" => {
                let trans_b = node.attr_int("transB").unwrap_or(0) != 0;
                Kernel::Gemm {
                    alpha: node.attr_float("alpha").unwrap_or(1.0),
                    beta: node.attr_float("beta").unwrap_or(1.0),
                    trans_a: node.attr_int("transA").unwrap_or(0) != 0,
                    trans_b,
                    bt: g.and_then(|g| prebind_gemm_bt(node, g, trans_b)),
                }
            }
            "ConvInteger" => {
                let attrs = ConvAttrs::from_node(node);
                g.and_then(|g| prebind_conv_integer(node, g, &attrs))
                    .unwrap_or(Kernel::ConvInteger { attrs })
            }
            "Conv" => Kernel::Conv {
                attrs: ConvAttrs::from_node(node),
                bias4: g.and_then(|g| prebind_conv_bias(node, g)),
            },
            "Add" | "Mul" | "Sub" | "Div" => Kernel::Binary {
                op: elementwise::BinOp::from_op_type(&node.op_type).unwrap(),
            },
            "Cast" => Kernel::Cast {
                to: node
                    .attr_str("to")
                    .and_then(DType::from_onnx_name)
                    .ok_or_else(|| OpError::Semantics("Cast: missing/unknown 'to'".into()))?,
            },
            "QuantizeLinear" => Kernel::QuantizeLinear,
            "DequantizeLinear" => Kernel::DequantizeLinear,
            "Relu" => Kernel::Relu,
            "Clip" => Kernel::Clip,
            "Tanh" => Kernel::Tanh,
            "Sigmoid" => Kernel::Sigmoid,
            "Softmax" => Kernel::Softmax {
                axis: node.attr_int("axis").unwrap_or(-1),
            },
            "MaxPool" => Kernel::MaxPool {
                kernel: node
                    .attr_ints("kernel_shape")
                    .ok_or_else(|| OpError::Semantics("MaxPool: missing kernel_shape".into()))?
                    .to_vec(),
                attrs: ConvAttrs::from_node(node),
            },
            "AveragePool" => Kernel::AveragePool {
                kernel: node
                    .attr_ints("kernel_shape")
                    .ok_or_else(|| {
                        OpError::Semantics("AveragePool: missing kernel_shape".into())
                    })?
                    .to_vec(),
                attrs: ConvAttrs::from_node(node),
            },
            "Reshape" => Kernel::Reshape {
                spec: g.and_then(|g| prebind_reshape_spec(node, g)),
            },
            "Flatten" => Kernel::Flatten {
                axis: node.attr_int("axis").unwrap_or(1) as usize,
            },
            "Identity" => Kernel::Identity,
            other => return Err(OpError::Unsupported(other.to_string())),
        };
        Ok(kernel)
    }

    /// Operator name reported in errors (the generic op, not the
    /// prebound specialization).
    pub fn op_name(&self) -> &'static str {
        match self {
            Kernel::MatMulInteger | Kernel::MatMulIntegerPrebound { .. } => "MatMulInteger",
            Kernel::MatMul => "MatMul",
            Kernel::Gemm { .. } => "Gemm",
            Kernel::ConvInteger { .. } | Kernel::ConvIntegerPrebound { .. } => "ConvInteger",
            Kernel::Conv { .. } => "Conv",
            Kernel::Binary { op } => match op {
                elementwise::BinOp::Add => "Add",
                elementwise::BinOp::Mul => "Mul",
                elementwise::BinOp::Sub => "Sub",
                elementwise::BinOp::Div => "Div",
            },
            Kernel::Cast { .. } => "Cast",
            Kernel::QuantizeLinear => "QuantizeLinear",
            Kernel::DequantizeLinear => "DequantizeLinear",
            Kernel::Relu => "Relu",
            Kernel::Clip => "Clip",
            Kernel::Tanh => "Tanh",
            Kernel::Sigmoid => "Sigmoid",
            Kernel::Softmax { .. } => "Softmax",
            Kernel::MaxPool { .. } => "MaxPool",
            Kernel::AveragePool { .. } => "AveragePool",
            Kernel::Reshape { .. } => "Reshape",
            Kernel::Flatten { .. } => "Flatten",
            Kernel::Identity => "Identity",
            Kernel::FusedQFc(_) => "FusedQFc",
            Kernel::FusedQConv(_) => "FusedQConv",
            Kernel::FusedActLut(_) => "FusedActLut",
        }
    }

    /// The plan-time kernel ISA stamped into this kernel, when it routes
    /// through the ISA-dispatched quantized microkernels ([`None`] for
    /// everything else — generic ops never leave the scalar path). This
    /// is the observability hook behind `Session::plan_stats()` and the
    /// bench per-ISA rows.
    pub fn isa(&self) -> Option<Isa> {
        match self {
            Kernel::MatMulIntegerPrebound { isa, .. }
            | Kernel::ConvIntegerPrebound { isa, .. } => Some(*isa),
            Kernel::FusedQFc(f) => Some(f.isa),
            Kernel::FusedQConv(f) => Some(f.isa),
            _ => None,
        }
    }

    /// The packed-GEMM problem this kernel will run in steady state, for
    /// the plan-time tuner ([`crate::tune::tuner`]): the baked widened
    /// weights plus their shape. `None` for kernels with no packed GEMM
    /// (either not quantized-prebound, or the weights refused to pack).
    pub fn tune_problem(&self) -> Option<crate::tune::GemmProblem<'_>> {
        use crate::tune::{GemmProblem, ProblemKind};
        match self {
            Kernel::MatMulIntegerPrebound { bw, bp, k, n, .. } if bp.is_some() => {
                Some(GemmProblem {
                    w: bw,
                    k: *k,
                    out: *n,
                    kind: ProblemKind::PackedBGemm,
                    bits: 8,
                })
            }
            Kernel::FusedQFc(f) => f.bp.as_ref().map(|p| GemmProblem {
                w: &f.bw,
                k: f.k,
                out: f.n,
                kind: ProblemKind::PackedBGemm,
                bits: p.bits(),
            }),
            Kernel::ConvIntegerPrebound {
                wv, wp, m, c, kh, kw, ..
            } if wp.is_some() => Some(GemmProblem {
                w: wv,
                k: c * kh * kw,
                out: *m,
                kind: ProblemKind::PackedAGemm,
                bits: 8,
            }),
            Kernel::FusedQConv(f) => f.wp.as_ref().map(|p| GemmProblem {
                w: &f.wv,
                k: f.c * f.kh * f.kw,
                out: f.m,
                kind: ProblemKind::PackedAGemm,
                bits: p.bits(),
            }),
            _ => None,
        }
    }

    /// Repack this kernel's baked weight panels with a tuned tile config
    /// (no-op for kernels without a packed GEMM). Bit-exactness is free:
    /// the panels hold the same widened values in a different layout, and
    /// every tile config accumulates in the same ascending-k order.
    pub fn retune(&mut self, cfg: crate::tune::GemmConfig) {
        use super::bitpack::{
            PackedA2, PackedA3, PackedA4, PackedB2, PackedB3, PackedB4, PackedConvWeights,
            PackedWeights,
        };
        use crate::ops::matmul::{PackedA, PackedB};
        match self {
            Kernel::MatMulIntegerPrebound { bw, bp, k, n, .. } if bp.is_some() => {
                *bp = PackedB::pack_with(bw, *k, *n, cfg);
            }
            Kernel::FusedQFc(f) => match &f.bp {
                Some(PackedWeights::I8(_)) => {
                    f.bp = PackedB::pack_with(&f.bw, f.k, f.n, cfg).map(PackedWeights::I8);
                }
                Some(PackedWeights::I4(_)) => {
                    // Keep the old panels if the tuned tile width can't
                    // byte-align nibbles (odd nr).
                    if let Some(p) = PackedB4::pack_with(&f.bw, f.k, f.n, cfg) {
                        f.bp = Some(PackedWeights::I4(p));
                    }
                }
                Some(PackedWeights::I3(_)) => {
                    // Tribble rows need nr*3 to fill whole bytes.
                    if let Some(p) = PackedB3::pack_with(&f.bw, f.k, f.n, cfg) {
                        f.bp = Some(PackedWeights::I3(p));
                    }
                }
                Some(PackedWeights::I2(_)) => {
                    if let Some(p) = PackedB2::pack_with(&f.bw, f.k, f.n, cfg) {
                        f.bp = Some(PackedWeights::I2(p));
                    }
                }
                // Bit columns have no tile parameters.
                Some(PackedWeights::Bipolar(_)) | None => {}
            },
            Kernel::ConvIntegerPrebound {
                wv, wp, m, c, kh, kw, ..
            } if wp.is_some() => {
                *wp = PackedA::pack_with(wv, *m, *c * *kh * *kw, cfg);
            }
            Kernel::FusedQConv(f) => match &f.wp {
                Some(PackedConvWeights::I8(_)) => {
                    f.wp = PackedA::pack_with(&f.wv, f.m, f.c * f.kh * f.kw, cfg)
                        .map(PackedConvWeights::I8);
                }
                Some(PackedConvWeights::I4(_)) => {
                    if let Some(p) = PackedA4::pack_with(&f.wv, f.m, f.c * f.kh * f.kw, cfg) {
                        f.wp = Some(PackedConvWeights::I4(p));
                    }
                }
                Some(PackedConvWeights::I3(_)) => {
                    if let Some(p) = PackedA3::pack_with(&f.wv, f.m, f.c * f.kh * f.kw, cfg) {
                        f.wp = Some(PackedConvWeights::I3(p));
                    }
                }
                Some(PackedConvWeights::I2(_)) => {
                    if let Some(p) = PackedA2::pack_with(&f.wv, f.m, f.c * f.kh * f.kw, cfg) {
                        f.wp = Some(PackedConvWeights::I2(p));
                    }
                }
                Some(PackedConvWeights::Bipolar(_)) | None => {}
            },
            _ => {}
        }
    }

    /// Bytes of baked quantized-weight storage this kernel holds (the
    /// widened i32 copy, the packed panels at whatever width the
    /// optimizer selected, the folded bias) — the plan-memory number
    /// behind the lazy-twin accounting and the per-width weight-memory
    /// figures. Float-path bakes (Gemm `bt`, Conv `bias4`) are excluded:
    /// they are not duplicated between fused and unfused twins in the
    /// paper patterns.
    pub fn baked_bytes(&self) -> usize {
        let opt_panel_b = |bp: &Option<matmul::PackedB>| bp.as_ref().map_or(0, |p| p.bytes());
        let opt_panel_a = |wp: &Option<matmul::PackedA>| wp.as_ref().map_or(0, |p| p.bytes());
        let opt_bias = |b: &Option<Vec<i32>>| b.as_ref().map_or(0, |v| v.len() * 4);
        match self {
            Kernel::MatMulIntegerPrebound { bw, bp, .. } => bw.len() * 4 + opt_panel_b(bp),
            Kernel::ConvIntegerPrebound { wv, wp, .. } => wv.len() * 4 + opt_panel_a(wp),
            Kernel::FusedQFc(f) => {
                f.bw.len() * 4
                    + f.bp.as_ref().map_or(0, |p| p.bytes())
                    + opt_bias(&f.bias)
            }
            Kernel::FusedQConv(f) => {
                f.wv.len() * 4
                    + f.wp.as_ref().map_or(0, |p| p.bytes())
                    + opt_bias(&f.bias)
            }
            _ => 0,
        }
    }

    /// Logical weight width of the packed storage this kernel will run
    /// with (`"int8"` / `"int4"` / `"int3"` / `"int2"` / `"bipolar"`),
    /// `None` when it holds no packed quantized weights. Observability twin of [`Kernel::isa`]
    /// for the width axis (plan stats, CI dispatch filters).
    pub fn weight_width(&self) -> Option<&'static str> {
        match self {
            Kernel::MatMulIntegerPrebound { bp: Some(_), .. }
            | Kernel::ConvIntegerPrebound { wp: Some(_), .. } => Some("int8"),
            Kernel::FusedQFc(f) => f.bp.as_ref().map(|p| p.width_name()),
            Kernel::FusedQConv(f) => f.wp.as_ref().map(|p| p.width_name()),
            _ => None,
        }
    }

    /// Execute the pre-bound kernel on resolved inputs (`None` = omitted
    /// optional input). All admitted operators are single-output.
    /// `MissingInput` errors are minted without a node name; callers that
    /// know it patch it in via [`OpError::with_node`].
    pub fn run(&self, inputs: &[Option<&Tensor>]) -> Result<Tensor, OpError> {
        self.run_with(inputs, None, &mut [None, None, None])
    }

    /// [`Kernel::run`] with the scratch planner's buffers: `recycled` is
    /// the retired output tensor of a previous run at this plan step
    /// (its storage is reused when dtype and capacity fit), `scratch`
    /// three per-step slots for kernel-internal intermediates (the conv
    /// im2col column buffer, the float conv's pre-bias result, the fused
    /// FC's packed-activation staging container). Results are
    /// bit-identical to [`Kernel::run`] for every kernel — only the
    /// origin of the output buffer differs.
    pub fn run_with(
        &self,
        inputs: &[Option<&Tensor>],
        recycled: Option<Tensor>,
        scratch: &mut [Option<Tensor>; 3],
    ) -> Result<Tensor, OpError> {
        let req = |i: usize| -> Result<&Tensor, OpError> {
            inputs
                .get(i)
                .copied()
                .flatten()
                .ok_or_else(|| OpError::MissingInput {
                    node: String::new(),
                    op: self.op_name().to_string(),
                    index: i,
                })
        };
        let opt = |i: usize| -> Option<&Tensor> { inputs.get(i).copied().flatten() };

        let out = match self {
            Kernel::MatMulInteger => {
                matmul::matmul_integer(req(0)?, req(1)?, opt(2), opt(3))?
            }
            Kernel::MatMulIntegerPrebound {
                bw,
                bp,
                k,
                n,
                a_zp,
                isa,
            } => matmul::matmul_integer_prewidened_into(
                req(0)?,
                bw,
                bp.as_ref(),
                *k,
                *n,
                *a_zp,
                *isa,
                recycled,
            )?,
            Kernel::MatMul => matmul::matmul_f32_into(req(0)?, req(1)?, recycled)?,
            Kernel::Gemm {
                alpha,
                beta,
                trans_a,
                trans_b,
                bt,
            } => match bt {
                // transB baked at plan time: op(B) is ready, no per-run
                // transpose (the provided weight input is the same
                // initializer the transpose was taken from).
                Some(bt) => {
                    matmul::gemm_opb(req(0)?, bt, opt(2), *alpha, *beta, *trans_a, recycled)?
                }
                None => {
                    matmul::gemm(req(0)?, req(1)?, opt(2), *alpha, *beta, *trans_a, *trans_b)?
                }
            },
            Kernel::ConvInteger { attrs } => {
                conv::conv_integer(req(0)?, req(1)?, opt(2), opt(3), attrs)?
            }
            Kernel::ConvIntegerPrebound {
                wv,
                wp,
                m,
                c,
                kh,
                kw,
                x_zp,
                attrs,
                isa,
            } => conv::conv_integer_prewidened_into(
                req(0)?,
                wv,
                wp.as_ref(),
                *m,
                *c,
                *kh,
                *kw,
                *x_zp,
                attrs,
                *isa,
                recycled,
                &mut scratch[0],
            )?,
            Kernel::Conv { attrs, bias4 } => {
                let [col_scratch, y_scratch, _] = scratch;
                match (opt(2), bias4) {
                    (None, _) => {
                        conv::conv_f32_into(req(0)?, req(1)?, attrs, recycled, col_scratch)?
                    }
                    (Some(_), Some(b4)) => {
                        let y = conv::conv_f32_into(
                            req(0)?,
                            req(1)?,
                            attrs,
                            y_scratch.take(),
                            col_scratch,
                        )?;
                        let out =
                            elementwise::binary_into(elementwise::BinOp::Add, &y, b4, recycled)?;
                        *y_scratch = Some(y);
                        out
                    }
                    (Some(b), None) => {
                        let y = conv::conv_f32_into(
                            req(0)?,
                            req(1)?,
                            attrs,
                            y_scratch.take(),
                            col_scratch,
                        )?;
                        let m = y.shape()[1];
                        let b4 = b.clone().reshape(&[1, m, 1, 1])?;
                        let out =
                            elementwise::binary_into(elementwise::BinOp::Add, &y, &b4, recycled)?;
                        *y_scratch = Some(y);
                        out
                    }
                }
            }
            Kernel::Binary { op } => {
                elementwise::binary_into(*op, req(0)?, req(1)?, recycled)?
            }
            Kernel::Cast { to } => req(0)?.cast_recycled(*to, recycled),
            Kernel::QuantizeLinear => {
                qlinear::quantize_linear_into(req(0)?, req(1)?, opt(2), recycled)?
            }
            Kernel::DequantizeLinear => {
                qlinear::dequantize_linear_into(req(0)?, req(1)?, opt(2), recycled)?
            }
            Kernel::Relu => elementwise::relu_into(req(0)?, recycled)?,
            Kernel::Clip => elementwise::clip_into(req(0)?, opt(1), opt(2), recycled)?,
            Kernel::Tanh => elementwise::tanh_into(req(0)?, recycled)?,
            Kernel::Sigmoid => elementwise::sigmoid_into(req(0)?, recycled)?,
            Kernel::Softmax { axis } => shape_ops::softmax_into(req(0)?, *axis, recycled)?,
            Kernel::MaxPool { kernel, attrs } => {
                pool::max_pool_into(req(0)?, kernel, *attrs, recycled)?
            }
            Kernel::AveragePool { kernel, attrs } => {
                pool::average_pool_into(req(0)?, kernel, *attrs, recycled)?
            }
            Kernel::Reshape { spec } => match spec {
                Some(s) => shape_ops::reshape_into(req(0)?, s, recycled)?,
                None => {
                    let s = req(1)?.as_i64()?.to_vec();
                    shape_ops::reshape_into(req(0)?, &s, recycled)?
                }
            },
            Kernel::Flatten { axis } => shape_ops::flatten_into(req(0)?, *axis, recycled)?,
            Kernel::Identity => req(0)?.clone_recycled(recycled),
            Kernel::FusedQFc(f) => f.run(req(0)?, recycled, scratch)?,
            Kernel::FusedQConv(f) => f.run(req(0)?, recycled, scratch)?,
            Kernel::FusedActLut(f) => f.run(req(0)?, recycled)?,
        };
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::ir::Attr;
    use crate::onnx::{batched, GraphBuilder};

    #[test]
    fn bind_parses_attributes_once() {
        let node = Node::new("g", "Gemm", &["a", "b"], &["y"])
            .with_attr("alpha", Attr::Float(2.0))
            .with_attr("transB", Attr::Int(1));
        match Kernel::bind(&node).unwrap() {
            Kernel::Gemm {
                alpha,
                beta,
                trans_a,
                trans_b,
                bt,
            } => {
                assert_eq!(alpha, 2.0);
                assert_eq!(beta, 1.0);
                assert!(!trans_a);
                assert!(trans_b);
                assert!(bt.is_none(), "no graph, nothing to bake");
            }
            _ => panic!("wrong kernel"),
        }
    }

    #[test]
    fn bind_rejects_unsupported_at_plan_time() {
        let node = Node::new("n", "LSTM", &["x"], &["y"]);
        assert!(matches!(Kernel::bind(&node), Err(OpError::Unsupported(_))));
    }

    #[test]
    fn bind_rejects_bad_cast_at_plan_time() {
        let node = Node::new("c", "Cast", &["x"], &["y"]);
        assert!(matches!(Kernel::bind(&node), Err(OpError::Semantics(_))));
    }

    #[test]
    fn prebound_matmul_matches_generic() {
        let mut b = GraphBuilder::new("g");
        b.input("x", DType::I8, &batched(&[4]));
        b.init("w", Tensor::from_i8(&[4, 2], vec![1, 2, 3, 4, 5, 6, 7, 8]).unwrap());
        let y = b.node("MatMulInteger", &["x", "w"], &[]);
        b.output(&y, DType::I32, &batched(&[2]));
        let model = b.finish_model();
        let node = &model.graph.nodes[0];
        let kernel = Kernel::bind_in_graph(node, &model.graph).unwrap();
        assert!(matches!(kernel, Kernel::MatMulIntegerPrebound { .. }));
        let x = Tensor::from_i8(&[3, 4], (0..12).map(|i| i as i8 - 6).collect()).unwrap();
        let w = model.graph.initializer("w").unwrap();
        let generic = Kernel::MatMulInteger
            .run(&[Some(&x), Some(w)])
            .unwrap();
        let prebound = kernel.run(&[Some(&x), Some(w)]).unwrap();
        assert_eq!(generic, prebound);
    }

    #[test]
    fn prebound_matmul_packs_weight_panels() {
        let mut b = GraphBuilder::new("g");
        b.input("x", DType::I8, &batched(&[4]));
        b.init("w", Tensor::from_i8(&[4, 2], vec![1, 2, 3, 4, 5, 6, 7, 8]).unwrap());
        let y = b.node("MatMulInteger", &["x", "w"], &[]);
        b.output(&y, DType::I32, &batched(&[2]));
        let model = b.finish_model();
        let kernel = Kernel::bind_in_graph(&model.graph.nodes[0], &model.graph).unwrap();
        match &kernel {
            Kernel::MatMulIntegerPrebound { bp, .. } => {
                assert!(bp.is_some(), "i8 weights must pack")
            }
            _ => panic!("wrong kernel"),
        }
        // Packed and recycled execution stays bit-identical to generic.
        let x = Tensor::from_i8(&[5, 4], (0..20).map(|i| (i * 3 % 256) as u8 as i8).collect())
            .unwrap();
        let w = model.graph.initializer("w").unwrap();
        let generic = Kernel::MatMulInteger.run(&[Some(&x), Some(w)]).unwrap();
        let packed = kernel.run(&[Some(&x), Some(w)]).unwrap();
        assert_eq!(generic, packed);
        let spare = Some(Tensor::from_i32(&[64], vec![5; 64]).unwrap());
        let recycled = kernel
            .run_with(&[Some(&x), Some(w)], spare, &mut [None, None, None])
            .unwrap();
        assert_eq!(generic, recycled);
    }

    #[test]
    fn gemm_transb_baked_at_plan_time() {
        let mut b = GraphBuilder::new("g");
        b.input("x", DType::F32, &batched(&[3]));
        b.init(
            "w",
            Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap(),
        );
        let y = b.node("Gemm", &["x", "w"], &[("transB", Attr::Int(1))]);
        b.output(&y, DType::F32, &batched(&[2]));
        let model = b.finish_model();
        let node = &model.graph.nodes[0];
        let baked = Kernel::bind_in_graph(node, &model.graph).unwrap();
        match &baked {
            Kernel::Gemm { bt, .. } => assert!(bt.is_some(), "transB weight must bake"),
            _ => panic!("wrong kernel"),
        }
        let unbaked = Kernel::bind(node).unwrap();
        let x = Tensor::from_f32(&[4, 3], (0..12).map(|i| i as f32 * 0.5 - 3.0).collect())
            .unwrap();
        let w = model.graph.initializer("w").unwrap();
        let want = unbaked.run(&[Some(&x), Some(w)]).unwrap();
        let got = baked.run(&[Some(&x), Some(w)]).unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn runtime_weight_falls_back_to_generic() {
        // Weight produced by another node: nothing to bake.
        let node = Node::new("mm", "MatMulInteger", &["x", "w_dyn"], &["y"]);
        let g = Graph {
            name: "g".into(),
            ..Default::default()
        };
        let kernel = Kernel::bind_in_graph(&node, &g).unwrap();
        assert!(matches!(kernel, Kernel::MatMulInteger));
    }
}
