//! ConvInteger (ONNX opset 10+) and float Conv, NCHW, via im2col + GEMM.
//!
//! The paper's Figure 3 pattern uses `ConvInteger` with int8 kernel
//! coefficients and an i32 result; zero points are optional (symmetric
//! quantization uses none). im2col turns the convolution into the same
//! blocked GEMM the fully-connected path uses, so one hot loop serves
//! both patterns.

use super::bitpack;
use super::isa::Isa;
use super::matmul::{gemm_f32, gemm_i32, gemm_i8_packed_a_isa, PackedA};
use super::OpError;
use crate::onnx::shape::ConvAttrs;
use crate::parallel::{self, ThreadPool};
use crate::tensor::{
    recycled_f32_zeroed, recycled_i32_zeroed, recycled_i8_zeroed, Tensor, TensorData,
};

/// Minimum multiply-accumulates per inference before the conv batch loop is
/// dispatched to the pool. Alias of the unified [`crate::tune::Thresholds`]
/// policy.
pub const CONV_PAR_MIN_WORK: usize = crate::tune::Thresholds::DEFAULT.conv_par_min_work;

/// im2col over an i32-widened NCHW input. Output layout is
/// `[C*kH*kW, oH*oW]` per batch element (column-major patches) so the
/// weight matrix `[M, C*kH*kW]` multiplies it directly.
#[allow(clippy::too_many_arguments)]
fn im2col<T: Copy + Default>(
    src: &[T],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    attrs: &ConvAttrs,
    oh: usize,
    ow: usize,
    dst: &mut [T],
) {
    let [stride_h, stride_w] = attrs.strides;
    let [pad_t, pad_l, _, _] = attrs.pads;
    let [dil_h, dil_w] = attrs.dilations;
    let patch = oh * ow;
    for ci in 0..c {
        for ki in 0..kh {
            for kj in 0..kw {
                let row = (ci * kh * kw + ki * kw + kj) * patch;
                for oy in 0..oh {
                    let iy = (oy * stride_h + ki * dil_h) as isize - pad_t as isize;
                    let base = row + oy * ow;
                    if iy < 0 || iy as usize >= h {
                        for ox in 0..ow {
                            dst[base + ox] = T::default();
                        }
                        continue;
                    }
                    let src_row = (ci * h + iy as usize) * w;
                    for ox in 0..ow {
                        let ix = (ox * stride_w + kj * dil_w) as isize - pad_l as isize;
                        dst[base + ox] = if ix < 0 || ix as usize >= w {
                            T::default()
                        } else {
                            src[src_row + ix as usize]
                        };
                    }
                }
            }
        }
    }
}

/// i8 im2col through a plan-selected ISA. For the common `stride_w == 1,
/// dil_w == 1` geometry each output row decomposes into left zero-pad +
/// one contiguous source run + right zero-pad, and the run is copied with
/// ISA-wide loads; any other geometry (and `Isa::Scalar`) falls back to
/// the generic per-element loop above. The decomposition moves exactly
/// the elements the generic loop moves (`ix = ox + kj*dil_w - pad_l`,
/// in-bounds ox solved in closed form), so the column buffer is
/// bit-identical either way — the differential conv tests prove it per
/// available ISA.
#[allow(clippy::too_many_arguments)]
fn im2col_i8(
    isa: Isa,
    src: &[i8],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    attrs: &ConvAttrs,
    oh: usize,
    ow: usize,
    dst: &mut [i8],
) {
    let [stride_h, stride_w] = attrs.strides;
    let [pad_t, pad_l, _, _] = attrs.pads;
    let [dil_h, dil_w] = attrs.dilations;
    if matches!(isa, Isa::Scalar) || stride_w != 1 || dil_w != 1 {
        im2col(src, c, h, w, kh, kw, attrs, oh, ow, dst);
        return;
    }
    let patch = oh * ow;
    for ci in 0..c {
        for ki in 0..kh {
            for kj in 0..kw {
                let row = (ci * kh * kw + ki * kw + kj) * patch;
                // With stride_w == dil_w == 1: ix = ox + off.
                let off = kj as isize - pad_l as isize;
                let lo = (-off).clamp(0, ow as isize) as usize;
                let hi = (w as isize - off).clamp(lo as isize, ow as isize) as usize;
                for oy in 0..oh {
                    let iy = (oy * stride_h + ki * dil_h) as isize - pad_t as isize;
                    let base = row + oy * ow;
                    if iy < 0 || iy as usize >= h {
                        dst[base..base + ow].fill(0);
                        continue;
                    }
                    dst[base..base + lo].fill(0);
                    dst[base + hi..base + ow].fill(0);
                    if hi > lo {
                        let src_row = (ci * h + iy as usize) * w;
                        let s0 = (lo as isize + off) as usize;
                        copy_i8(
                            isa,
                            &src[src_row + s0..src_row + s0 + (hi - lo)],
                            &mut dst[base + lo..base + hi],
                        );
                    }
                }
            }
        }
    }
}

/// Equal-length i8 copy through ISA-wide unaligned loads (the im2col
/// inner move). Unsupported values degrade to `copy_from_slice`.
fn copy_i8(isa: Isa, src: &[i8], dst: &mut [i8]) {
    debug_assert_eq!(src.len(), dst.len());
    match isa.normalized() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: normalized() verified the feature bit on this host.
        Isa::Avx2 => unsafe { x86::copy_i8_avx2(src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Isa::Sse41 => unsafe { x86::copy_i8_sse41(src, dst) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: normalized() admits Neon only on aarch64 hosts.
        Isa::Neon => unsafe { arm::copy_i8_neon(src, dst) },
        _ => dst.copy_from_slice(src),
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    #[allow(clippy::wildcard_imports)]
    use std::arch::x86_64::*;

    /// Safety: caller verified AVX2 and `src.len() == dst.len()`; every
    /// 32-byte load/store stays inside the main-loop bound `i + 32 <= len`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn copy_i8_avx2(src: &[i8], dst: &mut [i8]) {
        let len = src.len();
        let (sp, dp) = (src.as_ptr(), dst.as_mut_ptr());
        let mut i = 0;
        while i + 32 <= len {
            let v = _mm256_loadu_si256(sp.add(i) as *const __m256i);
            _mm256_storeu_si256(dp.add(i) as *mut __m256i, v);
            i += 32;
        }
        if i < len {
            dst[i..].copy_from_slice(&src[i..]);
        }
    }

    /// Safety: caller verified SSE4.1; bounds as in [`copy_i8_avx2`]
    /// with 16-byte chunks.
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn copy_i8_sse41(src: &[i8], dst: &mut [i8]) {
        let len = src.len();
        let (sp, dp) = (src.as_ptr(), dst.as_mut_ptr());
        let mut i = 0;
        while i + 16 <= len {
            let v = _mm_loadu_si128(sp.add(i) as *const __m128i);
            _mm_storeu_si128(dp.add(i) as *mut __m128i, v);
            i += 16;
        }
        if i < len {
            dst[i..].copy_from_slice(&src[i..]);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    #[allow(clippy::wildcard_imports)]
    use std::arch::aarch64::*;

    /// Safety: NEON is baseline on aarch64; bounds as in the x86 twins
    /// with 16-byte chunks.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn copy_i8_neon(src: &[i8], dst: &mut [i8]) {
        let len = src.len();
        let (sp, dp) = (src.as_ptr(), dst.as_mut_ptr());
        let mut i = 0;
        while i + 16 <= len {
            vst1q_s8(dp.add(i), vld1q_s8(sp.add(i)));
            i += 16;
        }
        if i < len {
            dst[i..].copy_from_slice(&src[i..]);
        }
    }
}

fn out_spatial(
    input: usize,
    kernel: usize,
    pad_b: usize,
    pad_e: usize,
    stride: usize,
    dil: usize,
) -> usize {
    (input + pad_b + pad_e - (dil * (kernel - 1) + 1)) / stride + 1
}

/// ONNX `ConvInteger` (group=1): x (i8/u8 NCHW), w (i8/u8 MCkk),
/// optional per-tensor zero points, i32 output.
pub fn conv_integer(
    x: &Tensor,
    w: &Tensor,
    x_zp: Option<&Tensor>,
    w_zp: Option<&Tensor>,
    attrs: &ConvAttrs,
) -> Result<Tensor, OpError> {
    if attrs.group != 1 {
        return Err(OpError::Semantics("group conv not supported".into()));
    }
    let (_, c, _, _) = nchw(x)?;
    let (m, wc, kh, kw) = nchw(w)?;
    if wc != c {
        return Err(OpError::Semantics(format!("channel mismatch {wc} vs {c}")));
    }
    let zp_of = |zp: Option<&Tensor>| -> Result<i32, OpError> {
        Ok(match zp {
            None => 0,
            Some(z) => z.as_quantized_i32()?[0],
        })
    };
    let xz = zp_of(x_zp)?;
    let wz = zp_of(w_zp)?;
    let mut wv = w.as_quantized_i32()?;
    if wz != 0 {
        for v in &mut wv {
            *v -= wz;
        }
    }
    conv_integer_prewidened(x, &wv, m, wc, kh, kw, xz, attrs)
}

/// `ConvInteger` against an `[m, c, kh, kw]` kernel that was widened to
/// i32 (zero point already subtracted) once at plan time, with the baked
/// input zero point `x_zp`. Bit-identical to [`conv_integer`] — the same
/// widened values reach the same im2col + GEMM loop.
#[allow(clippy::too_many_arguments)]
pub fn conv_integer_prewidened(
    x: &Tensor,
    wv: &[i32],
    m: usize,
    c: usize,
    kh: usize,
    kw: usize,
    x_zp: i32,
    attrs: &ConvAttrs,
) -> Result<Tensor, OpError> {
    // The unplanned path stays strictly scalar: it is the differential
    // oracle the planned (possibly SIMD) path is tested against.
    conv_integer_prewidened_into(
        x,
        wv,
        None,
        m,
        c,
        kh,
        kw,
        x_zp,
        attrs,
        Isa::Scalar,
        None,
        &mut None,
    )
}

/// The compiled-plan form of [`conv_integer_prewidened`]: optionally a
/// plan-time [`PackedA`] weight packing, recycled output storage and a
/// recycled im2col scratch buffer from the scratch planner.
///
/// Fast path (i8 input, zero input zero point, packed weights — the
/// paper's symmetric patterns): im2col runs **directly over the i8
/// activations** into a recycled i8 column buffer feeding the packed
/// GEMM, killing both the per-call full-tensor i32 widening and the
/// per-call `col` allocation. Integer products are identical whether the
/// operands were widened first or not, so the result is bit-exact vs the
/// widened path (proven by `prewidened_matches_conv_integer` below and
/// the plan-vs-legacy oracle).
///
/// NOTE on zero points: im2col pads with 0 AFTER zero-point handling,
/// matching the ONNX contract (padding value is the zero point, i.e. 0
/// after folding — and the fast path requires x_zp == 0).
#[allow(clippy::too_many_arguments)]
pub fn conv_integer_prewidened_into(
    x: &Tensor,
    wv: &[i32],
    wp: Option<&PackedA>,
    m: usize,
    c: usize,
    kh: usize,
    kw: usize,
    x_zp: i32,
    attrs: &ConvAttrs,
    isa: Isa,
    recycled: Option<Tensor>,
    scratch: &mut Option<Tensor>,
) -> Result<Tensor, OpError> {
    if attrs.group != 1 {
        return Err(OpError::Semantics("group conv not supported".into()));
    }
    let (n, xc, h, wd) = nchw(x)?;
    if c != xc {
        return Err(OpError::Semantics(format!("channel mismatch {c} vs {xc}")));
    }
    let oh = out_spatial(h, kh, attrs.pads[0], attrs.pads[2], attrs.strides[0], attrs.dilations[0]);
    let ow = out_spatial(wd, kw, attrs.pads[1], attrs.pads[3], attrs.strides[1], attrs.dilations[1]);

    let patch_rows = c * kh * kw;
    let patch = oh * ow;
    let mut out = recycled_i32_zeroed(recycled, n * m * patch);
    let pool = ThreadPool::global();
    let macs_per_image = m * patch * patch_rows;
    let pool_worthy = n >= 2
        && pool.threads() > 1
        && parallel::allow_pool_dispatch()
        && n.saturating_mul(macs_per_image) >= CONV_PAR_MIN_WORK;

    match (x.data(), x_zp, wp) {
        (TensorData::I8(xv), 0, Some(wp)) if wp.m == m && wp.k == patch_rows => {
            let batch_block_i8 = |col: &mut Vec<i8>, b0: usize, block: &mut [i32]| {
                col.resize(patch_rows * patch, 0);
                for (bi, dst) in block.chunks_mut(m * patch).enumerate() {
                    let b = b0 + bi;
                    let src = &xv[b * c * h * wd..(b + 1) * c * h * wd];
                    im2col_i8(isa, src, c, h, wd, kh, kw, attrs, oh, ow, col);
                    gemm_i8_packed_a_isa(isa, wp, col, patch, dst);
                }
            };
            if pool_worthy {
                // Batch elements are independent and each chunk owns a
                // disjoint slice of `out`, so the sweep is bit-exact vs
                // serial; each chunk allocates its own column buffer
                // (amortized over a large batch).
                parallel::par_row_chunks_mut(pool, &mut out, n, m * patch, 1, |b0, block| {
                    let mut col = Vec::new();
                    batch_block_i8(&mut col, b0, block);
                });
            } else {
                // Serial steady state: the column buffer cycles through
                // the per-step scratch slot — zero allocations.
                let mut col = recycled_i8_zeroed(scratch.take(), patch_rows * patch);
                batch_block_i8(&mut col, 0, &mut out);
                let len = col.len();
                *scratch = Tensor::from_i8(&[len], col).ok();
            }
        }
        _ => {
            let mut xv = x.as_quantized_i32()?;
            if x_zp != 0 {
                for v in &mut xv {
                    *v -= x_zp;
                }
            }
            let batch_block = |col: &mut Vec<i32>, b0: usize, block: &mut [i32]| {
                col.resize(patch_rows * patch, 0);
                for (bi, dst) in block.chunks_mut(m * patch).enumerate() {
                    let b = b0 + bi;
                    let src = &xv[b * c * h * wd..(b + 1) * c * h * wd];
                    im2col(src, c, h, wd, kh, kw, attrs, oh, ow, col);
                    gemm_i32(wv, col, m, patch_rows, patch, dst);
                }
            };
            if pool_worthy {
                parallel::par_row_chunks_mut(pool, &mut out, n, m * patch, 1, |b0, block| {
                    let mut col = Vec::new();
                    batch_block(&mut col, b0, block);
                });
            } else {
                let mut col = recycled_i32_zeroed(scratch.take(), patch_rows * patch);
                batch_block(&mut col, 0, &mut out);
                let len = col.len();
                *scratch = Tensor::from_i32(&[len], col).ok();
            }
        }
    }
    Ok(Tensor::from_i32(&[n, m, oh, ow], out)?)
}

/// Width-dispatched form of [`conv_integer_prewidened_into`]: the baked
/// conv weights may be i8 row panels, int4 nibble rows, or bipolar bit
/// rows (see [`bitpack::PackedConvWeights`]). Narrow paths engage only
/// when the whole call qualifies — i8 input with zero zero-point for
/// int4; additionally all-±1 input and zero padding for XNOR (im2col
/// pads with 0, which is not a bipolar level) — otherwise the call
/// degrades to the widened-i32 kernel over `wv`, identical results.
///
/// The XNOR path packs each im2col column block into a per-call bit
/// buffer (small: `patch * ceil(k/64)` words); the bipolar figure models
/// are tiny, so this stays off the alloc-regression paths.
#[allow(clippy::too_many_arguments)]
pub fn conv_integer_packed_into(
    x: &Tensor,
    wv: &[i32],
    wp: Option<&bitpack::PackedConvWeights>,
    m: usize,
    c: usize,
    kh: usize,
    kw: usize,
    x_zp: i32,
    attrs: &ConvAttrs,
    isa: Isa,
    recycled: Option<Tensor>,
    scratch: &mut Option<Tensor>,
) -> Result<Tensor, OpError> {
    let narrow = matches!(
        wp,
        Some(bitpack::PackedConvWeights::I4(_))
            | Some(bitpack::PackedConvWeights::I3(_))
            | Some(bitpack::PackedConvWeights::I2(_))
            | Some(bitpack::PackedConvWeights::Bipolar(_))
    );
    if !narrow {
        let wp8 = match wp {
            Some(bitpack::PackedConvWeights::I8(p)) => Some(p),
            _ => None,
        };
        return conv_integer_prewidened_into(
            x, wv, wp8, m, c, kh, kw, x_zp, attrs, isa, recycled, scratch,
        );
    }
    if attrs.group != 1 {
        return Err(OpError::Semantics("group conv not supported".into()));
    }
    let (n, xc, h, wd) = nchw(x)?;
    if c != xc {
        return Err(OpError::Semantics(format!("channel mismatch {c} vs {xc}")));
    }
    let oh = out_spatial(h, kh, attrs.pads[0], attrs.pads[2], attrs.strides[0], attrs.dilations[0]);
    let ow = out_spatial(wd, kw, attrs.pads[1], attrs.pads[3], attrs.strides[1], attrs.dilations[1]);
    let patch_rows = c * kh * kw;
    let patch = oh * ow;
    match (wp, x.data()) {
        (Some(bitpack::PackedConvWeights::I4(ap)), TensorData::I8(xv))
            if x_zp == 0 && ap.m == m && ap.k == patch_rows =>
        {
            let mut out = recycled_i32_zeroed(recycled, n * m * patch);
            let mut col = recycled_i8_zeroed(scratch.take(), patch_rows * patch);
            for (b, dst) in out.chunks_mut(m * patch).enumerate() {
                let src = &xv[b * c * h * wd..(b + 1) * c * h * wd];
                im2col_i8(isa, src, c, h, wd, kh, kw, attrs, oh, ow, &mut col);
                bitpack::gemm_i4_packed_a_isa(isa, ap, &col, patch, dst);
            }
            let len = col.len();
            *scratch = Tensor::from_i8(&[len], col).ok();
            Ok(Tensor::from_i32(&[n, m, oh, ow], out)?)
        }
        (Some(bitpack::PackedConvWeights::I3(ap)), TensorData::I8(xv))
            if x_zp == 0 && ap.m == m && ap.k == patch_rows =>
        {
            let mut out = recycled_i32_zeroed(recycled, n * m * patch);
            let mut col = recycled_i8_zeroed(scratch.take(), patch_rows * patch);
            for (b, dst) in out.chunks_mut(m * patch).enumerate() {
                let src = &xv[b * c * h * wd..(b + 1) * c * h * wd];
                im2col_i8(isa, src, c, h, wd, kh, kw, attrs, oh, ow, &mut col);
                bitpack::gemm_i3_packed_a_isa(isa, ap, &col, patch, dst);
            }
            let len = col.len();
            *scratch = Tensor::from_i8(&[len], col).ok();
            Ok(Tensor::from_i32(&[n, m, oh, ow], out)?)
        }
        (Some(bitpack::PackedConvWeights::I2(ap)), TensorData::I8(xv))
            if x_zp == 0 && ap.m == m && ap.k == patch_rows =>
        {
            let mut out = recycled_i32_zeroed(recycled, n * m * patch);
            let mut col = recycled_i8_zeroed(scratch.take(), patch_rows * patch);
            for (b, dst) in out.chunks_mut(m * patch).enumerate() {
                let src = &xv[b * c * h * wd..(b + 1) * c * h * wd];
                im2col_i8(isa, src, c, h, wd, kh, kw, attrs, oh, ow, &mut col);
                bitpack::gemm_i2_packed_a_isa(isa, ap, &col, patch, dst);
            }
            let len = col.len();
            *scratch = Tensor::from_i8(&[len], col).ok();
            Ok(Tensor::from_i32(&[n, m, oh, ow], out)?)
        }
        (Some(bitpack::PackedConvWeights::Bipolar(ap)), TensorData::I8(xv))
            if x_zp == 0
                && ap.m == m
                && ap.k == patch_rows
                && attrs.pads == [0, 0, 0, 0]
                && xv.iter().all(|&v| v == 1 || v == -1) =>
        {
            // All-±1 input and no zero padding ⇒ every im2col column is
            // ±1 and the bit pack cannot fail.
            let mut out = recycled_i32_zeroed(recycled, n * m * patch);
            let mut col = recycled_i8_zeroed(scratch.take(), patch_rows * patch);
            let mut bits: Vec<i64> = Vec::new();
            for (b, dst) in out.chunks_mut(m * patch).enumerate() {
                let src = &xv[b * c * h * wd..(b + 1) * c * h * wd];
                im2col_i8(isa, src, c, h, wd, kh, kw, attrs, oh, ow, &mut col);
                bits.clear();
                let ok = bitpack::pack_bits_cols(&col, patch_rows, patch, &mut bits);
                debug_assert!(ok);
                bitpack::gemm_xnor_a_isa(isa, ap, &bits, patch, dst);
            }
            let len = col.len();
            *scratch = Tensor::from_i8(&[len], col).ok();
            Ok(Tensor::from_i32(&[n, m, oh, ow], out)?)
        }
        _ => conv_integer_prewidened_into(
            x, wv, None, m, c, kh, kw, x_zp, attrs, isa, recycled, scratch,
        ),
    }
}

/// ONNX float `Conv` (group=1), same im2col+GEMM path in f32.
pub fn conv_f32(x: &Tensor, w: &Tensor, attrs: &ConvAttrs) -> Result<Tensor, OpError> {
    conv_f32_into(x, w, attrs, None, &mut None)
}

/// [`conv_f32`] with recycled output/scratch storage and the batch loop
/// dispatched to the pool for large calls — bit-exact vs serial (disjoint
/// per-image output slices, identical per-element f32 operation order).
pub fn conv_f32_into(
    x: &Tensor,
    w: &Tensor,
    attrs: &ConvAttrs,
    recycled: Option<Tensor>,
    scratch: &mut Option<Tensor>,
) -> Result<Tensor, OpError> {
    if attrs.group != 1 {
        return Err(OpError::Semantics("group conv not supported".into()));
    }
    let (n, c, h, wd) = nchw(x)?;
    let (m, wc, kh, kw) = nchw(w)?;
    if wc != c {
        return Err(OpError::Semantics(format!("channel mismatch {wc} vs {c}")));
    }
    let oh = out_spatial(h, kh, attrs.pads[0], attrs.pads[2], attrs.strides[0], attrs.dilations[0]);
    let ow = out_spatial(wd, kw, attrs.pads[1], attrs.pads[3], attrs.strides[1], attrs.dilations[1]);

    let xv = x.as_f32()?;
    let wv = w.as_f32()?;
    let patch_rows = c * kh * kw;
    let patch = oh * ow;
    let mut out = recycled_f32_zeroed(recycled, n * m * patch);
    let batch_block = |col: &mut Vec<f32>, b0: usize, block: &mut [f32]| {
        col.resize(patch_rows * patch, 0.0);
        for (bi, dst) in block.chunks_mut(m * patch).enumerate() {
            let b = b0 + bi;
            let src = &xv[b * c * h * wd..(b + 1) * c * h * wd];
            im2col(src, c, h, wd, kh, kw, attrs, oh, ow, col);
            gemm_f32(wv, col, m, patch_rows, patch, dst);
        }
    };
    let pool = ThreadPool::global();
    let macs_per_image = m * patch * patch_rows;
    if n >= 2
        && pool.threads() > 1
        && parallel::allow_pool_dispatch()
        && n.saturating_mul(macs_per_image) >= CONV_PAR_MIN_WORK
    {
        parallel::par_row_chunks_mut(pool, &mut out, n, m * patch, 1, |b0, block| {
            let mut col = Vec::new();
            batch_block(&mut col, b0, block);
        });
    } else {
        let mut col = recycled_f32_zeroed(scratch.take(), patch_rows * patch);
        batch_block(&mut col, 0, &mut out);
        let len = col.len();
        *scratch = Tensor::from_f32(&[len], col).ok();
    }
    Ok(Tensor::from_f32(&[n, m, oh, ow], out)?)
}

fn nchw(t: &Tensor) -> Result<(usize, usize, usize, usize), OpError> {
    let s = t.shape();
    if s.len() != 4 {
        return Err(OpError::Semantics(format!("expected rank-4, got {s:?}")));
    }
    Ok((s[0], s[1], s[2], s[3]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn attrs_default() -> ConvAttrs {
        ConvAttrs {
            strides: [1, 1],
            pads: [0, 0, 0, 0],
            dilations: [1, 1],
            group: 1,
        }
    }

    #[test]
    fn conv_integer_identity_kernel() {
        // 1x1 kernel of value 1 copies the input.
        let x = Tensor::from_i8(&[1, 1, 2, 2], vec![1, 2, 3, 4]).unwrap();
        let w = Tensor::from_i8(&[1, 1, 1, 1], vec![1]).unwrap();
        let y = conv_integer(&x, &w, None, None, &attrs_default()).unwrap();
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.as_i32().unwrap(), &[1, 2, 3, 4]);
    }

    #[test]
    fn conv_integer_sum_kernel() {
        // 2x2 all-ones kernel on a 3x3 ramp = window sums.
        let x = Tensor::from_i8(&[1, 1, 3, 3], (1..=9).collect::<Vec<i8>>()).unwrap();
        let w = Tensor::from_i8(&[1, 1, 2, 2], vec![1, 1, 1, 1]).unwrap();
        let y = conv_integer(&x, &w, None, None, &attrs_default()).unwrap();
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.as_i32().unwrap(), &[12, 16, 24, 28]);
    }

    #[test]
    fn conv_integer_padding() {
        let x = Tensor::from_i8(&[1, 1, 2, 2], vec![1, 2, 3, 4]).unwrap();
        let w = Tensor::from_i8(&[1, 1, 3, 3], vec![0, 0, 0, 0, 1, 0, 0, 0, 0]).unwrap();
        let mut attrs = attrs_default();
        attrs.pads = [1, 1, 1, 1];
        let y = conv_integer(&x, &w, None, None, &attrs).unwrap();
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.as_i32().unwrap(), &[1, 2, 3, 4]);
    }

    #[test]
    fn conv_integer_multichannel() {
        // 2 input channels, kernel sums both channels at center.
        let x = Tensor::from_i8(&[1, 2, 2, 2], vec![1, 2, 3, 4, 10, 20, 30, 40]).unwrap();
        let w = Tensor::from_i8(&[1, 2, 1, 1], vec![1, 1]).unwrap();
        let y = conv_integer(&x, &w, None, None, &attrs_default()).unwrap();
        assert_eq!(y.as_i32().unwrap(), &[11, 22, 33, 44]);
    }

    #[test]
    fn conv_integer_stride() {
        let x = Tensor::from_i8(&[1, 1, 4, 4], (0..16).map(|i| i as i8).collect::<Vec<_>>())
            .unwrap();
        let w = Tensor::from_i8(&[1, 1, 1, 1], vec![1]).unwrap();
        let mut attrs = attrs_default();
        attrs.strides = [2, 2];
        let y = conv_integer(&x, &w, None, None, &attrs).unwrap();
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.as_i32().unwrap(), &[0, 2, 8, 10]);
    }

    #[test]
    fn conv_f32_matches_integer_on_ints() {
        let xi: Vec<i8> = vec![3, -1, 2, 0, 5, -4, 1, 1, -2];
        let wi: Vec<i8> = vec![1, -1, 2, 0];
        let x8 = Tensor::from_i8(&[1, 1, 3, 3], xi.clone()).unwrap();
        let w8 = Tensor::from_i8(&[1, 1, 2, 2], wi.clone()).unwrap();
        let xf =
            Tensor::from_f32(&[1, 1, 3, 3], xi.iter().map(|&v| v as f32).collect()).unwrap();
        let wf =
            Tensor::from_f32(&[1, 1, 2, 2], wi.iter().map(|&v| v as f32).collect()).unwrap();
        let yi = conv_integer(&x8, &w8, None, None, &attrs_default()).unwrap();
        let yf = conv_f32(&xf, &wf, &attrs_default()).unwrap();
        let yi: Vec<f32> = yi.as_i32().unwrap().iter().map(|&v| v as f32).collect();
        assert_eq!(yi, yf.as_f32().unwrap());
    }

    #[test]
    fn conv_integer_parallel_batch_matches_per_image() {
        // Large enough that the pool path engages (when threads > 1); the
        // batched result must equal per-image execution bit-for-bit.
        let (n, c, h, w) = (8usize, 3usize, 16usize, 16usize);
        let m = 8usize;
        let mut state = 0x5EEDu64;
        let mut rnd8 = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 56) as u8 as i8
        };
        let x = Tensor::from_i8(&[n, c, h, w], (0..n * c * h * w).map(|_| rnd8()).collect())
            .unwrap();
        let wt = Tensor::from_i8(&[m, c, 3, 3], (0..m * c * 9).map(|_| rnd8()).collect())
            .unwrap();
        let mut attrs = attrs_default();
        attrs.pads = [1, 1, 1, 1];
        let whole = conv_integer(&x, &wt, None, None, &attrs).unwrap();
        for b in 0..n {
            let xb = x.slice_rows(b, 1).unwrap();
            let yb = conv_integer(&xb, &wt, None, None, &attrs).unwrap();
            let whole_b = whole.slice_rows(b, 1).unwrap();
            assert_eq!(yb, whole_b, "batch element {b}");
        }
    }

    #[test]
    fn prewidened_matches_conv_integer() {
        let x = Tensor::from_i8(&[2, 2, 3, 3], (0..36).map(|i| (i * 7 % 31) as i8 - 15).collect())
            .unwrap();
        let w = Tensor::from_i8(&[2, 2, 2, 2], (0..16).map(|i| (i * 3 % 17) as i8 - 8).collect())
            .unwrap();
        let mut attrs = attrs_default();
        attrs.pads = [1, 0, 0, 1];
        let want = conv_integer(&x, &w, None, None, &attrs).unwrap();
        let wv: Vec<i32> = w.as_quantized_i32().unwrap();
        let got = conv_integer_prewidened(&x, &wv, 2, 2, 2, 2, 0, &attrs).unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn packed_conv_matches_widened() {
        let x = Tensor::from_i8(&[2, 2, 5, 5], (0..100).map(|i| (i * 13 % 251) as u8 as i8).collect())
            .unwrap();
        let w = Tensor::from_i8(&[3, 2, 2, 2], (0..24).map(|i| (i * 5 % 17) as i8 - 8).collect())
            .unwrap();
        let wv = w.as_quantized_i32().unwrap();
        let wp = PackedA::pack(&wv, 3, 2 * 2 * 2).unwrap();
        let mut attrs = attrs_default();
        attrs.pads = [1, 0, 1, 0];
        attrs.strides = [2, 1];
        let want = conv_integer_prewidened(&x, &wv, 3, 2, 2, 2, 0, &attrs).unwrap();
        let mut scratch = None;
        let got = conv_integer_prewidened_into(
            &x, &wv, Some(&wp), 3, 2, 2, 2, 0, &attrs, Isa::Scalar, None, &mut scratch,
        )
        .unwrap();
        assert_eq!(want, got);
        // Scratch was parked for reuse; a second call recycles it and
        // must produce the same bits.
        let recycled_out = Some(Tensor::from_i32(&[4], vec![9; 4]).unwrap());
        let again = conv_integer_prewidened_into(
            &x, &wv, Some(&wp), 3, 2, 2, 2, 0, &attrs, Isa::Scalar, recycled_out, &mut scratch,
        )
        .unwrap();
        assert_eq!(want, again);
        // Nonzero input zero point must bypass the packed path and still
        // agree with conv_integer's own handling.
        let xu = x.cast(crate::tensor::DType::U8);
        let zp = Tensor::scalar_u8(128);
        let want_zp = conv_integer(&xu, &w, Some(&zp), None, &attrs).unwrap();
        let got_zp = conv_integer_prewidened_into(
            &xu, &wv, Some(&wp), 3, 2, 2, 2, 128, &attrs, Isa::Scalar, None, &mut scratch,
        )
        .unwrap();
        assert_eq!(want_zp, got_zp);
    }

    #[test]
    fn packed_conv_isa_variants_match_scalar() {
        // Every available ISA must reproduce the scalar fast path bit for
        // bit, across geometries that hit both im2col_i8 branches: the
        // segmented copy (stride_w == dil_w == 1, with and without
        // padding) and the generic fallback (strided / dilated width).
        let x = Tensor::from_i8(
            &[2, 3, 9, 9],
            (0..2 * 3 * 81).map(|i| (i * 29 % 251) as u8 as i8).collect(),
        )
        .unwrap();
        let w = Tensor::from_i8(
            &[4, 3, 3, 3],
            (0..4 * 3 * 9).map(|i| (i * 11 % 17) as i8 - 8).collect(),
        )
        .unwrap();
        let wv = w.as_quantized_i32().unwrap();
        let wp = PackedA::pack(&wv, 4, 3 * 3 * 3).unwrap();
        let cases = [
            ([1, 1], [0, 0, 0, 0], [1, 1]),
            ([1, 1], [1, 2, 2, 1], [1, 1]),
            ([2, 1], [1, 1, 1, 1], [1, 1]),
            ([1, 2], [0, 1, 1, 0], [1, 1]),
            ([1, 1], [2, 2, 2, 2], [2, 2]),
        ];
        for (strides, pads, dilations) in cases {
            let attrs = ConvAttrs { strides, pads, dilations, group: 1 };
            let mut scratch = None;
            let want = conv_integer_prewidened_into(
                &x, &wv, Some(&wp), 4, 3, 3, 3, 0, &attrs, Isa::Scalar, None, &mut scratch,
            )
            .unwrap();
            for isa in Isa::available() {
                let got = conv_integer_prewidened_into(
                    &x, &wv, Some(&wp), 4, 3, 3, 3, 0, &attrs, isa, None, &mut scratch,
                )
                .unwrap();
                assert_eq!(want, got, "{isa} attrs {attrs:?}");
            }
        }
    }

    #[test]
    fn conv_f32_into_recycles_and_matches() {
        let x = Tensor::from_f32(&[2, 1, 4, 4], (0..32).map(|i| i as f32 * 0.25 - 4.0).collect())
            .unwrap();
        let w = Tensor::from_f32(&[2, 1, 3, 3], (0..18).map(|i| (i as f32 - 9.0) * 0.5).collect())
            .unwrap();
        let mut attrs = attrs_default();
        attrs.pads = [1, 1, 1, 1];
        let want = conv_f32(&x, &w, &attrs).unwrap();
        let mut scratch = None;
        let first = conv_f32_into(&x, &w, &attrs, None, &mut scratch).unwrap();
        let second = conv_f32_into(
            &x,
            &w,
            &attrs,
            Some(Tensor::from_f32(&[1], vec![0.0]).unwrap()),
            &mut scratch,
        )
        .unwrap();
        assert_eq!(want, first);
        assert_eq!(want, second);
    }

    #[test]
    fn conv_integer_batch2() {
        let x = Tensor::from_i8(&[2, 1, 2, 2], vec![1, 1, 1, 1, 2, 2, 2, 2]).unwrap();
        let w = Tensor::from_i8(&[1, 1, 2, 2], vec![1, 1, 1, 1]).unwrap();
        let y = conv_integer(&x, &w, None, None, &attrs_default()).unwrap();
        assert_eq!(y.shape(), &[2, 1, 1, 1]);
        assert_eq!(y.as_i32().unwrap(), &[4, 8]);
    }
}
