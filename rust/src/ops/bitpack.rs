//! Bit-packed sub-8-bit weight storage and GEMM kernels (ROADMAP item 3,
//! QONNX/FINN-style lowering).
//!
//! Two kernel families below the existing i8 panels:
//!
//! * **int4** — weights whose widened values all fit `[-8, 7]` pack two
//!   two's-complement nibbles per byte (low nibble first). The GEMMs
//!   unpack one L1-sized block at a time into a stack buffer of plain i8
//!   and then run exactly the i8 microkernel accumulation, so the packed
//!   path halves weight memory traffic without new arithmetic.
//! * **bipolar (XNOR-popcount)** — weights/activations that are all
//!   {-1, +1} pack one bit per value (bit set ⇔ +1, 8 weights per byte,
//!   64 per word). Since `a·b = +1` iff the sign bits agree,
//!   `dot = k − 2·popcount(a_bits XOR b_bits)` over the logical k bits.
//!   Zero-padded tail bits XOR to 0, so counting over whole words equals
//!   counting over the logical bits and the ragged tail needs no mask.
//!
//! **Bit-exactness:** every kernel accumulates each output element's
//! k-products in ascending k order (the int4 paths literally run the i8
//! loop over unpacked values; the XNOR identity is exact over i32), so
//! results are bit-identical to the naive widen-to-i32 triple loop — the
//! same oracle the i8 packed kernels are proptested against
//! (`tests/packed_gemm.rs` per-width differential tests).
//!
//! The `isa` parameters on the dispatch wrappers are the same plan-time
//! seam the i8 kernels use (PR 6). The bodies are scalar today — the
//! int4 inner loop IS the i8 loop (already auto-vectorizable over the
//! unpacked block) and the XNOR kernel is dominated by `count_ones`,
//! which compiles to the native popcount instruction on every supported
//! target — so the wrappers exist to keep the call sites and the tuner
//! stable when `vpshufb`-style unpack or `vpopcntdq` variants land.

use super::isa::Isa;
use super::matmul::{self, GEMM_MR, GEMM_NR_MAX};
use crate::parallel::{self, ThreadPool};
use crate::tune::GemmConfig;

/// k-rows unpacked per stack block in the int4 kernels. One block is
/// `UNPACK_KC x GEMM_NR_MAX` i8 = 4 KiB, L1-resident next to the
/// activation rows streaming against it.
const UNPACK_KC: usize = 256;

// --- nibble packing ---------------------------------------------------------

/// Pack two int4 values (each in `[-8, 7]`) into one byte, low nibble
/// first.
#[inline]
pub fn pack_nibbles(lo: i8, hi: i8) -> u8 {
    debug_assert!((-8..=7).contains(&lo) && (-8..=7).contains(&hi));
    ((lo as u8) & 0x0f) | ((hi as u8) << 4)
}

/// Sign-extend the low nibble of a packed byte back to i8.
#[inline]
pub fn unpack_nibble_lo(b: u8) -> i8 {
    ((b << 4) as i8) >> 4
}

/// Sign-extend the high nibble of a packed byte back to i8.
#[inline]
pub fn unpack_nibble_hi(b: u8) -> i8 {
    (b as i8) >> 4
}

// --- int4 packed B (FC weights) ---------------------------------------------

/// A `[k, n]` B operand nibble-packed at plan time for
/// [`gemm_i4_packed`]: the exact panel layout of
/// [`matmul::PackedB`] (`ceil(n/nr)` column panels, each `[k x nr]`
/// row-major, ragged last panel zero-padded) at half the bytes — each
/// panel row of `nr` values is `nr/2` bytes, low nibble first. Packing
/// refuses (`None`) when any widened value leaves `[-8, 7]` or the tile
/// width is odd (panel rows must stay byte-aligned); callers then keep
/// the i8 or widened-i32 kernels — identical results either way.
pub struct PackedB4 {
    data: Vec<u8>,
    pub k: usize,
    pub n: usize,
    /// Tile config this operand was packed with (same roles as on
    /// [`matmul::PackedB`]).
    pub cfg: GemmConfig,
}

impl PackedB4 {
    /// Pack with the default tile config.
    pub fn pack(bw: &[i32], k: usize, n: usize) -> Option<PackedB4> {
        PackedB4::pack_with(bw, k, n, GemmConfig::DEFAULT)
    }

    /// Pack with an explicit (tuned) tile config.
    pub fn pack_with(bw: &[i32], k: usize, n: usize, cfg: GemmConfig) -> Option<PackedB4> {
        debug_assert_eq!(bw.len(), k * n);
        assert!(
            cfg.nr > 0 && cfg.nr <= GEMM_NR_MAX,
            "bad panel width {}",
            cfg.nr
        );
        if cfg.nr % 2 != 0 || bw.iter().any(|&v| !(-8..=7).contains(&v)) {
            return None;
        }
        let nr = cfg.nr;
        let row_bytes = nr / 2;
        let np = n.div_ceil(nr);
        let mut data = vec![0u8; np * k * row_bytes];
        for jp in 0..np {
            let j0 = jp * nr;
            let jw = nr.min(n - j0);
            let panel = &mut data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
            for kk in 0..k {
                for jj in 0..jw {
                    let v = bw[kk * n + j0 + jj] as i8;
                    let byte = &mut panel[kk * row_bytes + jj / 2];
                    *byte = if jj % 2 == 0 {
                        pack_nibbles(v, unpack_nibble_hi(*byte))
                    } else {
                        pack_nibbles(unpack_nibble_lo(*byte), v)
                    };
                }
            }
        }
        Some(PackedB4 { data, k, n, cfg })
    }

    /// Bytes held by the packed panels (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// i8-activation GEMM against a nibble-packed B: `C[m,n] = A[m,k] x
/// B[k,n]`, i32 accumulation. Per column panel, unpacks [`UNPACK_KC`]
/// panel rows at a time into a stack i8 block and runs the i8 register
/// tile over it; per output element the products still accumulate in
/// ascending k (block partial sums added in block order), so the result
/// is bit-identical to [`matmul::gemm_i8_i32`] over the widened values.
pub fn gemm_i4_packed(a: &[i8], bp: &PackedB4, m: usize, c: &mut [i32]) {
    match bp.cfg.nr {
        4 => gemm_i4_packed_tile::<4>(a, bp, m, c, 4),
        8 => gemm_i4_packed_tile::<8>(a, bp, m, c, 8),
        16 => gemm_i4_packed_tile::<16>(a, bp, m, c, 16),
        nr => gemm_i4_packed_tile::<GEMM_NR_MAX>(a, bp, m, c, nr),
    }
}

fn gemm_i4_packed_tile<const NR_CAP: usize>(
    a: &[i8],
    bp: &PackedB4,
    m: usize,
    c: &mut [i32],
    nr: usize,
) {
    let (k, n) = (bp.k, bp.n);
    debug_assert_eq!(nr, bp.cfg.nr);
    debug_assert!(nr > 0 && nr <= NR_CAP && nr % 2 == 0);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    let kc_blk = bp.cfg.kc.clamp(1, UNPACK_KC);
    let row_bytes = nr / 2;
    let np = n.div_ceil(nr);
    let mut unp = [0i8; UNPACK_KC * GEMM_NR_MAX];
    for jp in 0..np {
        let j0 = jp * nr;
        let jw = nr.min(n - j0);
        let panel = &bp.data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
        for i in 0..m {
            let base = i * n + j0;
            c[base..base + jw].fill(0);
        }
        let mut kb = 0;
        while kb < k {
            let kc = kc_blk.min(k - kb);
            // Unpack this k-block of the panel once for every row tile.
            for kk in 0..kc {
                let prow = &panel[(kb + kk) * row_bytes..(kb + kk + 1) * row_bytes];
                let urow = &mut unp[kk * nr..(kk + 1) * nr];
                for (jj, &byte) in prow.iter().enumerate() {
                    urow[2 * jj] = unpack_nibble_lo(byte);
                    urow[2 * jj + 1] = unpack_nibble_hi(byte);
                }
            }
            let mut i0 = 0;
            while i0 < m {
                let iw = GEMM_MR.min(m - i0);
                let mut acc = [[0i32; NR_CAP]; GEMM_MR];
                if nr == NR_CAP {
                    for kk in 0..kc {
                        let brow = &unp[kk * NR_CAP..(kk + 1) * NR_CAP];
                        for r in 0..iw {
                            let av = a[(i0 + r) * k + kb + kk] as i32;
                            for jj in 0..NR_CAP {
                                acc[r][jj] += av * brow[jj] as i32;
                            }
                        }
                    }
                } else {
                    for kk in 0..kc {
                        let brow = &unp[kk * nr..(kk + 1) * nr];
                        for r in 0..iw {
                            let av = a[(i0 + r) * k + kb + kk] as i32;
                            for (jj, &bv) in brow.iter().enumerate() {
                                acc[r][jj] += av * bv as i32;
                            }
                        }
                    }
                }
                for r in 0..iw {
                    let base = (i0 + r) * n + j0;
                    for (cv, av) in c[base..base + jw].iter_mut().zip(&acc[r][..jw]) {
                        *cv += av;
                    }
                }
                i0 += GEMM_MR;
            }
            kb += kc;
        }
    }
}

/// [`gemm_i4_packed`] through the plan-selected ISA seam (scalar body
/// today — see the module note).
pub fn gemm_i4_packed_isa(isa: Isa, a: &[i8], bp: &PackedB4, m: usize, c: &mut [i32]) {
    let _ = isa.normalized();
    gemm_i4_packed(a, bp, m, c);
}

/// Row-parallel wrapper over [`gemm_i4_packed_isa`] (bit-exact: disjoint
/// row blocks, identical per-element accumulation order). Thresholds come
/// from the operand's (possibly tuned) config.
pub fn gemm_i4_packed_par_isa(
    pool: &ThreadPool,
    isa: Isa,
    a: &[i8],
    bp: &PackedB4,
    m: usize,
    c: &mut [i32],
) {
    let (k, n) = (bp.k, bp.n);
    let min_rows = bp.cfg.par_min_rows.max(1);
    if !worth_parallel(pool, m, k, n, min_rows, bp.cfg.par_min_work) {
        gemm_i4_packed_isa(isa, a, bp, m, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, min_rows, |row0, block| {
        let rows = block.len() / n;
        gemm_i4_packed_isa(isa, &a[row0 * k..(row0 + rows) * k], bp, rows, block);
    });
}

// --- int4 packed A (conv weights) -------------------------------------------

/// An `[m, k]` A operand (the conv weight matrix) nibble-packed at plan
/// time for [`gemm_i4_packed_a`]: plain row-major, each row
/// `ceil(k/2)` bytes (low nibble = even k), rows independently
/// byte-aligned so the ragged k tail pads within its own row. `None`
/// when any value leaves `[-8, 7]`.
pub struct PackedA4 {
    data: Vec<u8>,
    pub m: usize,
    pub k: usize,
    /// Tile config carried for the runtime thresholds (the layout itself
    /// is row-major, not tiled).
    pub cfg: GemmConfig,
}

impl PackedA4 {
    pub fn pack(aw: &[i32], m: usize, k: usize) -> Option<PackedA4> {
        PackedA4::pack_with(aw, m, k, GemmConfig::DEFAULT)
    }

    pub fn pack_with(aw: &[i32], m: usize, k: usize, cfg: GemmConfig) -> Option<PackedA4> {
        debug_assert_eq!(aw.len(), m * k);
        if aw.iter().any(|&v| !(-8..=7).contains(&v)) {
            return None;
        }
        let row_bytes = k.div_ceil(2);
        let mut data = vec![0u8; m * row_bytes];
        for i in 0..m {
            for kk in 0..k {
                let v = aw[i * k + kk] as i8;
                let byte = &mut data[i * row_bytes + kk / 2];
                *byte = if kk % 2 == 0 {
                    pack_nibbles(v, unpack_nibble_hi(*byte))
                } else {
                    pack_nibbles(unpack_nibble_lo(*byte), v)
                };
            }
        }
        Some(PackedA4 { data, m, k, cfg })
    }

    /// Bytes held by the packed rows (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// GEMM against a nibble-packed A and a runtime row-major i8 B (the conv
/// im2col columns): `C[m,n] = A[m,k] x B[k,n]`. Unpacks [`GEMM_MR`] weight
/// rows x [`UNPACK_KC`] k at a time into a stack block, then streams the B
/// rows exactly like the widened kernel — ascending k per output element,
/// bit-identical to the naive loop.
pub fn gemm_i4_packed_a(ap: &PackedA4, b: &[i8], n: usize, c: &mut [i32]) {
    let (m, k) = (ap.m, ap.k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let row_bytes = k.div_ceil(2);
    c.fill(0);
    let mut unp = [0i8; GEMM_MR * UNPACK_KC];
    let mut i0 = 0;
    while i0 < m {
        let iw = GEMM_MR.min(m - i0);
        let mut kb = 0;
        while kb < k {
            let kc = UNPACK_KC.min(k - kb);
            for r in 0..iw {
                let prow = &ap.data[(i0 + r) * row_bytes..(i0 + r + 1) * row_bytes];
                for kk in 0..kc {
                    let byte = prow[(kb + kk) / 2];
                    unp[r * UNPACK_KC + kk] = if (kb + kk) % 2 == 0 {
                        unpack_nibble_lo(byte)
                    } else {
                        unpack_nibble_hi(byte)
                    };
                }
            }
            for kk in 0..kc {
                let brow = &b[(kb + kk) * n..(kb + kk + 1) * n];
                for r in 0..iw {
                    let av = unp[r * UNPACK_KC + kk] as i32;
                    if av == 0 {
                        continue;
                    }
                    let crow = &mut c[(i0 + r) * n..(i0 + r + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv as i32;
                    }
                }
            }
            kb += kc;
        }
        i0 += GEMM_MR;
    }
}

/// [`gemm_i4_packed_a`] through the plan-selected ISA seam (scalar body
/// today — see the module note).
pub fn gemm_i4_packed_a_isa(isa: Isa, ap: &PackedA4, b: &[i8], n: usize, c: &mut [i32]) {
    let _ = isa.normalized();
    gemm_i4_packed_a(ap, b, n, c);
}

// --- bipolar bit packing ----------------------------------------------------

/// Words of 64 bit-packed values covering `k`.
#[inline]
pub fn bit_words(k: usize) -> usize {
    k.div_ceil(64)
}

/// Pack `m` rows of ±1 i8 values into bit rows (bit set ⇔ +1), 64 per
/// i64 word, `bit_words(k)` words per row, tail bits zero. Appends to
/// `out` (callers pass a cleared recycled buffer) and returns `false` —
/// leaving `out` in an unspecified state — if any value is not ±1: the
/// runtime gate the fused kernels use to fall back to the widened path.
pub fn pack_bits_rows(a: &[i8], m: usize, k: usize, out: &mut Vec<i64>) -> bool {
    debug_assert_eq!(a.len(), m * k);
    let words = bit_words(k);
    out.reserve(m * words);
    for i in 0..m {
        let row = &a[i * k..(i + 1) * k];
        for wchunk in row.chunks(64) {
            let mut w = 0u64;
            for (t, &v) in wchunk.iter().enumerate() {
                match v {
                    1 => w |= 1 << t,
                    -1 => {}
                    _ => return false,
                }
            }
            out.push(w as i64);
        }
    }
    true
}

/// Pack the columns of a row-major `[k, n]` ±1 i8 matrix into bit
/// columns (`bit_words(k)` words per column). Same contract as
/// [`pack_bits_rows`].
pub fn pack_bits_cols(b: &[i8], k: usize, n: usize, out: &mut Vec<i64>) -> bool {
    debug_assert_eq!(b.len(), k * n);
    let words = bit_words(k);
    let base = out.len();
    out.resize(base + n * words, 0);
    for kk in 0..k {
        let (w, t) = (kk / 64, kk % 64);
        let brow = &b[kk * n..(kk + 1) * n];
        for (j, &v) in brow.iter().enumerate() {
            match v {
                1 => out[base + j * words + w] |= 1 << t,
                -1 => {}
                _ => return false,
            }
        }
    }
    true
}

/// A `[k, n]` bipolar B operand bit-packed at plan time for
/// [`gemm_xnor`]: column-major bit columns so each output element XORs
/// two contiguous word runs. `None` unless every widened value is ±1.
pub struct BitPackedB {
    data: Vec<i64>,
    pub k: usize,
    pub n: usize,
}

impl BitPackedB {
    pub fn pack(bw: &[i32], k: usize, n: usize) -> Option<BitPackedB> {
        debug_assert_eq!(bw.len(), k * n);
        if bw.iter().any(|&v| v != 1 && v != -1) {
            return None;
        }
        let words = bit_words(k);
        let mut data = vec![0i64; n * words];
        for kk in 0..k {
            let (w, t) = (kk / 64, kk % 64);
            for j in 0..n {
                if bw[kk * n + j] == 1 {
                    data[j * words + w] |= 1 << t;
                }
            }
        }
        Some(BitPackedB { data, k, n })
    }

    /// Bytes held by the packed bit columns (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len() * 8
    }
}

/// An `[m, k]` bipolar A operand (conv weights) bit-packed at plan time
/// for [`gemm_xnor_a`]: row-major bit rows. `None` unless all ±1.
pub struct BitPackedA {
    data: Vec<i64>,
    pub m: usize,
    pub k: usize,
}

impl BitPackedA {
    pub fn pack(aw: &[i32], m: usize, k: usize) -> Option<BitPackedA> {
        debug_assert_eq!(aw.len(), m * k);
        if aw.iter().any(|&v| v != 1 && v != -1) {
            return None;
        }
        let mut data = Vec::new();
        let packed: Vec<i8> = aw.iter().map(|&v| v as i8).collect();
        let ok = pack_bits_rows(&packed, m, k, &mut data);
        debug_assert!(ok);
        Some(BitPackedA { data, m, k })
    }

    /// Bytes held by the packed bit rows (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len() * 8
    }
}

/// XNOR-popcount GEMM: bit-packed ±1 activations (rows, from
/// [`pack_bits_rows`]) x bit-packed ±1 weights. For each element,
/// `dot = k − 2·popcount(a XOR b)` — exact over i32, so bit-identical to
/// the widened ±1 triple loop.
pub fn gemm_xnor(a_bits: &[i64], bb: &BitPackedB, m: usize, c: &mut [i32]) {
    let words = bit_words(bb.k);
    let (k, n) = (bb.k as i32, bb.n);
    debug_assert_eq!(a_bits.len(), m * words);
    debug_assert_eq!(c.len(), m * bb.n);
    for i in 0..m {
        let arow = &a_bits[i * words..(i + 1) * words];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let bcol = &bb.data[j * words..(j + 1) * words];
            let mut diff = 0u32;
            for (aw, bw) in arow.iter().zip(bcol) {
                diff += (aw ^ bw).count_ones();
            }
            *cv = k - 2 * diff as i32;
        }
    }
}

/// [`gemm_xnor`] through the plan-selected ISA seam (scalar body today —
/// `count_ones` already lowers to the native popcount; see module note).
pub fn gemm_xnor_isa(isa: Isa, a_bits: &[i64], bb: &BitPackedB, m: usize, c: &mut [i32]) {
    let _ = isa.normalized();
    gemm_xnor(a_bits, bb, m, c);
}

/// Row-parallel wrapper over [`gemm_xnor_isa`] (bit-exact: disjoint rows,
/// exact integer identity per element). Default thresholds — bit-packed
/// operands have no tuned config.
pub fn gemm_xnor_par_isa(
    pool: &ThreadPool,
    isa: Isa,
    a_bits: &[i64],
    bb: &BitPackedB,
    m: usize,
    c: &mut [i32],
) {
    let (k, n) = (bb.k, bb.n);
    let words = bit_words(k);
    if !worth_parallel(
        pool,
        m,
        k,
        n,
        matmul::GEMM_PAR_MIN_ROWS,
        matmul::GEMM_PAR_MIN_WORK,
    ) {
        gemm_xnor_isa(isa, a_bits, bb, m, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, matmul::GEMM_PAR_MIN_ROWS, |row0, block| {
        let rows = block.len() / n;
        gemm_xnor_isa(
            isa,
            &a_bits[row0 * words..(row0 + rows) * words],
            bb,
            rows,
            block,
        );
    });
}

/// XNOR-popcount GEMM with bit-packed A rows (conv weights) against
/// bit-packed B columns built at run time from the im2col buffer
/// ([`pack_bits_cols`]).
pub fn gemm_xnor_a(ap: &BitPackedA, b_bits: &[i64], n: usize, c: &mut [i32]) {
    let words = bit_words(ap.k);
    let (m, k) = (ap.m, ap.k as i32);
    debug_assert_eq!(b_bits.len(), n * words);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &ap.data[i * words..(i + 1) * words];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let bcol = &b_bits[j * words..(j + 1) * words];
            let mut diff = 0u32;
            for (aw, bw) in arow.iter().zip(bcol) {
                diff += (aw ^ bw).count_ones();
            }
            *cv = k - 2 * diff as i32;
        }
    }
}

/// [`gemm_xnor_a`] through the plan-selected ISA seam (scalar body today).
pub fn gemm_xnor_a_isa(isa: Isa, ap: &BitPackedA, b_bits: &[i64], n: usize, c: &mut [i32]) {
    let _ = isa.normalized();
    gemm_xnor_a(ap, b_bits, n, c);
}

// --- width-dispatched plan-time weight storage ------------------------------

/// Plan-time baked B-side weights at whatever width the optimizer
/// selected (see `opt::select_fc_width`): the i8 panels every chain gets
/// today, nibble panels when the weights fit int4, bit columns when they
/// are bipolar. The fused FC kernel dispatches on the variant at run
/// time and falls back to the widened-i32 path whenever the activations
/// don't qualify (non-i8, nonzero zero point, non-±1 for XNOR) — so the
/// narrow variants can never change results, only memory traffic.
pub enum PackedWeights {
    I8(matmul::PackedB),
    I4(PackedB4),
    Bipolar(BitPackedB),
}

impl PackedWeights {
    /// Bytes held by the baked storage (plan-memory accounting /
    /// `Kernel::baked_bytes`).
    pub fn bytes(&self) -> usize {
        match self {
            PackedWeights::I8(p) => p.bytes(),
            PackedWeights::I4(p) => p.bytes(),
            PackedWeights::Bipolar(p) => p.bytes(),
        }
    }

    /// Logical weight bits per value (8 / 4 / 1) — feeds the hwsim cost
    /// model's DRAM-traffic scaling and `plan_stats`.
    pub fn bits(&self) -> u8 {
        match self {
            PackedWeights::I8(_) => 8,
            PackedWeights::I4(_) => 4,
            PackedWeights::Bipolar(_) => 1,
        }
    }

    pub fn width_name(&self) -> &'static str {
        match self {
            PackedWeights::I8(_) => "int8",
            PackedWeights::I4(_) => "int4",
            PackedWeights::Bipolar(_) => "bipolar",
        }
    }
}

/// Plan-time baked A-side (conv) weights — the conv twin of
/// [`PackedWeights`].
pub enum PackedConvWeights {
    I8(matmul::PackedA),
    I4(PackedA4),
    Bipolar(BitPackedA),
}

impl PackedConvWeights {
    pub fn bytes(&self) -> usize {
        match self {
            PackedConvWeights::I8(p) => p.bytes(),
            PackedConvWeights::I4(p) => p.bytes(),
            PackedConvWeights::Bipolar(p) => p.bytes(),
        }
    }

    pub fn bits(&self) -> u8 {
        match self {
            PackedConvWeights::I8(_) => 8,
            PackedConvWeights::I4(_) => 4,
            PackedConvWeights::Bipolar(_) => 1,
        }
    }

    pub fn width_name(&self) -> &'static str {
        match self {
            PackedConvWeights::I8(_) => "int8",
            PackedConvWeights::I4(_) => "int4",
            PackedConvWeights::Bipolar(_) => "bipolar",
        }
    }
}

/// Local copy of the packed kernels' pool-dispatch policy (the matmul
/// original is private; the thresholds mean the same thing here).
fn worth_parallel(
    pool: &ThreadPool,
    m: usize,
    k: usize,
    n: usize,
    min_rows: usize,
    min_work: usize,
) -> bool {
    pool.threads() > 1
        && parallel::allow_pool_dispatch()
        && m >= 2 * min_rows
        && m.saturating_mul(k).saturating_mul(n) >= min_work
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn nibble_round_trip_all_values() {
        for lo in -8..=7i8 {
            for hi in -8..=7i8 {
                let b = pack_nibbles(lo, hi);
                assert_eq!(unpack_nibble_lo(b), lo);
                assert_eq!(unpack_nibble_hi(b), hi);
            }
        }
    }

    #[test]
    fn packed_b4_refuses_out_of_range() {
        assert!(PackedB4::pack(&[0, 8], 1, 2).is_none());
        assert!(PackedB4::pack(&[-9, 0], 1, 2).is_none());
        assert!(PackedB4::pack(&[-8, 7], 1, 2).is_some());
        assert!(PackedA4::pack(&[0, 8], 2, 1).is_none());
        assert!(PackedA4::pack(&[-8, 7], 2, 1).is_some());
    }

    #[test]
    fn i4_gemm_matches_naive_ragged() {
        // Shapes straddling panel width, MR, and the unpack block.
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (4, 16, 8), (5, 300, 17), (2, 513, 9)] {
            let a: Vec<i32> = (0..m * k).map(|i| (i as i32 * 37 % 255) - 127).collect();
            let b: Vec<i32> = (0..k * n).map(|i| (i as i32 * 13 % 16) - 8).collect();
            let want = naive(&a, &b, m, k, n);
            let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
            let bp = PackedB4::pack(&b, k, n).unwrap();
            let mut c = vec![0i32; m * n];
            gemm_i4_packed(&a8, &bp, m, &mut c);
            assert_eq!(c, want, "B-packed m={m} k={k} n={n}");
            let ap = PackedA4::pack(&a.iter().map(|&v| v.clamp(-8, 7)).collect::<Vec<_>>(), m, k)
                .unwrap();
            let want_a = naive(
                &a.iter().map(|&v| v.clamp(-8, 7)).collect::<Vec<_>>(),
                &b,
                m,
                k,
                n,
            );
            let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
            let mut c = vec![0i32; m * n];
            gemm_i4_packed_a(&ap, &b8, n, &mut c);
            assert_eq!(c, want_a, "A-packed m={m} k={k} n={n}");
        }
    }

    #[test]
    fn bit_pack_round_trip_and_ragged_tails() {
        // k not a multiple of 64: tail bits must pad to zero on both
        // sides so whole-word popcounts stay exact.
        for &(m, k) in &[(1, 1), (3, 63), (2, 64), (2, 65), (4, 130)] {
            let vals: Vec<i8> = (0..m * k).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect();
            let mut bits = Vec::new();
            assert!(pack_bits_rows(&vals, m, k, &mut bits));
            assert_eq!(bits.len(), m * bit_words(k));
            for i in 0..m {
                for kk in 0..k {
                    let bit = (bits[i * bit_words(k) + kk / 64] >> (kk % 64)) & 1;
                    assert_eq!(bit == 1, vals[i * k + kk] == 1, "row {i} bit {kk}");
                }
            }
        }
        let mut bits = Vec::new();
        assert!(!pack_bits_rows(&[1, 0, -1], 1, 3, &mut bits));
    }

    #[test]
    fn xnor_gemm_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 63, 5), (4, 64, 8), (5, 200, 17), (2, 513, 3)] {
            let a: Vec<i32> = (0..m * k).map(|i| if i % 5 < 2 { -1 } else { 1 }).collect();
            let b: Vec<i32> = (0..k * n).map(|i| if i % 7 < 4 { 1 } else { -1 }).collect();
            let want = naive(&a, &b, m, k, n);
            let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
            let mut a_bits = Vec::new();
            assert!(pack_bits_rows(&a8, m, k, &mut a_bits));
            let bb = BitPackedB::pack(&b, k, n).unwrap();
            let mut c = vec![0i32; m * n];
            gemm_xnor(&a_bits, &bb, m, &mut c);
            assert_eq!(c, want, "xnor m={m} k={k} n={n}");

            // Conv orientation: A bit rows at plan time, B bit cols at
            // run time.
            let ap = BitPackedA::pack(&a, m, k).unwrap();
            let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
            let mut b_bits = Vec::new();
            assert!(pack_bits_cols(&b8, k, n, &mut b_bits));
            let mut c = vec![0i32; m * n];
            gemm_xnor_a(&ap, &b_bits, n, &mut c);
            assert_eq!(c, want, "xnor-a m={m} k={k} n={n}");
        }
    }

    #[test]
    fn bipolar_pack_refuses_non_pm1() {
        assert!(BitPackedB::pack(&[1, -1, 0, 1], 2, 2).is_none());
        assert!(BitPackedA::pack(&[2, 1], 1, 2).is_none());
        assert!(BitPackedB::pack(&[1, -1, -1, 1], 2, 2).is_some());
    }

    #[test]
    fn packed_bytes_report_reduction() {
        let (k, n) = (128, 64);
        let b4: Vec<i32> = (0..k * n).map(|i| (i as i32 % 16) - 8 + 1).collect();
        let b1: Vec<i32> = (0..k * n).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let p8 = matmul::PackedB::pack(&b4, k, n).unwrap();
        let p4 = PackedB4::pack(&b4, k, n).unwrap();
        let p1 = BitPackedB::pack(&b1, k, n).unwrap();
        assert_eq!(p4.bytes() * 2, p8.bytes());
        assert_eq!(p1.bytes() * 8, k * n);
        assert_eq!(PackedWeights::I4(p4).bits(), 4);
        assert_eq!(PackedWeights::Bipolar(p1).width_name(), "bipolar");
    }
}
