//! Bit-packed sub-8-bit weight storage and GEMM kernels (ROADMAP item 3,
//! QONNX/FINN-style lowering).
//!
//! Two kernel families below the existing i8 panels:
//!
//! * **int4** — weights whose widened values all fit `[-8, 7]` pack two
//!   two's-complement nibbles per byte (low nibble first). The GEMMs
//!   unpack one L1-sized block at a time into a stack buffer of plain i8
//!   and then run exactly the i8 microkernel accumulation, so the packed
//!   path halves weight memory traffic without new arithmetic.
//! * **bipolar (XNOR-popcount)** — weights/activations that are all
//!   {-1, +1} pack one bit per value (bit set ⇔ +1, 8 weights per byte,
//!   64 per word). Since `a·b = +1` iff the sign bits agree,
//!   `dot = k − 2·popcount(a_bits XOR b_bits)` over the logical k bits.
//!   Zero-padded tail bits XOR to 0, so counting over whole words equals
//!   counting over the logical bits and the ragged tail needs no mask.
//!
//! **Bit-exactness:** every kernel accumulates each output element's
//! k-products in ascending k order (the int4 paths literally run the i8
//! loop over unpacked values; the XNOR identity is exact over i32), so
//! results are bit-identical to the naive widen-to-i32 triple loop — the
//! same oracle the i8 packed kernels are proptested against
//! (`tests/packed_gemm.rs` per-width differential tests).
//!
//! Two more storage-only widths ride the same seams:
//!
//! * **int2 (crumb)** — widened values in `[-2, 1]` pack four per byte
//!   (offset-encoded `v + 2` ∈ `[0, 3]`, little-endian within the byte).
//! * **int3 (tribble)** — widened values in `[-4, 3]` pack as 3-bit
//!   fields in a little-endian bitstream (`v + 4` ∈ `[0, 7]`).
//!
//! Both decode to plain i8 and accumulate exactly like the int4 path, so
//! they inherit its bit-exactness argument wholesale. Their kernels are
//! scalar reference implementations behind the same `_isa`/`_par_isa`
//! dispatch seams the int4/XNOR kernels started with — SIMD twins slot
//! in without touching any call site.
//!
//! **Bit-exactness of the SIMD twins** (`x86`/`arm` modules below): every
//! product `a[i,kk]·b[kk,j]` is computed exactly in an i32 lane (no
//! `maddbw`-style i16 pair-sums — the nibble unpack widens to 32-bit
//! lanes *before* multiplying, which sidesteps the documented
//! `_mm256_maddubs_epi16` saturation hazard entirely), and per output
//! element the lane still visits k in the scalar loop's ascending order.
//! i32 wrapping addition is associative and commutative, so the vector
//! regrouping cannot change any output bit. The XNOR twins replace
//! per-word `count_ones` with a `vpshufb` nibble-LUT popcount (AVX2) /
//! `vcntq_u8` (NEON) over 256/128-bit chunks plus a scalar word tail —
//! popcounts are exact integers, so the identity `dot = k − 2·popcount`
//! is untouched.
//!
//! The `_isa` wrappers run every value through [`Isa::normalized`]
//! before entering an `unsafe` body, exactly like `matmul.rs`: a forced
//! or stale ISA degrades to scalar instead of faulting.
//!
//! **Packed activations** (PR 10): [`pack_nibble_rows`] and
//! [`gemm_i4a_bytes`] let a fused producer hand its i8 output to the
//! next fused FC as nibble rows (half the intermediate traffic), and the
//! bitplane form from [`pack_bits_rows`] feeds [`gemm_xnor`] directly —
//! see `ops::fused` for the plan-time pairing decision.

use super::isa::Isa;
use super::matmul::{self, GEMM_MR, GEMM_NR, GEMM_NR_MAX};
use crate::parallel::{self, ThreadPool};
use crate::tune::GemmConfig;

/// k-rows unpacked per stack block in the int4 kernels. One block is
/// `UNPACK_KC x GEMM_NR_MAX` i8 = 4 KiB, L1-resident next to the
/// activation rows streaming against it.
const UNPACK_KC: usize = 256;

// --- nibble packing ---------------------------------------------------------

/// Pack two int4 values (each in `[-8, 7]`) into one byte, low nibble
/// first.
#[inline]
pub fn pack_nibbles(lo: i8, hi: i8) -> u8 {
    debug_assert!((-8..=7).contains(&lo) && (-8..=7).contains(&hi));
    ((lo as u8) & 0x0f) | ((hi as u8) << 4)
}

/// Sign-extend the low nibble of a packed byte back to i8.
#[inline]
pub fn unpack_nibble_lo(b: u8) -> i8 {
    ((b << 4) as i8) >> 4
}

/// Sign-extend the high nibble of a packed byte back to i8.
#[inline]
pub fn unpack_nibble_hi(b: u8) -> i8 {
    (b as i8) >> 4
}

// --- int4 packed B (FC weights) ---------------------------------------------

/// A `[k, n]` B operand nibble-packed at plan time for
/// [`gemm_i4_packed`]: the exact panel layout of
/// [`matmul::PackedB`] (`ceil(n/nr)` column panels, each `[k x nr]`
/// row-major, ragged last panel zero-padded) at half the bytes — each
/// panel row of `nr` values is `nr/2` bytes, low nibble first. Packing
/// refuses (`None`) when any widened value leaves `[-8, 7]` or the tile
/// width is odd (panel rows must stay byte-aligned); callers then keep
/// the i8 or widened-i32 kernels — identical results either way.
pub struct PackedB4 {
    data: Vec<u8>,
    pub k: usize,
    pub n: usize,
    /// Tile config this operand was packed with (same roles as on
    /// [`matmul::PackedB`]).
    pub cfg: GemmConfig,
}

impl PackedB4 {
    /// Pack with the default tile config.
    pub fn pack(bw: &[i32], k: usize, n: usize) -> Option<PackedB4> {
        PackedB4::pack_with(bw, k, n, GemmConfig::DEFAULT)
    }

    /// Pack with an explicit (tuned) tile config.
    pub fn pack_with(bw: &[i32], k: usize, n: usize, cfg: GemmConfig) -> Option<PackedB4> {
        debug_assert_eq!(bw.len(), k * n);
        assert!(
            cfg.nr > 0 && cfg.nr <= GEMM_NR_MAX,
            "bad panel width {}",
            cfg.nr
        );
        if cfg.nr % 2 != 0 || bw.iter().any(|&v| !(-8..=7).contains(&v)) {
            return None;
        }
        let nr = cfg.nr;
        let row_bytes = nr / 2;
        let np = n.div_ceil(nr);
        let mut data = vec![0u8; np * k * row_bytes];
        for jp in 0..np {
            let j0 = jp * nr;
            let jw = nr.min(n - j0);
            let panel = &mut data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
            for kk in 0..k {
                for jj in 0..jw {
                    let v = bw[kk * n + j0 + jj] as i8;
                    let byte = &mut panel[kk * row_bytes + jj / 2];
                    *byte = if jj % 2 == 0 {
                        pack_nibbles(v, unpack_nibble_hi(*byte))
                    } else {
                        pack_nibbles(unpack_nibble_lo(*byte), v)
                    };
                }
            }
        }
        Some(PackedB4 { data, k, n, cfg })
    }

    /// Bytes held by the packed panels (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// i8-activation GEMM against a nibble-packed B: `C[m,n] = A[m,k] x
/// B[k,n]`, i32 accumulation. Per column panel, unpacks [`UNPACK_KC`]
/// panel rows at a time into a stack i8 block and runs the i8 register
/// tile over it; per output element the products still accumulate in
/// ascending k (block partial sums added in block order), so the result
/// is bit-identical to [`matmul::gemm_i8_i32`] over the widened values.
pub fn gemm_i4_packed(a: &[i8], bp: &PackedB4, m: usize, c: &mut [i32]) {
    match bp.cfg.nr {
        4 => gemm_i4_packed_tile::<4>(a, bp, m, c, 4),
        8 => gemm_i4_packed_tile::<8>(a, bp, m, c, 8),
        16 => gemm_i4_packed_tile::<16>(a, bp, m, c, 16),
        nr => gemm_i4_packed_tile::<GEMM_NR_MAX>(a, bp, m, c, nr),
    }
}

fn gemm_i4_packed_tile<const NR_CAP: usize>(
    a: &[i8],
    bp: &PackedB4,
    m: usize,
    c: &mut [i32],
    nr: usize,
) {
    let (k, n) = (bp.k, bp.n);
    debug_assert_eq!(nr, bp.cfg.nr);
    debug_assert!(nr > 0 && nr <= NR_CAP && nr % 2 == 0);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    let kc_blk = bp.cfg.kc.clamp(1, UNPACK_KC);
    let row_bytes = nr / 2;
    let np = n.div_ceil(nr);
    let mut unp = [0i8; UNPACK_KC * GEMM_NR_MAX];
    for jp in 0..np {
        let j0 = jp * nr;
        let jw = nr.min(n - j0);
        let panel = &bp.data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
        for i in 0..m {
            let base = i * n + j0;
            c[base..base + jw].fill(0);
        }
        let mut kb = 0;
        while kb < k {
            let kc = kc_blk.min(k - kb);
            // Unpack this k-block of the panel once for every row tile.
            for kk in 0..kc {
                let prow = &panel[(kb + kk) * row_bytes..(kb + kk + 1) * row_bytes];
                let urow = &mut unp[kk * nr..(kk + 1) * nr];
                for (jj, &byte) in prow.iter().enumerate() {
                    urow[2 * jj] = unpack_nibble_lo(byte);
                    urow[2 * jj + 1] = unpack_nibble_hi(byte);
                }
            }
            let mut i0 = 0;
            while i0 < m {
                let iw = GEMM_MR.min(m - i0);
                let mut acc = [[0i32; NR_CAP]; GEMM_MR];
                if nr == NR_CAP {
                    for kk in 0..kc {
                        let brow = &unp[kk * NR_CAP..(kk + 1) * NR_CAP];
                        for r in 0..iw {
                            let av = a[(i0 + r) * k + kb + kk] as i32;
                            for jj in 0..NR_CAP {
                                acc[r][jj] += av * brow[jj] as i32;
                            }
                        }
                    }
                } else {
                    for kk in 0..kc {
                        let brow = &unp[kk * nr..(kk + 1) * nr];
                        for r in 0..iw {
                            let av = a[(i0 + r) * k + kb + kk] as i32;
                            for (jj, &bv) in brow.iter().enumerate() {
                                acc[r][jj] += av * bv as i32;
                            }
                        }
                    }
                }
                for r in 0..iw {
                    let base = (i0 + r) * n + j0;
                    for (cv, av) in c[base..base + jw].iter_mut().zip(&acc[r][..jw]) {
                        *cv += av;
                    }
                }
                i0 += GEMM_MR;
            }
            kb += kc;
        }
    }
}

/// [`gemm_i4_packed`] through a plan-selected ISA. The SIMD twins are
/// written for the default 8-lane panel width (one nibble-packed panel
/// row = one 32-bit word = one 8-lane unpack); any other tuned width
/// runs the bit-identical scalar kernel, mirroring `matmul.rs`.
pub fn gemm_i4_packed_isa(isa: Isa, a: &[i8], bp: &PackedB4, m: usize, c: &mut [i32]) {
    if bp.cfg.nr != GEMM_NR {
        return gemm_i4_packed(a, bp, m, c);
    }
    match isa.normalized() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: normalized() verified the feature bit on this host.
        Isa::Avx2 => unsafe { x86::gemm_i4_packed_avx2(a, bp, m, c) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Isa::Sse41 => unsafe { x86::gemm_i4_packed_sse41(a, bp, m, c) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: normalized() admits Neon only on aarch64 hosts.
        Isa::Neon => unsafe { arm::gemm_i4_packed_neon(a, bp, m, c) },
        _ => gemm_i4_packed(a, bp, m, c),
    }
}

/// Row-parallel wrapper over [`gemm_i4_packed_isa`] (bit-exact: disjoint
/// row blocks, identical per-element accumulation order). Thresholds come
/// from the operand's (possibly tuned) config.
pub fn gemm_i4_packed_par_isa(
    pool: &ThreadPool,
    isa: Isa,
    a: &[i8],
    bp: &PackedB4,
    m: usize,
    c: &mut [i32],
) {
    let (k, n) = (bp.k, bp.n);
    let min_rows = bp.cfg.par_min_rows.max(1);
    if !worth_parallel(pool, m, k, n, min_rows, bp.cfg.par_min_work) {
        gemm_i4_packed_isa(isa, a, bp, m, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, min_rows, |row0, block| {
        let rows = block.len() / n;
        gemm_i4_packed_isa(isa, &a[row0 * k..(row0 + rows) * k], bp, rows, block);
    });
}

// --- int4 packed A (conv weights) -------------------------------------------

/// An `[m, k]` A operand (the conv weight matrix) nibble-packed at plan
/// time for [`gemm_i4_packed_a`]: plain row-major, each row
/// `ceil(k/2)` bytes (low nibble = even k), rows independently
/// byte-aligned so the ragged k tail pads within its own row. `None`
/// when any value leaves `[-8, 7]`.
pub struct PackedA4 {
    data: Vec<u8>,
    pub m: usize,
    pub k: usize,
    /// Tile config carried for the runtime thresholds (the layout itself
    /// is row-major, not tiled).
    pub cfg: GemmConfig,
}

impl PackedA4 {
    pub fn pack(aw: &[i32], m: usize, k: usize) -> Option<PackedA4> {
        PackedA4::pack_with(aw, m, k, GemmConfig::DEFAULT)
    }

    pub fn pack_with(aw: &[i32], m: usize, k: usize, cfg: GemmConfig) -> Option<PackedA4> {
        debug_assert_eq!(aw.len(), m * k);
        if aw.iter().any(|&v| !(-8..=7).contains(&v)) {
            return None;
        }
        let row_bytes = k.div_ceil(2);
        let mut data = vec![0u8; m * row_bytes];
        for i in 0..m {
            for kk in 0..k {
                let v = aw[i * k + kk] as i8;
                let byte = &mut data[i * row_bytes + kk / 2];
                *byte = if kk % 2 == 0 {
                    pack_nibbles(v, unpack_nibble_hi(*byte))
                } else {
                    pack_nibbles(unpack_nibble_lo(*byte), v)
                };
            }
        }
        Some(PackedA4 { data, m, k, cfg })
    }

    /// Bytes held by the packed rows (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// GEMM against a nibble-packed A and a runtime row-major i8 B (the conv
/// im2col columns): `C[m,n] = A[m,k] x B[k,n]`. Unpacks [`GEMM_MR`] weight
/// rows x [`UNPACK_KC`] k at a time into a stack block, then streams the B
/// rows exactly like the widened kernel — ascending k per output element,
/// bit-identical to the naive loop.
pub fn gemm_i4_packed_a(ap: &PackedA4, b: &[i8], n: usize, c: &mut [i32]) {
    let (m, k) = (ap.m, ap.k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let row_bytes = k.div_ceil(2);
    c.fill(0);
    let mut unp = [0i8; GEMM_MR * UNPACK_KC];
    let mut i0 = 0;
    while i0 < m {
        let iw = GEMM_MR.min(m - i0);
        let mut kb = 0;
        while kb < k {
            let kc = UNPACK_KC.min(k - kb);
            for r in 0..iw {
                let prow = &ap.data[(i0 + r) * row_bytes..(i0 + r + 1) * row_bytes];
                for kk in 0..kc {
                    let byte = prow[(kb + kk) / 2];
                    unp[r * UNPACK_KC + kk] = if (kb + kk) % 2 == 0 {
                        unpack_nibble_lo(byte)
                    } else {
                        unpack_nibble_hi(byte)
                    };
                }
            }
            for kk in 0..kc {
                let brow = &b[(kb + kk) * n..(kb + kk + 1) * n];
                for r in 0..iw {
                    let av = unp[r * UNPACK_KC + kk] as i32;
                    if av == 0 {
                        continue;
                    }
                    let crow = &mut c[(i0 + r) * n..(i0 + r + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv as i32;
                    }
                }
            }
            kb += kc;
        }
        i0 += GEMM_MR;
    }
}

/// [`gemm_i4_packed_a`] through a plan-selected ISA. The row-major
/// nibble layout has no tile-width parameter, so every config reaches
/// the SIMD bodies (the ragged n tail is scalar inside them).
pub fn gemm_i4_packed_a_isa(isa: Isa, ap: &PackedA4, b: &[i8], n: usize, c: &mut [i32]) {
    match isa.normalized() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: normalized() verified the feature bit on this host.
        Isa::Avx2 => unsafe { x86::gemm_i4_packed_a_avx2(ap, b, n, c) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Isa::Sse41 => unsafe { x86::gemm_i4_packed_a_sse41(ap, b, n, c) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: normalized() admits Neon only on aarch64 hosts.
        Isa::Neon => unsafe { arm::gemm_i4_packed_a_neon(ap, b, n, c) },
        _ => gemm_i4_packed_a(ap, b, n, c),
    }
}

// --- packed activations (fused-chain A side) --------------------------------

/// Pack `m` rows of i8 values (each already saturated to `[-8, 7]` by a
/// narrow quantize epilogue) into row-major nibble rows — the activation
/// twin of [`PackedA4::pack`], producing the layout [`gemm_i4a_bytes`]
/// consumes. Rows are independently byte-aligned (`ceil(n/2)` bytes, low
/// nibble = even column); the caller guarantees the range at plan time
/// (the producing epilogue's `QType` admits int4), so packing is
/// infallible here.
pub fn pack_nibble_rows(src: &[i8], m: usize, n: usize, out: &mut Vec<u8>) {
    debug_assert_eq!(src.len(), m * n);
    debug_assert!(src.iter().all(|&v| (-8..=7).contains(&v)));
    let row_bytes = n.div_ceil(2);
    out.clear();
    out.resize(m * row_bytes, 0);
    for i in 0..m {
        let row = &src[i * n..(i + 1) * n];
        let orow = &mut out[i * row_bytes..(i + 1) * row_bytes];
        for (j, &v) in row.iter().enumerate() {
            orow[j / 2] |= ((v as u8) & 0x0f) << (4 * (j % 2));
        }
    }
}

/// GEMM with nibble-packed *activation* rows (from [`pack_nibble_rows`])
/// against the widened i32 weight matrix: `C[m,n] = A[m,k] x B[k,n]`.
/// This is the consumer side of a packed-activation fused pair — the
/// producing stage never materializes the i8 container for the edge, so
/// the unpack-repack round trip between fused stages disappears. Each
/// product is exact in i32 and k ascends per output element, so results
/// are bit-identical to the widened path over the same values.
pub fn gemm_i4a_bytes(a_bytes: &[u8], m: usize, k: usize, bw: &[i32], n: usize, c: &mut [i32]) {
    let row_bytes = k.div_ceil(2);
    debug_assert_eq!(a_bytes.len(), m * row_bytes);
    debug_assert_eq!(bw.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0);
    for i in 0..m {
        let arow = &a_bytes[i * row_bytes..(i + 1) * row_bytes];
        for kk in 0..k {
            let byte = arow[kk / 2];
            let av = if kk % 2 == 0 {
                unpack_nibble_lo(byte)
            } else {
                unpack_nibble_hi(byte)
            } as i32;
            if av == 0 {
                continue;
            }
            let brow = &bw[kk * n..(kk + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// [`gemm_i4a_bytes`] through the plan-selected ISA seam. Only the AVX2
/// body is vectorized today (the B rows are already i32, so the axpy
/// auto-vectorizes well on the 128-bit targets); everything else runs
/// the bit-identical scalar kernel.
pub fn gemm_i4a_bytes_isa(
    isa: Isa,
    a_bytes: &[u8],
    m: usize,
    k: usize,
    bw: &[i32],
    n: usize,
    c: &mut [i32],
) {
    match isa.normalized() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: normalized() verified the feature bit on this host.
        Isa::Avx2 => unsafe { x86::gemm_i4a_bytes_avx2(a_bytes, m, k, bw, n, c) },
        _ => gemm_i4a_bytes(a_bytes, m, k, bw, n, c),
    }
}

/// Row-parallel wrapper over [`gemm_i4a_bytes_isa`] (disjoint row
/// blocks; default thresholds — packed activations carry no tuned
/// config).
pub fn gemm_i4a_bytes_par_isa(
    pool: &ThreadPool,
    isa: Isa,
    a_bytes: &[u8],
    m: usize,
    k: usize,
    bw: &[i32],
    n: usize,
    c: &mut [i32],
) {
    let row_bytes = k.div_ceil(2);
    if !worth_parallel(
        pool,
        m,
        k,
        n,
        matmul::GEMM_PAR_MIN_ROWS,
        matmul::GEMM_PAR_MIN_WORK,
    ) {
        gemm_i4a_bytes_isa(isa, a_bytes, m, k, bw, n, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, matmul::GEMM_PAR_MIN_ROWS, |row0, block| {
        let rows = block.len() / n;
        gemm_i4a_bytes_isa(
            isa,
            &a_bytes[row0 * row_bytes..(row0 + rows) * row_bytes],
            rows,
            k,
            bw,
            n,
            block,
        );
    });
}

// --- bipolar bit packing ----------------------------------------------------

/// Words of 64 bit-packed values covering `k`.
#[inline]
pub fn bit_words(k: usize) -> usize {
    k.div_ceil(64)
}

/// Pack `m` rows of ±1 i8 values into bit rows (bit set ⇔ +1), 64 per
/// i64 word, `bit_words(k)` words per row, tail bits zero. Appends to
/// `out` (callers pass a cleared recycled buffer) and returns `false` —
/// leaving `out` in an unspecified state — if any value is not ±1: the
/// runtime gate the fused kernels use to fall back to the widened path.
pub fn pack_bits_rows(a: &[i8], m: usize, k: usize, out: &mut Vec<i64>) -> bool {
    debug_assert_eq!(a.len(), m * k);
    let words = bit_words(k);
    out.reserve(m * words);
    for i in 0..m {
        let row = &a[i * k..(i + 1) * k];
        for wchunk in row.chunks(64) {
            let mut w = 0u64;
            for (t, &v) in wchunk.iter().enumerate() {
                match v {
                    1 => w |= 1 << t,
                    -1 => {}
                    _ => return false,
                }
            }
            out.push(w as i64);
        }
    }
    true
}

/// Pack the columns of a row-major `[k, n]` ±1 i8 matrix into bit
/// columns (`bit_words(k)` words per column). Same contract as
/// [`pack_bits_rows`].
pub fn pack_bits_cols(b: &[i8], k: usize, n: usize, out: &mut Vec<i64>) -> bool {
    debug_assert_eq!(b.len(), k * n);
    let words = bit_words(k);
    let base = out.len();
    out.resize(base + n * words, 0);
    for kk in 0..k {
        let (w, t) = (kk / 64, kk % 64);
        let brow = &b[kk * n..(kk + 1) * n];
        for (j, &v) in brow.iter().enumerate() {
            match v {
                1 => out[base + j * words + w] |= 1 << t,
                -1 => {}
                _ => return false,
            }
        }
    }
    true
}

/// A `[k, n]` bipolar B operand bit-packed at plan time for
/// [`gemm_xnor`]: column-major bit columns so each output element XORs
/// two contiguous word runs. `None` unless every widened value is ±1.
pub struct BitPackedB {
    data: Vec<i64>,
    pub k: usize,
    pub n: usize,
}

impl BitPackedB {
    pub fn pack(bw: &[i32], k: usize, n: usize) -> Option<BitPackedB> {
        debug_assert_eq!(bw.len(), k * n);
        if bw.iter().any(|&v| v != 1 && v != -1) {
            return None;
        }
        let words = bit_words(k);
        let mut data = vec![0i64; n * words];
        for kk in 0..k {
            let (w, t) = (kk / 64, kk % 64);
            for j in 0..n {
                if bw[kk * n + j] == 1 {
                    data[j * words + w] |= 1 << t;
                }
            }
        }
        Some(BitPackedB { data, k, n })
    }

    /// Bytes held by the packed bit columns (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len() * 8
    }
}

/// An `[m, k]` bipolar A operand (conv weights) bit-packed at plan time
/// for [`gemm_xnor_a`]: row-major bit rows. `None` unless all ±1.
pub struct BitPackedA {
    data: Vec<i64>,
    pub m: usize,
    pub k: usize,
}

impl BitPackedA {
    pub fn pack(aw: &[i32], m: usize, k: usize) -> Option<BitPackedA> {
        debug_assert_eq!(aw.len(), m * k);
        if aw.iter().any(|&v| v != 1 && v != -1) {
            return None;
        }
        let mut data = Vec::new();
        let packed: Vec<i8> = aw.iter().map(|&v| v as i8).collect();
        let ok = pack_bits_rows(&packed, m, k, &mut data);
        debug_assert!(ok);
        Some(BitPackedA { data, m, k })
    }

    /// Bytes held by the packed bit rows (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len() * 8
    }
}

/// XNOR-popcount GEMM: bit-packed ±1 activations (rows, from
/// [`pack_bits_rows`]) x bit-packed ±1 weights. For each element,
/// `dot = k − 2·popcount(a XOR b)` — exact over i32, so bit-identical to
/// the widened ±1 triple loop.
pub fn gemm_xnor(a_bits: &[i64], bb: &BitPackedB, m: usize, c: &mut [i32]) {
    let words = bit_words(bb.k);
    let (k, n) = (bb.k as i32, bb.n);
    debug_assert_eq!(a_bits.len(), m * words);
    debug_assert_eq!(c.len(), m * bb.n);
    for i in 0..m {
        let arow = &a_bits[i * words..(i + 1) * words];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let bcol = &bb.data[j * words..(j + 1) * words];
            let mut diff = 0u32;
            for (aw, bw) in arow.iter().zip(bcol) {
                diff += (aw ^ bw).count_ones();
            }
            *cv = k - 2 * diff as i32;
        }
    }
}

/// [`gemm_xnor`] through a plan-selected ISA: AVX2 runs the `vpshufb`
/// nibble-LUT popcount over 256-bit chunks, NEON `vcntq_u8` over 128-bit
/// chunks, both with a scalar `count_ones` word tail. SSE4.1 has no
/// cheap vector popcount, so it keeps the scalar kernel (whose
/// `count_ones` already lowers to the native `popcnt` instruction).
pub fn gemm_xnor_isa(isa: Isa, a_bits: &[i64], bb: &BitPackedB, m: usize, c: &mut [i32]) {
    match isa.normalized() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: normalized() verified the feature bit on this host.
        Isa::Avx2 => unsafe { x86::gemm_xnor_avx2(a_bits, bb, m, c) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: normalized() admits Neon only on aarch64 hosts.
        Isa::Neon => unsafe { arm::gemm_xnor_neon(a_bits, bb, m, c) },
        _ => gemm_xnor(a_bits, bb, m, c),
    }
}

/// Row-parallel wrapper over [`gemm_xnor_isa`] (bit-exact: disjoint rows,
/// exact integer identity per element). Default thresholds — bit-packed
/// operands have no tuned config.
pub fn gemm_xnor_par_isa(
    pool: &ThreadPool,
    isa: Isa,
    a_bits: &[i64],
    bb: &BitPackedB,
    m: usize,
    c: &mut [i32],
) {
    let (k, n) = (bb.k, bb.n);
    let words = bit_words(k);
    if !worth_parallel(
        pool,
        m,
        k,
        n,
        matmul::GEMM_PAR_MIN_ROWS,
        matmul::GEMM_PAR_MIN_WORK,
    ) {
        gemm_xnor_isa(isa, a_bits, bb, m, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, matmul::GEMM_PAR_MIN_ROWS, |row0, block| {
        let rows = block.len() / n;
        gemm_xnor_isa(
            isa,
            &a_bits[row0 * words..(row0 + rows) * words],
            bb,
            rows,
            block,
        );
    });
}

/// XNOR-popcount GEMM with bit-packed A rows (conv weights) against
/// bit-packed B columns built at run time from the im2col buffer
/// ([`pack_bits_cols`]).
pub fn gemm_xnor_a(ap: &BitPackedA, b_bits: &[i64], n: usize, c: &mut [i32]) {
    let words = bit_words(ap.k);
    let (m, k) = (ap.m, ap.k as i32);
    debug_assert_eq!(b_bits.len(), n * words);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &ap.data[i * words..(i + 1) * words];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let bcol = &b_bits[j * words..(j + 1) * words];
            let mut diff = 0u32;
            for (aw, bw) in arow.iter().zip(bcol) {
                diff += (aw ^ bw).count_ones();
            }
            *cv = k - 2 * diff as i32;
        }
    }
}

/// [`gemm_xnor_a`] through a plan-selected ISA (same popcount bodies as
/// [`gemm_xnor_isa`]).
pub fn gemm_xnor_a_isa(isa: Isa, ap: &BitPackedA, b_bits: &[i64], n: usize, c: &mut [i32]) {
    match isa.normalized() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: normalized() verified the feature bit on this host.
        Isa::Avx2 => unsafe { x86::gemm_xnor_a_avx2(ap, b_bits, n, c) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: normalized() admits Neon only on aarch64 hosts.
        Isa::Neon => unsafe { arm::gemm_xnor_a_neon(ap, b_bits, n, c) },
        _ => gemm_xnor_a(ap, b_bits, n, c),
    }
}

// --- int2 (crumb) and int3 (tribble) packed storage -------------------------

/// Sign-decode an offset-encoded 2-bit crumb (`[0,3]` → `[-2,1]`).
#[inline]
fn decode_crumb(bits: u8) -> i8 {
    (bits & 0b11) as i8 - 2
}

/// Sign-decode an offset-encoded 3-bit tribble (`[0,7]` → `[-4,3]`).
#[inline]
fn decode_tribble(bits: u8) -> i8 {
    (bits & 0b111) as i8 - 4
}

/// A `[k, n]` B operand crumb-packed (int2) at plan time for
/// [`gemm_i2_packed`]: the [`PackedB4`] column-panel layout at a quarter
/// of the i8 bytes — each panel row of `nr` values is `nr/4` bytes, four
/// offset-encoded crumbs per byte, little-endian within the byte.
/// Packing refuses (`None`) when any widened value leaves `[-2, 1]` or
/// the tile width is not a multiple of 4 (panel rows must stay
/// byte-aligned); callers then keep the wider kernels.
pub struct PackedB2 {
    data: Vec<u8>,
    pub k: usize,
    pub n: usize,
    /// Tile config this operand was packed with.
    pub cfg: GemmConfig,
}

impl PackedB2 {
    pub fn pack(bw: &[i32], k: usize, n: usize) -> Option<PackedB2> {
        PackedB2::pack_with(bw, k, n, GemmConfig::DEFAULT)
    }

    pub fn pack_with(bw: &[i32], k: usize, n: usize, cfg: GemmConfig) -> Option<PackedB2> {
        debug_assert_eq!(bw.len(), k * n);
        assert!(
            cfg.nr > 0 && cfg.nr <= GEMM_NR_MAX,
            "bad panel width {}",
            cfg.nr
        );
        if cfg.nr % 4 != 0 || bw.iter().any(|&v| !(-2..=1).contains(&v)) {
            return None;
        }
        let nr = cfg.nr;
        let row_bytes = nr / 4;
        let np = n.div_ceil(nr);
        // Zero fill = crumb 0 = decoded -2 for padded lanes; those lanes
        // are never read back (jw masks them), matching PackedB4's
        // unread zero-nibble padding.
        let mut data = vec![0u8; np * k * row_bytes];
        for jp in 0..np {
            let j0 = jp * nr;
            let jw = nr.min(n - j0);
            let panel = &mut data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
            for kk in 0..k {
                for jj in 0..jw {
                    let enc = (bw[kk * n + j0 + jj] + 2) as u8;
                    panel[kk * row_bytes + jj / 4] |= enc << (2 * (jj % 4));
                }
            }
        }
        Some(PackedB2 { data, k, n, cfg })
    }

    /// Bytes held by the packed panels (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// A `[k, n]` B operand tribble-packed (int3) at plan time for
/// [`gemm_i3_packed`]: same column panels, each panel row a
/// little-endian bitstream of `nr` 3-bit offset-encoded fields
/// (`nr*3/8` bytes). Refuses widths where the row is not byte-aligned
/// (`nr*3 % 8 != 0` — so nr 8 and 16 pack, nr 4 falls back) or values
/// outside `[-4, 3]`.
pub struct PackedB3 {
    data: Vec<u8>,
    pub k: usize,
    pub n: usize,
    /// Tile config this operand was packed with.
    pub cfg: GemmConfig,
}

impl PackedB3 {
    pub fn pack(bw: &[i32], k: usize, n: usize) -> Option<PackedB3> {
        PackedB3::pack_with(bw, k, n, GemmConfig::DEFAULT)
    }

    pub fn pack_with(bw: &[i32], k: usize, n: usize, cfg: GemmConfig) -> Option<PackedB3> {
        debug_assert_eq!(bw.len(), k * n);
        assert!(
            cfg.nr > 0 && cfg.nr <= GEMM_NR_MAX,
            "bad panel width {}",
            cfg.nr
        );
        if cfg.nr * 3 % 8 != 0 || bw.iter().any(|&v| !(-4..=3).contains(&v)) {
            return None;
        }
        let nr = cfg.nr;
        let row_bytes = nr * 3 / 8;
        debug_assert!(row_bytes <= 8, "nr <= GEMM_NR_MAX keeps a row in one u64");
        let np = n.div_ceil(nr);
        let mut data = vec![0u8; np * k * row_bytes];
        for jp in 0..np {
            let j0 = jp * nr;
            let jw = nr.min(n - j0);
            let panel = &mut data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
            for kk in 0..k {
                let mut word = 0u64;
                for jj in 0..jw {
                    let enc = (bw[kk * n + j0 + jj] + 4) as u64;
                    word |= enc << (3 * jj);
                }
                let row = &mut panel[kk * row_bytes..(kk + 1) * row_bytes];
                row.copy_from_slice(&word.to_le_bytes()[..row_bytes]);
            }
        }
        Some(PackedB3 { data, k, n, cfg })
    }

    /// Bytes held by the packed panels (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// i8-activation GEMM against a crumb-packed B: decodes each panel row
/// to i8 on the fly and accumulates exactly like the int4 scalar kernel
/// (ascending k per output element, exact i32 products) — bit-identical
/// to the widened triple loop. Scalar reference body; the `_isa` seam
/// below is where SIMD twins will land (module note).
pub fn gemm_i2_packed(a: &[i8], bp: &PackedB2, m: usize, c: &mut [i32]) {
    let (k, n) = (bp.k, bp.n);
    let nr = bp.cfg.nr;
    let row_bytes = nr / 4;
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    let np = n.div_ceil(nr);
    let mut vals = [0i8; GEMM_NR_MAX];
    for jp in 0..np {
        let j0 = jp * nr;
        let jw = nr.min(n - j0);
        let panel = &bp.data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
        for i in 0..m {
            c[i * n + j0..i * n + j0 + jw].fill(0);
        }
        for kk in 0..k {
            let prow = &panel[kk * row_bytes..(kk + 1) * row_bytes];
            for jj in 0..jw {
                vals[jj] = decode_crumb(prow[jj / 4] >> (2 * (jj % 4)));
            }
            for i in 0..m {
                let av = a[i * k + kk] as i32;
                if av == 0 {
                    continue;
                }
                let crow = &mut c[i * n + j0..i * n + j0 + jw];
                for (cv, &bv) in crow.iter_mut().zip(&vals[..jw]) {
                    *cv += av * bv as i32;
                }
            }
        }
    }
}

/// i8-activation GEMM against a tribble-packed B (same structure and
/// bit-exactness argument as [`gemm_i2_packed`]).
pub fn gemm_i3_packed(a: &[i8], bp: &PackedB3, m: usize, c: &mut [i32]) {
    let (k, n) = (bp.k, bp.n);
    let nr = bp.cfg.nr;
    let row_bytes = nr * 3 / 8;
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    let np = n.div_ceil(nr);
    let mut vals = [0i8; GEMM_NR_MAX];
    for jp in 0..np {
        let j0 = jp * nr;
        let jw = nr.min(n - j0);
        let panel = &bp.data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
        for i in 0..m {
            c[i * n + j0..i * n + j0 + jw].fill(0);
        }
        for kk in 0..k {
            let prow = &panel[kk * row_bytes..(kk + 1) * row_bytes];
            let mut word = [0u8; 8];
            word[..row_bytes].copy_from_slice(prow);
            let word = u64::from_le_bytes(word);
            for jj in 0..jw {
                vals[jj] = decode_tribble((word >> (3 * jj)) as u8);
            }
            for i in 0..m {
                let av = a[i * k + kk] as i32;
                if av == 0 {
                    continue;
                }
                let crow = &mut c[i * n + j0..i * n + j0 + jw];
                for (cv, &bv) in crow.iter_mut().zip(&vals[..jw]) {
                    *cv += av * bv as i32;
                }
            }
        }
    }
}

/// [`gemm_i2_packed`] through the plan-selected ISA seam (scalar body
/// today; SIMD twins pending — the seam keeps call sites and the tuner
/// stable when they land, exactly as the int4 wrappers did pre-PR 10).
pub fn gemm_i2_packed_isa(isa: Isa, a: &[i8], bp: &PackedB2, m: usize, c: &mut [i32]) {
    let _ = isa.normalized();
    gemm_i2_packed(a, bp, m, c);
}

/// [`gemm_i3_packed`] through the plan-selected ISA seam (scalar body
/// today; see [`gemm_i2_packed_isa`]).
pub fn gemm_i3_packed_isa(isa: Isa, a: &[i8], bp: &PackedB3, m: usize, c: &mut [i32]) {
    let _ = isa.normalized();
    gemm_i3_packed(a, bp, m, c);
}

/// Row-parallel wrapper over [`gemm_i2_packed_isa`] (bit-exact: disjoint
/// row blocks; thresholds from the operand's config).
pub fn gemm_i2_packed_par_isa(
    pool: &ThreadPool,
    isa: Isa,
    a: &[i8],
    bp: &PackedB2,
    m: usize,
    c: &mut [i32],
) {
    let (k, n) = (bp.k, bp.n);
    let min_rows = bp.cfg.par_min_rows.max(1);
    if !worth_parallel(pool, m, k, n, min_rows, bp.cfg.par_min_work) {
        gemm_i2_packed_isa(isa, a, bp, m, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, min_rows, |row0, block| {
        let rows = block.len() / n;
        gemm_i2_packed_isa(isa, &a[row0 * k..(row0 + rows) * k], bp, rows, block);
    });
}

/// Row-parallel wrapper over [`gemm_i3_packed_isa`].
pub fn gemm_i3_packed_par_isa(
    pool: &ThreadPool,
    isa: Isa,
    a: &[i8],
    bp: &PackedB3,
    m: usize,
    c: &mut [i32],
) {
    let (k, n) = (bp.k, bp.n);
    let min_rows = bp.cfg.par_min_rows.max(1);
    if !worth_parallel(pool, m, k, n, min_rows, bp.cfg.par_min_work) {
        gemm_i3_packed_isa(isa, a, bp, m, c);
        return;
    }
    parallel::par_row_chunks_mut(pool, c, m, n, min_rows, |row0, block| {
        let rows = block.len() / n;
        gemm_i3_packed_isa(isa, &a[row0 * k..(row0 + rows) * k], bp, rows, block);
    });
}

/// An `[m, k]` A operand (conv weights) crumb-packed at plan time for
/// [`gemm_i2_packed_a`]: plain row-major like [`PackedA4`], each row
/// `ceil(k/4)` bytes. `None` when any value leaves `[-2, 1]`.
pub struct PackedA2 {
    data: Vec<u8>,
    pub m: usize,
    pub k: usize,
    /// Tile config carried for the runtime thresholds.
    pub cfg: GemmConfig,
}

impl PackedA2 {
    pub fn pack(aw: &[i32], m: usize, k: usize) -> Option<PackedA2> {
        PackedA2::pack_with(aw, m, k, GemmConfig::DEFAULT)
    }

    pub fn pack_with(aw: &[i32], m: usize, k: usize, cfg: GemmConfig) -> Option<PackedA2> {
        debug_assert_eq!(aw.len(), m * k);
        if aw.iter().any(|&v| !(-2..=1).contains(&v)) {
            return None;
        }
        let row_bytes = k.div_ceil(4);
        let mut data = vec![0u8; m * row_bytes];
        for i in 0..m {
            for kk in 0..k {
                let enc = (aw[i * k + kk] + 2) as u8;
                data[i * row_bytes + kk / 4] |= enc << (2 * (kk % 4));
            }
        }
        Some(PackedA2 { data, m, k, cfg })
    }

    /// Bytes held by the packed rows (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// An `[m, k]` A operand tribble-packed at plan time for
/// [`gemm_i3_packed_a`]: row-major little-endian 3-bit bitstream per
/// row (`ceil(3k/8)` bytes; fields may straddle byte boundaries within
/// a row, never across rows). `None` when any value leaves `[-4, 3]`.
pub struct PackedA3 {
    data: Vec<u8>,
    pub m: usize,
    pub k: usize,
    /// Tile config carried for the runtime thresholds.
    pub cfg: GemmConfig,
}

impl PackedA3 {
    pub fn pack(aw: &[i32], m: usize, k: usize) -> Option<PackedA3> {
        PackedA3::pack_with(aw, m, k, GemmConfig::DEFAULT)
    }

    pub fn pack_with(aw: &[i32], m: usize, k: usize, cfg: GemmConfig) -> Option<PackedA3> {
        debug_assert_eq!(aw.len(), m * k);
        if aw.iter().any(|&v| !(-4..=3).contains(&v)) {
            return None;
        }
        let row_bytes = (3 * k).div_ceil(8);
        let mut data = vec![0u8; m * row_bytes];
        for i in 0..m {
            let row = &mut data[i * row_bytes..(i + 1) * row_bytes];
            for kk in 0..k {
                let enc = (aw[i * k + kk] + 4) as u16;
                let bit = 3 * kk;
                let (byte, off) = (bit / 8, bit % 8);
                row[byte] |= (enc << off) as u8;
                if off > 5 {
                    row[byte + 1] |= (enc >> (8 - off)) as u8;
                }
            }
        }
        Some(PackedA3 { data, m, k, cfg })
    }

    /// Decode one weight value (exposed for the kernels and tests).
    #[inline]
    fn get(&self, i: usize, kk: usize) -> i8 {
        let row_bytes = (3 * self.k).div_ceil(8);
        let row = &self.data[i * row_bytes..(i + 1) * row_bytes];
        let bit = 3 * kk;
        let (byte, off) = (bit / 8, bit % 8);
        let lo = (row[byte] >> off) as u16;
        let hi = if off > 5 && byte + 1 < row_bytes {
            (row[byte + 1] as u16) << (8 - off)
        } else {
            0
        };
        decode_tribble((lo | hi) as u8)
    }

    /// Bytes held by the packed rows (plan-memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// GEMM against a crumb-packed A and a runtime row-major i8 B (the conv
/// im2col columns) — the int2 twin of [`gemm_i4_packed_a`]: per weight
/// an exact i32 product, k ascending per output element, bit-identical
/// to the widened loop. Scalar reference body behind the `_isa` seam.
pub fn gemm_i2_packed_a(ap: &PackedA2, b: &[i8], n: usize, c: &mut [i32]) {
    let (m, k) = (ap.m, ap.k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let row_bytes = k.div_ceil(4);
    c.fill(0);
    for i in 0..m {
        let arow = &ap.data[i * row_bytes..(i + 1) * row_bytes];
        for kk in 0..k {
            let av = decode_crumb(arow[kk / 4] >> (2 * (kk % 4))) as i32;
            if av == 0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv as i32;
            }
        }
    }
}

/// GEMM against a tribble-packed A (int3 twin of [`gemm_i4_packed_a`]).
pub fn gemm_i3_packed_a(ap: &PackedA3, b: &[i8], n: usize, c: &mut [i32]) {
    let (m, k) = (ap.m, ap.k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0);
    for i in 0..m {
        for kk in 0..k {
            let av = ap.get(i, kk) as i32;
            if av == 0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv as i32;
            }
        }
    }
}

/// [`gemm_i2_packed_a`] through the plan-selected ISA seam (scalar body
/// today; SIMD twins pending, same as the B side).
pub fn gemm_i2_packed_a_isa(isa: Isa, ap: &PackedA2, b: &[i8], n: usize, c: &mut [i32]) {
    let _ = isa.normalized();
    gemm_i2_packed_a(ap, b, n, c);
}

/// [`gemm_i3_packed_a`] through the plan-selected ISA seam (scalar body
/// today).
pub fn gemm_i3_packed_a_isa(isa: Isa, ap: &PackedA3, b: &[i8], n: usize, c: &mut [i32]) {
    let _ = isa.normalized();
    gemm_i3_packed_a(ap, b, n, c);
}

// --- width-dispatched plan-time weight storage ------------------------------

/// Plan-time baked B-side weights at whatever width the optimizer
/// selected (see `opt::select_fc_width`): the i8 panels every chain gets
/// today, nibble panels when the weights fit int4, bit columns when they
/// are bipolar. The fused FC kernel dispatches on the variant at run
/// time and falls back to the widened-i32 path whenever the activations
/// don't qualify (non-i8, nonzero zero point, non-±1 for XNOR) — so the
/// narrow variants can never change results, only memory traffic.
pub enum PackedWeights {
    I8(matmul::PackedB),
    I4(PackedB4),
    I3(PackedB3),
    I2(PackedB2),
    Bipolar(BitPackedB),
}

impl PackedWeights {
    /// Bytes held by the baked storage (plan-memory accounting /
    /// `Kernel::baked_bytes`).
    pub fn bytes(&self) -> usize {
        match self {
            PackedWeights::I8(p) => p.bytes(),
            PackedWeights::I4(p) => p.bytes(),
            PackedWeights::I3(p) => p.bytes(),
            PackedWeights::I2(p) => p.bytes(),
            PackedWeights::Bipolar(p) => p.bytes(),
        }
    }

    /// Logical weight bits per value (8 / 4 / 3 / 2 / 1) — feeds the
    /// hwsim cost model's DRAM-traffic scaling and `plan_stats`.
    pub fn bits(&self) -> u8 {
        match self {
            PackedWeights::I8(_) => 8,
            PackedWeights::I4(_) => 4,
            PackedWeights::I3(_) => 3,
            PackedWeights::I2(_) => 2,
            PackedWeights::Bipolar(_) => 1,
        }
    }

    pub fn width_name(&self) -> &'static str {
        match self {
            PackedWeights::I8(_) => "int8",
            PackedWeights::I4(_) => "int4",
            PackedWeights::I3(_) => "int3",
            PackedWeights::I2(_) => "int2",
            PackedWeights::Bipolar(_) => "bipolar",
        }
    }
}

/// Plan-time baked A-side (conv) weights — the conv twin of
/// [`PackedWeights`].
pub enum PackedConvWeights {
    I8(matmul::PackedA),
    I4(PackedA4),
    I3(PackedA3),
    I2(PackedA2),
    Bipolar(BitPackedA),
}

impl PackedConvWeights {
    pub fn bytes(&self) -> usize {
        match self {
            PackedConvWeights::I8(p) => p.bytes(),
            PackedConvWeights::I4(p) => p.bytes(),
            PackedConvWeights::I3(p) => p.bytes(),
            PackedConvWeights::I2(p) => p.bytes(),
            PackedConvWeights::Bipolar(p) => p.bytes(),
        }
    }

    pub fn bits(&self) -> u8 {
        match self {
            PackedConvWeights::I8(_) => 8,
            PackedConvWeights::I4(_) => 4,
            PackedConvWeights::I3(_) => 3,
            PackedConvWeights::I2(_) => 2,
            PackedConvWeights::Bipolar(_) => 1,
        }
    }

    pub fn width_name(&self) -> &'static str {
        match self {
            PackedConvWeights::I8(_) => "int8",
            PackedConvWeights::I4(_) => "int4",
            PackedConvWeights::I3(_) => "int3",
            PackedConvWeights::I2(_) => "int2",
            PackedConvWeights::Bipolar(_) => "bipolar",
        }
    }
}

/// Local copy of the packed kernels' pool-dispatch policy (the matmul
/// original is private; the thresholds mean the same thing here).
fn worth_parallel(
    pool: &ThreadPool,
    m: usize,
    k: usize,
    n: usize,
    min_rows: usize,
    min_work: usize,
) -> bool {
    pool.threads() > 1
        && parallel::allow_pool_dispatch()
        && m >= 2 * min_rows
        && m.saturating_mul(k).saturating_mul(n) >= min_work
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{BitPackedA, BitPackedB, PackedA4, PackedB4, GEMM_MR, GEMM_NR};
    #[allow(clippy::wildcard_imports)]
    use std::arch::x86_64::*;

    /// AVX2 twin of [`super::gemm_i4_packed`]: one nibble-packed panel
    /// row (4 bytes at nr = 8) is broadcast as a 32-bit word, each lane
    /// shifts its own nibble into place (`vpsrlvd`), masks, and
    /// sign-extends via `(x ^ 8) - 8` — all in 32-bit lanes, so every
    /// product is exact (no `vpmaddubsw` i16 saturation hazard) and the
    /// k-ascending accumulation matches the scalar kernel bit for bit.
    ///
    /// Safety: caller must have verified AVX2 (`Isa::normalized`). The
    /// 4-byte panel-row read is `panel[kk*4 .. kk*4+4]` with `kk < k`
    /// and `panel.len() == k*4` — always in bounds (safe slice read).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_i4_packed_avx2(a: &[i8], bp: &PackedB4, m: usize, c: &mut [i32]) {
        let (k, n) = (bp.k, bp.n);
        debug_assert_eq!(bp.cfg.nr, GEMM_NR);
        let row_bytes = GEMM_NR / 2;
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(c.len(), m * n);
        let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
        let maskf = _mm256_set1_epi32(0xf);
        let eight = _mm256_set1_epi32(8);
        let np = n.div_ceil(GEMM_NR);
        for jp in 0..np {
            let j0 = jp * GEMM_NR;
            let jw = GEMM_NR.min(n - j0);
            let panel = &bp.data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
            let mut i0 = 0;
            while i0 < m {
                let iw = GEMM_MR.min(m - i0);
                let mut acc = [_mm256_setzero_si256(); GEMM_MR];
                for kk in 0..k {
                    let w = u32::from_le_bytes(
                        panel[kk * row_bytes..kk * row_bytes + 4].try_into().unwrap(),
                    );
                    let nib = _mm256_and_si256(
                        _mm256_srlv_epi32(_mm256_set1_epi32(w as i32), shifts),
                        maskf,
                    );
                    let bv = _mm256_sub_epi32(_mm256_xor_si256(nib, eight), eight);
                    for r in 0..iw {
                        let av = _mm256_set1_epi32(a[(i0 + r) * k + kk] as i32);
                        acc[r] = _mm256_add_epi32(acc[r], _mm256_mullo_epi32(av, bv));
                    }
                }
                let mut tmp = [0i32; GEMM_NR];
                for r in 0..iw {
                    _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, acc[r]);
                    let base = (i0 + r) * n + j0;
                    c[base..base + jw].copy_from_slice(&tmp[..jw]);
                }
                i0 += GEMM_MR;
            }
        }
    }

    /// SSE4.1 twin of [`super::gemm_i4_packed`]: the 8-wide panel row as
    /// two 4-lane halves; nibbles are shifted/masked on the scalar side
    /// and sign-extended + multiplied in 32-bit vector lanes (`pmulld`).
    ///
    /// Safety: caller verified SSE4.1; read bounds as in the AVX2 twin.
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn gemm_i4_packed_sse41(a: &[i8], bp: &PackedB4, m: usize, c: &mut [i32]) {
        let (k, n) = (bp.k, bp.n);
        debug_assert_eq!(bp.cfg.nr, GEMM_NR);
        let row_bytes = GEMM_NR / 2;
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(c.len(), m * n);
        let eight = _mm_set1_epi32(8);
        let np = n.div_ceil(GEMM_NR);
        for jp in 0..np {
            let j0 = jp * GEMM_NR;
            let jw = GEMM_NR.min(n - j0);
            let panel = &bp.data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
            let mut i0 = 0;
            while i0 < m {
                let iw = GEMM_MR.min(m - i0);
                let mut acc = [[_mm_setzero_si128(); 2]; GEMM_MR];
                for kk in 0..k {
                    let w = u32::from_le_bytes(
                        panel[kk * row_bytes..kk * row_bytes + 4].try_into().unwrap(),
                    );
                    let lo = _mm_setr_epi32(
                        (w & 0xf) as i32,
                        ((w >> 4) & 0xf) as i32,
                        ((w >> 8) & 0xf) as i32,
                        ((w >> 12) & 0xf) as i32,
                    );
                    let hi = _mm_setr_epi32(
                        ((w >> 16) & 0xf) as i32,
                        ((w >> 20) & 0xf) as i32,
                        ((w >> 24) & 0xf) as i32,
                        ((w >> 28) & 0xf) as i32,
                    );
                    let blo = _mm_sub_epi32(_mm_xor_si128(lo, eight), eight);
                    let bhi = _mm_sub_epi32(_mm_xor_si128(hi, eight), eight);
                    for r in 0..iw {
                        let av = _mm_set1_epi32(a[(i0 + r) * k + kk] as i32);
                        acc[r][0] = _mm_add_epi32(acc[r][0], _mm_mullo_epi32(av, blo));
                        acc[r][1] = _mm_add_epi32(acc[r][1], _mm_mullo_epi32(av, bhi));
                    }
                }
                let mut tmp = [0i32; GEMM_NR];
                for r in 0..iw {
                    _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, acc[r][0]);
                    _mm_storeu_si128(tmp.as_mut_ptr().add(4) as *mut __m128i, acc[r][1]);
                    let base = (i0 + r) * n + j0;
                    c[base..base + jw].copy_from_slice(&tmp[..jw]);
                }
                i0 += GEMM_MR;
            }
        }
    }

    /// AVX2 twin of [`super::gemm_i4_packed_a`]: the weight nibble is
    /// decoded once per (row, k) on the scalar side (O(mk) work) and the
    /// O(mkn) axpy over the runtime B row runs in 8-wide i32 lanes
    /// (widening `vpmovsxbd` B load). Zero weights are skipped exactly
    /// like the scalar kernel (adding zero is the identity, so the skip
    /// cannot change bits).
    ///
    /// Safety: caller verified AVX2. The raw 8-byte B load reads
    /// `b[kk*n + j .. +8]` with `j + 8 <= n` — in bounds; the tail is
    /// scalar.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_i4_packed_a_avx2(ap: &PackedA4, b: &[i8], n: usize, c: &mut [i32]) {
        let (m, k) = (ap.m, ap.k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let row_bytes = k.div_ceil(2);
        c.fill(0);
        for i in 0..m {
            let arow = &ap.data[i * row_bytes..(i + 1) * row_bytes];
            for kk in 0..k {
                let byte = arow[kk / 2];
                let av = if kk % 2 == 0 {
                    super::unpack_nibble_lo(byte)
                } else {
                    super::unpack_nibble_hi(byte)
                } as i32;
                if av == 0 {
                    continue;
                }
                let avv = _mm256_set1_epi32(av);
                let brow = &b[kk * n..(kk + 1) * n];
                let crow = &mut c[i * n..(i + 1) * n];
                let mut j = 0;
                while j + 8 <= n {
                    let bv = _mm256_cvtepi8_epi32(_mm_loadl_epi64(
                        brow.as_ptr().add(j) as *const __m128i
                    ));
                    let cv = _mm256_loadu_si256(crow.as_ptr().add(j) as *const __m256i);
                    _mm256_storeu_si256(
                        crow.as_mut_ptr().add(j) as *mut __m256i,
                        _mm256_add_epi32(cv, _mm256_mullo_epi32(avv, bv)),
                    );
                    j += 8;
                }
                while j < n {
                    crow[j] += av * brow[j] as i32;
                    j += 1;
                }
            }
        }
    }

    /// SSE4.1 twin of [`super::gemm_i4_packed_a`] (4-wide axpy halves).
    ///
    /// Safety: caller verified SSE4.1; the raw 4-byte B load reads
    /// `b[kk*n + j .. +4]` with `j + 4 <= n`.
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn gemm_i4_packed_a_sse41(ap: &PackedA4, b: &[i8], n: usize, c: &mut [i32]) {
        let (m, k) = (ap.m, ap.k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let row_bytes = k.div_ceil(2);
        c.fill(0);
        for i in 0..m {
            let arow = &ap.data[i * row_bytes..(i + 1) * row_bytes];
            for kk in 0..k {
                let byte = arow[kk / 2];
                let av = if kk % 2 == 0 {
                    super::unpack_nibble_lo(byte)
                } else {
                    super::unpack_nibble_hi(byte)
                } as i32;
                if av == 0 {
                    continue;
                }
                let avv = _mm_set1_epi32(av);
                let brow = &b[kk * n..(kk + 1) * n];
                let crow = &mut c[i * n..(i + 1) * n];
                let mut j = 0;
                while j + 4 <= n {
                    // SAFETY: j + 4 <= n keeps the unaligned 4-byte read
                    // inside this B row.
                    let b4 = _mm_cvtsi32_si128(
                        (brow.as_ptr().add(j) as *const i32).read_unaligned(),
                    );
                    let bv = _mm_cvtepi8_epi32(b4);
                    let cv = _mm_loadu_si128(crow.as_ptr().add(j) as *const __m128i);
                    _mm_storeu_si128(
                        crow.as_mut_ptr().add(j) as *mut __m128i,
                        _mm_add_epi32(cv, _mm_mullo_epi32(avv, bv)),
                    );
                    j += 4;
                }
                while j < n {
                    crow[j] += av * brow[j] as i32;
                    j += 1;
                }
            }
        }
    }

    /// AVX2 twin of [`super::gemm_i4a_bytes`]: same scalar nibble decode
    /// per (row, k), vector axpy over the already-i32 weight row.
    ///
    /// Safety: caller verified AVX2; the 8-lane loads read
    /// `bw[kk*n + j .. +8]` / `c[i*n + j .. +8]` with `j + 8 <= n`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_i4a_bytes_avx2(
        a_bytes: &[u8],
        m: usize,
        k: usize,
        bw: &[i32],
        n: usize,
        c: &mut [i32],
    ) {
        let row_bytes = k.div_ceil(2);
        debug_assert_eq!(a_bytes.len(), m * row_bytes);
        debug_assert_eq!(bw.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        c.fill(0);
        for i in 0..m {
            let arow = &a_bytes[i * row_bytes..(i + 1) * row_bytes];
            for kk in 0..k {
                let byte = arow[kk / 2];
                let av = if kk % 2 == 0 {
                    super::unpack_nibble_lo(byte)
                } else {
                    super::unpack_nibble_hi(byte)
                } as i32;
                if av == 0 {
                    continue;
                }
                let avv = _mm256_set1_epi32(av);
                let brow = &bw[kk * n..(kk + 1) * n];
                let crow = &mut c[i * n..(i + 1) * n];
                let mut j = 0;
                while j + 8 <= n {
                    let bv = _mm256_loadu_si256(brow.as_ptr().add(j) as *const __m256i);
                    let cv = _mm256_loadu_si256(crow.as_ptr().add(j) as *const __m256i);
                    _mm256_storeu_si256(
                        crow.as_mut_ptr().add(j) as *mut __m256i,
                        _mm256_add_epi32(cv, _mm256_mullo_epi32(avv, bv)),
                    );
                    j += 8;
                }
                while j < n {
                    crow[j] += av * brow[j];
                    j += 1;
                }
            }
        }
    }

    /// XOR-popcount of two equal-length word runs: `vpshufb` nibble-LUT
    /// popcount + `vpsadbw` horizontal byte sums over 256-bit chunks
    /// (4 words), scalar `count_ones` for the ragged word tail. Exact
    /// integer popcount — identical to the scalar sum by construction.
    ///
    /// Safety: caller verified AVX2. Each 32-byte load reads
    /// `x[w .. w+4]` words with `w + 4 <= len` — in bounds.
    #[target_feature(enable = "avx2")]
    unsafe fn xor_popcnt_avx2(aw: &[i64], bw: &[i64]) -> u32 {
        debug_assert_eq!(aw.len(), bw.len());
        #[rustfmt::skip]
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0xf);
        let mut acc = _mm256_setzero_si256();
        let mut w = 0;
        while w + 4 <= aw.len() {
            let av = _mm256_loadu_si256(aw.as_ptr().add(w) as *const __m256i);
            let bv = _mm256_loadu_si256(bw.as_ptr().add(w) as *const __m256i);
            let x = _mm256_xor_si256(av, bv);
            let lo = _mm256_and_si256(x, low);
            let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(x), low);
            let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, _mm256_setzero_si256()));
            w += 4;
        }
        let mut tmp = [0u64; 4];
        _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, acc);
        let mut diff = (tmp[0] + tmp[1] + tmp[2] + tmp[3]) as u32;
        while w < aw.len() {
            diff += (aw[w] ^ bw[w]).count_ones();
            w += 1;
        }
        diff
    }

    /// AVX2 twin of [`super::gemm_xnor`]: same `(i, j)` loop, the inner
    /// word loop replaced by [`xor_popcnt_avx2`].
    ///
    /// Safety: caller verified AVX2 (the popcount helper's bounds hold
    /// for every row/column slice pair — both are `words` long).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_xnor_avx2(a_bits: &[i64], bb: &BitPackedB, m: usize, c: &mut [i32]) {
        let words = super::bit_words(bb.k);
        let (k, n) = (bb.k as i32, bb.n);
        debug_assert_eq!(a_bits.len(), m * words);
        debug_assert_eq!(c.len(), m * bb.n);
        for i in 0..m {
            let arow = &a_bits[i * words..(i + 1) * words];
            let crow = &mut c[i * n..(i + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let bcol = &bb.data[j * words..(j + 1) * words];
                *cv = k - 2 * xor_popcnt_avx2(arow, bcol) as i32;
            }
        }
    }

    /// AVX2 twin of [`super::gemm_xnor_a`].
    ///
    /// Safety: as [`gemm_xnor_avx2`].
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_xnor_a_avx2(
        ap: &BitPackedA,
        b_bits: &[i64],
        n: usize,
        c: &mut [i32],
    ) {
        let words = super::bit_words(ap.k);
        let (m, k) = (ap.m, ap.k as i32);
        debug_assert_eq!(b_bits.len(), n * words);
        debug_assert_eq!(c.len(), m * n);
        for i in 0..m {
            let arow = &ap.data[i * words..(i + 1) * words];
            let crow = &mut c[i * n..(i + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let bcol = &b_bits[j * words..(j + 1) * words];
                *cv = k - 2 * xor_popcnt_avx2(arow, bcol) as i32;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{BitPackedA, BitPackedB, PackedA4, PackedB4, GEMM_MR, GEMM_NR};
    #[allow(clippy::wildcard_imports)]
    use std::arch::aarch64::*;

    /// NEON twin of [`super::gemm_i4_packed`]: the 8-wide nibble row as
    /// two 4-lane halves, nibbles shifted into place with per-lane
    /// variable right shifts (`vshlq_u32` with negative counts), masked,
    /// and sign-extended `(x ^ 8) - 8` in 32-bit lanes — exact products,
    /// scalar accumulation order.
    ///
    /// Safety: caller verified NEON via `Isa::normalized` (baseline on
    /// aarch64); all reads are safe slice accesses.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm_i4_packed_neon(a: &[i8], bp: &PackedB4, m: usize, c: &mut [i32]) {
        let (k, n) = (bp.k, bp.n);
        debug_assert_eq!(bp.cfg.nr, GEMM_NR);
        let row_bytes = GEMM_NR / 2;
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(c.len(), m * n);
        let sh_lo: [i32; 4] = [0, -4, -8, -12];
        let sh_hi: [i32; 4] = [-16, -20, -24, -28];
        let sh_lo = vld1q_s32(sh_lo.as_ptr());
        let sh_hi = vld1q_s32(sh_hi.as_ptr());
        let maskf = vdupq_n_u32(0xf);
        let eight = vdupq_n_s32(8);
        let np = n.div_ceil(GEMM_NR);
        for jp in 0..np {
            let j0 = jp * GEMM_NR;
            let jw = GEMM_NR.min(n - j0);
            let panel = &bp.data[jp * k * row_bytes..(jp + 1) * k * row_bytes];
            let mut i0 = 0;
            while i0 < m {
                let iw = GEMM_MR.min(m - i0);
                let mut acc = [[vdupq_n_s32(0); 2]; GEMM_MR];
                for kk in 0..k {
                    let w = u32::from_le_bytes(
                        panel[kk * row_bytes..kk * row_bytes + 4].try_into().unwrap(),
                    );
                    let wv = vdupq_n_u32(w);
                    let lo = vandq_u32(vshlq_u32(wv, sh_lo), maskf);
                    let hi = vandq_u32(vshlq_u32(wv, sh_hi), maskf);
                    let blo = vsubq_s32(veorq_s32(vreinterpretq_s32_u32(lo), eight), eight);
                    let bhi = vsubq_s32(veorq_s32(vreinterpretq_s32_u32(hi), eight), eight);
                    for r in 0..iw {
                        let av = vdupq_n_s32(a[(i0 + r) * k + kk] as i32);
                        acc[r][0] = vmlaq_s32(acc[r][0], av, blo);
                        acc[r][1] = vmlaq_s32(acc[r][1], av, bhi);
                    }
                }
                let mut tmp = [0i32; GEMM_NR];
                for r in 0..iw {
                    vst1q_s32(tmp.as_mut_ptr(), acc[r][0]);
                    vst1q_s32(tmp.as_mut_ptr().add(4), acc[r][1]);
                    let base = (i0 + r) * n + j0;
                    c[base..base + jw].copy_from_slice(&tmp[..jw]);
                }
                i0 += GEMM_MR;
            }
        }
    }

    /// NEON twin of [`super::gemm_i4_packed_a`] (8-wide widening axpy:
    /// `vmovl_s8`/`vmovl_s16` B load, `vmlaq_s32` accumulate).
    ///
    /// Safety: caller verified NEON; the raw 8-byte B load reads
    /// `b[kk*n + j .. +8]` with `j + 8 <= n`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm_i4_packed_a_neon(ap: &PackedA4, b: &[i8], n: usize, c: &mut [i32]) {
        let (m, k) = (ap.m, ap.k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let row_bytes = k.div_ceil(2);
        c.fill(0);
        for i in 0..m {
            let arow = &ap.data[i * row_bytes..(i + 1) * row_bytes];
            for kk in 0..k {
                let byte = arow[kk / 2];
                let av = if kk % 2 == 0 {
                    super::unpack_nibble_lo(byte)
                } else {
                    super::unpack_nibble_hi(byte)
                } as i32;
                if av == 0 {
                    continue;
                }
                let avv = vdupq_n_s32(av);
                let brow = &b[kk * n..(kk + 1) * n];
                let crow = &mut c[i * n..(i + 1) * n];
                let mut j = 0;
                while j + 8 <= n {
                    let b16 = vmovl_s8(vld1_s8(brow.as_ptr().add(j)));
                    let blo = vmovl_s16(vget_low_s16(b16));
                    let bhi = vmovl_s16(vget_high_s16(b16));
                    let clo = vld1q_s32(crow.as_ptr().add(j));
                    let chi = vld1q_s32(crow.as_ptr().add(j + 4));
                    vst1q_s32(crow.as_mut_ptr().add(j), vmlaq_s32(clo, avv, blo));
                    vst1q_s32(crow.as_mut_ptr().add(j + 4), vmlaq_s32(chi, avv, bhi));
                    j += 8;
                }
                while j < n {
                    crow[j] += av * brow[j] as i32;
                    j += 1;
                }
            }
        }
    }

    /// XOR-popcount of two equal-length word runs: `vcntq_u8` byte
    /// popcount + pairwise widening sums over 128-bit chunks (2 words),
    /// scalar `count_ones` tail.
    ///
    /// Safety: caller verified NEON. Each 16-byte load reads
    /// `x[w .. w+2]` words with `w + 2 <= len`.
    #[target_feature(enable = "neon")]
    unsafe fn xor_popcnt_neon(aw: &[i64], bw: &[i64]) -> u32 {
        debug_assert_eq!(aw.len(), bw.len());
        let mut acc = vdupq_n_u64(0);
        let mut w = 0;
        while w + 2 <= aw.len() {
            let av = vld1q_u8(aw.as_ptr().add(w) as *const u8);
            let bv = vld1q_u8(bw.as_ptr().add(w) as *const u8);
            let x = veorq_u8(av, bv);
            acc = vaddq_u64(acc, vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(x)))));
            w += 2;
        }
        let mut diff = (vgetq_lane_u64::<0>(acc) + vgetq_lane_u64::<1>(acc)) as u32;
        while w < aw.len() {
            diff += (aw[w] ^ bw[w]).count_ones();
            w += 1;
        }
        diff
    }

    /// NEON twin of [`super::gemm_xnor`].
    ///
    /// Safety: caller verified NEON.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm_xnor_neon(a_bits: &[i64], bb: &BitPackedB, m: usize, c: &mut [i32]) {
        let words = super::bit_words(bb.k);
        let (k, n) = (bb.k as i32, bb.n);
        debug_assert_eq!(a_bits.len(), m * words);
        debug_assert_eq!(c.len(), m * bb.n);
        for i in 0..m {
            let arow = &a_bits[i * words..(i + 1) * words];
            let crow = &mut c[i * n..(i + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let bcol = &bb.data[j * words..(j + 1) * words];
                *cv = k - 2 * xor_popcnt_neon(arow, bcol) as i32;
            }
        }
    }

    /// NEON twin of [`super::gemm_xnor_a`].
    ///
    /// Safety: caller verified NEON.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm_xnor_a_neon(
        ap: &BitPackedA,
        b_bits: &[i64],
        n: usize,
        c: &mut [i32],
    ) {
        let words = super::bit_words(ap.k);
        let (m, k) = (ap.m, ap.k as i32);
        debug_assert_eq!(b_bits.len(), n * words);
        debug_assert_eq!(c.len(), m * n);
        for i in 0..m {
            let arow = &ap.data[i * words..(i + 1) * words];
            let crow = &mut c[i * n..(i + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let bcol = &b_bits[j * words..(j + 1) * words];
                *cv = k - 2 * xor_popcnt_neon(arow, bcol) as i32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn nibble_round_trip_all_values() {
        for lo in -8..=7i8 {
            for hi in -8..=7i8 {
                let b = pack_nibbles(lo, hi);
                assert_eq!(unpack_nibble_lo(b), lo);
                assert_eq!(unpack_nibble_hi(b), hi);
            }
        }
    }

    #[test]
    fn packed_b4_refuses_out_of_range() {
        assert!(PackedB4::pack(&[0, 8], 1, 2).is_none());
        assert!(PackedB4::pack(&[-9, 0], 1, 2).is_none());
        assert!(PackedB4::pack(&[-8, 7], 1, 2).is_some());
        assert!(PackedA4::pack(&[0, 8], 2, 1).is_none());
        assert!(PackedA4::pack(&[-8, 7], 2, 1).is_some());
    }

    #[test]
    fn i4_gemm_matches_naive_ragged() {
        // Shapes straddling panel width, MR, and the unpack block.
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (4, 16, 8), (5, 300, 17), (2, 513, 9)] {
            let a: Vec<i32> = (0..m * k).map(|i| (i as i32 * 37 % 255) - 127).collect();
            let b: Vec<i32> = (0..k * n).map(|i| (i as i32 * 13 % 16) - 8).collect();
            let want = naive(&a, &b, m, k, n);
            let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
            let bp = PackedB4::pack(&b, k, n).unwrap();
            let mut c = vec![0i32; m * n];
            gemm_i4_packed(&a8, &bp, m, &mut c);
            assert_eq!(c, want, "B-packed m={m} k={k} n={n}");
            let ap = PackedA4::pack(&a.iter().map(|&v| v.clamp(-8, 7)).collect::<Vec<_>>(), m, k)
                .unwrap();
            let want_a = naive(
                &a.iter().map(|&v| v.clamp(-8, 7)).collect::<Vec<_>>(),
                &b,
                m,
                k,
                n,
            );
            let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
            let mut c = vec![0i32; m * n];
            gemm_i4_packed_a(&ap, &b8, n, &mut c);
            assert_eq!(c, want_a, "A-packed m={m} k={k} n={n}");
        }
    }

    #[test]
    fn bit_pack_round_trip_and_ragged_tails() {
        // k not a multiple of 64: tail bits must pad to zero on both
        // sides so whole-word popcounts stay exact.
        for &(m, k) in &[(1, 1), (3, 63), (2, 64), (2, 65), (4, 130)] {
            let vals: Vec<i8> = (0..m * k).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect();
            let mut bits = Vec::new();
            assert!(pack_bits_rows(&vals, m, k, &mut bits));
            assert_eq!(bits.len(), m * bit_words(k));
            for i in 0..m {
                for kk in 0..k {
                    let bit = (bits[i * bit_words(k) + kk / 64] >> (kk % 64)) & 1;
                    assert_eq!(bit == 1, vals[i * k + kk] == 1, "row {i} bit {kk}");
                }
            }
        }
        let mut bits = Vec::new();
        assert!(!pack_bits_rows(&[1, 0, -1], 1, 3, &mut bits));
    }

    #[test]
    fn xnor_gemm_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 63, 5), (4, 64, 8), (5, 200, 17), (2, 513, 3)] {
            let a: Vec<i32> = (0..m * k).map(|i| if i % 5 < 2 { -1 } else { 1 }).collect();
            let b: Vec<i32> = (0..k * n).map(|i| if i % 7 < 4 { 1 } else { -1 }).collect();
            let want = naive(&a, &b, m, k, n);
            let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
            let mut a_bits = Vec::new();
            assert!(pack_bits_rows(&a8, m, k, &mut a_bits));
            let bb = BitPackedB::pack(&b, k, n).unwrap();
            let mut c = vec![0i32; m * n];
            gemm_xnor(&a_bits, &bb, m, &mut c);
            assert_eq!(c, want, "xnor m={m} k={k} n={n}");

            // Conv orientation: A bit rows at plan time, B bit cols at
            // run time.
            let ap = BitPackedA::pack(&a, m, k).unwrap();
            let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
            let mut b_bits = Vec::new();
            assert!(pack_bits_cols(&b8, k, n, &mut b_bits));
            let mut c = vec![0i32; m * n];
            gemm_xnor_a(&ap, &b_bits, n, &mut c);
            assert_eq!(c, want, "xnor-a m={m} k={k} n={n}");
        }
    }

    #[test]
    fn bipolar_pack_refuses_non_pm1() {
        assert!(BitPackedB::pack(&[1, -1, 0, 1], 2, 2).is_none());
        assert!(BitPackedA::pack(&[2, 1], 1, 2).is_none());
        assert!(BitPackedB::pack(&[1, -1, -1, 1], 2, 2).is_some());
    }

    #[test]
    fn packed_bytes_report_reduction() {
        let (k, n) = (128, 64);
        let b4: Vec<i32> = (0..k * n).map(|i| (i as i32 % 16) - 8 + 1).collect();
        let b1: Vec<i32> = (0..k * n).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let p8 = matmul::PackedB::pack(&b4, k, n).unwrap();
        let p4 = PackedB4::pack(&b4, k, n).unwrap();
        let p1 = BitPackedB::pack(&b1, k, n).unwrap();
        assert_eq!(p4.bytes() * 2, p8.bytes());
        assert_eq!(p1.bytes() * 8, k * n);
        assert_eq!(PackedWeights::I4(p4).bits(), 4);
        assert_eq!(PackedWeights::Bipolar(p1).width_name(), "bipolar");
        let b2: Vec<i32> = (0..k * n).map(|i| (i as i32 % 4) - 2).collect();
        let b3: Vec<i32> = (0..k * n).map(|i| (i as i32 % 8) - 4).collect();
        let p2 = PackedB2::pack(&b2, k, n).unwrap();
        let p3 = PackedB3::pack(&b3, k, n).unwrap();
        assert_eq!(p2.bytes() * 4, k * n);
        assert_eq!(p3.bytes() * 8, k * n * 3);
        assert_eq!(PackedWeights::I2(p2).bits(), 2);
        assert_eq!(PackedWeights::I3(p3).width_name(), "int3");
    }

    #[test]
    fn narrow_simd_twins_match_scalar_per_isa() {
        // Every host-supported ISA must agree bit for bit with the scalar
        // kernels through the dispatch seams (the same differential the
        // i8 kernels get in tests/packed_gemm.rs).
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (5, 300, 17), (2, 513, 9), (6, 64, 24)] {
            let a: Vec<i8> = (0..m * k).map(|i| ((i * 37) % 255) as u8 as i8).collect();
            let b4: Vec<i32> = (0..k * n).map(|i| (i as i32 * 13 % 16) - 8).collect();
            let bp = PackedB4::pack(&b4, k, n).unwrap();
            let mut want = vec![0i32; m * n];
            gemm_i4_packed(&a, &bp, m, &mut want);
            for isa in Isa::available() {
                let mut got = vec![0i32; m * n];
                gemm_i4_packed_isa(isa, &a, &bp, m, &mut got);
                assert_eq!(got, want, "i4 B {isa} m={m} k={k} n={n}");
            }
            let a4: Vec<i32> = (0..m * k).map(|i| (i as i32 * 11 % 16) - 8).collect();
            let ap = PackedA4::pack(&a4, m, k).unwrap();
            let b8: Vec<i8> = b4.iter().map(|&v| v as i8).collect();
            let mut want = vec![0i32; m * n];
            gemm_i4_packed_a(&ap, &b8, n, &mut want);
            for isa in Isa::available() {
                let mut got = vec![0i32; m * n];
                gemm_i4_packed_a_isa(isa, &ap, &b8, n, &mut got);
                assert_eq!(got, want, "i4 A {isa} m={m} k={k} n={n}");
            }
        }
        for &(m, k, n) in &[(1, 1, 1), (3, 63, 5), (2, 256, 8), (5, 200, 17), (2, 513, 3)] {
            let a: Vec<i8> = (0..m * k).map(|i| if i % 5 < 2 { -1 } else { 1 }).collect();
            let b: Vec<i32> = (0..k * n).map(|i| if i % 7 < 4 { 1 } else { -1 }).collect();
            let mut a_bits = Vec::new();
            assert!(pack_bits_rows(&a, m, k, &mut a_bits));
            let bb = BitPackedB::pack(&b, k, n).unwrap();
            let mut want = vec![0i32; m * n];
            gemm_xnor(&a_bits, &bb, m, &mut want);
            for isa in Isa::available() {
                let mut got = vec![0i32; m * n];
                gemm_xnor_isa(isa, &a_bits, &bb, m, &mut got);
                assert_eq!(got, want, "xnor {isa} m={m} k={k} n={n}");
            }
            let aw: Vec<i32> = a.iter().map(|&v| v as i32).collect();
            let ap = BitPackedA::pack(&aw, m, k).unwrap();
            let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
            let mut b_bits = Vec::new();
            assert!(pack_bits_cols(&b8, k, n, &mut b_bits));
            let mut want = vec![0i32; m * n];
            gemm_xnor_a(&ap, &b_bits, n, &mut want);
            for isa in Isa::available() {
                let mut got = vec![0i32; m * n];
                gemm_xnor_a_isa(isa, &ap, &b_bits, n, &mut got);
                assert_eq!(got, want, "xnor A {isa} m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn i2_i3_pack_refusal_and_round_trip() {
        assert!(PackedB2::pack(&[0, 2], 1, 2).is_none());
        assert!(PackedB2::pack(&[-3, 0], 1, 2).is_none());
        assert!(PackedB2::pack(&[-2, 1], 1, 2).is_some());
        assert!(PackedB3::pack(&[0, 4], 1, 2).is_none());
        assert!(PackedB3::pack(&[-5, 0], 1, 2).is_none());
        assert!(PackedB3::pack(&[-4, 3], 1, 2).is_some());
        assert!(PackedA2::pack(&[0, -3], 2, 1).is_none());
        assert!(PackedA2::pack(&[-2, 1], 2, 1).is_some());
        assert!(PackedA3::pack(&[4, 0], 2, 1).is_none());
        assert!(PackedA3::pack(&[-4, 3], 2, 1).is_some());
        // Tile widths that cannot byte-align refuse too (int3 at nr=4:
        // 12-bit rows).
        let nr4 = GemmConfig {
            nr: 4,
            ..GemmConfig::DEFAULT
        };
        assert!(PackedB3::pack_with(&[0; 8], 2, 4, nr4).is_none());
        assert!(PackedB2::pack_with(&[0; 8], 2, 4, nr4).is_some());
    }

    #[test]
    fn i2_i3_gemm_matches_naive_ragged() {
        // Shapes straddling panel width, MR, byte boundaries (4 crumbs /
        // 8-value tribble rows), and the k blocking.
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (4, 16, 8), (5, 300, 17), (2, 513, 9)] {
            let a: Vec<i32> = (0..m * k).map(|i| (i as i32 * 37 % 255) - 127).collect();
            let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
            let b2: Vec<i32> = (0..k * n).map(|i| (i as i32 * 13 % 4) - 2).collect();
            let b3: Vec<i32> = (0..k * n).map(|i| (i as i32 * 11 % 8) - 4).collect();
            let want2 = naive(&a, &b2, m, k, n);
            let want3 = naive(&a, &b3, m, k, n);
            let p2 = PackedB2::pack(&b2, k, n).unwrap();
            let p3 = PackedB3::pack(&b3, k, n).unwrap();
            let mut c = vec![0i32; m * n];
            gemm_i2_packed(&a8, &p2, m, &mut c);
            assert_eq!(c, want2, "int2 B m={m} k={k} n={n}");
            let mut c = vec![0i32; m * n];
            gemm_i3_packed(&a8, &p3, m, &mut c);
            assert_eq!(c, want3, "int3 B m={m} k={k} n={n}");
            for isa in Isa::available() {
                let mut c = vec![0i32; m * n];
                gemm_i2_packed_isa(isa, &a8, &p2, m, &mut c);
                assert_eq!(c, want2, "int2 B {isa}");
                let mut c = vec![0i32; m * n];
                gemm_i3_packed_isa(isa, &a8, &p3, m, &mut c);
                assert_eq!(c, want3, "int3 B {isa}");
            }

            // A-side (conv orientation): narrow weights, runtime i8 B.
            let w2: Vec<i32> = (0..m * k).map(|i| (i as i32 * 7 % 4) - 2).collect();
            let w3: Vec<i32> = (0..m * k).map(|i| (i as i32 * 5 % 8) - 4).collect();
            let b: Vec<i32> = (0..k * n).map(|i| (i as i32 * 29 % 255) - 127).collect();
            let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
            let pa2 = PackedA2::pack(&w2, m, k).unwrap();
            let pa3 = PackedA3::pack(&w3, m, k).unwrap();
            let mut c = vec![0i32; m * n];
            gemm_i2_packed_a(&pa2, &b8, n, &mut c);
            assert_eq!(c, naive(&w2, &b, m, k, n), "int2 A m={m} k={k} n={n}");
            let mut c = vec![0i32; m * n];
            gemm_i3_packed_a(&pa3, &b8, n, &mut c);
            assert_eq!(c, naive(&w3, &b, m, k, n), "int3 A m={m} k={k} n={n}");
        }
    }

    #[test]
    fn nibble_activation_gemm_matches_widened() {
        // The packed-activation consumer path: i8 rows already saturated
        // to int4 range, packed to nibble rows, multiplied against the
        // widened i32 weights — bit-identical to the container path.
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (4, 16, 8), (5, 33, 17), (2, 64, 9)] {
            let acts: Vec<i8> = (0..m * k).map(|i| ((i * 5) % 16) as i8 - 8).collect();
            let bw: Vec<i32> = (0..k * n).map(|i| (i as i32 * 37 % 255) - 127).collect();
            let aw: Vec<i32> = acts.iter().map(|&v| v as i32).collect();
            let want = naive(&aw, &bw, m, k, n);
            let mut packed = Vec::new();
            pack_nibble_rows(&acts, m, k, &mut packed);
            assert_eq!(packed.len(), m * k.div_ceil(2));
            let mut c = vec![0i32; m * n];
            gemm_i4a_bytes(&packed, m, k, &bw, n, &mut c);
            assert_eq!(c, want, "nibble-A m={m} k={k} n={n}");
            for isa in Isa::available() {
                let mut c = vec![0i32; m * n];
                gemm_i4a_bytes_isa(isa, &packed, m, k, &bw, n, &mut c);
                assert_eq!(c, want, "nibble-A {isa} m={m} k={k} n={n}");
            }
        }
    }
}
