//! Plan-time ISA selection for the quantized microkernels.
//!
//! The paper codifies quantized models in standard ONNX precisely so a
//! backend can lower them to hardware-native operations; this module is
//! the lowering decision. The instruction set is detected ONCE (first
//! use, cached), the opt/ pass pipeline stamps it into every pre-bound
//! and fused kernel it emits, and the hot loop dispatches on the stamped
//! value — no per-call feature probing, no per-call branching beyond one
//! match.
//!
//! Contract with the kernels:
//!
//! - `Isa::Scalar` is always available and is the differential oracle:
//!   every SIMD variant must produce bit-identical results (the integer
//!   lanes replay the exact ascending-k i32 accumulation; the float
//!   epilogue lanes perform the same IEEE-754 single operations per
//!   element — see EXPERIMENTS.md §SIMD for the full argument, and
//!   `tests/packed_gemm.rs` for the proof).
//! - A dispatch site never trusts an `Isa` value blindly: it runs the
//!   value through [`Isa::normalized`] first, so a forced or stale value
//!   can never route into an intrinsic the host does not support. This
//!   is what makes `PQDL_FORCE_ISA=avx2` safe on any machine — on a
//!   non-AVX2 host it degrades to scalar instead of faulting, which is
//!   also how the CI feature matrix "skips unsupported ISAs gracefully".
//!
//! Knob: `PQDL_FORCE_ISA=scalar|sse41|avx2|neon` pins the selection for
//! testing (read once; unknown or unsupported names fall back to scalar).

use std::fmt;
use std::sync::OnceLock;

/// A kernel instruction-set variant. `Scalar` is the portable reference
/// implementation; the rest are `std::arch` intrinsic twins selected at
/// plan time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Isa {
    /// Portable Rust loops — always available, the differential oracle.
    Scalar,
    /// x86_64 SSE4.1 (128-bit lanes; `pmulld`/`roundps`).
    Sse41,
    /// x86_64 AVX2 (256-bit lanes).
    Avx2,
    /// aarch64 NEON (128-bit lanes; baseline on AArch64).
    Neon,
}

static ACTIVE: OnceLock<Isa> = OnceLock::new();

impl Isa {
    /// Every variant, in preference order (later = preferred when
    /// supported).
    pub const ALL: [Isa; 4] = [Isa::Scalar, Isa::Neon, Isa::Sse41, Isa::Avx2];

    /// Stable lowercase name (the `PQDL_FORCE_ISA` vocabulary and the
    /// bench/JSON row label).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse41 => "sse41",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse a (case-insensitive, whitespace-tolerant) ISA name.
    pub fn from_name(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "sse41" | "sse4.1" => Some(Isa::Sse41),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// True when this host can execute the variant. Scalar is always
    /// true; SIMD variants require both the right target architecture
    /// (compile time) and the CPU feature bit (runtime).
    pub fn supported(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Sse41 => std::arch::is_x86_feature_detected!("sse4.1"),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => true,
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// This value if the host supports it, else `Scalar`. Every dispatch
    /// site applies this before entering an `unsafe` intrinsic body —
    /// the soundness guard that makes forcing any ISA on any host safe.
    pub fn normalized(self) -> Isa {
        if self.supported() {
            self
        } else {
            Isa::Scalar
        }
    }

    /// Best ISA the host supports (ignores the env override).
    pub fn detect() -> Isa {
        detect_arch()
    }

    /// The plan-time selection: `PQDL_FORCE_ISA` if set (normalized to
    /// scalar when unknown/unsupported — graceful degradation for the CI
    /// matrix), else [`Isa::detect`]. Read once and cached, so steady-
    /// state plan execution never touches the environment (the
    /// allocation-regression test depends on this being warm after
    /// `Session::new`).
    pub fn active() -> Isa {
        *ACTIVE.get_or_init(|| match std::env::var("PQDL_FORCE_ISA") {
            Ok(s) => Isa::from_name(&s).unwrap_or(Isa::Scalar).normalized(),
            Err(_) => Isa::detect(),
        })
    }

    /// Every variant this host supports, scalar first. This is the test
    /// and bench matrix: differential suites iterate it so the SIMD
    /// twins are exercised wherever they can run.
    pub fn available() -> Vec<Isa> {
        Isa::ALL.iter().copied().filter(|i| i.supported()).collect()
    }
}

impl fmt::Display for Isa {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Isa {
    if std::arch::is_x86_feature_detected!("avx2") {
        Isa::Avx2
    } else if std::arch::is_x86_feature_detected!("sse4.1") {
        Isa::Sse41
    } else {
        Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Isa {
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Isa {
    Isa::Scalar
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for isa in Isa::ALL {
            assert_eq!(Isa::from_name(isa.name()), Some(isa));
            assert_eq!(format!("{isa}"), isa.name());
        }
        assert_eq!(Isa::from_name(" AVX2\n"), Some(Isa::Avx2));
        assert_eq!(Isa::from_name("sse4.1"), Some(Isa::Sse41));
        assert_eq!(Isa::from_name("avx512"), None);
    }

    #[test]
    fn scalar_always_available() {
        assert!(Isa::Scalar.supported());
        assert_eq!(Isa::Scalar.normalized(), Isa::Scalar);
        let avail = Isa::available();
        assert!(avail.contains(&Isa::Scalar));
        assert!(avail.contains(&Isa::detect()));
        // available() only lists what supported() admits, and every
        // listed variant normalizes to itself.
        for isa in avail {
            assert!(isa.supported());
            assert_eq!(isa.normalized(), isa);
        }
    }

    #[test]
    fn unsupported_normalizes_to_scalar() {
        for isa in Isa::ALL {
            if !isa.supported() {
                assert_eq!(isa.normalized(), Isa::Scalar);
            }
        }
        // detect() must itself be supported (it only returns what the
        // feature probe admitted).
        assert!(Isa::detect().supported());
    }

    #[test]
    fn active_is_supported_and_stable() {
        // Whatever the environment says, active() lands on a supported
        // variant and keeps answering the same thing (OnceLock).
        let first = Isa::active();
        assert!(first.supported());
        assert_eq!(Isa::active(), first);
    }
}
