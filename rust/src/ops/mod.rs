//! Standard ONNX operator implementations and the node-level dispatcher.
//!
//! This is the execution half of the "runs on standard tools" claim: the
//! operators implement the public ONNX contracts (opset 13 subset listed
//! in [`crate::onnx::check::STANDARD_OPS`]) with no knowledge of the
//! paper's quantization scheme — exactly like ONNXruntime.
//!
//! Dispatch is split compile-style: [`Kernel::bind`] parses a node's
//! attributes into a pre-bound kernel once, [`Kernel::run`] executes it
//! against resolved input tensors. [`execute_node`] composes the two for
//! callers that hold a bare node (rewrite passes, tests); the interpreter
//! binds at plan time and only runs in its hot loop.

pub mod bitpack;
pub mod conv;
pub mod elementwise;
pub mod fused;
pub mod isa;
pub mod kernel;
pub mod matmul;
pub mod pool;
pub mod qlinear;
pub mod shape_ops;

pub use isa::Isa;
pub use kernel::Kernel;

use crate::onnx::ir::Node;
use crate::tensor::{Tensor, TensorError};
use thiserror::Error;

#[derive(Error, Debug)]
pub enum OpError {
    #[error("semantics: {0}")]
    Semantics(String),
    #[error(transparent)]
    Tensor(#[from] TensorError),
    #[error("node '{node}' ({op}): missing required input #{index}")]
    MissingInput {
        node: String,
        op: String,
        index: usize,
    },
    #[error("unsupported operator '{0}'")]
    Unsupported(String),
}

impl OpError {
    /// Fill in the node name on errors minted inside [`Kernel::run`]
    /// (which only knows the operator, not the node).
    pub fn with_node(mut self, name: &str) -> OpError {
        if let OpError::MissingInput { node, .. } = &mut self {
            if node.is_empty() {
                *node = name.to_string();
            }
        }
        self
    }
}

/// Execute one node given resolved input tensors (None = omitted optional
/// input). Returns the node's output tensors in declaration order.
///
/// Thin bind+run compat wrapper over [`Kernel`]: attribute parsing happens
/// on every call here, so hot paths should bind once and reuse the kernel
/// (as [`crate::interp::Session`]'s compiled plan does).
pub fn execute_node(node: &Node, inputs: &[Option<&Tensor>]) -> Result<Vec<Tensor>, OpError> {
    let kernel = Kernel::bind(node)?;
    let out = kernel.run(inputs).map_err(|e| e.with_node(&node.name))?;
    Ok(vec![out])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::ir::Attr;

    #[test]
    fn dispatch_matmul_integer() {
        let node = Node::new("mm", "MatMulInteger", &["a", "b"], &["c"]);
        let a = Tensor::from_i8(&[1, 2], vec![1, 2]).unwrap();
        let b = Tensor::from_i8(&[2, 1], vec![3, 4]).unwrap();
        let out = execute_node(&node, &[Some(&a), Some(&b)]).unwrap();
        assert_eq!(out[0].as_i32().unwrap(), &[11]);
    }

    #[test]
    fn dispatch_cast_attr() {
        let node = Node::new("c", "Cast", &["x"], &["y"])
            .with_attr("to", Attr::Str("FLOAT".into()));
        let x = Tensor::from_i32(&[2], vec![1, -1]).unwrap();
        let out = execute_node(&node, &[Some(&x)]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[1.0, -1.0]);
    }

    #[test]
    fn missing_input_reported() {
        let node = Node::new("mm", "MatMulInteger", &["a", "b"], &["c"]);
        let a = Tensor::from_i8(&[1, 2], vec![1, 2]).unwrap();
        let err = execute_node(&node, &[Some(&a), None]).unwrap_err();
        assert!(matches!(err, OpError::MissingInput { index: 1, .. }));
        // The compat wrapper patches the node name into the error.
        assert!(err.to_string().contains("'mm'"));
    }

    #[test]
    fn unsupported_op_reported() {
        let node = Node::new("n", "LSTM", &[], &["y"]);
        assert!(matches!(
            execute_node(&node, &[]),
            Err(OpError::Unsupported(_))
        ));
    }

    #[test]
    fn conv_with_bias_input() {
        let node = Node::new("c", "Conv", &["x", "w", "b"], &["y"]);
        let x = Tensor::from_f32(&[1, 1, 2, 2], vec![1., 2., 3., 4.]).unwrap();
        let w = Tensor::from_f32(&[1, 1, 1, 1], vec![1.0]).unwrap();
        let b = Tensor::from_f32(&[1], vec![10.0]).unwrap();
        let out = execute_node(&node, &[Some(&x), Some(&w), Some(&b)]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[11., 12., 13., 14.]);
    }
}
