//! Standard ONNX operator implementations and the node-level dispatcher.
//!
//! This is the execution half of the "runs on standard tools" claim: the
//! operators implement the public ONNX contracts (opset 13 subset listed
//! in [`crate::onnx::check::STANDARD_OPS`]) with no knowledge of the
//! paper's quantization scheme — exactly like ONNXruntime.

pub mod conv;
pub mod elementwise;
pub mod matmul;
pub mod pool;
pub mod qlinear;
pub mod shape_ops;

use crate::onnx::ir::Node;
use crate::onnx::shape::ConvAttrs;
use crate::tensor::{DType, Tensor, TensorError};
use thiserror::Error;

#[derive(Error, Debug)]
pub enum OpError {
    #[error("semantics: {0}")]
    Semantics(String),
    #[error(transparent)]
    Tensor(#[from] TensorError),
    #[error("node '{node}' ({op}): missing required input #{index}")]
    MissingInput {
        node: String,
        op: String,
        index: usize,
    },
    #[error("unsupported operator '{0}'")]
    Unsupported(String),
}

/// Execute one node given resolved input tensors (None = omitted optional
/// input). Returns the node's output tensors in declaration order.
pub fn execute_node(node: &Node, inputs: &[Option<&Tensor>]) -> Result<Vec<Tensor>, OpError> {
    let req = |i: usize| -> Result<&Tensor, OpError> {
        inputs
            .get(i)
            .copied()
            .flatten()
            .ok_or_else(|| OpError::MissingInput {
                node: node.name.clone(),
                op: node.op_type.clone(),
                index: i,
            })
    };
    let opt = |i: usize| -> Option<&Tensor> { inputs.get(i).copied().flatten() };

    let out = match node.op_type.as_str() {
        "MatMulInteger" => vec![matmul::matmul_integer(req(0)?, req(1)?, opt(2), opt(3))?],
        "MatMul" => vec![matmul::matmul_f32(req(0)?, req(1)?)?],
        "Gemm" => {
            let alpha = node.attr_float("alpha").unwrap_or(1.0);
            let beta = node.attr_float("beta").unwrap_or(1.0);
            let trans_a = node.attr_int("transA").unwrap_or(0) != 0;
            let trans_b = node.attr_int("transB").unwrap_or(0) != 0;
            vec![matmul::gemm(req(0)?, req(1)?, opt(2), alpha, beta, trans_a, trans_b)?]
        }
        "ConvInteger" => {
            let attrs = ConvAttrs::from_node(node);
            vec![conv::conv_integer(req(0)?, req(1)?, opt(2), opt(3), &attrs)?]
        }
        "Conv" => {
            let attrs = ConvAttrs::from_node(node);
            let y = conv::conv_f32(req(0)?, req(1)?, &attrs)?;
            // ONNX Conv takes an optional fp32 bias input B [M].
            match opt(2) {
                None => vec![y],
                Some(b) => {
                    let m = y.shape()[1];
                    let b4 = b.clone().reshape(&[1, m, 1, 1])?;
                    vec![elementwise::binary(elementwise::BinOp::Add, &y, &b4)?]
                }
            }
        }
        "Add" | "Mul" | "Sub" | "Div" => {
            let op = elementwise::BinOp::from_op_type(&node.op_type).unwrap();
            vec![elementwise::binary(op, req(0)?, req(1)?)?]
        }
        "Cast" => {
            let to = node
                .attr_str("to")
                .and_then(DType::from_onnx_name)
                .ok_or_else(|| OpError::Semantics("Cast: missing/unknown 'to'".into()))?;
            vec![req(0)?.cast(to)]
        }
        "QuantizeLinear" => vec![qlinear::quantize_linear(req(0)?, req(1)?, opt(2))?],
        "DequantizeLinear" => vec![qlinear::dequantize_linear(req(0)?, req(1)?, opt(2))?],
        "Relu" => vec![elementwise::relu(req(0)?)?],
        "Tanh" => vec![elementwise::tanh(req(0)?)?],
        "Sigmoid" => vec![elementwise::sigmoid(req(0)?)?],
        "Softmax" => {
            let axis = node.attr_int("axis").unwrap_or(-1);
            vec![shape_ops::softmax(req(0)?, axis)?]
        }
        "MaxPool" => {
            let kernel = node
                .attr_ints("kernel_shape")
                .ok_or_else(|| OpError::Semantics("MaxPool: missing kernel_shape".into()))?
                .to_vec();
            vec![pool::max_pool(req(0)?, &kernel, ConvAttrs::from_node(node))?]
        }
        "AveragePool" => {
            let kernel = node
                .attr_ints("kernel_shape")
                .ok_or_else(|| OpError::Semantics("AveragePool: missing kernel_shape".into()))?
                .to_vec();
            vec![pool::average_pool(req(0)?, &kernel, ConvAttrs::from_node(node))?]
        }
        "Reshape" => {
            let spec = req(1)?.as_i64()?.to_vec();
            vec![shape_ops::reshape(req(0)?, &spec)?]
        }
        "Flatten" => {
            let axis = node.attr_int("axis").unwrap_or(1) as usize;
            vec![shape_ops::flatten(req(0)?, axis)?]
        }
        "Identity" => vec![req(0)?.clone()],
        other => return Err(OpError::Unsupported(other.to_string())),
    };
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::ir::Attr;

    #[test]
    fn dispatch_matmul_integer() {
        let node = Node::new("mm", "MatMulInteger", &["a", "b"], &["c"]);
        let a = Tensor::from_i8(&[1, 2], vec![1, 2]).unwrap();
        let b = Tensor::from_i8(&[2, 1], vec![3, 4]).unwrap();
        let out = execute_node(&node, &[Some(&a), Some(&b)]).unwrap();
        assert_eq!(out[0].as_i32().unwrap(), &[11]);
    }

    #[test]
    fn dispatch_cast_attr() {
        let node = Node::new("c", "Cast", &["x"], &["y"])
            .with_attr("to", Attr::Str("FLOAT".into()));
        let x = Tensor::from_i32(&[2], vec![1, -1]).unwrap();
        let out = execute_node(&node, &[Some(&x)]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[1.0, -1.0]);
    }

    #[test]
    fn missing_input_reported() {
        let node = Node::new("mm", "MatMulInteger", &["a", "b"], &["c"]);
        let a = Tensor::from_i8(&[1, 2], vec![1, 2]).unwrap();
        let err = execute_node(&node, &[Some(&a), None]).unwrap_err();
        assert!(matches!(err, OpError::MissingInput { index: 1, .. }));
    }

    #[test]
    fn unsupported_op_reported() {
        let node = Node::new("n", "LSTM", &[], &["y"]);
        assert!(matches!(
            execute_node(&node, &[]),
            Err(OpError::Unsupported(_))
        ));
    }

    #[test]
    fn conv_with_bias_input() {
        let node = Node::new("c", "Conv", &["x", "w", "b"], &["y"]);
        let x = Tensor::from_f32(&[1, 1, 2, 2], vec![1., 2., 3., 4.]).unwrap();
        let w = Tensor::from_f32(&[1, 1, 1, 1], vec![1.0]).unwrap();
        let b = Tensor::from_f32(&[1], vec![10.0]).unwrap();
        let out = execute_node(&node, &[Some(&x), Some(&w), Some(&b)]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[11., 12., 13., 14.]);
    }
}
