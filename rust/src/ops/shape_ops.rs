//! Shape-manipulating operators (Reshape, Flatten, Identity) and Softmax.

use super::OpError;
use crate::tensor::{recycled_f32_zeroed, Shape, Tensor};

/// ONNX `Reshape` with 0 (copy) and -1 (infer) semantics.
pub fn reshape(x: &Tensor, spec: &[i64]) -> Result<Tensor, OpError> {
    reshape_into(x, spec, None)
}

/// [`reshape`] copying into recycled storage (the planned executor's
/// form: data copy + inline-shape computation, no steady-state
/// allocation).
pub fn reshape_into(x: &Tensor, spec: &[i64], recycled: Option<Tensor>) -> Result<Tensor, OpError> {
    let mut dims = Shape::empty();
    let mut infer_at = None;
    for (i, &s) in spec.iter().enumerate() {
        match s {
            0 => {
                let d = *x
                    .shape()
                    .get(i)
                    .ok_or_else(|| OpError::Semantics("0-dim out of range".into()))?;
                dims.push(d);
            }
            -1 => {
                if infer_at.is_some() {
                    return Err(OpError::Semantics("multiple -1 dims".into()));
                }
                infer_at = Some(i);
                dims.push(1);
            }
            s if s > 0 => dims.push(s as usize),
            s => return Err(OpError::Semantics(format!("bad dim {s}"))),
        }
    }
    if let Some(at) = infer_at {
        let rest: usize = dims
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != at)
            .map(|(_, &d)| d)
            .product();
        if rest == 0 || x.numel() % rest != 0 {
            return Err(OpError::Semantics(format!(
                "cannot infer -1: numel {} over {}",
                x.numel(),
                rest
            )));
        }
        dims.as_mut_slice()[at] = x.numel() / rest;
    }
    Ok(x.clone_recycled(recycled).reshape(&dims)?)
}

/// ONNX `Flatten`.
pub fn flatten(x: &Tensor, axis: usize) -> Result<Tensor, OpError> {
    flatten_into(x, axis, None)
}

/// [`flatten`] copying into recycled storage.
pub fn flatten_into(x: &Tensor, axis: usize, recycled: Option<Tensor>) -> Result<Tensor, OpError> {
    if axis > x.rank() {
        return Err(OpError::Semantics("axis out of range".into()));
    }
    let d0: usize = x.shape()[..axis].iter().product();
    let d1: usize = x.shape()[axis..].iter().product();
    Ok(x.clone_recycled(recycled).reshape(&[d0, d1])?)
}

/// ONNX `Softmax` along `axis` (f32). Numerically-stable max-subtraction
/// form; used by the fp32 reference models and accuracy evaluation.
pub fn softmax(x: &Tensor, axis: i64) -> Result<Tensor, OpError> {
    softmax_into(x, axis, None)
}

/// [`softmax`] into recycled storage (identical values).
pub fn softmax_into(x: &Tensor, axis: i64, recycled: Option<Tensor>) -> Result<Tensor, OpError> {
    let rank = x.rank() as i64;
    let axis = if axis < 0 { axis + rank } else { axis };
    if axis < 0 || axis >= rank {
        return Err(OpError::Semantics("axis out of range".into()));
    }
    let axis = axis as usize;
    let v = x.as_f32()?;
    let shape = x.shape();
    let axis_len = shape[axis];
    let inner: usize = shape[axis + 1..].iter().product();
    let outer: usize = shape[..axis].iter().product();
    let mut out = recycled_f32_zeroed(recycled, v.len());
    for o in 0..outer {
        for i in 0..inner {
            let idx = |a: usize| (o * axis_len + a) * inner + i;
            let mut max = f32::NEG_INFINITY;
            for a in 0..axis_len {
                max = max.max(v[idx(a)]);
            }
            let mut sum = 0.0;
            for a in 0..axis_len {
                let e = (v[idx(a)] - max).exp();
                out[idx(a)] = e;
                sum += e;
            }
            for a in 0..axis_len {
                out[idx(a)] /= sum;
            }
        }
    }
    Ok(Tensor::from_f32(shape, out)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reshape_infer() {
        let x = Tensor::from_f32(&[2, 6], vec![0.0; 12]).unwrap();
        let y = reshape(&x, &[0, 2, -1]).unwrap();
        assert_eq!(y.shape(), &[2, 2, 3]);
        assert!(reshape(&x, &[5, -1]).is_err());
    }

    #[test]
    fn flatten_axis() {
        let x = Tensor::from_f32(&[2, 3, 4], vec![0.0; 24]).unwrap();
        assert_eq!(flatten(&x, 1).unwrap().shape(), &[2, 12]);
        assert_eq!(flatten(&x, 0).unwrap().shape(), &[1, 24]);
        assert_eq!(flatten(&x, 3).unwrap().shape(), &[24, 1]);
    }

    #[test]
    fn softmax_rows() {
        let x = Tensor::from_f32(&[2, 2], vec![0.0, 0.0, 1000.0, 0.0]).unwrap();
        let y = softmax(&x, -1).unwrap();
        let v = y.as_f32().unwrap();
        assert!((v[0] - 0.5).abs() < 1e-6);
        assert!((v[2] - 1.0).abs() < 1e-6); // stable under large inputs
        let row_sum: f32 = v[..2].iter().sum();
        assert!((row_sum - 1.0).abs() < 1e-6);
    }
}
