//! Elementwise operators: Add / Mul / Sub / Div (with NumPy broadcasting)
//! and the activations Relu / Tanh / Sigmoid.
//!
//! The paper's rescale stage is two (or one) `Mul` nodes on the f32 path
//! (§3.1) and an i32 `Add` for the bias (Eq. 5); Figures 4–6 run Tanh and
//! Sigmoid in f32 or genuine f16.

use super::OpError;
use crate::tensor::{
    recycled_f16, recycled_f32, recycled_i32, recycled_i8, BroadcastIndexer, Shape, Tensor,
    TensorData,
};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Mul,
    Sub,
    Div,
}

impl BinOp {
    pub fn from_op_type(op: &str) -> Option<BinOp> {
        Some(match op {
            "Add" => BinOp::Add,
            "Mul" => BinOp::Mul,
            "Sub" => BinOp::Sub,
            "Div" => BinOp::Div,
            _ => return None,
        })
    }
}

#[inline]
fn apply_f32(op: BinOp, x: f32, y: f32) -> f32 {
    match op {
        BinOp::Add => x + y,
        BinOp::Mul => x * y,
        BinOp::Sub => x - y,
        BinOp::Div => x / y,
    }
}

/// i32 path uses wrapping arithmetic: the ONNX integer operators are
/// defined modulo 2^32 on overflow, and hardware accumulators wrap.
#[inline]
fn apply_i32(op: BinOp, x: i32, y: i32) -> i32 {
    match op {
        BinOp::Add => x.wrapping_add(y),
        BinOp::Mul => x.wrapping_mul(y),
        BinOp::Sub => x.wrapping_sub(y),
        BinOp::Div => {
            if y == 0 {
                0
            } else {
                x.wrapping_div(y)
            }
        }
    }
}

/// Elementwise binary op with multidirectional broadcasting.
pub fn binary(op: BinOp, a: &Tensor, b: &Tensor) -> Result<Tensor, OpError> {
    binary_into(op, a, b, None)
}

/// [`binary`] writing into recycled storage (identical values element for
/// element; the scratch planner's steady-state form — the shape
/// classification and every fast path allocate nothing).
pub fn binary_into(
    op: BinOp,
    a: &Tensor,
    b: &Tensor,
    recycled: Option<Tensor>,
) -> Result<Tensor, OpError> {
    if a.dtype() != b.dtype() {
        return Err(OpError::Semantics(format!(
            "dtype mismatch {} vs {}",
            a.dtype(),
            b.dtype()
        )));
    }
    let out_shape: Shape = crate::tensor::broadcast_dims(a.shape(), b.shape())?;
    let n: usize = out_shape.iter().product();
    let dims: &[usize] = &out_shape;
    let same = a.shape() == dims && b.shape() == dims;
    // Fast-path classification (hot in every pattern: the rescale Mul is
    // tensor×scalar, the bias Add broadcasts along one axis — rows×[N]
    // for FC, [1,C,1,1] for conv. See EXPERIMENTS.md §Perf).
    let a_full = a.shape() == dims;
    let b_scalar = b.numel() == 1;
    let a_scalar = a.numel() == 1;
    // Single-axis broadcast of b over a full-shape a: b's non-1 dims
    // reduce to one axis matching out_shape. Yields (axis_len, chunk):
    // b[j] applies to contiguous runs of `chunk` elements, cycling j.
    let b_axis: Option<(usize, usize)> = if a_full && !b_scalar {
        let rank = dims.len();
        let pad = rank - b.rank();
        let mut axis = None;
        let mut ok = true;
        for (i, &d) in b.shape().iter().enumerate() {
            if d == 1 {
                continue;
            }
            if d == dims[pad + i] && axis.is_none() {
                axis = Some(pad + i);
            } else {
                ok = false;
                break;
            }
        }
        match (ok, axis) {
            (true, Some(ax)) => {
                let chunk: usize = dims[ax + 1..].iter().product();
                Some((dims[ax], chunk))
            }
            _ => None,
        }
    } else {
        None
    };

    macro_rules! fused_loops {
        ($av:expr, $bv:expr, $apply:expr, $recycle:path, $wrap:expr) => {{
            let (av, bv) = ($av, $bv);
            let mut out = $recycle(recycled, n);
            if same {
                out.extend(av.iter().zip(bv).map(|(&x, &y)| $apply(op, x, y)));
            } else if b_scalar && a_full {
                let s = bv[0];
                out.extend(av.iter().map(|&x| $apply(op, x, s)));
            } else if a_scalar && b.shape() == dims {
                let s = av[0];
                out.extend(bv.iter().map(|&y| $apply(op, s, y)));
            } else if let Some((axis_len, chunk)) = b_axis {
                if chunk == 1 {
                    // b cycles elementwise (e.g. FC bias over rows).
                    for row in av.chunks_exact(axis_len) {
                        out.extend(row.iter().zip(bv).map(|(&x, &y)| $apply(op, x, y)));
                    }
                } else {
                    // b[j] constant over contiguous chunks (conv bias).
                    let mut pos = 0;
                    while pos < n {
                        for j in 0..axis_len {
                            let s = bv[j];
                            out.extend(
                                av[pos..pos + chunk].iter().map(|&x| $apply(op, x, s)),
                            );
                            pos += chunk;
                        }
                    }
                }
            } else {
                let ia = BroadcastIndexer::new(dims, a.shape());
                let ib = BroadcastIndexer::new(dims, b.shape());
                out.extend((0..n).map(|i| $apply(op, av[ia.map(i)], bv[ib.map(i)])));
            }
            $wrap(out)
        }};
    }

    let data = match (a.data(), b.data()) {
        (TensorData::F32(av), TensorData::F32(bv)) => {
            fused_loops!(av, bv, apply_f32, recycled_f32, TensorData::F32)
        }
        (TensorData::I32(av), TensorData::I32(bv)) => {
            fused_loops!(av, bv, apply_i32, recycled_i32, TensorData::I32)
        }
        (TensorData::F16(av), TensorData::F16(bv)) => {
            // f16 arithmetic: compute in f32, round back per op (what
            // fp16 ALUs do for a single operation).
            let f = |x: crate::tensor::F16, y: crate::tensor::F16| {
                crate::tensor::F16::from_f32(apply_f32(op, x.to_f32(), y.to_f32()))
            };
            let mut out = recycled_f16(recycled, n);
            if same {
                out.extend(av.iter().zip(bv).map(|(&x, &y)| f(x, y)));
            } else {
                let ia = BroadcastIndexer::new(dims, a.shape());
                let ib = BroadcastIndexer::new(dims, b.shape());
                out.extend((0..n).map(|i| f(av[ia.map(i)], bv[ib.map(i)])));
            }
            TensorData::F16(out)
        }
        _ => {
            return Err(OpError::Semantics(format!(
                "unsupported dtype {} for elementwise op",
                a.dtype()
            )))
        }
    };
    Ok(Tensor::new(out_shape, data)?)
}

/// ONNX `Relu`: max(x, 0). Supports the dtypes the paper's patterns can
/// place it on: f32, f16, i32 (pre-rescale) and i8 (post-requantize).
pub fn relu(x: &Tensor) -> Result<Tensor, OpError> {
    relu_into(x, None)
}

/// [`relu`] into recycled storage (identical values).
pub fn relu_into(x: &Tensor, recycled: Option<Tensor>) -> Result<Tensor, OpError> {
    let n = x.numel();
    let data = match x.data() {
        TensorData::F32(v) => {
            let mut o = recycled_f32(recycled, n);
            o.extend(v.iter().map(|&x| x.max(0.0)));
            TensorData::F32(o)
        }
        TensorData::F16(v) => {
            let mut o = recycled_f16(recycled, n);
            o.extend(
                v.iter()
                    .map(|&x| if x.to_f32() > 0.0 { x } else { crate::tensor::F16::ZERO }),
            );
            TensorData::F16(o)
        }
        TensorData::I32(v) => {
            let mut o = recycled_i32(recycled, n);
            o.extend(v.iter().map(|&x| x.max(0)));
            TensorData::I32(o)
        }
        TensorData::I8(v) => {
            let mut o = recycled_i8(recycled, n);
            o.extend(v.iter().map(|&x| x.max(0)));
            TensorData::I8(o)
        }
        d => {
            return Err(OpError::Semantics(format!(
                "Relu: unsupported dtype {}",
                d.dtype()
            )))
        }
    };
    Ok(Tensor::new(Shape::from_slice(x.shape()), data)?)
}

/// ONNX `Clip` (opset 13 form: optional scalar `min`/`max` inputs).
///
/// The sub-8-bit codification places an f32 Clip with integer bounds
/// between the rescale stage and its `QuantizeLinear` to declare the
/// narrow logical range (see `quant::scheme`). Semantics are numpy's:
/// out-of-range values pin to the violated bound, NaN propagates
/// (comparisons with NaN are false). NaN propagation is what makes the
/// matcher's Clip absorption exact — the fused epilogue's
/// `clamp(round(x))` also sends NaN through to the saturating cast, so
/// both paths agree on every f32 bit pattern.
pub fn clip(x: &Tensor, lo: Option<&Tensor>, hi: Option<&Tensor>) -> Result<Tensor, OpError> {
    clip_into(x, lo, hi, None)
}

/// [`clip`] into recycled storage (identical values).
pub fn clip_into(
    x: &Tensor,
    lo: Option<&Tensor>,
    hi: Option<&Tensor>,
    recycled: Option<Tensor>,
) -> Result<Tensor, OpError> {
    let scalar = |t: Option<&Tensor>, which: &str| -> Result<Option<f32>, OpError> {
        match t {
            None => Ok(None),
            Some(t) => {
                if t.numel() != 1 {
                    return Err(OpError::Semantics(format!(
                        "Clip: {which} must be a scalar, got shape {:?}",
                        t.shape()
                    )));
                }
                Ok(Some(t.as_f32()?[0]))
            }
        }
    };
    let (lo, hi) = (scalar(lo, "min")?, scalar(hi, "max")?);
    let n = x.numel();
    let data = match x.data() {
        TensorData::F32(v) => {
            let mut o = recycled_f32(recycled, n);
            o.extend(v.iter().map(|&x| {
                let mut y = x;
                if let Some(l) = lo {
                    if y < l {
                        y = l;
                    }
                }
                if let Some(h) = hi {
                    if y > h {
                        y = h;
                    }
                }
                y
            }));
            TensorData::F32(o)
        }
        d => {
            return Err(OpError::Semantics(format!(
                "Clip: unsupported dtype {}",
                d.dtype()
            )))
        }
    };
    Ok(Tensor::new(Shape::from_slice(x.shape()), data)?)
}

/// ONNX `Tanh` — f32 or genuine f16 (Figure 5's `Tanh FLOAT16 -> FLOAT16`).
pub fn tanh(x: &Tensor) -> Result<Tensor, OpError> {
    tanh_into(x, None)
}

/// [`tanh`] into recycled storage (identical values).
pub fn tanh_into(x: &Tensor, recycled: Option<Tensor>) -> Result<Tensor, OpError> {
    let n = x.numel();
    let data = match x.data() {
        TensorData::F32(v) => {
            let mut o = recycled_f32(recycled, n);
            o.extend(v.iter().map(|&x| x.tanh()));
            TensorData::F32(o)
        }
        TensorData::F16(v) => {
            let mut o = recycled_f16(recycled, n);
            o.extend(v.iter().map(|x| x.tanh()));
            TensorData::F16(o)
        }
        d => {
            return Err(OpError::Semantics(format!(
                "Tanh: unsupported dtype {}",
                d.dtype()
            )))
        }
    };
    Ok(Tensor::new(Shape::from_slice(x.shape()), data)?)
}

/// ONNX `Sigmoid` — f32 or genuine f16 (Figure 6).
pub fn sigmoid(x: &Tensor) -> Result<Tensor, OpError> {
    sigmoid_into(x, None)
}

/// [`sigmoid`] into recycled storage (identical values).
pub fn sigmoid_into(x: &Tensor, recycled: Option<Tensor>) -> Result<Tensor, OpError> {
    let n = x.numel();
    let data = match x.data() {
        TensorData::F32(v) => {
            let mut o = recycled_f32(recycled, n);
            o.extend(v.iter().map(|&x| 1.0 / (1.0 + (-x).exp())));
            TensorData::F32(o)
        }
        TensorData::F16(v) => {
            let mut o = recycled_f16(recycled, n);
            o.extend(v.iter().map(|x| x.sigmoid()));
            TensorData::F16(o)
        }
        d => {
            return Err(OpError::Semantics(format!(
                "Sigmoid: unsupported dtype {}",
                d.dtype()
            )))
        }
    };
    Ok(Tensor::new(Shape::from_slice(x.shape()), data)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::F16;

    #[test]
    fn add_i32_bias_broadcast() {
        // Eq. 5's bias add: [2,3] + [3].
        let acc = Tensor::from_i32(&[2, 3], vec![1, 2, 3, 4, 5, 6]).unwrap();
        let bias = Tensor::from_i32(&[3], vec![10, 20, 30]).unwrap();
        let y = binary(BinOp::Add, &acc, &bias).unwrap();
        assert_eq!(y.as_i32().unwrap(), &[11, 22, 33, 14, 25, 36]);
    }

    #[test]
    fn mul_f32_scalar_broadcast() {
        // The rescale Mul: tensor * scalar Quant_scale.
        let x = Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let s = Tensor::scalar_f32(0.25);
        let y = binary(BinOp::Mul, &x, &s).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[0.25, 0.5, 0.75, 1.0]);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let a = Tensor::from_f32(&[1], vec![1.0]).unwrap();
        let b = Tensor::from_i32(&[1], vec![1]).unwrap();
        assert!(binary(BinOp::Add, &a, &b).is_err());
    }

    #[test]
    fn i32_add_wraps() {
        let a = Tensor::from_i32(&[1], vec![i32::MAX]).unwrap();
        let b = Tensor::from_i32(&[1], vec![1]).unwrap();
        let y = binary(BinOp::Add, &a, &b).unwrap();
        assert_eq!(y.as_i32().unwrap(), &[i32::MIN]);
    }

    #[test]
    fn relu_variants() {
        let f = Tensor::from_f32(&[3], vec![-1.0, 0.0, 2.0]).unwrap();
        assert_eq!(relu(&f).unwrap().as_f32().unwrap(), &[0.0, 0.0, 2.0]);
        let i = Tensor::from_i32(&[3], vec![-5, 0, 5]).unwrap();
        assert_eq!(relu(&i).unwrap().as_i32().unwrap(), &[0, 0, 5]);
        let q = Tensor::from_i8(&[2], vec![-7, 7]).unwrap();
        assert_eq!(relu(&q).unwrap().as_i8().unwrap(), &[0, 7]);
    }

    #[test]
    fn clip_bounds_and_nan() {
        let x = Tensor::from_f32(&[5], vec![-9.0, -1.0, 0.5, 7.0, f32::NAN]).unwrap();
        let lo = Tensor::scalar_f32(-7.0);
        let hi = Tensor::scalar_f32(7.0);
        let y = clip(&x, Some(&lo), Some(&hi)).unwrap();
        let v = y.as_f32().unwrap();
        assert_eq!(&v[..4], &[-7.0, -1.0, 0.5, 7.0]);
        assert!(v[4].is_nan(), "Clip must propagate NaN (numpy semantics)");
        // One-sided and missing bounds.
        let y = clip(&x, Some(&lo), None).unwrap();
        assert_eq!(y.as_f32().unwrap()[0], -7.0);
        let y = clip(&x, None, None).unwrap();
        assert_eq!(y.as_f32().unwrap()[..4], [-9.0, -1.0, 0.5, 7.0]);
        // Non-scalar bound rejected.
        let bad = Tensor::from_f32(&[2], vec![0.0, 1.0]).unwrap();
        assert!(clip(&x, Some(&bad), None).is_err());
    }

    #[test]
    fn tanh_f16_is_rounded_f16() {
        let x = Tensor::from_f16(&[1], vec![F16::from_f32(1.0)]).unwrap();
        let y = tanh(&x).unwrap();
        let got = y.as_f16().unwrap()[0];
        // Must be the f16-rounded value of tanh(1.0) = 0.761594...
        assert_eq!(got.0, F16::from_f32(0.7615942_f32).0);
    }

    #[test]
    fn sigmoid_f32() {
        let x = Tensor::from_f32(&[2], vec![0.0, 100.0]).unwrap();
        let y = sigmoid(&x).unwrap();
        assert_eq!(y.as_f32().unwrap()[0], 0.5);
        assert_eq!(y.as_f32().unwrap()[1], 1.0);
    }

    #[test]
    fn into_variants_match_allocating_forms() {
        let acc = Tensor::from_i32(&[2, 3], vec![1, -2, 3, -4, 5, -6]).unwrap();
        let bias = Tensor::from_i32(&[3], vec![10, 20, 30]).unwrap();
        let spare = || Some(Tensor::from_i32(&[32], vec![7; 32]).unwrap());
        assert_eq!(
            binary(BinOp::Add, &acc, &bias).unwrap(),
            binary_into(BinOp::Add, &acc, &bias, spare()).unwrap()
        );
        let f = Tensor::from_f32(&[4], vec![-1.5, 0.0, 2.5, -0.1]).unwrap();
        let fspare = || Some(Tensor::from_f32(&[2], vec![0.0; 2]).unwrap());
        assert_eq!(relu(&f).unwrap(), relu_into(&f, fspare()).unwrap());
        assert_eq!(tanh(&f).unwrap(), tanh_into(&f, fspare()).unwrap());
        assert_eq!(sigmoid(&f).unwrap(), sigmoid_into(&f, fspare()).unwrap());
    }

    #[test]
    fn f16_add_rounds_per_op() {
        // 2048 + 1 in f16: 2049 is not representable (spacing is 2 there),
        // ties-to-even keeps 2048.
        let a = Tensor::from_f16(&[1], vec![F16::from_f32(2048.0)]).unwrap();
        let b = Tensor::from_f16(&[1], vec![F16::ONE]).unwrap();
        let y = binary(BinOp::Add, &a, &b).unwrap();
        assert_eq!(y.as_f16().unwrap()[0].to_f32(), 2048.0);
    }
}

#[cfg(test)]
mod bcast_prop_tests {
    use super::*;
    use crate::tensor::{BroadcastIndexer, Tensor};
    use crate::train::Rng;

    /// Reference implementation: always the generic indexer.
    fn binary_reference(op: BinOp, a: &Tensor, b: &Tensor) -> Tensor {
        let out_shape = crate::tensor::broadcast_shape(a.shape(), b.shape()).unwrap();
        let n: usize = out_shape.iter().product();
        let ia = BroadcastIndexer::new(&out_shape, a.shape());
        let ib = BroadcastIndexer::new(&out_shape, b.shape());
        let av = a.as_f32().unwrap();
        let bv = b.as_f32().unwrap();
        let v: Vec<f32> = (0..n)
            .map(|i| apply_f32(op, av[ia.map(i)], bv[ib.map(i)]))
            .collect();
        Tensor::from_f32(&out_shape, v).unwrap()
    }

    /// Property: every fast path in `binary` agrees with the generic
    /// indexer across random shapes and broadcast patterns (guards the
    /// §Perf fast paths).
    #[test]
    fn fast_paths_match_reference() {
        let mut rng = Rng::new(0xFA57);
        for case in 0..300 {
            // Random output shape, rank 1..4, dims 1..5.
            let rank = 1 + rng.below(4);
            let out_shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(5)).collect();
            // b: randomly degrade axes to 1 and possibly drop leading dims.
            let keep_from = rng.below(rank);
            let mut b_shape: Vec<usize> = out_shape[keep_from..].to_vec();
            for d in &mut b_shape {
                if rng.below(2) == 0 {
                    *d = 1;
                }
            }
            if b_shape.is_empty() {
                b_shape = vec![];
            }
            let n_a: usize = out_shape.iter().product();
            let n_b: usize = b_shape.iter().product::<usize>().max(1);
            let a = Tensor::from_f32(
                &out_shape,
                (0..n_a).map(|_| rng.range_f32(-4.0, 4.0)).collect(),
            )
            .unwrap();
            let b = Tensor::from_f32(
                &b_shape,
                (0..n_b).map(|_| rng.range_f32(-4.0, 4.0)).collect(),
            )
            .unwrap();
            for op in [BinOp::Add, BinOp::Mul, BinOp::Sub] {
                let fast = binary(op, &a, &b).unwrap();
                let slow = binary_reference(op, &a, &b);
                assert_eq!(
                    fast, slow,
                    "case {case}: op {op:?} a{:?} b{:?}",
                    out_shape, b_shape
                );
                // And the mirrored argument order.
                let fast = binary(op, &b, &a).unwrap();
                let slow = binary_reference(op, &b, &a);
                assert_eq!(fast, slow, "case {case} swapped");
            }
        }
    }
}
