//! QuantizeLinear / DequantizeLinear (ONNX opset 13 per-tensor form).
//!
//! In the paper's patterns `QuantizeLinear` is used with `y_scale = 1`,
//! `y_zero_point = 0` purely as the **rounding + clipping** stage after
//! the Mul-codified rescale (§3.1); the zero-point *dtype* selects int8
//! vs uint8 output. `DequantizeLinear` re-enters float space before the
//! Tanh/Sigmoid activations (Figs. 4–6). Implemented to the full operator
//! contract: y = saturate(round(x / y_scale) + y_zero_point) with
//! round-half-to-nearest-even, matching ONNXruntime bit-for-bit.

use super::OpError;
use crate::tensor::{recycled_f32, recycled_i8, recycled_u8, DType, Shape, Tensor, TensorData};

/// Round half to even ("banker's rounding"), the rounding ONNX specifies
/// for QuantizeLinear. `f32::round` rounds half away from zero, which
/// differs on exact .5 values — those occur constantly with power-of-two
/// scales, so this matters for bit-exactness.
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    // IEEE 754 roundTiesToEven — a single hardware rounding instruction
    // on x86 (roundss) vs the branchy tie-fixup this replaced (§Perf).
    x.round_ties_even()
}

/// Saturating clamp of QuantizeLinear against an integer range, kept in
/// f32 so the caller picks the container cast. Every saturate in the
/// stack (here, the fused epilogues in [`super::fused`], hwsim) derives
/// its `(lo, hi)` from [`crate::quant::QType::range`] and funnels through
/// this one clamp, so a new width cannot drift the bounds anywhere.
#[inline]
pub(crate) fn saturate_range(v: f32, lo: i32, hi: i32) -> f32 {
    v.clamp(lo as f32, hi as f32)
}

/// Saturating f32 -> i8 cast of QuantizeLinear (shared with the fused
/// epilogue in [`super::fused`], which must replicate it bit for bit).
/// Bounds derived from the int8 logical range, not restated.
#[inline]
pub(crate) fn saturate_i8(v: f32) -> i8 {
    let (lo, hi) = crate::quant::QType::I8.range();
    saturate_range(v, lo, hi) as i8
}

/// See [`saturate_i8`].
#[inline]
pub(crate) fn saturate_u8(v: f32) -> u8 {
    let (lo, hi) = crate::quant::QType::U8.range();
    saturate_range(v, lo, hi) as u8
}

/// ONNX `QuantizeLinear` (per-tensor): output dtype = zero-point dtype.
pub fn quantize_linear(
    x: &Tensor,
    y_scale: &Tensor,
    y_zero_point: Option<&Tensor>,
) -> Result<Tensor, OpError> {
    quantize_linear_into(x, y_scale, y_zero_point, None)
}

/// [`quantize_linear`] into recycled storage (identical values; the
/// zero-point scalar is read without the widening `Vec` of the old path).
pub fn quantize_linear_into(
    x: &Tensor,
    y_scale: &Tensor,
    y_zero_point: Option<&Tensor>,
    recycled: Option<Tensor>,
) -> Result<Tensor, OpError> {
    let scale = y_scale.as_f32()?[0];
    if scale <= 0.0 || !scale.is_finite() {
        return Err(OpError::Semantics(format!("invalid y_scale {scale}")));
    }
    let xv = x.as_f32()?;
    let (out_dtype, zp) = match y_zero_point {
        None => (DType::U8, 0i32),
        Some(z) => (z.dtype(), z.quantized_scalar_i32()?),
    };
    let inv = 1.0 / scale;
    match out_dtype {
        DType::I8 => {
            let mut v = recycled_i8(recycled, xv.len());
            v.extend(
                xv.iter()
                    .map(|&x| saturate_i8(round_half_even(x * inv) + zp as f32)),
            );
            Ok(Tensor::new(Shape::from_slice(x.shape()), TensorData::I8(v))?)
        }
        DType::U8 => {
            let mut v = recycled_u8(recycled, xv.len());
            v.extend(
                xv.iter()
                    .map(|&x| saturate_u8(round_half_even(x * inv) + zp as f32)),
            );
            Ok(Tensor::new(Shape::from_slice(x.shape()), TensorData::U8(v))?)
        }
        d => Err(OpError::Semantics(format!(
            "QuantizeLinear zero_point must be INT8/UINT8, got {d}"
        ))),
    }
}

/// ONNX `DequantizeLinear` (per-tensor): y = (x - zero_point) * scale.
pub fn dequantize_linear(
    x: &Tensor,
    x_scale: &Tensor,
    x_zero_point: Option<&Tensor>,
) -> Result<Tensor, OpError> {
    dequantize_linear_into(x, x_scale, x_zero_point, None)
}

/// [`dequantize_linear`] into recycled storage. The per-source loops
/// widen inline (same `(q - zp) as f32 * scale` arithmetic), replacing
/// the old path's whole-tensor `as_quantized_i32` intermediate — the
/// second steady-state allocation on the Figs. 4–6 activation path.
pub fn dequantize_linear_into(
    x: &Tensor,
    x_scale: &Tensor,
    x_zero_point: Option<&Tensor>,
    recycled: Option<Tensor>,
) -> Result<Tensor, OpError> {
    let scale = x_scale.as_f32()?[0];
    let zp = match x_zero_point {
        None => 0i32,
        Some(z) => z.quantized_scalar_i32()?,
    };
    let mut v = recycled_f32(recycled, x.numel());
    match x.data() {
        TensorData::I8(q) => v.extend(q.iter().map(|&q| (q as i32 - zp) as f32 * scale)),
        TensorData::U8(q) => v.extend(q.iter().map(|&q| (q as i32 - zp) as f32 * scale)),
        TensorData::I32(q) => v.extend(q.iter().map(|&q| (q - zp) as f32 * scale)),
        // Same error the old whole-tensor widening surfaced.
        d => {
            return Err(OpError::Tensor(crate::tensor::TensorError::DTypeMismatch {
                expected: DType::I8,
                got: d.dtype(),
            }))
        }
    }
    Ok(Tensor::new(Shape::from_slice(x.shape()), TensorData::F32(v))?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_half_even_cases() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(-2.5), -2.0);
        assert_eq!(round_half_even(1.4), 1.0);
        assert_eq!(round_half_even(1.6), 2.0);
        assert_eq!(round_half_even(127.5), 128.0);
        assert_eq!(round_half_even(126.5), 126.0);
    }

    #[test]
    fn quantize_saturates_int8() {
        let x = Tensor::from_f32(&[4], vec![-1000.0, -128.4, 127.4, 1000.0]).unwrap();
        let s = Tensor::scalar_f32(1.0);
        let zp = Tensor::scalar_i8(0);
        let q = quantize_linear(&x, &s, Some(&zp)).unwrap();
        assert_eq!(q.as_i8().unwrap(), &[-128, -128, 127, 127]);
    }

    #[test]
    fn quantize_uint8_via_zero_point_dtype() {
        // Paper §3.1: "an uint8 zero_point argument results in uint8 output".
        let x = Tensor::from_f32(&[3], vec![-5.0, 100.0, 300.0]).unwrap();
        let s = Tensor::scalar_f32(1.0);
        let zp = Tensor::scalar_u8(0);
        let q = quantize_linear(&x, &s, Some(&zp)).unwrap();
        assert_eq!(q.dtype(), DType::U8);
        assert_eq!(q.as_u8().unwrap(), &[0, 100, 255]);
    }

    #[test]
    fn quantize_scale_divides() {
        let x = Tensor::from_f32(&[2], vec![1.0, -1.0]).unwrap();
        let s = Tensor::scalar_f32(0.5);
        let zp = Tensor::scalar_i8(0);
        let q = quantize_linear(&x, &s, Some(&zp)).unwrap();
        assert_eq!(q.as_i8().unwrap(), &[2, -2]);
    }

    #[test]
    fn quantize_rounds_half_even() {
        // 0.5/1.0 -> 0, 1.5 -> 2, 2.5 -> 2: distinguishable from
        // round-half-away which would give 1, 2, 3.
        let x = Tensor::from_f32(&[3], vec![0.5, 1.5, 2.5]).unwrap();
        let s = Tensor::scalar_f32(1.0);
        let zp = Tensor::scalar_i8(0);
        let q = quantize_linear(&x, &s, Some(&zp)).unwrap();
        assert_eq!(q.as_i8().unwrap(), &[0, 2, 2]);
    }

    #[test]
    fn dequantize_round_trip() {
        let q = Tensor::from_i8(&[3], vec![-128, 0, 127]).unwrap();
        let s = Tensor::scalar_f32(0.25);
        let f = dequantize_linear(&q, &s, None).unwrap();
        assert_eq!(f.as_f32().unwrap(), &[-32.0, 0.0, 31.75]);
    }

    #[test]
    fn dequantize_i32_bias_path() {
        // DequantizeLinear also accepts INT32 (used for bias inspection).
        let q = Tensor::from_i32(&[2], vec![1000, -1000]).unwrap();
        let s = Tensor::scalar_f32(0.001);
        let f = dequantize_linear(&q, &s, None).unwrap();
        assert!((f.as_f32().unwrap()[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_scale() {
        let x = Tensor::from_f32(&[1], vec![1.0]).unwrap();
        assert!(quantize_linear(&x, &Tensor::scalar_f32(0.0), None).is_err());
        assert!(quantize_linear(&x, &Tensor::scalar_f32(-1.0), None).is_err());
    }
}
