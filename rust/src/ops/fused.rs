//! Fused quantized kernels — the execution half of the plan-time graph
//! optimizer (`crate::opt`).
//!
//! Each kernel replaces a whole codified operator chain with one step:
//!
//! * [`FusedQFc`] / [`FusedQConv`] — the integer accumulate (reusing the
//!   packed int8 GEMM / im2col kernels through their existing `_into`
//!   entry points, accumulator parked in per-step scratch) followed by a
//!   SINGLE epilogue pass doing bias add, the Mul-codified rescale, the
//!   optional ReLU, and the round+saturate requantization — writing the
//!   final i8/u8 output directly. The unfused chain executes the same
//!   arithmetic as 5–7 separate full passes over the activation tensor
//!   with an intermediate buffer each.
//! * [`FusedActLut`] — the Dequantize → (f16) activation → Quantize chain
//!   as a 256-entry table lookup ([`ActLut::build_exact`]).
//!
//! **Bit-identity contract:** every per-element operation here is the
//! same f32/i32 scalar sequence the unfused kernels perform, in the same
//! order — `(acc +wrap bias) as f32 * s1 [* s2] [max 0] * (1/scale)`,
//! `round_half_even`, `+ zp`, saturate — so fused plans are bit-identical
//! to unfused plans and to the legacy interpreter on every input
//! (differential proof: `tests/executor_plan.rs`; the epilogue is
//! elementwise, so the GEMM's blocking/parallelism guarantees carry over
//! unchanged).

use super::isa::Isa;
use super::OpError;
use super::{bitpack, conv, matmul, qlinear};
use crate::onnx::shape::ConvAttrs;
use crate::parallel::ThreadPool;
use crate::quant::lut::ActLut;
use crate::quant::QType;
use crate::tensor::{
    recycled_i32_zeroed, recycled_i64, recycled_i8, recycled_u8, DType, Shape, Tensor, TensorData,
};

/// How a fused FC stage's activation edge travels between two fused
/// kernels — the plan-time packed-activation decision (stamped by the
/// optimizer's pairing pass; see `opt`). `Container` is both the default
/// and the universal fallback: the consumer dispatches on the ARRIVING
/// dtype, so a producer that declines to pack at run time (bitplane with
/// a 0 in the batch) degrades to the container path without any extra
/// coordination.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ActPack {
    /// Plain i8/u8 container tensor (the unpaired form).
    #[default]
    Container,
    /// `[m, ceil(n/2)]` u8 nibble rows — two int4 values per byte,
    /// low nibble = even column ([`bitpack::pack_nibble_rows`]).
    /// Infallible at run time: the producing epilogue's `out_qtype`
    /// saturates to `[-8, 7]` by construction.
    Nibble,
    /// `[m, words(n)]` i64 sign bitplanes for the consumer's XNOR GEMM.
    /// Runtime-gated: the bipolar epilogue can emit 0 (a bit plane can't
    /// represent it), so any non-±1 value falls the batch back to the
    /// container form.
    Bitplane,
}

/// The baked scalar tail of a quantized FC/conv chain: `Cast → Mul(s1)
/// [→ Mul(s2)] [→ Relu] → QuantizeLinear(1/inv_scale, zp)`.
pub struct QEpilogue {
    pub s1: f32,
    pub s2: Option<f32>,
    pub relu: bool,
    /// `1.0 / q_scale`, the same reciprocal `quantize_linear_into`
    /// computes per call (baking it changes nothing: same f32 value).
    pub inv_scale: f32,
    pub zp: i32,
    pub out_qtype: QType,
}

impl QEpilogue {
    /// The exact unfused per-element sequence on a post-bias accumulator
    /// value, up to (but not including) the saturating cast.
    #[inline]
    fn rescale(&self, v: i32) -> f32 {
        let mut x = v as f32; // Cast INT32 -> FLOAT
        x *= self.s1; // Mul(Quant_scale)
        if let Some(s2) = self.s2 {
            x *= s2; // Mul(Quant_shift)
        }
        if self.relu {
            x = x.max(0.0); // Relu (f32)
        }
        qlinear::round_half_even(x * self.inv_scale) + self.zp as f32
    }
}

/// How the chain's bias Add broadcasts over the accumulator.
pub enum BiasLayout<'a> {
    None,
    /// FC: bias `[N]` (or `[1, N]`) cycling per output row.
    PerColumn(&'a [i32]),
    /// Conv: bias `[1, M, 1, 1]`, constant over each `oh*ow` patch.
    PerChannel { bias: &'a [i32], patch: usize },
}

/// Bias source for one contiguous accumulator run.
enum BiasSrc<'a> {
    /// One bias value for the whole run (per-channel patch, or no-bias
    /// as 0 — `v.wrapping_add(0) == v`, so the sequences coincide).
    Splat(i32),
    /// One bias value per element (a per-column row), same length as the
    /// run.
    Slice(&'a [i32]),
}

/// Lanes per epilogue vector step (the AVX2 width; the 128-bit ISAs run
/// two half-width steps per call so every ISA shares this blocking).
const EPI_LANES: usize = 8;

/// Rescale + saturate one accumulator run into `o`. The SIMD path runs
/// the float sequence [`EPI_LANES`] at a time into a stack buffer; the
/// final saturating cast stays SCALAR per lane deliberately: Rust's
/// `NaN as i8` is 0 while the vector float->int conversions return an
/// `INT_MIN` sentinel on NaN/out-of-range, so a vectorized cast would
/// diverge from the scalar kernel exactly on the degenerate epilogues
/// (inf/NaN scales). Every vector lane upstream of the cast performs the
/// same IEEE-754 single-precision operation sequence as
/// [`QEpilogue::rescale`], so the f32 bits entering the cast are
/// identical — see EXPERIMENTS.md §SIMD for the full argument.
fn emit_run<T>(
    o: &mut Vec<T>,
    run: &[i32],
    bias: BiasSrc<'_>,
    epi: &QEpilogue,
    isa: Isa,
    sat: impl Fn(f32) -> T,
) {
    let len = run.len();
    let mut i = 0;
    if !matches!(isa, Isa::Scalar) {
        let mut tmp = [0f32; EPI_LANES];
        let splat = match bias {
            BiasSrc::Splat(v) => [v; EPI_LANES],
            BiasSrc::Slice(_) => [0; EPI_LANES],
        };
        while i + EPI_LANES <= len {
            let bl = match bias {
                BiasSrc::Splat(_) => &splat[..],
                BiasSrc::Slice(b) => &b[i..i + EPI_LANES],
            };
            rescale_lanes(isa, &run[i..i + EPI_LANES], bl, epi, &mut tmp);
            for &x in &tmp {
                o.push(sat(x));
            }
            i += EPI_LANES;
        }
    }
    for j in i..len {
        let bv = match bias {
            BiasSrc::Splat(v) => v,
            BiasSrc::Slice(b) => b[j],
        };
        o.push(sat(epi.rescale(run[j].wrapping_add(bv))));
    }
}

/// One 8-lane vector step of [`QEpilogue::rescale`] over
/// `acc[i] +wrap bias[i]`. The `_` arm replays the scalar sequence, so
/// the function is total even if a SIMD value reaches it on a target
/// with no vector body (unreachable after [`Isa::normalized`]).
fn rescale_lanes(
    isa: Isa,
    acc: &[i32],
    bias: &[i32],
    epi: &QEpilogue,
    out: &mut [f32; EPI_LANES],
) {
    debug_assert!(acc.len() >= EPI_LANES && bias.len() >= EPI_LANES);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: write_quantized normalized the ISA for this host, and
        // both slices cover at least EPI_LANES i32s (asserted above).
        Isa::Avx2 => unsafe {
            x86::rescale8_avx2(acc.as_ptr(), bias.as_ptr(), epi, out.as_mut_ptr())
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above; two disjoint half-width steps.
        Isa::Sse41 => unsafe {
            x86::rescale4_sse41(acc.as_ptr(), bias.as_ptr(), epi, out.as_mut_ptr());
            x86::rescale4_sse41(
                acc.as_ptr().add(4),
                bias.as_ptr().add(4),
                epi,
                out.as_mut_ptr().add(4),
            );
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; bounds as above.
        Isa::Neon => unsafe {
            arm::rescale4_neon(acc.as_ptr(), bias.as_ptr(), epi, out.as_mut_ptr());
            arm::rescale4_neon(
                acc.as_ptr().add(4),
                bias.as_ptr().add(4),
                epi,
                out.as_mut_ptr().add(4),
            );
        },
        _ => {
            for l in 0..EPI_LANES {
                out[l] = epi.rescale(acc[l].wrapping_add(bias[l]));
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::QEpilogue;
    #[allow(clippy::wildcard_imports)]
    use std::arch::x86_64::*;

    /// 8 lanes of the epilogue float sequence. Lane-for-lane IEEE-754
    /// twins of the scalar ops: `vpaddd` wraps like `wrapping_add`,
    /// `vcvtdq2ps` rounds-to-nearest-even like `as f32`, `vmulps` is the
    /// scalar `*`, `vmaxps(x, 0)` returns 0 for NaN exactly like
    /// `f32::max(NaN, 0.0)`, and `vroundps` with mode 8 (nearest-even,
    /// no-exc) IS `round_ties_even`.
    ///
    /// Safety: caller verified AVX2 and that `acc`/`bias` point at >= 8
    /// readable i32s and `out` at >= 8 writable f32s.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn rescale8_avx2(
        acc: *const i32,
        bias: *const i32,
        epi: &QEpilogue,
        out: *mut f32,
    ) {
        let v = _mm256_add_epi32(
            _mm256_loadu_si256(acc as *const __m256i),
            _mm256_loadu_si256(bias as *const __m256i),
        );
        let mut x = _mm256_cvtepi32_ps(v);
        x = _mm256_mul_ps(x, _mm256_set1_ps(epi.s1));
        if let Some(s2) = epi.s2 {
            x = _mm256_mul_ps(x, _mm256_set1_ps(s2));
        }
        if epi.relu {
            x = _mm256_max_ps(x, _mm256_setzero_ps());
        }
        x = _mm256_mul_ps(x, _mm256_set1_ps(epi.inv_scale));
        x = _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(x);
        x = _mm256_add_ps(x, _mm256_set1_ps(epi.zp as f32));
        _mm256_storeu_ps(out, x);
    }

    /// 4 lanes of the epilogue float sequence (see [`rescale8_avx2`] for
    /// the per-op equivalence argument — same instructions, 128-bit).
    ///
    /// Safety: caller verified SSE4.1; pointers cover >= 4 elements.
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn rescale4_sse41(
        acc: *const i32,
        bias: *const i32,
        epi: &QEpilogue,
        out: *mut f32,
    ) {
        let v = _mm_add_epi32(
            _mm_loadu_si128(acc as *const __m128i),
            _mm_loadu_si128(bias as *const __m128i),
        );
        let mut x = _mm_cvtepi32_ps(v);
        x = _mm_mul_ps(x, _mm_set1_ps(epi.s1));
        if let Some(s2) = epi.s2 {
            x = _mm_mul_ps(x, _mm_set1_ps(s2));
        }
        if epi.relu {
            x = _mm_max_ps(x, _mm_setzero_ps());
        }
        x = _mm_mul_ps(x, _mm_set1_ps(epi.inv_scale));
        x = _mm_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(x);
        x = _mm_add_ps(x, _mm_set1_ps(epi.zp as f32));
        _mm_storeu_ps(out, x);
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::QEpilogue;
    #[allow(clippy::wildcard_imports)]
    use std::arch::aarch64::*;

    /// 4 lanes of the epilogue float sequence. `scvtf` converts i32->f32
    /// with round-to-nearest-even like `as f32`, `fmaxnm` matches Rust
    /// `f32::max` (returns the non-NaN operand — plain `fmax` would
    /// propagate NaN and diverge), and `frintn` IS `round_ties_even`.
    ///
    /// Safety: NEON is baseline on aarch64; pointers cover >= 4 elements.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn rescale4_neon(
        acc: *const i32,
        bias: *const i32,
        epi: &QEpilogue,
        out: *mut f32,
    ) {
        let v = vaddq_s32(vld1q_s32(acc), vld1q_s32(bias));
        let mut x = vcvtq_f32_s32(v);
        x = vmulq_n_f32(x, epi.s1);
        if let Some(s2) = epi.s2 {
            x = vmulq_n_f32(x, s2);
        }
        if epi.relu {
            x = vmaxnmq_f32(x, vdupq_n_f32(0.0));
        }
        x = vmulq_n_f32(x, epi.inv_scale);
        x = vrndnq_f32(x);
        x = vaddq_f32(x, vdupq_n_f32(epi.zp as f32));
        vst1q_f32(out, x);
    }
}

/// One pass over the i32 accumulator: bias add (wrapping, exactly the
/// unfused i32 `Add`), epilogue rescale (ISA-dispatched, bit-identical —
/// see [`emit_run`]), saturate, write the quantized output into recycled
/// storage.
fn write_quantized(
    acc: &[i32],
    bias: BiasLayout<'_>,
    epi: &QEpilogue,
    shape: Shape,
    isa: Isa,
    recycled: Option<Tensor>,
) -> Result<Tensor, OpError> {
    let isa = isa.normalized();
    let n = acc.len();
    // Satellite fix + width generalization in one: the clamp bounds come
    // from the qtype's derived logical range (single source with
    // `qlinear::saturate_range`), not restated per container — a narrow
    // out_qtype (int4, bipolar) saturates to ITS range while writing the
    // same i8/u8 container tensor the rest of the plan consumes.
    let (lo, hi) = epi.out_qtype.range();
    macro_rules! emit {
        ($recycle:ident, $sat:expr, $variant:ident) => {{
            let mut o = $recycle(recycled, n);
            match bias {
                BiasLayout::PerColumn(b) if !b.is_empty() => {
                    for row in acc.chunks_exact(b.len()) {
                        emit_run(&mut o, row, BiasSrc::Slice(b), epi, isa, $sat);
                    }
                }
                BiasLayout::PerChannel { bias: b, patch } if !b.is_empty() && patch > 0 => {
                    let mut pos = 0;
                    while pos < n {
                        for &bv in b {
                            emit_run(
                                &mut o,
                                &acc[pos..pos + patch],
                                BiasSrc::Splat(bv),
                                epi,
                                isa,
                                $sat,
                            );
                            pos += patch;
                        }
                    }
                }
                _ => emit_run(&mut o, acc, BiasSrc::Splat(0), epi, isa, $sat),
            }
            TensorData::$variant(o)
        }};
    }
    let data = match epi.out_qtype.dtype() {
        crate::tensor::DType::I8 => emit!(
            recycled_i8,
            |v: f32| qlinear::saturate_range(v, lo, hi) as i8,
            I8
        ),
        _ => emit!(
            recycled_u8,
            |v: f32| qlinear::saturate_range(v, lo, hi) as u8,
            U8
        ),
    };
    Ok(Tensor::new(shape, data)?)
}

/// Fused quantized fully-connected block: `MatMulInteger [+Add] + Cast +
/// Mul[+Mul] [+Relu] + QuantizeLinear` as one kernel. The weight fields
/// extend [`super::Kernel::MatMulIntegerPrebound`]'s (packed weights with
/// the widened-i32 fallback) to whatever width the optimizer baked —
/// i8 panels, int4 nibble panels, or bipolar bit columns.
pub struct FusedQFc {
    pub bw: Vec<i32>,
    pub bp: Option<bitpack::PackedWeights>,
    pub k: usize,
    pub n: usize,
    pub a_zp: i32,
    /// Row-broadcast bias, length `n`.
    pub bias: Option<Vec<i32>>,
    /// Plan-time kernel ISA for the packed GEMM and the epilogue pass
    /// (stamped by the optimizer from [`Isa::active`]; bit-identical
    /// results whatever it names).
    pub isa: Isa,
    pub epi: QEpilogue,
    /// How this stage EMITS its output when the sole consumer is another
    /// fused FC ([`ActPack::Container`] unless the pairing pass fired).
    pub emit: ActPack,
    /// What activation form this stage ACCEPTS from its paired producer.
    /// The run-time dispatch keys on the arriving dtype, so a container
    /// tensor (unpaired edge, or a bitplane producer's fallback batch)
    /// always takes the ordinary path regardless of this field.
    pub a_pack: ActPack,
}

impl FusedQFc {
    /// `scratch[0]` parks the i32 accumulator between runs (the only
    /// intermediate buffer of the whole chain); `scratch[1]` the XNOR
    /// activation bit-pack buffer when the weights are bipolar;
    /// `scratch[2]` the i8 container staging buffer when this stage emits
    /// a packed activation edge; `recycled` is the retired quantized
    /// output — steady state allocates nothing
    /// (`tests/alloc_regression.rs`).
    pub fn run(
        &self,
        x: &Tensor,
        recycled: Option<Tensor>,
        scratch: &mut [Option<Tensor>; 3],
    ) -> Result<Tensor, OpError> {
        let [acc_scratch, bits_scratch, pack_scratch] = scratch;
        let acc = match (x.data(), self.a_pack) {
            // Paired edge, nibble form: rows of two int4 values per byte
            // against the widened i32 weights. Bit-identical to unpacking
            // into the i8 container first — same values, same k order,
            // each product exact in i32 (see `bitpack::gemm_i4a_bytes`).
            (TensorData::U8(bytes), ActPack::Nibble) => {
                let row_bytes = self.k.div_ceil(2);
                if self.a_zp != 0 || row_bytes == 0 || bytes.len() % row_bytes != 0 {
                    return Err(OpError::Semantics(format!(
                        "FusedQFc: nibble-packed activation rows do not fit k={} (len {}, a_zp {})",
                        self.k,
                        bytes.len(),
                        self.a_zp
                    )));
                }
                let m = bytes.len() / row_bytes;
                let mut c = recycled_i32_zeroed(acc_scratch.take(), m * self.n);
                bitpack::gemm_i4a_bytes_par_isa(
                    ThreadPool::global(),
                    self.isa,
                    bytes,
                    m,
                    self.k,
                    &self.bw,
                    self.n,
                    &mut c,
                );
                Tensor::new(Shape::from_slice(&[m, self.n]), TensorData::I32(c))?
            }
            // Paired edge, bitplane form: the producer already packed the
            // sign bits, so the XNOR GEMM runs without this stage's own
            // pack pass (`bits_scratch` stays parked).
            (TensorData::I64(bits), ActPack::Bitplane) => {
                let Some(bitpack::PackedWeights::Bipolar(bb)) = self.bp.as_ref() else {
                    return Err(OpError::Semantics(
                        "FusedQFc: bitplane activation arrived but weights are not bipolar"
                            .to_string(),
                    ));
                };
                let words = bitpack::bit_words(self.k);
                if self.a_zp != 0 || words == 0 || bits.len() % words != 0 {
                    return Err(OpError::Semantics(format!(
                        "FusedQFc: bitplane activation rows do not fit k={} (len {}, a_zp {})",
                        self.k,
                        bits.len(),
                        self.a_zp
                    )));
                }
                let m = bits.len() / words;
                let mut c = recycled_i32_zeroed(acc_scratch.take(), m * self.n);
                bitpack::gemm_xnor_par_isa(ThreadPool::global(), self.isa, bits, bb, m, &mut c);
                Tensor::new(Shape::from_slice(&[m, self.n]), TensorData::I32(c))?
            }
            // Container form — unpaired edges AND every fallback.
            _ => matmul::matmul_integer_packed_into(
                x,
                &self.bw,
                self.bp.as_ref(),
                self.k,
                self.n,
                self.a_zp,
                self.isa,
                acc_scratch.take(),
                bits_scratch,
            )?,
        };
        let bias = match &self.bias {
            Some(b) => BiasLayout::PerColumn(b),
            None => BiasLayout::None,
        };
        if self.emit == ActPack::Container {
            let out = write_quantized(
                acc.as_i32()?,
                bias,
                &self.epi,
                Shape::from_slice(acc.shape()),
                self.isa,
                recycled,
            )?;
            *acc_scratch = Some(acc);
            return Ok(out);
        }
        // Packed emission: quantize into the staging container first (the
        // exact same epilogue pass — the packed form re-encodes the SAME
        // saturated values, so three-way bit-identity is preserved), then
        // pack the rows for the paired consumer. A fallback round retires
        // the container itself, so route an i8 retiree back to the
        // staging side; a packed retiree (u8/i64) seeds the packed buffer.
        let mut staging = pack_scratch.take();
        let mut packed_recycle = recycled;
        if staging.is_none()
            && packed_recycle
                .as_ref()
                .is_some_and(|t| t.dtype() == DType::I8)
        {
            staging = packed_recycle.take();
        }
        let container = write_quantized(
            acc.as_i32()?,
            bias,
            &self.epi,
            Shape::from_slice(acc.shape()),
            self.isa,
            staging,
        )?;
        *acc_scratch = Some(acc);
        let TensorData::I8(vals) = container.data() else {
            // Plan-time pairing only fires for i8-container out_qtypes;
            // reaching here means the plan is inconsistent.
            return Err(OpError::Semantics(
                "FusedQFc: packed emission requires an i8-container out_qtype".to_string(),
            ));
        };
        debug_assert_eq!(container.numel() % self.n.max(1), 0);
        let rows = container.numel() / self.n.max(1);
        match self.emit {
            ActPack::Nibble => {
                let row_bytes = self.n.div_ceil(2);
                let mut buf = recycled_u8(packed_recycle, rows * row_bytes);
                bitpack::pack_nibble_rows(vals, rows, self.n, &mut buf);
                let out = Tensor::new(Shape::from_slice(&[rows, row_bytes]), TensorData::U8(buf))?;
                *pack_scratch = Some(container);
                Ok(out)
            }
            ActPack::Bitplane => {
                // Pre-scan before touching the word buffer so a steady
                // fallback stream allocates nothing.
                if vals.iter().all(|&v| v == 1 || v == -1) {
                    let words = bitpack::bit_words(self.n);
                    let mut bits = recycled_i64(packed_recycle, rows * words);
                    if bitpack::pack_bits_rows(vals, rows, self.n, &mut bits) {
                        let out =
                            Tensor::new(Shape::from_slice(&[rows, words]), TensorData::I64(bits))?;
                        *pack_scratch = Some(container);
                        return Ok(out);
                    }
                }
                Ok(container)
            }
            ActPack::Container => unreachable!("handled above"),
        }
    }
}

/// Fused quantized convolution block: the same chain over `ConvInteger`.
/// Weight fields extend [`super::Kernel::ConvIntegerPrebound`]'s to the
/// optimizer-selected width (i8 / int4 / bipolar).
pub struct FusedQConv {
    pub wv: Vec<i32>,
    pub wp: Option<bitpack::PackedConvWeights>,
    pub m: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub x_zp: i32,
    pub attrs: ConvAttrs,
    /// Per-output-channel bias, length `m` (from the `[1, M, 1, 1]`
    /// initializer).
    pub bias: Option<Vec<i32>>,
    /// Plan-time kernel ISA (see [`FusedQFc::isa`]).
    pub isa: Isa,
    pub epi: QEpilogue,
}

impl FusedQConv {
    /// `scratch[0]` is the im2col column buffer, `scratch[1]` parks the
    /// i32 accumulator (`scratch[2]` is unused — conv stages never emit
    /// packed activation edges; the array is shared with [`FusedQFc`]);
    /// `recycled` the retired quantized output.
    pub fn run(
        &self,
        x: &Tensor,
        recycled: Option<Tensor>,
        scratch: &mut [Option<Tensor>; 3],
    ) -> Result<Tensor, OpError> {
        let [col_scratch, acc_scratch, _] = scratch;
        let acc = conv::conv_integer_packed_into(
            x,
            &self.wv,
            self.wp.as_ref(),
            self.m,
            self.c,
            self.kh,
            self.kw,
            self.x_zp,
            &self.attrs,
            self.isa,
            acc_scratch.take(),
            col_scratch,
        )?;
        let shape = acc.shape(); // [nb, m, oh, ow]
        let patch = shape[2] * shape[3];
        let bias = match &self.bias {
            Some(b) => BiasLayout::PerChannel { bias: b, patch },
            None => BiasLayout::None,
        };
        let out = write_quantized(
            acc.as_i32()?,
            bias,
            &self.epi,
            Shape::from_slice(acc.shape()),
            self.isa,
            recycled,
        )?;
        *acc_scratch = Some(acc);
        Ok(out)
    }
}

/// Fused activation chain as a 256-entry table over the 8-bit input —
/// see [`ActLut::build_exact`] for why a lookup is bit-identical to the
/// node chain.
pub struct FusedActLut {
    pub lut: ActLut,
    /// The planned input domain (i8 vs u8 — fixed by the checker's type
    /// of the dequantize input at plan time).
    pub in_qtype: QType,
}

impl FusedActLut {
    pub fn run(&self, x: &Tensor, recycled: Option<Tensor>) -> Result<Tensor, OpError> {
        let n = x.numel();
        let shape = Shape::from_slice(x.shape());
        // The dispatch keys on the CONTAINER dtypes; narrow logical
        // widths share their container's arm (the table already encodes
        // the narrow saturation).
        let in_dt = self.in_qtype.dtype();
        let out_dt = self.lut.out_qtype.dtype();
        let data = match (x.data(), in_dt, out_dt) {
            (TensorData::I8(v), DType::I8, DType::I8) => {
                let mut o = recycled_i8(recycled, n);
                o.extend(v.iter().map(|&q| self.lut.get_raw(q as u8) as i8));
                TensorData::I8(o)
            }
            (TensorData::I8(v), DType::I8, DType::U8) => {
                let mut o = recycled_u8(recycled, n);
                o.extend(v.iter().map(|&q| self.lut.get_raw(q as u8) as u8));
                TensorData::U8(o)
            }
            (TensorData::U8(v), DType::U8, DType::I8) => {
                let mut o = recycled_i8(recycled, n);
                o.extend(v.iter().map(|&q| self.lut.get_raw(q) as i8));
                TensorData::I8(o)
            }
            (TensorData::U8(v), DType::U8, DType::U8) => {
                let mut o = recycled_u8(recycled, n);
                o.extend(v.iter().map(|&q| self.lut.get_raw(q) as u8));
                TensorData::U8(o)
            }
            _ => {
                return Err(OpError::Semantics(format!(
                    "FusedActLut: input dtype {} does not match planned {:?} domain",
                    x.dtype(),
                    self.in_qtype
                )))
            }
        };
        Ok(Tensor::new(shape, data)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{elementwise, qlinear as ql};
    use crate::tensor::DType;

    fn epi(s1: f32, s2: Option<f32>, relu: bool, scale: f32, zp: i32, out: QType) -> QEpilogue {
        QEpilogue {
            s1,
            s2,
            relu,
            inv_scale: 1.0 / scale,
            zp,
            out_qtype: out,
        }
    }

    /// Reference: run the actual unfused kernels over the accumulator.
    #[allow(clippy::too_many_arguments)]
    fn reference_chain(
        acc: &Tensor,
        bias: Option<&Tensor>,
        s1: f32,
        s2: Option<f32>,
        relu: bool,
        scale: f32,
        zp: i32,
        out: QType,
    ) -> Tensor {
        let mut t = match bias {
            Some(b) => elementwise::binary(elementwise::BinOp::Add, acc, b).unwrap(),
            None => acc.clone(),
        };
        t = t.cast(DType::F32);
        t = elementwise::binary(
            elementwise::BinOp::Mul,
            &t,
            &Tensor::scalar_f32(s1),
        )
        .unwrap();
        if let Some(s2) = s2 {
            t = elementwise::binary(
                elementwise::BinOp::Mul,
                &t,
                &Tensor::scalar_f32(s2),
            )
            .unwrap();
        }
        if relu {
            t = elementwise::relu(&t).unwrap();
        }
        let zp = match out.dtype() {
            DType::I8 => Tensor::scalar_i8(zp as i8),
            _ => Tensor::scalar_u8(zp as u8),
        };
        ql::quantize_linear(&t, &Tensor::scalar_f32(scale), Some(&zp)).unwrap()
    }

    #[test]
    fn epilogue_matches_unfused_chain_elementwise() {
        // Accumulators spanning sign changes, saturation, and .5 ties.
        // n = 19 makes each per-column row 2 vector steps + a 3-wide
        // scalar tail, so every ISA exercises both paths of emit_run.
        let (m, n) = (4usize, 19usize);
        let acc_v: Vec<i32> = (0..m * n).map(|i| (i as i32 * 977 - 5000) * 3).collect();
        let acc = Tensor::from_i32(&[m, n], acc_v.clone()).unwrap();
        let bias_v: Vec<i32> = (0..n).map(|j| j as i32 * 97 - 250).collect();
        let bias = Tensor::from_i32(&[n], bias_v.clone()).unwrap();
        // Includes asymmetric zero points (§3.1 uint8 zp=128 and a
        // nonzero i8 zp): the `round -> + zp -> saturate` order must
        // match the unfused QuantizeLinear exactly.
        for (s1, s2, relu, scale, zp, out) in [
            (3.0, Some(1.0 / 8.0), false, 1.0, 0, QType::I8),
            (0.017, None, true, 1.0, 0, QType::U8),
            (5.0, Some(1.0 / 64.0), true, 0.5, 0, QType::I8),
            (0.02, None, false, 1.0, 128, QType::U8),
            (0.013, Some(0.5), true, 0.25, -16, QType::I8),
        ] {
            for isa in Isa::available() {
                let want = reference_chain(&acc, Some(&bias), s1, s2, relu, scale, zp, out);
                let got = write_quantized(
                    &acc_v,
                    BiasLayout::PerColumn(&bias_v),
                    &epi(s1, s2, relu, scale, zp, out),
                    Shape::from_slice(&[m, n]),
                    isa,
                    None,
                )
                .unwrap();
                assert_eq!(want, got, "{isa} s1={s1} s2={s2:?} relu={relu} zp={zp}");
                // No-bias form.
                let want = reference_chain(&acc, None, s1, s2, relu, scale, zp, out);
                let got = write_quantized(
                    &acc_v,
                    BiasLayout::None,
                    &epi(s1, s2, relu, scale, zp, out),
                    Shape::from_slice(&[m, n]),
                    isa,
                    None,
                )
                .unwrap();
                assert_eq!(want, got, "{isa} no-bias s1={s1} zp={zp}");
            }
        }
    }

    #[test]
    fn per_channel_bias_matches_conv_broadcast() {
        // [nb=2, m=3, oh*ow=10] accumulator vs the [1, M, 1, 1] Add —
        // patch = 10 gives each per-channel run one vector step plus a
        // scalar tail on the SIMD ISAs.
        let (nb, m, patch) = (2usize, 3usize, 10usize);
        let acc_v: Vec<i32> = (0..nb * m * patch).map(|i| i as i32 * 31 - 300).collect();
        let acc = Tensor::from_i32(&[nb, m, 2, 5], acc_v.clone()).unwrap();
        let bias_v = vec![10, -20, 1000];
        let bias4 = Tensor::from_i32(&[1, m, 1, 1], bias_v.clone()).unwrap();
        let want = reference_chain(&acc, Some(&bias4), 0.5, None, false, 1.0, 0, QType::I8);
        for isa in Isa::available() {
            let got = write_quantized(
                &acc_v,
                BiasLayout::PerChannel {
                    bias: &bias_v,
                    patch,
                },
                &epi(0.5, None, false, 1.0, 0, QType::I8),
                Shape::from_slice(&[nb, m, 2, 5]),
                isa,
                None,
            )
            .unwrap();
            assert_eq!(want, got, "{isa}");
        }
    }

    #[test]
    fn wrapping_bias_add_matches_i32_add_semantics() {
        // 10 elements: the vector add (`vpaddd`/`vaddq_s32` — wrapping,
        // like `wrapping_add`) covers the overflow lanes on SIMD ISAs.
        let acc_v = vec![i32::MAX, 0, i32::MIN, -1, i32::MAX, i32::MIN, 7, -7, 100, -100];
        let acc = Tensor::from_i32(&[1, 10], acc_v.clone()).unwrap();
        let bias_v = vec![1, 2, -1, -2, i32::MAX, i32::MIN, 3, -3, 0, 0];
        let bias = Tensor::from_i32(&[10], bias_v.clone()).unwrap();
        let want = reference_chain(&acc, Some(&bias), 1e-9, None, false, 1.0, 0, QType::I8);
        for isa in Isa::available() {
            let got = write_quantized(
                &acc_v,
                BiasLayout::PerColumn(&bias_v),
                &epi(1e-9, None, false, 1.0, 0, QType::I8),
                Shape::from_slice(&[1, 10]),
                isa,
                None,
            )
            .unwrap();
            assert_eq!(want, got, "{isa}");
        }
    }
}
