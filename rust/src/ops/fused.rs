//! Fused quantized kernels — the execution half of the plan-time graph
//! optimizer (`crate::opt`).
//!
//! Each kernel replaces a whole codified operator chain with one step:
//!
//! * [`FusedQFc`] / [`FusedQConv`] — the integer accumulate (reusing the
//!   packed int8 GEMM / im2col kernels through their existing `_into`
//!   entry points, accumulator parked in per-step scratch) followed by a
//!   SINGLE epilogue pass doing bias add, the Mul-codified rescale, the
//!   optional ReLU, and the round+saturate requantization — writing the
//!   final i8/u8 output directly. The unfused chain executes the same
//!   arithmetic as 5–7 separate full passes over the activation tensor
//!   with an intermediate buffer each.
//! * [`FusedActLut`] — the Dequantize → (f16) activation → Quantize chain
//!   as a 256-entry table lookup ([`ActLut::build_exact`]).
//!
//! **Bit-identity contract:** every per-element operation here is the
//! same f32/i32 scalar sequence the unfused kernels perform, in the same
//! order — `(acc +wrap bias) as f32 * s1 [* s2] [max 0] * (1/scale)`,
//! `round_half_even`, `+ zp`, saturate — so fused plans are bit-identical
//! to unfused plans and to the legacy interpreter on every input
//! (differential proof: `tests/executor_plan.rs`; the epilogue is
//! elementwise, so the GEMM's blocking/parallelism guarantees carry over
//! unchanged).

use super::OpError;
use super::{conv, matmul, qlinear};
use crate::onnx::shape::ConvAttrs;
use crate::quant::lut::ActLut;
use crate::quant::QType;
use crate::tensor::{recycled_i8, recycled_u8, Shape, Tensor, TensorData};

/// The baked scalar tail of a quantized FC/conv chain: `Cast → Mul(s1)
/// [→ Mul(s2)] [→ Relu] → QuantizeLinear(1/inv_scale, zp)`.
pub struct QEpilogue {
    pub s1: f32,
    pub s2: Option<f32>,
    pub relu: bool,
    /// `1.0 / q_scale`, the same reciprocal `quantize_linear_into`
    /// computes per call (baking it changes nothing: same f32 value).
    pub inv_scale: f32,
    pub zp: i32,
    pub out_qtype: QType,
}

impl QEpilogue {
    /// The exact unfused per-element sequence on a post-bias accumulator
    /// value, up to (but not including) the saturating cast.
    #[inline]
    fn rescale(&self, v: i32) -> f32 {
        let mut x = v as f32; // Cast INT32 -> FLOAT
        x *= self.s1; // Mul(Quant_scale)
        if let Some(s2) = self.s2 {
            x *= s2; // Mul(Quant_shift)
        }
        if self.relu {
            x = x.max(0.0); // Relu (f32)
        }
        qlinear::round_half_even(x * self.inv_scale) + self.zp as f32
    }
}

/// How the chain's bias Add broadcasts over the accumulator.
pub enum BiasLayout<'a> {
    None,
    /// FC: bias `[N]` (or `[1, N]`) cycling per output row.
    PerColumn(&'a [i32]),
    /// Conv: bias `[1, M, 1, 1]`, constant over each `oh*ow` patch.
    PerChannel { bias: &'a [i32], patch: usize },
}

/// One pass over the i32 accumulator: bias add (wrapping, exactly the
/// unfused i32 `Add`), epilogue rescale, saturate, write the quantized
/// output into recycled storage.
fn write_quantized(
    acc: &[i32],
    bias: BiasLayout<'_>,
    epi: &QEpilogue,
    shape: Shape,
    recycled: Option<Tensor>,
) -> Result<Tensor, OpError> {
    let n = acc.len();
    macro_rules! emit {
        ($recycle:ident, $sat:path, $variant:ident) => {{
            let mut o = $recycle(recycled, n);
            match bias {
                BiasLayout::PerColumn(b) if !b.is_empty() => {
                    for row in acc.chunks_exact(b.len()) {
                        o.extend(
                            row.iter()
                                .zip(b)
                                .map(|(&v, &bv)| $sat(epi.rescale(v.wrapping_add(bv)))),
                        );
                    }
                }
                BiasLayout::PerChannel { bias: b, patch } if !b.is_empty() && patch > 0 => {
                    let mut pos = 0;
                    while pos < n {
                        for &bv in b {
                            o.extend(
                                acc[pos..pos + patch]
                                    .iter()
                                    .map(|&v| $sat(epi.rescale(v.wrapping_add(bv)))),
                            );
                            pos += patch;
                        }
                    }
                }
                _ => o.extend(acc.iter().map(|&v| $sat(epi.rescale(v)))),
            }
            TensorData::$variant(o)
        }};
    }
    let data = match epi.out_qtype {
        QType::I8 => emit!(recycled_i8, qlinear::saturate_i8, I8),
        QType::U8 => emit!(recycled_u8, qlinear::saturate_u8, U8),
    };
    Ok(Tensor::new(shape, data)?)
}

/// Fused quantized fully-connected block: `MatMulInteger [+Add] + Cast +
/// Mul[+Mul] [+Relu] + QuantizeLinear` as one kernel. The weight fields
/// mirror [`super::Kernel::MatMulIntegerPrebound`] (packed i8 panels with
/// the widened-i32 fallback).
pub struct FusedQFc {
    pub bw: Vec<i32>,
    pub bp: Option<matmul::PackedB>,
    pub k: usize,
    pub n: usize,
    pub a_zp: i32,
    /// Row-broadcast bias, length `n`.
    pub bias: Option<Vec<i32>>,
    pub epi: QEpilogue,
}

impl FusedQFc {
    /// `scratch[0]` parks the i32 accumulator between runs (the only
    /// intermediate buffer of the whole chain); `recycled` is the retired
    /// quantized output — steady state allocates nothing
    /// (`tests/alloc_regression.rs`).
    pub fn run(
        &self,
        x: &Tensor,
        recycled: Option<Tensor>,
        scratch: &mut [Option<Tensor>; 2],
    ) -> Result<Tensor, OpError> {
        let acc = matmul::matmul_integer_prewidened_into(
            x,
            &self.bw,
            self.bp.as_ref(),
            self.k,
            self.n,
            self.a_zp,
            scratch[0].take(),
        )?;
        let bias = match &self.bias {
            Some(b) => BiasLayout::PerColumn(b),
            None => BiasLayout::None,
        };
        let out = write_quantized(
            acc.as_i32()?,
            bias,
            &self.epi,
            Shape::from_slice(acc.shape()),
            recycled,
        )?;
        scratch[0] = Some(acc);
        Ok(out)
    }
}

/// Fused quantized convolution block: the same chain over `ConvInteger`.
/// Weight fields mirror [`super::Kernel::ConvIntegerPrebound`].
pub struct FusedQConv {
    pub wv: Vec<i32>,
    pub wp: Option<matmul::PackedA>,
    pub m: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub x_zp: i32,
    pub attrs: ConvAttrs,
    /// Per-output-channel bias, length `m` (from the `[1, M, 1, 1]`
    /// initializer).
    pub bias: Option<Vec<i32>>,
    pub epi: QEpilogue,
}

impl FusedQConv {
    /// `scratch[0]` is the im2col column buffer, `scratch[1]` parks the
    /// i32 accumulator; `recycled` the retired quantized output.
    pub fn run(
        &self,
        x: &Tensor,
        recycled: Option<Tensor>,
        scratch: &mut [Option<Tensor>; 2],
    ) -> Result<Tensor, OpError> {
        let [col_scratch, acc_scratch] = scratch;
        let acc = conv::conv_integer_prewidened_into(
            x,
            &self.wv,
            self.wp.as_ref(),
            self.m,
            self.c,
            self.kh,
            self.kw,
            self.x_zp,
            &self.attrs,
            acc_scratch.take(),
            col_scratch,
        )?;
        let shape = acc.shape(); // [nb, m, oh, ow]
        let patch = shape[2] * shape[3];
        let bias = match &self.bias {
            Some(b) => BiasLayout::PerChannel { bias: b, patch },
            None => BiasLayout::None,
        };
        let out = write_quantized(
            acc.as_i32()?,
            bias,
            &self.epi,
            Shape::from_slice(acc.shape()),
            recycled,
        )?;
        *acc_scratch = Some(acc);
        Ok(out)
    }
}

/// Fused activation chain as a 256-entry table over the 8-bit input —
/// see [`ActLut::build_exact`] for why a lookup is bit-identical to the
/// node chain.
pub struct FusedActLut {
    pub lut: ActLut,
    /// The planned input domain (i8 vs u8 — fixed by the checker's type
    /// of the dequantize input at plan time).
    pub in_qtype: QType,
}

impl FusedActLut {
    pub fn run(&self, x: &Tensor, recycled: Option<Tensor>) -> Result<Tensor, OpError> {
        let n = x.numel();
        let shape = Shape::from_slice(x.shape());
        let data = match (x.data(), self.in_qtype, self.lut.out_qtype) {
            (TensorData::I8(v), QType::I8, QType::I8) => {
                let mut o = recycled_i8(recycled, n);
                o.extend(v.iter().map(|&q| self.lut.get_raw(q as u8) as i8));
                TensorData::I8(o)
            }
            (TensorData::I8(v), QType::I8, QType::U8) => {
                let mut o = recycled_u8(recycled, n);
                o.extend(v.iter().map(|&q| self.lut.get_raw(q as u8) as u8));
                TensorData::U8(o)
            }
            (TensorData::U8(v), QType::U8, QType::I8) => {
                let mut o = recycled_i8(recycled, n);
                o.extend(v.iter().map(|&q| self.lut.get_raw(q) as i8));
                TensorData::I8(o)
            }
            (TensorData::U8(v), QType::U8, QType::U8) => {
                let mut o = recycled_u8(recycled, n);
                o.extend(v.iter().map(|&q| self.lut.get_raw(q) as u8));
                TensorData::U8(o)
            }
            _ => {
                return Err(OpError::Semantics(format!(
                    "FusedActLut: input dtype {} does not match planned {:?} domain",
                    x.dtype(),
                    self.in_qtype
                )))
            }
        };
        Ok(Tensor::new(shape, data)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{elementwise, qlinear as ql};
    use crate::tensor::DType;

    fn epi(s1: f32, s2: Option<f32>, relu: bool, scale: f32, zp: i32, out: QType) -> QEpilogue {
        QEpilogue {
            s1,
            s2,
            relu,
            inv_scale: 1.0 / scale,
            zp,
            out_qtype: out,
        }
    }

    /// Reference: run the actual unfused kernels over the accumulator.
    #[allow(clippy::too_many_arguments)]
    fn reference_chain(
        acc: &Tensor,
        bias: Option<&Tensor>,
        s1: f32,
        s2: Option<f32>,
        relu: bool,
        scale: f32,
        zp: i32,
        out: QType,
    ) -> Tensor {
        let mut t = match bias {
            Some(b) => elementwise::binary(elementwise::BinOp::Add, acc, b).unwrap(),
            None => acc.clone(),
        };
        t = t.cast(DType::F32);
        t = elementwise::binary(
            elementwise::BinOp::Mul,
            &t,
            &Tensor::scalar_f32(s1),
        )
        .unwrap();
        if let Some(s2) = s2 {
            t = elementwise::binary(
                elementwise::BinOp::Mul,
                &t,
                &Tensor::scalar_f32(s2),
            )
            .unwrap();
        }
        if relu {
            t = elementwise::relu(&t).unwrap();
        }
        let zp = match out {
            QType::I8 => Tensor::scalar_i8(zp as i8),
            QType::U8 => Tensor::scalar_u8(zp as u8),
        };
        ql::quantize_linear(&t, &Tensor::scalar_f32(scale), Some(&zp)).unwrap()
    }

    #[test]
    fn epilogue_matches_unfused_chain_elementwise() {
        // Accumulators spanning sign changes, saturation, and .5 ties.
        let (m, n) = (4usize, 3usize);
        let acc_v: Vec<i32> = (0..m * n as usize)
            .map(|i| (i as i32 * 977 - 5000) * 3)
            .collect();
        let acc = Tensor::from_i32(&[m, n], acc_v.clone()).unwrap();
        let bias_v = vec![100, -250, 7];
        let bias = Tensor::from_i32(&[n], bias_v.clone()).unwrap();
        // Includes asymmetric zero points (§3.1 uint8 zp=128 and a
        // nonzero i8 zp): the `round -> + zp -> saturate` order must
        // match the unfused QuantizeLinear exactly.
        for (s1, s2, relu, scale, zp, out) in [
            (3.0, Some(1.0 / 8.0), false, 1.0, 0, QType::I8),
            (0.017, None, true, 1.0, 0, QType::U8),
            (5.0, Some(1.0 / 64.0), true, 0.5, 0, QType::I8),
            (0.02, None, false, 1.0, 128, QType::U8),
            (0.013, Some(0.5), true, 0.25, -16, QType::I8),
        ] {
            let want = reference_chain(&acc, Some(&bias), s1, s2, relu, scale, zp, out);
            let got = write_quantized(
                &acc_v,
                BiasLayout::PerColumn(&bias_v),
                &epi(s1, s2, relu, scale, zp, out),
                Shape::from_slice(&[m, n]),
                None,
            )
            .unwrap();
            assert_eq!(want, got, "s1={s1} s2={s2:?} relu={relu} zp={zp}");
            // No-bias form.
            let want = reference_chain(&acc, None, s1, s2, relu, scale, zp, out);
            let got = write_quantized(
                &acc_v,
                BiasLayout::None,
                &epi(s1, s2, relu, scale, zp, out),
                Shape::from_slice(&[m, n]),
                None,
            )
            .unwrap();
            assert_eq!(want, got, "no-bias s1={s1} zp={zp}");
        }
    }

    #[test]
    fn per_channel_bias_matches_conv_broadcast() {
        // [nb=2, m=3, oh*ow=4] accumulator vs the [1, M, 1, 1] Add.
        let (nb, m, patch) = (2usize, 3usize, 4usize);
        let acc_v: Vec<i32> = (0..nb * m * patch).map(|i| i as i32 * 31 - 300).collect();
        let acc = Tensor::from_i32(&[nb, m, 2, 2], acc_v.clone()).unwrap();
        let bias_v = vec![10, -20, 1000];
        let bias4 = Tensor::from_i32(&[1, m, 1, 1], bias_v.clone()).unwrap();
        let want = reference_chain(&acc, Some(&bias4), 0.5, None, false, 1.0, 0, QType::I8);
        let got = write_quantized(
            &acc_v,
            BiasLayout::PerChannel {
                bias: &bias_v,
                patch,
            },
            &epi(0.5, None, false, 1.0, 0, QType::I8),
            Shape::from_slice(&[nb, m, 2, 2]),
            None,
        )
        .unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn wrapping_bias_add_matches_i32_add_semantics() {
        let acc_v = vec![i32::MAX, 0];
        let acc = Tensor::from_i32(&[1, 2], acc_v.clone()).unwrap();
        let bias_v = vec![1, 2];
        let bias = Tensor::from_i32(&[2], bias_v.clone()).unwrap();
        let want = reference_chain(&acc, Some(&bias), 1e-9, None, false, 1.0, 0, QType::I8);
        let got = write_quantized(
            &acc_v,
            BiasLayout::PerColumn(&bias_v),
            &epi(1e-9, None, false, 1.0, 0, QType::I8),
            Shape::from_slice(&[1, 2]),
            None,
        )
        .unwrap();
        assert_eq!(want, got);
    }
}
