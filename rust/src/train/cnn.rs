//! Small fp32 CNN (Conv → ReLU → MaxPool → FC) with manual backprop —
//! the trained-model source for the paper's Figure 3 (ConvInteger)
//! pattern. Sized for the 8×8 synthetic-digits images.

use super::data::Dataset;
use super::rng::Rng;
use crate::onnx::ir::Attr;
use crate::onnx::{batched, GraphBuilder, Model};
use crate::tensor::{DType, Tensor};

/// Conv(1→F, 3×3, pad 1) + ReLU + MaxPool(2×2) + Dense(F·16 → classes).
#[derive(Clone, Debug)]
pub struct Cnn {
    pub filters: usize,
    pub classes: usize,
    /// Kernels `[F, 1, 3, 3]`.
    pub conv_w: Vec<f32>,
    pub conv_b: Vec<f32>,
    /// Dense weights `[F*16, classes]`.
    pub fc_w: Vec<f32>,
    pub fc_b: Vec<f32>,
    vw_conv: Vec<f32>,
    vb_conv: Vec<f32>,
    vw_fc: Vec<f32>,
    vb_fc: Vec<f32>,
}

const H: usize = 8;
const PH: usize = 4; // pooled

struct Forward {
    conv_act: Vec<f32>,   // post-ReLU [n, F, 8, 8]
    pool_idx: Vec<usize>, // argmax flat index into conv_act, [n, F, 4, 4]
    pooled: Vec<f32>,     // [n, F*16]
    logits: Vec<f32>,     // [n, classes]
}

impl Cnn {
    pub fn new(filters: usize, classes: usize, seed: u64) -> Cnn {
        let mut rng = Rng::new(seed);
        let k = 9;
        let conv_scale = (2.0 / k as f32).sqrt();
        let fc_in = filters * PH * PH;
        let fc_scale = (2.0 / fc_in as f32).sqrt();
        Cnn {
            filters,
            classes,
            conv_w: (0..filters * k).map(|_| conv_scale * rng.normal()).collect(),
            conv_b: vec![0.0; filters],
            fc_w: (0..fc_in * classes).map(|_| fc_scale * rng.normal()).collect(),
            fc_b: vec![0.0; classes],
            vw_conv: vec![0.0; filters * k],
            vb_conv: vec![0.0; filters],
            vw_fc: vec![0.0; fc_in * classes],
            vb_fc: vec![0.0; classes],
        }
    }

    pub fn param_count(&self) -> usize {
        self.conv_w.len() + self.conv_b.len() + self.fc_w.len() + self.fc_b.len()
    }

    fn forward(&self, x: &[f32], n: usize) -> Forward {
        let f = self.filters;
        let mut conv_act = vec![0f32; n * f * H * H];
        // 3x3 pad-1 convolution over single-channel 8x8.
        for b in 0..n {
            let img = &x[b * H * H..(b + 1) * H * H];
            for fi in 0..f {
                let kw = &self.conv_w[fi * 9..(fi + 1) * 9];
                let out = &mut conv_act[(b * f + fi) * H * H..(b * f + fi + 1) * H * H];
                for y in 0..H {
                    for xx in 0..H {
                        let mut acc = self.conv_b[fi];
                        for ky in 0..3usize {
                            let iy = y as isize + ky as isize - 1;
                            if !(0..H as isize).contains(&iy) {
                                continue;
                            }
                            for kx in 0..3usize {
                                let ix = xx as isize + kx as isize - 1;
                                if !(0..H as isize).contains(&ix) {
                                    continue;
                                }
                                acc += kw[ky * 3 + kx] * img[iy as usize * H + ix as usize];
                            }
                        }
                        out[y * H + xx] = acc.max(0.0); // ReLU fused
                    }
                }
            }
        }
        // 2x2 max pool with argmax bookkeeping.
        let mut pool_idx = vec![0usize; n * f * PH * PH];
        let mut pooled = vec![0f32; n * f * PH * PH];
        for b in 0..n {
            for fi in 0..f {
                let plane_base = (b * f + fi) * H * H;
                for py in 0..PH {
                    for px in 0..PH {
                        let mut best = f32::NEG_INFINITY;
                        let mut best_i = 0usize;
                        for dy in 0..2usize {
                            for dx in 0..2usize {
                                let idx = plane_base + (py * 2 + dy) * H + px * 2 + dx;
                                if conv_act[idx] > best {
                                    best = conv_act[idx];
                                    best_i = idx;
                                }
                            }
                        }
                        let o = (b * f + fi) * PH * PH + py * PH + px;
                        pooled[o] = best;
                        pool_idx[o] = best_i;
                    }
                }
            }
        }
        // Dense head.
        let fc_in = f * PH * PH;
        let mut logits = vec![0f32; n * self.classes];
        for b in 0..n {
            let row = &pooled[b * fc_in..(b + 1) * fc_in];
            let out = &mut logits[b * self.classes..(b + 1) * self.classes];
            out.copy_from_slice(&self.fc_b);
            for (k, &a) in row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let wrow = &self.fc_w[k * self.classes..(k + 1) * self.classes];
                for (o, &w) in out.iter_mut().zip(wrow) {
                    *o += a * w;
                }
            }
        }
        Forward {
            conv_act,
            pool_idx,
            pooled,
            logits,
        }
    }

    pub fn predict(&self, x: &[f32], n: usize) -> Vec<usize> {
        let fwd = self.forward(x, n);
        fwd.logits
            .chunks(self.classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect()
    }

    /// One SGD-with-momentum step; returns mean CE loss.
    pub fn train_batch(&mut self, x: &[f32], y: &[usize], lr: f32, momentum: f32) -> f32 {
        let n = y.len();
        let f = self.filters;
        let fc_in = f * PH * PH;
        let fwd = self.forward(x, n);

        // Softmax CE delta.
        let mut delta = vec![0f32; n * self.classes];
        let mut loss = 0f32;
        for i in 0..n {
            let row = &fwd.logits[i * self.classes..(i + 1) * self.classes];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|&v| (v - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            for c in 0..self.classes {
                let p = exps[c] / sum;
                delta[i * self.classes + c] = (p - if c == y[i] { 1.0 } else { 0.0 }) / n as f32;
                if c == y[i] {
                    loss -= p.max(1e-12).ln();
                }
            }
        }
        loss /= n as f32;

        // FC grads.
        let mut dw_fc = vec![0f32; fc_in * self.classes];
        let mut db_fc = vec![0f32; self.classes];
        let mut grad_pool = vec![0f32; n * fc_in];
        for i in 0..n {
            let g_row = &delta[i * self.classes..(i + 1) * self.classes];
            let a_row = &fwd.pooled[i * fc_in..(i + 1) * fc_in];
            for (d, &g) in db_fc.iter_mut().zip(g_row) {
                *d += g;
            }
            for (k, &a) in a_row.iter().enumerate() {
                let wrow = &self.fc_w[k * self.classes..(k + 1) * self.classes];
                let dst = &mut dw_fc[k * self.classes..(k + 1) * self.classes];
                let mut gsum = 0f32;
                for ((dv, &g), &w) in dst.iter_mut().zip(g_row).zip(wrow) {
                    *dv += a * g;
                    gsum += w * g;
                }
                grad_pool[i * fc_in + k] = gsum;
            }
        }

        // Un-pool (route gradient to argmax), then ReLU mask, then conv grads.
        let mut grad_conv = vec![0f32; n * f * H * H];
        for (o, &src) in fwd.pool_idx.iter().enumerate() {
            grad_conv[src] += grad_pool[o];
        }
        for (g, &a) in grad_conv.iter_mut().zip(&fwd.conv_act) {
            if a <= 0.0 {
                *g = 0.0;
            }
        }
        let mut dw_conv = vec![0f32; f * 9];
        let mut db_conv = vec![0f32; f];
        for b in 0..n {
            let img = &x[b * H * H..(b + 1) * H * H];
            for fi in 0..f {
                let gplane = &grad_conv[(b * f + fi) * H * H..(b * f + fi + 1) * H * H];
                for y in 0..H {
                    for xx in 0..H {
                        let g = gplane[y * H + xx];
                        if g == 0.0 {
                            continue;
                        }
                        db_conv[fi] += g;
                        for ky in 0..3usize {
                            let iy = y as isize + ky as isize - 1;
                            if !(0..H as isize).contains(&iy) {
                                continue;
                            }
                            for kx in 0..3usize {
                                let ix = xx as isize + kx as isize - 1;
                                if !(0..H as isize).contains(&ix) {
                                    continue;
                                }
                                dw_conv[fi * 9 + ky * 3 + kx] +=
                                    g * img[iy as usize * H + ix as usize];
                            }
                        }
                    }
                }
            }
        }

        // Momentum updates.
        let upd = |w: &mut [f32], v: &mut [f32], d: &[f32]| {
            for ((w, v), d) in w.iter_mut().zip(v).zip(d) {
                *v = momentum * *v - lr * d;
                *w += *v;
            }
        };
        upd(&mut self.fc_w, &mut self.vw_fc, &dw_fc);
        upd(&mut self.fc_b, &mut self.vb_fc, &db_fc);
        upd(&mut self.conv_w, &mut self.vw_conv, &dw_conv);
        upd(&mut self.conv_b, &mut self.vb_conv, &db_conv);
        loss
    }

    /// Export as fp32 ONNX: Conv(+bias) → Relu → MaxPool → Flatten →
    /// Gemm → Softmax, input `[N, 1, 8, 8]`.
    pub fn to_model(&self, name: &str) -> Model {
        let mut b = GraphBuilder::new(name);
        b.input("x", DType::F32, &batched(&[1, H, H]));
        let w = b.init(
            "conv_w",
            Tensor::from_f32(&[self.filters, 1, 3, 3], self.conv_w.clone()).unwrap(),
        );
        let cb = b.init(
            "conv_b",
            Tensor::from_f32(&[self.filters], self.conv_b.clone()).unwrap(),
        );
        let conv = b.node(
            "Conv",
            &["x", &w, &cb],
            &[
                ("pads", Attr::Ints(vec![1, 1, 1, 1])),
                ("strides", Attr::Ints(vec![1, 1])),
            ],
        );
        let relu = b.node("Relu", &[&conv], &[]);
        let pool = b.node(
            "MaxPool",
            &[&relu],
            &[
                ("kernel_shape", Attr::Ints(vec![2, 2])),
                ("strides", Attr::Ints(vec![2, 2])),
            ],
        );
        let flat = b.node("Flatten", &[&pool], &[("axis", Attr::Int(1))]);
        let fw = b.init(
            "fc_w",
            Tensor::from_f32(&[self.filters * PH * PH, self.classes], self.fc_w.clone())
                .unwrap(),
        );
        let fb = b.init(
            "fc_b",
            Tensor::from_f32(&[self.classes], self.fc_b.clone()).unwrap(),
        );
        let logits = b.node("Gemm", &[&flat, &fw, &fb], &[]);
        let sm = b.node("Softmax", &[&logits], &[("axis", Attr::Int(-1))]);
        b.output(&sm, DType::F32, &batched(&[self.classes]));
        b.finish_model()
    }
}

/// Train on a dataset of 8×8 images; returns per-epoch loss.
pub fn train_cnn(
    cnn: &mut Cnn,
    data: &Dataset,
    epochs: usize,
    batch: usize,
    lr: f32,
    momentum: f32,
    seed: u64,
) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut losses = Vec::with_capacity(epochs);
    for _ in 0..epochs {
        let perm = rng.permutation(data.len());
        let mut epoch_loss = 0f32;
        let mut batches = 0usize;
        for chunk in perm.chunks(batch) {
            let mut x = Vec::with_capacity(chunk.len() * data.dim);
            let mut y = Vec::with_capacity(chunk.len());
            for &i in chunk {
                let (xi, yi) = data.sample(i);
                x.extend_from_slice(xi);
                y.push(yi);
            }
            epoch_loss += cnn.train_batch(&x, &y, lr, momentum);
            batches += 1;
        }
        losses.push(epoch_loss / batches.max(1) as f32);
    }
    losses
}

/// Accuracy of the CNN on a dataset.
pub fn cnn_accuracy(cnn: &Cnn, data: &Dataset) -> f32 {
    let preds = cnn.predict(&data.x, data.len());
    let correct = preds.iter().zip(&data.y).filter(|(p, y)| p == y).count();
    correct as f32 / data.len().max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::data::synthetic_digits;

    #[test]
    fn learns_digits() {
        let data = synthetic_digits(1000, 21);
        let (train, test) = data.split(0.2, 22);
        let mut cnn = Cnn::new(6, 10, 23);
        let losses = train_cnn(&mut cnn, &train, 12, 32, 0.08, 0.9, 24);
        assert!(
            losses.last().unwrap() < &losses[0],
            "loss did not decrease: {losses:?}"
        );
        let acc = cnn_accuracy(&cnn, &test);
        assert!(acc > 0.85, "accuracy {acc}");
    }

    #[test]
    fn exported_model_matches_forward() {
        let data = synthetic_digits(300, 31);
        let mut cnn = Cnn::new(4, 10, 32);
        train_cnn(&mut cnn, &data, 4, 32, 0.08, 0.9, 33);
        let model = cnn.to_model("digits_cnn");
        crate::onnx::check_model(&model).unwrap();
        let sess = crate::interp::Session::new(model).unwrap();
        let mut agree = 0;
        for i in 0..20 {
            let (x, _) = data.sample(i);
            let probs = sess
                .run(&[(
                    "x",
                    Tensor::from_f32(&[1, 1, 8, 8], x.to_vec()).unwrap(),
                )])
                .unwrap();
            let probs = probs[0].as_f32().unwrap().to_vec();
            let onnx_pred = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            let native_pred = cnn.predict(x, 1)[0];
            if onnx_pred == native_pred {
                agree += 1;
            }
        }
        assert_eq!(agree, 20, "ONNX export diverges from native forward");
    }
}
