//! Sub-8-bit figure-class models: an int4-weight MLP and a
//! bipolar-weight CNN.
//!
//! Both are genuinely trained in fp32 ([`super::mlp`], [`super::cnn`]),
//! post-training quantized to their narrow width, and emitted as pure
//! standard-ONNX pre-quantized graphs through [`crate::rewrite::patterns`]
//! — the same codification the Figure 1–6 models use, extended with the
//! sub-8-bit `Clip` stage. They are figure-class citizens: deterministic
//! (seeded training, memoized per process), registry-addressable via
//! [`NarrowModel::ALL`], and covered by the three-way differential oracle
//! in `tests/subwidth.rs`.
//!
//! Width mechanics:
//!
//! * **`Mlp4`** quantizes both FC layers symmetrically to `[-7, 7]` and
//!   declares its hidden activations int4 through the emitted
//!   `Clip(-8, 7) + QuantizeLinear` stage, so the optimizer both bakes
//!   nibble-packed weights and absorbs the narrow saturation epilogue.
//! * **`BipolarCnn`** binarizes its conv kernel and FC head to `{-1, +1}`
//!   (per-tensor scale = mean |w|), consumes sign-binarized ±1 images,
//!   and uses zero padding — exactly the preconditions of the
//!   XNOR-popcount conv kernel. Its FC head is retrained on the
//!   *deployed* integer conv features (the classic BNN
//!   freeze-then-retrain recipe), so conv quantization error never
//!   reaches the head as train/serve skew.
//!
//! Both models also carry advisory `pqdl.width.*` metadata props for
//! their narrow initializers; the checker verifies the annotations
//! against the stored values (paper goal 1: advisory, never required).

use super::cnn::{train_cnn, Cnn};
use super::data::{gaussian_blobs, synthetic_digits, Dataset};
use super::mlp::{train_classifier, HiddenAct, Mlp};
use crate::interp::Session;
use crate::onnx::check::WIDTH_META_PREFIX;
use crate::onnx::ir::Attr;
use crate::onnx::{batched, GraphBuilder, Model};
use crate::quant::QType;
use crate::rewrite::patterns::{emit_conv, emit_fc, ActKind, ConvParams, FcParams, RescaleOp};
use crate::tensor::{DType, Tensor};
use std::sync::OnceLock;

/// The sub-8-bit figure-class models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NarrowModel {
    /// Two-layer FC classifier: int4 weights, int4 hidden activations.
    Mlp4,
    /// Conv + pool + FC digit classifier with `{-1, +1}` weights end to
    /// end, deployed on ±1 inputs with zero padding (XNOR-eligible).
    BipolarCnn,
}

const MLP4_IN: usize = 8;
const MLP4_HID: usize = 16;
const MLP4_CLASSES: usize = 3;

const BCNN_FILTERS: usize = 4;
const BCNN_CLASSES: usize = 10;
/// Conv 8×8 pad-0 → 6×6, pool 2×2 → 3×3.
const BCNN_FEAT: usize = BCNN_FILTERS * 3 * 3;

impl NarrowModel {
    pub const ALL: [NarrowModel; 2] = [NarrowModel::Mlp4, NarrowModel::BipolarCnn];

    pub fn name(&self) -> &'static str {
        match self {
            NarrowModel::Mlp4 => "mlp_int4",
            NarrowModel::BipolarCnn => "cnn_bipolar",
        }
    }

    /// Per-sample input dims (without the batch axis).
    pub fn input_dims(&self) -> Vec<usize> {
        match self {
            NarrowModel::Mlp4 => vec![MLP4_IN],
            NarrowModel::BipolarCnn => vec![1, 8, 8],
        }
    }

    /// Per-sample output dims (without the batch axis).
    pub fn output_dims(&self) -> Vec<usize> {
        match self {
            NarrowModel::Mlp4 => vec![MLP4_CLASSES],
            NarrowModel::BipolarCnn => vec![BCNN_CLASSES],
        }
    }

    /// Train (memoized per process), quantize, and emit the
    /// standard-ONNX pre-quantized model. Training is seeded, so every
    /// call returns the identical model.
    pub fn model(&self) -> Model {
        match self {
            NarrowModel::Mlp4 => mlp4_parts().model.clone(),
            NarrowModel::BipolarCnn => bipolar_parts().model.clone(),
        }
    }

    /// Deterministic i8 input batch; for the bipolar CNN every element
    /// is ±1 (the XNOR input alphabet).
    pub fn input(&self, batch: usize, seed: u64) -> Tensor {
        let dims = self.input_dims();
        let flat: usize = dims.iter().product();
        let t = crate::figures::canonical_input(batch, flat, seed);
        let t = match self {
            NarrowModel::Mlp4 => t,
            NarrowModel::BipolarCnn => {
                let pm1: Vec<i8> = t
                    .as_i8()
                    .unwrap()
                    .iter()
                    .map(|&v| if v < 0 { -1 } else { 1 })
                    .collect();
                Tensor::from_i8(&[batch, flat], pm1).unwrap()
            }
        };
        let mut shape = vec![batch];
        shape.extend(dims);
        t.reshape(&shape).unwrap()
    }
}

/// Symmetric quantization to `[-limit, limit]`: the largest-magnitude
/// weight maps exactly to ±limit (so `QType::minimal_for` recovers the
/// intended width), and `w ≈ q * scale`.
fn quantize_sym(w: &[f32], limit: i32) -> (Vec<i8>, f32) {
    let max = w.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-6);
    let scale = max / limit as f32;
    let lim = limit as f32;
    let q = w
        .iter()
        .map(|&v| (v / scale).round().clamp(-lim, lim) as i8)
        .collect();
    (q, scale)
}

/// Sign-binarize to `{-1, +1}` (zero counts as +1, keeping the alphabet
/// strictly bipolar); scale = mean |w| (the BinaryConnect/XNOR-Net
/// per-tensor scaling factor).
fn binarize(w: &[f32]) -> (Vec<i8>, f32) {
    let mean = w.iter().map(|&v| v.abs() as f64).sum::<f64>() / w.len().max(1) as f64;
    let q = w.iter().map(|&v| if v < 0.0 { -1i8 } else { 1 }).collect();
    (q, (mean as f32).max(1e-6))
}

fn max_abs(v: &[f32]) -> f32 {
    v.iter().fold(0f32, |m, &x| m.max(x.abs()))
}

/// Tag the (builder-suffixed) initializer whose name starts with
/// `init_prefix` with an advisory `pqdl.width.*` metadata prop.
fn tag_width(model: &mut Model, init_prefix: &str, qtype: QType) {
    let name = model
        .graph
        .initializers
        .iter()
        .map(|(n, _)| n)
        .find(|n| n.starts_with(init_prefix))
        .unwrap_or_else(|| panic!("no initializer with prefix '{init_prefix}'"))
        .clone();
    model
        .metadata
        .push((format!("{WIDTH_META_PREFIX}{name}"), qtype.name()));
}

struct Mlp4Parts {
    model: Model,
    /// Input quantization scale (`x_q = round(x / s_x)`).
    s_x: f32,
    /// Training set the accuracy test replays through the model.
    data: Dataset,
    /// fp32 reference accuracy on `data` (pre-quantization).
    fp32_acc: f32,
}

fn mlp4_parts() -> &'static Mlp4Parts {
    static CACHE: OnceLock<Mlp4Parts> = OnceLock::new();
    CACHE.get_or_init(build_mlp4)
}

fn build_mlp4() -> Mlp4Parts {
    let data = gaussian_blobs(400, MLP4_IN, MLP4_CLASSES, 0.25, 0xA401);
    let mut mlp = Mlp::new(&[MLP4_IN, MLP4_HID, MLP4_CLASSES], HiddenAct::Relu, 0xA402);
    train_classifier(&mut mlp, &data, 15, 16, 0.05, 0.9, 0xA403);
    let fp32_acc = super::mlp::accuracy(&mlp, &data);

    let n = data.len();
    let s_x = (max_abs(&data.x) / 127.0).max(1e-6);
    let (w0q, s_w0) = quantize_sym(&mlp.layers[0].w, 7);
    let (w1q, s_w1) = quantize_sym(&mlp.layers[1].w, 7);

    // Calibrate the int4 hidden scale on the fp32 pre-activations (ReLU
    // only discards negatives, so max |pre-act| bounds the post-ReLU
    // range too).
    let l0 = &mlp.layers[0];
    let mut hidden = vec![0f32; n * MLP4_HID];
    for i in 0..n {
        let (x, _) = data.sample(i);
        let h = &mut hidden[i * MLP4_HID..(i + 1) * MLP4_HID];
        h.copy_from_slice(&l0.b);
        for (k, &xv) in x.iter().enumerate() {
            for (hv, &wv) in h.iter_mut().zip(&l0.w[k * MLP4_HID..(k + 1) * MLP4_HID]) {
                *hv += xv * wv;
            }
        }
    }
    let s_h = (max_abs(&hidden) / 7.0).max(1e-6);
    let s_out = (max_abs(&mlp.logits(&data.x, n)) / 127.0).max(1e-6);

    let l1 = &mlp.layers[1];
    let b0q: Vec<i32> = l0.b.iter().map(|&b| (b / (s_x * s_w0)).round() as i32).collect();
    let b1q: Vec<i32> = l1.b.iter().map(|&b| (b / (s_h * s_w1)).round() as i32).collect();

    let mut b = GraphBuilder::new("mlp_int4");
    b.input("x", DType::I8, &batched(&[MLP4_IN]));
    let h = emit_fc(
        &mut b,
        "x",
        &FcParams {
            weight_q: Tensor::from_i8(&[MLP4_IN, MLP4_HID], w0q).unwrap(),
            bias_q: Some(Tensor::from_i32(&[MLP4_HID], b0q).unwrap()),
            rescale: RescaleOp::OneMul(s_x * s_w0 / s_h),
            activation: ActKind::Relu,
            out_qtype: QType::Int(4),
        },
        "l0",
    );
    let y = emit_fc(
        &mut b,
        &h,
        &FcParams {
            weight_q: Tensor::from_i8(&[MLP4_HID, MLP4_CLASSES], w1q).unwrap(),
            bias_q: Some(Tensor::from_i32(&[MLP4_CLASSES], b1q).unwrap()),
            rescale: RescaleOp::OneMul(s_h * s_w1 / s_out),
            activation: ActKind::None,
            out_qtype: QType::I8,
        },
        "l1",
    );
    b.output(&y, DType::I8, &batched(&[MLP4_CLASSES]));
    let mut model = b.finish_model();
    tag_width(&mut model, "l0_weight_q", QType::Int(4));
    tag_width(&mut model, "l1_weight_q", QType::Int(4));
    Mlp4Parts {
        model,
        s_x,
        data,
        fp32_acc,
    }
}

struct BipolarParts {
    model: Model,
    /// Binarized (±1.0 f32) training images.
    data: Dataset,
}

fn bipolar_parts() -> &'static BipolarParts {
    static CACHE: OnceLock<BipolarParts> = OnceLock::new();
    CACHE.get_or_init(build_bipolar_cnn)
}

/// Threshold the synthetic-digit images to the strict ±1 alphabet
/// (lit pixels sit near 1.0, background near 0.0; 0.5 separates them).
fn binarize_images(mut d: Dataset) -> Dataset {
    for v in &mut d.x {
        *v = if *v > 0.5 { 1.0 } else { -1.0 };
    }
    d
}

fn build_bipolar_cnn() -> BipolarParts {
    let data = binarize_images(synthetic_digits(400, 0xB101));
    let mut cnn = Cnn::new(BCNN_FILTERS, BCNN_CLASSES, 0xB102);
    train_cnn(&mut cnn, &data, 6, 32, 0.05, 0.9, 0xB103);

    let (cwq, alpha_c) = binarize(&cnn.conv_w);
    // ±1 input at scale 1 × ±1 kernel at scale alpha_c: the bias enters
    // the accumulator at scale alpha_c.
    let b_cq: Vec<i32> = cnn.conv_b.iter().map(|&b| (b / alpha_c).round() as i32).collect();
    // Analytic accumulator bound: nine ±1·±1 taps plus the bias. Scaling
    // that bound onto the full i8 range keeps the conv output exact
    // through the rescale (decompose() accepts multipliers > 1).
    let acc_max = 9 + b_cq.iter().map(|b| b.abs()).max().unwrap_or(0);
    let m_c = 127.0 / acc_max as f32;
    // Conv output q represents q * s_c in fp32 terms.
    let s_c = alpha_c / m_c;

    let conv_params = ConvParams {
        weight_q: Tensor::from_i8(&[BCNN_FILTERS, 1, 3, 3], cwq).unwrap(),
        bias_q: Some(Tensor::from_i32(&[BCNN_FILTERS], b_cq).unwrap()),
        rescale: RescaleOp::OneMul(m_c),
        relu: true,
        out_qtype: QType::I8,
        strides: [1, 1],
        // Zero padding injects 0, which is outside the {-1,+1} alphabet —
        // pad-free valid convolution is the XNOR kernel's precondition.
        pads: [0, 0, 0, 0],
    };
    let pool_attrs = [
        ("kernel_shape", Attr::Ints(vec![2, 2])),
        ("strides", Attr::Ints(vec![2, 2])),
    ];

    // Deployment-true feature extractor: run the *quantized* conv + pool
    // through the interpreter so the retrained head never sees
    // train/serve skew from conv binarization.
    let feat_model = {
        let mut b = GraphBuilder::new("cnn_bipolar_features");
        b.input("x", DType::I8, &batched(&[1, 8, 8]));
        let c = emit_conv(&mut b, "x", &conv_params, "c0");
        let p = b.node("MaxPool", &[&c], &pool_attrs);
        let f = b.node("Flatten", &[&p], &[("axis", Attr::Int(1))]);
        b.output(&f, DType::I8, &batched(&[BCNN_FEAT]));
        b.finish_model()
    };
    let n = data.len();
    let x_q: Vec<i8> = data.x.iter().map(|&v| if v > 0.0 { 1i8 } else { -1 }).collect();
    let sess = Session::new(feat_model).expect("bipolar feature model");
    let feats_q = sess
        .run(&[("x", Tensor::from_i8(&[n, 1, 8, 8], x_q).unwrap())])
        .expect("bipolar feature run");
    let feats: Vec<f32> = feats_q[0]
        .as_quantized_i32()
        .unwrap()
        .iter()
        .map(|&q| q as f32 * s_c)
        .collect();

    let feat_data = Dataset {
        x: feats,
        y: data.y.clone(),
        dim: BCNN_FEAT,
        classes: BCNN_CLASSES,
        image_shape: None,
    };
    // Single Dense layer (no hidden stage), retrained on the integer
    // features, then itself binarized.
    let mut head = Mlp::new(&[BCNN_FEAT, BCNN_CLASSES], HiddenAct::Relu, 0xB104);
    train_classifier(&mut head, &feat_data, 20, 32, 0.05, 0.9, 0xB105);

    let hl = &head.layers[0];
    let (fwq, alpha_f) = binarize(&hl.w);
    let b_fq: Vec<i32> = hl.b.iter().map(|&b| (b / (s_c * alpha_f)).round() as i32).collect();
    let s_out = (max_abs(&head.logits(&feat_data.x, n)) / 127.0).max(1e-6);
    let m_f = s_c * alpha_f / s_out;

    let mut b = GraphBuilder::new("cnn_bipolar");
    b.input("x", DType::I8, &batched(&[1, 8, 8]));
    let c = emit_conv(&mut b, "x", &conv_params, "c0");
    let p = b.node("MaxPool", &[&c], &pool_attrs);
    let f = b.node("Flatten", &[&p], &[("axis", Attr::Int(1))]);
    let y = emit_fc(
        &mut b,
        &f,
        &FcParams {
            weight_q: Tensor::from_i8(&[BCNN_FEAT, BCNN_CLASSES], fwq).unwrap(),
            bias_q: Some(Tensor::from_i32(&[BCNN_CLASSES], b_fq).unwrap()),
            rescale: RescaleOp::OneMul(m_f),
            activation: ActKind::None,
            out_qtype: QType::I8,
        },
        "fc",
    );
    b.output(&y, DType::I8, &batched(&[BCNN_CLASSES]));
    let mut model = b.finish_model();
    tag_width(&mut model, "c0_kernel_q", QType::Bipolar);
    tag_width(&mut model, "fc_weight_q", QType::Bipolar);
    BipolarParts { model, data }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::check_model;

    fn argmax(row: &[i32]) -> usize {
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        best
    }

    fn quantized_accuracy(model: Model, x: Tensor, y: &[usize], classes: usize) -> f32 {
        let sess = Session::new(model).unwrap();
        let out = sess.run(&[("x", x)]).unwrap();
        let logits = out[0].as_quantized_i32().unwrap();
        let correct = logits
            .chunks(classes)
            .zip(y)
            .filter(|(row, &want)| argmax(row) == want)
            .count();
        correct as f32 / y.len().max(1) as f32
    }

    #[test]
    fn mlp4_validates_and_keeps_accuracy() {
        let parts = mlp4_parts();
        check_model(&parts.model).unwrap();
        // Width metadata is present for both FC weights.
        let widths: Vec<&str> = parts
            .model
            .metadata
            .iter()
            .filter(|(k, _)| k.starts_with(WIDTH_META_PREFIX))
            .map(|(_, v)| v.as_str())
            .collect();
        assert_eq!(widths, vec!["int4", "int4"]);

        // The fp32 net separates the blobs; int4 weights + int4 hidden
        // activations should not destroy that.
        assert!(parts.fp32_acc > 0.9, "fp32 accuracy {}", parts.fp32_acc);
        let n = parts.data.len();
        let xq: Vec<i8> = parts
            .data
            .x
            .iter()
            .map(|&v| (v / parts.s_x).round().clamp(-127.0, 127.0) as i8)
            .collect();
        let acc = quantized_accuracy(
            parts.model.clone(),
            Tensor::from_i8(&[n, MLP4_IN], xq).unwrap(),
            &parts.data.y,
            MLP4_CLASSES,
        );
        assert!(acc > 0.8, "int4 MLP accuracy {acc}");
    }

    #[test]
    fn bipolar_cnn_validates_and_beats_chance() {
        let parts = bipolar_parts();
        check_model(&parts.model).unwrap();
        let widths: Vec<&str> = parts
            .model
            .metadata
            .iter()
            .filter(|(k, _)| k.starts_with(WIDTH_META_PREFIX))
            .map(|(_, v)| v.as_str())
            .collect();
        assert_eq!(widths, vec!["bipolar", "bipolar"]);

        // Deliberately loose bar: the model exists to exercise the XNOR
        // path end to end, not to chase accuracy — but single-bit weights
        // on 10-class digits must still beat chance (0.1) by a wide
        // margin or the quantization math is broken.
        let n = parts.data.len();
        let xq: Vec<i8> = parts
            .data
            .x
            .iter()
            .map(|&v| if v > 0.0 { 1i8 } else { -1 })
            .collect();
        let acc = quantized_accuracy(
            parts.model.clone(),
            Tensor::from_i8(&[n, 1, 8, 8], xq).unwrap(),
            &parts.data.y,
            BCNN_CLASSES,
        );
        assert!(acc > 0.25, "bipolar CNN accuracy {acc}");
    }

    #[test]
    fn narrow_models_are_deterministic() {
        for m in NarrowModel::ALL {
            assert_eq!(m.model(), m.model(), "{} not deterministic", m.name());
            let a = m.input(3, 1);
            let b = m.input(3, 1);
            assert_eq!(a, b);
            let mut dims = vec![3];
            dims.extend(m.input_dims());
            assert_eq!(a.shape(), &dims[..]);
        }
        // Bipolar inputs are strictly ±1.
        let t = NarrowModel::BipolarCnn.input(2, 7);
        assert!(t.as_i8().unwrap().iter().all(|&v| v == 1 || v == -1));
    }
}
